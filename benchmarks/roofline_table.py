"""Roofline table from the dry-run artifacts (EXPERIMENTS.md SSRoofline).

Reads experiments/dryrun/*.json, prints the three terms per cell, flags
the dominant bottleneck, and nominates the hillclimb candidates: the worst
roofline fraction, the most collective-bound, and the cell most
representative of the paper's technique (decode over the paged KV path).
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_rows(mesh: str = "single") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def main() -> None:
    rows = load_rows("single")
    ok = [r for r in rows if r.get("status") == "ok"]
    for r in ok:
        name = f"roofline/{r['arch']}/{r['shape']}"
        dom = max(("t_compute", "t_memory", "t_collective"),
                  key=lambda k: r.get(k, 0.0))
        emit(name, r["t_compute"] * 1e6 if r.get("t_compute") else 0.0,
             f"t_mem_us={r.get('t_memory', 0)*1e6:.1f};"
             f"t_coll_us={r.get('t_collective', 0)*1e6:.1f};"
             f"bottleneck={r.get('bottleneck', dom)};"
             f"frac={r.get('roofline_fraction', 0):.3f};"
             f"useful={r.get('useful_ratio', 0):.3f};"
             f"GiB_dev={r.get('per_device_memory', 0)/2**30:.2f}")
    for r in rows:
        if r.get("status") == "skip":
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0, "SKIP")
        elif r.get("status") == "fail":
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                 f"FAIL:{r.get('error', '?')[:60]}")
    if ok:
        worst = min(ok, key=lambda r: r.get("roofline_fraction", 1.0))
        coll = max(ok, key=lambda r: r.get("t_collective", 0.0)
                   / max(r.get("t_compute", 1e-12), 1e-12))
        emit("roofline/hillclimb/worst_fraction", 0.0,
             f"{worst['arch']}/{worst['shape']}")
        emit("roofline/hillclimb/most_collective_bound", 0.0,
             f"{coll['arch']}/{coll['shape']}")
        emit("roofline/hillclimb/paper_representative", 0.0,
             "qwen2.5-3b/decode_32k (paged-KV decode = the paper's technique)")


if __name__ == "__main__":
    main()
