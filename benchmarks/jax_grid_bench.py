#!/usr/bin/env python
"""Benchmark: the jax sweep backend vs. the forked-process loop pipeline.

Times the same latency x threads grid through both `sweep_latency`
backends on one shared LSM default-pairing trace and prints one CSV row
per grid size::

    grid,cells,loop_s,jax_warm_s,jax_cold_s,speedup_warm

``loop_s`` uses the default worker-process fan-out (all cores);
``jax_cold_s`` includes jit compilation, ``jax_warm_s`` is the steady
state (best of ``--reps``).  The numbers recorded in
docs/SIMULATION.md's benchmark note come from this script on the repo's
2-core CI-class container.

Usage::

    PYTHONPATH=src python benchmarks/jax_grid_bench.py
    PYTHONPATH=src python benchmarks/jax_grid_bench.py --grids 20x8,40x16
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _grid_axes(spec: str, candidates_all: tuple[int, ...]):
    n_lat, n_cand = (int(x) for x in spec.split("x"))
    lats_us = list(np.round(np.linspace(0.1, 10.0, n_lat), 3))
    # Interpolate a fine thread axis through the canonical candidate range.
    cands = sorted({int(round(c)) for c in np.linspace(
        min(candidates_all), max(candidates_all), n_cand)})
    return lats_us, cands


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--grids", default="20x8,40x16",
                    help="comma-separated LATxTHREADS grid sizes")
    ap.add_argument("--n-ops", type=int, default=5000)
    ap.add_argument("--reps", type=int, default=3,
                    help="warm-run repetitions (best is reported)")
    ap.add_argument("--n-keys", type=int, default=30_000)
    ap.add_argument("--n-wl-ops", type=int, default=10_000)
    args = ap.parse_args()

    from repro.core import workloads
    from repro.core.engines import LSMStore, run_trace
    from repro.core.sim import US, SimConfig
    from repro.core.sim.config import DEFAULT_THREAD_CANDIDATES
    from repro.core.sim.sweep import sweep_latency

    store = LSMStore(args.n_keys)
    wl = workloads.zipf(args.n_keys, args.n_wl_ops, 0.99, (1, 0), seed=3)
    tr = run_trace(store, wl)
    cfg = SimConfig(P=12, seed=7)
    print(f"# trace: {tr.trace!r}", flush=True)
    print("grid,cells,loop_s,jax_warm_s,jax_cold_s,speedup_warm")

    # Time every loop-pipeline grid before jax is ever imported: importing
    # jax switches the pipeline's worker start method off plain fork (see
    # sweep._pick_context), and the loop backend deserves its fast path.
    rows = []
    for spec in args.grids.split(","):
        lats_us, cands = _grid_axes(spec, DEFAULT_THREAD_CANDIDATES)
        lats = [l * US for l in lats_us]
        t0 = time.perf_counter()
        sweep_latency(cfg, tr.trace, lats, cands, n_ops=args.n_ops)
        rows.append((spec, lats, cands, time.perf_counter() - t0))

    for spec, lats, cands, t_loop in rows:
        t0 = time.perf_counter()
        sweep_latency(cfg, tr.trace, lats, cands, n_ops=args.n_ops,
                      backend="jax")
        t_cold = time.perf_counter() - t0
        t_warm = min(
            _timed(sweep_latency, cfg, tr.trace, lats, cands,
                   n_ops=args.n_ops, backend="jax")
            for _ in range(args.reps)
        )
        print(f"{spec},{len(lats) * len(cands)},{t_loop:.2f},{t_warm:.2f},"
              f"{t_cold:.2f},{t_loop / t_warm:.2f}", flush=True)


def _timed(fn, *a, **kw) -> float:
    t0 = time.perf_counter()
    fn(*a, **kw)
    return time.perf_counter() - t0


if __name__ == "__main__":
    main()
