#!/usr/bin/env python
"""Benchmark: the jax sweep backend vs. the forked-process loop pipeline.

Times the same latency x threads grids through both ``sweep_latency``
backends and records the measurements as JSON (schema
``repro.jax_grid_bench/v1``; validated by ``tools/check_bench.py``).
Three suites:

``default``
    The paper's default scenario grid (6 latencies x 5 thread
    candidates, one LSM default-pairing trace).  The loop side is the
    real forked worker pipeline; the acceptance bar is warm jax >= 1x.
``mega``
    The scale story: 4 engines x n_ssd {1,2} x 128 latencies x
    {8,16,32,64} threads -- 4096 cells, each engine x device point
    swept as one jitted grid call (the 2-SSD half uses the matrix
    device config with IO token clocks).  The loop side runs the
    identical cells through the same pipeline (this is the slow part
    of the bench: minutes).  Acceptance bar: warm jax >= 5x.
``het``
    The cohort story: one engine, 64 latencies x a maximally *uneven*
    thread axis (8..128) -- the monolithic single-scan layout (every
    cell padded to 128 threads, all scanned to the global worst-case
    step bound) against the cohort early-exit scan that buckets cells
    by thread width and step bound.  Records ``jax_mono_warm_s`` /
    ``mono_speedup`` (cohort vs. monolithic on identical cells) and the
    wasted-step counters (``cell_steps_bound`` vs ``cell_steps_run``).
    Acceptance bar: cohort >= 1.5x monolithic.
``smoke``
    A seconds-scale slice (one small trace, 8 cells) for CI: same
    schema, compared against the checked-in baseline ratio by the
    perf-smoke job with a generous threshold (machine-to-machine noise
    is expected; a real regression is 5-10x, not 20%).

The checked-in ``BENCH_jax_grid.json`` is produced by::

    PYTHONPATH=src python benchmarks/jax_grid_bench.py \
        --suite default,mega,het,smoke --out BENCH_jax_grid.json

Cold timings include jit compilation; warm is the best of ``--reps``
repetitions.  Every loop grid is timed before jax is first imported, so
the pipeline keeps its plain-fork worker start method (see
``sweep._pick_context``).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

import numpy as np

SCHEMA = "repro.jax_grid_bench/v1"
US = 1e-6

# The mega suite's axes: every registered engine family with a distinct
# suboperation mix, an n_ssd axis (plain single-SSD config vs. the
# matrix 2-SSD device config with IO token clocks), a fine latency
# axis, and the pow2 thread candidates that bucket into one (G, 64)
# grid call per engine x device point.
MEGA_ENGINES = ("lsm", "hash-index", "tree-index", "two-tier-cache")
MEGA_N_SSD = (1, 2)
MEGA_N_LATS = 128
MEGA_CANDS = (8, 16, 32, 64)
MEGA_N_OPS = 2000

# The het suite's axes: a deliberately uneven thread spread (16x between
# the narrowest and widest cell, straddling five pow2 buckets) so the
# monolithic layout's padding-to-T_max and global step bound are maximally
# wasteful -- the structure the cohort scan exists to avoid.
HET_N_LATS = 64
HET_CANDS = (8, 16, 24, 32, 48, 64, 96, 128)
HET_N_OPS = 2000


def _timed(fn, *a, **kw) -> float:
    t0 = time.perf_counter()
    fn(*a, **kw)
    return time.perf_counter() - t0


def _trace(engine: str, n_keys: int, n_wl_ops: int):
    """The engine's default-pairing zipf trace (compiled)."""
    from repro.core import workloads
    from repro.core.engines import available_engines, run_trace

    store = available_engines()[engine](n_keys)
    wl = workloads.zipf(n_keys, n_wl_ops, 0.99, (1, 0), seed=3)
    return run_trace(store, wl).trace


def _suite_specs(suite: str, args):
    """The grids of one suite: (name, engine, dev_kwargs, trace_params,
    lats, cands, n_ops) tuples."""
    from repro.core.experiment import Scenario

    if suite == "default":
        sc = Scenario(engine="lsm")
        return [("default", "lsm", {},
                 (args.n_keys, args.n_wl_ops),
                 [l * US for l in sc.latencies_us],
                 list(sc.thread_candidates), args.n_ops)]
    if suite == "mega":
        lats = [float(l) * US for l in
                np.round(np.linspace(0.1, 10.0, MEGA_N_LATS), 4)]
        devs = {1: {},
                2: dict(n_ssd=2, R_io=250e3, L_switch=0.3 * US)}
        return [(f"mega:{eng}:ssd{n_ssd}", eng, devs[n_ssd],
                 (args.n_keys, args.n_wl_ops),
                 lats, list(MEGA_CANDS), MEGA_N_OPS)
                for eng in MEGA_ENGINES for n_ssd in MEGA_N_SSD]
    if suite == "het":
        lats = [float(l) * US for l in
                np.round(np.linspace(0.1, 10.0, HET_N_LATS), 4)]
        return [("het:lsm", "lsm", {},
                 (args.n_keys, args.n_wl_ops),
                 lats, list(HET_CANDS), HET_N_OPS)]
    if suite == "smoke":
        return [("smoke", "hash-index", {}, (4_000, 1_500),
                 [l * US for l in (0.5, 2, 5, 9)], [8, 16], 800)]
    raise SystemExit(f"unknown suite {suite!r} "
                     "(valid: default, mega, het, smoke)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="default",
                    help="comma-separated: default, mega, smoke")
    ap.add_argument("--out", default=None, metavar="OUT.json",
                    help="write the measurement JSON here (default: "
                         "print to stdout)")
    ap.add_argument("--reps", type=int, default=3,
                    help="warm-run repetitions (best is reported)")
    ap.add_argument("--n-ops", type=int, default=5000,
                    help="measured ops per cell (default suite)")
    ap.add_argument("--n-keys", type=int, default=30_000)
    ap.add_argument("--n-wl-ops", type=int, default=10_000)
    args = ap.parse_args()

    # The perf contract: jax timings use XLA's legacy inline CPU runtime
    # (process-global, so it must be exported before jax initializes --
    # which is also why the loop side runs first, before any jax import).
    os.environ.setdefault("REPRO_JAX_LEGACY_CPU", "1")

    from repro.core.sim import SimConfig
    from repro.core.sim.sweep import sweep_latency

    specs = []
    for suite in args.suite.split(","):
        specs.extend(_suite_specs(suite.strip(), args))

    traces = {}
    for _name, eng, _dev, (nk, nw), *_rest in specs:
        if (eng, nk, nw) not in traces:
            traces[(eng, nk, nw)] = _trace(eng, nk, nw)

    # Loop side first, before jax is ever imported (keeps the pipeline's
    # plain-fork workers).  The pipeline is timed end to end, exactly as
    # a user would run it.
    entries = []
    for name, eng, dev, (nk, nw), lats, cands, n_ops in specs:
        cfg = SimConfig(P=12, seed=7, **dev)
        tr = traces[(eng, nk, nw)]
        t_loop = _timed(sweep_latency, cfg, tr, lats, cands, n_ops=n_ops)
        entries.append({
            "name": name, "engine": eng, "n_ssd": cfg.n_ssd,
            "n_latencies": len(lats), "n_threads": len(cands),
            "cells": len(lats) * len(cands), "n_ops": n_ops,
            "loop_s": round(t_loop, 4), "loop_mode": "pipeline",
        })
        print(f"# {name}: loop pipeline {t_loop:.2f}s "
              f"({len(lats) * len(cands)} cells)", file=sys.stderr,
              flush=True)

    for entry, (name, eng, dev, (nk, nw), lats, cands, n_ops) \
            in zip(entries, specs):
        cfg = SimConfig(P=12, seed=7, **dev)
        tr = traces[(eng, nk, nw)]
        t_cold = _timed(sweep_latency, cfg, tr, lats, cands, n_ops=n_ops,
                        backend="jax")
        t_warm = min(
            _timed(sweep_latency, cfg, tr, lats, cands, n_ops=n_ops,
                   backend="jax")
            for _ in range(args.reps))
        entry["jax_cold_s"] = round(t_cold, 4)
        entry["jax_warm_s"] = round(t_warm, 4)
        entry["warm_speedup"] = round(entry["loop_s"] / t_warm, 3)
        print(f"# {name}: jax cold {t_cold:.2f}s warm {t_warm:.2f}s "
              f"-> {entry['warm_speedup']:.2f}x", file=sys.stderr,
              flush=True)

        if name.startswith("het"):
            # Cohort vs. monolithic on identical cells, both through
            # sweep_grid directly so the comparison excludes the (shared,
            # tiny) sweep_latency wrapper.  bucket_threads=False +
            # early_exit=False is exactly the pre-cohort single-scan
            # layout: one T_max-wide plane, one global step bound.
            from repro.core.sim.replay_jax import sweep_grid

            g = sweep_grid(cfg, tr, lats, cands, n_ops=n_ops)
            t_coh = min(_timed(sweep_grid, cfg, tr, lats, cands,
                               n_ops=n_ops) for _ in range(args.reps))
            _timed(sweep_grid, cfg, tr, lats, cands, n_ops=n_ops,
                   bucket_threads=False, early_exit=False)  # mono compile
            t_mono = min(_timed(sweep_grid, cfg, tr, lats, cands,
                                n_ops=n_ops, bucket_threads=False,
                                early_exit=False)
                         for _ in range(args.reps))
            entry["jax_cohort_warm_s"] = round(t_coh, 4)
            entry["jax_mono_warm_s"] = round(t_mono, 4)
            entry["mono_speedup"] = round(t_mono / t_coh, 3)
            entry["cell_steps_bound"] = int(g.cell_steps_bound)
            entry["cell_steps_run"] = int(g.cell_steps_run)
            saved = 1.0 - g.cell_steps_run / max(g.cell_steps_bound, 1)
            entry["steps_saved_frac"] = round(saved, 4)
            print(f"# {name}: cohort {t_coh:.2f}s vs monolithic "
                  f"{t_mono:.2f}s -> {entry['mono_speedup']:.2f}x "
                  f"(early exit saved {saved:.1%} of bounded steps)",
                  file=sys.stderr, flush=True)

    import jax

    def _agg(prefix):
        sel = [e for e in entries if e["name"].startswith(prefix)]
        if not sel:
            return None
        loop = sum(e["loop_s"] for e in sel)
        warm = sum(e["jax_warm_s"] for e in sel)
        return {"cells": sum(e["cells"] for e in sel),
                "loop_s": round(loop, 4), "jax_warm_s": round(warm, 4),
                "warm_speedup": round(loop / warm, 3)}

    doc = {
        "schema": SCHEMA,
        "host": {
            "platform": platform.platform(),
            "machine": platform.machine(),
            "cpu_count": os.cpu_count(),
            "python": platform.python_version(),
            "jax": jax.__version__,
        },
        "entries": entries,
        "summary": {k: v for k, v in (
            ("default", _agg("default")),
            ("mega", _agg("mega:")),
            ("het", _agg("het")),
            ("smoke", _agg("smoke")),
        ) if v is not None},
    }
    het_sel = [e for e in entries if e["name"].startswith("het")]
    if het_sel:
        coh = sum(e["jax_cohort_warm_s"] for e in het_sel)
        mono = sum(e["jax_mono_warm_s"] for e in het_sel)
        bound = sum(e["cell_steps_bound"] for e in het_sel)
        run = sum(e["cell_steps_run"] for e in het_sel)
        doc["summary"]["het"].update(
            jax_cohort_warm_s=round(coh, 4),
            jax_mono_warm_s=round(mono, 4),
            mono_speedup=round(mono / coh, 3),
            steps_saved_frac=round(1.0 - run / max(bound, 1), 4),
        )
    text = json.dumps(doc, indent=2) + "\n"
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"# wrote {args.out}", file=sys.stderr)
    else:
        print(text, end="")


if __name__ == "__main__":
    main()
