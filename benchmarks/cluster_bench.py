#!/usr/bin/env python
"""Benchmark: sharded-fleet scenarios through ``repro.core.cluster``.

The single-host benches measure one node.  This bench runs the three
fleet situations a sharded SSD-backed KV service actually meets, on the
cluster pipeline (``Scenario.cluster`` -> ``sweep_cluster``), and records
per-node *and* fleet-wide tails under open-loop load:

``hot_shard`` / ``hot_shard_drift``
    Zipf mass concentrates on whichever shard owns the hottest keys (the
    drift variant sharpens the skew across the op stream via the
    ``drifting-zipf`` workload).  Replication 2 with the ``spread`` read
    policy shows replicas absorbing part of the hot shard's read load.
``degraded_node``
    One node's SSD clocks slow mid-run (``io_degrade`` onset at
    ``T_degrade_us``): its tail detaches from the healthy nodes' while
    the fleet percentiles blend both populations.
``migration``
    A shard handover under load: at ``at_frac`` of the op stream, shard
    0's ops start executing on node 2, which then serves two shards.

Protocol, per scenario: a closed-loop capacity probe (lowest-latency
fleet throughput at the suite thread count) fixes ``C``; one open-loop
Poisson sweep at ``LOAD_FRAC x C`` with ``collect_percentiles=True``
produces the entries.  Both phases run through the public
:class:`~repro.core.experiment.Experiment` API, so this bench also
exercises the ``Scenario.cluster`` wiring end to end.

Measurements land in JSON (schema ``repro.cluster_bench/v1``; validated
by ``tools/check_bench.py``: fleet and per-node achieved <= offered,
ordered fleet percentiles, shares summing to 1, and the degraded-node
entry present).  The checked-in ``BENCH_cluster.json`` is produced by::

    PYTHONPATH=src python benchmarks/cluster_bench.py --out BENCH_cluster.json

``--smoke`` shrinks traces and op counts to a seconds-scale CI slice
(same schema); ``--scenario NAME`` restricts to one scenario;
``--backend jax`` replays the per-node cells on the vectorized grid
(fleet tails then come from merged log-histograms, ``source: "hist"``).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys

SCHEMA = "repro.cluster_bench/v1"
US = 1e-6

#: Offered load as a fraction of the probed fleet capacity.
LOAD_FRAC = 0.7

FULL_SIZE = dict(n_keys=30_000, n_wl_ops=12_000, n_ops=4000,
                 latencies_us=(0.5, 2.0, 5.0, 8.0),
                 thread_candidates=(8, 16))
SMOKE_SIZE = dict(n_keys=4_000, n_wl_ops=2_000, n_ops=800,
                  latencies_us=(1.0, 5.0), thread_candidates=(16,))

#: Every fleet scenario routes through a 4-node hash-partitioned cluster
#: behind a 5 us router hop; the scenarios differ in workload skew and
#: per-node state.
N_NODES = 4
L_ROUTE_US = 5.0


def _scenario(name: str, smoke: bool, workload: str, workload_kwargs: dict,
              cluster_extra: dict | None = None):
    from repro.core.experiment import Scenario

    size = SMOKE_SIZE if smoke else FULL_SIZE
    cluster = dict(n_nodes=N_NODES, partition="hash",
                   L_route_us=L_ROUTE_US, **(cluster_extra or {}))
    return Scenario(
        engine="hash-index", engine_kwargs={"seed": 6},
        workload=workload, workload_kwargs=workload_kwargs,
        cluster=cluster, name=name, seed=7, P=12, **size)


def hot_shard(smoke: bool):
    """Static Zipf skew; replication 2 + spread reads shave the hot shard."""
    return _scenario(
        "hot_shard", smoke, "zipf",
        {"exponent": 1.2, "read_write": (1, 0), "seed": 3},
        {"replication": 2, "replica_policy": "spread"})


def hot_shard_drift(smoke: bool):
    """Skew sharpening over the op stream (drifting-zipf), primary reads."""
    return _scenario(
        "hot_shard_drift", smoke, "drifting-zipf",
        {"exponent0": 0.6, "exponent1": 1.4, "read_write": (1, 0),
         "seed": 3})


def degraded_node(smoke: bool):
    """Node 1's SSD clocks slow 4x partway into each cell's virtual run."""
    t_degrade_us = 1_000.0 if smoke else 4_000.0
    return _scenario(
        "degraded_node", smoke, "uniform",
        {"read_write": (1, 0), "seed": 2},
        {"node_overrides": {
            "1": {"io_degrade": 4.0, "T_degrade_us": t_degrade_us}}})


def migration(smoke: bool):
    """Shard 0 hands over to node 2 at 50% of the op stream, under load."""
    return _scenario(
        "migration", smoke, "zipf",
        {"exponent": 1.1, "read_write": (1, 0), "seed": 3},
        {"migrate": {"shard": 0, "to": 2, "at_frac": 0.5}})


#: name -> builder(smoke) for every fleet scenario this bench ships (also
#: the registry behind ``benchmarks.run --list-cluster-scenarios``).
SCENARIOS = {
    "hot_shard": hot_shard,
    "hot_shard_drift": hot_shard_drift,
    "degraded_node": degraded_node,
    "migration": migration,
}


def _degraded_nodes(scenario) -> set[int]:
    return {int(k) for k, ov in scenario.cluster.get(
        "node_overrides", {}).items()
        if float(ov.get("io_degrade", 1.0)) != 1.0}


def _tail_us(tail: dict, field: str) -> float | None:
    v = tail[field]
    return None if v is None else round(v, 3)


def run_scenario(name: str, smoke: bool, backend: str) -> dict:
    import dataclasses

    from repro.core.experiment import Experiment, RunOptions

    scenario = SCENARIOS[name](smoke)
    probe = Experiment(scenario, RunOptions(backend=backend)).run()
    capacity = float(probe.rows[0].throughput)
    rate = LOAD_FRAC * capacity
    print(f"# {name}: fleet capacity {capacity / 1e3:.1f} kops/s at "
          f"L={scenario.latencies_us[0]}us -> offering {LOAD_FRAC:.0%}",
          file=sys.stderr, flush=True)

    open_sc = dataclasses.replace(
        scenario, arrival={"kind": "poisson", "rate": rate, "seed": 11})
    art = Experiment(
        open_sc, RunOptions(backend=backend, collect_percentiles=True),
    ).run()

    degraded = _degraded_nodes(scenario)
    migrate = bool(scenario.cluster.get("migrate"))
    entries = []
    for row in art.rows:
        t = row.tail
        # Fleet achieved load = completed ops / fleet makespan (the fleet
        # is done when its slowest node is).  The artifact's fleet
        # throughput sums per-node rates, which overstates the open-loop
        # rate when migration time-concentrates a node's window.
        active = [nd for nd in row.nodes if nd["n_ops"] > 0]
        achieved = (sum(nd["n_ops"] for nd in active)
                    / max(nd["time"] for nd in active))
        nodes = []
        for nd in row.nodes:
            nt = nd["tail"]
            nodes.append({
                "node": nd["node"],
                "share": round(nd["share"], 6),
                "degraded": nd["node"] in degraded,
                "n_ops": nd["n_ops"],
                "offered_load": round(nt["offered_load"], 1),
                "achieved_load": round(nd["throughput"], 1),
                "p50_us": _tail_us(nt, "p50_us"),
                "p90_us": _tail_us(nt, "p90_us"),
                "p99_us": _tail_us(nt, "p99_us"),
                "max_us": _tail_us(nt, "max_us"),
                "count": nt["count"], "missed": nt["missed"],
            })
        entries.append({
            "name": name, "engine": scenario.engine, "backend": backend,
            "n_nodes": N_NODES, "L_us": row.L_us,
            "n_threads": row.n_threads, "n_ops": scenario.n_ops,
            "migrate": migrate,
            "offered_frac": LOAD_FRAC,
            "offered_load": round(rate, 1),
            "achieved_load": round(achieved, 1),
            "p50_us": _tail_us(t, "p50_us"),
            "p90_us": _tail_us(t, "p90_us"),
            "p99_us": _tail_us(t, "p99_us"),
            "max_us": _tail_us(t, "max_us"),
            "count": t["count"], "missed": t["missed"],
            "miss_rate": round(t["miss_rate"], 6),
            "source": t["source"],
            "nodes": nodes,
        })
    lo, hi = entries[0], entries[-1]
    hot = max(entries[0]["nodes"], key=lambda n: n["share"])
    print(f"# {name}: fleet P99 {lo['p99_us']:.1f}us @ {lo['L_us']}us ... "
          f"{hi['p99_us']:.1f}us @ {hi['L_us']}us "
          f"(hottest shard: node {hot['node']} at {hot['share']:.0%})",
          file=sys.stderr, flush=True)
    return {
        "capacity": round(capacity, 1),
        "entries": entries,
        "summary": {
            "capacity": round(capacity, 1),
            "offered_frac": LOAD_FRAC,
            "n_points": len(entries),
            "n_nodes": N_NODES,
            "hottest_share": hot["share"],
            "degraded_nodes": sorted(degraded),
            "migrate": migrate,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI slice (small traces, 800 ops)")
    ap.add_argument("--scenario", default=None, choices=sorted(SCENARIOS),
                    help="run one fleet scenario (default: all)")
    ap.add_argument("--backend", default="loop",
                    choices=("loop", "generic", "jax"),
                    help="per-node cell backend (default loop: exact "
                         "fleet percentiles; jax merges log-histograms)")
    ap.add_argument("--out", default=None, metavar="OUT.json",
                    help="write the measurement JSON here (default: "
                         "print to stdout)")
    args = ap.parse_args()

    if args.backend == "jax":
        os.environ.setdefault("REPRO_JAX_LEGACY_CPU", "1")

    names = [args.scenario] if args.scenario else sorted(SCENARIOS)
    entries, summary = [], {}
    for name in names:
        res = run_scenario(name, args.smoke, args.backend)
        entries += res["entries"]
        summary[name] = res["summary"]

    doc = {
        "schema": SCHEMA,
        "host": {
            "platform": platform.platform(),
            "machine": platform.machine(),
            "cpu_count": os.cpu_count(),
            "python": platform.python_version(),
        },
        "backend": args.backend,
        "smoke": bool(args.smoke),
        "entries": entries,
        "summary": summary,
    }
    text = json.dumps(doc, indent=2) + "\n"
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"# wrote {args.out}", file=sys.stderr)
    else:
        print(text, end="")


if __name__ == "__main__":
    main()
