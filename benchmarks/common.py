"""Shared helpers for the per-figure benchmarks.

Every benchmark prints CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is the simulated (virtual-time) microseconds per KV
operation at the row's operating point and ``derived`` carries the
figure-specific quantity (normalized throughput, model error, ...).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import workloads
from repro.core.kvstore import LSMStore, TreeIndexStore, TwoTierCacheStore, run_trace
from repro.core.latency_model import US, OpParams
from repro.core.simulator import SimConfig, best_over_threads, simulate, trace_source

L_SWEEP_US = (0.1, 0.3, 0.5, 1, 2, 3, 5, 8, 10)
N_CANDIDATES = (16, 24, 32, 48, 64)


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.4f},{derived}")


def sweep_trace(src, l_us_list=L_SWEEP_US, n_ops=5000, P=12, seed=7, **cfg_kw):
    """Best-over-threads throughput per latency point (paper protocol)."""
    out = {}
    for l_us in l_us_list:
        cfg = SimConfig(L_mem=l_us * US, P=P, seed=seed, **cfg_kw)
        r, n = best_over_threads(cfg, src, n_ops, candidates=N_CANDIDATES)
        out[l_us] = r
    return out


def build_engines(nk=100_000, nops=30_000):
    """The three engines with their default (paper Table 5-ish) workloads."""
    return {
        "aerospike-like": (
            TreeIndexStore(nk, seed=1),
            workloads.uniform(nk, nops, (1, 0), seed=2),
        ),
        "rocksdb-like": (
            LSMStore(nk),
            workloads.zipf(nk, nops, 0.99, (1, 0), seed=3),
        ),
        "cachelib-like": (
            TwoTierCacheStore(nk, seed=4),
            workloads.gaussian(nk, nops, 0.08, (2, 1), seed=5),
        ),
    }


def engine_trace(name, store, wl):
    tr = run_trace(store, wl)
    p = tr.op_params(store.times, P=12, T_sw=0.05 * US)
    return tr, p, trace_source(tr.ops)
