"""Shared helpers for the per-figure benchmarks.

Every benchmark prints CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is the simulated (virtual-time) microseconds per KV
operation at the row's operating point and ``derived`` carries the
figure-specific quantity (normalized throughput, model error, ...).

Latency sweeps run through :func:`repro.core.sim.sweep_latency`: one
compiled trace shared across the whole latency x threads grid, cells fanned
out over worker processes.  ``benchmarks.run`` can point ``SWEEP_CACHE`` at
a directory (``--sweep-cache``) to memoize finished cells across runs and
``SWEEP_PROCESSES`` (``--processes``) at a worker count.
"""
from __future__ import annotations

from repro.core import workloads
from repro.core.engines import LSMStore, TreeIndexStore, TwoTierCacheStore, run_trace
from repro.core.latency_model import US
from repro.core.sim import SimConfig, sweep_latency

L_SWEEP_US = (0.1, 0.3, 0.5, 1, 2, 3, 5, 8, 10)
N_CANDIDATES = (16, 24, 32, 48, 64)

# Set by benchmarks.run from --processes / --sweep-cache.
SWEEP_PROCESSES: int | None = None
SWEEP_CACHE: str | None = None


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.4f},{derived}")


def sweep_points(source, l_us_list=L_SWEEP_US, candidates=N_CANDIDATES,
                 n_ops=5000, P=12, seed=7, **cfg_kw):
    """Paper protocol over the fast pipeline: best-over-threads per latency.

    Returns ``{l_us: SweepPoint}`` in input order.  ``source`` is anything
    :func:`sweep_latency` accepts (compiled trace, TraceResult, op list, or
    a legacy callable source).
    """
    cfg = SimConfig(P=P, seed=seed, **cfg_kw)
    pts = sweep_latency(
        cfg,
        source,
        [l_us * US for l_us in l_us_list],
        candidates,
        n_ops=n_ops,
        processes=SWEEP_PROCESSES,
        cache_dir=SWEEP_CACHE,
    )
    return dict(zip(l_us_list, pts))


def sweep_trace(src, l_us_list=L_SWEEP_US, n_ops=5000, P=12, seed=7, **cfg_kw):
    """Legacy-shaped helper: ``{l_us: SimResult}`` (kept for callers of the
    old API; new code should use :func:`sweep_points`)."""
    pts = sweep_points(src, l_us_list, N_CANDIDATES, n_ops=n_ops, P=P,
                       seed=seed, **cfg_kw)
    return {l_us: pt.result for l_us, pt in pts.items()}


def build_engines(nk=100_000, nops=30_000):
    """The three engines with their default (paper Table 5-ish) workloads."""
    return {
        "aerospike-like": (
            TreeIndexStore(nk, seed=1),
            workloads.uniform(nk, nops, (1, 0), seed=2),
        ),
        "rocksdb-like": (
            LSMStore(nk),
            workloads.zipf(nk, nops, 0.99, (1, 0), seed=3),
        ),
        "cachelib-like": (
            TwoTierCacheStore(nk, seed=4),
            workloads.gaussian(nk, nops, 0.08, (2, 1), seed=5),
        ),
    }


def engine_trace(name, store, wl):
    """Trace + model params + the compiled trace (the sweep-ready source)."""
    tr = run_trace(store, wl)
    p = tr.op_params(store.times, P=12, T_sw=0.05 * US)
    return tr, p, tr.trace
