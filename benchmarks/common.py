"""Shared helpers for the per-figure benchmarks.

Every benchmark prints CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is the simulated (virtual-time) microseconds per KV
operation at the row's operating point and ``derived`` carries the
figure-specific quantity (normalized throughput, model error, ...).

Latency sweeps run through :func:`repro.core.sim.sweep_latency`: one
compiled trace shared across the whole latency x threads grid, cells fanned
out over worker processes.  ``benchmarks.run`` can point ``SWEEP_CACHE`` at
a directory (``--sweep-cache``) to memoize finished cells across runs and
``SWEEP_PROCESSES`` (``--processes``) at a worker count.

The engine x device matrix: any engine in the :mod:`repro.core.engines`
registry can be swept against any device config via :func:`build_engine`
(engine + its default paper-style workload) and :func:`matrix_sweep`
(latency-tolerance curve per (engine, n_ssd) pair) -- this is what
``benchmarks.run --engine NAME --devices N`` and the cross-engine figure
drive.
"""
from __future__ import annotations

from repro.core import workloads
from repro.core.engines import (
    LSMStore,
    TreeIndexStore,
    TwoTierCacheStore,
    get_engine,
    run_trace,
)
from repro.core.latency_model import US
from repro.core.sim import SimConfig, sweep_latency

L_SWEEP_US = (0.1, 0.3, 0.5, 1, 2, 3, 5, 8, 10)
N_CANDIDATES = (16, 24, 32, 48, 64)
MATRIX_L_US = (0.1, 1, 3, 5, 8, 10)

# Set by benchmarks.run from --processes / --sweep-cache.
SWEEP_PROCESSES: int | None = None
SWEEP_CACHE: str | None = None


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.4f},{derived}")


def sweep_points(source, l_us_list=L_SWEEP_US, candidates=N_CANDIDATES,
                 n_ops=5000, P=12, seed=7, **cfg_kw):
    """Paper protocol over the fast pipeline: best-over-threads per latency.

    Returns ``{l_us: SweepPoint}`` in input order.  ``source`` is anything
    :func:`sweep_latency` accepts (compiled trace, TraceResult, op list, or
    a legacy callable source).
    """
    cfg = SimConfig(P=P, seed=seed, **cfg_kw)
    pts = sweep_latency(
        cfg,
        source,
        [l_us * US for l_us in l_us_list],
        candidates,
        n_ops=n_ops,
        processes=SWEEP_PROCESSES,
        cache_dir=SWEEP_CACHE,
    )
    return dict(zip(l_us_list, pts))


def sweep_trace(src, l_us_list=L_SWEEP_US, n_ops=5000, P=12, seed=7, **cfg_kw):
    """Legacy-shaped helper: ``{l_us: SimResult}`` (kept for callers of the
    old API; new code should use :func:`sweep_points`)."""
    pts = sweep_points(src, l_us_list, N_CANDIDATES, n_ops=n_ops, P=P,
                       seed=seed, **cfg_kw)
    return {l_us: pt.result for l_us, pt in pts.items()}


# -- the engine axis ---------------------------------------------------------

# Default (paper Table 5-ish) workload and constructor kwargs per canonical
# engine name.  Workload factories take (n_keys, n_ops).
ENGINE_DEFAULTS = {
    "tree-index": (
        dict(seed=1),
        lambda nk, nops: workloads.uniform(nk, nops, (1, 0), seed=2),
    ),
    "lsm": (
        dict(),
        lambda nk, nops: workloads.zipf(nk, nops, 0.99, (1, 0), seed=3),
    ),
    "two-tier-cache": (
        dict(seed=4),
        lambda nk, nops: workloads.gaussian(nk, nops, 0.08, (2, 1), seed=5),
    ),
    "hash-index": (
        dict(seed=6),
        lambda nk, nops: workloads.uniform(nk, nops, (1, 0), seed=2),
    ),
    "slab-cache": (
        dict(seed=8),
        lambda nk, nops: workloads.zipf(nk, nops, 0.9, (3, 1), seed=8),
    ),
}


def build_engine(name: str, nk: int = 100_000, nops: int = 30_000):
    """One registered engine + its default workload, by any registry name.

    Accepts canonical names, aliases, and CLI-style underscores
    (``hash_index``); unknown engines raise ``KeyError`` listing what is
    registered.
    """
    cls = get_engine(name)
    canonical = cls.engine_name
    kwargs, wl_factory = ENGINE_DEFAULTS.get(
        canonical, (dict(), lambda nk, nops: workloads.uniform(nk, nops, (1, 0), seed=2))
    )
    return cls(nk, **kwargs), wl_factory(nk, nops)


def build_engines(nk=100_000, nops=30_000, names=None):
    """Engines with their default workloads, keyed by paper-facing name.

    The original three keep their paper aliases as keys (existing figures
    index by those); the newer engines use their canonical registry names.
    """
    if names is None:
        names = ("aerospike-like", "rocksdb-like", "cachelib-like",
                 "hash-index", "slab-cache")
    return {name: build_engine(name, nk, nops) for name in names}


def engine_trace(name, store, wl):
    """Trace + model params + the compiled trace (the sweep-ready source)."""
    tr = run_trace(store, wl)
    p = tr.op_params(store.times, P=12, T_sw=0.05 * US)
    return tr, p, tr.trace


# -- the device axis ---------------------------------------------------------

def device_config(n_ssd: int = 1, R_io: float = 0.0, B_io: float = 0.0,
                  L_switch_us: float = 0.0, **cfg_kw) -> SimConfig:
    """A :class:`SimConfig` for one device setup of the matrix.

    ``R_io``/``B_io`` are per-device rates; ``n_ssd > 1`` stripes IOs
    round-robin over per-device token clocks, and only a multi-device pool
    pays the CXL/PCIe switch fan-out hop ``L_switch_us`` per IO (a single
    direct-attached SSD has no switch to cross).
    """
    return SimConfig(n_ssd=n_ssd, R_io=R_io, B_io=B_io,
                     L_switch=L_switch_us * US if n_ssd > 1 else 0.0,
                     **cfg_kw)


def matrix_sweep(engine: str, n_ssd: int = 1, l_us_list=MATRIX_L_US,
                 candidates=N_CANDIDATES, nk: int = 100_000,
                 nops: int = 30_000, n_ops: int = 5000, seed: int = 7,
                 R_io: float = 250e3, L_switch_us: float = 0.3):
    """Latency-tolerance sweep of one (engine, device-count) matrix cell.

    Returns ``(trace_result, {l_us: SweepPoint})``.  Device defaults give
    each SSD a 250 kIOPS random-read token clock -- one device caps the
    IO-richest engines (hash index runs every get through the SSD) while
    two devices free them, so the figure shows both axes: device count
    lifts IOPS-bound curves, memory latency bends the unbound ones.  Pools
    with ``n_ssd > 1`` also pay a 0.3 us switch fan-out hop per IO.
    """
    store, wl = build_engine(engine, nk, nops)
    tr = run_trace(store, wl)
    cfg = device_config(n_ssd=n_ssd, R_io=R_io, L_switch_us=L_switch_us,
                        P=12, seed=seed)
    pts = sweep_latency(
        cfg, tr.trace, [l_us * US for l_us in l_us_list], candidates,
        n_ops=n_ops, processes=SWEEP_PROCESSES, cache_dir=SWEEP_CACHE,
    )
    return tr, dict(zip(l_us_list, pts))
