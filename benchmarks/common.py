"""Shared helpers for the per-figure benchmarks.

Every benchmark prints CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is the simulated (virtual-time) microseconds per KV
operation at the row's operating point and ``derived`` carries the
figure-specific quantity (normalized throughput, model error, ...).

Since the experiment-API redesign this module is a thin layer over
:mod:`repro.core.experiment`: scenarios (engine + workload + device spec +
sweep axes) are first-class library objects, the engine -> default-workload
pairings live in :data:`repro.core.experiment.ENGINE_DEFAULTS`, and
``benchmarks.run --engine/--devices/--scenario`` all execute through
:class:`~repro.core.experiment.Experiment`.  What remains here:

* :func:`emit` -- the CSV row format;
* :func:`sweep_points` / :func:`sweep_trace` -- raw-source sweeps for the
  microbenchmark figures (sources that are not engine scenarios);
* :func:`run_options` -- the module-level ``SWEEP_PROCESSES`` /
  ``SWEEP_CACHE`` globals (set by ``benchmarks.run`` flags) folded into a
  :class:`~repro.core.experiment.RunOptions`;
* deprecation shims (``ENGINE_DEFAULTS``, and delegating ``build_engine`` /
  ``matrix_sweep`` wrappers) for pre-redesign callers.
"""
from __future__ import annotations

import warnings

from repro.core import workloads
from repro.core.engines import get_engine, run_trace
from repro.core.experiment import (
    Experiment,
    RunOptions,
    default_scenario,
)
from repro.core.latency_model import US
from repro.core.sim import SimConfig, sweep_latency

L_SWEEP_US = (0.1, 0.3, 0.5, 1, 2, 3, 5, 8, 10)
N_CANDIDATES = (16, 24, 32, 48, 64)
MATRIX_L_US = (0.1, 1, 3, 5, 8, 10)

# Set by benchmarks.run from --processes / --sweep-cache; library code
# should take a RunOptions instead (see run_options()).
SWEEP_PROCESSES: int | None = None
SWEEP_CACHE: str | None = None


def run_options(**overrides) -> RunOptions:
    """The benchmark CLI's sweep settings as a :class:`RunOptions`."""
    kw = dict(processes=SWEEP_PROCESSES, cache_dir=SWEEP_CACHE)
    kw.update(overrides)
    return RunOptions(**kw)


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.4f},{derived}")


def sweep_points(source, l_us_list=L_SWEEP_US, candidates=N_CANDIDATES,
                 n_ops=5000, P=12, seed=7, **cfg_kw):
    """Paper protocol over the fast pipeline: best-over-threads per latency.

    Returns ``{l_us: SweepPoint}`` in input order.  ``source`` is anything
    :func:`sweep_latency` accepts (compiled trace, TraceResult, op list, or
    a legacy callable source) -- use this for figure sources that are not
    engine scenarios (microbenchmarks, ad-hoc traces); engine sweeps should
    go through :class:`repro.core.experiment.Experiment`.
    """
    cfg = SimConfig(P=P, seed=seed, **cfg_kw)
    opts = run_options()
    pts = sweep_latency(
        cfg,
        source,
        [l_us * US for l_us in l_us_list],
        candidates,
        n_ops=n_ops,
        processes=opts.processes,
        cache_dir=opts.cache_dir,
    )
    return dict(zip(l_us_list, pts))


def sweep_trace(src, l_us_list=L_SWEEP_US, n_ops=5000, P=12, seed=7, **cfg_kw):
    """Legacy-shaped helper: ``{l_us: SimResult}`` (kept for callers of the
    old API; new code should use :func:`sweep_points`)."""
    pts = sweep_points(src, l_us_list, N_CANDIDATES, n_ops=n_ops, P=P,
                       seed=seed, **cfg_kw)
    return {l_us: pt.result for l_us, pt in pts.items()}


# -- the engine axis ---------------------------------------------------------

# Legacy-format engine -> (ctor kwargs, workload factory(nk, nops)) table,
# materialized once from the library pairings.  Kept mutable and consulted
# by build_engine so the pre-redesign registration pattern ("add an entry
# to benchmarks.common.ENGINE_DEFAULTS") keeps affecting sweeps; new code
# should edit repro.core.experiment.ENGINE_DEFAULTS instead.
_LEGACY_DEFAULTS: dict | None = None
_LEGACY_PRISTINE: dict = {}


def _legacy_defaults() -> dict:
    global _LEGACY_DEFAULTS
    if _LEGACY_DEFAULTS is None:
        from repro.core.experiment import ENGINE_DEFAULTS
        from repro.core.workloads import create_workload

        _LEGACY_DEFAULTS = {
            eng: (dict(ekw),
                  lambda nk, nops, _w=wname, _k=wkw: create_workload(
                      _w, nk, nops, **_k))
            for eng, (ekw, wname, wkw) in ENGINE_DEFAULTS.items()
        }
        _LEGACY_PRISTINE.update(_LEGACY_DEFAULTS)
    return _LEGACY_DEFAULTS


def _legacy_override(canonical: str) -> bool:
    """True iff legacy code replaced this engine's entry in the deprecated
    ``ENGINE_DEFAULTS`` table (the entries are compared by identity against
    the snapshot taken when the table was first materialized)."""
    return (_LEGACY_DEFAULTS is not None and
            _LEGACY_DEFAULTS.get(canonical) is not
            _LEGACY_PRISTINE.get(canonical))


def build_engine(name: str, nk: int = 100_000, nops: int = 30_000):
    """One registered engine + its default workload, by any registry name.

    Legacy spelling of :func:`repro.core.experiment.build_engine`; the only
    difference is that it honors entries added to the deprecated
    ``benchmarks.common.ENGINE_DEFAULTS`` table.
    """
    cls = get_engine(name)
    kwargs, wl_factory = _legacy_defaults().get(
        cls.engine_name,
        (dict(), lambda nk, nops: workloads.uniform(nk, nops, (1, 0), seed=2)),
    )
    return cls(nk, **kwargs), wl_factory(nk, nops)


def build_engines(nk=100_000, nops=30_000, names=None):
    """Engines with their default workloads, keyed by paper-facing name.

    The original three keep their paper aliases as keys (existing figures
    index by those); the newer engines use their canonical registry names.
    """
    if names is None:
        names = ("aerospike-like", "rocksdb-like", "cachelib-like",
                 "hash-index", "slab-cache")
    return {name: build_engine(name, nk, nops) for name in names}


def engine_trace(name, store, wl):
    """Trace + model params + the compiled trace (the sweep-ready source)."""
    tr = run_trace(store, wl)
    p = tr.op_params(store.times, P=12, T_sw=0.05 * US)
    return tr, p, tr.trace


# -- the device axis ---------------------------------------------------------

def device_config(n_ssd: int = 1, R_io: float = 0.0, B_io: float = 0.0,
                  L_switch_us: float = 0.0, **cfg_kw) -> SimConfig:
    """A :class:`SimConfig` for one device setup of the matrix.

    ``R_io``/``B_io`` are per-device rates; ``n_ssd > 1`` stripes IOs
    round-robin over per-device token clocks, and only a multi-device pool
    pays the CXL/PCIe switch fan-out hop ``L_switch_us`` per IO (a single
    direct-attached SSD has no switch to cross).
    """
    return SimConfig(n_ssd=n_ssd, R_io=R_io, B_io=B_io,
                     L_switch=L_switch_us * US if n_ssd > 1 else 0.0,
                     **cfg_kw)


def matrix_sweep(engine: str, n_ssd: int = 1, l_us_list=MATRIX_L_US,
                 candidates=N_CANDIDATES, nk: int = 100_000,
                 nops: int = 30_000, n_ops: int = 5000, seed: int = 7,
                 R_io: float = 250e3, L_switch_us: float = 0.3):
    """Latency-tolerance sweep of one (engine, device-count) matrix cell.

    Shim over the experiment layer: builds the equivalent
    :class:`~repro.core.experiment.Scenario` (via :func:`default_scenario`)
    and runs it, so its sweep points are bit-identical to
    ``Experiment(default_scenario(engine, n_ssd=n_ssd)).run()``.  Returns
    the legacy ``(trace_result, {l_us: SweepPoint})`` shape.

    Pre-redesign mutation-based registration is still honored: if legacy
    code replaced this engine's entry in the deprecated
    ``ENGINE_DEFAULTS`` table, the sweep runs the mutated pairing through
    the pre-redesign inline protocol instead of the library scenario.
    """
    canonical = get_engine(engine).engine_name
    if _legacy_override(canonical):
        store, wl = build_engine(engine, nk, nops)
        tr = run_trace(store, wl)
        cfg = device_config(n_ssd=n_ssd, R_io=R_io,
                            L_switch_us=L_switch_us, P=12, seed=seed)
        opts = run_options()
        pts = sweep_latency(
            cfg, tr.trace, [l_us * US for l_us in l_us_list], candidates,
            n_ops=n_ops, processes=opts.processes, cache_dir=opts.cache_dir,
        )
        return tr, dict(zip(l_us_list, pts))
    sc = default_scenario(
        engine, n_ssd=n_ssd, latencies_us=tuple(l_us_list),
        thread_candidates=tuple(candidates), n_keys=nk, n_wl_ops=nops,
        n_ops=n_ops, seed=seed, R_io=R_io, L_switch_us=L_switch_us,
    )
    art = Experiment(sc, run_options()).run()
    return art.trace_result, dict(zip(l_us_list, art.points))


def __getattr__(name):
    if name == "ENGINE_DEFAULTS":
        warnings.warn(
            "benchmarks.common.ENGINE_DEFAULTS moved into the library; "
            "migration map: ENGINE_DEFAULTS -> "
            "repro.core.experiment.ENGINE_DEFAULTS (now "
            "{engine: (engine_kwargs, workload_name, workload_kwargs)} "
            "with workloads resolved via the repro.core.workloads "
            "registry); build_engine -> repro.core.experiment.build_engine",
            DeprecationWarning,
            stacklevel=2,
        )
        # One persistent dict: legacy mutation-based registration
        # (common.ENGINE_DEFAULTS["my-engine"] = (kwargs, factory)) still
        # affects this module's build_engine/build_engines.
        return _legacy_defaults()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
