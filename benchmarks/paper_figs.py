"""One benchmark per paper figure/table (Figs. 3, 10-12, 14-18, Table 6).

Each ``fig_*`` function reproduces the measurement protocol of its figure
with the discrete-event simulator standing in for the FPGA testbed, and
prints CSV rows; ``benchmarks.run`` calls them all.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import workloads
from repro.core.engines import LSMStore, TreeIndexStore, TwoTierCacheStore, run_trace
from repro.core.experiment import Experiment, default_scenario
from repro.core.latency_model import (
    US,
    OpParams,
    PAPER_EXAMPLE,
    SystemParams,
    cost_performance_ratio,
    theta_extended_inv,
    theta_mask_inv,
    theta_mem_inv,
    theta_multi_inv,
    theta_prob_inv,
    theta_single_inv,
)
from repro.core.sim import (
    SimConfig,
    microbenchmark_source,
    simulate,
    simulate_compiled,
    sweep_latency,
)
from repro.core.tiering import FLASH_CXL

from .common import (
    L_SWEEP_US,
    N_CANDIDATES,
    build_engine,
    build_engines,
    emit,
    engine_trace,
    run_options,
    sweep_points,
)

#: the paper's three modified stores (Figs. 11/13); the matrix figure widens
#: this to every registered engine
PAPER_ENGINES = ("aerospike-like", "rocksdb-like", "cachelib-like")


def fig3_model_curves() -> None:
    """Fig. 3: normalized throughput of the four analytical models."""
    L = np.array(L_SWEEP_US) * US
    p = PAPER_EXAMPLE
    curves = {
        "single": theta_single_inv(L, p),
        "multi-unlimited": theta_multi_inv(L, p),
        "mem-P-limited": theta_mem_inv(L, p),
        "masking-only": theta_mask_inv(L, p),
        "probabilistic": theta_prob_inv(L, p),
    }
    for name, inv in curves.items():
        base = inv[0]
        for l_us, v in zip(L_SWEEP_US, inv):
            emit(f"fig3/{name}/L{l_us}us", v / US, f"norm={base / v:.4f}")


def fig10_load_latency() -> None:
    """Fig. 10: load-latency distribution (stall histogram), normal and
    cache-constrained (eps) conditions."""
    src = microbenchmark_source(10, 0.1 * US, 1.5 * US, 0.2 * US)
    for tag, eps in (("60MB-L3", 0.0), ("4MB-L3", 0.05)):
        cfg = SimConfig(L_mem=10 * US, n_threads=48, eps=eps, seed=3,
                        collect_load_hist=True)
        r = simulate(cfg, src, 8000)
        st = np.array(r.load_stalls)
        frac0 = float((st < 0.05 * US).mean())
        frac_tail = float((st > 8 * US).mean())
        emit(f"fig10/{tag}", 1e6 / r.throughput,
             f"zero_stall={frac0:.4f};full_latency_tail={frac_tail:.5f}")


def fig11_microbenchmark() -> None:
    """Fig. 11(a)(b): microbenchmark vs models, two parameter combos."""
    combos = {
        "a": OpParams(M=10, T_io_pre=1.5 * US, T_io_post=0.2 * US, P=12),
        "b": OpParams(M=10, T_io_pre=3.5 * US, T_io_post=2.2 * US, P=12),
    }
    for tag, p in combos.items():
        src = microbenchmark_source(int(p.M), p.T_mem, p.T_io_pre, p.T_io_post)
        pts = sweep_points(src, L_SWEEP_US, N_CANDIDATES, n_ops=5000,
                           P=p.P, seed=5, T_sw=p.T_sw)
        errs = []
        for l_us, pt in pts.items():
            L = np.array([l_us * US])
            prob = 1 / theta_prob_inv(L, p)[0]
            mask = 1 / theta_mask_inv(L, p)[0]
            errs.append(pt.throughput / prob - 1)
            emit(f"fig11{tag}/L{l_us}us", 1e6 / pt.throughput,
                 f"sim_over_prob={pt.throughput / prob:.4f};"
                 f"sim_over_mask={pt.throughput / mask:.4f}")
        emit(f"fig11{tag}/max_model_err", 0.0,
             f"max_abs_rel={max(abs(e) for e in errs):.4f}")


def fig11_kvstores() -> None:
    """Fig. 11(c)(d)(e): the three engines vs models (single core)."""
    for name, (store, wl) in build_engines(names=PAPER_ENGINES).items():
        tr, p, trace = engine_trace(name, store, wl)
        pts = sweep_points(trace, (0.1, 1, 3, 5, 8, 10), N_CANDIDATES,
                           n_ops=5000, P=p.P, seed=7)
        base = None
        for l_us, pt in pts.items():
            if base is None:
                base = pt.throughput
            L = np.array([l_us * US])
            prob = 1 / theta_prob_inv(L, p)[0]
            emit(f"fig11/{name}/L{l_us}us", 1e6 / pt.throughput,
                 f"norm={pt.throughput / base:.4f};"
                 f"sim_over_prob={pt.throughput / prob:.4f}")
        emit(f"fig11/{name}/params", 0.0,
             f"M={p.M:.1f};S={p.S:.3f};Tmem_us={p.T_mem / US:.3f}")


def fig12_extended() -> None:
    """Fig. 12: scenarios where other limits bind; extended model tracks."""
    p = PAPER_EXAMPLE
    src = microbenchmark_source(10, p.T_mem, p.T_io_pre, p.T_io_post)

    # (a) SSD bandwidth-limited (one SSD, big IOs)
    cfg = SimConfig(L_mem=1 * US, n_threads=64, A_io=65536, B_io=2e9, seed=3)
    r = simulate(cfg, src, 4000)
    cap = 2e9 / 65536
    emit("fig12a/ssd_bw", 1e6 / r.throughput,
         f"cap_frac={r.throughput / cap:.3f}")

    # (b) SSD IOPS-limited (slow SATA)
    cfg = SimConfig(L_mem=1 * US, n_threads=64, R_io=75e3, seed=3)
    r = simulate(cfg, src, 4000)
    emit("fig12b/ssd_iops", 1e6 / r.throughput,
         f"cap_frac={r.throughput / 75e3:.3f}")

    # (c) memory-bandwidth throttled
    cfg = SimConfig(L_mem=1 * US, n_threads=64, A_mem=64, B_mem=64 / (0.3 * US),
                    seed=3)
    r = simulate(cfg, src, 4000)
    emit("fig12c/mem_bw", 1e6 / r.throughput,
         f"cap_frac={r.throughput / (1 / (10 * 0.3 * US)):.3f}")

    # (d) small CPU cache: premature eviction
    for eps in (0.0, 0.05):
        cfg = SimConfig(L_mem=5 * US, n_threads=48, eps=eps, seed=3)
        r = simulate(cfg, src, 4000)
        pred = 1 / theta_prob_inv(np.array([5 * US]), p,
                                  sysp=SystemParams(eps=eps))[0]
        emit(f"fig12d/eps{eps}", 1e6 / r.throughput,
             f"sim_over_model={r.throughput / pred:.3f}")

    # (e) tiering rho
    for rho in (1.0, 0.7, 0.3):
        cfg = SimConfig(L_mem=8 * US, n_threads=48, rho=rho, seed=3)
        r = simulate(cfg, src, 4000)
        pred = 1 / theta_prob_inv(np.array([8 * US]), p,
                                  sysp=SystemParams(rho=rho))[0]
        emit(f"fig12e/rho{rho}", 1e6 / r.throughput,
             f"sim_over_model={r.throughput / pred:.3f}")


def fig14_multicore() -> None:
    """Fig. 14: multi-core scaling at 5 us with lock contention."""
    store, wl = build_engine("aerospike-like")
    tr, p, trace = engine_trace("aerospike-like", store, wl)
    base = None
    for cores in (1, 2, 4, 8, 16):
        cfg = SimConfig(L_mem=5 * US, n_threads=32, n_cores=cores,
                        T_lock=0.15 * US, R_io=2.2e6, seed=9)
        r = simulate_compiled(cfg, trace, 3000 * cores)
        if base is None:
            base = r.throughput
        emit(f"fig14/{cores}cores", 1e6 / r.throughput * cores,
             f"speedup={r.throughput / base:.2f}")


def fig15_settings() -> None:
    """Fig. 15: setting variations; geomean degradation at 5 us (paper: 8%)."""
    nk, nops = 60_000, 20_000
    variants = {
        "tree/uniform-ro": (TreeIndexStore(nk, seed=1),
                            workloads.uniform(nk, nops, (1, 0), 2)),
        "tree/zipf1.1-ro": (TreeIndexStore(nk, seed=1),
                            workloads.zipf(nk, nops, 1.1, (1, 0), 2)),
        "tree/uniform-w21": (TreeIndexStore(nk, seed=1),
                             workloads.uniform(nk, nops, (2, 1), 2)),
        "lsm/zipf0.99-ro": (LSMStore(nk), workloads.zipf(nk, nops, 0.99, (1, 0), 3)),
        "lsm/zipf0.8-ro": (LSMStore(nk), workloads.zipf(nk, nops, 0.8, (1, 0), 3)),
        "lsm/zipf0.99-w21": (LSMStore(nk), workloads.zipf(nk, nops, 0.99, (2, 1), 3)),
        "cache/gauss-w21": (TwoTierCacheStore(nk, seed=4),
                            workloads.gaussian(nk, nops, 0.08, (2, 1), 5)),
        "cache/gcl-w11": (TwoTierCacheStore(nk, seed=4),
                          workloads.graph_cache_leader(nk, nops, (1, 1), 5)),
    }
    degs = []
    for name, (store, wl) in variants.items():
        tr, p, trace = engine_trace(name, store, wl)
        pts = sweep_points(trace, (0.1, 5.0), (24, 40, 56), n_ops=4000,
                           P=p.P, seed=11)
        thr = {l_us: pt.throughput for l_us, pt in pts.items()}
        d = 1 - thr[5.0] / thr[0.1]
        degs.append(max(d, 1e-4))
        emit(f"fig15/{name}", 1e6 / thr[5.0], f"degradation_at_5us={d:.4f}")
    geo = float(np.exp(np.mean(np.log(degs))))
    emit("fig15/geomean_degradation", 0.0, f"geomean={geo:.4f}")


def fig16_threads() -> None:
    """Fig. 16: throughput vs thread count (stability of the peak)."""
    p = PAPER_EXAMPLE
    src = microbenchmark_source(10, p.T_mem, p.T_io_pre, p.T_io_post)
    for l_us in (1.0, 5.0):
        vals = []
        for n in (8, 16, 24, 32, 48, 64, 96):
            r = simulate(SimConfig(L_mem=l_us * US, n_threads=n, seed=13),
                         src, 4000)
            vals.append(r.throughput)
            emit(f"fig16/L{l_us}us/N{n}", 1e6 / r.throughput,
                 f"thr_kops={r.throughput / 1e3:.1f}")
        peak_region = max(vals) / np.mean(sorted(vals)[-4:])
        emit(f"fig16/L{l_us}us/peak_stability", 0.0, f"max_over_top4mean={peak_region:.3f}")


def fig17_op_latency() -> None:
    """Fig. 17: KV operation latency grows mildly with memory latency."""
    store, wl = build_engine("aerospike-like")
    tr, p, trace = engine_trace("aerospike-like", store, wl)
    base = None
    for l_us in (0.1, 2, 5, 10):
        cfg = SimConfig(L_mem=l_us * US, n_threads=32, seed=15)
        r = simulate_compiled(cfg, trace, 4000, collect_latency=True)
        lat = r.mean_op_latency
        if base is None:
            base = lat
        emit(f"fig17/L{l_us}us", lat / US, f"latency_ratio={lat / base:.2f}")


def table6_cpr() -> None:
    """Table 6: cost-performance ratios, with the tail-latency profile of
    Sec. 5.1 driving the measured degradation d for flash."""
    store, wl = build_engine("aerospike-like")
    tr, p, trace = engine_trace("aerospike-like", store, wl)
    thr = {}
    for tag, lmem in (("dram", 0.1 * US), ("flash", FLASH_CXL.latency_spec())):
        cfg = SimConfig(P=p.P, seed=17)
        (pt,) = sweep_latency(cfg, trace, [lmem], N_CANDIDATES, n_ops=5000)
        thr[tag] = pt.throughput
    d_flash = 1 - thr["flash"] / thr["dram"]
    emit("table6/flash_tail_degradation", 1e6 / thr["flash"], f"d={d_flash:.4f}")
    for name, b, d in (
        ("compressed-dram-lo", 1 / 2, 0.02),
        ("compressed-dram-hi", 1 / 3, 0.0),
        ("flash-lo", 0.2, max(d_flash, 0.02)),
        ("flash-hi", 0.15, 0.02),
    ):
        r = cost_performance_ratio(0.4, b, d)
        emit(f"table6/cpr/{name}", 0.0, f"r={r:.3f}")


def fig18_capacity() -> None:
    """Fig. 18: spend the DRAM savings on capacity: a 4x larger block cache
    on microsecond memory beats the small DRAM-only cache."""
    nk, nops = 200_000, 30_000
    wl = workloads.zipf(nk, nops, 0.7, (1, 0), seed=19)
    small = LSMStore(nk, cache_blocks=nk // 10 // 12)   # DRAM-sized cache
    big = LSMStore(nk, cache_blocks=4 * (nk // 10 // 12))
    tr_s = run_trace(small, wl)
    tr_b = run_trace(big, wl)
    p_s = tr_s.op_params(small.times, 12, 0.05 * US)
    p_b = tr_b.op_params(big.times, 12, 0.05 * US)
    (pt_small,) = sweep_latency(SimConfig(seed=21), tr_s.trace,
                                [0.1 * US], N_CANDIDATES, n_ops=5000)
    (pt_big,) = sweep_latency(SimConfig(seed=21), tr_b.trace,
                              [FLASH_CXL.latency_spec()], N_CANDIDATES,
                              n_ops=5000)
    r_small, r_big = pt_small.result, pt_big.result
    gain = r_big.throughput / r_small.throughput - 1
    emit("fig18/lsm_small_dram", 1e6 / r_small.throughput,
         f"hit={tr_s.hit_stats['block_cache']:.3f}")
    emit("fig18/lsm_big_cxl", 1e6 / r_big.throughput,
         f"hit={tr_b.hit_stats['block_cache']:.3f};gain={gain:+.3f}")


def fig13_engine_matrix() -> None:
    """Engine x device matrix: the paper's key qualitative result across the
    full registry.  One latency-tolerance curve per (engine, SSD count) --
    IO-rich engines (hash index: S=1) stay near-flat out to 10 us while
    cache engines with high hit rates (few IOs to hide behind) degrade
    fastest; doubling the SSDs moves every IOPS-bound curve up without
    changing its latency-tolerance shape.  Each cell is one declarative
    scenario through the public experiment API."""
    lats = (0.1, 1, 5, 10)
    cands = (24, 40, 56)
    for engine in ("tree-index", "lsm", "two-tier-cache", "hash-index",
                   "slab-cache"):
        for n_ssd in (1, 2):
            sc = default_scenario(engine, n_ssd=n_ssd, latencies_us=lats,
                                  thread_candidates=cands, n_ops=4000)
            art = Experiment(sc, run_options()).run()
            base = art.baseline_throughput
            for row in art.rows:
                emit(f"fig13/{engine}/ssd{n_ssd}/{row.label()}",
                     1e6 / row.throughput,
                     f"norm={row.throughput / base:.4f}")
            d10 = 1 - art.rows[-1].throughput / base
            emit(f"fig13/{engine}/ssd{n_ssd}/degradation_at_10us", 0.0,
                 f"d={d10:.4f};S={art.S:.3f};M={art.M:.2f}")


ALL = [
    fig3_model_curves,
    fig10_load_latency,
    fig11_microbenchmark,
    fig11_kvstores,
    fig12_extended,
    fig13_engine_matrix,
    fig14_multicore,
    fig15_settings,
    fig16_threads,
    fig17_op_latency,
    table6_cpr,
    fig18_capacity,
]
