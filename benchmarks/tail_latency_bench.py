#!/usr/bin/env python
"""Benchmark: tail latency vs. memory latency under open-loop load.

The paper's Eq. 14 story is about *mean* throughput: slow memory inflates
per-op work, the thread pool hides it until the device or CPU cap bites.
This bench replays the same apparatus open-loop -- a Poisson arrival
process offers a fixed load while the memory latency sweeps -- and records
where the *tail* (P50/P99/max sojourn: arrival -> completion, queueing
included) lands at each operating point.  At low offered load the tail
tracks the service time and barely moves with memory latency; near
capacity the queue amplifies every extra microsecond of memory latency
into many microseconds of P99.  That is the Eq.-14-at-the-tail figure.

Protocol, per suite:

1. *Capacity probe*: a closed-loop sweep over the memory-latency axis at
   the suite's fixed thread count; the lowest-latency point's throughput
   is the capacity ``C``.
2. *Open-loop grid*: for each offered-load fraction (0.5 x C, 0.9 x C)
   and each memory latency, one open-loop Poisson sweep cell
   (``sweep_latency`` with an :class:`~repro.core.sim.ArrivalSpec`,
   ``collect_percentiles=True``) on the loop backend -- the exact-sorted
   percentile path, no histogram error.

Measurements land in JSON (schema ``repro.tail_latency_bench/v1``;
validated by ``tools/check_bench.py``: achieved <= offered, P99 >= P50,
>= 2 distinct offered loads).  The checked-in ``BENCH_tail_latency.json``
is produced by::

    PYTHONPATH=src python benchmarks/tail_latency_bench.py \
        --out BENCH_tail_latency.json

``--smoke`` shrinks the trace and op counts to a seconds-scale slice for
CI (same schema); ``--fig tail.png`` additionally renders the P99-vs-L
curves per offered load (matplotlib, Agg).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys

SCHEMA = "repro.tail_latency_bench/v1"
US = 1e-6

LOAD_FRACS = (0.5, 0.9)

# Full suite: the default hash-index pairing trace, one fixed pool of 16
# threads, the paper's memory-latency axis.  Smoke: the jax_grid_bench
# smoke trace (4k keys) and a 4-point latency axis.
FULL = dict(name="tail", engine="hash-index", n_keys=30_000,
            n_wl_ops=10_000, n_ops=4000, threads=16,
            lats_us=(0.1, 0.5, 1.0, 2.0, 3.0, 5.0, 8.0, 10.0))
SMOKE = dict(name="tail-smoke", engine="hash-index", n_keys=4_000,
             n_wl_ops=1_500, n_ops=800, threads=16,
             lats_us=(0.5, 2.0, 5.0, 9.0))


def _trace(engine: str, n_keys: int, n_wl_ops: int):
    from repro.core import workloads
    from repro.core.engines import available_engines, run_trace

    store = available_engines()[engine](n_keys)
    wl = workloads.zipf(n_keys, n_wl_ops, 0.99, (1, 0), seed=3)
    return run_trace(store, wl).trace


def run_suite(suite: dict, backend: str) -> dict:
    from repro.core.sim import ArrivalSpec, SimConfig, sweep_latency

    cfg = SimConfig(P=12, seed=7)
    tr = _trace(suite["engine"], suite["n_keys"], suite["n_wl_ops"])
    lats = [l * US for l in suite["lats_us"]]
    cands = [suite["threads"]]
    n_ops = suite["n_ops"]

    closed = sweep_latency(cfg, tr, lats, cands, n_ops=n_ops,
                           backend=backend)
    capacity = float(closed[0].throughput)
    print(f"# {suite['name']}: capacity {capacity / 1e3:.1f} kops/s at "
          f"L={suite['lats_us'][0]}us x {suite['threads']} threads",
          file=sys.stderr, flush=True)

    entries = []
    for frac in LOAD_FRACS:
        rate = frac * capacity
        spec = ArrivalSpec(kind="poisson", rate=rate, seed=11)
        pts = sweep_latency(cfg, tr, lats, cands, n_ops=n_ops,
                            backend=backend, arrival=spec,
                            collect_percentiles=True)
        for l_us, pt in zip(suite["lats_us"], pts):
            s = pt.result.latency_summary
            entries.append({
                "name": suite["name"], "engine": suite["engine"],
                "L_us": l_us, "n_threads": pt.n_threads, "n_ops": n_ops,
                "offered_frac": frac,
                "offered_load": round(rate, 1),
                "achieved_load": round(float(pt.throughput), 1),
                "p50_us": round(s.p50 / US, 3),
                "p90_us": round(s.p90 / US, 3),
                "p99_us": round(s.p99 / US, 3),
                "max_us": round(s.max / US, 3),
                "count": s.count, "missed": s.missed,
                "miss_rate": round(s.miss_rate, 6),
                "source": s.source,
            })
        lo, hi = entries[-len(lats)], entries[-1]
        print(f"# {suite['name']}: load {frac:.0%} -> P99 "
              f"{lo['p99_us']:.1f}us @ {lo['L_us']}us ... "
              f"{hi['p99_us']:.1f}us @ {hi['L_us']}us",
              file=sys.stderr, flush=True)
    return {"capacity": round(capacity, 1), "entries": entries}


def render_fig(entries: list[dict], path: str) -> None:
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(6, 4))
    fracs = sorted({e["offered_frac"] for e in entries})
    for frac in fracs:
        sel = sorted((e for e in entries if e["offered_frac"] == frac),
                     key=lambda e: e["L_us"])
        ax.plot([e["L_us"] for e in sel], [e["p99_us"] for e in sel],
                marker="o", label=f"P99 @ {frac:.0%} load")
        ax.plot([e["L_us"] for e in sel], [e["p50_us"] for e in sel],
                marker=".", linestyle="--", label=f"P50 @ {frac:.0%} load")
    ax.set_xlabel("memory latency L (us)")
    ax.set_ylabel("sojourn latency (us)")
    ax.set_yscale("log")
    ax.set_title("Open-loop tail vs. memory latency (Eq. 14 at the tail)")
    ax.legend(fontsize=8)
    ax.grid(True, alpha=0.3)
    fig.tight_layout()
    fig.savefig(path, dpi=110)
    print(f"# wrote {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI slice (small trace, 800 ops)")
    ap.add_argument("--backend", default="loop", choices=("loop", "jax"),
                    help="sweep backend (default loop: exact percentiles; "
                         "jax uses the log-histogram path)")
    ap.add_argument("--out", default=None, metavar="OUT.json",
                    help="write the measurement JSON here (default: "
                         "print to stdout)")
    ap.add_argument("--fig", default=None, metavar="OUT.png",
                    help="also render the P50/P99-vs-latency figure")
    args = ap.parse_args()

    if args.backend == "jax":
        os.environ.setdefault("REPRO_JAX_LEGACY_CPU", "1")

    suite = SMOKE if args.smoke else FULL
    res = run_suite(suite, args.backend)

    doc = {
        "schema": SCHEMA,
        "host": {
            "platform": platform.platform(),
            "machine": platform.machine(),
            "cpu_count": os.cpu_count(),
            "python": platform.python_version(),
        },
        "backend": args.backend,
        "entries": res["entries"],
        "summary": {
            suite["name"]: {
                "capacity": res["capacity"],
                "offered_fracs": list(LOAD_FRACS),
                "n_points": len(res["entries"]),
            },
        },
    }
    text = json.dumps(doc, indent=2) + "\n"
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"# wrote {args.out}", file=sys.stderr)
    else:
        print(text, end="")
    if args.fig:
        render_fig(res["entries"], args.fig)


if __name__ == "__main__":
    main()
