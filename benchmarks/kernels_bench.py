"""Kernel benchmarks: arithmetic intensity + modeled TPU-v5e time.

This container has no TPU, so wall-clock timings would measure the Python
interpreter, not the kernel. Instead each kernel's FLOPs and HBM bytes are
counted analytically from its blocking structure, and the modeled time is
max(flops/197T, bytes/819G) -- the same roofline the dry-run uses. The
derived column reports arithmetic intensity and whether the kernel is MXU-
or HBM-bound at its default tile sizes, plus the paged kernel's prefetch-
pipeline efficiency from the paper's Theta model at host-memory latency.
"""
from __future__ import annotations

import numpy as np

from repro.core.latency_model import OpParams, theta_prob_inv
from repro.core.tiering import TPU_HOST
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

from .common import emit


def _model_time(flops: float, bytes_: float) -> tuple[float, str]:
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    return max(t_c, t_m), ("mxu" if t_c >= t_m else "hbm")


def flash_attention_bench() -> None:
    D = 128
    for B, Hq, Hkv, S in ((8, 32, 8, 4096), (1, 32, 8, 32768)):
        flops = 4.0 * B * Hq * S * S * D / 2        # causal: half the blocks
        bytes_ = 2 * B * S * D * (Hq + 2 * Hkv)     # q read + kv streamed
        bytes_ += 2 * B * S * Hq * D                # out write
        t, bound = _model_time(flops, bytes_)
        ai = flops / bytes_
        emit(f"kernels/flash/B{B}S{S}", t * 1e6,
             f"AI={ai:.0f};bound={bound}")


def paged_decode_bench() -> None:
    D, page = 128, 64
    for B, Hq, Hkv, S in ((128, 32, 8, 32768),):
        pages = S // page
        flops = 4.0 * B * Hq * S * D
        bytes_ = 2 * B * pages * page * Hkv * D * 2   # kv pages streamed
        t, bound = _model_time(flops, bytes_)
        emit(f"kernels/paged_decode/B{B}S{S}", t * 1e6,
             f"AI={flops/bytes_:.1f};bound={bound}")
        # prefetch-pipeline efficiency at host-memory latency, via the
        # paper's model: per-page compute vs fetch latency and depth P.
        t_page = (4.0 * Hq * page * D) / PEAK_FLOPS + 2e-7
        other = 2 * (flops / B) / PEAK_FLOPS         # rest of layer approx
        p = OpParams(M=float(pages), T_mem=t_page, T_io_pre=other / 2,
                     T_io_post=other / 2, T_sw=0.0, P=4)
        inv4 = theta_prob_inv(np.array([TPU_HOST.latency]), p)[0]
        inv16 = theta_prob_inv(np.array([TPU_HOST.latency]),
                               OpParams(M=float(pages), T_mem=t_page,
                                        T_io_pre=other / 2, T_io_post=other / 2,
                                        T_sw=0.0, P=16))[0]
        plateau = pages * t_page + other
        emit("kernels/paged_decode/pipeline_eff_P4", inv4 * 1e6,
             f"eff={plateau / inv4:.3f}")
        emit("kernels/paged_decode/pipeline_eff_P16", inv16 * 1e6,
             f"eff={plateau / inv16:.3f}")


def wkv6_bench() -> None:
    B, S, H, D = 8, 4096, 40, 64
    flops = B * S * H * (3 * D * D + 4 * D) * 1.0
    bytes_ = 2 * B * S * H * D * 5                   # r,k,v,w in + out
    t, bound = _model_time(flops, bytes_)
    emit(f"kernels/wkv6/B{B}S{S}", t * 1e6, f"AI={flops/bytes_:.1f};bound={bound}")


ALL = [flash_attention_bench, paged_decode_bench, wkv6_bench]
