# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

``python -m benchmarks.run``            -- paper figures + kernels + roofline
``python -m benchmarks.run --only fig11``
``python -m benchmarks.run --only fig11 --processes 4 --sweep-cache .sweep_cache``

Latency sweeps go through the batched :func:`repro.core.sim.sweep_latency`
pipeline; ``--processes`` sets the worker-process count for the grid and
``--sweep-cache`` memoizes finished sweep cells on disk so repeated runs
only simulate what changed.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter on bench names")
    ap.add_argument("--processes", type=int, default=None,
                    help="worker processes for sweep grids (default: cpu count)")
    ap.add_argument("--sweep-cache", default=None, metavar="DIR",
                    help="directory memoizing finished sweep cells "
                         "(e.g. .sweep_cache)")
    args = ap.parse_args()

    from . import common, kernels_bench, paper_figs, roofline_table

    common.SWEEP_PROCESSES = args.processes
    common.SWEEP_CACHE = args.sweep_cache

    benches = [(f.__name__, f) for f in paper_figs.ALL]
    benches += [(f.__name__, f) for f in kernels_bench.ALL]
    benches += [("roofline_table", roofline_table.main)]

    print("name,us_per_call,derived")
    failed = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"bench/{name}/wall,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"bench/{name}/wall,0,FAILED:{type(e).__name__}:{e}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
