# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

``python -m benchmarks.run``            -- paper figures + kernels + roofline
``python -m benchmarks.run --only fig11``
``python -m benchmarks.run --only fig11 --processes 4 --sweep-cache .sweep_cache``
``python -m benchmarks.run --scenario examples/scenarios/hash_index_2ssd.json``
                                        -- one declarative scenario through
                                           the public experiment API
``python -m benchmarks.run --suite examples/scenarios``
                                        -- every scenario spec in a directory
                                           as one suite matrix, written to
                                           ``BENCH_<dirname>.json`` for
                                           baseline diffing with
                                           ``tools/artifact_diff.py``
``python -m benchmarks.run --engine hash_index --devices 2``
                                        -- sugar: builds the default matrix
                                           scenario for one engine on N SSDs
``python -m benchmarks.run --list-engines``  / ``--list-workloads``
                                        -- canonical registry names valid in
                                           scenario specs

Latency sweeps go through the batched :func:`repro.core.sim.sweep_latency`
pipeline; ``--processes`` sets the worker-process count for the grid,
``--sweep-cache`` memoizes finished sweep cells on disk so repeated runs
only simulate what changed (``--sweep-cache-clear`` empties it first;
``--sweep-cache-prune MB`` / ``--sweep-cache-prune-days D`` evict
least-recently-used cells instead of everything; cell
keys include the backend and a code-version salt so stale cells never
survive code changes), ``--adaptive`` warm-starts the per-point thread
search from the previous latency point's winner, and ``--backend jax``
replays a scenario's whole grid as one jitted jax call
(see ``docs/SIMULATION.md``; ``--backend-pallas`` routes it through the
fused whole-step scheduler kernel, ``--backend-unroll`` /
``--backend-substeps`` tune scan unrolling and the steps-per-kernel
batch, ``--backend-host-devices`` shards the grid's cells over XLA host
CPU devices).  ``--artifact``
writes the scenario run's full :class:`~repro.core.experiment.RunArtifact`
(sweep table + trace stats + model predictions + config provenance) as
JSON.  ``--arrival KIND --rate OPS_PER_S`` (optionally ``--burst FRAC``)
switches a scenario/engine sweep from the closed loop to an open-loop
arrival process and reports per-cell sojourn tail percentiles
(``p50_us``/``p99_us``/``miss_rate`` in the derived column; see
``docs/TAIL_LATENCY.md``).  ``--nodes N`` (optionally ``--replicas R``,
``--route-latency US``) shards a scenario/engine sweep across an N-node
hash-partitioned cluster behind a router (the
:class:`~repro.core.cluster.ClusterSpec` path; per-node and fleet tails
land in the artifact, see ``docs/CLUSTER.md``), and
``--list-cluster-scenarios`` prints the named fleet scenarios shipped by
``benchmarks.cluster_bench``.  ``--engine`` accepts any name or alias in the ``repro.core.engines``
registry (underscores work: ``hash_index`` == ``hash-index``); ``--devices``
sets the simulated SSD count (per-device IOPS token clocks, round-robin
striping, switch fan-out hop) and ``--cores`` the simulated host CPU core
count (per-core run queues; thread candidates are per core).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _list_registry(kind: str) -> None:
    """Print canonical registry names, one per line (aliases omitted --
    these are the values valid in scenario specs)."""
    if kind == "engines":
        from repro.core.engines import available_engines

        names = sorted({cls.engine_name for cls in
                        available_engines().values()})
    else:
        from repro.core.workloads import available_workloads

        names = sorted({fn.workload_name for fn in
                        available_workloads().values()})
    for name in names:
        print(name)


def emit_artifact(art, prefix: str) -> None:
    """Print one scenario artifact in the benchmark CSV row format."""
    from . import common

    base = art.baseline_throughput
    for row in art.rows:
        derived = (f"norm={row.throughput / base:.4f};"
                   f"threads={row.n_threads};"
                   f"model_kops={row.model_throughput / 1e3:.1f}")
        if row.mean_op_latency_us is not None:
            derived += f";op_latency_us={row.mean_op_latency_us:.3f}"
        if row.tail is not None:
            t = row.tail
            if t["p99_us"] is not None:
                derived += (f";p50_us={t['p50_us']:.3f}"
                            f";p99_us={t['p99_us']:.3f}")
            derived += f";miss_rate={t['miss_rate']:.4f}"
            if t["offered_load"] is not None:
                derived += (f";offered_kops={t['offered_load'] / 1e3:.1f}"
                            f";achieved_kops={t['achieved_load'] / 1e3:.1f}")
        if row.nodes is not None:
            hot = max(row.nodes, key=lambda n: n["share"])
            derived += (f";nodes={len(row.nodes)}"
                        f";hot_node={hot['node']}"
                        f";hot_share={hot['share']:.2f}")
        common.emit(f"{prefix}/{row.label()}", 1e6 / row.throughput, derived)
    last = art.rows[-1]
    common.emit(
        f"{prefix}/summary",
        0.0,
        f"degradation_at_{last.label()[1:]}="
        f"{1 - last.throughput / base:.4f};"
        f"S={art.S:.3f};M={art.M:.2f}",
    )


def run_scenario_cmd(scenario, artifact_out: str | None,
                     collect_latency: bool, adaptive: bool,
                     backend: str = "loop",
                     prefix: str | None = None,
                     backend_opts: dict | None = None,
                     arrival: dict | None = None,
                     cluster: dict | None = None) -> None:
    """Execute one scenario through the public experiment API.

    ``backend_opts`` are jax-backend tuning fields of
    :class:`~repro.core.experiment.RunOptions`
    (``use_pallas``/``unroll``/``substeps``/``host_devices``).
    ``arrival`` (an :class:`~repro.core.sim.ArrivalSpec` dict from
    ``--arrival/--rate/--burst``) overrides the scenario's driver and
    switches on per-cell tail percentiles; ``cluster`` (a partial
    :class:`~repro.core.cluster.ClusterSpec` dict from
    ``--nodes/--replicas/--route-latency``) overlays the scenario's
    fleet shape."""
    import dataclasses as _dc

    from repro.core.experiment import Experiment

    from . import common

    try:
        if arrival is not None:
            scenario = _dc.replace(scenario, arrival=arrival)
        if cluster is not None:
            scenario = _dc.replace(
                scenario, cluster={**dict(scenario.cluster), **cluster})
        # an open-loop run without tail stats is useless -- collect them
        collect_percentiles = bool(scenario.arrival)
        # display_name resolves the engine too: unknown names fail here,
        # before the (expensive) run, with the registry listing
        prefix = prefix or f"scenario/{scenario.display_name}"
        art = Experiment(
            scenario,
            common.run_options(collect_latency=collect_latency,
                               adaptive=adaptive, backend=backend,
                               collect_percentiles=collect_percentiles,
                               **(backend_opts or {})),
        ).run()
    except KeyError as e:  # unknown engine/workload: resolution is lazy and
        sys.exit(str(e.args[0]) if e.args else str(e))  # lists what exists
    except ValueError as e:  # e.g. incompatible --backend combination
        sys.exit(str(e))
    emit_artifact(art, prefix)
    if artifact_out:
        with open(artifact_out, "w") as f:
            f.write(art.to_json())
        print(f"{prefix}/artifact,0.0000,wrote={artifact_out}",
              file=sys.stderr)


def run_suite_cmd(suite_dir: str, out_path: str | None,
                  collect_latency: bool, adaptive: bool,
                  backend: str = "loop",
                  backend_opts: dict | None = None) -> None:
    """Sweep a directory of scenario specs as one suite matrix.

    Every ``*.json`` in ``suite_dir`` is a :class:`Scenario` spec; the
    suite document (``BENCH_<dirname>.json`` by default) carries a shared
    ``index`` (one summary entry per scenario) plus per-scenario ``rows``
    under ``artifacts``, in the shape ``tools/artifact_diff.py`` compares
    suite-wise against a checked-in baseline.  On the loop backend the
    simulator is deterministic in virtual time, so the rows -- unlike the
    ``host`` block and wall-clock fields, which the diff ignores -- are
    machine-independent.
    """
    import json
    import os
    import platform
    from pathlib import Path

    from repro.core.experiment import Experiment, Scenario

    from . import common

    d = Path(suite_dir)
    paths = sorted(d.glob("*.json"))
    if not paths:
        sys.exit(f"no *.json scenario specs in {suite_dir!r}")
    suite = d.name or "suite"
    artifacts: dict = {}
    index: list = []
    t_suite = time.time()
    for path in paths:
        try:
            spec = Scenario.from_json(path.read_text())
        except (OSError, ValueError, TypeError, KeyError) as e:
            sys.exit(f"bad scenario spec {str(path)!r}: {e}")
        name = path.stem
        t0 = time.time()
        try:
            art = Experiment(
                spec,
                common.run_options(
                    collect_latency=collect_latency, adaptive=adaptive,
                    backend=backend,
                    collect_percentiles=bool(spec.arrival),
                    **(backend_opts or {})),
            ).run()
        except (KeyError, ValueError) as e:
            sys.exit(f"scenario {name!r}: {e.args[0] if e.args else e}")
        wall = time.time() - t0
        emit_artifact(art, f"suite/{suite}/{name}")
        rows = json.loads(art.to_json())["rows"]
        artifacts[name] = {"rows": rows}
        cl = spec.cluster_spec()
        index.append({
            "scenario": name,
            "file": path.name,
            "engine": art.engine,
            "workload": art.workload,
            "n_rows": len(rows),
            "arrival": (dict(spec.arrival).get("kind", "closed")
                        if spec.arrival else "closed"),
            "cluster_nodes": cl.n_nodes if cl is not None else 1,
            "wall_s": round(wall, 3),
        })
    doc = {
        "schema": "repro.scenario_suite/v1",
        "suite": suite,
        "backend": backend,
        "host": {
            "platform": platform.platform(),
            "machine": platform.machine(),
            "cpu_count": os.cpu_count(),
            "python": platform.python_version(),
        },
        "index": index,
        "artifacts": artifacts,
        "summary": {
            "n_scenarios": len(index),
            "total_rows": sum(e["n_rows"] for e in index),
            "total_wall_s": round(time.time() - t_suite, 3),
        },
    }
    out = out_path or f"BENCH_{suite}.json"
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"suite/{suite}/artifact,0.0000,wrote={out}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter on bench names")
    ap.add_argument("--processes", type=int, default=None,
                    help="worker processes for sweep grids (default: cpu count)")
    ap.add_argument("--sweep-cache", default=None, metavar="DIR",
                    help="directory memoizing finished sweep cells "
                         "(e.g. .sweep_cache); cells are keyed by config, "
                         "trace, backend, and a code-version salt, so "
                         "cells from older code are never served")
    ap.add_argument("--sweep-cache-clear", action="store_true",
                    help="with --sweep-cache: delete every memoized cell "
                         "in the cache directory before running")
    ap.add_argument("--sweep-cache-prune", type=float, default=None,
                    metavar="MB",
                    help="with --sweep-cache: before running, evict "
                         "least-recently-used cells (mtime order; cache "
                         "hits refresh it) until the cache is at most MB "
                         "megabytes")
    ap.add_argument("--sweep-cache-prune-days", type=float, default=None,
                    metavar="D",
                    help="with --sweep-cache: before running, drop cells "
                         "not used in the last D days (combines with "
                         "--sweep-cache-prune)")
    ap.add_argument("--backend", default="loop", choices=("loop", "jax"),
                    help="with --scenario/--engine: sweep execution "
                         "backend -- 'loop' interpreter cells (default) "
                         "or the vectorized 'jax' grid (one jitted call; "
                         "tolerance-equivalent, see docs/SIMULATION.md)")
    ap.add_argument("--backend-pallas", action="store_true",
                    help="with --backend jax: route the grid through the "
                         "fused whole-step Pallas scheduler kernel "
                         "(bit-identical to the jnp scan; interpreted "
                         "off-TPU)")
    ap.add_argument("--backend-unroll", type=int, default=None, metavar="N",
                    help="with --backend jax: scan unroll factor of the "
                         "jnp path (default: sweep_grid's)")
    ap.add_argument("--backend-substeps", type=int, default=None,
                    metavar="K",
                    help="with --backend jax: scheduler steps batched per "
                         "fused-kernel invocation (must divide the RNG "
                         "chunk; default: sweep_grid's)")
    ap.add_argument("--backend-host-devices", type=int, default=None,
                    metavar="N",
                    help="with --backend jax: shard grid cells over N XLA "
                         "host CPU devices (requires the process to have "
                         "been started with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=N or more; incompatible with --backend-pallas)")
    ap.add_argument("--scenario", default=None, metavar="SPEC.json",
                    help="run one declarative scenario spec through the "
                         "experiment API instead of the paper figures")
    ap.add_argument("--suite", default=None, metavar="DIR",
                    help="run every *.json scenario spec in DIR as one "
                         "suite matrix and write BENCH_<dirname>.json "
                         "(shared artifact index + per-scenario rows; "
                         "compare against a checked-in baseline with "
                         "tools/artifact_diff.py)")
    ap.add_argument("--suite-out", default=None, metavar="OUT.json",
                    help="with --suite: suite document path (default "
                         "BENCH_<dirname>.json in the working directory)")
    ap.add_argument("--artifact", default=None, metavar="OUT.json",
                    help="with --scenario/--engine: write the RunArtifact "
                         "(sweep table + provenance) as JSON")
    ap.add_argument("--collect-latency", action="store_true",
                    help="with --scenario/--engine: record per-op latencies "
                         "(bypasses the sweep cache)")
    ap.add_argument("--adaptive", action="store_true",
                    help="with --scenario/--engine: warm-started thread "
                         "search instead of the full grid (cells run "
                         "serially; --processes has no effect)")
    ap.add_argument("--arrival", default=None,
                    choices=("poisson", "bursty", "diurnal"),
                    help="with --scenario/--engine: drive the sweep "
                         "open-loop with this arrival process instead of "
                         "the closed loop (requires --rate; records "
                         "per-cell sojourn tail percentiles, see "
                         "docs/TAIL_LATENCY.md)")
    ap.add_argument("--rate", type=float, default=None, metavar="OPS_PER_S",
                    help="with --arrival: offered load in ops/sec "
                         "(time-average rate for bursty/diurnal)")
    ap.add_argument("--burst", type=float, default=None, metavar="FRAC",
                    help="with --arrival bursty: ON-state duty cycle in "
                         "(0, 1] (default 0.25; the ON rate is "
                         "rate / FRAC, so the time-average stays --rate)")
    ap.add_argument("--nodes", type=int, default=None, metavar="N",
                    help="with --scenario/--engine: shard the sweep over "
                         "an N-node hash-partitioned cluster behind a "
                         "router (per-node + fleet tails in the "
                         "artifact; see docs/CLUSTER.md)")
    ap.add_argument("--replicas", type=int, default=None, metavar="R",
                    help="with --nodes: replication factor with the "
                         "'spread' read policy (reads rotate over the "
                         "shard's replica set; default 1)")
    ap.add_argument("--route-latency", type=float, default=None,
                    metavar="US",
                    help="with --nodes: router hop in microseconds, paid "
                         "once inbound per op (default 0)")
    ap.add_argument("--engine", default=None, metavar="NAME",
                    help="sugar for --scenario: sweep one registered "
                         "engine's default matrix scenario (any registry "
                         "name/alias, e.g. hash_index)")
    ap.add_argument("--devices", type=int, default=1, metavar="N",
                    help="simulated SSD count for --engine (default 1)")
    ap.add_argument("--cores", type=int, default=1, metavar="N",
                    help="simulated host CPU cores for --engine "
                         "(default 1; thread candidates are per core)")
    ap.add_argument("--list-engines", action="store_true",
                    help="print canonical engine registry names and exit")
    ap.add_argument("--list-workloads", action="store_true",
                    help="print canonical workload registry names and exit")
    ap.add_argument("--list-cluster-scenarios", action="store_true",
                    help="print the named fleet scenarios shipped by "
                         "benchmarks.cluster_bench and exit")
    args = ap.parse_args()

    if args.list_engines:
        _list_registry("engines")
        return
    if args.list_workloads:
        _list_registry("workloads")
        return
    if args.list_cluster_scenarios:
        from .cluster_bench import SCENARIOS

        for name in sorted(SCENARIOS):
            print(name)
        return

    from . import common

    common.SWEEP_PROCESSES = args.processes
    common.SWEEP_CACHE = args.sweep_cache

    if args.sweep_cache_clear:
        if args.sweep_cache is None:
            sys.exit("--sweep-cache-clear requires --sweep-cache DIR")
        from repro.core.sim import clear_sweep_cache

        removed = clear_sweep_cache(args.sweep_cache)
        print(f"sweep-cache: cleared {removed} cell(s) from "
              f"{args.sweep_cache}", file=sys.stderr)

    if (args.sweep_cache_prune is not None
            or args.sweep_cache_prune_days is not None):
        if args.sweep_cache is None:
            sys.exit("--sweep-cache-prune requires --sweep-cache DIR")
        from repro.core.sim import prune_sweep_cache

        max_bytes = (None if args.sweep_cache_prune is None
                     else int(args.sweep_cache_prune * 1e6))
        try:
            removed = prune_sweep_cache(
                args.sweep_cache, max_bytes=max_bytes,
                max_age_days=args.sweep_cache_prune_days)
        except ValueError as e:
            sys.exit(str(e))
        print(f"sweep-cache: pruned {removed} cell(s) from "
              f"{args.sweep_cache}", file=sys.stderr)

    if args.backend == "jax":
        # Perf opt-in (see replay_jax._XLA_CPU_FLAGS): the CLI owns the
        # process, so the legacy CPU runtime is safe here; jax has not
        # initialized yet because replay_jax is imported lazily per sweep.
        import os

        os.environ.setdefault("REPRO_JAX_LEGACY_CPU", "1")
    backend_opts = {"use_pallas": args.backend_pallas,
                    "unroll": args.backend_unroll,
                    "substeps": args.backend_substeps,
                    "host_devices": args.backend_host_devices}

    arrival = None
    if args.arrival is not None:
        if args.rate is None or args.rate <= 0:
            sys.exit("--arrival requires --rate OPS_PER_S > 0")
        arrival = {"kind": args.arrival, "rate": args.rate}
        if args.burst is not None:
            if args.arrival != "bursty":
                sys.exit("--burst only applies to --arrival bursty")
            if not 0 < args.burst <= 1:
                sys.exit("--burst must be in (0, 1]")
            arrival["on_fraction"] = args.burst
    elif args.rate is not None or args.burst is not None:
        sys.exit("--rate/--burst require --arrival KIND")

    cluster = None
    if args.nodes is not None:
        if args.nodes < 1:
            sys.exit("--nodes must be >= 1")
        cluster = {"n_nodes": args.nodes}
        if args.replicas is not None:
            if not 1 <= args.replicas <= args.nodes:
                sys.exit("--replicas must be in [1, --nodes]")
            cluster["replication"] = args.replicas
            cluster["replica_policy"] = "spread"
        if args.route_latency is not None:
            if args.route_latency < 0:
                sys.exit("--route-latency must be >= 0")
            cluster["L_route_us"] = args.route_latency
    elif args.replicas is not None or args.route_latency is not None:
        sys.exit("--replicas/--route-latency require --nodes N")

    print("name,us_per_call,derived")

    if args.suite is not None:
        if args.scenario is not None or args.engine is not None:
            sys.exit("--suite is exclusive with --scenario/--engine")
        if arrival is not None or cluster is not None:
            sys.exit("--suite specs are self-contained; drop "
                     "--arrival/--nodes overlays")
        run_suite_cmd(args.suite, args.suite_out, args.collect_latency,
                      args.adaptive, args.backend,
                      backend_opts=backend_opts)
        return
    if args.suite_out is not None:
        sys.exit("--suite-out requires --suite DIR")

    if args.scenario is not None:
        from repro.core.experiment import Scenario

        try:
            with open(args.scenario) as f:
                spec = f.read()
        except OSError as e:
            sys.exit(f"cannot read scenario spec: {e}")
        try:
            scenario = Scenario.from_json(spec)
        except (ValueError, TypeError, KeyError) as e:
            sys.exit(f"bad scenario spec {args.scenario!r}: {e}")
        run_scenario_cmd(scenario, args.artifact, args.collect_latency,
                         args.adaptive, args.backend,
                         backend_opts=backend_opts, arrival=arrival,
                         cluster=cluster)
        return

    if args.engine is not None:
        if args.devices < 1:
            sys.exit("--devices must be >= 1")
        if args.cores < 1:
            sys.exit("--cores must be >= 1")
        from repro.core.experiment import default_scenario

        try:
            scenario = default_scenario(args.engine, n_ssd=args.devices,
                                        n_cores=args.cores)
        except KeyError as e:  # unknown engine: get_engine lists what exists
            sys.exit(str(e.args[0]) if e.args else str(e))
        prefix = f"matrix/{args.engine}/ssd{args.devices}"
        if args.cores > 1:
            prefix += f"/cores{args.cores}"
        run_scenario_cmd(scenario, args.artifact, args.collect_latency,
                         args.adaptive, args.backend,
                         prefix=prefix,
                         backend_opts=backend_opts, arrival=arrival,
                         cluster=cluster)
        return

    from . import kernels_bench, paper_figs, roofline_table

    benches = [(f.__name__, f) for f in paper_figs.ALL]
    benches += [(f.__name__, f) for f in kernels_bench.ALL]
    benches += [("roofline_table", roofline_table.main)]

    failed = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"bench/{name}/wall,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"bench/{name}/wall,0,FAILED:{type(e).__name__}:{e}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
