# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

``python -m benchmarks.run``            -- paper figures + kernels + roofline
``python -m benchmarks.run --only fig11``
``python -m benchmarks.run --only fig11 --processes 4 --sweep-cache .sweep_cache``
``python -m benchmarks.run --engine hash_index --devices 2``
                                        -- latency-tolerance sweep of one
                                           registered engine on N SSDs

Latency sweeps go through the batched :func:`repro.core.sim.sweep_latency`
pipeline; ``--processes`` sets the worker-process count for the grid and
``--sweep-cache`` memoizes finished sweep cells on disk so repeated runs
only simulate what changed.  ``--engine`` accepts any name or alias in the
``repro.core.engines`` registry (underscores work: ``hash_index`` ==
``hash-index``); ``--devices`` sets the simulated SSD count (per-device
IOPS token clocks, round-robin striping, switch fan-out hop).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def run_engine_matrix(engine: str, devices: int) -> None:
    """One engine x device matrix cell as a full latency-tolerance sweep."""
    from . import common

    try:
        tr, pts = common.matrix_sweep(engine, n_ssd=devices)
    except KeyError as e:  # unknown engine: get_engine lists what exists
        sys.exit(str(e.args[0]) if e.args else str(e))
    base = None
    for l_us, pt in pts.items():
        base = base or pt.throughput
        common.emit(
            f"matrix/{engine}/ssd{devices}/L{l_us}us",
            1e6 / pt.throughput,
            f"norm={pt.throughput / base:.4f};threads={pt.n_threads}",
        )
    l_last = list(pts)[-1]
    common.emit(
        f"matrix/{engine}/ssd{devices}/summary",
        0.0,
        f"degradation_at_{l_last}us={1 - pts[l_last].throughput / base:.4f};"
        f"S={tr.io_per_op:.3f};M={tr.mem_per_op:.2f}",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter on bench names")
    ap.add_argument("--processes", type=int, default=None,
                    help="worker processes for sweep grids (default: cpu count)")
    ap.add_argument("--sweep-cache", default=None, metavar="DIR",
                    help="directory memoizing finished sweep cells "
                         "(e.g. .sweep_cache)")
    ap.add_argument("--engine", default=None, metavar="NAME",
                    help="sweep one registered engine instead of the paper "
                         "figures (any registry name/alias, e.g. hash_index)")
    ap.add_argument("--devices", type=int, default=1, metavar="N",
                    help="simulated SSD count for --engine (default 1)")
    args = ap.parse_args()

    from . import common

    common.SWEEP_PROCESSES = args.processes
    common.SWEEP_CACHE = args.sweep_cache

    print("name,us_per_call,derived")

    if args.engine is not None:
        if args.devices < 1:
            sys.exit("--devices must be >= 1")
        run_engine_matrix(args.engine, args.devices)
        return

    from . import kernels_bench, paper_figs, roofline_table

    benches = [(f.__name__, f) for f in paper_figs.ALL]
    benches += [(f.__name__, f) for f in kernels_bench.ALL]
    benches += [("roofline_table", roofline_table.main)]

    failed = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"bench/{name}/wall,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"bench/{name}/wall,0,FAILED:{type(e).__name__}:{e}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
