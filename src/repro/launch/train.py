"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs a reduced (smoke) or full config on whatever devices exist; the
production meshes are exercised by dryrun.py (this container has 1 CPU
device -- real runs pass --mesh to map onto the pod slice).
"""
from __future__ import annotations

import argparse

from ..configs import get_config, list_archs, smoke_config
from ..train.train_step import TrainHParams
from ..train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    hp = TrainHParams(peak_lr=args.lr, warmup=max(args.steps // 10, 1),
                      total_steps=args.steps)
    tr = Trainer(cfg, hp, ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every)
    tr.hp_global_batch = args.batch
    tr.hp_seq_len = args.seq
    state, log = tr.fit(args.steps)
    for i, m in enumerate(log):
        if i % max(len(log) // 10, 1) == 0 or i == len(log) - 1:
            print(f"step {i:5d} loss={float(m.get('loss', 0)):.4f} "
                  f"gnorm={float(m.get('grad_norm', 0)):.3f} "
                  f"wall={m.get('wall', 0):.2f}s")


if __name__ == "__main__":
    main()
