"""ShapeDtypeStruct stand-ins + sharding assignments for every dry-run cell.

``cell(arch, shape, mesh)`` returns everything ``dryrun.py`` needs:
the step function, abstract kwargs (no allocation anywhere), and
in/out shardings -- for train, prefill and decode kinds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import SHAPES, get_config, shape_applicable, shape_config
from ..distributed.sharding import (
    act_rules,
    batch_shardings,
    cache_shardings,
    state_shardings,
)
from ..models.layers import abstract_params, mesh_context
from ..optim.adamw import AdamWConfig, init_opt_state
from ..train.train_step import TrainHParams, make_train_step
from ..zoo import get_api

__all__ = ["Cell", "make_cell", "batch_specs"]

DTYPE = jnp.bfloat16


def batch_specs(cfg, shape) -> dict[str, SDS]:
    """Abstract model inputs for one (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    out: dict[str, SDS] = {}
    if shape.kind == "decode":
        out["tokens"] = SDS((B, 1), jnp.int32)
        return out
    s_text = S
    if cfg.family == "vlm":
        s_text = S - cfg.n_patches
        out["patches"] = SDS((B, cfg.n_patches, cfg.vision_dim), DTYPE)
    if cfg.family == "encdec":
        out["frames"] = SDS((B, cfg.n_frames, cfg.d_model), DTYPE)
    out["tokens"] = SDS((B, s_text), jnp.int32)
    if shape.kind == "train":
        out["targets"] = SDS((B, S), jnp.int32)
        out["loss_mask"] = SDS((B, S), jnp.float32)
    return out


def default_microbatches(cfg, shape, mesh, policy: str = "baseline") -> int:
    """Pick microbatch count so the per-device microbatch is a few
    sequences (1 for the >=8k-wide models) -- the activation-memory knob."""
    dp = mesh.shape["data"] * (mesh.shape["pod"] if "pod" in mesh.axis_names else 1)
    if policy == "dp2d":
        dp *= mesh.shape["model"]
    b_loc = max(shape.global_batch // dp, 1)
    target = 1 if cfg.d_model >= 8192 else 4
    mb = max(b_loc // target, 1)
    while shape.global_batch % mb:
        mb -= 1
    return max(mb, 1)


def abstract_cache(api, cfg, shape):
    return jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len)
    )


@dataclass
class Cell:
    arch: str
    shape_name: str
    cfg: Any
    fn: Callable              # jit-able step function
    kwargs: dict              # abstract inputs, in fn's argument order
    in_shardings: Any
    out_shardings: Any
    donate: tuple = ()
    skip_reason: str = ""

    @property
    def skipped(self) -> bool:
        return bool(self.skip_reason)


def make_cell(arch: str, shape_name: str, mesh, hp: TrainHParams | None = None,
              cfg_override=None, policy: str = "baseline") -> Cell:
    shape = SHAPES[shape_name]
    cfg0 = cfg_override if cfg_override is not None else get_config(arch)
    ok, why = shape_applicable(cfg0, shape)
    if not ok:
        return Cell(arch, shape_name, cfg0, None, {}, None, None, skip_reason=why)
    cfg = shape_config(cfg0, shape)
    api = get_api(cfg)
    specs = api.param_specs(cfg)
    params_abs = abstract_params(specs)
    p_shard = state_shardings(specs, mesh, policy=policy)
    rules = act_rules(mesh, policy=policy)
    b_abs = batch_specs(cfg, shape)
    b_shard = batch_shardings(b_abs, mesh, policy=policy)
    mdtype = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    if shape.kind == "train":
        hp = hp or TrainHParams(microbatches=default_microbatches(cfg, shape, mesh, policy))
        step = make_train_step(api, cfg, hp, moment_dtype=mdtype)

        def fn(state, batch):
            with mesh_context(mesh, rules):
                return step(state, batch)

        opt_abs = jax.eval_shape(
            lambda p: init_opt_state(p, AdamWConfig(moment_dtype=mdtype)), params_abs
        )
        opt_shard = {
            "m": p_shard,
            "v": p_shard,
            "count": NamedSharding(mesh, P()),
        }
        state_abs = {"params": params_abs, "opt": opt_abs}
        state_shard = {"params": p_shard, "opt": opt_shard}
        return Cell(
            arch, shape_name, cfg, fn,
            {"state": state_abs, "batch": b_abs},
            (state_shard, b_shard),
            (state_shard, None),
            donate=(0,),
        )

    if shape.kind == "prefill":
        # NB: full-sequence logits are never materialized: the lm head is
        # applied to the final position only (last_only=True).
        def fn_last(params, batch):
            with mesh_context(mesh, rules):
                from ..models import (hybrid, moe, rwkv6, transformer, vlm,
                                      whisper)
                mod = {"dense": transformer, "moe": moe, "vlm": vlm,
                       "hybrid": hybrid, "ssm": rwkv6, "encdec": whisper}[cfg.family]
                kw = {}
                if cfg.family == "vlm":
                    kw["patches"] = batch.get("patches")
                    out = mod.forward(params, batch["tokens"], cfg, remat=False,
                                      last_only=True, **kw)
                elif cfg.family == "encdec":
                    out = mod.forward(params, batch["tokens"], cfg,
                                      frames=batch["frames"], remat=False,
                                      last_only=True)
                else:
                    out = mod.forward(params, batch["tokens"], cfg, remat=False,
                                      last_only=True)
                if isinstance(out, tuple):
                    out = out[0]
                return out

        return Cell(
            arch, shape_name, cfg, fn_last,
            {"params": params_abs, "batch": b_abs},
            (p_shard, b_shard),
            None,
        )

    # decode
    cache_abs = abstract_cache(api, cfg, shape)
    c_shard = cache_shardings(cache_abs, mesh, batch_dim=1)

    def fn(params, cache, tokens):
        with mesh_context(mesh, rules):
            return api.decode(params, cache, tokens, cfg)

    tok_shard = batch_shardings({"t": b_abs["tokens"]}, mesh)["t"]
    return Cell(
        arch, shape_name, cfg, fn,
        {"params": params_abs, "cache": cache_abs, "tokens": b_abs["tokens"]},
        (p_shard, c_shard, tok_shard),
        (None, c_shard),
        donate=(1,),
    )
