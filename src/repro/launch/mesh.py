"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state. Single-pod: (16, 16) = 256 chips, axes
(data, model). Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model);
the pod axis composes with data for the batch dimension (DCN-crossing
gradient all-reduce), model parallelism stays inside a pod (ICI).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Mesh over whatever devices exist (tests, smoke runs, examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
