import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step with AdamW,
prefill, or decode) against ShapeDtypeStruct inputs -- no allocation --
compiles it for the production mesh, and records:

  * memory_analysis()  -- proves the cell fits per-device HBM,
  * cost_analysis()    -- HLO FLOPs / bytes for the roofline,
  * collective bytes   -- parsed from the compiled HLO text,
  * the derived roofline terms (repro.roofline.analysis).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import SHAPES, list_archs
from ..roofline.analysis import (
    RooflineTerms,
    active_params,
    collective_bytes,
    count_params,
    dot_bytes,
    model_flops,
)
from .mesh import make_production_mesh
from .specs import make_cell


def _compile_metrics(cell, mesh) -> dict:
    """Compile one cell; return flat metrics dict (per-device where XLA
    reports per-device)."""
    with mesh:
        jitted = jax.jit(
            cell.fn,
            in_shardings=tuple(cell.in_shardings),
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.kwargs.values())
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    counts = coll.pop("_counts")
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "dot_bytes": dot_bytes(hlo_text),
        "coll_total": float(sum(coll.values())),
        "mem": _mem_dict(mem),
        "coll_counts": counts,
    }
    for k, v in coll.items():
        out[f"coll_{k}"] = float(v)
    return out


_FIT_KEYS = ("flops", "bytes", "dot_bytes", "coll_total", "coll_all-gather", "coll_all-reduce",
             "coll_reduce-scatter", "coll_all-to-all", "coll_collective-permute")


def _inner_chunks(cfg, shape_name: str) -> int:
    """SSM/WKV chunk-loop trips per layer for this shape (0 = no loop)."""
    if cfg.family not in ("hybrid", "ssm"):
        return 0
    shape = SHAPES[shape_name]
    S = 1 if shape.kind == "decode" else shape.seq_len
    if S <= 1:
        return 0
    return -(-S // cfg.ssm_chunk)


def _features(cfg, n_layers: int, mb: int, cap: int, shape_name: str):
    """[1, mb, n_layers (, n_attn) (, counted_chunks)] -- metric =
    u + mb*c + n*d (+ n*chunks*e).

    Layer work is linear in n_layers at FIXED total tokens (microbatching
    splits the same tokens: mb is only a per-microbatch overhead). For
    SSM/hybrid the per-layer chunk loop is unrolled only up to ``cap``
    chunks (full unroll at 32k tokens is a compile explosion), so the
    counted-chunks column -- varied via cap across variants -- identifies
    the per-chunk-body coefficient, extrapolated to the real trip count."""
    nc = _inner_chunks(cfg, shape_name)
    if cfg.family == "hybrid":
        from ..models.hybrid import plan_layers

        nm, na, _ = plan_layers(cfg.replace(n_layers=n_layers))
        return [1.0, float(mb), float(nm), float(na),
                float(nm * min(nc, cap))]
    feat = [1.0, float(mb), float(n_layers)]
    if cfg.family == "ssm":
        feat.append(float(n_layers * min(nc, cap)))
    return feat


def _fit_metrics(arch, shape_name, mesh, cfg, real_mb: int,
                 policy: str = "baseline") -> dict | None:
    """cost_analysis counts a while-loop body once, so scanned layer stacks
    (and the microbatch-accumulation scan) under-report FLOPs / bytes /
    collectives. We re-lower the cell at small layer/microbatch counts with
    ALL scans unrolled, fit metric = u + mb*(c + n_layers*d) (hybrid gets a
    separate attention coefficient), and extrapolate -- exact, because
    layers are identical by construction."""
    import numpy as np

    from ..train.train_step import TrainHParams

    is_train = SHAPES[shape_name].kind == "train"
    U = 8  # default inner-chunk unroll cap (attention block loops are <=16)
    if cfg.family == "hybrid":
        # per-layer math is attn_every-independent: fit tiny patterns
        # (attn_every=2 -> 1-2 mamba layers per variant) so the unrolled-
        # chunk lowerings stay cheap, then extrapolate to (n_mamba, n_attn).
        ls = [(2, 1, 16), (4, 1, 16), (3, 1, 16)]
        if is_train:
            ls.append((2, 2, 16))
        if _inner_chunks(cfg, shape_name) > 16:
            ls.append((2, 1, 8))   # second cap point -> chunk-body slope
    elif cfg.family == "ssm":
        ls = [(1, 1, 16), (2, 1, 16)]
        if is_train:
            ls.append((1, 2, 16))
        if _inner_chunks(cfg, shape_name) > 16:
            ls.append((1, 1, 8))
    else:
        ls = [(1, 1, 16), (2, 1, 16)]
        if is_train:
            ls.append((1, 2, 16))
    rows, coefs = [], []
    for L, mb, cap in ls:
        vcfg = cfg.replace(n_layers=L, unroll_inner=cap, unroll_layers=True,
                           remat_groups=0)
        if cfg.family == "hybrid":
            vcfg = vcfg.replace(attn_every=2)
        hp = TrainHParams(microbatches=mb) if is_train else None
        cell = make_cell(arch, shape_name, mesh, hp=hp, cfg_override=vcfg,
                         policy=policy)
        if cell.skipped:
            return None
        rows.append(_compile_metrics(cell, mesh))
        coefs.append(_features(vcfg, L, mb, cap, shape_name))
    A = np.array(coefs)
    nc_real = _inner_chunks(cfg, shape_name)
    target = _features(cfg, cfg.n_layers, real_mb if is_train else 1,
                       max(nc_real, 16), shape_name)
    fitted = {}
    for key in _FIT_KEYS:
        y = np.array([r.get(key, 0.0) for r in rows])
        sol, *_ = np.linalg.lstsq(A, y, rcond=None)
        fitted[key] = float(max(np.dot(target, sol), y.max()))
    return fitted


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             fit: bool = True, cfg_override=None, policy: str = "baseline",
             microbatches: int | None = None) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    t0 = time.time()
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        hp = None
        if microbatches is not None:
            from ..train.train_step import TrainHParams

            hp = TrainHParams(microbatches=abs(microbatches),
                              remat=microbatches > 0)
        cell = make_cell(arch, shape_name, mesh, hp=hp, cfg_override=cfg_override,
                         policy=policy)
        if cell.skipped:
            if verbose:
                print(f"SKIP  {arch} x {shape_name} x {mesh_name}: {cell.skip_reason}")
            return {**base, "status": "skip", "reason": cell.skip_reason}

        from .specs import default_microbatches

        raw = _compile_metrics(cell, mesh)
        real_mb = (microbatches if microbatches is not None else
                   default_microbatches(cell.cfg, SHAPES[shape_name], mesh, policy))
        fitted = (
            _fit_metrics(arch, shape_name, mesh, cell.cfg, real_mb, policy)
            if fit else None
        )
        use = fitted or raw
        specs = __import__("repro.zoo", fromlist=["get_api"]).get_api(
            cell.cfg
        ).param_specs(cell.cfg)
        n_params = count_params(specs)
        n_active = active_params(cell.cfg, specs)
        shape = SHAPES[shape_name]
        mem_d = raw["mem"]
        per_dev = float(mem_d.get("argument_size_in_bytes", 0)
                        + mem_d.get("temp_size_in_bytes", 0))
        terms = RooflineTerms(
            arch=arch,
            shape=shape_name,
            mesh=mesh_name,
            chips=mesh.size,
            hlo_flops=use["flops"] * mesh.size,   # cost_analysis is per-device
            hlo_bytes=use["bytes"] * mesh.size,
            hbm_bytes_est=use["dot_bytes"] * mesh.size,
            coll_bytes_link=use["coll_total"],
            coll_by_kind={k: use.get(f"coll_{k}", 0.0) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute")},
            model_flops=model_flops(cell.cfg, shape, n_params, n_active),
            per_device_memory=per_dev,
        )
        row = terms.row()
        row.update(
            status="ok",
            n_params=n_params,
            n_active=n_active,
            compile_s=time.time() - t0,
            memory_analysis=mem_d,
            raw_metrics={k: raw.get(k) for k in _FIT_KEYS},
            fitted=bool(fitted),
            coll_counts=raw["coll_counts"],
        )
        if verbose:
            print(
                f"OK    {arch} x {shape_name} x {mesh_name}: "
                f"{row['per_device_memory']/2**30:.2f} GiB/dev, "
                f"flops={row['hlo_flops']:.3e}, "
                f"t_comp={row['t_compute']*1e3:.2f}ms "
                f"t_mem={row['t_memory']*1e3:.2f}ms "
                f"t_coll={row['t_collective']*1e3:.2f}ms "
                f"bottleneck={row['bottleneck']} "
                f"({row['compile_s']:.0f}s)"
            )
        return row
    except Exception as e:  # noqa: BLE001 -- a failed cell is a result, not a crash
        if verbose:
            print(f"FAIL  {arch} x {shape_name} x {mesh_name}: {e}")
            traceback.print_exc()
        return {**base, "status": "fail", "error": f"{type(e).__name__}: {e}",
                "compile_s": time.time() - t0}


def _peak_bytes(mem) -> float:
    for attr in ("peak_memory_in_bytes", "temp_size_in_bytes"):
        if hasattr(mem, attr):
            try:
                extra = (
                    getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)
                )
                if attr == "peak_memory_in_bytes":
                    return float(max(getattr(mem, attr), extra))
                return float(extra)
            except Exception:  # noqa: BLE001
                continue
    return 0.0


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "peak_memory_in_bytes", "alias_size_in_bytes"):
        if hasattr(mem, attr):
            try:
                out[attr] = int(getattr(mem, attr))
            except Exception:  # noqa: BLE001
                pass
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--policy", default="baseline",
                    choices=["baseline", "dp2d", "sp", "serve"])
    ap.add_argument("--no-fit", action="store_true",
                    help="skip the layer-fit lowerings (multi-pod pass: "
                         "compile-only validation, no roofline terms)")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    suffix = "" if args.policy == "baseline" else f"__{args.policy}"
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}{suffix}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"CACHED {tag}")
                    continue
                row = run_cell(arch, shape, mp, policy=args.policy,
                               fit=not args.no_fit)
                row["policy"] = args.policy
                with open(path, "w") as f:
                    json.dump(row, f, indent=1, default=str)


if __name__ == "__main__":
    main()
