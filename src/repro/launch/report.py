"""Assemble EXPERIMENTS.md sections from dry-run artifacts.

``python -m repro.launch.report`` regenerates the SSDry-run and SSRoofline
tables from experiments/dryrun/*.json (SSPerf rows are curated by hand in
EXPERIMENTS.md since they narrate hypotheses).
"""
from __future__ import annotations

import glob
import json
import os
import sys

DIR = "experiments/dryrun"


def load(mesh: str, policy: str | None = None) -> list[dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(DIR, f"*__{mesh}*.json"))):
        base = os.path.basename(p)[: -len(".json")]
        parts = base.split("__")
        pol = parts[3] if len(parts) > 3 else "baseline"
        if policy is not None and pol != policy:
            continue
        with open(p) as f:
            r = json.load(f)
        r["policy"] = r.get("policy", pol)
        rows.append(r)
    return rows


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def dryrun_table(mesh: str) -> str:
    rows = load(mesh, "baseline")
    out = [
        f"| arch | shape | status | GiB/dev | HLO GFLOPs (global) | "
        f"coll GiB/chip | collective mix |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") == "ok":
            mix = r.get("coll_counts", {})
            mixs = " ".join(f"{k.split('-')[-1]}:{v}" for k, v in mix.items() if v)
            out.append(
                f"| {r['arch']} | {r['shape']} | ok | "
                f"{fmt_bytes(r.get('per_device_memory', 0))} | "
                f"{r.get('hlo_flops', 0) / 1e9:.0f} | "
                f"{r.get('coll_bytes_link', 0) / 2**30:.2f} | {mixs} |"
            )
        elif r.get("status") == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - | "
                       f"{r.get('reason', '')[:60]} |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | - | - | - | "
                       f"{r.get('error', '')[:60]} |")
    return "\n".join(out)


def roofline_table(mesh: str) -> str:
    rows = [r for r in load(mesh, "baseline") if r.get("status") == "ok"]
    out = [
        "| arch | shape | t_comp ms | t_mem ms | t_coll ms | bottleneck | "
        "roofline frac | MODEL/HLO flops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute'] * 1e3:.2f} | "
            f"{r['t_memory'] * 1e3:.2f} | {r['t_collective'] * 1e3:.2f} | "
            f"{r['bottleneck']} | {r['roofline_fraction']:.3f} | "
            f"{r['useful_ratio']:.3f} |"
        )
    return "\n".join(out)


def main() -> None:
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(f"### Dry-run ({mesh}-pod)\n")
    print(dryrun_table(mesh))
    print(f"\n### Roofline ({mesh}-pod)\n")
    print(roofline_table(mesh))


if __name__ == "__main__":
    main()
