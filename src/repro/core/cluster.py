"""Sharded-cluster simulation: one host -> a fleet behind a router.

The paper's Eq. 14 analysis is single-host.  Real SSD-backed KV services
that would adopt microsecond-latency memory run as *sharded fleets*: a
router resolves every request to a shard, pays a routing hop, and the
request then executes against that node's engine and device clocks.  This
module grows the single-host pipeline into that shape without touching the
per-node scheduler arithmetic -- a cluster is composed out of the existing
cells, so every per-node result keeps the loop/jax equivalence contracts.

The model
---------
A :class:`ClusterSpec` declares the fleet: node count, hash or key-range
partitioning, replication factor with a read-replica policy, the router
hop ``L_route_us``, optional per-node device overrides (a degraded node is
just ``io_degrade``/``T_degrade_us`` on one node), and an optional
shard-migration event.  Given the compiled trace *and the per-op keys*
(recovered from the workload; trace ops carry no keys), the partitioner
assigns every trace op to a node.  Each node then runs the existing
single-host simulation over its own sub-trace:

  * its ops, in stream order, as a :class:`~repro.core.trace_ir.CompiledTrace`;
  * its own :class:`~repro.core.sim.SimConfig` (base config + overrides,
    seed decorrelated per node);
  * its share of the measured ops (largest-remainder apportionment, so
    shares sum exactly to ``n_ops``);
  * under open-loop load, the client arrival stream *routed*: client
    arrival ``i`` goes to the node owning trace op ``i mod n_trace``, and
    reaches it ``L_route`` later.

The routing recurrence is one stage in front of the per-node scheduler
recurrence: with client arrival :math:`A_i`, the node sees the op at
:math:`A_i + L_{route}`, the node's unchanged recurrence produces the node
sojourn :math:`W_i`, and the client-observed sojourn is
:math:`W_i + L_{route}` (the hop is paid once, inbound; SLA deadlines are
checked in the client frame by giving nodes ``deadline - L_route``).

Fleet reduction: at every (latency, thread-count) cell the fleet
throughput is the sum of node throughputs; the winning thread count is
chosen fleet-wide (same count on every node, first candidate wins ties,
matching :func:`~repro.core.sim.sweep_latency`).  Tail summaries are
reported per node *and* fleet-wide -- exactly merged from per-op sojourns
on the loop backends, merged log-histogram counts on the jax grid.

Degeneracy contract: a trivial spec (one node, replication 1, zero route
hop, no overrides, no migration) reproduces the plain single-host path
byte-for-byte on the generic and compiled loops and bit-identically on
the jax grid -- same sub-trace object, same config, same arrival stream,
same winner rule; see ``tests/test_cluster.py``.

Cluster sweeps do not use the on-disk cell cache: cells are keyed by
sub-traces derived from (trace, keys, spec), and the cluster benchmark
surface is small enough that recomputing is cheaper than proving those
keys stable.
"""
from __future__ import annotations

import dataclasses
import json
import numbers
from dataclasses import dataclass, field, replace
from typing import Iterable, Sequence

import numpy as np

from .sim.arrivals import (
    ArrivalSpec,
    LatencySummary,
    generate_arrivals,
    summarize_exact,
    summarize_hist,
)
from .sim.config import SimConfig, SimResult
from .sim.engine_loop import simulate, simulate_compiled
from .sim.sweep import SweepPoint
from .trace_ir import US, CompiledTrace

__all__ = [
    "ClusterSpec",
    "ClusterPlan",
    "NodeCell",
    "ClusterPoint",
    "shard_of",
    "assign_ops",
    "build_plan",
    "sweep_cluster",
    "CLUSTER_BACKENDS",
]

#: Cluster sweeps run per-node cells on one of the three backends: the
#: compiled fast loop, the generic event loop (equivalence harness), or
#: the vectorized jax grid.
CLUSTER_BACKENDS = ("loop", "generic", "jax")

# Knuth multiplicative hash -- the same constant the zipf workloads use to
# scatter ranked keys, so hash partitioning is uniform over key space.
_HASH_MULT = np.uint64(2654435761)
_HASH_MASK = np.uint64(0xFFFFFFFF)

#: Per-node SimConfig override keys accepted in ``node_overrides`` values
#: (``*_us`` fields are microseconds, converted on application).
NODE_OVERRIDE_FIELDS = ("R_io", "B_io", "n_ssd", "L_switch_us", "L_io_us",
                        "io_degrade", "T_degrade_us")


@dataclass(frozen=True)
class ClusterSpec:
    """Declarative fleet shape, JSON-round-trippable like ``ArrivalSpec``.

    ``node_overrides`` maps node index (as a *string*, the JSON object key
    form) to a dict of :data:`NODE_OVERRIDE_FIELDS`; ``migrate`` is empty
    or ``{"shard": s, "to": t, "at_frac": f}`` -- ops in the trailing
    ``1 - f`` fraction of the op stream whose primary shard is ``s`` are
    served by node ``t`` instead (a handover under load).
    """

    n_nodes: int = 1
    partition: str = "hash"            # "hash" | "range"
    replication: int = 1
    replica_policy: str = "primary"    # "primary" | "spread"
    L_route_us: float = 0.0
    node_overrides: dict = field(default_factory=dict)
    migrate: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {self.n_nodes}")
        if self.partition not in ("hash", "range"):
            raise ValueError(
                f"partition must be 'hash' or 'range', got "
                f"{self.partition!r}")
        if not 1 <= self.replication <= self.n_nodes:
            raise ValueError(
                f"replication must be in [1, n_nodes={self.n_nodes}], got "
                f"{self.replication}")
        if self.replica_policy not in ("primary", "spread"):
            raise ValueError(
                f"replica_policy must be 'primary' or 'spread', got "
                f"{self.replica_policy!r}")
        if self.L_route_us < 0:
            raise ValueError(
                f"L_route_us must be >= 0, got {self.L_route_us}")
        for node, ov in dict(self.node_overrides).items():
            try:
                idx = int(node)
            except (TypeError, ValueError):
                raise ValueError(
                    f"node_overrides keys must be node indices, got "
                    f"{node!r}") from None
            if not 0 <= idx < self.n_nodes:
                raise ValueError(
                    f"node_overrides key {node!r} outside "
                    f"[0, {self.n_nodes})")
            unknown = set(ov) - set(NODE_OVERRIDE_FIELDS)
            if unknown:
                raise ValueError(
                    f"unknown node override field(s) {sorted(unknown)} for "
                    f"node {node}; known: {list(NODE_OVERRIDE_FIELDS)}")
            for k, v in ov.items():
                if not isinstance(v, numbers.Real):
                    raise ValueError(
                        f"node override {k}={v!r} must be numeric")
        if self.migrate:
            mig = dict(self.migrate)
            unknown = set(mig) - {"shard", "to", "at_frac"}
            if unknown:
                raise ValueError(
                    f"unknown migrate field(s) {sorted(unknown)}; known: "
                    "['shard', 'to', 'at_frac']")
            for k in ("shard", "to", "at_frac"):
                if k not in mig:
                    raise ValueError(f"migrate requires {k!r}")
            if not 0 <= int(mig["shard"]) < self.n_nodes:
                raise ValueError(
                    f"migrate shard {mig['shard']} outside "
                    f"[0, {self.n_nodes})")
            if not 0 <= int(mig["to"]) < self.n_nodes:
                raise ValueError(
                    f"migrate to {mig['to']} outside [0, {self.n_nodes})")
            if int(mig["shard"]) == int(mig["to"]):
                raise ValueError("migrate shard and to must differ")
            if not 0.0 <= float(mig["at_frac"]) <= 1.0:
                raise ValueError(
                    f"migrate at_frac must be in [0, 1], got "
                    f"{mig['at_frac']}")

    @property
    def is_trivial(self) -> bool:
        """True when the spec degenerates to the plain single-host path."""
        return (self.n_nodes == 1 and self.L_route_us == 0.0
                and not self.node_overrides and not self.migrate)

    @property
    def L_route(self) -> float:
        return self.L_route_us * US

    def node_config(self, cfg: SimConfig, node: int) -> SimConfig:
        """``cfg`` with this node's device overrides and decorrelated seed
        applied (node 0 with no overrides returns ``cfg`` itself)."""
        ov = dict(self.node_overrides.get(str(node), {}))
        kw = {}
        if "R_io" in ov:
            kw["R_io"] = float(ov["R_io"])
        if "B_io" in ov:
            kw["B_io"] = float(ov["B_io"])
        if "n_ssd" in ov:
            kw["n_ssd"] = int(ov["n_ssd"])
        if "L_switch_us" in ov:
            kw["L_switch"] = float(ov["L_switch_us"]) * US
        if "L_io_us" in ov:
            kw["L_io"] = float(ov["L_io_us"]) * US
        if "io_degrade" in ov:
            kw["io_degrade"] = float(ov["io_degrade"])
        if "T_degrade_us" in ov:
            kw["T_degrade"] = float(ov["T_degrade_us"]) * US
        if node:
            kw["seed"] = cfg.seed + node
        return replace(cfg, **kw) if kw else cfg

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ClusterSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown ClusterSpec field(s) {sorted(unknown)}; known: "
                f"{sorted(known)}")
        return cls(**d)

    def key(self) -> str:
        """Canonical string form, stable across processes."""
        return json.dumps(self.to_dict(), sort_keys=True)


# -- partitioners ------------------------------------------------------------
#
# Pure numpy functions of (keys, spec) shared by every backend, so shard
# assignment is byte-identical no matter which backend replays the cells.


def shard_of(keys, spec: ClusterSpec, n_keys: int) -> np.ndarray:
    """Primary shard of each key (int64 array in ``[0, n_nodes)``)."""
    k = np.asarray(keys, dtype=np.int64)
    if k.size and (k.min() < 0 or k.max() >= n_keys):
        raise ValueError(
            f"keys must lie in [0, n_keys={n_keys}), got range "
            f"[{k.min()}, {k.max()}]")
    if spec.partition == "range":
        # Contiguous key ranges of near-equal width; the last node absorbs
        # the remainder so every key in [0, n_keys) maps in-range.
        return np.minimum(k * spec.n_nodes // n_keys, spec.n_nodes - 1)
    h = (k.astype(np.uint64) * _HASH_MULT) & _HASH_MASK
    return (h % np.uint64(spec.n_nodes)).astype(np.int64)


def replica_set(shard: int, spec: ClusterSpec) -> tuple[int, ...]:
    """Nodes holding a copy of ``shard``: primary plus the next
    ``replication - 1`` nodes in ring order."""
    return tuple((shard + j) % spec.n_nodes for j in range(spec.replication))


def assign_ops(keys, is_write, spec: ClusterSpec, n_keys: int) -> np.ndarray:
    """Serving node of each op in the trace-op stream (int64 array).

    Writes always execute at the primary.  With ``replica_policy ==
    "spread"`` reads rotate over the shard's replica set by op-stream
    index; ``"primary"`` sends reads to the primary too (replicas are then
    capacity headroom only).  A ``migrate`` event reassigns the migrated
    shard's ops from the cut index onward.
    """
    shard = shard_of(keys, spec, n_keys)
    node = shard.copy()
    w = np.asarray(is_write, dtype=bool)
    if w.shape != shard.shape:
        raise ValueError(
            f"keys and is_write disagree: {shard.shape} vs {w.shape}")
    if spec.replication > 1 and spec.replica_policy == "spread":
        idx = np.arange(len(node), dtype=np.int64)
        reads = ~w
        node[reads] = (shard[reads]
                       + idx[reads] % spec.replication) % spec.n_nodes
    if spec.migrate:
        cut = int(float(spec.migrate["at_frac"]) * len(node))
        moved = (np.arange(len(node)) >= cut) & (
            shard == int(spec.migrate["shard"]))
        node[moved] = int(spec.migrate["to"])
    return node


def _subtrace(trace: CompiledTrace, mask: np.ndarray) -> CompiledTrace | None:
    """The ops selected by ``mask``, in stream order, as a new trace.

    Selecting every op returns the *original* trace object (identity, so
    the trivial cluster replays the exact same arrays and ``as_lists``
    cache); selecting none returns ``None``.
    """
    if mask.all():
        return trace
    if not mask.any():
        return None
    starts, ends = trace.bounds[:-1][mask], trace.bounds[1:][mask]
    idx = np.concatenate(
        [np.arange(a, b) for a, b in zip(starts, ends)])
    bounds = np.concatenate(
        [[0], np.cumsum(ends - starts)]).astype(np.int64)
    return CompiledTrace.from_columns(
        trace.kinds[idx], trace.durs[idx], bounds)


def _apportion(total: int, weights: np.ndarray) -> np.ndarray:
    """Integer shares of ``total`` proportional to ``weights`` (largest
    remainder; ties to lower index), summing exactly to ``total``."""
    w = np.asarray(weights, dtype=np.float64)
    s = w.sum()
    if s <= 0:
        raise ValueError("cannot apportion over all-zero weights")
    quota = total * w / s
    base = np.floor(quota).astype(np.int64)
    rem = int(total - base.sum())
    order = np.argsort(-(quota - base), kind="stable")
    base[order[:rem]] += 1
    return base


# -- plan --------------------------------------------------------------------


@dataclass(frozen=True)
class ClusterPlan:
    """Everything a cluster sweep derives once from (trace, keys, spec):
    per-op node assignment, per-node sub-traces/configs/op budgets, and
    (open loop) the routed per-node arrival streams."""

    spec: ClusterSpec
    assignment: np.ndarray            # node of each trace op, stream order
    node_traces: tuple                # CompiledTrace | None per node
    node_cfgs: tuple                  # SimConfig per node
    node_ops: tuple                   # measured ops per node (sum == n_ops)
    node_shares: tuple                # trace-op fraction per node
    node_arrivals: tuple              # np.ndarray | None per node
    node_deadline: float              # node-frame SLA deadline (0 = off)

    @property
    def active(self) -> tuple:
        """Node indices that serve at least one measured op."""
        return tuple(k for k, n in enumerate(self.node_ops) if n > 0)


def _node_arrival_need(cfg: SimConfig, candidates, warmup_ops,
                       node_ops: int) -> int:
    """Arrival timestamps node cells may consume (the plain sweep's widest-
    cell formula, with this node's measured-op budget)."""
    return max(
        cfg.n_cores * c
        + (warmup_ops if warmup_ops is not None else 2 * c * cfg.n_cores)
        + node_ops
        for c in candidates) + 1


def build_plan(
    cfg: SimConfig,
    trace: CompiledTrace,
    keys,
    is_write,
    spec: ClusterSpec,
    n_ops: int,
    warmup_ops: int | None,
    thread_candidates: Sequence[int],
    arrival: ArrivalSpec | None = None,
) -> ClusterPlan:
    """Partition one single-host experiment into per-node pieces.

    ``keys`` / ``is_write`` align 1:1 with ``trace``'s ops in stream order
    (the post-warmup slice of the workload that produced the trace).
    """
    keys = np.asarray(keys)
    if len(keys) != trace.n_ops:
        raise ValueError(
            f"keys has {len(keys)} entries but the trace has "
            f"{trace.n_ops} ops; pass the post-warmup workload slice")
    n_keys = int(keys.max()) + 1 if len(keys) else 1
    assignment = assign_ops(keys, is_write, spec, n_keys)
    counts = np.bincount(assignment, minlength=spec.n_nodes)

    node_traces = tuple(
        _subtrace(trace, assignment == k) for k in range(spec.n_nodes))
    node_cfgs = tuple(
        spec.node_config(cfg, k) for k in range(spec.n_nodes))
    node_ops = tuple(int(v) for v in _apportion(n_ops, counts))
    node_shares = tuple(float(c) / len(assignment) for c in counts)

    deadline = arrival.deadline if arrival is not None else 0.0
    l_route = spec.L_route
    node_deadline = 0.0
    if deadline > 0.0:
        node_deadline = deadline - l_route
        if node_deadline <= 0.0:
            raise ValueError(
                f"deadline ({deadline}s) must exceed the route hop "
                f"({l_route}s); every op would miss")

    node_arrivals: list = [None] * spec.n_nodes
    if arrival is not None:
        n_trace = trace.n_ops
        # Client-stream length so every active node receives the arrivals
        # its widest cell may consume (client arrival i routes to
        # assignment[i % n_trace]); with one node this is exactly the
        # plain sweep's stream.
        n_client = 0
        for k in range(spec.n_nodes):
            if node_ops[k] == 0 or counts[k] == 0:
                continue
            need_k = _node_arrival_need(node_cfgs[k], thread_candidates,
                                        warmup_ops, node_ops[k])
            pos = np.flatnonzero(assignment == k)
            full, rem = divmod(need_k, len(pos))
            if rem == 0:
                length = (full - 1) * n_trace + int(pos[-1]) + 1
            else:
                length = full * n_trace + int(pos[rem - 1]) + 1
            n_client = max(n_client, length)
        arr = generate_arrivals(arrival, n_client)
        stream_nodes = assignment[np.arange(n_client) % n_trace]
        for k in range(spec.n_nodes):
            if node_ops[k] == 0 or counts[k] == 0:
                continue
            node_arr = arr[stream_nodes == k]
            node_arrivals[k] = node_arr + l_route if l_route else node_arr

    return ClusterPlan(
        spec=spec,
        assignment=assignment,
        node_traces=node_traces,
        node_cfgs=node_cfgs,
        node_ops=node_ops,
        node_shares=node_shares,
        node_arrivals=tuple(node_arrivals),
        node_deadline=node_deadline,
    )


# -- sweep -------------------------------------------------------------------


@dataclass(frozen=True)
class NodeCell:
    """One node's contribution to a winning operating point (client frame:
    ``summary`` percentiles include the route hop)."""

    node: int
    share: float                  # fraction of the op stream it serves
    n_ops: int                    # measured ops it simulated
    throughput: float             # ops/sec (0 for idle nodes)
    time: float                   # virtual seconds of its run
    missed: int
    summary: LatencySummary | None


@dataclass
class ClusterPoint(SweepPoint):
    """A :class:`~repro.core.sim.sweep.SweepPoint` whose ``result`` is the
    fleet aggregate, carrying the per-node breakdown."""

    nodes: tuple = ()


def _shift_summary(s: LatencySummary | None,
                   d: float) -> LatencySummary | None:
    """Move a node-frame summary to the client frame (+route hop)."""
    if s is None or d == 0.0 or s.count == 0:
        return s
    return dataclasses.replace(
        s, p50=s.p50 + d, p90=s.p90 + d, p99=s.p99 + d, max=s.max + d)


def _classify(op_latencies, deadline: float) -> tuple[list, int]:
    """Split measured sojourns into (kept, missed) with the loops' exact
    rule, so host-side fleet merging matches the cells' own summaries."""
    if deadline <= 0.0:
        return list(op_latencies), 0
    kept, missed = [], 0
    for v in op_latencies:
        if v > deadline:
            missed += 1
        else:
            kept.append(v)
    return kept, missed


def sweep_cluster(
    cfg: SimConfig,
    trace: CompiledTrace,
    keys,
    is_write,
    spec: ClusterSpec,
    latencies: Iterable,
    thread_candidates: Sequence[int],
    n_ops: int = 5000,
    warmup_ops: int | None = None,
    backend: str = "loop",
    collect_latency: bool = False,
    collect_percentiles: bool = False,
    arrival: ArrivalSpec | dict | None = None,
    use_pallas: bool = False,
    unroll: int | None = None,
    substeps: int | None = None,
    host_devices: int | None = None,
) -> list[ClusterPoint]:
    """Throughput vs. memory latency for a sharded fleet.

    The cluster analogue of :func:`~repro.core.sim.sweep_latency`: every
    (latency, thread count) cell runs once *per node* (each node gets its
    sub-trace, config, measured-op share, and routed arrivals from
    :func:`build_plan`), the fleet throughput at a cell is the sum of node
    throughputs, and the per-latency winner is the thread count -- applied
    fleet-wide -- with the highest fleet throughput (first candidate wins
    ties).  ``backend`` selects how node cells execute: the compiled loop
    (``"loop"``), the generic event loop (``"generic"``, the equivalence
    harness), or the jax grid (``"jax"``; mixture latencies fall back to
    the compiled loop per cell, like the plain sweep).

    Returns one :class:`ClusterPoint` per latency: ``result`` aggregates
    the fleet (throughput summed, makespan time, fleet-merged tail
    summary), ``nodes`` holds each node's :class:`NodeCell` in node order
    (idle nodes included, with zero ops).  All reported latency summaries
    are in the client frame (route hop included).
    """
    if backend not in CLUSTER_BACKENDS:
        raise ValueError(
            f"backend must be one of {CLUSTER_BACKENDS}, got {backend!r}")
    latencies = list(latencies)
    candidates = list(thread_candidates)
    if not latencies or not candidates:
        return []
    if backend == "jax" and collect_latency:
        raise ValueError(
            "per-op latency collection is only available from the loop "
            "backends")
    arrival_spec = None
    if arrival is not None:
        arrival_spec = (arrival if isinstance(arrival, ArrivalSpec)
                        else ArrivalSpec.from_dict(dict(arrival)))

    plan = build_plan(cfg, trace, keys, is_write, spec, n_ops, warmup_ops,
                      candidates, arrival_spec)
    l_route = spec.L_route
    active = plan.active
    if not active:
        raise ValueError("no node serves any measured op")
    # Fleet merging needs raw sojourns from every exactly-merged cell
    # (loop/generic cells, and the jax backend's mixture-latency
    # fallback cells -- run_loop_cell only ever runs those).
    want_raw = collect_percentiles

    def run_loop_cell(k: int, L, c: int) -> SimResult:
        cfg_c = replace(plan.node_cfgs[k], L_mem=L, n_threads=c)
        kw = dict(arrivals=plan.node_arrivals[k],
                  collect_percentiles=collect_percentiles,
                  deadline=plan.node_deadline)
        if backend == "generic":
            return simulate(cfg_c, plan.node_traces[k].as_source(),
                            plan.node_ops[k], warmup_ops,
                            collect_latency or want_raw, **kw)
        return simulate_compiled(cfg_c, plan.node_traces[k],
                                 plan.node_ops[k], warmup_ops,
                                 collect_latency or want_raw, **kw)

    # cells[k][li][ci] -> SimResult; grids[k] -> (GridResult, {li: row})
    # for jax nodes (scalar-latency rows come from the grid call).
    cells: dict = {}
    grids: dict = {}
    scalar_lis = [li for li, L in enumerate(latencies)
                  if isinstance(L, numbers.Real)]
    for k in active:
        if backend == "jax":
            from .sim import replay_jax   # deferred: heavyweight import

            row_of = {}
            grid = None
            if scalar_lis:
                jax_opts = {"use_pallas": use_pallas}
                if unroll is not None:
                    jax_opts["unroll"] = unroll
                if substeps is not None:
                    jax_opts["substeps"] = substeps
                if host_devices is not None:
                    jax_opts["host_devices"] = host_devices
                grid = replay_jax.sweep_grid(
                    plan.node_cfgs[k], plan.node_traces[k],
                    [latencies[li] for li in scalar_lis], candidates,
                    plan.node_ops[k], warmup_ops,
                    arrivals=plan.node_arrivals[k],
                    collect_percentiles=collect_percentiles,
                    deadline=plan.node_deadline, **jax_opts)
                row_of = {li: r for r, li in enumerate(scalar_lis)}
            grids[k] = (grid, row_of)
            cells[k] = [
                [grid.result(row_of[li], ci) if li in row_of
                 else run_loop_cell(k, latencies[li], candidates[ci])
                 for ci in range(len(candidates))]
                for li in range(len(latencies))
            ]
        else:
            cells[k] = [
                [run_loop_cell(k, L, c) for c in candidates]
                for L in latencies
            ]

    points: list[ClusterPoint] = []
    for li, L in enumerate(latencies):
        fleet_thr = [
            sum(cells[k][li][ci].throughput for k in active)
            for ci in range(len(candidates))
        ]
        best = min(range(len(candidates)),
                   key=lambda ci: (-fleet_thr[ci], ci))

        node_cells = []
        fleet_summary = None
        use_hist = backend == "jax" and li in scalar_lis
        if collect_percentiles:
            if use_hist:
                hist = None
                vmax = float("nan")
                missed_total = 0
                for k in active:
                    grid, row_of = grids[k]
                    row = row_of[li]
                    h = grid.lat_hist[row, best]
                    hist = h if hist is None else hist + h
                    m = grid.lat_max[row, best]
                    if not np.isnan(m):
                        vmax = m if np.isnan(vmax) else max(vmax, float(m))
                    missed_total += int(grid.missed[row, best])
                fleet_summary = _shift_summary(
                    summarize_hist(hist, vmax, missed_total), l_route)
            else:
                kept_all: list = []
                missed_total = 0
                for k in active:
                    kept, missed = _classify(
                        cells[k][li][best].op_latencies,
                        plan.node_deadline)
                    kept_all.extend(kept)
                    missed_total += missed
                fleet_summary = _shift_summary(
                    summarize_exact(kept_all, missed_total), l_route)

        for k in range(spec.n_nodes):
            if k not in cells:
                node_cells.append(NodeCell(
                    node=k, share=plan.node_shares[k], n_ops=0,
                    throughput=0.0, time=0.0, missed=0, summary=None))
                continue
            r = cells[k][li][best]
            node_cells.append(NodeCell(
                node=k, share=plan.node_shares[k],
                n_ops=plan.node_ops[k], throughput=r.throughput,
                time=r.time, missed=r.missed_ops,
                summary=_shift_summary(r.latency_summary, l_route)))

        winners = [cells[k][li][best] for k in active]
        op_lats: list = []
        if collect_latency and backend != "jax":
            for r in winners:
                if l_route:
                    op_lats.extend(v + l_route for v in r.op_latencies)
                else:
                    op_lats.extend(r.op_latencies)
        fleet = SimResult(
            ops=sum(plan.node_ops[k] for k in active),
            time=max(r.time for r in winners),
            throughput=sum(r.throughput for r in winners),
            mem_stall_total=sum(r.mem_stall_total for r in winners),
            mem_accesses=sum(r.mem_accesses for r in winners),
            op_latencies=op_lats,
            missed_ops=sum(r.missed_ops for r in winners),
            latency_summary=fleet_summary,
        )
        points.append(ClusterPoint(
            L_mem=L,
            n_threads=candidates[best],
            result=fleet,
            per_thread={candidates[ci]: fleet_thr[ci]
                        for ci in range(len(candidates))},
            nodes=tuple(node_cells),
        ))
    return points
