"""Memory-tier descriptors shared by the simulator, planner and serving engine.

The paper's Figure 1(b) spectrum, plus the TPU-side tiers the serving engine
uses. Latencies/bandwidths are per-device defaults and freely overridable --
the whole point of the paper (and of this framework's planner) is that the
*law* relating latency to throughput is what matters, not one device's spec.
"""
from __future__ import annotations

from dataclasses import dataclass

US = 1e-6

__all__ = ["MemoryTier", "DRAM", "CXL_EXPANDER", "CXL_MICROSECOND", "FLASH_CXL",
           "TPU_HBM", "TPU_HOST", "SSD", "tail_mixture"]


@dataclass(frozen=True)
class MemoryTier:
    name: str
    latency: float                    # seconds, average
    bandwidth: float                  # bytes/sec per device
    bit_cost: float                   # $/GB relative to DRAM (=1.0)
    tail: tuple[tuple[float, float], ...] = ()  # [(latency, prob)] overrides

    def latency_spec(self):
        """Latency in the simulator's scalar-or-mixture format."""
        return list(self.tail) if self.tail else self.latency


DRAM = MemoryTier("dram", 0.1 * US, 38e9, 1.0)
CXL_EXPANDER = MemoryTier("cxl-dram", 0.3 * US, 28e9, 0.9)
CXL_MICROSECOND = MemoryTier("cxl-usec", 5.0 * US, 10e9, 0.18)
# Low-latency-flash CXL with the paper's Sec. 5.1 tail profile:
# 5 us (90%), 14 us (9.9%), 48 us (0.1%) -- fit to a Samsung Z-SSD-like curve.
FLASH_CXL = MemoryTier(
    "flash-cxl", 5.0 * US, 10e9, 0.18,
    tail=((5.0 * US, 0.90), (14.0 * US, 0.099), (48.0 * US, 0.001)),
)
TPU_HBM = MemoryTier("tpu-hbm", 0.5 * US, 819e9, 4.0)
TPU_HOST = MemoryTier("tpu-host", 3.0 * US, 50e9, 1.0)   # over PCIe, DMA-visible
SSD = MemoryTier("ssd", 80.0 * US, 10e9, 0.02)


def tail_mixture(mean: float, tail_lat: float, tail_prob: float):
    """Two-point latency mixture with a given mean and tail."""
    base = (mean - tail_prob * tail_lat) / (1.0 - tail_prob)
    return [(base, 1.0 - tail_prob), (tail_lat, tail_prob)]
