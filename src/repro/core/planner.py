"""Model-driven sizing of latency-hiding resources.

The paper sizes user-level-thread counts empirically ("try different numbers
of threads and report the highest"). The closed-form model lets us *plan*
instead: given operation parameters and a memory tier, pick

  * the number of concurrent operations (threads / decode slots) N,
  * the prefetch depth (in-flight fetches / staging buffers) P,

that reach a target fraction of the latency-hidden plateau. The serving
engine uses the same planner to size its KV-page prefetch pipeline: there,
T_mem is the per-page compute time, T_io the per-step "other work"
(attention FLOPs, collectives), and L_mem the slow-tier fetch latency.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from .latency_model import OpParams, theta_multi_inv, theta_prob_inv
from .tiering import MemoryTier

__all__ = ["Plan", "plan_concurrency", "plan_pipeline_depth"]


@dataclass(frozen=True)
class Plan:
    n_threads: int
    prefetch_depth: int
    predicted_inv: float          # expected seconds per operation
    plateau_inv: float            # best achievable seconds per operation
    efficiency: float             # plateau_inv / predicted_inv


def plan_concurrency(
    p: OpParams,
    L_mem: float,
    target: float = 0.98,
    n_max: int = 4096,
) -> int:
    """Smallest N with Theta_multi within ``target`` of the N->inf plateau.

    Little's-law sizing (Eq. 2): N >= (T_mem + L_mem) / (T_mem + T_sw).
    """
    plateau = p.T_mem + p.T_sw
    for n in range(1, n_max + 1):
        inv = theta_multi_inv(np.asarray([L_mem]), replace(p, N=n))[0]
        if plateau / inv >= target:
            return n
    return n_max


def plan_pipeline_depth(
    p: OpParams,
    L_mem: float,
    p_max: int = 64,
    target: float = 0.98,
) -> Plan:
    """Smallest prefetch depth P whose Theta_prob reaches ``target`` of the
    P->inf plateau at latency ``L_mem``.

    On TPU this is the number of VMEM staging buffers the paged-KV pipeline
    allocates: more buffers hide more latency but eat VMEM, so we want the
    knee, not the max (Eq. 8 says the knee moves out by P*E/M thanks to the
    compute that plays the role of IO).
    """
    m_per_io = p.M / p.S
    plateau = p.S * (m_per_io * (p.T_mem + p.T_sw) + p.E)
    best = None
    for depth in range(1, p_max + 1):
        inv = theta_prob_inv(np.asarray([L_mem]), replace(p, P=depth))[0]
        eff = plateau / inv
        best = Plan(
            n_threads=plan_concurrency(p, L_mem),
            prefetch_depth=depth,
            predicted_inv=float(inv),
            plateau_inv=float(plateau),
            efficiency=float(eff),
        )
        if eff >= target:
            return best
    assert best is not None
    return best


def plan_for_tier(p: OpParams, tier: MemoryTier, **kw) -> Plan:
    return plan_pipeline_depth(p, tier.latency, **kw)
