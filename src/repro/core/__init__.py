"""Core of the reproduction, in three layers plus the analytical model:

  * :mod:`repro.core.engines`  -- pluggable KV-store engines (tree index /
    LSM / two-tier cache / hash index / slab cache) recording columnar
    suboperation traces
  * :mod:`repro.core.trace_ir` -- the compiled columnar trace format shared
    by engines, simulator, model calibration and benchmarks
  * :mod:`repro.core.sim`      -- the discrete-event simulator standing in
    for the FPGA testbed, plus the batched latency-sweep pipeline
  * :mod:`repro.core.latency_model` -- the paper's closed-form models,
    reused by the planner and the TPU serving engine
  * :mod:`repro.core.experiment`   -- the public entry point: declarative
    :class:`~repro.core.experiment.Scenario` specs (engine + workload by
    registry name, device spec, sweep axes), executed by
    :class:`~repro.core.experiment.Experiment` into serializable
    :class:`~repro.core.experiment.RunArtifact` sweep tables

``repro.core.kvstore`` and ``repro.core.simulator`` remain as deprecation
shims over the engines and sim packages.
"""
from . import (  # noqa: F401
    cluster,
    engines,
    experiment,
    latency_model,
    planner,
    sim,
    tiering,
    trace_ir,
    workloads,
)


def __getattr__(name):
    # Legacy attribute access (`repro.core.kvstore` / `repro.core.simulator`
    # after `import repro.core`) keeps working: resolve the deprecation
    # shims lazily so their DeprecationWarning only fires on actual use.
    if name in ("kvstore", "simulator", "conformance"):
        # conformance resolves lazily too, so `python -m
        # repro.core.conformance` doesn't re-import its own main module.
        import importlib

        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
from .latency_model import (  # noqa: F401
    OpParams,
    SystemParams,
    cost_performance_ratio,
    theta_best_inv,
    theta_extended_inv,
    theta_mask_inv,
    theta_mem_inv,
    theta_multi_inv,
    theta_prob_inv,
    theta_single_inv,
)
from .sim import (  # noqa: F401
    CompiledTrace,
    Op,
    SimConfig,
    SimResult,
    simulate,
    simulate_compiled,
    sweep_latency,
)
from .experiment import (  # noqa: F401
    Experiment,
    RunArtifact,
    RunOptions,
    Scenario,
    default_scenario,
    run_scenario,
)
from .cluster import (  # noqa: F401
    ClusterSpec,
    sweep_cluster,
)
