"""Core of the reproduction: the paper's analytical model, the discrete-event
simulator standing in for the FPGA testbed, the KV-store engines, and the
model-driven planner reused by the TPU serving engine."""
from . import kvstore, latency_model, planner, simulator, tiering, workloads  # noqa: F401
from .latency_model import (  # noqa: F401
    OpParams,
    SystemParams,
    cost_performance_ratio,
    theta_best_inv,
    theta_extended_inv,
    theta_mask_inv,
    theta_mem_inv,
    theta_multi_inv,
    theta_prob_inv,
    theta_single_inv,
)
from .simulator import Op, SimConfig, SimResult, simulate  # noqa: F401
