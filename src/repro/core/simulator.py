"""Deprecation shim: the simulator now lives in :mod:`repro.core.sim`.

``repro.core.simulator`` re-exports the full public API of the old
monolithic module so existing imports keep working:

  * the generic event loop (:func:`simulate`) and sources
    (:func:`microbenchmark_source`, :func:`trace_source`)
  * :class:`SimConfig` / :class:`SimResult` / :class:`Op`
  * the suboperation kind constants ``MEM``/``PREIO``/``POSTIO``/``CPU``
  * :func:`best_over_threads`

New code should import from :mod:`repro.core.sim`, which additionally
provides the compiled fast loop (:func:`simulate_compiled`) and the batched
sweep pipeline (:func:`sweep_latency`).
"""
from __future__ import annotations

import warnings

from .sim import (  # noqa: F401
    CPU,
    MEM,
    POSTIO,
    PREIO,
    US,
    CompiledTrace,
    Op,
    SimConfig,
    SimResult,
    best_over_threads,
    microbenchmark_source,
    simulate,
    simulate_compiled,
    sweep_latency,
    trace_source,
)

__all__ = [
    "SimConfig",
    "SimResult",
    "Op",
    "simulate",
    "microbenchmark_source",
    "trace_source",
    "best_over_threads",
]

# stacklevel=2 attributes the warning to the importing file: CPython's warn
# walks past its own importlib frames when counting stack levels, so level 2
# of a module body *is* the caller's ``import repro.core.simulator`` line.
warnings.warn(
    "repro.core.simulator is deprecated: the simulation layer lives in "
    "repro.core.sim (e.g. 'from repro.core.sim import SimConfig, simulate, "
    "sweep_latency'); the compiled fast loop (simulate_compiled) and the "
    "batched sweep pipeline (sweep_latency) are only exported there. "
    "See docs/ENGINES.md for the migration map.",
    DeprecationWarning,
    stacklevel=2,
)
