"""Discrete-event simulator of the paper's execution model (the "FPGA testbed").

The paper measures KV-operation throughput on real hardware whose memory
latency is made adjustable by an FPGA CXL board. This container has no such
hardware, so we reproduce the *measurement apparatus* as a discrete-event
simulator with exactly the paper's free parameters:

  * N user-level threads on each of C cores, strict FIFO ready ring,
    context-switch cost T_sw charged on every yield;
  * software prefetch with a per-core in-flight queue depth P: a prefetch
    issued while P are in flight starts only when a slot frees (Fig. 5);
  * a thread resuming a memory suboperation whose prefetch has not completed
    stalls the core (the gray bars of Figs. 5 and 8);
  * asynchronous IO: submit (T_io_pre), park until completion (L_io, gated by
    shared SSD bandwidth B_io and IOPS R_io token clocks), then T_io_post;
  * memory-bandwidth throttling (A_mem/B_mem spacing of prefetch starts),
    DRAM/secondary tiering ratio rho, premature-eviction probability eps,
    tail-latency mixtures, and a global per-op critical section T_lock for
    multi-core lock contention.

Operations are sequences of suboperations produced by an ``OpSource`` --
either the microbenchmark's fixed-M pointer chase (Sec. 4.1) or measured
traversal traces from the KV-store engines in :mod:`repro.core.kvstore`.

Everything is virtual-time; wall-clock speed is irrelevant to fidelity.
"""
from __future__ import annotations

import heapq
import random
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterable, Sequence

US = 1e-6

__all__ = [
    "SimConfig",
    "SimResult",
    "Op",
    "simulate",
    "microbenchmark_source",
    "trace_source",
    "best_over_threads",
]

# Suboperation kinds
MEM, PREIO, POSTIO, CPU = 0, 1, 2, 3


@dataclass(frozen=True)
class Op:
    """One KV operation: a flat tuple of (kind, duration) suboperations.

    ``duration`` of a MEM subop is its CPU compute time (T_mem); PREIO /
    POSTIO carry their CPU times; CPU is plain compute with no memory or IO
    semantics (used by the KV engines for hashing/serialization work).
    """

    subops: tuple[tuple[int, float], ...]


@dataclass(frozen=True)
class SimConfig:
    # Core/thread structure
    n_threads: int = 48
    n_cores: int = 1
    T_sw: float = 0.05 * US
    # Prefetch path
    P: int = 12
    L_mem: float | Sequence[tuple[float, float]] = 5.0 * US  # scalar or [(lat, prob)]
    rho: float = 1.0
    L_dram: float = 0.1 * US
    eps: float = 0.0
    A_mem: float = 64.0
    B_mem: float = 0.0            # bytes/sec; 0 disables the throttle
    # IO path
    L_io: float = 80.0 * US
    L_io_jitter: float = 0.25     # uniform +-fraction of L_io (real SSDs jitter;
                                  # this is what naturally misaligns threads,
                                  # Sec. 3.2.2 "timing ... will be mostly random")
    A_io: float = 1024.0
    B_io: float = 0.0             # 0 disables
    R_io: float = 0.0             # 0 disables
    # Contention
    T_lock: float = 0.0
    seed: int = 0
    collect_load_hist: bool = False


@dataclass
class SimResult:
    ops: int
    time: float                     # virtual seconds elapsed
    throughput: float               # ops/sec
    mem_stall_total: float          # total prefetch-wait (gray-bar) seconds
    mem_accesses: int
    op_latencies: list[float] = field(default_factory=list)
    load_stalls: list[float] = field(default_factory=list)  # Fig. 10 histogram

    @property
    def mean_op_latency(self) -> float:
        return sum(self.op_latencies) / max(len(self.op_latencies), 1)


def microbenchmark_source(
    M: int,
    T_mem: float,
    T_io_pre: float,
    T_io_post: float,
    n_io: int = 1,
) -> Callable[[random.Random], Op]:
    """The Sec. 4.1 microbenchmark: M pointer-chase accesses then one IO."""
    per_io = [(MEM, T_mem)] * (M // max(n_io, 1))
    sub: list[tuple[int, float]] = []
    if n_io == 0:
        sub = [(MEM, T_mem)] * M
    else:
        for _ in range(n_io):
            sub += per_io + [(PREIO, T_io_pre), (POSTIO, T_io_post)]
    op = Op(tuple(sub))
    return lambda rng: op


def trace_source(ops: Sequence[Op]) -> Callable[[random.Random], Op]:
    """Replay measured traversal traces (from the KV engines), cyclically
    but starting each thread at a random offset so traces interleave."""
    n = len(ops)

    def src(rng: random.Random, _state={}) -> Op:
        i = _state.setdefault("i", rng.randrange(n))
        _state["i"] = (i + 1) % n
        return ops[i]

    return src


class _Thread:
    __slots__ = ("tid", "subops", "idx", "pf_ready", "op_start", "wake")

    def __init__(self, tid: int):
        self.tid = tid
        self.subops: tuple[tuple[int, float], ...] = ()
        self.idx = 0
        self.pf_ready = 0.0   # completion time of the prefetch for subops[idx]
        self.op_start = 0.0
        self.wake = 0.0


class _Core:
    __slots__ = ("now", "ready", "pf_inflight", "bw_next", "idle")

    def __init__(self):
        self.now = 0.0
        self.ready: deque[_Thread] = deque()
        self.pf_inflight: list[float] = []   # heap of completion times
        self.bw_next = 0.0
        self.idle = 0.0


def _sample_lmem(cfg: SimConfig, rng: random.Random) -> float:
    if cfg.rho < 1.0 and rng.random() >= cfg.rho:
        return cfg.L_dram
    lm = cfg.L_mem
    if isinstance(lm, (int, float)):
        return float(lm)
    u = rng.random()
    acc = 0.0
    for lat, prob in lm:
        acc += prob
        if u < acc:
            return lat
    return lm[-1][0]


def simulate(
    cfg: SimConfig,
    op_source: Callable[[random.Random], Op],
    n_ops: int,
    warmup_ops: int | None = None,
    collect_latency: bool = False,
) -> SimResult:
    """Run the event simulation until ``n_ops`` operations complete.

    ``warmup_ops`` (default: 2 ops per thread) are excluded from throughput
    so the pipeline fill does not bias short runs.
    """
    rng = random.Random(cfg.seed)
    total_threads = cfg.n_threads * cfg.n_cores
    if warmup_ops is None:
        warmup_ops = 2 * total_threads

    cores = [_Core() for _ in range(cfg.n_cores)]
    # Shared (cross-core) token clocks for the SSD and the op-lock.
    io_bw_next = 0.0
    io_tok_next = 0.0
    lock_next = 0.0

    # Parked threads (waiting on IO): heap of (wake_time, seq, core_id, thread)
    parked: list[tuple[float, int, int, _Thread]] = []
    seq = 0

    def start_op(th: _Thread, now: float) -> None:
        op = op_source(rng)
        th.subops = op.subops
        th.idx = 0
        th.op_start = now

    for cid, core in enumerate(cores):
        for t in range(cfg.n_threads):
            th = _Thread(cid * cfg.n_threads + t)
            start_op(th, 0.0)
            # The first MEM access of the very first op: treat its prefetch
            # as issued at a random phase before t=0 (threads never start in
            # lockstep on real hardware), so the warm-up does not seed the
            # pathological aligned schedule of Fig. 7(a).
            th.pf_ready = rng.random() * _sample_lmem(cfg, rng)
            core.ready.append(th)

    done = 0
    counted = 0
    t_start_measure = None
    mem_stall = 0.0
    mem_accesses = 0
    op_lat: list[float] = []
    stalls: list[float] = []
    hist = cfg.collect_load_hist

    # Event loop over cores ordered by their local clocks.
    core_heap = [(0.0, cid) for cid in range(cfg.n_cores)]
    heapq.heapify(core_heap)

    measuring = lambda: done >= warmup_ops

    while counted < n_ops:
        # Wake any parked threads whose IO completed before the earliest
        # core time (they rejoin their core's ready ring).
        tmin = core_heap[0][0]
        while parked and parked[0][0] <= tmin:
            _, _, cid, th = heapq.heappop(parked)
            cores[cid].ready.append(th)

        t_core, cid = heapq.heappop(core_heap)
        core = cores[cid]
        core.now = max(core.now, t_core)

        if not core.ready:
            # Idle until this core's earliest parked thread wakes (or any
            # parked thread if the core has none -- then just re-arm later).
            mine = [e for e in parked if e[2] == cid]
            if not mine:
                if parked:
                    heapq.heappush(core_heap, (parked[0][0], cid))
                # else: deadlock cannot happen (some thread always runnable)
                continue
            wake = min(e[0] for e in mine)
            core.now = max(core.now, wake)
            while parked and parked[0][0] <= core.now:
                _, _, c2, th2 = heapq.heappop(parked)
                cores[c2].ready.append(th2)
            if not core.ready:
                heapq.heappush(core_heap, (core.now + 1e-9, cid))
                continue

        th = core.ready.popleft()
        kind, dur = th.subops[th.idx]
        now = core.now

        if kind == MEM:
            if cfg.eps > 0.0 and rng.random() < cfg.eps:
                ready_at = now + _sample_lmem(cfg, rng)  # premature eviction
            else:
                ready_at = th.pf_ready
            stall = ready_at - now
            if stall > 0.0:
                if measuring():
                    mem_stall += stall
                now = ready_at
            if hist and measuring():
                stalls.append(max(stall, 0.0))
            if measuring():
                mem_accesses += 1
            now += dur
        elif kind == PREIO:
            now += dur
        elif kind == POSTIO:
            now += dur
        else:  # CPU
            now += dur

        th.idx += 1
        end_of_op = th.idx >= len(th.subops)

        if end_of_op:
            done += 1
            if measuring():
                if t_start_measure is None:
                    t_start_measure = now
                counted += 1
                if collect_latency:
                    op_lat.append(now - th.op_start)
            start_op(th, now)
            if cfg.T_lock > 0.0:
                start = max(now, lock_next)
                now = start + cfg.T_lock
                lock_next = now

        nkind = th.subops[th.idx][0]
        park_until = None

        if kind == PREIO and not end_of_op:
            # Submit the IO now; completion is gated by the shared SSD clocks.
            svc = now
            if cfg.R_io > 0.0:
                svc = max(svc, io_tok_next)
                io_tok_next = svc + 1.0 / cfg.R_io
            if cfg.B_io > 0.0:
                svc = max(svc, io_bw_next)
                io_bw_next = svc + cfg.A_io / cfg.B_io
            lat_io = cfg.L_io
            if cfg.L_io_jitter > 0.0:
                lat_io *= 1.0 + cfg.L_io_jitter * (2.0 * rng.random() - 1.0)
            park_until = svc + lat_io

        if nkind == MEM:
            # Issue the prefetch for the next access (pointer now known).
            pq = core.pf_inflight
            while pq and pq[0] <= now:
                heapq.heappop(pq)
            start = now if len(pq) < cfg.P else max(now, pq[0])
            if cfg.B_mem > 0.0:
                start = max(start, core.bw_next)
                core.bw_next = start + cfg.A_mem / cfg.B_mem
            comp = start + _sample_lmem(cfg, rng)
            if len(pq) >= cfg.P:
                heapq.heappop(pq)
            heapq.heappush(pq, comp)
            th.pf_ready = comp

        now += cfg.T_sw  # one context switch per suboperation (yield)
        core.now = now

        if park_until is not None:
            seq += 1
            heapq.heappush(parked, (max(park_until, now), seq, cid, th))
        else:
            core.ready.append(th)
        heapq.heappush(core_heap, (core.now, cid))

    t0 = t_start_measure if t_start_measure is not None else 0.0
    t_end = max(c.now for c in cores)
    elapsed = max(t_end - t0, 1e-12)
    return SimResult(
        ops=counted,
        time=elapsed,
        throughput=counted / elapsed,
        mem_stall_total=mem_stall,
        mem_accesses=mem_accesses,
        op_latencies=op_lat,
        load_stalls=stalls,
    )


def best_over_threads(
    cfg: SimConfig,
    op_source: Callable[[random.Random], Op],
    n_ops: int,
    candidates: Iterable[int] = (8, 16, 24, 32, 48, 64, 96, 128),
) -> tuple[SimResult, int]:
    """The paper's protocol: per latency point, optimize the thread count."""
    import dataclasses

    best: tuple[SimResult, int] | None = None
    for n in candidates:
        r = simulate(dataclasses.replace(cfg, n_threads=n), op_source, n_ops)
        if best is None or r.throughput > best[0].throughput:
            best = (r, n)
    assert best is not None
    return best
