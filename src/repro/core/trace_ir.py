"""Columnar trace IR shared by the KV engines and the simulator.

A *trace* is a sequence of KV operations, each a run of suboperations in
the paper's Sec. 3 operation model:

  * ``MEM``    -- a pointer dereference on slow memory (prefetch + yield);
                  the duration is the CPU compute attached to the hop (T_mem)
  * ``PREIO``  -- asynchronous IO submission (T_io_pre), parks the thread
  * ``POSTIO`` -- IO completion check + copy (T_io_post)
  * ``CPU``    -- plain compute (hashing, serialization); never yields

Two representations exist:

  * :class:`Op` -- one operation as a tuple of ``(kind, duration)`` pairs.
    The original row-oriented form; kept for ad-hoc construction and
    backward compatibility.
  * :class:`CompiledTrace` -- the whole trace as three numpy columns
    (``kinds``, ``durs``, ``bounds``).  This is the hot-path format: built
    once by :class:`repro.core.engines.trace.Recorder`, summarized
    vectorized by ``TraceResult.op_params``, shipped cheaply to worker
    processes, and replayed by the simulator's compiled fast loop without
    per-op tuple churn.

The columnar layout
-------------------
All suboperations of all operations are concatenated into two parallel flat
arrays plus one offset array marking where each operation starts::

    kinds  : int8[n_subops]     -- MEM/PREIO/POSTIO/CPU code per suboperation
    durs   : float64[n_subops]  -- CPU seconds attached to that suboperation
    bounds : int64[n_ops + 1]   -- bounds[i]:bounds[i+1] slices out op i

For example, a get that chases two index pointers and reads one value from
SSD, followed by a pure-cache-hit get, is::

    kinds  = [MEM, MEM, PREIO, POSTIO, CPU,   MEM, MEM]
    durs   = [0.1u, 0.1u, 1.5u, 0.2u, 0.3u,   0.1u, 0.1u]
    bounds = [0,                          5,            7]

``bounds[0] == 0``, ``bounds[-1] == n_subops``, and empty operations are
disallowed -- every index in ``kinds`` belongs to exactly one op, so the
replay loop needs no sentinel checks.  Note that ``durs`` never stores a
*memory or IO latency*: those are device properties sampled at simulation
time (the same trace is replayed at every point of a latency sweep); a MEM
duration is only the CPU compute attached to the hop.

This module deliberately has no dependency on either the engines or the
simulator packages -- it is the neutral layer both import.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

US = 1e-6

# Suboperation kinds (stable on-disk/in-array encoding).
MEM, PREIO, POSTIO, CPU = 0, 1, 2, 3

KIND_NAMES = {MEM: "MEM", PREIO: "PREIO", POSTIO: "POSTIO", CPU: "CPU"}

__all__ = [
    "US",
    "MEM",
    "PREIO",
    "POSTIO",
    "CPU",
    "KIND_NAMES",
    "Op",
    "CompiledTrace",
    "compile_ops",
]


@dataclass(frozen=True)
class Op:
    """One KV operation: a flat tuple of (kind, duration) suboperations.

    ``duration`` of a MEM subop is its CPU compute time (T_mem); PREIO /
    POSTIO carry their CPU times; CPU is plain compute with no memory or IO
    semantics (used by the KV engines for hashing/serialization work).
    """

    subops: tuple[tuple[int, float], ...]


class CompiledTrace:
    """A whole trace in columnar form: ``kinds``/``durs`` + op ``bounds``.

    Construct via :meth:`from_ops`, :meth:`from_columns`, or let a
    ``Recorder`` emit one.  Instances are immutable by convention (the
    arrays are flagged non-writeable) so they can be shared freely across
    sweep points and worker processes.
    """

    __slots__ = ("kinds", "durs", "bounds", "_lists")

    def __init__(self, kinds: np.ndarray, durs: np.ndarray, bounds: np.ndarray):
        kinds = np.ascontiguousarray(kinds, dtype=np.int8)
        durs = np.ascontiguousarray(durs, dtype=np.float64)
        bounds = np.ascontiguousarray(bounds, dtype=np.int64)
        if bounds.ndim != 1 or len(bounds) < 2:
            raise ValueError("bounds must hold n_ops + 1 >= 2 offsets")
        if bounds[0] != 0 or bounds[-1] != len(kinds) or len(kinds) != len(durs):
            raise ValueError("inconsistent columnar trace shape")
        if np.any(np.diff(bounds) <= 0):
            raise ValueError("empty ops are not allowed in a compiled trace")
        for a in (kinds, durs, bounds):
            a.setflags(write=False)
        self.kinds = kinds
        self.durs = durs
        self.bounds = bounds
        self._lists: tuple | None = None

    # The columns cross process boundaries (sweep workers); the derived
    # list cache is dropped and rebuilt on the far side.
    def __getstate__(self):
        return (self.kinds, self.durs, self.bounds)

    def __setstate__(self, state):
        kinds, durs, bounds = state
        for a in (kinds, durs, bounds):
            a.setflags(write=False)
        self.kinds = kinds
        self.durs = durs
        self.bounds = bounds
        self._lists = None

    # -- construction -----------------------------------------------------

    @classmethod
    def from_ops(cls, ops: Sequence[Op]) -> "CompiledTrace":
        """Compile a row-oriented list of :class:`Op` (the legacy format)."""
        kinds: list[int] = []
        durs: list[float] = []
        bounds = [0]
        for op in ops:
            for k, d in op.subops:
                kinds.append(k)
                durs.append(d)
            bounds.append(len(kinds))
        return cls(np.asarray(kinds, dtype=np.int8),
                   np.asarray(durs, dtype=np.float64),
                   np.asarray(bounds, dtype=np.int64))

    @classmethod
    def from_columns(cls, kinds, durs, bounds) -> "CompiledTrace":
        return cls(np.asarray(kinds), np.asarray(durs), np.asarray(bounds))

    @classmethod
    def single_op(cls, op: Op) -> "CompiledTrace":
        """A one-op trace (e.g. the microbenchmark's fixed pointer chase)."""
        return cls.from_ops([op])

    # -- views ------------------------------------------------------------

    @property
    def n_ops(self) -> int:
        return len(self.bounds) - 1

    @property
    def n_subops(self) -> int:
        return len(self.kinds)

    def __len__(self) -> int:
        return self.n_ops

    def op(self, i: int) -> Op:
        s, e = int(self.bounds[i]), int(self.bounds[i + 1])
        return Op(tuple(zip(self.kinds[s:e].tolist(), self.durs[s:e].tolist())))

    def to_ops(self) -> list[Op]:
        """Materialize the legacy row-oriented form (back-compat paths)."""
        kinds = self.kinds.tolist()
        durs = self.durs.tolist()
        bounds = self.bounds.tolist()
        return [
            Op(tuple(zip(kinds[s:e], durs[s:e])))
            for s, e in zip(bounds, bounds[1:])
        ]

    def as_lists(self) -> tuple[list[int], list[float], list[int], list[int]]:
        """(kinds, durs, op_starts, op_ends) as plain Python lists.

        The simulator's compiled loop indexes these in its inner loop --
        plain lists are ~3x faster than numpy scalar indexing there.  The
        conversion is done once and cached on the instance (and therefore
        once per worker process after a fork).
        """
        if self._lists is None:
            bounds = self.bounds.tolist()
            self._lists = (
                self.kinds.tolist(),
                self.durs.tolist(),
                bounds[:-1],
                bounds[1:],
            )
        return self._lists

    # -- summaries --------------------------------------------------------

    def counts(self) -> dict[str, int]:
        out = {}
        for kind, name in KIND_NAMES.items():
            out[name] = int(np.count_nonzero(self.kinds == kind))
        return out

    def mean_per_op(self, kind: int) -> float:
        return float(np.count_nonzero(self.kinds == kind)) / max(self.n_ops, 1)

    def yield_spans(self) -> tuple[dict[int, float], dict[int, int]]:
        """Mean CPU span between yields per yield kind, vectorized.

        Implements the paper's Sec. 4.2.3 calibration: plain CPU subops do
        not yield, so their time folds into the span of the *next* yield
        point; CPU time trailing the last yield folds backward into it.
        Returns (span_sum, span_n) keyed by MEM/PREIO/POSTIO.
        """
        kinds = self.kinds
        durs = self.durs
        is_cpu = kinds == CPU
        cpu_cum = np.cumsum(np.where(is_cpu, durs, 0.0))
        yield_idx = np.flatnonzero(~is_cpu)
        span_sum = {MEM: 0.0, PREIO: 0.0, POSTIO: 0.0}
        span_n = {MEM: 0, PREIO: 0, POSTIO: 0}
        if len(yield_idx) == 0:
            return span_sum, span_n
        # CPU accumulated strictly before each yield, minus what was already
        # attributed to the previous yield.
        cpu_before = cpu_cum[yield_idx]  # kinds[yield_idx] != CPU, so this
        # equals the cumulative CPU up to (not including) the yield.
        prev = np.concatenate(([0.0], cpu_before[:-1]))
        spans = durs[yield_idx] + (cpu_before - prev)
        ykinds = kinds[yield_idx]
        for kind in (MEM, PREIO, POSTIO):
            mask = ykinds == kind
            span_sum[kind] = float(spans[mask].sum())
            span_n[kind] = int(np.count_nonzero(mask))
        trailing = float(cpu_cum[-1] - cpu_before[-1])
        if trailing > 0.0:
            span_sum[int(ykinds[-1])] += trailing
        return span_sum, span_n

    # -- interop with the generic simulator ------------------------------

    def as_source(self) -> Callable:
        """A ``trace_source``-compatible callable over this trace.

        Byte-for-byte equivalent to ``trace_source(self.to_ops())`` --
        including the quirk that one ``rng.randrange`` is drawn per fetch
        (the legacy closure evaluates it as a ``setdefault`` argument), so
        generic-loop results are bit-identical either way.
        """
        ops = self.to_ops()
        n = len(ops)

        def src(rng, _state={}):
            i = _state.setdefault("i", rng.randrange(n))
            _state["i"] = (i + 1) % n
            return ops[i]

        return src

    def __repr__(self) -> str:
        return (f"CompiledTrace(n_ops={self.n_ops}, n_subops={self.n_subops}, "
                f"counts={self.counts()})")


def compile_ops(ops: Sequence[Op]) -> CompiledTrace:
    """Functional alias for :meth:`CompiledTrace.from_ops`."""
    return CompiledTrace.from_ops(ops)
