"""Declarative experiments: scenario specs, run options, run artifacts.

The paper's central artifact is a *protocol*: trace an engine under a
workload, sweep memory latency with the thread count re-optimized at every
point, and compare the simulated "measurement" against the closed-form
model (Figs. 9-13).  This module makes that protocol a first-class,
serializable object instead of benchmark-package glue:

  * :class:`Scenario` -- a frozen, JSON-round-trippable spec naming an
    engine (registry name + kwargs), a workload (registry name + kwargs,
    or the engine's default pairing), a device setup (``n_ssd`` /
    per-device ``R_io`` / ``B_io`` / ``L_switch_us``), and the sweep axes
    (latencies, thread candidates, simulated ops per cell).
  * :class:`RunOptions` -- *how* to run (worker processes, cell cache
    directory, latency collection, adaptive thread search); never part of
    the scientific spec, never serialized into artifacts' scenarios.
  * :class:`Experiment` -- traces the engine once, drives
    :func:`~repro.core.sim.sweep_latency` over the grid, evaluates the
    paper's probabilistic model at every point, and returns a
  * :class:`RunArtifact` -- sweep table + trace stats (``S``, ``M``) +
    model predictions + full config provenance, with ``to_json`` /
    ``from_json`` round-trip and CSV export.

The engine -> default-workload pairings (previously
``benchmarks/common.py::ENGINE_DEFAULTS``) live here as
:data:`ENGINE_DEFAULTS`; :func:`default_scenario` builds the matrix cell
``benchmarks.run --engine NAME --devices N`` sweeps, so CLI flags are just
sugar over scenarios.  All latencies in a scenario are in **microseconds**
(the unit the paper's figures are drawn in); conversion to the simulator's
seconds happens inside :class:`Experiment`.
"""
from __future__ import annotations

import dataclasses
import io
import json
import math
from dataclasses import dataclass, field
from typing import Any, Sequence

import numpy as np

from .engines import TraceResult, get_engine, run_trace
from .latency_model import US, OpParams, theta_prob_inv
from .sim import ArrivalSpec, SimConfig, SweepPoint, sweep_latency
from .workloads import Workload, create_workload, get_workload

__all__ = [
    "ENGINE_DEFAULTS",
    "Scenario",
    "RunOptions",
    "SweepRow",
    "RunArtifact",
    "Experiment",
    "run_scenario",
    "default_scenario",
    "build_engine",
]

SCHEMA_VERSION = 1

#: Default (paper Table 5-ish) constructor kwargs and workload pairing per
#: canonical engine name: ``{engine: (engine_kwargs, workload,
#: workload_kwargs)}``.  A scenario whose ``workload`` is empty resolves it
#: from this table (unknown engines fall back to uniform read-only keys).
ENGINE_DEFAULTS: dict[str, tuple[dict, str, dict]] = {
    "tree-index": (dict(seed=1), "uniform", dict(read_write=(1, 0), seed=2)),
    "lsm": (dict(), "zipf", dict(exponent=0.99, read_write=(1, 0), seed=3)),
    "two-tier-cache": (
        dict(seed=4), "gaussian", dict(sigma_frac=0.08, read_write=(2, 1), seed=5),
    ),
    "hash-index": (dict(seed=6), "uniform", dict(read_write=(1, 0), seed=2)),
    "slab-cache": (dict(seed=8), "zipf", dict(exponent=0.9, read_write=(3, 1), seed=8)),
}

_FALLBACK_PAIRING = (dict(), "uniform", dict(read_write=(1, 0), seed=2))


def default_pairing(canonical_engine: str) -> tuple[dict, str, dict]:
    """``(engine_kwargs, workload, workload_kwargs)`` for one engine."""
    return ENGINE_DEFAULTS.get(canonical_engine, _FALLBACK_PAIRING)


def _expected_us(l_us) -> float:
    """Scalar latency, or a mixture spec's expected value, in us."""
    if isinstance(l_us, (tuple, list)):
        return sum(lat * prob for lat, prob in l_us)
    return float(l_us)


def _norm(v):
    """Normalize spec values so Python-built and JSON-loaded scenarios
    compare equal: sequences become tuples (recursively), dicts stay dicts
    with normalized values."""
    if isinstance(v, (list, tuple)):
        return tuple(_norm(x) for x in v)
    if isinstance(v, dict):
        return {k: _norm(x) for k, x in v.items()}
    return v


@dataclass(frozen=True)
class Scenario:
    """One declarative experiment: *what* to measure, as plain data.

    ``latencies_us`` entries are scalars (microseconds) or tail-latency
    mixtures ``((lat_us, prob), ...)``; ``L_switch_us`` is only paid when
    ``n_ssd > 1`` (a single direct-attached SSD has no switch to cross),
    mirroring the device-matrix semantics.  An empty ``workload`` selects
    the engine's default pairing from :data:`ENGINE_DEFAULTS`.
    """

    engine: str
    engine_kwargs: dict = field(default_factory=dict)
    workload: str = ""
    workload_kwargs: dict = field(default_factory=dict)
    n_keys: int = 100_000
    n_wl_ops: int = 30_000        # workload length fed to the engine trace
    warmup_frac: float = 0.3
    # device spec (R_io / B_io are per device; 0 disables the token clock)
    n_ssd: int = 1
    R_io: float = 0.0
    B_io: float = 0.0
    L_switch_us: float = 0.0
    # host spec: CPU cores running the store (thread_candidates are per
    # core) and the serialized per-op commit window (T_lock)
    n_cores: int = 1
    T_lock_us: float = 0.0
    # sweep axes
    latencies_us: tuple = (0.1, 1, 3, 5, 8, 10)
    thread_candidates: tuple = (16, 24, 32, 48, 64)
    n_ops: int = 5000             # simulated ops per grid cell
    P: int = 12
    T_sw_us: float = 0.05
    seed: int = 7
    # open-loop driver: an ArrivalSpec.to_dict() (empty = closed loop).
    # NOTE: ArrivalSpec fields are SI -- ``rate`` in ops/sec, ``period``
    # and ``deadline`` in *seconds* -- unlike the scenario's _us fields.
    arrival: dict = field(default_factory=dict)
    # sharded fleet: a ClusterSpec.to_dict() (empty = single host).  The
    # scenario's device fields describe each *node*; ``cluster`` adds the
    # fleet shape on top (node count, partitioning, route hop, per-node
    # overrides) -- see repro.core.cluster.
    cluster: dict = field(default_factory=dict)
    name: str = ""

    def __post_init__(self):
        for f in ("engine_kwargs", "workload_kwargs", "latencies_us",
                  "thread_candidates", "arrival", "cluster"):
            object.__setattr__(self, f, _norm(getattr(self, f)))
        if self.arrival:
            ArrivalSpec.from_dict(dict(self.arrival))   # validate eagerly
        if self.cluster:
            from .cluster import ClusterSpec
            ClusterSpec.from_dict(dict(self.cluster))   # validate eagerly
        if not self.latencies_us or not self.thread_candidates:
            raise ValueError(
                "Scenario sweep axes must be non-empty "
                f"(latencies_us={self.latencies_us!r}, "
                f"thread_candidates={self.thread_candidates!r})"
            )
        if self.n_ssd < 1:
            raise ValueError(f"n_ssd must be >= 1, got {self.n_ssd}")
        if self.n_cores < 1:
            raise ValueError(f"n_cores must be >= 1, got {self.n_cores}")
        if self.T_lock_us < 0:
            raise ValueError(
                f"T_lock_us must be >= 0, got {self.T_lock_us}")
        for f in ("n_keys", "n_wl_ops", "n_ops"):
            if getattr(self, f) < 1:
                raise ValueError(f"{f} must be >= 1, got {getattr(self, f)}")

    # -- resolution ----------------------------------------------------------

    @property
    def canonical_engine(self) -> str:
        return get_engine(self.engine).engine_name

    def resolved_workload(self) -> tuple[str, dict]:
        """Workload registry name + kwargs, applying the default pairing."""
        if self.workload:
            return get_workload(self.workload).workload_name, dict(
                self.workload_kwargs)
        _, wname, wkw = default_pairing(self.canonical_engine)
        return wname, {**wkw, **self.workload_kwargs}

    @property
    def display_name(self) -> str:
        return self.name or (
            f"{self.canonical_engine.replace('-', '_')}_{self.n_ssd}ssd")

    def sim_config(self) -> SimConfig:
        """The base :class:`SimConfig` of every grid cell (``L_mem`` and
        ``n_threads`` are overridden per cell by the sweep)."""
        return SimConfig(
            P=self.P, T_sw=self.T_sw_us * US, seed=self.seed,
            n_ssd=self.n_ssd, R_io=self.R_io, B_io=self.B_io,
            L_switch=self.L_switch_us * US if self.n_ssd > 1 else 0.0,
            n_cores=self.n_cores, T_lock=self.T_lock_us * US,
        )

    def latencies_sec(self) -> list:
        """Latency axis in the simulator's scalar-or-mixture seconds form."""
        return [
            [(lat * US, prob) for lat, prob in l]
            if isinstance(l, tuple) else l * US
            for l in self.latencies_us
        ]

    def arrival_spec(self) -> ArrivalSpec | None:
        """The open-loop :class:`~repro.core.sim.ArrivalSpec`, or ``None``
        for the closed-loop driver."""
        return (ArrivalSpec.from_dict(dict(self.arrival))
                if self.arrival else None)

    def cluster_spec(self):
        """The :class:`~repro.core.cluster.ClusterSpec`, or ``None`` for
        the plain single-host path."""
        if not self.cluster:
            return None
        from .cluster import ClusterSpec
        return ClusterSpec.from_dict(dict(self.cluster))

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown Scenario field(s) {sorted(unknown)}; "
                f"known: {sorted(known)}"
            )
        return cls(**d)

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        return cls.from_dict(json.loads(s))


@dataclass(frozen=True)
class RunOptions:
    """How to execute a scenario (absorbs the old module-level
    ``SWEEP_PROCESSES`` / ``SWEEP_CACHE`` benchmark globals); never part
    of an artifact's provenance.  ``processes``/``cache_dir`` cannot change
    the numbers; ``collect_latency`` only *adds* the latency column;
    ``adaptive`` evaluates a subset of the thread grid (``per_thread``
    covers fewer candidates, and the winner matches the full grid only on
    unimodal throughput-vs-threads curves -- the paper-sweep shape; see
    :func:`~repro.core.sim.sweep_latency`); ``backend="jax"`` replays the
    grid as one jitted scan whose per-cell throughput agrees with the loop
    backend within sampling tolerance, not bit-identically (the scientific
    spec is unchanged -- the measurement apparatus is; see
    ``docs/SIMULATION.md``).  ``use_pallas``/``unroll``/``substeps``/
    ``host_devices`` tune how the jax grid executes (fused whole-step
    kernel, scan unrolling, steps per kernel invocation, shard_map over
    host CPU devices) without changing any cell value."""

    processes: int | None = None       # sweep worker processes (None: auto)
    cache_dir: str | None = None       # on-disk sweep-cell cache
    collect_latency: bool = False      # per-op latencies per winning cell
    collect_percentiles: bool = False  # p50/p90/p99 tail summary per cell
    adaptive: bool = False             # warm-started thread search
    backend: str = "loop"              # "loop" interpreters | "jax" grid
    use_pallas: bool = False           # jax: fused whole-step kernel
    unroll: int | None = None          # jax: jnp scan unroll (None: default)
    substeps: int | None = None        # jax: steps per kernel invocation
    host_devices: int | None = None    # jax: shard cells over N host devs


@dataclass(frozen=True)
class SweepRow:
    """One latency point of an artifact's sweep table."""

    L_us: Any                     # scalar us, or ((lat_us, prob), ...)
    n_threads: int
    throughput: float             # ops/sec at the best thread count
    model_throughput: float       # paper probabilistic model at this point
    per_thread: tuple = ()        # ((n_threads, throughput), ...)
    mean_op_latency_us: float | None = None
    # Tail summary of the winning cell when RunOptions.collect_percentiles
    # was on (None otherwise, and in artifacts predating it): p50_us /
    # p90_us / p99_us / max_us (None when every op missed), count, missed,
    # miss_rate, source ("exact" | "hist"), offered_load (ops/sec, None
    # closed loop) and achieved_load (measured throughput).
    tail: dict | None = None
    # Cluster runs only: one dict per node (node index, op-stream share,
    # measured ops, throughput, virtual time, and the node's own tail
    # summary in the client frame).  None on single-host rows.
    nodes: tuple | None = None

    def __post_init__(self):
        object.__setattr__(self, "L_us", _norm(self.L_us))
        object.__setattr__(self, "per_thread", _norm(self.per_thread))
        if self.tail is not None:
            object.__setattr__(self, "tail", dict(self.tail))
        if self.nodes is not None:
            object.__setattr__(self, "nodes", _norm(tuple(self.nodes)))

    @property
    def mean_latency_us(self) -> float:
        """Scalar latency, or the mixture's expected value, in us."""
        return _expected_us(self.L_us)

    def label(self) -> str:
        if isinstance(self.L_us, tuple):
            return "Lmix" + "|".join(f"{lat:g}@{prob:g}"
                                     for lat, prob in self.L_us) + "us"
        return f"L{self.L_us:g}us"


@dataclass
class RunArtifact:
    """Everything one experiment run produced, as serializable data.

    ``points`` / ``trace_result`` are live in-process handles (the raw
    :class:`SweepPoint` list and :class:`TraceResult`) populated by
    :meth:`Experiment.run`; they are excluded from equality and JSON, so
    ``RunArtifact.from_json(a.to_json()) == a`` holds.
    """

    scenario: Scenario
    engine: str                   # canonical registry names, resolved
    workload: str
    S: float                      # SSD accesses per op (trace-measured)
    M: float                      # slow-memory hops per op
    T_mem_us: float               # calibrated model spans (Sec. 4.2.3)
    T_io_pre_us: float
    T_io_post_us: float
    hit_stats: dict = field(default_factory=dict)
    rows: tuple = ()              # tuple[SweepRow, ...]
    schema_version: int = SCHEMA_VERSION
    points: list = field(default=None, compare=False, repr=False)
    trace_result: TraceResult | None = field(
        default=None, compare=False, repr=False)

    def __post_init__(self):
        self.rows = tuple(
            r if isinstance(r, SweepRow) else SweepRow(**r)
            for r in self.rows
        )
        self.hit_stats = {k: _jsonable(v) for k, v in self.hit_stats.items()}

    # -- views ---------------------------------------------------------------

    @property
    def baseline_throughput(self) -> float:
        return self.rows[0].throughput if self.rows else 0.0

    def normalized(self) -> list[float]:
        """Throughput per point normalized by the first (DRAM-ish) point."""
        base = self.baseline_throughput
        return [r.throughput / base for r in self.rows] if base else []

    def op_params(self) -> OpParams:
        """The calibrated model parameters this artifact's predictions used."""
        return OpParams(
            M=self.M, S=max(self.S, 1e-9), T_mem=self.T_mem_us * US,
            T_io_pre=self.T_io_pre_us * US, T_io_post=self.T_io_post_us * US,
            T_sw=self.scenario.T_sw_us * US, P=self.scenario.P,
        )

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        d = {
            "schema_version": self.schema_version,
            "scenario": self.scenario.to_dict(),
            "engine": self.engine,
            "workload": self.workload,
            "S": self.S,
            "M": self.M,
            "T_mem_us": self.T_mem_us,
            "T_io_pre_us": self.T_io_pre_us,
            "T_io_post_us": self.T_io_post_us,
            "hit_stats": self.hit_stats,
            "rows": [dataclasses.asdict(r) for r in self.rows],
        }
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "RunArtifact":
        d = dict(d)
        version = d.pop("schema_version", SCHEMA_VERSION)
        if version > SCHEMA_VERSION:
            raise ValueError(
                f"artifact schema_version {version} is newer than "
                f"supported {SCHEMA_VERSION}"
            )
        d["scenario"] = Scenario.from_dict(d["scenario"])
        return cls(schema_version=version, **d)

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "RunArtifact":
        return cls.from_dict(json.loads(s))

    def to_csv(self) -> str:
        """The sweep table as CSV (one row per latency point)."""
        buf = io.StringIO()
        buf.write("L_us,n_threads,throughput_ops,model_throughput_ops,"
                  "normalized,mean_op_latency_us\n")
        base = self.baseline_throughput or 1.0
        for r in self.rows:
            l_col = (f"{r.mean_latency_us:g}" if isinstance(r.L_us, tuple)
                     else f"{r.L_us:g}")
            lat = ("" if r.mean_op_latency_us is None
                   else f"{r.mean_op_latency_us:.4f}")
            buf.write(f"{l_col},{r.n_threads},{r.throughput:.4f},"
                      f"{r.model_throughput:.4f},"
                      f"{r.throughput / base:.6f},{lat}\n")
        return buf.getvalue()


def _jsonable(v):
    """Coerce numpy scalars etc. so artifacts always json.dumps cleanly."""
    if isinstance(v, (bool, np.bool_)):
        return bool(v)
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        return float(v)
    if isinstance(v, (str, type(None))):
        return v
    return str(v)


def build_engine(name: str, n_keys: int = 100_000, n_wl_ops: int = 30_000
                 ) -> tuple[Any, Workload]:
    """One registered engine + its default workload, by any registry name.

    Accepts canonical names, aliases, and CLI-style underscores
    (``hash_index``); unknown engines raise ``KeyError`` listing what is
    registered.
    """
    cls = get_engine(name)
    kw, wname, wkw = default_pairing(cls.engine_name)
    return cls(n_keys, **kw), create_workload(wname, n_keys, n_wl_ops, **wkw)


class Experiment:
    """Execute one :class:`Scenario`: trace once, sweep the grid, compare
    against the analytical model, and package a :class:`RunArtifact`.

    >>> art = Experiment(default_scenario("hash-index", n_ssd=2)).run()
    """

    def __init__(self, scenario: Scenario, options: RunOptions | None = None):
        self.scenario = scenario
        self.options = options or RunOptions()

    def build(self) -> tuple[Any, Workload]:
        """Instantiate the scenario's engine and workload."""
        s = self.scenario
        store = get_engine(s.engine)(s.n_keys, **s.engine_kwargs)
        wname, wkw = s.resolved_workload()
        wl = create_workload(wname, s.n_keys, s.n_wl_ops, **wkw)
        return store, wl

    def run(self) -> RunArtifact:
        s, o = self.scenario, self.options
        store, wl = self.build()
        tr = run_trace(store, wl, warmup_frac=s.warmup_frac)
        p = tr.op_params(store.times, P=s.P, T_sw=s.T_sw_us * US)
        cfg = s.sim_config()
        arrival = s.arrival_spec()
        cl = s.cluster_spec()
        if cl is not None:
            from .cluster import sweep_cluster
            # Trace ops carry no keys; the partitioner needs them, and the
            # post-warmup workload slice aligns 1:1 with the trace ops.
            n_warm = int(len(wl) * s.warmup_frac)
            pts = sweep_cluster(
                cfg, tr.trace, wl.keys[n_warm:], wl.is_write[n_warm:], cl,
                s.latencies_sec(), s.thread_candidates, n_ops=s.n_ops,
                backend=o.backend, collect_latency=o.collect_latency,
                collect_percentiles=o.collect_percentiles, arrival=arrival,
                use_pallas=o.use_pallas, unroll=o.unroll,
                substeps=o.substeps, host_devices=o.host_devices,
            )
        else:
            pts = sweep_latency(
                cfg, tr.trace, s.latencies_sec(), s.thread_candidates,
                n_ops=s.n_ops, processes=o.processes, cache_dir=o.cache_dir,
                collect_latency=o.collect_latency, adaptive=o.adaptive,
                backend=o.backend, use_pallas=o.use_pallas, unroll=o.unroll,
                substeps=o.substeps, host_devices=o.host_devices,
                arrival=arrival, collect_percentiles=o.collect_percentiles,
            )
        # Eq. 14 outer IO caps for the model column, matching the scenario's
        # declared device pool (aggregate over the n_ssd per-device rates;
        # 0 disables a cap, like in the simulator).
        cap_inv = 0.0
        if s.R_io > 0:
            cap_inv = max(cap_inv, p.S / (s.n_ssd * s.R_io))
        if s.B_io > 0:
            cap_inv = max(cap_inv, p.S * cfg.A_io / (s.n_ssd * s.B_io))
        # Cluster fleet model: the hottest shard saturates first, so the
        # fleet tops out at min over nodes of C_k / w_k (node capacity
        # from Eq. 14 with its own device overrides, over its op share).
        shares = None
        if cl is not None and pts:
            shares = []
            for nc in pts[0].nodes:
                if nc.share <= 0.0:
                    continue
                ncfg = cl.node_config(cfg, nc.node)
                ci = 0.0
                if ncfg.R_io > 0:
                    ci = max(ci, p.S / (ncfg.n_ssd * ncfg.R_io))
                if ncfg.B_io > 0:
                    ci = max(ci, p.S * ncfg.A_io / (ncfg.n_ssd * ncfg.B_io))
                shares.append((nc.share, ci))
        rows = tuple(
            _make_row(l_us, pt, p, cap_inv, o.collect_latency, arrival,
                      shares=shares, nodes=_node_dicts(pt, arrival))
            for l_us, pt in zip(s.latencies_us, pts)
        )
        wname, _ = s.resolved_workload()
        return RunArtifact(
            scenario=s,
            engine=s.canonical_engine,
            workload=wname,
            S=float(tr.io_per_op),
            M=float(tr.mem_per_op),
            T_mem_us=float(p.T_mem / US),
            T_io_pre_us=float(p.T_io_pre / US),
            T_io_post_us=float(p.T_io_post / US),
            hit_stats=dict(tr.hit_stats),
            rows=rows,
            points=pts,
            trace_result=tr,
        )


def _summary_tail(summ, offered: float | None,
                  achieved: float) -> dict | None:
    """Flatten a :class:`LatencySummary` into the JSON-friendly tail
    mapping (microseconds; NaN percentiles from all-missed cells become
    ``None`` so artifacts round-trip through strict JSON)."""
    if summ is None:
        return None

    def us_or_none(v: float) -> float | None:
        return None if math.isnan(v) else float(v) / US

    return {
        "p50_us": us_or_none(summ.p50),
        "p90_us": us_or_none(summ.p90),
        "p99_us": us_or_none(summ.p99),
        "max_us": us_or_none(summ.max),
        "count": int(summ.count),
        "missed": int(summ.missed),
        "miss_rate": float(summ.miss_rate),
        "source": summ.source,
        "offered_load": offered,
        "achieved_load": float(achieved),
    }


def _tail_dict(pt: SweepPoint, arrival: ArrivalSpec | None) -> dict | None:
    return _summary_tail(
        pt.result.latency_summary,
        float(arrival.offered_rate) if arrival is not None else None,
        pt.throughput)


def _node_dicts(pt: SweepPoint,
                arrival: ArrivalSpec | None) -> tuple | None:
    """Per-node breakdown of a cluster point as JSON-friendly dicts (a
    node's offered load is the fleet offered rate times its op share)."""
    nodes = getattr(pt, "nodes", None)
    if not nodes:
        return None
    out = []
    for nc in nodes:
        offered = (float(arrival.offered_rate) * nc.share
                   if arrival is not None else None)
        out.append({
            "node": int(nc.node),
            "share": float(nc.share),
            "n_ops": int(nc.n_ops),
            "throughput": float(nc.throughput),
            "time": float(nc.time),
            "tail": _summary_tail(nc.summary, offered, nc.throughput),
        })
    return tuple(out)


def _make_row(l_us, pt: SweepPoint, p: OpParams, cap_inv: float,
              collected: bool, arrival: ArrivalSpec | None = None,
              shares=None, nodes=None) -> SweepRow:
    # Mixtures are fed to the closed-form model as their expected latency
    # (the model takes a scalar L; the simulator samples the real mixture).
    # cap_inv is the Eq. 14 device-cap floor on reciprocal throughput, so
    # IOPS/bandwidth-capped scenarios get a model the sim can actually meet.
    # shares (cluster runs) replaces it with the hottest-shard bound
    # min_k C_k / w_k over (op share, per-node cap floor) pairs.
    rev = float(theta_prob_inv(np.array([_expected_us(l_us) * US]), p)[0])
    if shares is None:
        model = 1.0 / max(rev, cap_inv)
    else:
        model = min((1.0 / max(rev, ci)) / w for w, ci in shares)
    return SweepRow(
        L_us=l_us,
        n_threads=pt.n_threads,
        throughput=float(pt.throughput),
        model_throughput=model,
        per_thread=tuple(pt.per_thread.items()),
        mean_op_latency_us=(
            float(pt.result.mean_op_latency / US) if collected else None),
        tail=_tail_dict(pt, arrival),
        nodes=nodes,
    )


def run_scenario(scenario: Scenario,
                 options: RunOptions | None = None) -> RunArtifact:
    """Convenience: ``Experiment(scenario, options).run()``."""
    return Experiment(scenario, options).run()


def default_scenario(engine: str, n_ssd: int = 1, **overrides) -> Scenario:
    """The engine x device matrix cell as a scenario (what
    ``benchmarks.run --engine NAME --devices N`` sweeps).

    Device defaults give each SSD a 250 kIOPS random-read token clock --
    one device caps the IO-richest engines while two free them -- and
    pools with ``n_ssd > 1`` pay a 0.3 us switch fan-out hop per IO.
    Any :class:`Scenario` field can be overridden by keyword.
    """
    cls = get_engine(engine)
    ekw, wname, wkw = default_pairing(cls.engine_name)
    spec = dict(
        engine=cls.engine_name,
        engine_kwargs=ekw,
        workload=wname,
        workload_kwargs=wkw,
        n_ssd=n_ssd,
        R_io=250e3,
        L_switch_us=0.3,
    )
    spec.update(overrides)
    return Scenario(**spec)
