"""Differential conformance: the backend equivalence-contract table plus
a seeded Scenario fuzzer that enforces it.

The repo's core claim is that all three simulation backends realize the
same prefetch+IO model: the generic event loop (``simulate``), the
compiled fast loop (``simulate_compiled``), and the jax/Pallas grid
(``replay_jax.sweep_grid``).  The *contracts* between backend pairs --
which pairs are bit-identical and which are tolerance-bound, and at what
op count the tolerance was measured -- were historically hardcoded across
``tests/test_replay_jax.py`` and ``tests/test_cluster.py``.  This module
is now the single home for those numbers (:data:`CONTRACTS` and the
constants it is built from); the tests, the fuzzer, and
``docs/TESTING.md`` all consume the same table, so they cannot drift.

Two layers:

* **Contract table** -- :class:`EquivalenceContract` rows keyed by pair
  name.  Bit-identical pairs (``generic-vs-compiled``, ``pallas-vs-jnp``,
  ``trivial-cluster``) carry no tolerance; tolerance pairs
  (``jax-vs-loop``, ``cluster-jax-vs-loop``) carry a throughput bound
  documented at a reference op count plus tail bounds.  Sampling noise
  between the loop's Mersenne stream and the grid's counter RNG scales
  like ``1/sqrt(n_ops)``, so :func:`jax_grid_tol` / :func:`tail_tol`
  scale a documented bound to any cell size -- the scattered literals
  ``0.01`` (20k-op paper grid), ``0.02`` (5k-op grids), ``0.03``
  (1.5k-op integration runs) are all one formula.

* **Fuzzer** -- :func:`scenario_for_seed` samples a small frozen
  :class:`~repro.core.experiment.Scenario` across engines x workloads x
  devices x arrivals x clusters; :func:`check_scenario` runs it through
  every applicable backend via ``Experiment.run()`` and diffs the
  artifacts against the contract table; :func:`shrink_scenario` greedily
  minimizes a failing spec; :func:`write_repro` emits the shrunk spec as
  a plain scenario JSON (replayable with ``benchmarks.run --scenario``)
  into ``examples/conformance/``, which doubles as the checked-in seed
  corpus that :func:`replay_corpus` re-runs green in CI.

CLI (see ``python -m repro.core.conformance --help``)::

    python -m repro.core.conformance fuzz --seeds 25
    python -m repro.core.conformance replay examples/conformance
    python -m repro.core.conformance sample 17 --out scenario.json
"""
from __future__ import annotations

import argparse
import math
import os
import random
import sys
from dataclasses import dataclass, replace
from pathlib import Path
from typing import Callable, Iterable, Sequence

from .experiment import Experiment, RunArtifact, RunOptions, Scenario

__all__ = [
    "EquivalenceContract",
    "CONTRACTS",
    "JAX_GRID_TOL",
    "JAX_GRID_REF_OPS",
    "P50_TOL",
    "P99_TOL",
    "P50_BIMODAL_GATE",
    "TAIL_REF_OPS",
    "CLUSTER_JAX_TOL",
    "CLUSTER_TAIL_TOL",
    "CLUSTER_REF_OPS",
    "jax_grid_tol",
    "tail_tol",
    "ConformanceFailure",
    "CHECK_NAMES",
    "check_scenario",
    "scenario_for_seed",
    "sample_scenario",
    "shrink_scenario",
    "write_repro",
    "replay_corpus",
    "fuzz",
]

# -- contract constants ------------------------------------------------------
#
# jax-vs-loop throughput: the two backends draw jitter from different RNG
# streams, so per-cell throughput differs by sampling noise ~ 1/sqrt(n).
# The documented bound is 1% on the paper grid's 20k-op cells
# (docs/SIMULATION.md); jax_grid_tol() scales it to other cell sizes.
JAX_GRID_TOL = 0.01           # relative throughput bound at JAX_GRID_REF_OPS
JAX_GRID_REF_OPS = 20_000     # paper-grid cell size the bound is measured at

# jax-vs-loop tails: the grid's log-histogram percentiles vs the loop's
# exact nearest-rank percentiles, documented at 400-op open-loop cells
# (tests/test_replay_jax.py measured ~3.4% p50 / ~6.2% p99 worst-case).
P50_TOL = 0.08                # relative p50 bound at TAIL_REF_OPS
P99_TOL = 0.12                # relative p90/p99 bound at TAIL_REF_OPS
TAIL_REF_OPS = 400
# p50 is only comparable on unimodal sojourn distributions: when the mass
# splits into a fast hit mode and a slow IO mode, the median rides the
# boundary and nearest-rank vs histogram quantiles legitimately disagree.
# Gate: compare p50 only when p90 < P50_BIMODAL_GATE * p50.
P50_BIMODAL_GATE = 1.5

# cluster jax-vs-loop: fleet throughput sums per-node cells, which
# averages the per-node noise down; documented at 800-op fleet sweeps
# (tests/test_cluster.py).  Fleet tails use one bound for p50 and p99.
CLUSTER_JAX_TOL = 0.01
CLUSTER_TAIL_TOL = 0.10
CLUSTER_REF_OPS = 800


def jax_grid_tol(n_ops: int, *, base: float = JAX_GRID_TOL,
                 ref_ops: int = JAX_GRID_REF_OPS,
                 slack: float = 1.0) -> float:
    """The jax-vs-loop relative throughput bound at a given cell size.

    Sampling noise between the two RNG streams scales like
    ``1/sqrt(n_ops)``, so the bound documented at ``ref_ops`` widens by
    ``sqrt(ref_ops / n_ops)`` for smaller cells (and never tightens below
    ``base`` for larger ones).  ``slack`` multiplies the result -- tests
    use small slacks for measured headroom, the fuzzer a larger one
    because it samples far outside the tuned grids.
    """
    return slack * base * max(1.0, math.sqrt(ref_ops / max(n_ops, 1)))


def tail_tol(n_ops: int, *, base: float,
             ref_ops: int = TAIL_REF_OPS, slack: float = 1.0) -> float:
    """Scale a documented tail-percentile bound to a given cell size."""
    return slack * base * max(1.0, math.sqrt(ref_ops / max(n_ops, 1)))


@dataclass(frozen=True)
class EquivalenceContract:
    """One row of the backend equivalence matrix.

    ``bit_identical`` pairs must agree byte-for-byte; tolerance pairs
    carry a relative ``throughput_tol`` documented at ``ref_ops``
    simulated ops per cell (scale with :func:`jax_grid_tol`) and tail
    bounds ``p50_tol`` / ``p99_tol`` (p90 shares the p99 bound; p50 is
    gated by :data:`P50_BIMODAL_GATE`).
    """

    name: str
    pair: tuple
    bit_identical: bool
    throughput_tol: float | None = None
    ref_ops: int | None = None
    p50_tol: float | None = None
    p99_tol: float | None = None
    tail_ref_ops: int | None = None
    why: str = ""


CONTRACTS: dict[str, EquivalenceContract] = {
    c.name: c for c in (
        EquivalenceContract(
            name="generic-vs-compiled",
            pair=("simulate", "simulate_compiled"),
            bit_identical=True,
            why="same event loop, same RNG draw order; the compiled loop "
                "is a mechanical specialization",
        ),
        EquivalenceContract(
            name="pallas-vs-jnp",
            pair=("sweep_grid(use_pallas=True)", "sweep_grid"),
            bit_identical=True,
            why="the fused Pallas kernel (interpreter mode on CPU) computes "
                "the same lockstep update as the jnp scan, same dtypes",
        ),
        EquivalenceContract(
            name="trivial-cluster",
            pair=("sweep_cluster(n_nodes=1)", "sweep_latency"),
            bit_identical=True,
            why="a 1-node fleet routes every op to node 0 with no route "
                "hop; the per-node cell is the single-host cell",
        ),
        EquivalenceContract(
            name="jax-vs-loop",
            pair=("sweep_grid", "simulate_compiled"),
            bit_identical=False,
            throughput_tol=JAX_GRID_TOL, ref_ops=JAX_GRID_REF_OPS,
            p50_tol=P50_TOL, p99_tol=P99_TOL, tail_ref_ops=TAIL_REF_OPS,
            why="different jitter RNG streams (Mersenne vs counter) and "
                "histogram vs exact percentiles; noise ~ 1/sqrt(n_ops)",
        ),
        EquivalenceContract(
            name="cluster-jax-vs-loop",
            pair=("sweep_cluster(backend='jax')",
                  "sweep_cluster(backend='loop')"),
            bit_identical=False,
            throughput_tol=CLUSTER_JAX_TOL, ref_ops=CLUSTER_REF_OPS,
            p50_tol=CLUSTER_TAIL_TOL, p99_tol=CLUSTER_TAIL_TOL,
            tail_ref_ops=CLUSTER_REF_OPS,
            why="fleet throughput sums per-node cells (noise averages "
                "down); fleet tails merge per-node histograms",
        ),
    )
}

# The fuzzer samples far outside the tuned benchmark grids (tiny cells,
# skewed clusters, deadline-censored tails), so it widens the documented
# bounds by a fixed slack on top of the 1/sqrt(n) scaling.  The cluster
# slack is largest: a skewed partition concentrates a fuzz cell's few
# hundred ops onto one hot node, so the effective per-cell sample is far
# smaller than the fleet total the 1/sqrt(n) scaling sees.
FUZZ_SLACK = 2.0
FUZZ_TAIL_SLACK = 1.5
FUZZ_CLUSTER_SLACK = 4.0

# Tails are only contract-comparable while service time dominates the
# sojourn.  Once the cell runs near or past saturation, queueing delay
# amplifies any throughput difference between the two RNG streams into
# unbounded tail divergence (rho/(1-rho) sensitivity), so the fuzzer
# skips tail comparison when the reference p99 exceeds this multiple of
# the cell's service scale (n_threads / throughput, the closed-loop
# per-op latency).  Throughput comparison -- which stays robust under
# overload -- still applies to those cells.
TAIL_QUEUE_GATE = 3.0

# Peaky open-loop arrivals (bursty on/off, diurnal with a deep swing)
# concentrate the tail mass into the burst peak: at a few hundred ops the
# p99 is decided by one or two peak-phase samples, which different
# service-RNG streams place differently.  Skip tail comparison for such
# rows unless the sample is large enough to average over phases.
PEAKY_TAIL_MIN_OPS = 400
DIURNAL_PEAKY_AMPLITUDE = 0.5

# Pallas interpreter mode executes the kernel step-by-step in Python, so
# the bit-identity check clips the scenario to one grid cell and at most
# this many ops -- the contract is per-cell, clipping loses no coverage.
PALLAS_CLIP_OPS = 120


# -- differential checks -----------------------------------------------------

@dataclass(frozen=True)
class ConformanceFailure:
    """One contract violation (or crash) found by :func:`check_scenario`."""

    check: str
    contract: str
    message: str
    scenario: Scenario

    def __str__(self) -> str:
        return (f"[{self.check}] {self.contract}: {self.message} "
                f"(scenario {self.scenario.display_name})")


def _run(sc: Scenario, **opts) -> RunArtifact:
    opts.setdefault("collect_percentiles", True)
    return Experiment(sc, RunOptions(**opts)).run()


def _rel(a: float, b: float) -> float:
    return abs(a - b) / abs(a) if a else (0.0 if not b else math.inf)


def _is_cluster(sc: Scenario) -> bool:
    cl = sc.cluster_spec()
    return cl is not None and cl.n_nodes > 1


def _row_core(row) -> dict:
    """The backend-determined slice of a row: what bit-identity compares.

    ``model_throughput`` is analytical (identical by construction but
    computed via the shares path on cluster rows) and ``nodes`` is absent
    on single-host rows, so both stay out of the cross-path comparison.
    """
    return {
        "L_us": row.L_us,
        "n_threads": row.n_threads,
        "throughput": row.throughput,
        "per_thread": row.per_thread,
        "tail": row.tail,
    }


def _check_compiled(sc: Scenario) -> list[ConformanceFailure]:
    """generic-vs-compiled bit-identity; on single-host scenarios also
    trivial-cluster degeneracy (the 1-node generic fleet must reproduce
    the compiled single-host sweep byte-for-byte, covering both
    contracts in one diff)."""
    ref = _run(sc, backend="loop")
    if _is_cluster(sc):
        contract = "generic-vs-compiled"
        other = _run(sc, backend="generic")
    else:
        contract = "trivial-cluster"
        other = _run(replace(sc, cluster={"n_nodes": 1}), backend="generic")
    fails = []
    for i, (rr, gr) in enumerate(zip(ref.rows, other.rows)):
        a, b = _row_core(rr), _row_core(gr)
        if a != b:
            diff = [k for k in a if a[k] != b[k]]
            fails.append(ConformanceFailure(
                "compiled", contract,
                f"row {i} ({rr.label()}) differs on {diff}: "
                f"{ {k: (a[k], b[k]) for k in diff} }", sc))
    return fails


def _tail_fails(ref_tail, got_tail, *, p50_tol, p99_tol, check, contract,
                label, sc) -> list[ConformanceFailure]:
    fails = []
    if not ref_tail or not got_tail:
        return fails

    def val(t, fld):
        v = t.get(fld)
        return v if isinstance(v, (int, float)) and v > 0 else None

    for fld in ("p90_us", "p99_us"):
        a, b = val(ref_tail, fld), val(got_tail, fld)
        if a and b and _rel(a, b) > p99_tol:
            fails.append(ConformanceFailure(
                check, contract,
                f"{label} {fld}: {a:.3g} vs {b:.3g} "
                f"(rel {_rel(a, b):.3f} > {p99_tol:.3f})", sc))
    a50, b50 = val(ref_tail, "p50_us"), val(got_tail, "p50_us")
    a90 = val(ref_tail, "p90_us")
    unimodal = a50 and a90 and a90 < P50_BIMODAL_GATE * a50
    if a50 and b50 and unimodal and _rel(a50, b50) > p50_tol:
        fails.append(ConformanceFailure(
            check, contract,
            f"{label} p50_us: {a50:.3g} vs {b50:.3g} "
            f"(rel {_rel(a50, b50):.3f} > {p50_tol:.3f})", sc))
    return fails


def _peaky_arrival(sc: Scenario) -> bool:
    """True for arrival processes whose tail mass sits in a burst peak
    (see :data:`PEAKY_TAIL_MIN_OPS`)."""
    arr = sc.arrival or {}
    kind = arr.get("kind")
    if kind == "bursty":
        return True
    return (kind == "diurnal"
            and arr.get("amplitude", 0.0) >= DIURNAL_PEAKY_AMPLITUDE)


def _queueing_dominated(row, n_nodes: int) -> bool:
    """True when the row's sojourn tail is queueing- rather than
    service-dominated (see :data:`TAIL_QUEUE_GATE`)."""
    tail = row.tail or {}
    p99 = tail.get("p99_us")
    if not isinstance(p99, (int, float)) or row.throughput <= 0:
        return False
    svc_us = 1e6 * row.n_threads * n_nodes / row.throughput
    return p99 > TAIL_QUEUE_GATE * svc_us


def _check_jax(sc: Scenario) -> list[ConformanceFailure]:
    """jax grid vs compiled loop within the contract's scaled tolerance.

    Per-thread cells are compared cell-wise (winning thread counts may
    legitimately differ when two candidates sit within noise of each
    other); the winner's throughput and tails are compared only when both
    backends picked the same candidate.
    """
    ref = _run(sc, backend="loop")
    jx = _run(sc, backend="jax")
    if _is_cluster(sc):
        contract = CONTRACTS["cluster-jax-vs-loop"]
        n_nodes = sc.cluster_spec().n_nodes
        tol = jax_grid_tol(sc.n_ops, base=contract.throughput_tol,
                           ref_ops=contract.ref_ops,
                           slack=FUZZ_CLUSTER_SLACK)
    else:
        contract = CONTRACTS["jax-vs-loop"]
        n_nodes = 1
        tol = jax_grid_tol(sc.n_ops, slack=FUZZ_SLACK)
    p50 = tail_tol(sc.n_ops, base=contract.p50_tol,
                   ref_ops=contract.tail_ref_ops, slack=FUZZ_TAIL_SLACK)
    p99 = tail_tol(sc.n_ops, base=contract.p99_tol,
                   ref_ops=contract.tail_ref_ops, slack=FUZZ_TAIL_SLACK)
    fails = []
    for i, (rr, jr) in enumerate(zip(ref.rows, jx.rows)):
        lbl = f"row {i} ({rr.label()})"
        ra, ja = dict(rr.per_thread), dict(jr.per_thread)
        for n in sorted(set(ra) & set(ja)):
            r = _rel(ra[n], ja[n])
            if r > tol:
                fails.append(ConformanceFailure(
                    "jax", contract.name,
                    f"{lbl} per_thread[{n}]: {ra[n]:.6g} vs {ja[n]:.6g} "
                    f"(rel {r:.4f} > {tol:.4f})", sc))
        if rr.n_threads == jr.n_threads:
            r = _rel(rr.throughput, jr.throughput)
            if r > tol:
                fails.append(ConformanceFailure(
                    "jax", contract.name,
                    f"{lbl} throughput: {rr.throughput:.6g} vs "
                    f"{jr.throughput:.6g} (rel {r:.4f} > {tol:.4f})", sc))
            skip_tails = (_queueing_dominated(rr, n_nodes)
                          or (_peaky_arrival(sc)
                              and sc.n_ops < PEAKY_TAIL_MIN_OPS))
            if not skip_tails:
                fails.extend(_tail_fails(
                    rr.tail, jr.tail, p50_tol=p50, p99_tol=p99,
                    check="jax", contract=contract.name, label=lbl, sc=sc))
    return fails


def _pallas_clip(sc: Scenario) -> Scenario:
    return replace(
        sc,
        latencies_us=(sc.latencies_us[0],),
        thread_candidates=(sc.thread_candidates[0],),
        n_ops=min(sc.n_ops, PALLAS_CLIP_OPS),
    )


def _check_pallas(sc: Scenario) -> list[ConformanceFailure]:
    """Pallas-interpreter vs jnp-scan bit-identity on one clipped cell."""
    clip = _pallas_clip(sc)
    ref = _run(clip, backend="jax")
    pal = _run(clip, backend="jax", use_pallas=True)
    fails = []
    for i, (rr, pr) in enumerate(zip(ref.rows, pal.rows)):
        a, b = _row_core(rr), _row_core(pr)
        if a != b:
            diff = [k for k in a if a[k] != b[k]]
            fails.append(ConformanceFailure(
                "pallas", "pallas-vs-jnp",
                f"row {i} ({rr.label()}) differs on {diff}: "
                f"{ {k: (a[k], b[k]) for k in diff} }", sc))
    return fails


_CHECKS: dict[str, Callable[[Scenario], list]] = {
    "compiled": _check_compiled,
    "jax": _check_jax,
    "pallas": _check_pallas,
}
CHECK_NAMES = tuple(_CHECKS)


def check_scenario(sc: Scenario,
                   checks: Sequence[str] = CHECK_NAMES
                   ) -> list[ConformanceFailure]:
    """Run the differential checks; a crash inside a check is itself a
    conformance failure (the backends must *run* everywhere the Scenario
    space is valid, not just agree where they run)."""
    fails: list[ConformanceFailure] = []
    for name in checks:
        try:
            fails.extend(_CHECKS[name](sc))
        except KeyError:
            raise ValueError(
                f"unknown check {name!r}; valid: {CHECK_NAMES}") from None
        except Exception as e:  # noqa: BLE001 -- crash == failure
            fails.append(ConformanceFailure(
                name, "crash", f"{type(e).__name__}: {e}", sc))
    return fails


# -- scenario sampling -------------------------------------------------------

# Every registered engine is fair game; the tiny key/op counts below keep
# even the heaviest traces sub-second.
ENGINE_POOL = (
    "hash-index", "open-addressing", "tree-index", "lsm", "slab-cache",
    "two-tier-cache", "cachelib-like", "memcached-like", "rocksdb-like",
    "aerospike-like",
)
_WORKLOAD_POOL = ("uniform", "zipf", "gaussian", "drifting-zipf")


def sample_scenario(rng: random.Random, seed: int = 0) -> Scenario:
    """One random small Scenario covering the fuzz axes.

    Sizes are chosen so a full differential pass (4 ``Experiment.run()``
    calls, two of them jax) stays in the seconds range: <= 3k keys, <= 1k
    trace ops, <= 600 simulated ops per cell, <= 4 grid cells.
    """
    spec: dict = dict(engine=rng.choice(ENGINE_POOL),
                      name=f"fuzz-{seed}",
                      seed=rng.randrange(1, 64),
                      n_keys=rng.choice((1500, 3000)),
                      n_wl_ops=rng.choice((600, 1000)),
                      n_ssd=rng.choice((1, 2)),
                      n_cores=rng.choice((1, 1, 2)))
    if rng.random() < 0.5:
        wname = rng.choice(_WORKLOAD_POOL)
        wkw: dict = {"seed": rng.randrange(5)}
        if wname == "zipf":
            wkw["exponent"] = rng.choice((0.9, 1.1, 1.3))
        elif wname == "gaussian":
            wkw["sigma_frac"] = rng.choice((0.05, 0.15))
        elif wname == "drifting-zipf":
            wkw["n_segments"] = rng.choice((4, 8))
        if rng.random() < 0.5:
            wkw["read_write"] = rng.choice(((1, 0), (2, 1), (1, 1)))
        spec.update(workload=wname, workload_kwargs=wkw)
    if spec["n_ssd"] > 1:
        spec["L_switch_us"] = rng.choice((0.0, 0.3))
    if rng.random() < 0.5:
        spec["R_io"] = rng.choice((150e3, 250e3))
    if rng.random() < 0.3:
        spec["T_lock_us"] = rng.choice((0.2, 0.5))
    lats = rng.sample((0.5, 1.0, 2.0, 5.0, 8.0), k=rng.choice((1, 2)))
    if rng.random() < 0.25:
        # tail-latency mixture entry (CXL-style slow outliers)
        lats[0] = ((1.0, 0.9), (10.0, 0.1))
    spec["latencies_us"] = tuple(lats)
    spec["thread_candidates"] = tuple(sorted(
        rng.sample((4, 8, 12, 16), k=rng.choice((1, 2)))))
    spec["n_ops"] = rng.choice((240, 400, 600))
    kind = rng.choice(("closed", "poisson", "bursty", "diurnal"))
    if kind != "closed":
        arr: dict = {"kind": kind,
                     "rate": rng.choice((80e3, 160e3, 240e3)),
                     "seed": rng.randrange(4)}
        if kind == "bursty":
            arr.update(on_fraction=0.25, period=0.005)
        elif kind == "diurnal":
            arr.update(amplitude=0.8, period=0.01)
        if rng.random() < 0.25:
            arr["deadline"] = 0.003
        spec["arrival"] = arr
    if rng.random() < 0.35:
        n_nodes = rng.choice((2, 3, 4))
        cl: dict = {"n_nodes": n_nodes,
                    "partition": rng.choice(("hash", "range")),
                    "L_route_us": rng.choice((0.0, 5.0))}
        if rng.random() < 0.5:
            cl.update(replication=2, replica_policy="spread")
        if rng.random() < 0.3:
            cl["node_overrides"] = {
                "1": {"io_degrade": 4.0, "T_degrade_us": 400.0}}
        if rng.random() < 0.25:
            cl["migrate"] = {"shard": 0, "to": n_nodes - 1, "at_frac": 0.5}
        spec["cluster"] = cl
    return Scenario(**spec)


def scenario_for_seed(seed: int) -> Scenario:
    """The deterministic Scenario for a fuzz seed (stable across runs and
    machines -- ``random.Random`` is a versioned PRNG)."""
    return sample_scenario(random.Random(0x5EED ^ (seed * 2654435761)),
                           seed)


# -- shrinking ---------------------------------------------------------------

def _reductions(sc: Scenario) -> Iterable[tuple[str, Scenario]]:
    """Candidate one-step simplifications, most structural first."""

    def attempt(name, **kw):
        try:
            return name, replace(sc, **kw)
        except (ValueError, TypeError):
            return None

    cands = []
    if sc.cluster:
        cands.append(attempt("drop-cluster", cluster={}))
        cl = dict(sc.cluster)
        if cl.get("migrate"):
            cands.append(attempt(
                "drop-migrate", cluster={**cl, "migrate": {}}))
        if cl.get("node_overrides"):
            cands.append(attempt(
                "drop-overrides", cluster={**cl, "node_overrides": {}}))
        if int(cl.get("replication", 1)) > 1:
            cands.append(attempt("drop-replication", cluster={
                **cl, "replication": 1, "replica_policy": "primary"}))
    if sc.arrival:
        cands.append(attempt("drop-arrival", arrival={}))
        if dict(sc.arrival).get("deadline"):
            cands.append(attempt("drop-deadline", arrival={
                **dict(sc.arrival), "deadline": 0.0}))
    if len(sc.latencies_us) > 1:
        cands.append(attempt(
            "one-latency", latencies_us=(sc.latencies_us[0],)))
        cands.append(attempt(
            "last-latency", latencies_us=(sc.latencies_us[-1],)))
    if len(sc.thread_candidates) > 1:
        cands.append(attempt(
            "one-candidate", thread_candidates=(sc.thread_candidates[0],)))
    if sc.n_ops > 60:
        cands.append(attempt("halve-n_ops", n_ops=max(60, sc.n_ops // 2)))
    if sc.n_wl_ops > 200:
        cands.append(attempt(
            "halve-n_wl_ops", n_wl_ops=max(200, sc.n_wl_ops // 2)))
    if sc.n_keys > 500:
        cands.append(attempt(
            "halve-n_keys", n_keys=max(500, sc.n_keys // 2)))
    if sc.n_cores > 1:
        cands.append(attempt("one-core", n_cores=1))
    if sc.n_ssd > 1:
        cands.append(attempt("one-ssd", n_ssd=1, L_switch_us=0.0))
    if sc.R_io or sc.B_io:
        cands.append(attempt("no-token-clock", R_io=0.0, B_io=0.0))
    if sc.T_lock_us:
        cands.append(attempt("no-lock", T_lock_us=0.0))
    if sc.workload:
        cands.append(attempt(
            "default-workload", workload="", workload_kwargs={}))
    return [c for c in cands if c is not None]


def shrink_scenario(sc: Scenario, checks: Sequence[str] = CHECK_NAMES,
                    budget: int = 40) -> tuple[Scenario, int]:
    """Greedily minimize a failing Scenario.

    Repeatedly tries the one-step reductions in order, accepting the
    first that still fails any of ``checks``, until a full pass accepts
    nothing or the evaluation ``budget`` (number of re-checks) runs out.
    Returns the smallest still-failing spec and the evaluations spent.
    """
    current, evals = sc, 0
    improved = True
    while improved and evals < budget:
        improved = False
        for name, cand in _reductions(current):
            if evals >= budget:
                break
            evals += 1
            if check_scenario(cand, checks):
                current = replace(cand, name=f"{sc.name}-shrunk")
                improved = True
                break
    return current, evals


def write_repro(sc: Scenario, check: str, out_dir: str | Path) -> Path:
    """Emit a failing (ideally shrunk) spec as plain scenario JSON.

    The file is a bare ``Scenario`` document, so it replays through
    ``benchmarks.run --scenario`` and ``replay_corpus`` alike; landing it
    in ``examples/conformance/`` turns the repro into a permanent
    regression test.
    """
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"repro_{check}_{sc.name or 'scenario'}.json"
    path.write_text(sc.to_json() + "\n")
    return path


# -- corpus + CLI ------------------------------------------------------------

def replay_corpus(corpus_dir: str | Path,
                  checks: Sequence[str] = CHECK_NAMES,
                  verbose: bool = False) -> list[ConformanceFailure]:
    """Re-run every ``*.json`` scenario in a corpus directory through the
    differential checks; returns all failures (empty == green)."""
    corpus_dir = Path(corpus_dir)
    paths = sorted(corpus_dir.glob("*.json"))
    if not paths:
        raise FileNotFoundError(f"no *.json scenarios in {corpus_dir}")
    fails: list[ConformanceFailure] = []
    for path in paths:
        sc = Scenario.from_json(path.read_text())
        got = check_scenario(sc, checks)
        fails.extend(got)
        if verbose:
            print(f"  {path.name}: "
                  f"{'FAIL x' + str(len(got)) if got else 'ok'}")
    return fails


def fuzz(n_seeds: int, seed_start: int = 0,
         checks: Sequence[str] = CHECK_NAMES,
         failures_dir: str | Path | None = None,
         shrink: bool = True, verbose: bool = False
         ) -> list[ConformanceFailure]:
    """Run ``n_seeds`` sampled scenarios through the checks, shrinking
    and emitting a repro JSON for each failing seed."""
    all_fails: list[ConformanceFailure] = []
    for seed in range(seed_start, seed_start + n_seeds):
        sc = scenario_for_seed(seed)
        fails = check_scenario(sc, checks)
        if verbose:
            print(f"  seed {seed} ({sc.display_name}): "
                  f"{'FAIL x' + str(len(fails)) if fails else 'ok'}")
        if not fails:
            continue
        failing_checks = tuple(dict.fromkeys(f.check for f in fails))
        shrunk = sc
        if shrink:
            shrunk, evals = shrink_scenario(sc, failing_checks)
            if verbose:
                print(f"    shrunk after {evals} evals: "
                      f"{shrunk.to_dict()}")
        if failures_dir is not None:
            path = write_repro(shrunk, failing_checks[0], failures_dir)
            if verbose:
                print(f"    repro -> {path}")
        all_fails.extend(fails)
    return all_fails


def _parse_checks(spec: str) -> tuple:
    checks = tuple(s.strip() for s in spec.split(",") if s.strip())
    unknown = set(checks) - set(CHECK_NAMES)
    if unknown:
        raise argparse.ArgumentTypeError(
            f"unknown check(s) {sorted(unknown)}; valid: {CHECK_NAMES}")
    return checks


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.conformance",
        description="Differential conformance fuzzer for the simulation "
                    "backends (see CONTRACTS in this module).")
    sub = ap.add_subparsers(dest="cmd", required=True)

    f = sub.add_parser("fuzz", help="sample seeds and check them")
    f.add_argument("--seeds", type=int, default=10)
    f.add_argument("--seed-start", type=int, default=0)
    f.add_argument("--checks", type=_parse_checks, default=CHECK_NAMES)
    f.add_argument("--failures", default=None, metavar="DIR",
                   help="emit shrunk repro JSONs here")
    f.add_argument("--no-shrink", action="store_true")

    r = sub.add_parser("replay", help="re-check a corpus directory")
    r.add_argument("corpus", help="directory of scenario *.json files")
    r.add_argument("--checks", type=_parse_checks, default=CHECK_NAMES)

    s = sub.add_parser("sample", help="print the Scenario for a seed")
    s.add_argument("seed", type=int)
    s.add_argument("--out", default=None, metavar="FILE")

    args = ap.parse_args(argv)
    # match benchmarks.run: keep the jax grid on the stable CPU path
    os.environ.setdefault("REPRO_JAX_LEGACY_CPU", "1")

    if args.cmd == "sample":
        sc = scenario_for_seed(args.seed)
        text = sc.to_json() + "\n"
        if args.out:
            Path(args.out).write_text(text)
            print(f"wrote {args.out}")
        else:
            print(text, end="")
        return 0

    if args.cmd == "replay":
        fails = replay_corpus(args.corpus, args.checks, verbose=True)
    else:
        fails = fuzz(args.seeds, args.seed_start, args.checks,
                     failures_dir=args.failures,
                     shrink=not args.no_shrink, verbose=True)
    for fail in fails:
        print(f"FAIL {fail}")
    print(f"{len(fails)} conformance failure(s)")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
