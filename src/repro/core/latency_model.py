"""Analytical throughput models from the paper (Eqs. 1-16).

Implements, in closed form and vectorized over memory latency:

  * Eq. 1   ``theta_single_inv``    -- single-threaded memory-only
  * Eq. 2   ``theta_multi_inv``     -- multi-threaded memory-only, no prefetch cap
  * Eq. 3   ``theta_mem_inv``       -- multi-threaded memory-only with prefetch
                                       queue depth P (Cho et al. regime)
  * Eq. 4   ``lstar_mem``           -- knee latency P*(T_mem+T_sw)
  * Eq. 5/6 ``theta_mask_inv``      -- masking-only memory-and-IO model
  * Eq. 7   ``theta_best_inv``      -- best-case misaligned memory-and-IO model
  * Eq. 8   ``lstar_best``          -- knee latency with IO: + P*E/M
  * Eq. 9-13 ``theta_prob_inv``     -- THE paper's probabilistic model
  * Eq. 14-15 ``theta_extended_inv``-- bandwidth/IOPS caps, tiering rho,
                                       premature-eviction epsilon
  * Eq. 16  ``cost_performance_ratio``

All times are in SECONDS (the paper quotes microseconds; helpers below accept
seconds so they compose with the simulator and the serving planner). All
``*_inv`` functions return the *reciprocal throughput*: expected CPU-core
seconds per KV operation. ``normalized_throughput`` reproduces the paper's
figures, which normalize by the DRAM-latency (0.1 us) operating point.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Callable

import numpy as np

US = 1e-6  # microsecond, for readable call sites

__all__ = [
    "OpParams",
    "SystemParams",
    "theta_single_inv",
    "theta_multi_inv",
    "theta_mem_inv",
    "theta_mask_inv",
    "theta_best_inv",
    "theta_prob_inv",
    "theta_extended_inv",
    "lstar_mem",
    "lstar_best",
    "normalized_throughput",
    "cost_performance_ratio",
    "fit_p_tsw_from_memory_only",
    "PAPER_EXAMPLE",
    "PAPER_SYSTEM",
]


@dataclass(frozen=True)
class OpParams:
    """Operation-model parameters (Table 1 of the paper).

    ``M`` is the average number of (long-latency) memory accesses per
    *operation* and ``S`` the average number of IOs per operation. The
    Sec. 3.2.3 extension splits one op into S sub-operations with M/S
    memory accesses each; the theta functions below do that internally,
    so Table 1's per-IO M equals ``M/S``.  ``N=None`` means "optimally
    many user-level threads" (the paper reports the best N per point).
    """

    M: float = 10.0
    T_mem: float = 0.10 * US
    T_io_pre: float = 4.0 * US
    T_io_post: float = 3.0 * US
    T_sw: float = 0.05 * US
    P: int = 10
    N: int | None = None
    S: float = 1.0

    @property
    def E(self) -> float:
        """Eq. 6: CPU time one IO costs: pre + post + two context switches."""
        return self.T_io_pre + self.T_io_post + 2.0 * self.T_sw


@dataclass(frozen=True)
class SystemParams:
    """System parameters for the extended model (Table 2 of the paper)."""

    A_mem: float = 64.0            # memory access (cacheline) size, bytes
    B_mem: float = 10e9            # max memory bandwidth, bytes/sec
    A_io: float = 1024.0           # SSD access size, bytes
    B_io: float = 10e9             # max SSD bandwidth, bytes/sec
    R_io: float = 2.2e6            # max SSD random IOPS
    rho: float = 1.0               # offload ratio of indices/caches
    eps: float = 0.0               # premature CPU-cache eviction ratio
    L_dram: float = 0.1 * US       # host DRAM latency


PAPER_EXAMPLE = OpParams()          # Table 1 example column
PAPER_SYSTEM = SystemParams()       # Table 2 example column


def _as_array(L_mem) -> np.ndarray:
    return np.atleast_1d(np.asarray(L_mem, dtype=np.float64))


# ---------------------------------------------------------------------------
# Memory-only models (Sec. 3.1)
# ---------------------------------------------------------------------------

def theta_single_inv(L_mem, p: OpParams = PAPER_EXAMPLE) -> np.ndarray:
    """Eq. 1: reciprocal throughput of naive single-threaded execution."""
    L = _as_array(L_mem)
    return p.T_mem + L


def theta_multi_inv(L_mem, p: OpParams = PAPER_EXAMPLE) -> np.ndarray:
    """Eq. 2: multi-threaded, unlimited prefetch queue."""
    L = _as_array(L_mem)
    first = p.T_mem + p.T_sw
    if p.N is None:  # optimal N: second term vanishes
        return np.full_like(L, first)
    return np.maximum(first, (p.T_mem + L) / p.N)


def theta_mem_inv(L_mem, p: OpParams = PAPER_EXAMPLE) -> np.ndarray:
    """Eq. 3: multi-threaded with prefetch-queue depth P."""
    L = _as_array(L_mem)
    out = np.maximum(theta_multi_inv(L, p), L / p.P)
    return out


def lstar_mem(p: OpParams = PAPER_EXAMPLE) -> float:
    """Eq. 4: latency knee of the memory-only model."""
    return p.P * (p.T_mem + p.T_sw)


# ---------------------------------------------------------------------------
# Memory-and-IO models (Sec. 3.2)
# ---------------------------------------------------------------------------

def theta_mask_inv(L_mem, p: OpParams = PAPER_EXAMPLE) -> np.ndarray:
    """Eq. 5: masking-only model -- IO only adds a constant CPU-time offset.

    Represents the *aligned* thread schedule of Fig. 7(a); the paper shows it
    underestimates throughput by up to 32.7%.
    """
    m_per_io = p.M / p.S
    return p.S * (m_per_io * theta_mem_inv(L_mem, p) + p.E)


def theta_best_inv(L_mem, p: OpParams = PAPER_EXAMPLE) -> np.ndarray:
    """Eq. 7: best-case fully misaligned schedule (upper bound on throughput)."""
    L = _as_array(L_mem)
    m_per_io = p.M / p.S
    core = np.maximum(m_per_io * (p.T_mem + p.T_sw) + p.E, m_per_io * L / p.P)
    return p.S * core


def lstar_best(p: OpParams = PAPER_EXAMPLE) -> float:
    """Eq. 8: latency knee with IO -- extended by P*E/M."""
    m_per_io = p.M / p.S
    return p.P * (p.T_mem + p.T_sw) + p.P * p.E / m_per_io


def _logfact(n: np.ndarray) -> np.ndarray:
    return np.vectorize(math.lgamma)(np.asarray(n, dtype=np.float64) + 1.0)


def theta_prob_inv(
    L_mem,
    p: OpParams = PAPER_EXAMPLE,
    sysp: SystemParams | None = None,
    k_max: int = 120,
) -> np.ndarray:
    """Eqs. 9-13: the paper's probabilistic memory-and-IO model.

    With ``sysp`` given, applies the Eq. 15 latency replacement (tiering rho
    and memory-bandwidth floor) and the epsilon premature-eviction extension;
    the Eq. 14 outer IO caps are applied by :func:`theta_extended_inv`.
    """
    L = _as_array(L_mem)
    m_per_io = p.M / p.S
    Mp2 = m_per_io + 2.0

    eps = 0.0 if sysp is None else sysp.eps
    q_mem = (1.0 - eps) * m_per_io / Mp2     # pre-eviction memory subop
    q_pre = 1.0 / Mp2                        # pre-IO subop
    q_post = 1.0 / Mp2                       # post-IO subop
    q_ev = eps * m_per_io / Mp2              # post-eviction memory subop

    P = int(p.P)
    js = np.arange(P + 1)

    if sysp is None:
        L_eff = np.broadcast_to(L, (P + 1, L.size))  # (j, L)
    else:
        tier = sysp.rho * L + (1.0 - sysp.rho) * sysp.L_dram
        bw_floor = ((P - js)[:, None]) * sysp.A_mem / sysp.B_mem
        L_eff = np.maximum(tier[None, :], bw_floor)   # Eq. 15, (j, L)

    base = P * (p.T_mem + p.T_sw)
    red_pre = p.T_io_pre - p.T_mem           # Fig. 8(b)
    red_post = p.T_io_post + p.T_sw          # Fig. 8(c)
    red_ev = L_eff + p.T_sw                  # eviction stall drains like post-IO

    lf = math.lgamma
    log_qmem = math.log(q_mem) if q_mem > 0 else -math.inf
    log_qpre = math.log(q_pre)
    log_qpost = math.log(q_post)
    log_qev = math.log(q_ev) if q_ev > 0 else -math.inf

    num = np.zeros(L.size)
    den = 0.0
    extra_stall = np.zeros(L.size)  # expected direct eviction stall per subop

    m_max = 0 if eps == 0.0 else k_max
    for j in range(P + 1):
        for k in range(k_max + 1):
            for m in range(m_max + 1):
                n_len = P + k + m
                logp = (
                    lf(n_len + 1) - lf(P - j + 1) - lf(j + 1) - lf(k + 1) - lf(m + 1)
                    + (P - j) * log_qmem + j * log_qpre + k * log_qpost
                    + (m * log_qev if m > 0 else 0.0)
                )
                prob = math.exp(logp) if logp > -745.0 else 0.0
                if prob < 1e-14 and (k > 2 or m > 2):
                    break  # tail vanishes monotonically in k (and m)
                wait = np.maximum(
                    0.0,
                    L_eff[j]
                    - base
                    - j * red_pre
                    - k * red_post
                    - (m * red_ev[j] if m > 0 else 0.0),
                )
                num += prob * wait
                den += prob * n_len
                if m > 0:
                    extra_stall += prob * m * L_eff[j]
        # inner `break` only exits the m loop; the k loop breaks on its own
        # via the same vanishing-probability criterion below.
    t_wait_subop = num / den                             # Eq. 12
    t_evict_subop = extra_stall / den if eps > 0 else 0.0

    core = (
        m_per_io * (p.T_mem + p.T_sw)
        + p.E
        + (m_per_io + 2.0) * (t_wait_subop + t_evict_subop)
    )                                                    # Eq. 13
    return p.S * core


def theta_extended_inv(
    L_mem,
    p: OpParams = PAPER_EXAMPLE,
    sysp: SystemParams = PAPER_SYSTEM,
    n_cores: int = 1,
    k_max: int = 120,
) -> np.ndarray:
    """Eq. 14: per-core reciprocal throughput with SSD bandwidth/IOPS caps.

    ``n_cores`` scales the shared-SSD caps: with C cores running in parallel,
    each core may use only 1/C of the SSD bandwidth and IOPS budget.
    """
    rev = theta_prob_inv(L_mem, p, sysp=sysp, k_max=k_max)
    io_bw_cap = p.S * sysp.A_io / (sysp.B_io / n_cores)
    io_ops_cap = p.S / (sysp.R_io / n_cores)
    return np.maximum(rev, np.maximum(io_bw_cap, io_ops_cap))


# ---------------------------------------------------------------------------
# Utilities
# ---------------------------------------------------------------------------

def normalized_throughput(
    inv_fn: Callable[..., np.ndarray],
    L_mem,
    p: OpParams = PAPER_EXAMPLE,
    L_ref: float = 0.1 * US,
    **kw,
) -> np.ndarray:
    """Throughput(L) / Throughput(L_ref) as plotted in Figs. 3 and 11."""
    num = inv_fn(np.asarray([L_ref]), p, **kw)
    return num[0] / inv_fn(L_mem, p, **kw)


def cost_performance_ratio(c: float, b: float, d: float) -> float:
    """Eq. 16: CPR r = (1 - d) / (c*b + (1 - c)).

    c: replaced-DRAM share of server cost, b: relative bit cost of the
    secondary memory, d: throughput degradation it causes. r > 1 means the
    cheaper memory wins.
    """
    return (1.0 - d) / (c * b + (1.0 - c))


def fit_p_tsw_from_memory_only(
    L_mem: np.ndarray, theta: np.ndarray, T_mem: float
) -> tuple[int, float]:
    """Estimate (P, T_sw) from a measured memory-only throughput curve.

    Mirrors the paper's calibration: the flat region gives 1/(T_mem+T_sw),
    the latency-proportional tail gives L/P (Eq. 3).
    """
    inv = 1.0 / np.asarray(theta, dtype=np.float64)
    L = np.asarray(L_mem, dtype=np.float64)
    flat = inv.min()
    t_sw = max(flat - T_mem, 0.0)
    tail = L > 4.0 * (T_mem + t_sw) * 1.0  # comfortably past the knee
    if not np.any(tail):
        return 10, t_sw
    slopes = L[tail] / inv[tail]
    return int(round(float(np.median(slopes)))), t_sw
