"""SSD-based KV-store engines mirroring the paper's three modified stores.

The paper modifies Aerospike, RocksDB and CacheLib so their large in-memory
indices/caches live on microsecond-latency memory and every access to them is
a prefetch+yield. We implement the *data-structure cores* of those three
designs (Fig. 13) as real Python/numpy structures:

  * :class:`TreeIndexStore`   (Aerospike-like)  -- per-sprig binary search
    trees of 64-byte nodes on slow memory; values on SSD; writes buffered
    into large flush blocks.
  * :class:`LSMStore`         (RocksDB-like)    -- sorted-run data blocks on
    SSD, an LRU block cache on slow memory, fence index + memtable in DRAM,
    Zipfian access, flush/compaction background writes.
  * :class:`TwoTierCacheStore` (CacheLib-like)  -- DRAM hash buckets chaining
    to items + LRU lists on slow memory (tier 1), small-object cache on SSD
    (tier 2), admission on miss and buffered eviction writes.

Running a workload produces a **trace**: per-operation suboperation lists
(`Op`) in which every pointer dereference on slow memory is a MEM subop and
every SSD access a PREIO/POSTIO pair -- exactly the operation model of
Sec. 3. The trace is executed by :mod:`repro.core.simulator` to obtain
throughput vs. memory latency, and summarized into ``OpParams`` so the
closed-form model of :mod:`repro.core.latency_model` can be compared against
the "measurement" (Figs. 11(c)(d)(e)).

Only reads/updates go through the traced path; bulk loading is untraced
(the paper also measures after load + warm-up).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .latency_model import OpParams, US
from .simulator import CPU, MEM, POSTIO, PREIO, Op
from .workloads import Workload

__all__ = [
    "EngineTimes",
    "Recorder",
    "TraceResult",
    "TreeIndexStore",
    "LSMStore",
    "TwoTierCacheStore",
]


@dataclass(frozen=True)
class EngineTimes:
    """CPU-time constants of one engine's suboperations (calibratable)."""

    t_mem: float = 0.10 * US      # compute attached to one slow-memory hop
    t_io_pre: float = 1.5 * US    # IO submission (io_uring sqe prep + submit)
    t_io_post: float = 0.2 * US   # completion check + copy
    t_probe: float = 0.05 * US    # a DRAM-side probe (hash, fence index)
    t_value: float = 0.3 * US     # value (de)serialization / checksum


class Recorder:
    """Collects suboperations for the current KV operation."""

    def __init__(self, times: EngineTimes):
        self.t = times
        self.ops: list[Op] = []
        self._cur: list[tuple[int, float]] = []
        self.n_mem = 0
        self.n_io = 0
        self.n_ops = 0

    def mem(self, n: int = 1) -> None:
        self._cur.extend([(MEM, self.t.t_mem)] * n)
        self.n_mem += n

    def cpu(self, t: float) -> None:
        if t > 0.0:
            self._cur.append((CPU, t))

    def io(self, pre_extra: float = 0.0, post_extra: float = 0.0) -> None:
        self._cur.append((PREIO, self.t.t_io_pre + pre_extra))
        self._cur.append((POSTIO, self.t.t_io_post + post_extra))
        self.n_io += 1

    def end_op(self) -> None:
        if not self._cur:  # never emit empty ops
            self._cur.append((CPU, self.t.t_probe))
        self.ops.append(Op(tuple(self._cur)))
        self._cur = []
        self.n_ops += 1


@dataclass
class TraceResult:
    ops: list[Op]
    mem_per_op: float             # average slow-memory hops per operation
    io_per_op: float              # average SSD accesses per operation (S)
    hit_stats: dict = field(default_factory=dict)

    def op_params(self, times: EngineTimes, P: int, T_sw: float) -> OpParams:
        """Summarize the trace into the paper's model parameters.

        Calibrated the way the paper does it (Sec. 4.2.3): T_mem / T_io_pre /
        T_io_post are the mean *CPU spans between yields* measured on the
        trace -- plain CPU suboperations (hashing, serialization) do not
        yield, so their time folds into the span of the next yield point.
        M is memory accesses per *operation*; the theta functions divide
        by S internally (Sec. 3.2.3 splitting). Ops with no IO (pure
        cache hits) contribute their hops to the average.
        """
        del times  # spans are measured from the trace, not the constants
        span_sum = {MEM: 0.0, PREIO: 0.0, POSTIO: 0.0}
        span_n = {MEM: 0, PREIO: 0, POSTIO: 0}
        pending_cpu = 0.0
        last_yield: int | None = None
        for op in self.ops:
            for kind, dur in op.subops:
                if kind == CPU:
                    pending_cpu += dur
                    continue
                span_sum[kind] += dur + pending_cpu
                span_n[kind] += 1
                pending_cpu = 0.0
                last_yield = kind
        if pending_cpu > 0.0 and last_yield is not None:
            span_sum[last_yield] += pending_cpu

        def mean(kind: int, default: float) -> float:
            return span_sum[kind] / span_n[kind] if span_n[kind] else default

        S = max(self.io_per_op, 1e-9)
        return OpParams(
            M=self.mem_per_op,
            T_mem=mean(MEM, 0.1 * US),
            T_io_pre=mean(PREIO, 1.5 * US),
            T_io_post=mean(POSTIO, 0.2 * US),
            T_sw=T_sw,
            P=P,
            S=S,
        )


# ---------------------------------------------------------------------------
# Aerospike-like: in-memory tree index, values on SSD
# ---------------------------------------------------------------------------

class TreeIndexStore:
    """Per-sprig unbalanced BSTs of 64-byte nodes (Aerospike primary index).

    get  = sprig hash (DRAM) + tree walk (slow-memory hops) + one SSD read.
    put  = tree walk + write-buffer append; a large flush IO every
           ``flush_block // value_size`` writes (Aerospike write blocks).
    """

    def __init__(
        self,
        n_keys: int,
        n_sprigs: int = 256,
        value_size: int = 1536,
        flush_block: int = 131072,
        times: EngineTimes | None = None,
        seed: int = 0,
    ):
        # Aerospike's storage path spends much more CPU per IO than raw
        # io_uring (network/defrag bookkeeping); the paper's Table 1
        # example quotes T_io_pre ~ 4 us, T_io_post ~ 3 us for this class.
        self.times = times or EngineTimes(t_io_pre=3.0 * US, t_io_post=2.0 * US)
        self.n_keys = n_keys
        self.n_sprigs = n_sprigs
        self.value_size = value_size
        self.flush_every = max(flush_block // value_size, 1)
        rng = np.random.default_rng(seed)
        order = rng.permutation(n_keys)
        # array-based BST per sprig: node i has key keys[i], children l/r
        self.sprig_of = (
            (np.arange(n_keys, dtype=np.uint64) * np.uint64(0x9E3779B97F4A7C15))
            % np.uint64(n_sprigs)
        ).astype(np.int64)
        self.root = [-1] * n_sprigs
        self.key = np.empty(n_keys, dtype=np.int64)
        self.left = np.full(n_keys, -1, dtype=np.int64)
        self.right = np.full(n_keys, -1, dtype=np.int64)
        self.node_of: dict[int, int] = {}
        self._n_nodes = 0
        for k in order.tolist():
            self._insert(int(k))
        self._pending_writes = 0

    def _insert(self, k: int) -> int:
        """Untraced build-time insert; returns hop count."""
        i = self._n_nodes
        self.key[i] = k
        self.node_of[k] = i
        self._n_nodes += 1
        s = int(self.sprig_of[k])
        cur = self.root[s]
        hops = 0
        if cur < 0:
            self.root[s] = i
            return 0
        while True:
            hops += 1
            if k < self.key[cur]:
                if self.left[cur] < 0:
                    self.left[cur] = i
                    return hops
                cur = self.left[cur]
            else:
                if self.right[cur] < 0:
                    self.right[cur] = i
                    return hops
                cur = self.right[cur]

    def _sprig(self, k: int) -> int:
        # python ints: intentional 64-bit multiplicative hash without
        # numpy's overflow warning
        return ((int(k) * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) % self.n_sprigs

    def _walk(self, k: int, rec: Recorder) -> bool:
        rec.cpu(self.times.t_probe)  # sprig hash + root lookup (DRAM)
        cur = self.root[self._sprig(k)]
        while cur >= 0:
            rec.mem()  # node is a 64-byte record on slow memory
            if k == self.key[cur]:
                return True
            cur = self.left[cur] if k < self.key[cur] else self.right[cur]
        return False

    def op(self, k: int, is_write: bool, rec: Recorder) -> None:
        found = self._walk(k, rec)
        if is_write:
            rec.cpu(self.times.t_value)       # serialize into write buffer
            rec.mem()                          # update index entry in place
            self._pending_writes += 1
            if self._pending_writes >= self.flush_every:
                self._pending_writes = 0
                rec.io(pre_extra=0.5 * US)     # large-block flush write
        elif found:
            rec.io()                           # read value from SSD
            rec.cpu(self.times.t_value)
        rec.end_op()


# ---------------------------------------------------------------------------
# RocksDB-like: LSM data blocks on SSD, block cache on slow memory
# ---------------------------------------------------------------------------

class LSMStore:
    """Single sorted run partitioned into data blocks + LRU block cache.

    Fence index and memtable stay in DRAM (the paper offloads only the 32-GB
    block cache, 80% of footprint). A block-cache probe costs hash + LRU
    hops on slow memory; a hit binary-searches the block's restart points
    (slow memory); a miss reads the 4-kB block from SSD and installs it.
    """

    def __init__(
        self,
        n_keys: int,
        entries_per_block: int = 10,       # ~4 kB / 400-B values
        cache_blocks: int | None = None,   # None: sized for ~67% hit @ Zipf .99
        restart_interval: int = 16,
        memtable_ops: int = 4096,
        times: EngineTimes = EngineTimes(),
    ):
        self.times = times
        self.n_keys = n_keys
        self.epb = entries_per_block
        self.n_blocks = (n_keys + entries_per_block - 1) // entries_per_block
        if cache_blocks is None:
            cache_blocks = max(self.n_blocks // 12, 1)
        self.cache_cap = cache_blocks
        self.restart = restart_interval
        self.memtable_ops = memtable_ops
        # LRU block cache: block_id -> tick; plus an eviction clock.
        from collections import OrderedDict

        self.cache: "OrderedDict[int, None]" = OrderedDict()
        self._mem_writes = 0
        self.hits = 0
        self.lookups = 0

    def _search_block(self, rec: Recorder) -> None:
        # binary search over restart points, then linear scan inside one
        # restart interval; every probed key is a slow-memory access.
        import math

        n_restarts = max(self.epb // self.restart, 1)
        hops = max(int(math.ceil(math.log2(n_restarts + 1))), 1)
        hops += min(self.restart, self.epb) // 4  # expected linear-scan touches
        rec.mem(hops)

    def op(self, k: int, is_write: bool, rec: Recorder) -> None:
        t = self.times
        if is_write:
            rec.cpu(t.t_probe + t.t_value)     # memtable insert (DRAM skiplist)
            self._mem_writes += 1
            if self._mem_writes >= self.memtable_ops:
                self._mem_writes = 0
                # flush: one large sequential write + compaction read+write
                rec.io(pre_extra=1.0 * US)
                rec.io(pre_extra=1.0 * US)
                rec.cpu(20.0 * US)             # compaction merge CPU burst
            rec.end_op()
            return
        rec.cpu(t.t_probe)                     # memtable probe (DRAM)
        rec.cpu(t.t_probe)                     # fence-index binary search (DRAM)
        block = int(k) // self.epb
        self.lookups += 1
        rec.mem()                              # cache hash-bucket probe
        if block in self.cache:
            self.hits += 1
            self.cache.move_to_end(block)
            rec.mem(2)                         # LRU unlink/relink touches
        else:
            rec.io()                           # read 4-kB data block
            rec.cpu(t.t_value)                 # checksum + decode
            self.cache[block] = None
            rec.mem(2)                         # insert into hash + LRU head
            if len(self.cache) > self.cache_cap:
                self.cache.popitem(last=False)
                rec.mem(2)                     # evict tail: unlink + hash del
        self._search_block(rec)
        rec.cpu(t.t_value)
        rec.end_op()

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(self.lookups, 1)


# ---------------------------------------------------------------------------
# CacheLib-like: two-tier cache, chained items + LRU on slow memory
# ---------------------------------------------------------------------------

class TwoTierCacheStore:
    """Tier-1: DRAM hash buckets -> item chains + LRU list on slow memory.
    Tier-2: SSD small-object cache. Misses fetch from the backing store
    (CPU-modelled) and admit into tier 1, evicting to tier 2.
    """

    def __init__(
        self,
        n_keys: int,
        tier1_items: int | None = None,    # None: ~8% of keys (8 GB / 100 M)
        tier2_items: int | None = None,    # None: ~32% of keys
        avg_chain: float = 1.5,
        times: EngineTimes = EngineTimes(),
        seed: int = 0,
    ):
        from collections import OrderedDict

        self.times = times
        self.n_keys = n_keys
        self.t1_cap = tier1_items if tier1_items is not None else max(n_keys // 12, 1)
        self.t2_cap = tier2_items if tier2_items is not None else max(n_keys // 3, 1)
        self.avg_chain = avg_chain
        self.t1: "OrderedDict[int, None]" = OrderedDict()
        self.t2: "OrderedDict[int, None]" = OrderedDict()
        self.rng = np.random.default_rng(seed)
        self.t1_hits = 0
        self.t2_hits = 0
        self.t2_lookups = 0
        self.gets = 0
        self._evict_buffer = 0
        self._flush_every = 16                 # buffered tier-2 region writes

    def _chain_walk(self, rec: Recorder, found: bool) -> None:
        # hash bucket is DRAM; each chained item is a slow-memory node
        rec.cpu(self.times.t_probe)
        hops = 1 + self.rng.poisson(max(self.avg_chain - 1.0, 0.0))
        if not found:
            hops = max(hops - 1, 1)
        rec.mem(int(hops))

    def _admit(self, k: int, rec: Recorder) -> None:
        self.t1[k] = None
        rec.mem(2)                             # alloc item + chain-head insert
        if len(self.t1) > self.t1_cap:
            victim, _ = self.t1.popitem(last=False)
            rec.mem(3)                         # LRU tail unlink + chain del
            self.t2[victim] = None
            self._evict_buffer += 1
            if self._evict_buffer >= self._flush_every:
                self._evict_buffer = 0
                rec.io(pre_extra=0.5 * US)     # flush a tier-2 region write
            if len(self.t2) > self.t2_cap:
                self.t2.popitem(last=False)

    def op(self, k: int, is_write: bool, rec: Recorder) -> None:
        t = self.times
        if is_write:
            if k in self.t1:
                self._chain_walk(rec, True)
                self.t1.move_to_end(k)
                rec.mem(3)                     # LRU promote
                rec.cpu(t.t_value)
            else:
                self._chain_walk(rec, False)
                rec.cpu(t.t_value)
                self._admit(k, rec)
            rec.end_op()
            return
        self.gets += 1
        if k in self.t1:
            self.t1_hits += 1
            self._chain_walk(rec, True)
            self.t1.move_to_end(k)
            rec.mem(3)                         # LRU promote
            rec.cpu(t.t_value)
            rec.end_op()
            return
        self._chain_walk(rec, False)
        self.t2_lookups += 1
        rec.io()                               # tier-2 SOC bucket read
        if k in self.t2:
            self.t2_hits += 1
            self.t2.move_to_end(k)
            rec.cpu(t.t_value)
        else:
            rec.cpu(2.0 * US)                  # backing-store fetch + build
        self._admit(k, rec)
        rec.end_op()

    @property
    def hit_stats(self) -> dict:
        t1 = self.t1_hits / max(self.gets, 1)
        t2 = self.t2_hits / max(self.t2_lookups, 1)
        return {"tier1": t1, "tier2": t2, "overall": t1 + (1 - t1) * t2}


# ---------------------------------------------------------------------------
# Tracing driver
# ---------------------------------------------------------------------------

def run_trace(store, wl: Workload, warmup_frac: float = 0.3) -> TraceResult:
    """Run a workload through an engine, recording only the post-warm-up ops."""
    n_warm = int(len(wl) * warmup_frac)
    warm_rec = Recorder(store.times)
    rec = Recorder(store.times)
    for i, (k, w) in enumerate(wl.pairs()):
        store.op(int(k), bool(w), warm_rec if i < n_warm else rec)
        if i < n_warm:
            # discard warm-up subops to bound memory
            warm_rec.ops.clear()
    hit_stats = {}
    if isinstance(store, LSMStore):
        hit_stats = {"block_cache": store.hit_ratio}
    elif isinstance(store, TwoTierCacheStore):
        hit_stats = store.hit_stats
    return TraceResult(
        ops=rec.ops,
        mem_per_op=rec.n_mem / max(rec.n_ops, 1),
        io_per_op=rec.n_io / max(rec.n_ops, 1),
        hit_stats=hit_stats,
    )
