"""Deprecation shim: the KV engines now live in :mod:`repro.core.engines`.

``repro.core.kvstore`` re-exports the old module's public API so existing
imports keep working:

  * the three engines (:class:`TreeIndexStore`, :class:`LSMStore`,
    :class:`TwoTierCacheStore`) and their :class:`EngineTimes`
  * the tracing machinery (:class:`Recorder`, :class:`TraceResult`,
    :func:`run_trace`)

New code should import from :mod:`repro.core.engines`, which additionally
provides the :class:`KVEngine` protocol and the engine registry
(:func:`register_engine` / :func:`get_engine` / :func:`create_engine`).
"""
from __future__ import annotations

import warnings

# The pre-refactor module also exposed these at module level (it imported
# them for its own use); legacy `from repro.core.kvstore import ...` lines
# must keep resolving them.
from .latency_model import OpParams, US  # noqa: F401
from .trace_ir import CPU, MEM, POSTIO, PREIO, Op  # noqa: F401
from .workloads import Workload  # noqa: F401
from .engines import (  # noqa: F401
    EngineTimes,
    KVEngine,
    LSMStore,
    Recorder,
    TraceResult,
    TreeIndexStore,
    TwoTierCacheStore,
    available_engines,
    create_engine,
    get_engine,
    register_engine,
    run_trace,
)

__all__ = [
    "EngineTimes",
    "Recorder",
    "TraceResult",
    "TreeIndexStore",
    "LSMStore",
    "TwoTierCacheStore",
    "run_trace",
]

# stacklevel=2 attributes the warning to the importing file: CPython's warn
# walks past its own importlib frames when counting stack levels, so level 2
# of a module body *is* the caller's ``import repro.core.kvstore`` line.
warnings.warn(
    "repro.core.kvstore is deprecated: the engines live in "
    "repro.core.engines (e.g. 'from repro.core.engines import LSMStore, "
    "run_trace'); model/trace types moved to repro.core.latency_model / "
    "repro.core.trace_ir / repro.core.workloads. See docs/ENGINES.md for "
    "the migration map.",
    DeprecationWarning,
    stacklevel=2,
)
