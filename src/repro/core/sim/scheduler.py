"""Thread/core scheduling state: FIFO ready rings and the parked-thread heap.

The paper's execution model is N user-level threads per core on a strict
FIFO ready ring, one context switch (T_sw) charged per suboperation yield,
and threads parked off-core while their asynchronous IO is in flight.  This
module holds those data structures; :mod:`.engine_loop` drives them.

Division of labour with :mod:`.devices`: the scheduler owns *where a thread
is* (on a ready ring, or in the parked heap keyed by its IO completion
time), the device layer owns *when things finish* (prefetch completions and
the per-device SSD token clocks).  The two meet at exactly two points --
a PREIO suboperation parks its thread until ``SSDClocks.submit`` says the
IO completes (on whichever SSD the round-robin stripe placed it), and a MEM
suboperation stalls its thread until ``PrefetchUnit.issue``'s completion
time.  Per-core state (ready ring, prefetch unit) is private to the core;
the parked heap and the SSD clocks are shared across all cores, which is
what makes multi-device IOPS an aggregate, machine-wide resource.
"""
from __future__ import annotations

import heapq
from collections import deque

from .devices import PrefetchUnit

__all__ = ["Thread", "Core", "ParkedHeap"]


class Thread:
    """One user-level thread: its current op (as subop cursor) + prefetch."""

    __slots__ = ("tid", "subops", "idx", "pf_ready", "op_start", "wake")

    def __init__(self, tid: int):
        self.tid = tid
        self.subops: tuple[tuple[int, float], ...] = ()
        self.idx = 0
        self.pf_ready = 0.0   # completion time of the prefetch for subops[idx]
        self.op_start = 0.0
        self.wake = 0.0


class Core:
    """One core: local clock, FIFO ready ring, and its prefetch unit."""

    __slots__ = ("now", "ready", "prefetch", "idle")

    def __init__(self):
        self.now = 0.0
        self.ready: deque[Thread] = deque()
        self.prefetch = PrefetchUnit()
        self.idle = 0.0


class ParkedHeap:
    """Threads waiting on IO completion, ordered by wake time.

    Entries are ``(wake_time, seq, core_id, thread)``; ``seq`` breaks ties
    FIFO so scheduling is deterministic.
    """

    __slots__ = ("heap", "_seq")

    def __init__(self):
        self.heap: list[tuple[float, int, int, Thread]] = []
        self._seq = 0

    def __bool__(self) -> bool:
        return bool(self.heap)

    def park(self, wake: float, cid: int, th: Thread) -> None:
        self._seq += 1
        heapq.heappush(self.heap, (wake, self._seq, cid, th))

    def next_wake(self) -> float:
        return self.heap[0][0]

    def wake_until(self, t: float, cores) -> None:
        """Move every thread whose IO completed by ``t`` back onto its
        core's ready ring (FIFO append, wake-time order)."""
        heap = self.heap
        while heap and heap[0][0] <= t:
            _, _, cid, th = heapq.heappop(heap)
            cores[cid].ready.append(th)

    def earliest_for(self, cid: int) -> float | None:
        """Earliest wake time among this core's parked threads, if any."""
        mine = [e[0] for e in self.heap if e[2] == cid]
        return min(mine) if mine else None
