"""Batched latency sweeps: the paper's measurement protocol as one fast call.

The headline artifact of the paper (Figs. 9-11) is throughput vs. memory
latency with the thread count re-optimized at every latency point.  The
legacy way to produce it was a Python loop calling ``best_over_threads``
per point over a row-oriented tuple trace -- re-paying interpreter overhead
for every cell of the latency x threads grid.

:func:`sweep_latency` runs the whole grid through the compiled fast loop
(:func:`~repro.core.sim.engine_loop.simulate_compiled`) against **one**
shared :class:`~repro.core.trace_ir.CompiledTrace`, optionally fans the
cells out over worker processes (fork start method; the trace is inherited,
never pickled per task), and can memoize finished cells in a small on-disk
cache so repeated benchmark runs are incremental.

Each grid cell is seeded exactly like the legacy protocol
(``replace(cfg, L_mem=L, n_threads=n)`` with the same ``cfg.seed``), so
per-point throughput matches the legacy event loop; see
``tests/test_sweep.py`` for the equivalence guarantees.
"""
from __future__ import annotations

import hashlib
import json
import multiprocessing as mp
import os
import sys
from dataclasses import dataclass, replace
from typing import Callable, Iterable, Sequence

from ..trace_ir import CompiledTrace, Op
from .config import DEFAULT_THREAD_CANDIDATES, SimConfig, SimResult
from .engine_loop import simulate, simulate_compiled

__all__ = ["SweepPoint", "sweep_latency"]


@dataclass
class SweepPoint:
    """Best operating point at one memory latency."""

    L_mem: float | Sequence[tuple[float, float]]
    n_threads: int                 # best thread count at this latency
    result: SimResult              # the winning simulation
    per_thread: dict[int, float]   # throughput of every candidate

    @property
    def throughput(self) -> float:
        return self.result.throughput


def _coerce_trace(source) -> tuple[CompiledTrace | None, Callable | None]:
    """Accept CompiledTrace / TraceResult / list[Op] / legacy callable."""
    if isinstance(source, CompiledTrace):
        return source, None
    trace = getattr(source, "trace", None)   # TraceResult duck-type
    if isinstance(trace, CompiledTrace):
        return trace, None
    if isinstance(source, (list, tuple)):
        if not source:
            raise ValueError("cannot sweep an empty op list")
        if isinstance(source[0], Op):
            return CompiledTrace.from_ops(source), None
    if callable(source):
        return None, source
    raise TypeError(
        "source must be a CompiledTrace, TraceResult, list[Op], or an "
        f"op-source callable, not {type(source).__name__}"
    )


def _run_cell(cfg: SimConfig, trace, src_fn, n_ops: int,
              warmup_ops: int | None,
              collect_latency: bool = False) -> SimResult:
    if trace is not None:
        return simulate_compiled(cfg, trace, n_ops, warmup_ops,
                                 collect_latency)
    return simulate(cfg, src_fn, n_ops, warmup_ops, collect_latency)


# -- worker-process plumbing -------------------------------------------------

_WORKER_STATE: dict = {}


def _worker_init(trace, src_fn, n_ops, warmup_ops, collect_latency):
    _WORKER_STATE["args"] = (trace, src_fn, n_ops, warmup_ops,
                             collect_latency)
    if trace is not None:
        trace.as_lists()   # pay the one-time columnar->list cost per worker


def _worker_run(cfg: SimConfig) -> SimResult:
    trace, src_fn, n_ops, warmup_ops, collect_latency = _WORKER_STATE["args"]
    return _run_cell(cfg, trace, src_fn, n_ops, warmup_ops, collect_latency)


def _pick_context(trace, src_fn):
    """Choose a start method that is both fast and fork-safe.

    * ``fork`` is the fast path: the trace (or a stateless source callable)
      is inherited by the workers, nothing is pickled per task.  It is only
      safe while the parent has no thread pools -- jax famously deadlocks
      forked children -- so it is used only when jax is not loaded.
    * ``forkserver`` sidesteps that (workers fork from a clean server
      process) at the cost of pickling the initargs, so it needs a
      picklable trace; the server preloads this module so workers do not
      re-import numpy/repro per pool.
    * Otherwise: run serial.
    """
    methods = mp.get_all_start_methods()
    if "fork" in methods and "jax" not in sys.modules:
        return mp.get_context("fork")
    if "forkserver" in methods and trace is not None and src_fn is None:
        ctx = mp.get_context("forkserver")
        try:
            ctx.set_forkserver_preload(["repro.core.sim.sweep"])
        except Exception:  # pragma: no cover - preload is best-effort
            pass
        return ctx
    return None


# -- on-disk cell cache ------------------------------------------------------

# op_latencies / load_stalls are deliberately NOT cached (they are large and
# rarely wanted); any call that needs them must bypass the cache entirely --
# otherwise a cache hit would silently return mean_op_latency == 0 where a
# cold run would not (see sweep_latency's ``use_cache`` predicate).
_CACHED_FIELDS = ("ops", "time", "throughput", "mem_stall_total",
                  "mem_accesses")


def _cache_key(cfg: SimConfig, trace_digest: str, n_ops: int,
               warmup_ops) -> str:
    blob = json.dumps(
        [repr(cfg), trace_digest, n_ops, warmup_ops], sort_keys=True
    ).encode()
    return hashlib.sha1(blob).hexdigest()


def _cache_load(path: str) -> SimResult | None:
    try:
        with open(path) as f:
            d = json.load(f)
        return SimResult(**{k: d[k] for k in _CACHED_FIELDS})
    except (OSError, ValueError, KeyError, TypeError):
        return None


def _cache_store(path: str, r: SimResult) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump({k: getattr(r, k) for k in _CACHED_FIELDS}, f)
        os.replace(tmp, path)
    except OSError:
        pass


def _make_point(L, candidates: list[int],
                evals: dict[int, SimResult]) -> SweepPoint:
    """Reduce evaluated cells of one latency point (lowest index wins ties,
    matching the full grid's first-candidate-wins rule)."""
    best_j = min(evals, key=lambda j: (-evals[j].throughput, j))
    return SweepPoint(
        L_mem=L,
        n_threads=candidates[best_j],
        result=evals[best_j],
        per_thread={candidates[j]: evals[j].throughput
                    for j in sorted(evals)},
    )


def sweep_latency(
    cfg: SimConfig,
    source,
    latencies: Iterable,
    thread_candidates: Iterable[int] = DEFAULT_THREAD_CANDIDATES,
    n_ops: int = 5000,
    warmup_ops: int | None = None,
    processes: int | None = None,
    cache_dir: str | os.PathLike | None = None,
    collect_latency: bool = False,
    adaptive: bool = False,
) -> list[SweepPoint]:
    """Throughput vs. memory latency with per-point thread optimization.

    Parameters
    ----------
    cfg
        Base configuration; ``L_mem`` and ``n_threads`` are overridden per
        grid cell (each cell keeps ``cfg.seed``, like the legacy protocol).
    source
        A :class:`CompiledTrace`, a ``TraceResult``, a legacy ``list[Op]``
        (compiled on the fly), or an op-source callable (runs through the
        generic loop; still parallelized).  Results are deterministic in
        both modes: parallel runs give every cell a pristine fork of the
        callable's state as of this call, serial runs thread it through
        the cells in fixed grid order.  Stateless sources (microbenchmark,
        compiled traces) are identical either way; for stateful legacy
        ``trace_source`` closures prefer passing the compiled trace.
    latencies
        Memory-latency points -- scalars in seconds, or mixture specs
        ``[(lat, prob), ...]``.
    thread_candidates
        Thread counts tried at every latency; earlier candidates win ties.
    processes
        Worker processes for the grid.  Default: up to the CPU count
        (capped by the grid size); ``0``/``1`` forces serial.  The start
        method is chosen automatically (``fork`` in jax-free processes,
        a preloaded ``forkserver`` otherwise; serial when neither is
        available or the source cannot cross a process boundary).
    cache_dir
        If set, finished cells are memoized as small JSON files keyed by
        (config, trace digest, n_ops); repeated sweeps only simulate new
        cells.  Histogram/latency collection is never cached: a
        ``collect_latency=True`` (or ``cfg.collect_load_hist``) call
        bypasses the cache entirely -- loads *and* stores -- because the
        cached cells drop ``op_latencies``/``load_stalls`` and a cache hit
        would silently return ``mean_op_latency == 0``.
    collect_latency
        Record per-op latencies in every cell (``SimResult.op_latencies``),
        e.g. for Fig. 17-style latency curves.  Disables the cell cache.
    adaptive
        Warm-started thread search: the first latency point evaluates the
        full candidate list; every later point starts from the previous
        point's winner and only expands to neighboring candidates while the
        running best sits on the edge of the evaluated window.  Picks the
        same winner as the full grid whenever throughput vs. thread count
        is unimodal over the candidate list (the paper-sweep shape; see
        ``tests/test_sweep.py``), while evaluating far fewer cells.  Cells
        run serially (later points depend on earlier winners), so
        ``processes`` is ignored; ``per_thread`` only contains the
        candidates actually evaluated.

    Returns one :class:`SweepPoint` per latency, in input order.
    """
    latencies = list(latencies)
    candidates = list(thread_candidates)
    if not latencies or not candidates:
        return []
    trace, src_fn = _coerce_trace(source)

    use_cache = (cache_dir is not None and trace is not None
                 and not cfg.collect_load_hist and not collect_latency)
    digest = ""
    if use_cache:
        os.makedirs(cache_dir, exist_ok=True)
        digest = hashlib.sha1(
            trace.kinds.tobytes() + trace.durs.tobytes() +
            trace.bounds.tobytes()
        ).hexdigest()

    def cell_path(c: SimConfig) -> str:
        return os.path.join(
            str(cache_dir), _cache_key(c, digest, n_ops, warmup_ops) + ".json")

    if adaptive:
        return _sweep_adaptive(cfg, trace, src_fn, latencies, candidates,
                               n_ops, warmup_ops, collect_latency,
                               use_cache, cell_path)

    grid_cfgs = [
        replace(cfg, L_mem=L, n_threads=n)
        for L in latencies
        for n in candidates
    ]

    # -- cache probe ---------------------------------------------------------
    paths: list[str | None] = [None] * len(grid_cfgs)
    results: list[SimResult | None] = [None] * len(grid_cfgs)
    if use_cache:
        for i, c in enumerate(grid_cfgs):
            paths[i] = cell_path(c)
            results[i] = _cache_load(paths[i])

    todo = [i for i, r in enumerate(results) if r is None]

    # -- run missing cells ---------------------------------------------------
    if processes is None:
        processes = min(os.cpu_count() or 1, len(todo) or 1)
    ctx = _pick_context(trace, src_fn)
    if todo:
        if processes > 1 and ctx is not None and len(todo) > 1:
            # Callable sources may carry mutable state (trace_source
            # closures); giving every cell a pristine fork of the parent
            # state (maxtasksperchild=1) keeps parallel results
            # deterministic and identical to processes=1.
            with ctx.Pool(
                min(processes, len(todo)),
                initializer=_worker_init,
                initargs=(trace, src_fn, n_ops, warmup_ops, collect_latency),
                maxtasksperchild=1 if src_fn is not None else None,
            ) as pool:
                for i, r in zip(todo,
                                pool.map(_worker_run,
                                         [grid_cfgs[i] for i in todo],
                                         chunksize=1)):
                    results[i] = r
        else:
            for i in todo:
                results[i] = _run_cell(grid_cfgs[i], trace, src_fn, n_ops,
                                       warmup_ops, collect_latency)
        if use_cache:
            for i in todo:
                _cache_store(paths[i], results[i])

    # -- reduce: best thread count per latency (first candidate wins ties) ---
    k = len(candidates)
    return [
        _make_point(L, candidates,
                    dict(enumerate(results[li * k:(li + 1) * k])))
        for li, L in enumerate(latencies)
    ]


def _sweep_adaptive(cfg, trace, src_fn, latencies, candidates, n_ops,
                    warmup_ops, collect_latency, use_cache,
                    cell_path) -> list[SweepPoint]:
    """Warm-started hill search over the candidate list, one point at a time.

    Invariant per latency point: the evaluated window ``[lo, hi]`` always
    contains the previous point's winner, and is expanded while the current
    best sits on a window edge -- so on a unimodal throughput-vs-threads
    curve the search provably reaches the global grid winner.
    """

    def eval_cell(c: SimConfig) -> SimResult:
        if use_cache:
            path = cell_path(c)
            r = _cache_load(path)
            if r is not None:
                return r
        r = _run_cell(c, trace, src_fn, n_ops, warmup_ops, collect_latency)
        if use_cache:
            _cache_store(path, r)
        return r

    def argmax(evals: dict[int, SimResult]) -> int:
        return min(evals, key=lambda j: (-evals[j].throughput, j))

    k = len(candidates)
    out: list[SweepPoint] = []
    prev: int | None = None
    for L in latencies:
        evals: dict[int, SimResult] = {}
        if prev is None:                       # first point: full grid
            for j in range(k):
                evals[j] = eval_cell(replace(cfg, L_mem=L,
                                             n_threads=candidates[j]))
        else:
            lo, hi = max(prev - 1, 0), min(prev + 1, k - 1)
            for j in range(lo, hi + 1):
                evals[j] = eval_cell(replace(cfg, L_mem=L,
                                             n_threads=candidates[j]))
            best = argmax(evals)
            while best == lo and lo > 0:
                lo -= 1
                evals[lo] = eval_cell(replace(cfg, L_mem=L,
                                              n_threads=candidates[lo]))
                best = argmax(evals)
            while best == hi and hi < k - 1:
                hi += 1
                evals[hi] = eval_cell(replace(cfg, L_mem=L,
                                              n_threads=candidates[hi]))
                best = argmax(evals)
        prev = argmax(evals)
        out.append(_make_point(L, candidates, evals))
    return out
