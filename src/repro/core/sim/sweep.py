"""Batched latency sweeps: the paper's measurement protocol as one fast call.

The headline artifact of the paper (Figs. 9-11) is throughput vs. memory
latency with the thread count re-optimized at every latency point.  The
legacy way to produce it was a Python loop calling ``best_over_threads``
per point over a row-oriented tuple trace -- re-paying interpreter overhead
for every cell of the latency x threads grid.

:func:`sweep_latency` runs the whole grid through the compiled fast loop
(:func:`~repro.core.sim.engine_loop.simulate_compiled`) against **one**
shared :class:`~repro.core.trace_ir.CompiledTrace`, optionally fans the
cells out over worker processes (fork start method; the trace is inherited,
never pickled per task), and can memoize finished cells in a small on-disk
cache so repeated benchmark runs are incremental.

Each grid cell is seeded exactly like the legacy protocol
(``replace(cfg, L_mem=L, n_threads=n)`` with the same ``cfg.seed``), so
per-point throughput matches the legacy event loop; see
``tests/test_sweep.py`` for the equivalence guarantees.
"""
from __future__ import annotations

import hashlib
import json
import multiprocessing as mp
import numbers
import os
import re
import sys
import time
from dataclasses import dataclass, replace
from typing import Callable, Iterable, Sequence

from ..trace_ir import CompiledTrace, Op
from .arrivals import ArrivalSpec, LatencySummary, generate_arrivals
from .config import DEFAULT_THREAD_CANDIDATES, SimConfig, SimResult
from .engine_loop import simulate, simulate_compiled

__all__ = ["SweepPoint", "sweep_latency", "clear_sweep_cache",
           "prune_sweep_cache", "BACKENDS"]

#: Valid ``backend=`` values: the interpreter loops (generic/compiled), or
#: the vectorized jax grid (:mod:`.replay_jax`).
BACKENDS = ("loop", "jax")


@dataclass
class SweepPoint:
    """Best operating point at one memory latency."""

    L_mem: float | Sequence[tuple[float, float]]
    n_threads: int                 # best thread count at this latency
    result: SimResult              # the winning simulation
    per_thread: dict[int, float]   # throughput of every candidate

    @property
    def throughput(self) -> float:
        return self.result.throughput


def _coerce_trace(source) -> tuple[CompiledTrace | None, Callable | None]:
    """Accept CompiledTrace / TraceResult / list[Op] / legacy callable."""
    if isinstance(source, CompiledTrace):
        return source, None
    trace = getattr(source, "trace", None)   # TraceResult duck-type
    if isinstance(trace, CompiledTrace):
        return trace, None
    if isinstance(source, (list, tuple)):
        if not source:
            raise ValueError("cannot sweep an empty op list")
        if isinstance(source[0], Op):
            return CompiledTrace.from_ops(source), None
    if callable(source):
        return None, source
    raise TypeError(
        "source must be a CompiledTrace, TraceResult, list[Op], or an "
        f"op-source callable, not {type(source).__name__}"
    )


def _run_cell(cfg: SimConfig, trace, src_fn, n_ops: int,
              warmup_ops: int | None,
              collect_latency: bool = False,
              arrivals=None, collect_percentiles: bool = False,
              deadline: float = 0.0) -> SimResult:
    kw = dict(arrivals=arrivals, collect_percentiles=collect_percentiles,
              deadline=deadline)
    if trace is not None:
        return simulate_compiled(cfg, trace, n_ops, warmup_ops,
                                 collect_latency, **kw)
    return simulate(cfg, src_fn, n_ops, warmup_ops, collect_latency, **kw)


# -- worker-process plumbing -------------------------------------------------

_WORKER_STATE: dict = {}


def _worker_init(trace, src_fn, n_ops, warmup_ops, collect_latency,
                 arrivals=None, collect_percentiles=False, deadline=0.0):
    _WORKER_STATE["args"] = (trace, src_fn, n_ops, warmup_ops,
                             collect_latency, arrivals,
                             collect_percentiles, deadline)
    if trace is not None:
        trace.as_lists()   # pay the one-time columnar->list cost per worker


def _worker_run(cfg: SimConfig) -> SimResult:
    return _run_cell(cfg, *_WORKER_STATE["args"])


def _pick_context(trace, src_fn):
    """Choose a start method that is both fast and fork-safe.

    * ``fork`` is the fast path: the trace (or a stateless source callable)
      is inherited by the workers, nothing is pickled per task.  It is only
      safe while the parent has no thread pools -- jax famously deadlocks
      forked children -- so it is used only when jax is not loaded.
    * ``forkserver`` sidesteps that (workers fork from a clean server
      process) at the cost of pickling the initargs, so it needs a
      picklable trace; the server preloads this module so workers do not
      re-import numpy/repro per pool.
    * Otherwise: run serial.
    """
    methods = mp.get_all_start_methods()
    if "fork" in methods and "jax" not in sys.modules:
        return mp.get_context("fork")
    if "forkserver" in methods and trace is not None and src_fn is None:
        ctx = mp.get_context("forkserver")
        try:
            ctx.set_forkserver_preload(["repro.core.sim.sweep"])
        except Exception:  # pragma: no cover - preload is best-effort
            pass
        return ctx
    return None


def _run_jax_cells(cfg: SimConfig, trace: CompiledTrace, latencies,
                   candidates, n_ops, warmup_ops, results, todo,
                   jax_opts=None, arrivals=None,
                   collect_percentiles=False, deadline=0.0) -> None:
    """Fill ``results[i]`` for every grid index in ``todo`` via the jax
    backend.  All missing scalar-latency cells run as one vectorized grid
    call (:func:`repro.core.sim.replay_jax.sweep_grid`); mixture-latency
    cells (which the jax backend does not model) run through the compiled
    loop per cell.  ``jax_opts`` are extra ``sweep_grid`` tuning kwargs
    (``use_pallas``/``unroll``/``substeps``) -- they select execution
    strategy, never values."""
    from . import replay_jax   # deferred: jax is a heavyweight import

    k = len(candidates)
    # numbers.Real admits numpy scalars too (np.float32 is not a float
    # subclass), keeping this classification consistent with sweep_grid's
    need_lis = sorted({
        i // k for i in todo
        if isinstance(latencies[i // k], numbers.Real)
    })
    grid = None
    if need_lis:
        grid = replay_jax.sweep_grid(
            cfg, trace, [latencies[li] for li in need_lis], candidates,
            n_ops, warmup_ops, arrivals=arrivals,
            collect_percentiles=collect_percentiles, deadline=deadline,
            **(jax_opts or {}))
    row_of = {li: r for r, li in enumerate(need_lis)}
    for i in todo:
        li, ci = divmod(i, k)
        if li in row_of:
            results[i] = grid.result(row_of[li], ci)
        else:
            results[i] = simulate_compiled(
                replace(cfg, L_mem=latencies[li], n_threads=candidates[ci]),
                trace, n_ops, warmup_ops, arrivals=arrivals,
                collect_percentiles=collect_percentiles, deadline=deadline)


# -- on-disk cell cache ------------------------------------------------------

# op_latencies / load_stalls are deliberately NOT cached (they are large and
# rarely wanted); any call that needs them must bypass the cache entirely --
# otherwise a cache hit would silently return mean_op_latency == 0 where a
# cold run would not (see sweep_latency's ``use_cache`` predicate).  The
# percentile *summary* (a handful of floats) IS cached, so
# ``collect_percentiles`` sweeps stay incremental: a cell cached without a
# summary simply misses when a summary is requested (``need_summary``) and
# is recomputed and overwritten in place.
_CACHED_FIELDS = ("ops", "time", "throughput", "mem_stall_total",
                  "mem_accesses", "missed_ops")

# Bumped whenever the cell-file layout changes (v2: missed_ops +
# latency_summary).  Folded into every key, so a schema change simply
# orphans the old cells -- they age out via prune_sweep_cache instead of
# being misread (eviction-safe, no in-place migration).
_CACHE_SCHEMA = 2

# Source files whose semantics define what a cached cell means.  Their
# digest is folded into every cell key, so cells from an older revision of
# the simulator can never be served as current results (previously stale
# cells silently survived code changes).
_SALT_FILES = ("arrivals.py", "config.py", "devices.py", "engine_loop.py",
               "scheduler.py", "sweep.py", "replay_jax.py")
_CODE_SALT: str | None = None


def _code_salt() -> str:
    """Digest of the simulation-defining sources (cached per process)."""
    global _CODE_SALT
    if _CODE_SALT is None:
        here = os.path.dirname(os.path.abspath(__file__))
        core = os.path.dirname(here)
        paths = [os.path.join(here, name) for name in _SALT_FILES]
        paths.append(os.path.join(core, "trace_ir.py"))
        # the jax backend's scheduler/token-clock arithmetic lives in the
        # kernels package; every kernel source defines cached jax cells
        # too, so hash the whole directory (sorted: order-stable digest)
        kdir = os.path.join(os.path.dirname(core), "kernels")
        paths.extend(sorted(
            os.path.join(kdir, name) for name in os.listdir(kdir)
            if name.endswith(".py")))
        h = hashlib.sha1()
        for path in paths:
            with open(path, "rb") as fh:
                h.update(fh.read())
        _CODE_SALT = h.hexdigest()[:16]
    return _CODE_SALT


def _cache_key(cfg: SimConfig, trace_digest: str, n_ops: int,
               warmup_ops, backend: str, arrival_key: str | None = None) -> str:
    # The backend is part of the key: loop and jax cells agree only within
    # tolerance, so a cached cell must never answer for the other backend.
    # The arrival spec is part of the key too (it changes every cell
    # value); the shared arrival array itself is NOT -- each cell consumes
    # a deterministic prefix that depends only on the spec and the cell's
    # own (n_threads, warmup, n_ops), so cells stay pure across sweeps
    # with different candidate lists.
    blob = json.dumps(
        [_CACHE_SCHEMA, repr(cfg), trace_digest, n_ops, warmup_ops, backend,
         arrival_key, _code_salt()],
        sort_keys=True,
    ).encode()
    return hashlib.sha1(blob).hexdigest()


# Cell files are "<sha1 hex>.json" (plus "<...>.json.tmp.<pid>" while a
# store is in flight); clear_sweep_cache must only ever match that shape --
# the cache dir may be a working directory holding scenario specs or
# artifact JSON that are NOT ours to delete.
_CELL_FILE = re.compile(r"^[0-9a-f]{40}\.json(\.tmp\.\d+)?$")


def clear_sweep_cache(cache_dir: str | os.PathLike) -> int:
    """Delete every memoized sweep cell in ``cache_dir``; returns the number
    of cells removed (in-flight temp files are removed but not counted).
    Only cell-shaped file names are touched; anything else in the
    directory is left alone.  Used by ``benchmarks.run
    --sweep-cache-clear``."""
    removed = 0
    try:
        names = os.listdir(cache_dir)
    except OSError:
        return 0
    for name in names:
        if _CELL_FILE.match(name):
            try:
                os.remove(os.path.join(str(cache_dir), name))
            except OSError:
                continue
            if name.endswith(".json"):
                removed += 1
    return removed


def prune_sweep_cache(
    cache_dir: str | os.PathLike,
    max_bytes: int | None = None,
    max_age_days: float | None = None,
) -> int:
    """Evict memoized sweep cells, least-recently-used first.

    ``max_age_days`` removes every cell whose mtime is older than that
    many days; ``max_bytes`` then removes the oldest remaining cells until
    the directory's cell bytes fit the budget.  ``_cache_load`` touches a
    cell's mtime on every hit, so mtime order is LRU order.  Stale
    in-flight temp files (``*.json.tmp.<pid>``) older than a day are
    swept unconditionally.  Only cell-shaped names are touched (see
    :func:`clear_sweep_cache`); returns the number of cells removed.
    Used by ``benchmarks.run --sweep-cache-prune``."""
    if max_bytes is not None and max_bytes < 0:
        raise ValueError(f"max_bytes must be >= 0, got {max_bytes}")
    if max_age_days is not None and max_age_days < 0:
        raise ValueError(f"max_age_days must be >= 0, got {max_age_days}")
    try:
        names = os.listdir(cache_dir)
    except OSError:
        return 0
    now = time.time()
    cells: list[tuple[float, int, str]] = []   # (mtime, size, path)
    for name in names:
        if not _CELL_FILE.match(name):
            continue
        path = os.path.join(str(cache_dir), name)
        try:
            st = os.stat(path)
        except OSError:
            continue
        if not name.endswith(".json"):         # orphaned temp file
            if now - st.st_mtime > 86400.0:
                try:
                    os.remove(path)
                except OSError:
                    pass
            continue
        cells.append((st.st_mtime, st.st_size, path))
    cells.sort()                               # oldest (least recent) first

    removed = 0

    def evict(entry: tuple[float, int, str]) -> bool:
        nonlocal removed
        try:
            os.remove(entry[2])
        except OSError:
            return False
        removed += 1
        return True

    if max_age_days is not None:
        cutoff = now - max_age_days * 86400.0
        keep = []
        for entry in cells:
            if entry[0] < cutoff:
                evict(entry)
            else:
                keep.append(entry)
        cells = keep
    if max_bytes is not None:
        total = sum(size for _, size, _ in cells)
        for entry in cells:
            if total <= max_bytes:
                break
            if evict(entry):
                total -= entry[1]
    return removed


def _cache_load(path: str, need_summary: bool = False) -> SimResult | None:
    try:
        with open(path) as f:
            d = json.load(f)
        summary = d.get("latency_summary")
        if need_summary and summary is None:
            # Cached before percentiles were requested: a miss, not an
            # error -- the recompute overwrites the cell with its summary.
            return None
        r = SimResult(
            **{k: d[k] for k in _CACHED_FIELDS},
            latency_summary=(LatencySummary.from_dict(summary)
                             if summary is not None else None))
    except (OSError, ValueError, KeyError, TypeError, AttributeError):
        # corrupt/foreign cells (non-JSON, wrong shape) are misses
        return None
    try:
        os.utime(path)   # mtime is the LRU clock for prune_sweep_cache
    except OSError:
        pass
    return r


def _cache_store(path: str, r: SimResult) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    doc = {k: getattr(r, k) for k in _CACHED_FIELDS}
    doc["latency_summary"] = (r.latency_summary.to_dict()
                              if r.latency_summary is not None else None)
    try:
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, path)
    except OSError:
        pass


def _make_point(L, candidates: list[int],
                evals: dict[int, SimResult]) -> SweepPoint:
    """Reduce evaluated cells of one latency point (lowest index wins ties,
    matching the full grid's first-candidate-wins rule)."""
    best_j = min(evals, key=lambda j: (-evals[j].throughput, j))
    return SweepPoint(
        L_mem=L,
        n_threads=candidates[best_j],
        result=evals[best_j],
        per_thread={candidates[j]: evals[j].throughput
                    for j in sorted(evals)},
    )


def sweep_latency(
    cfg: SimConfig,
    source,
    latencies: Iterable,
    thread_candidates: Iterable[int] = DEFAULT_THREAD_CANDIDATES,
    n_ops: int = 5000,
    warmup_ops: int | None = None,
    processes: int | None = None,
    cache_dir: str | os.PathLike | None = None,
    collect_latency: bool = False,
    adaptive: bool = False,
    backend: str = "loop",
    use_pallas: bool = False,
    unroll: int | None = None,
    substeps: int | None = None,
    host_devices: int | None = None,
    arrival: ArrivalSpec | dict | None = None,
    collect_percentiles: bool = False,
) -> list[SweepPoint]:
    """Throughput vs. memory latency with per-point thread optimization.

    Parameters
    ----------
    cfg
        Base configuration; ``L_mem`` and ``n_threads`` are overridden per
        grid cell (each cell keeps ``cfg.seed``, like the legacy protocol).
    source
        A :class:`CompiledTrace`, a ``TraceResult``, a legacy ``list[Op]``
        (compiled on the fly), or an op-source callable (runs through the
        generic loop; still parallelized).  Results are deterministic in
        both modes: parallel runs give every cell a pristine fork of the
        callable's state as of this call, serial runs thread it through
        the cells in fixed grid order.  Stateless sources (microbenchmark,
        compiled traces) are identical either way; for stateful legacy
        ``trace_source`` closures prefer passing the compiled trace.
    latencies
        Memory-latency points -- scalars in seconds, or mixture specs
        ``[(lat, prob), ...]``.
    thread_candidates
        Thread counts tried at every latency; earlier candidates win ties.
    processes
        Worker processes for the grid.  Default: up to the CPU count
        (capped by the grid size); ``0``/``1`` forces serial.  The start
        method is chosen automatically (``fork`` in jax-free processes,
        a preloaded ``forkserver`` otherwise; serial when neither is
        available or the source cannot cross a process boundary).
    cache_dir
        If set, finished cells are memoized as small JSON files keyed by
        (config, trace digest, n_ops, arrival spec); repeated sweeps only
        simulate new cells.  Bulk per-op collection is never cached: a
        ``collect_latency=True`` (or ``cfg.collect_load_hist``) call
        bypasses the cache entirely -- loads *and* stores -- because the
        cached cells drop ``op_latencies``/``load_stalls`` and a cache hit
        would silently return ``mean_op_latency == 0``.  The compact
        percentile summary IS cached: ``collect_percentiles`` sweeps hit
        the cache, and a cell cached without its summary is transparently
        recomputed (and upgraded) the first time percentiles are asked of
        it.
    collect_latency
        Record per-op latencies in every cell (``SimResult.op_latencies``),
        e.g. for Fig. 17-style latency curves.  Disables the cell cache.
    adaptive
        Warm-started thread search: the first latency point evaluates the
        full candidate list; every later point starts from the previous
        point's winner and only expands to neighboring candidates while the
        running best sits on the edge of the evaluated window.  Picks the
        same winner as the full grid whenever throughput vs. thread count
        is unimodal over the candidate list (the paper-sweep shape; see
        ``tests/test_sweep.py``), while evaluating far fewer cells.  Cells
        run serially (later points depend on earlier winners), so
        ``processes`` is ignored; ``per_thread`` only contains the
        candidates actually evaluated.
    backend
        ``"loop"`` (default) runs every cell through the interpreter loops
        (compiled fast path, generic fallback) as above.  ``"jax"`` lowers
        the compiled trace to device arrays once and replays the entire
        scalar-latency grid as one jitted scan
        (:func:`repro.core.sim.replay_jax.sweep_grid`): per-cell
        throughput agrees with the loops within sampling tolerance rather
        than bit-identically (see ``docs/SIMULATION.md``), mixture-latency
        points still run through the loop per cell, and ``processes`` is
        ignored for the jax cells.  Requires a trace source (not a
        callable) and no latency/histogram collection; incompatible with
        ``adaptive=True``.  Cached cells are keyed per backend, so the two
        never answer for each other.
    use_pallas, unroll, substeps, host_devices
        Jax-backend execution tuning, forwarded to
        :func:`~repro.core.sim.replay_jax.sweep_grid`: ``use_pallas``
        routes the scan through the fused whole-step kernel (``substeps``
        inner steps per kernel invocation), ``unroll`` amortizes dispatch
        on the jnp scan path, ``host_devices`` shard_maps the cell axis
        over that many host CPU devices (requires the process to have been
        started with ``--xla_force_host_platform_device_count``).  ``None``
        keeps ``sweep_grid``'s default.  Strategy knobs only -- cell
        values (and hence cache keys) do not depend on them; ignored by
        ``backend="loop"``.
    arrival
        An :class:`~repro.core.sim.arrivals.ArrivalSpec` (or its dict
        form) switching every cell to the open-loop driver: one shared
        deterministic timestamp stream (seconds; sized to the widest
        cell's demand) drives all cells and backends, ops wait for their
        arrival, and the spec's ``deadline`` classifies late sojourns as
        missed.  ``None`` (default) keeps the closed-loop driver.
    collect_percentiles
        Summarize each cell's measured sojourn latencies into
        ``SimResult.latency_summary`` (p50/p90/p99/max + missed count):
        exact nearest-rank on the loop backends, log-histogram on the jax
        backend (within ``arrivals.HIST_REL_ERROR``).  Cache-friendly,
        unlike ``collect_latency``.

    Returns one :class:`SweepPoint` per latency, in input order.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    latencies = list(latencies)
    candidates = list(thread_candidates)
    if not latencies or not candidates:
        return []
    trace, src_fn = _coerce_trace(source)

    if backend == "jax":
        if adaptive:
            raise ValueError(
                "backend='jax' evaluates the whole grid in one call; the "
                "warm-started adaptive search is a loop-backend strategy")
        if collect_latency or cfg.collect_load_hist:
            raise ValueError(
                "per-op latency / load-histogram collection is only "
                "available from backend='loop'")
        if trace is None:
            raise ValueError(
                "backend='jax' replays compiled traces; pass a "
                "CompiledTrace / TraceResult / list[Op], not a callable")

    arrival_spec: ArrivalSpec | None = None
    if arrival is not None:
        arrival_spec = (arrival if isinstance(arrival, ArrivalSpec)
                        else ArrivalSpec.from_dict(arrival))
    deadline = arrival_spec.deadline if arrival_spec is not None else 0.0
    arrivals_arr = None
    if arrival_spec is not None:
        # One shared stream sized to the widest cell's demand
        # (init threads + warmup + measured ops); every cell consumes its
        # own prefix, so the stream length never changes cell values.
        need = max(
            cfg.n_cores * c
            + (warmup_ops if warmup_ops is not None
               else 2 * c * cfg.n_cores)
            + n_ops
            for c in candidates) + 1
        arrivals_arr = generate_arrivals(arrival_spec, need)
    arrival_key = arrival_spec.key() if arrival_spec is not None else None

    use_cache = (cache_dir is not None and trace is not None
                 and not cfg.collect_load_hist and not collect_latency)
    digest = ""
    if use_cache:
        os.makedirs(cache_dir, exist_ok=True)
        digest = hashlib.sha1(
            trace.kinds.tobytes() + trace.durs.tobytes() +
            trace.bounds.tobytes()
        ).hexdigest()

    def cell_path(c: SimConfig) -> str:
        return os.path.join(
            str(cache_dir),
            _cache_key(c, digest, n_ops, warmup_ops, backend,
                       arrival_key) + ".json")

    if adaptive:
        return _sweep_adaptive(cfg, trace, src_fn, latencies, candidates,
                               n_ops, warmup_ops, collect_latency,
                               use_cache, cell_path, arrivals_arr,
                               collect_percentiles, deadline)

    grid_cfgs = [
        replace(cfg, L_mem=L, n_threads=n)
        for L in latencies
        for n in candidates
    ]

    # -- cache probe ---------------------------------------------------------
    paths: list[str | None] = [None] * len(grid_cfgs)
    results: list[SimResult | None] = [None] * len(grid_cfgs)
    if use_cache:
        for i, c in enumerate(grid_cfgs):
            paths[i] = cell_path(c)
            results[i] = _cache_load(paths[i],
                                     need_summary=collect_percentiles)

    todo = [i for i, r in enumerate(results) if r is None]

    # -- run missing cells ---------------------------------------------------
    if backend == "jax" and todo:
        jax_opts = {"use_pallas": use_pallas}
        if unroll is not None:
            jax_opts["unroll"] = unroll
        if substeps is not None:
            jax_opts["substeps"] = substeps
        if host_devices is not None:
            jax_opts["host_devices"] = host_devices
        _run_jax_cells(cfg, trace, latencies, candidates, n_ops,
                       warmup_ops, results, todo, jax_opts,
                       arrivals_arr, collect_percentiles, deadline)
        if use_cache:
            for i in todo:
                _cache_store(paths[i], results[i])
        todo = []
    if processes is None:
        processes = min(os.cpu_count() or 1, len(todo) or 1)
    ctx = _pick_context(trace, src_fn)
    if todo:
        if processes > 1 and ctx is not None and len(todo) > 1:
            # Callable sources may carry mutable state (trace_source
            # closures); giving every cell a pristine fork of the parent
            # state (maxtasksperchild=1) keeps parallel results
            # deterministic and identical to processes=1.
            with ctx.Pool(
                min(processes, len(todo)),
                initializer=_worker_init,
                initargs=(trace, src_fn, n_ops, warmup_ops, collect_latency,
                          arrivals_arr, collect_percentiles, deadline),
                maxtasksperchild=1 if src_fn is not None else None,
            ) as pool:
                for i, r in zip(todo,
                                pool.map(_worker_run,
                                         [grid_cfgs[i] for i in todo],
                                         chunksize=1)):
                    results[i] = r
        else:
            for i in todo:
                results[i] = _run_cell(grid_cfgs[i], trace, src_fn, n_ops,
                                       warmup_ops, collect_latency,
                                       arrivals_arr, collect_percentiles,
                                       deadline)
        if use_cache:
            for i in todo:
                _cache_store(paths[i], results[i])

    # -- reduce: best thread count per latency (first candidate wins ties) ---
    k = len(candidates)
    return [
        _make_point(L, candidates,
                    dict(enumerate(results[li * k:(li + 1) * k])))
        for li, L in enumerate(latencies)
    ]


def _sweep_adaptive(cfg, trace, src_fn, latencies, candidates, n_ops,
                    warmup_ops, collect_latency, use_cache,
                    cell_path, arrivals=None, collect_percentiles=False,
                    deadline=0.0) -> list[SweepPoint]:
    """Warm-started hill search over the candidate list, one point at a time.

    Invariant per latency point: the evaluated window ``[lo, hi]`` always
    contains the previous point's winner, and is expanded while the current
    best sits on a window edge -- so on a unimodal throughput-vs-threads
    curve the search provably reaches the global grid winner.
    """

    def eval_cell(c: SimConfig) -> SimResult:
        if use_cache:
            path = cell_path(c)
            r = _cache_load(path, need_summary=collect_percentiles)
            if r is not None:
                return r
        r = _run_cell(c, trace, src_fn, n_ops, warmup_ops, collect_latency,
                      arrivals, collect_percentiles, deadline)
        if use_cache:
            _cache_store(path, r)
        return r

    def argmax(evals: dict[int, SimResult]) -> int:
        return min(evals, key=lambda j: (-evals[j].throughput, j))

    k = len(candidates)
    out: list[SweepPoint] = []
    prev: int | None = None
    for L in latencies:
        evals: dict[int, SimResult] = {}
        if prev is None:                       # first point: full grid
            for j in range(k):
                evals[j] = eval_cell(replace(cfg, L_mem=L,
                                             n_threads=candidates[j]))
        else:
            lo, hi = max(prev - 1, 0), min(prev + 1, k - 1)
            for j in range(lo, hi + 1):
                evals[j] = eval_cell(replace(cfg, L_mem=L,
                                             n_threads=candidates[j]))
            best = argmax(evals)
            while best == lo and lo > 0:
                lo -= 1
                evals[lo] = eval_cell(replace(cfg, L_mem=L,
                                              n_threads=candidates[lo]))
                best = argmax(evals)
            while best == hi and hi < k - 1:
                hi += 1
                evals[hi] = eval_cell(replace(cfg, L_mem=L,
                                              n_threads=candidates[hi]))
                best = argmax(evals)
        prev = argmax(evals)
        out.append(_make_point(L, candidates, evals))
    return out
