"""The discrete-event loops executing operations on the simulated machine.

Two implementations of the same execution model:

  * :func:`simulate` -- the generic loop.  Takes any ``op_source`` callable,
    supports multi-core, and composes the :mod:`.scheduler` and
    :mod:`.devices` layers.  This is the reference semantics.
  * :func:`simulate_compiled` -- the fast path.  Takes a columnar
    :class:`~repro.core.trace_ir.CompiledTrace`, specializes the single-core
    case into one tight loop over flat Python lists (no per-op tuple churn,
    no core heap, inlined scalar latency sampling), and reproduces the
    generic loop's RNG draw order exactly, so its results are bit-identical
    to ``simulate(cfg, trace_source(trace.to_ops()), ...)`` while running
    several times faster.  Multi-core configs run through a compiled
    multi-core specialization (flat per-core rings/prefetch heaps, same
    core-heap event order and RNG draw order as the generic loop, so still
    bit-identical) instead of falling back to the interpreter.

Everything is virtual-time; wall-clock speed is irrelevant to fidelity.
"""
from __future__ import annotations

import heapq
import random
from collections import deque
from dataclasses import replace
from typing import Callable, Iterable, Sequence

from ..trace_ir import CPU, MEM, POSTIO, PREIO, CompiledTrace, Op
from .arrivals import summarize_exact
from .config import DEFAULT_THREAD_CANDIDATES, SimConfig, SimResult
from .devices import SSDClocks, sample_lmem
from .scheduler import Core, ParkedHeap, Thread

__all__ = [
    "simulate",
    "simulate_compiled",
    "microbenchmark_source",
    "trace_source",
    "best_over_threads",
]


def microbenchmark_source(
    M: int,
    T_mem: float,
    T_io_pre: float,
    T_io_post: float,
    n_io: int = 1,
) -> Callable[[random.Random], Op]:
    """The Sec. 4.1 microbenchmark: M pointer-chase accesses then one IO."""
    per_io = [(MEM, T_mem)] * (M // max(n_io, 1))
    sub: list[tuple[int, float]] = []
    if n_io == 0:
        sub = [(MEM, T_mem)] * M
    else:
        for _ in range(n_io):
            sub += per_io + [(PREIO, T_io_pre), (POSTIO, T_io_post)]
    op = Op(tuple(sub))
    return lambda rng: op


def trace_source(ops: Sequence[Op]) -> Callable[[random.Random], Op]:
    """Replay measured traversal traces (from the KV engines), cyclically
    but starting each thread at a random offset so traces interleave."""
    n = len(ops)

    def src(rng: random.Random, _state={}) -> Op:
        i = _state.setdefault("i", rng.randrange(n))
        _state["i"] = (i + 1) % n
        return ops[i]

    return src


def simulate(
    cfg: SimConfig,
    op_source: Callable[[random.Random], Op],
    n_ops: int,
    warmup_ops: int | None = None,
    collect_latency: bool = False,
    *,
    arrivals: Sequence[float] | None = None,
    collect_percentiles: bool = False,
    deadline: float = 0.0,
) -> SimResult:
    """Run the event simulation until ``n_ops`` operations complete.

    ``warmup_ops`` (default: 2 ops per thread) are excluded from throughput
    so the pipeline fill does not bias short runs.

    ``arrivals`` switches the driver open loop: a monotone timestamp
    sequence (seconds; see :func:`.arrivals.generate_arrivals`) consumed
    one entry per op issue, in issue order (threads in tid order at init,
    then one per completion).  An op whose arrival is in the future parks
    its thread on the shared wake heap until the arrival clock, so
    queueing delay becomes observable; per-op latency is then the
    *sojourn* (arrival -> completion).  With ``collect_percentiles`` the
    measured sojourns are summarized into ``SimResult.latency_summary``;
    ops whose sojourn exceeds ``deadline`` (seconds, 0 = disabled) count
    as ``missed_ops`` and are excluded from the percentiles.  The arrival
    timestamps come from a separate RNG stream, so closed-loop results
    (``arrivals=None``) are untouched.
    """
    rng = random.Random(cfg.seed)
    total_threads = cfg.n_threads * cfg.n_cores
    if warmup_ops is None:
        warmup_ops = 2 * total_threads

    arr_seq = None if arrivals is None else list(arrivals)
    open_loop = arr_seq is not None
    n_arr = len(arr_seq) if open_loop else 0
    if open_loop and n_arr == 0:
        raise ValueError("arrivals must be non-empty when provided")
    arr_i = 0

    cores = [Core() for _ in range(cfg.n_cores)]
    ssd = SSDClocks(cfg)
    lock_next = 0.0

    parked = ParkedHeap()

    def start_op(th: Thread, now: float) -> None:
        nonlocal arr_i
        op = op_source(rng)
        th.subops = op.subops
        th.idx = 0
        if open_loop:
            # Ops are stamped with their arrival, not the fetch time; a
            # stream shorter than the run clamps to its last timestamp
            # (sweep_latency always generates enough -- see _arrival_count).
            th.op_start = arr_seq[arr_i] if arr_i < n_arr else arr_seq[-1]
            arr_i += 1
        else:
            th.op_start = now

    for cid, core in enumerate(cores):
        for t in range(cfg.n_threads):
            th = Thread(cid * cfg.n_threads + t)
            start_op(th, 0.0)
            # The first MEM access of the very first op: treat its prefetch
            # as issued at a random phase before t=0 (threads never start in
            # lockstep on real hardware), so the warm-up does not seed the
            # pathological aligned schedule of Fig. 7(a).  Open loop: the
            # phase offset is anchored at the op's arrival instead.
            th.pf_ready = th.op_start + rng.random() * sample_lmem(cfg, rng)
            if th.op_start > 0.0:
                parked.park(th.op_start, cid, th)
            else:
                core.ready.append(th)

    done = 0
    counted = 0
    t_start_measure = None
    mem_stall = 0.0
    mem_accesses = 0
    op_lat: list[float] = []
    stalls: list[float] = []
    lat_acc: list[float] | None = [] if collect_percentiles else None
    missed = 0
    hist = cfg.collect_load_hist

    # Event loop over cores ordered by their local clocks.
    core_heap = [(0.0, cid) for cid in range(cfg.n_cores)]
    heapq.heapify(core_heap)

    measuring = lambda: done >= warmup_ops  # noqa: E731

    while counted < n_ops:
        # Wake any parked threads whose IO completed before the earliest
        # core time (they rejoin their core's ready ring).
        parked.wake_until(core_heap[0][0], cores)

        t_core, cid = heapq.heappop(core_heap)
        core = cores[cid]
        core.now = max(core.now, t_core)

        if not core.ready:
            # Idle until this core's earliest parked thread wakes (or any
            # parked thread if the core has none -- then just re-arm later).
            wake = parked.earliest_for(cid)
            if wake is None:
                if parked:
                    heapq.heappush(core_heap, (parked.next_wake(), cid))
                # else: deadlock cannot happen (some thread always runnable)
                continue
            core.now = max(core.now, wake)
            parked.wake_until(core.now, cores)
            if not core.ready:
                heapq.heappush(core_heap, (core.now + 1e-9, cid))
                continue

        th = core.ready.popleft()
        kind, dur = th.subops[th.idx]
        now = core.now

        if kind == MEM:
            if cfg.eps > 0.0 and rng.random() < cfg.eps:
                ready_at = now + sample_lmem(cfg, rng)  # premature eviction
            else:
                ready_at = th.pf_ready
            stall = ready_at - now
            if stall > 0.0:
                if measuring():
                    mem_stall += stall
                now = ready_at
            if hist and measuring():
                stalls.append(max(stall, 0.0))
            if measuring():
                mem_accesses += 1
            now += dur
        else:  # PREIO / POSTIO / CPU all just burn their CPU time here
            now += dur

        th.idx += 1
        end_of_op = th.idx >= len(th.subops)

        if end_of_op:
            done += 1
            if measuring():
                if t_start_measure is None:
                    t_start_measure = now
                counted += 1
                if collect_latency or lat_acc is not None:
                    sojourn = now - th.op_start
                    if collect_latency:
                        op_lat.append(sojourn)
                    if lat_acc is not None:
                        if deadline > 0.0 and sojourn > deadline:
                            missed += 1
                        else:
                            lat_acc.append(sojourn)
            start_op(th, now)
            if cfg.T_lock > 0.0:
                start = max(now, lock_next)
                now = start + cfg.T_lock
                lock_next = now

        nkind = th.subops[th.idx][0]
        park_until = None

        if kind == PREIO and not end_of_op:
            # Submit the IO now; completion is gated by the shared SSD clocks.
            park_until = ssd.submit(now, rng)

        if nkind == MEM:
            # Issue the prefetch for the next access (pointer now known).
            # Open loop: a not-yet-arrived op cannot have issued its
            # prefetch before its arrival.
            t_iss = now
            if end_of_op and th.op_start > t_iss:
                t_iss = th.op_start
            th.pf_ready = core.prefetch.issue(t_iss, cfg, rng)

        now += cfg.T_sw  # one context switch per suboperation (yield)
        core.now = now

        if park_until is not None:
            parked.park(max(park_until, now), cid, th)
        elif end_of_op and th.op_start > now:
            # Open loop: the next op has not arrived yet -- park until the
            # arrival clock (closed loop never takes this branch).
            parked.park(th.op_start, cid, th)
        else:
            core.ready.append(th)
        heapq.heappush(core_heap, (core.now, cid))

    t0 = t_start_measure if t_start_measure is not None else 0.0
    t_end = max(c.now for c in cores)
    elapsed = max(t_end - t0, 1e-12)
    return SimResult(
        ops=counted,
        time=elapsed,
        throughput=counted / elapsed,
        mem_stall_total=mem_stall,
        mem_accesses=mem_accesses,
        op_latencies=op_lat,
        load_stalls=stalls,
        missed_ops=missed,
        latency_summary=(summarize_exact(lat_acc, missed)
                         if lat_acc is not None else None),
    )


def simulate_compiled(
    cfg: SimConfig,
    trace: CompiledTrace,
    n_ops: int,
    warmup_ops: int | None = None,
    collect_latency: bool = False,
    *,
    arrivals: Sequence[float] | None = None,
    collect_percentiles: bool = False,
    deadline: float = 0.0,
) -> SimResult:
    """Fast replay of a :class:`CompiledTrace` (bit-identical to the generic
    loop over ``trace_source(trace.to_ops())``; see module docstring).

    The specialization covers all device features (eps, rho, latency
    mixtures, per-SSD token clocks with ``n_ssd`` round-robin striping, the
    ``L_switch`` fan-out hop, memory throttle, T_lock) and the open-loop
    arrival/percentile extensions (see :func:`simulate`); multi-core
    configs route to :func:`_simulate_compiled_multicore`, which keeps the
    generic loop's core-heap event order and RNG draw order.
    """
    if cfg.n_cores != 1:
        return _simulate_compiled_multicore(
            cfg, trace, n_ops, warmup_ops, collect_latency,
            arrivals=arrivals, collect_percentiles=collect_percentiles,
            deadline=deadline)

    rng = random.Random(cfg.seed)
    rrandom = rng.random
    rrandrange = rng.randrange
    if warmup_ops is None:
        warmup_ops = 2 * cfg.n_threads

    kinds, durs, op_starts, op_ends = trace.as_lists()
    n_trace = trace.n_ops

    # Hoist config into locals (attribute loads dominate the interpreted
    # inner loop otherwise).
    P = cfg.P
    T_sw = cfg.T_sw
    T_lock = cfg.T_lock
    eps = cfg.eps
    L_io = cfg.L_io
    jitter = cfg.L_io_jitter
    io_degrade = cfg.io_degrade
    T_degrade = cfg.T_degrade
    has_degrade = io_degrade != 1.0
    R_io = cfg.R_io
    B_io = cfg.B_io
    A_io = cfg.A_io
    B_mem = cfg.B_mem
    A_mem = cfg.A_mem
    hist = cfg.collect_load_hist

    simple_mem = cfg.rho >= 1.0 and isinstance(cfg.L_mem, (int, float))
    lmem_scalar = float(cfg.L_mem) if simple_mem else 0.0

    def sample() -> float:
        # Same draw order as devices.sample_lmem (used on the slow paths).
        return sample_lmem(cfg, rng)

    # Trace cursor, replicating trace_source exactly: one randrange is drawn
    # per fetch (the legacy closure evaluates it as a setdefault argument),
    # only the first draw picks the starting offset.
    cursor = -1

    arr_seq = None if arrivals is None else list(arrivals)
    open_loop = arr_seq is not None
    n_arr = len(arr_seq) if open_loop else 0
    if open_loop and n_arr == 0:
        raise ValueError("arrivals must be non-empty when provided")

    n_threads = cfg.n_threads
    t_idx = [0] * n_threads        # current flat subop index
    t_end = [0] * n_threads        # flat end index of the current op
    t_pf = [0.0] * n_threads       # prefetch completion for subops[idx]
    t_opstart = [0.0] * n_threads

    parked: list[tuple[float, int, int]] = []   # (wake, seq, tid)
    seq = 0
    heappush = heapq.heappush
    heappop = heapq.heappop

    ready: deque[int] = deque()     # FIFO ring of tids
    for tid in range(n_threads):
        j = rrandrange(n_trace)
        if cursor < 0:
            cursor = j
        t_idx[tid] = op_starts[cursor]
        t_end[tid] = op_ends[cursor]
        cursor = (cursor + 1) % n_trace
        a0 = (arr_seq[tid] if tid < n_arr else arr_seq[-1]) if open_loop \
            else 0.0
        t_opstart[tid] = a0
        t_pf[tid] = a0 + rrandom() * (lmem_scalar if simple_mem else sample())
        if a0 > 0.0:
            seq += 1
            heappush(parked, (a0, seq, tid))
        else:
            ready.append(tid)
    arr_i = n_threads
    ready_pop = ready.popleft
    ready_push = ready.append

    pf_inflight: list[float] = []   # the single core's prefetch heap
    pf_bw_next = 0.0
    # Per-SSD token clocks + the round-robin striping cursor (the inlined
    # mirror of devices.SSDClocks; with n_ssd == 1 the arithmetic is the
    # single-device model unchanged).
    n_ssd = cfg.n_ssd
    if n_ssd < 1:
        raise ValueError(f"n_ssd must be >= 1, got {n_ssd}")
    L_switch = cfg.L_switch
    io_tok_next = [0.0] * n_ssd
    io_bw_next = [0.0] * n_ssd
    io_rr = 0
    lock_next = 0.0

    done = 0
    counted = 0
    t_start_measure = None
    mem_stall = 0.0
    mem_accesses = 0
    op_lat: list[float] = []
    stalls: list[float] = []
    lat_acc: list[float] | None = [] if collect_percentiles else None
    missed = 0
    measuring = warmup_ops <= 0

    now = 0.0
    while counted < n_ops:
        while parked and parked[0][0] <= now:
            ready_push(heappop(parked)[2])
        if not ready:
            # All threads parked on IO: idle-skip to the earliest wake.
            wake = parked[0][0]
            if wake > now:
                now = wake
            while parked and parked[0][0] <= now:
                ready_push(heappop(parked)[2])

        tid = ready_pop()
        i = t_idx[tid]
        kind = kinds[i]
        dur = durs[i]

        if kind == 0:  # MEM
            if eps > 0.0 and rrandom() < eps:
                ready_at = now + (lmem_scalar if simple_mem else sample())
            else:
                ready_at = t_pf[tid]
            stall = ready_at - now
            if stall > 0.0:
                if measuring:
                    mem_stall += stall
                now = ready_at
            if measuring:
                if hist:
                    stalls.append(stall if stall > 0.0 else 0.0)
                mem_accesses += 1
            now += dur
        else:
            now += dur

        i += 1
        end_of_op = i >= t_end[tid]

        if end_of_op:
            done += 1
            if done >= warmup_ops:
                measuring = True
                if t_start_measure is None:
                    t_start_measure = now
                counted += 1
                if collect_latency or lat_acc is not None:
                    sojourn = now - t_opstart[tid]
                    if collect_latency:
                        op_lat.append(sojourn)
                    if lat_acc is not None:
                        if deadline > 0.0 and sojourn > deadline:
                            missed += 1
                        else:
                            lat_acc.append(sojourn)
            # Start the next op from the shared cyclic cursor.  The
            # rrandrange draw is discarded on purpose: the legacy
            # trace_source evaluates one per fetch (setdefault argument),
            # and keeping the RNG stream identical keeps results
            # bit-identical to the generic loop.
            rrandrange(n_trace)
            i = op_starts[cursor]
            t_end[tid] = op_ends[cursor]
            cursor = (cursor + 1) % n_trace
            if open_loop:
                t_opstart[tid] = (arr_seq[arr_i] if arr_i < n_arr
                                  else arr_seq[-1])
                arr_i += 1
            else:
                t_opstart[tid] = now
            if T_lock > 0.0:
                start = now if now > lock_next else lock_next
                now = start + T_lock
                lock_next = now

        park_until = None
        if kind == 1 and not end_of_op:  # PREIO: submit the IO now
            dev = io_rr % n_ssd
            io_rr += 1
            svc = now
            if R_io > 0.0:
                if io_tok_next[dev] > svc:
                    svc = io_tok_next[dev]
                io_tok_next[dev] = svc + 1.0 / R_io
            if B_io > 0.0:
                if io_bw_next[dev] > svc:
                    svc = io_bw_next[dev]
                io_bw_next[dev] = svc + A_io / B_io
            # Mid-run degradation keys off the *submission* time (same
            # rule as SSDClocks.submit, so the loops stay bit-identical).
            lat_io = L_io
            if has_degrade and now >= T_degrade:
                lat_io = L_io * io_degrade
            if jitter > 0.0:
                lat_io *= 1.0 + jitter * (2.0 * rrandom() - 1.0)
            park_until = svc + lat_io + L_switch

        if kinds[i] == 0:  # next subop is MEM: issue its prefetch now
            # Open loop: a not-yet-arrived op issues at its arrival clock.
            t_iss = now
            if end_of_op and t_opstart[tid] > t_iss:
                t_iss = t_opstart[tid]
            pq = pf_inflight
            while pq and pq[0] <= t_iss:
                heappop(pq)
            if len(pq) < P:
                start = t_iss
            else:
                start = t_iss if t_iss > pq[0] else pq[0]
            if B_mem > 0.0:
                if pf_bw_next > start:
                    start = pf_bw_next
                pf_bw_next = start + A_mem / B_mem
            comp = start + (lmem_scalar if simple_mem else sample())
            if len(pq) >= P:
                heappop(pq)
            heappush(pq, comp)
            t_pf[tid] = comp

        now += T_sw
        t_idx[tid] = i

        if park_until is not None:
            seq += 1
            heappush(parked, (park_until if park_until > now else now, seq, tid))
        elif end_of_op and t_opstart[tid] > now:
            # Open loop: park until the next op's arrival (closed loop
            # never takes this branch -- t_opstart <= now there).
            seq += 1
            heappush(parked, (t_opstart[tid], seq, tid))
        else:
            ready_push(tid)

    t0 = t_start_measure if t_start_measure is not None else 0.0
    elapsed = max(now - t0, 1e-12)
    return SimResult(
        ops=counted,
        time=elapsed,
        throughput=counted / elapsed,
        mem_stall_total=mem_stall,
        mem_accesses=mem_accesses,
        op_latencies=op_lat,
        load_stalls=stalls,
        missed_ops=missed,
        latency_summary=(summarize_exact(lat_acc, missed)
                         if lat_acc is not None else None),
    )


def _simulate_compiled_multicore(
    cfg: SimConfig,
    trace: CompiledTrace,
    n_ops: int,
    warmup_ops: int | None = None,
    collect_latency: bool = False,
    *,
    arrivals: Sequence[float] | None = None,
    collect_percentiles: bool = False,
    deadline: float = 0.0,
) -> SimResult:
    """Multi-core compiled fast loop, bit-identical to :func:`simulate`.

    A straight transcription of the generic loop's control flow -- the core
    heap ordered by local clocks, per-core FIFO rings and prefetch units,
    the shared parked heap / SSD clocks / lock clock / trace cursor -- onto
    flat lists with the device arithmetic inlined.  Every RNG draw happens
    at the same point in the same order as the generic loop (per-thread
    init: one discarded ``randrange`` per fetch then ``random() * sample``;
    runtime: eps + eviction sample, IO jitter, prefetch sample), so results
    are byte-for-byte identical, just ~2-3x faster in the interpreter.
    """
    rng = random.Random(cfg.seed)
    rrandom = rng.random
    rrandrange = rng.randrange
    n_threads = cfg.n_threads
    n_cores = cfg.n_cores
    if warmup_ops is None:
        warmup_ops = 2 * n_threads * n_cores

    kinds, durs, op_starts, op_ends = trace.as_lists()
    n_trace = trace.n_ops

    P = cfg.P
    T_sw = cfg.T_sw
    T_lock = cfg.T_lock
    eps = cfg.eps
    L_io = cfg.L_io
    jitter = cfg.L_io_jitter
    io_degrade = cfg.io_degrade
    T_degrade = cfg.T_degrade
    has_degrade = io_degrade != 1.0
    R_io = cfg.R_io
    B_io = cfg.B_io
    A_io = cfg.A_io
    B_mem = cfg.B_mem
    A_mem = cfg.A_mem
    hist = cfg.collect_load_hist

    simple_mem = cfg.rho >= 1.0 and isinstance(cfg.L_mem, (int, float))
    lmem_scalar = float(cfg.L_mem) if simple_mem else 0.0

    def sample() -> float:
        return sample_lmem(cfg, rng)

    cursor = -1
    total_threads = n_threads * n_cores
    t_idx = [0] * total_threads
    t_end = [0] * total_threads
    t_pf = [0.0] * total_threads
    t_opstart = [0.0] * total_threads

    arr_seq = None if arrivals is None else list(arrivals)
    open_loop = arr_seq is not None
    n_arr = len(arr_seq) if open_loop else 0
    if open_loop and n_arr == 0:
        raise ValueError("arrivals must be non-empty when provided")

    ready: list[deque[int]] = [deque() for _ in range(n_cores)]
    core_now = [0.0] * n_cores
    pf_inflight: list[list[float]] = [[] for _ in range(n_cores)]
    pf_bw_next = [0.0] * n_cores

    # Shared parked heap: (wake, seq, cid, tid).  seq breaks wake-time ties
    # FIFO, matching ParkedHeap's deterministic ordering.
    parked: list[tuple[float, int, int, int]] = []
    seq = 0
    heappush = heapq.heappush
    heappop = heapq.heappop

    for cid in range(n_cores):
        rq = ready[cid]
        for t in range(n_threads):
            tid = cid * n_threads + t
            j = rrandrange(n_trace)
            if cursor < 0:
                cursor = j
            t_idx[tid] = op_starts[cursor]
            t_end[tid] = op_ends[cursor]
            cursor = (cursor + 1) % n_trace
            a0 = (arr_seq[tid] if tid < n_arr else arr_seq[-1]) \
                if open_loop else 0.0
            t_opstart[tid] = a0
            t_pf[tid] = a0 + rrandom() * (lmem_scalar if simple_mem
                                          else sample())
            if a0 > 0.0:
                seq += 1
                heappush(parked, (a0, seq, cid, tid))
            else:
                rq.append(tid)
    arr_i = total_threads

    n_ssd = cfg.n_ssd
    if n_ssd < 1:
        raise ValueError(f"n_ssd must be >= 1, got {n_ssd}")
    L_switch = cfg.L_switch
    io_tok_next = [0.0] * n_ssd
    io_bw_next = [0.0] * n_ssd
    io_rr = 0
    lock_next = 0.0

    core_heap = [(0.0, cid) for cid in range(n_cores)]
    heapq.heapify(core_heap)

    done = 0
    counted = 0
    t_start_measure = None
    mem_stall = 0.0
    mem_accesses = 0
    op_lat: list[float] = []
    stalls: list[float] = []
    lat_acc: list[float] | None = [] if collect_percentiles else None
    missed = 0

    while counted < n_ops:
        # Wake threads whose IO completed before the earliest core time.
        horizon = core_heap[0][0]
        while parked and parked[0][0] <= horizon:
            e = heappop(parked)
            ready[e[2]].append(e[3])

        t_core, cid = heappop(core_heap)
        now = core_now[cid]
        if t_core > now:
            now = t_core
        rq = ready[cid]

        if not rq:
            # Idle until this core's earliest parked thread wakes (or re-arm
            # at the global next wake if this core has none parked).
            wake = None
            for e in parked:
                if e[2] == cid and (wake is None or e[0] < wake):
                    wake = e[0]
            if wake is None:
                if parked:
                    heappush(core_heap, (parked[0][0], cid))
                core_now[cid] = now
                continue
            if wake > now:
                now = wake
            while parked and parked[0][0] <= now:
                e = heappop(parked)
                ready[e[2]].append(e[3])
            if not rq:
                heappush(core_heap, (now + 1e-9, cid))
                core_now[cid] = now
                continue

        tid = rq.popleft()
        i = t_idx[tid]
        kind = kinds[i]
        dur = durs[i]

        if kind == 0:  # MEM
            if eps > 0.0 and rrandom() < eps:
                ready_at = now + (lmem_scalar if simple_mem else sample())
            else:
                ready_at = t_pf[tid]
            stall = ready_at - now
            if stall > 0.0:
                if done >= warmup_ops:
                    mem_stall += stall
                now = ready_at
            if done >= warmup_ops:
                if hist:
                    stalls.append(stall if stall > 0.0 else 0.0)
                mem_accesses += 1
            now += dur
        else:
            now += dur

        i += 1
        end_of_op = i >= t_end[tid]

        if end_of_op:
            done += 1
            if done >= warmup_ops:
                if t_start_measure is None:
                    t_start_measure = now
                counted += 1
                if collect_latency or lat_acc is not None:
                    sojourn = now - t_opstart[tid]
                    if collect_latency:
                        op_lat.append(sojourn)
                    if lat_acc is not None:
                        if deadline > 0.0 and sojourn > deadline:
                            missed += 1
                        else:
                            lat_acc.append(sojourn)
            # Shared cyclic cursor; the discarded rrandrange mirrors
            # trace_source's one-draw-per-fetch (see simulate_compiled).
            rrandrange(n_trace)
            i = op_starts[cursor]
            t_end[tid] = op_ends[cursor]
            cursor = (cursor + 1) % n_trace
            if open_loop:
                t_opstart[tid] = (arr_seq[arr_i] if arr_i < n_arr
                                  else arr_seq[-1])
                arr_i += 1
            else:
                t_opstart[tid] = now
            if T_lock > 0.0:
                start = now if now > lock_next else lock_next
                now = start + T_lock
                lock_next = now

        park_until = None
        if kind == 1 and not end_of_op:  # PREIO: shared SSD token clocks
            dev = io_rr % n_ssd
            io_rr += 1
            svc = now
            if R_io > 0.0:
                if io_tok_next[dev] > svc:
                    svc = io_tok_next[dev]
                io_tok_next[dev] = svc + 1.0 / R_io
            if B_io > 0.0:
                if io_bw_next[dev] > svc:
                    svc = io_bw_next[dev]
                io_bw_next[dev] = svc + A_io / B_io
            # Mid-run degradation keys off the *submission* time (same
            # rule as SSDClocks.submit, so the loops stay bit-identical).
            lat_io = L_io
            if has_degrade and now >= T_degrade:
                lat_io = L_io * io_degrade
            if jitter > 0.0:
                lat_io *= 1.0 + jitter * (2.0 * rrandom() - 1.0)
            park_until = svc + lat_io + L_switch

        if kinds[i] == 0:  # next subop is MEM: this core's prefetch unit
            # Open loop: a not-yet-arrived op issues at its arrival clock.
            t_iss = now
            if end_of_op and t_opstart[tid] > t_iss:
                t_iss = t_opstart[tid]
            pq = pf_inflight[cid]
            while pq and pq[0] <= t_iss:
                heappop(pq)
            if len(pq) < P:
                start = t_iss
            else:
                start = t_iss if t_iss > pq[0] else pq[0]
            if B_mem > 0.0:
                if pf_bw_next[cid] > start:
                    start = pf_bw_next[cid]
                pf_bw_next[cid] = start + A_mem / B_mem
            comp = start + (lmem_scalar if simple_mem else sample())
            if len(pq) >= P:
                heappop(pq)
            heappush(pq, comp)
            t_pf[tid] = comp

        now += T_sw
        t_idx[tid] = i
        core_now[cid] = now

        if park_until is not None:
            seq += 1
            heappush(parked,
                     (park_until if park_until > now else now, seq, cid, tid))
        elif end_of_op and t_opstart[tid] > now:
            # Open loop: park until the next op's arrival.
            seq += 1
            heappush(parked, (t_opstart[tid], seq, cid, tid))
        else:
            rq.append(tid)
        heappush(core_heap, (now, cid))

    t0 = t_start_measure if t_start_measure is not None else 0.0
    t_end_time = max(core_now)
    elapsed = max(t_end_time - t0, 1e-12)
    return SimResult(
        ops=counted,
        time=elapsed,
        throughput=counted / elapsed,
        mem_stall_total=mem_stall,
        mem_accesses=mem_accesses,
        op_latencies=op_lat,
        load_stalls=stalls,
        missed_ops=missed,
        latency_summary=(summarize_exact(lat_acc, missed)
                         if lat_acc is not None else None),
    )


def best_over_threads(
    cfg: SimConfig,
    op_source: Callable[[random.Random], Op],
    n_ops: int,
    candidates: Iterable[int] = DEFAULT_THREAD_CANDIDATES,
) -> tuple[SimResult, int]:
    """The paper's protocol: per latency point, optimize the thread count."""
    best: tuple[SimResult, int] | None = None
    for n in candidates:
        r = simulate(replace(cfg, n_threads=n), op_source, n_ops)
        if best is None or r.throughput > best[0].throughput:
            best = (r, n)
    assert best is not None
    return best
