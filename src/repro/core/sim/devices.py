"""Device models: memory-latency sampling, SSD token clocks, prefetch queue.

These encapsulate all *device* state of the simulation -- everything that is
not thread scheduling.  The generic event loop and the compiled fast loop in
:mod:`.engine_loop` both build on them; arithmetic and RNG draw order are
kept byte-identical between the two paths so results are reproducible across
refactors.
"""
from __future__ import annotations

import heapq
import random

from .config import SimConfig

__all__ = ["sample_lmem", "SSDClocks", "PrefetchUnit"]


def sample_lmem(cfg: SimConfig, rng: random.Random) -> float:
    """One memory-load latency: DRAM-tier short-circuit, scalar, or mixture."""
    if cfg.rho < 1.0 and rng.random() >= cfg.rho:
        return cfg.L_dram
    lm = cfg.L_mem
    if isinstance(lm, (int, float)):
        return float(lm)
    u = rng.random()
    acc = 0.0
    for lat, prob in lm:
        acc += prob
        if u < acc:
            return lat
    return lm[-1][0]


class SSDClocks:
    """Shared (cross-core) SSD gating: IOPS and bandwidth token clocks plus
    per-IO latency jitter.  ``submit`` returns the completion time of an IO
    submitted at ``now``."""

    __slots__ = ("R_io", "B_io", "A_io", "L_io", "jitter", "tok_next", "bw_next")

    def __init__(self, cfg: SimConfig):
        self.R_io = cfg.R_io
        self.B_io = cfg.B_io
        self.A_io = cfg.A_io
        self.L_io = cfg.L_io
        self.jitter = cfg.L_io_jitter
        self.tok_next = 0.0
        self.bw_next = 0.0

    def submit(self, now: float, rng: random.Random) -> float:
        svc = now
        if self.R_io > 0.0:
            svc = max(svc, self.tok_next)
            self.tok_next = svc + 1.0 / self.R_io
        if self.B_io > 0.0:
            svc = max(svc, self.bw_next)
            self.bw_next = svc + self.A_io / self.B_io
        lat_io = self.L_io
        if self.jitter > 0.0:
            lat_io *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return svc + lat_io


class PrefetchUnit:
    """Per-core software-prefetch state: an in-flight completion heap bounded
    by queue depth P, plus the memory-bandwidth throttle spacing prefetch
    starts (A_mem/B_mem)."""

    __slots__ = ("inflight", "bw_next")

    def __init__(self):
        self.inflight: list[float] = []   # heap of completion times
        self.bw_next = 0.0

    def issue(self, now: float, cfg: SimConfig, rng: random.Random) -> float:
        """Issue a prefetch at ``now``; returns its completion time.

        If P slots are all in flight the start is delayed until the earliest
        one completes (Fig. 5); the bandwidth throttle can delay it further.
        """
        pq = self.inflight
        while pq and pq[0] <= now:
            heapq.heappop(pq)
        start = now if len(pq) < cfg.P else max(now, pq[0])
        if cfg.B_mem > 0.0:
            start = max(start, self.bw_next)
            self.bw_next = start + cfg.A_mem / cfg.B_mem
        comp = start + sample_lmem(cfg, rng)
        if len(pq) >= cfg.P:
            heapq.heappop(pq)
        heapq.heappush(pq, comp)
        return comp
