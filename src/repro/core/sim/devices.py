"""Device models: memory-latency sampling, SSD token clocks, prefetch queue.

These encapsulate all *device* state of the simulation -- everything that is
not thread scheduling.  The generic event loop and the compiled fast loop in
:mod:`.engine_loop` both build on them; arithmetic and RNG draw order are
kept byte-identical between the two paths so results are reproducible across
refactors.

The token-clock model
---------------------
Every rate-limited resource is modelled as a *token clock*: a single float
holding the earliest time the next grant may start.  Admitting a request at
time ``now`` costs ``max(now, clock)`` as its start time and advances the
clock by the per-request spacing (``1/R_io`` for an IOPS limit, ``bytes/B``
for a bandwidth limit).  That is an exact fluid model of a token bucket with
burst size one; it needs no queues, is O(1) per request, and composes --
an IO is gated first by its device's IOPS clock, then its bandwidth clock,
then pays the device latency (plus jitter and the optional switch hop).
A clock of ``0.0`` with a rate of ``0.0`` disables that limit.

Multi-SSD fan-out
-----------------
With ``cfg.n_ssd > 1`` each device gets its *own* pair of token clocks
(``R_io``/``B_io`` are per-device rates, so aggregate capacity scales with
the device count), and IOs are striped over devices round-robin in global
submission order.  Real stores stripe by data placement (key -> device);
round-robin is the deterministic stand-in that keeps the generic and
compiled loops bit-identical and is exact whenever IOs are
placement-uniform, which the paper's uniform/Zipf-hashed workloads are.
``cfg.L_switch`` adds a fixed CXL/PCIe-switch fan-out hop to every IO's
completion, modelling a device pool hanging off a shared switch.
"""
from __future__ import annotations

import heapq
import random

from .config import SimConfig

__all__ = ["sample_lmem", "SSDClocks", "PrefetchUnit"]


def sample_lmem(cfg: SimConfig, rng: random.Random) -> float:
    """One memory-load latency: DRAM-tier short-circuit, scalar, or mixture."""
    if cfg.rho < 1.0 and rng.random() >= cfg.rho:
        return cfg.L_dram
    lm = cfg.L_mem
    if isinstance(lm, (int, float)):
        return float(lm)
    u = rng.random()
    acc = 0.0
    for lat, prob in lm:
        acc += prob
        if u < acc:
            return lat
    return lm[-1][0]


class SSDClocks:
    """Shared (cross-core) SSD gating: per-device IOPS and bandwidth token
    clocks plus per-IO latency jitter and the switch fan-out hop.

    ``submit`` returns the completion time of an IO submitted at ``now``;
    the IO is placed on the next device in round-robin order and gated by
    that device's clocks only (see the module docstring for the model).
    """

    __slots__ = ("R_io", "B_io", "A_io", "L_io", "jitter", "L_switch",
                 "n_ssd", "degrade", "T_degrade", "tok_next", "bw_next",
                 "_rr")

    def __init__(self, cfg: SimConfig):
        if cfg.n_ssd < 1:
            raise ValueError(f"n_ssd must be >= 1, got {cfg.n_ssd}")
        self.R_io = cfg.R_io
        self.B_io = cfg.B_io
        self.A_io = cfg.A_io
        self.L_io = cfg.L_io
        self.jitter = cfg.L_io_jitter
        self.L_switch = cfg.L_switch
        self.n_ssd = cfg.n_ssd
        self.degrade = cfg.io_degrade
        self.T_degrade = cfg.T_degrade
        self.tok_next = [0.0] * cfg.n_ssd
        self.bw_next = [0.0] * cfg.n_ssd
        self._rr = 0

    def submit(self, now: float, rng: random.Random) -> float:
        dev = self._rr % self.n_ssd
        self._rr += 1
        svc = now
        if self.R_io > 0.0:
            svc = max(svc, self.tok_next[dev])
            self.tok_next[dev] = svc + 1.0 / self.R_io
        if self.B_io > 0.0:
            svc = max(svc, self.bw_next[dev])
            self.bw_next[dev] = svc + self.A_io / self.B_io
        # Mid-run degradation slows the device latency of every IO
        # *submitted* at now >= T_degrade (submission time, not the gated
        # start: a queued IO issued before the fault is still fast).
        lat_io = self.L_io
        if self.degrade != 1.0 and now >= self.T_degrade:
            lat_io = self.L_io * self.degrade
        if self.jitter > 0.0:
            lat_io *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return svc + lat_io + self.L_switch


class PrefetchUnit:
    """Per-core software-prefetch state: an in-flight completion heap bounded
    by queue depth P, plus the memory-bandwidth throttle spacing prefetch
    starts (A_mem/B_mem)."""

    __slots__ = ("inflight", "bw_next")

    def __init__(self):
        self.inflight: list[float] = []   # heap of completion times
        self.bw_next = 0.0

    def issue(self, now: float, cfg: SimConfig, rng: random.Random) -> float:
        """Issue a prefetch at ``now``; returns its completion time.

        If P slots are all in flight the start is delayed until the earliest
        one completes (Fig. 5); the bandwidth throttle can delay it further.
        """
        pq = self.inflight
        while pq and pq[0] <= now:
            heapq.heappop(pq)
        start = now if len(pq) < cfg.P else max(now, pq[0])
        if cfg.B_mem > 0.0:
            start = max(start, self.bw_next)
            self.bw_next = start + cfg.A_mem / cfg.B_mem
        comp = start + sample_lmem(cfg, rng)
        if len(pq) >= cfg.P:
            heapq.heappop(pq)
        heapq.heappush(pq, comp)
        return comp
