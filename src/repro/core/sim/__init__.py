"""Discrete-event simulation of the paper's execution model (the "FPGA
testbed"), as a layered package:

  * :mod:`.config`      -- :class:`SimConfig` / :class:`SimResult`
  * :mod:`.arrivals`    -- open-loop arrival processes (Poisson, bursty,
                           diurnal, multi-tenant mixes) and the sojourn
                           tail-latency accumulator shared by all backends
  * :mod:`.devices`     -- memory-latency sampling, per-SSD token clocks
                           (``n_ssd`` devices, round-robin striping, switch
                           fan-out hop), per-core prefetch queue + throttle
  * :mod:`.scheduler`   -- threads, cores, FIFO ready rings, parked heap
  * :mod:`.engine_loop` -- the generic event loop and the compiled
                           single-core fast loop over columnar traces
  * :mod:`.sweep`       -- the batched latency x threads sweep pipeline
                           (``backend="loop"`` interpreter cells or
                           ``backend="jax"`` vectorized grid)
  * :mod:`.replay_jax`  -- the jax backend: the compiled trace lowered to
                           device arrays and the whole grid replayed as one
                           jitted scan (imported lazily -- importing jax is
                           heavyweight and changes multiprocessing choices)

The paper measures KV-operation throughput on real hardware whose memory
latency is made adjustable by an FPGA CXL board.  This container has no
such hardware, so we reproduce the *measurement apparatus* in virtual time
with exactly the paper's free parameters: N threads per core with strict
FIFO scheduling and per-yield context-switch cost T_sw, software prefetch
with per-core queue depth P, stall-on-incomplete-prefetch (the gray bars of
Figs. 5 and 8), asynchronous IO striped over one or more SSDs each gated by
its own bandwidth/IOPS token clocks (plus an optional CXL-switch fan-out
hop), memory-bandwidth throttling, DRAM tiering, premature eviction,
tail-latency mixtures, and a global per-op critical section.

Operations come from an ``OpSource`` callable (microbenchmark or legacy
trace replay) or, on the fast path, from a columnar
:class:`~repro.core.trace_ir.CompiledTrace` recorded by the engines in
:mod:`repro.core.engines`.
"""
from ..trace_ir import CPU, MEM, POSTIO, PREIO, US, CompiledTrace, Op  # noqa: F401
from .arrivals import (  # noqa: F401
    HIST_REL_ERROR,
    ArrivalSpec,
    LatencySummary,
    generate_arrivals,
    summarize_exact,
    summarize_hist,
)
from .config import SimConfig, SimResult  # noqa: F401
from .devices import PrefetchUnit, SSDClocks, sample_lmem  # noqa: F401
from .engine_loop import (  # noqa: F401
    best_over_threads,
    microbenchmark_source,
    simulate,
    simulate_compiled,
    trace_source,
)
from .scheduler import Core, ParkedHeap, Thread  # noqa: F401
from .sweep import (  # noqa: F401
    BACKENDS,
    SweepPoint,
    clear_sweep_cache,
    prune_sweep_cache,
    sweep_latency,
)

__all__ = [
    "US",
    "MEM",
    "PREIO",
    "POSTIO",
    "CPU",
    "Op",
    "CompiledTrace",
    "SimConfig",
    "SimResult",
    "simulate",
    "simulate_compiled",
    "microbenchmark_source",
    "trace_source",
    "best_over_threads",
    "sweep_latency",
    "SweepPoint",
    "BACKENDS",
    "clear_sweep_cache",
    "prune_sweep_cache",
    "ArrivalSpec",
    "LatencySummary",
    "generate_arrivals",
    "summarize_exact",
    "summarize_hist",
    "HIST_REL_ERROR",
]
