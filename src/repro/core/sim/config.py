"""Simulation configuration and result types (the paper's free parameters)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from ..trace_ir import US

__all__ = ["US", "DEFAULT_THREAD_CANDIDATES", "SimConfig", "SimResult"]

# The thread counts tried when optimizing per latency point -- shared by the
# legacy best_over_threads protocol and the batched sweep pipeline so the
# two always search the same grid.
DEFAULT_THREAD_CANDIDATES = (8, 16, 24, 32, 48, 64, 96, 128)


@dataclass(frozen=True)
class SimConfig:
    # Core/thread structure
    n_threads: int = 48
    n_cores: int = 1
    T_sw: float = 0.05 * US
    # Prefetch path
    P: int = 12
    L_mem: float | Sequence[tuple[float, float]] = 5.0 * US  # scalar or [(lat, prob)]
    rho: float = 1.0
    L_dram: float = 0.1 * US
    eps: float = 0.0
    A_mem: float = 64.0
    B_mem: float = 0.0            # bytes/sec; 0 disables the throttle
    # IO path
    L_io: float = 80.0 * US
    L_io_jitter: float = 0.25     # uniform +-fraction of L_io (real SSDs jitter;
                                  # this is what naturally misaligns threads,
                                  # Sec. 3.2.2 "timing ... will be mostly random")
    A_io: float = 1024.0
    B_io: float = 0.0             # 0 disables; per device when n_ssd > 1
    R_io: float = 0.0             # 0 disables; per device when n_ssd > 1
    n_ssd: int = 1                # SSDs behind the IO path, each with its own
                                  # IOPS/bandwidth token clocks; IOs are striped
                                  # round-robin in submission order
    L_switch: float = 0.0         # CXL/PCIe-switch fan-out hop added to every
                                  # IO when the device pool hangs off a switch
    io_degrade: float = 1.0       # L_io multiplier for IOs submitted at
                                  # now >= T_degrade (1.0 disables) -- models a
                                  # device whose clocks slow mid-run (a failing
                                  # SSD, a GC storm, a degraded cluster node)
    T_degrade: float = 0.0        # virtual-time onset (seconds) of io_degrade;
                                  # 0.0 degrades the whole run
    # Contention
    T_lock: float = 0.0
    seed: int = 0
    collect_load_hist: bool = False

    def __post_init__(self):
        if self.n_cores < 1:
            raise ValueError(f"n_cores must be >= 1, got {self.n_cores}")
        if self.n_threads < 1:
            raise ValueError(f"n_threads must be >= 1, got {self.n_threads}")
        if self.T_lock < 0:
            raise ValueError(f"T_lock must be >= 0, got {self.T_lock}")
        if self.io_degrade <= 0:
            raise ValueError(
                f"io_degrade must be > 0, got {self.io_degrade}")
        if self.T_degrade < 0:
            raise ValueError(
                f"T_degrade must be >= 0, got {self.T_degrade}")


@dataclass
class SimResult:
    ops: int
    time: float                     # virtual seconds elapsed
    throughput: float               # ops/sec
    mem_stall_total: float          # total prefetch-wait (gray-bar) seconds
    mem_accesses: int
    op_latencies: list[float] = field(default_factory=list)
    load_stalls: list[float] = field(default_factory=list)  # Fig. 10 histogram
    # Open-loop tail-latency extras (see repro.core.sim.arrivals): measured
    # ops whose sojourn blew the SLA deadline, and the per-cell percentile
    # summary (an arrivals.LatencySummary) when collect_percentiles was on.
    missed_ops: int = 0
    latency_summary: object | None = None

    @property
    def mean_op_latency(self) -> float:
        return sum(self.op_latencies) / max(len(self.op_latencies), 1)
