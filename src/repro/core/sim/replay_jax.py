"""Vectorized JAX replay: the whole latency x threads grid as one jitted call.

The loop backends (:mod:`.engine_loop`) re-run an interpreter per grid cell;
this module instead lowers the columnar :class:`~repro.core.trace_ir.
CompiledTrace` into device arrays **once** (:class:`TraceArrays`), expresses
one cell's scheduler recurrence as a ``jax.lax.scan`` over suboperation
executions, and batches that scan across every ``(L_mem, n_threads)`` cell
of a sweep, so an entire Fig. 9-style grid is a single compiled XLA program
(:func:`sweep_grid`).

The recurrence
--------------
One scan step executes exactly one suboperation of one thread in every grid
cell.  Per cell the carried state is the single-core scheduler of the
compiled loop, vectorized:

  * thread selection: ready threads carry a monotone FIFO *ticket*
    (their ring position), parked threads their IO *wake* time.  A step
    wakes the earliest completed parked threads onto the back of the ring
    in wake order (``ticket = counter++``, up to ``_WAKES_PER_STEP`` of
    them -- see that constant's comment for why the bound is safe),
    idle-skips the clock to the earliest wake-up when nothing is
    runnable, and runs the smallest ticket -- a few ``argmin``
    reductions, everything else one-hot scatters;
  * MEM stalls against the thread's outstanding prefetch (or a resampled
    latency on an eps-eviction), PREIO submits to the per-device token
    clocks (round-robin striping, jitter, switch hop), op completion pays
    ``T_lock``, and the next suboperation's prefetch is issued against the
    P-deep in-flight window -- all the device arithmetic of
    :mod:`.devices`, expressed on ``(n_cells, ...)`` arrays;
  * the prefetch window is a fixed ``(n_cells, P)`` array of completion
    times: entries ``<= now`` are free slots (the loop backends' lazily
    drained heap), the replacement slot is the argmin, and the
    all-in-flight delay is the row minimum.

Cells that complete their measured ops latch their measurement (the
counters stop; the simulation harmlessly idles on) while the scan drains
the slower cells; the scan length is a worst-case bound computed from the
trace's op-length prefix sums, so no cell can run out of steps.

Exactness
---------
Scheduling, device arithmetic, and draw *distributions* match the loop
backends; the RNG streams do not (``jax.random`` threefry vs. the stdlib
Mersenne twister), and simultaneous-ready ties can resolve in a different
order.  Per-cell throughput therefore agrees with the loop backends to
sampling noise rather than bit-identically: ~0.5% typical (tails ~1.5%)
at the default ``n_ops=5000``, shrinking as ``1/sqrt(n_ops)`` -- the 1%
per-cell bound on the paper's default grid is enforced at
``n_ops=20_000`` by ``tests/test_replay_jax.py``.  Scalar
latencies and single-core configs only; ``sweep_latency(backend="jax")``
routes mixture latencies through the loop backend per-cell.

The per-step token-clock update can optionally run through the Pallas
kernel :mod:`repro.kernels.token_clock` (``use_pallas=True``): on TPU that
compiles the hot update; on CPU it runs in interpreter mode, which is far
too slow for real sweeps but lets CI validate the kernel bit-for-bit
against the pure-jnp path on tiny grids.

Everything here is computed in float64 (``jax.experimental.enable_x64``):
the state mixes ~second-scale clocks with 50 ns context switches, which
float32 cannot carry.
"""
from __future__ import annotations

import numbers
import struct
import zlib
from dataclasses import dataclass
from functools import partial
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..trace_ir import CPU, MEM, PREIO, CompiledTrace
from .config import SimConfig, SimResult

__all__ = ["TraceArrays", "GridResult", "sweep_grid", "lower_trace"]

_STEP_BUCKET = 4096     # scan lengths round up to this (compile-cache reuse)
_PAD_SENTINEL = CPU     # padded suboperations are inert plain-CPU entries


def _bucket(n: int, bucket: int) -> int:
    return max(bucket, -(-n // bucket) * bucket)


@dataclass(frozen=True)
class TraceArrays:
    """A :class:`CompiledTrace` lowered to device arrays, shape-padded.

    ``kinds``/``durs`` are the flat suboperation columns; ``op_starts`` /
    ``op_ends`` are the per-op slice bounds (``bounds[:-1]``/``bounds[1:]``
    of the source trace).  Arrays are padded up to power-of-two-ish buckets
    so traces of similar size share one compiled sweep program; ``n_ops`` /
    ``n_subops`` are the true (pre-padding) counts, and the replay indexes
    ops modulo ``n_ops`` so padding is never executed.  ``to_trace``
    reconstructs the source trace losslessly (``tests/test_replay_jax.py``
    proves the round-trip for every registered engine).
    """

    kinds: jax.Array      # int32 (n_subops_padded,)
    durs: jax.Array       # float64 (n_subops_padded,)
    op_starts: jax.Array  # int32 (n_ops_padded,)
    op_ends: jax.Array    # int32 (n_ops_padded,)
    n_ops: int
    n_subops: int

    @classmethod
    def from_trace(cls, trace: CompiledTrace,
                   bucket: int = 1024) -> "TraceArrays":
        n_ops, n_subops = trace.n_ops, trace.n_subops
        kinds = np.full(_bucket(n_subops, bucket), _PAD_SENTINEL,
                        dtype=np.int32)
        kinds[:n_subops] = trace.kinds
        durs = np.zeros(len(kinds), dtype=np.float64)
        durs[:n_subops] = trace.durs
        n_ops_pad = _bucket(n_ops, bucket)
        starts = np.empty(n_ops_pad, dtype=np.int32)
        ends = np.empty(n_ops_pad, dtype=np.int32)
        starts[:n_ops] = trace.bounds[:-1]
        ends[:n_ops] = trace.bounds[1:]
        starts[n_ops:] = trace.bounds[-2]    # replicate the last op; the
        ends[n_ops:] = trace.bounds[-1]      # replay never reads past n_ops
        with enable_x64():
            return cls(jnp.asarray(kinds), jnp.asarray(durs),
                       jnp.asarray(starts), jnp.asarray(ends),
                       n_ops, n_subops)

    def to_trace(self) -> CompiledTrace:
        """Decode back to the exact source :class:`CompiledTrace`."""
        starts = np.asarray(self.op_starts)[: self.n_ops]
        ends = np.asarray(self.op_ends)[: self.n_ops]
        bounds = np.concatenate([starts, ends[-1:]]).astype(np.int64)
        return CompiledTrace.from_columns(
            np.asarray(self.kinds)[: self.n_subops].astype(np.int8),
            np.asarray(self.durs)[: self.n_subops],
            bounds,
        )


def lower_trace(trace: CompiledTrace, bucket: int = 1024) -> TraceArrays:
    """Functional alias for :meth:`TraceArrays.from_trace`."""
    return TraceArrays.from_trace(trace, bucket)


@dataclass(frozen=True)
class GridResult:
    """Per-cell sweep results, shaped ``(n_latencies, n_candidates)``."""

    throughput: np.ndarray
    time: np.ndarray
    mem_stall_total: np.ndarray
    mem_accesses: np.ndarray
    ops: int                      # measured ops per cell (same for all)
    steps: int                    # scan length the grid compiled to

    def result(self, li: int, ci: int) -> SimResult:
        """One cell as a :class:`SimResult` (no per-op latency columns --
        use the loop backends for those)."""
        return SimResult(
            ops=self.ops,
            time=float(self.time[li, ci]),
            throughput=float(self.throughput[li, ci]),
            mem_stall_total=float(self.mem_stall_total[li, ci]),
            mem_accesses=int(self.mem_accesses[li, ci]),
        )


def _max_window_subops(bounds: np.ndarray, n_window_ops: int) -> int:
    """Worst-case suboperation count of ``n_window_ops`` consecutive ops of
    the cyclic trace, over all start offsets (exact, via prefix sums)."""
    lens = np.diff(bounds)
    n = len(lens)
    total = int(lens.sum())
    cycles, rem = divmod(n_window_ops, n)
    worst_rem = 0
    if rem:
        cs = np.concatenate([[0], np.cumsum(np.concatenate([lens, lens]))])
        worst_rem = int((cs[rem: rem + n] - cs[:n]).max())
    return cycles * total + worst_rem


def _steps_bound(trace: CompiledTrace, n_ops: int, max_warmup: int,
                 max_threads: int) -> int:
    """Scan length guaranteeing every cell completes its measured ops.

    A cell terminates once ``warmup + n_ops - 1`` ops have completed; every
    executed suboperation belongs to an op issued from the shared cyclic
    cursor, and at most ``completions + n_threads`` ops are ever issued --
    a consecutive window whose suboperation count bounds the step count.
    """
    window = max_warmup + n_ops + max_threads
    return _bucket(_max_window_subops(trace.bounds, window), _STEP_BUCKET)


# -- the jitted grid ---------------------------------------------------------


def _make_flags(cfg: SimConfig) -> dict:
    """Static specialization flags (Python bools baked into the program)."""
    return dict(
        has_eps=cfg.eps > 0.0,
        has_rho=cfg.rho < 1.0,
        has_jitter=cfg.L_io_jitter > 0.0,
        has_rio=cfg.R_io > 0.0,
        has_bio=cfg.B_io > 0.0,
        has_bmem=cfg.B_mem > 0.0,
        has_lock=cfg.T_lock > 0.0,
    )


def _tok_fn(use_pallas: bool):
    if use_pallas:
        from repro.kernels.token_clock import token_clock_update
        return token_clock_update
    from repro.kernels.token_clock import token_clock_update_ref
    return token_clock_update_ref


_RNG_CHUNK = 1024   # steps per generated uniform block (memory/dispatch knob)

# IO wake-ups processed per scan step.  The loop backends drain *every*
# completed parked thread at each scheduler iteration; the scan wakes a
# bounded number and defers the rest one step, which only matters when
# several IO completions land inside one suboperation's span.  Arrival
# rates are well below 1 wake/step (<= S / subops-per-op, at most ~1/3
# for the IO-densest engine), so a small constant keeps the deferral
# probability -- and its throughput bias -- negligible for every
# registered engine (tests/test_replay_jax.py enforces the 1% budget).
_WAKES_PER_STEP = 3


@partial(jax.jit, static_argnames=(
    "T_max", "P", "n_ssd", "steps", "unroll", "use_pallas", "has_eps",
    "has_rho", "has_jitter", "has_rio", "has_bio", "has_bmem", "has_lock"))
def _run_grid(kinds, durs, op_starts, op_ends, n_trace,
              L_mem_g, nthr_g, warm_g, n_ops, dyn, key, stream_ids, *,
              T_max, P, n_ssd, steps, unroll, use_pallas,
              has_eps, has_rho, has_jitter, has_rio, has_bio, has_bmem,
              has_lock):
    has_io_clock = has_rio or has_bio
    f = jnp.float64
    i4 = jnp.int32
    G = L_mem_g.shape[0]
    (T_sw, eps, rho, L_dram, L_io, jitter, inv_R, cost_bw_io, L_switch,
     cost_bmem, T_lock) = dyn

    def lmem(u, L):
        """sample_lmem for scalar latencies: DRAM-tier short-circuit."""
        if has_rho:
            return jnp.where(u >= rho, L_dram, L)
        return L

    # Packed trace columns: one gather serves (kind, dur) / (start, end).
    kd = jnp.stack([kinds.astype(f), durs], axis=1)          # (n_subops, 2)
    se = jnp.stack([op_starts, op_ends], axis=1)             # (n_ops, 2)

    # Uniform draws actually consumed per step, in consumption order (the
    # static flags decide): eps-eviction test + its resample, IO jitter,
    # the prefetch latency sample.  Draws are generated one _RNG_CHUNK of
    # steps at a time and fed to the inner scan as xs, so the step body
    # contains no hashing.
    n_u = 2 * has_eps + has_jitter + has_rho

    # -- per-cell RNG streams ------------------------------------------------
    # Every draw derives from fold_in(key, stream_id) where the stream id
    # hashes the cell's (L_mem, n_threads) identity -- NOT its position or
    # the batch size -- so a cell's numbers are identical whether it runs
    # alone, inside the full grid, or as the cache-miss remainder of a
    # partially memoized sweep (the cell cache requires cell values to be
    # a pure function of their key).  Per-thread init draws fold in the
    # thread index individually for the same reason: they must not depend
    # on the batch's T_max padding.
    cell_keys = jax.vmap(jax.random.fold_in, (None, 0))(key, stream_ids)
    k_chunks = jax.vmap(lambda k: jax.random.fold_in(k, 1))(cell_keys)
    tids = jnp.arange(T_max, dtype=i4)
    active = tids[None, :] < nthr_g[:, None]                       # (G, T)
    u_cursor = jax.vmap(lambda k: jax.random.uniform(
        jax.random.fold_in(k, 0), (), dtype=f))(cell_keys)
    cursor0 = jnp.floor(u_cursor * n_trace).astype(i4)
    opidx0 = (cursor0[:, None] + tids[None, :]) % n_trace
    cursor_init = (cursor0 + nthr_g) % n_trace
    u_thread = jax.vmap(lambda k: jax.vmap(
        lambda t: jax.random.uniform(jax.random.fold_in(k, 2 + t), (2,),
                                     dtype=f))(tids))(cell_keys)  # (G, T, 2)
    pf0 = u_thread[:, :, 0] * lmem(u_thread[:, :, 1], L_mem_g[:, None])

    # Per-cell scalar state lives in two packed (G, k) arrays: every carried
    # array is a materialization point for XLA's fuser, so fewer/wider
    # carries mean fewer tiny kernels per step.  Column layouts:
    #   cf: 0 now, 1 FIFO ticket counter, 2 prefetch bandwidth clock,
    #       3 lock clock, 4 t_start, 5 t_end, 6 measured stall seconds
    #   ci: 0 trace cursor, 1 IO round-robin, 2 completed ops, 3 measured
    #       ops, 4 measured MEM accesses, 5 measuring flag (0/1)
    #
    # Per-thread state is (G, T) planes, updated by one-hot scatters only
    # (XLA keeps those in-place inside the scan, so per-step traffic is
    # O(G) writes plus the reduction reads):
    #   pf     -- outstanding prefetch completion time
    #   ticket -- ready threads' FIFO ring position (+inf while parked);
    #             a monotone per-cell counter stamps every push
    #   wake   -- parked threads' IO completion time (+inf while ready)
    #
    # Each step re-creates the loop backends' scheduler iteration: wake
    # the earliest parked thread whose IO completed (it joins the BACK of
    # the ring: ticket = counter++), idle-skip the clock to the earliest
    # wake-up when nothing is runnable, then run the ring head (smallest
    # ticket).  Waking one thread per step instead of draining a batch
    # only matters when several wake-ups land inside one suboperation's
    # span -- the later ones join the ring a step late, a rare bounded
    # one-position slip that is part of the backend's tolerance budget.
    rows = jnp.arange(G, dtype=i4)
    state = dict(
        cf=jnp.zeros((G, 7), f).at[:, 4].set(-1.0).at[:, 1].set(
            float(T_max)),
        ci=jnp.stack(
            [cursor_init, jnp.zeros(G, i4), jnp.zeros(G, i4),
             jnp.zeros(G, i4), jnp.zeros(G, i4),
             (warm_g <= 0).astype(i4)], axis=1),
        pf=pf0,
        ticket=jnp.where(active, tids[None, :].astype(f), jnp.inf),
        wake=jnp.full((G, T_max), jnp.inf, f),
        thr_i=jnp.stack([op_starts[opidx0], op_ends[opidx0]], axis=2),
        pf_slots=jnp.zeros((G, P), f),
    )
    if has_io_clock:
        state["io_tok"] = jnp.zeros((G, n_ssd), f)
        state["io_bw"] = jnp.zeros((G, n_ssd), f)

    def step(s, u):
        un = iter(range(n_u))
        cf, ci = s["cf"], s["ci"]
        counter = cf[:, 1]
        counted0 = ci[:, 3]
        reached = counted0 >= n_ops    # cell already took its last op

        # -- wake + idle-skip + pop, in loop-backend order -------------------
        r_tid = jnp.argmin(s["ticket"], axis=1)
        r_t = jnp.take_along_axis(s["ticket"], r_tid[:, None], 1)[:, 0]
        ready_exists = jnp.isfinite(r_t)
        ticket, wake = s["ticket"], s["wake"]
        now = cf[:, 0]
        tid = r_tid
        for k in range(_WAKES_PER_STEP):
            w_tid = jnp.argmin(wake, axis=1)
            w_t = jnp.take_along_axis(wake, w_tid[:, None], 1)[:, 0]
            if k == 0:
                # nothing runnable: jump to the earliest IO completion
                now = jnp.where(ready_exists, now, jnp.maximum(now, w_t))
                tid = jnp.where(ready_exists, r_tid, w_tid)
            do_wake = w_t <= now
            # When nothing is parked w_tid is a bogus all-inf argmin (it
            # can point at a READY thread), so the no-wake branch must
            # write the existing values back, never a constant.
            t_at_w = jnp.take_along_axis(ticket, w_tid[:, None], 1)[:, 0]
            ticket = ticket.at[rows, w_tid].set(
                jnp.where(do_wake, counter, t_at_w))
            wake = wake.at[rows, w_tid].set(
                jnp.where(do_wake, jnp.inf, w_t))
            counter = counter + do_wake

        ie = jnp.take_along_axis(s["thr_i"], tid[:, None, None], 1)[:, 0]
        i, end_tid = ie[:, 0], ie[:, 1]
        pf_tid0 = jnp.take_along_axis(s["pf"], tid[:, None], 1)[:, 0]
        kd_i = kd[i]                                 # (G, 2)
        kind = kd_i[:, 0]
        dur = kd_i[:, 1]

        # -- MEM: stall on the outstanding prefetch (or an eps re-fetch) -----
        is_mem = kind == MEM
        ready_at = pf_tid0
        if has_eps:
            u_eps = u[next(un)]
            u_evict = u[next(un)]
            ready_at = jnp.where(u_eps < eps,
                                 now + lmem(u_evict, L_mem_g), ready_at)
        stall = ready_at - now
        stalled = is_mem & (stall > 0.0)
        live = (ci[:, 5] > 0) & ~reached
        mem_stall = cf[:, 6] + jnp.where(stalled & live, stall, 0.0)
        mem_acc = ci[:, 4] + (is_mem & live)
        now = jnp.where(stalled, ready_at, now) + dur

        # -- op completion: counters, measurement window, next op, T_lock ----
        i2 = i + 1
        eoo = i2 >= end_tid
        done = ci[:, 2] + eoo
        meas_evt = eoo & (done >= warm_g) & ~reached
        measuring = jnp.maximum(ci[:, 5], meas_evt)
        counted = counted0 + meas_evt
        t_start = jnp.where(meas_evt & (cf[:, 4] < 0.0), now, cf[:, 4])
        se_c = se[ci[:, 0]]                          # (G, 2)
        ni = jnp.where(eoo, se_c[:, 0], i2)
        nend = jnp.where(eoo, se_c[:, 1], end_tid)
        cursor = jnp.where(eoo, (ci[:, 0] + 1) % n_trace, ci[:, 0])
        lock_next = cf[:, 3]
        if has_lock:
            lock_end = jnp.maximum(now, lock_next) + T_lock
            now = jnp.where(eoo, lock_end, now)
            lock_next = jnp.where(eoo, lock_end, lock_next)

        # -- PREIO: submit against the striped per-device token clocks -------
        park = (kind == PREIO) & ~eoo
        io_rr = ci[:, 1]
        if not has_io_clock:
            svc = now
            io_out = {}
        elif n_ssd == 1 and not use_pallas:
            # Inlined single-device clocks (the common matrix config);
            # clocks only advance for cells actually submitting an IO.
            io_tok, io_bw = s["io_tok"][:, 0], s["io_bw"][:, 0]
            svc = now
            if has_rio:
                svc = jnp.maximum(svc, io_tok)
                io_tok = jnp.where(park, svc + inv_R, io_tok)
            if has_bio:
                svc = jnp.maximum(svc, io_bw)
                io_bw = jnp.where(park, svc + cost_bw_io, io_bw)
            io_out = {"io_tok": io_tok[:, None], "io_bw": io_bw[:, None]}
        else:
            devmask = (jnp.arange(n_ssd)[None, :]
                       == (io_rr % n_ssd)[:, None]) & park[:, None]
            svc, tok2d, bw2d = _tok_fn(use_pallas)(
                now, devmask, s["io_tok"], s["io_bw"], inv_R, cost_bw_io)
            io_out = {"io_tok": tok2d, "io_bw": bw2d}
            io_rr = io_rr + park
        lat_io = L_io
        if has_jitter:
            lat_io = L_io * (1.0 + jitter * (2.0 * u[next(un)] - 1.0))
        park_until = svc + lat_io + L_switch

        # -- issue the next suboperation's prefetch (P-deep window) ----------
        issue = kd[ni][:, 0] == MEM
        # All P slots in flight <=> the window minimum is still in the
        # future, so the all-busy delay is just max(now, min slot); the
        # minimum slot is also the replacement target either way.
        slot = jnp.argmin(s["pf_slots"], axis=1)
        slot_min = jnp.take_along_axis(s["pf_slots"], slot[:, None], 1)[:, 0]
        pstart = jnp.maximum(now, slot_min)
        pf_bw = cf[:, 2]
        if has_bmem:
            pstart = jnp.maximum(pstart, pf_bw)
            pf_bw = jnp.where(issue, pstart + cost_bmem, pf_bw)
        u_pf = u[next(un)] if has_rho else None
        comp = pstart + lmem(u_pf, L_mem_g)
        pf_slots = s["pf_slots"].at[rows, slot].set(
            jnp.where(issue, comp, slot_min))
        pf_tid = jnp.where(issue, comp, pf_tid0)

        # -- yield: context switch, park or re-enter the ready ring ----------
        now = now + T_sw

        crossed = (counted >= n_ops) & ~reached
        t_end = jnp.where(crossed, now, cf[:, 5])
        return dict(
            cf=jnp.stack([now, counter + 1.0, pf_bw, lock_next, t_start,
                          t_end, mem_stall], axis=1),
            ci=jnp.stack([cursor, io_rr, done, counted, mem_acc,
                          measuring], axis=1),
            pf=s["pf"].at[rows, tid].set(pf_tid),
            ticket=ticket.at[rows, tid].set(
                jnp.where(park, jnp.inf, counter)),
            wake=wake.at[rows, tid].set(
                jnp.where(park, jnp.maximum(park_until, now), jnp.inf)),
            thr_i=s["thr_i"].at[rows, tid].set(
                jnp.stack([ni, nend], axis=1)),
            pf_slots=pf_slots,
            **io_out,
        ), None

    def chunk(s, ck):
        if n_u:
            us = jax.vmap(lambda k: jax.random.uniform(
                jax.random.fold_in(k, ck), (_RNG_CHUNK, n_u),
                dtype=f))(k_chunks)              # (G, CH, n_u), per cell
            us = jnp.moveaxis(us, 0, -1)         # (CH, n_u, G)
        else:
            us = jnp.zeros((_RNG_CHUNK, 0, G), f)
        return jax.lax.scan(step, s, us, unroll=unroll)

    state, _ = jax.lax.scan(
        chunk, state, jnp.arange(steps // _RNG_CHUNK, dtype=i4))
    cf, ci = state["cf"], state["ci"]
    elapsed = jnp.maximum(cf[:, 5] - cf[:, 4], 1e-12)
    return dict(
        throughput=n_ops / elapsed,
        time=elapsed,
        mem_stall_total=cf[:, 6],
        mem_accesses=ci[:, 4],
        counted=ci[:, 3],
    )


def sweep_grid(
    cfg: SimConfig,
    trace: CompiledTrace | TraceArrays,
    latencies: Sequence[float],
    thread_candidates: Sequence[int],
    n_ops: int = 5000,
    warmup_ops: int | None = None,
    *,
    use_pallas: bool = False,
    unroll: int = 2,
) -> GridResult:
    """Run the full ``latencies x thread_candidates`` grid in one compiled
    call; see the module docstring for semantics and exactness.

    ``cfg`` supplies everything except ``L_mem``/``n_threads`` (the grid
    axes).  Scalar latencies and single-core configs only; ``warmup_ops``
    defaults per cell to ``2 * n_threads``, like the loop backends.
    """
    if cfg.n_cores != 1:
        raise ValueError(
            "the jax backend replays single-core configs only; use "
            "backend='loop' for n_cores > 1")
    if cfg.collect_load_hist:
        raise ValueError(
            "per-load stall histograms are not available from the jax "
            "backend; use backend='loop'")
    if cfg.n_ssd < 1:
        raise ValueError(f"n_ssd must be >= 1, got {cfg.n_ssd}")
    latencies = list(latencies)
    candidates = [int(n) for n in thread_candidates]
    if not latencies or not candidates:
        raise ValueError("empty sweep grid")
    if not all(isinstance(L, numbers.Real) for L in latencies):
        raise ValueError(
            "the jax backend replays scalar latencies only; "
            "sweep_latency(backend='jax') routes mixture points through "
            "the loop backend")
    if min(candidates) < 1:
        raise ValueError(f"thread candidates must be >= 1: {candidates}")

    source = trace if isinstance(trace, CompiledTrace) else trace.to_trace()
    ta = trace if isinstance(trace, TraceArrays) else lower_trace(trace)
    T_max = max(candidates)
    n_lat, n_cand = len(latencies), len(candidates)
    L_mem_g = np.repeat(np.asarray(latencies, dtype=np.float64), n_cand)
    nthr_g = np.tile(np.asarray(candidates, dtype=np.int32), n_lat)
    warm_g = (np.full_like(nthr_g, warmup_ops) if warmup_ops is not None
              else 2 * nthr_g)
    steps = _steps_bound(source, n_ops, int(warm_g.max()), T_max)

    # Each cell's RNG stream is keyed by its (L_mem, n_threads) VALUES, so
    # a cell's result never depends on which other cells share the call
    # (cache purity; see the per-cell RNG comment in _run_grid).
    stream_ids = np.array(
        [zlib.crc32(struct.pack("<dq", L, n))
         for L in np.asarray(latencies, dtype=np.float64)
         for n in candidates],
        dtype=np.uint32,
    )

    dyn = (
        cfg.T_sw, cfg.eps, cfg.rho, cfg.L_dram, cfg.L_io, cfg.L_io_jitter,
        1.0 / cfg.R_io if cfg.R_io > 0.0 else 0.0,
        cfg.A_io / cfg.B_io if cfg.B_io > 0.0 else 0.0,
        cfg.L_switch,
        cfg.A_mem / cfg.B_mem if cfg.B_mem > 0.0 else 0.0,
        cfg.T_lock,
    )
    with enable_x64():
        out = _run_grid(
            ta.kinds, ta.durs, ta.op_starts, ta.op_ends,
            jnp.int32(ta.n_ops),
            jnp.asarray(L_mem_g), jnp.asarray(nthr_g), jnp.asarray(warm_g),
            jnp.float64(n_ops),
            tuple(jnp.float64(d) for d in dyn),
            jax.random.PRNGKey(cfg.seed),
            jnp.asarray(stream_ids),
            T_max=T_max, P=cfg.P, n_ssd=cfg.n_ssd, steps=steps,
            unroll=unroll, use_pallas=use_pallas, **_make_flags(cfg),
        )
        out = {k: np.asarray(v) for k, v in out.items()}
    if not np.all(out["counted"] >= n_ops):
        short = int(out["counted"].min())
        raise RuntimeError(
            f"jax replay under-ran its step bound ({steps} steps, worst "
            f"cell counted {short}/{n_ops} ops) -- this is a bug in "
            "_steps_bound")
    shape = (n_lat, n_cand)
    return GridResult(
        throughput=out["throughput"].reshape(shape),
        time=out["time"].reshape(shape),
        mem_stall_total=out["mem_stall_total"].reshape(shape),
        mem_accesses=out["mem_accesses"].reshape(shape),
        ops=n_ops,
        steps=steps,
    )
