"""Vectorized JAX replay: the whole latency x threads grid as one jitted call.

The loop backends (:mod:`.engine_loop`) re-run an interpreter per grid cell;
this module instead lowers the columnar :class:`~repro.core.trace_ir.
CompiledTrace` into device arrays **once** (:class:`TraceArrays`), expresses
one cell's scheduler recurrence as a ``jax.lax.scan`` over suboperation
executions, and batches that scan across every ``(L_mem, n_threads)`` cell
of a sweep, so an entire Fig. 9-style grid is a single compiled XLA program
(:func:`sweep_grid`).

The recurrence
--------------
One scan step executes exactly one suboperation of one thread in every grid
cell.  The step body itself lives in :mod:`repro.kernels.sched_step` (the
fused whole-step scheduler kernel; see that module for the state layout):

  * thread selection: ready threads carry a monotone FIFO *stamp* with
    their thread id packed into the low mantissa bits, so a single ``min``
    reduction pops the ring head -- no ``argmin`` anywhere in the step;
  * wake drain: every parked thread whose IO completed re-joins the back
    of the ring in wake order in one masked pass -- the *exact* drain the
    loop backends perform, not a bounded-per-step approximation -- and
    the clock idle-skips to the earliest wake-up when nothing is
    runnable;
  * MEM stalls against the thread's outstanding prefetch (or a resampled
    latency on an eps-eviction), PREIO submits to the per-device token
    clocks (round-robin striping, jitter, switch hop), op completion pays
    ``T_lock``, and the next suboperation's prefetch is issued against the
    P-deep in-flight window -- all the device arithmetic of
    :mod:`.devices`, expressed on ``(n_cells, ...)`` arrays.

Cells that complete their measured ops latch their measurement (the
counters stop; the simulation harmlessly idles on) while the scan drains
the slower cells; the scan length is a worst-case bound computed from the
trace's op-length prefix sums, so no cell can run out of steps.  Grids
whose thread candidates span a wide range are split into power-of-two
thread *buckets* so small-thread cells do not pay the widest cell's
``T_max`` padding (per-cell RNG purity makes the split invisible to
results).

Exactness
---------
Scheduling, device arithmetic, and draw *distributions* match the loop
backends; the RNG streams do not (``jax.random`` threefry vs. the stdlib
Mersenne twister), and simultaneous-ready ties can resolve in a different
order.  Per-cell throughput therefore agrees with the loop backends to
sampling noise rather than bit-identically: ~0.5% typical (tails ~1.5%)
at the default ``n_ops=5000``, shrinking as ``1/sqrt(n_ops)`` -- the 1%
per-cell bound on the paper's default grid is enforced at
``n_ops=20_000`` by ``tests/test_replay_jax.py``.  Scalar
latencies and single-core configs only; ``sweep_latency(backend="jax")``
routes mixture latencies through the loop backend per-cell.

``use_pallas=True`` runs the scan through the fused Pallas kernel
(:func:`repro.kernels.sched_step.fused_steps`): the scheduler planes stay
resident in VMEM across ``substeps`` inner steps per kernel invocation.
On TPU that is the compiled fast path; on CPU it runs in interpreter mode,
which is far too slow for real sweeps but lets CI validate the kernel
bit-for-bit against the pure-jnp scan on tiny grids.

Everything here is computed in float64 (``jax.experimental.enable_x64``):
the state mixes ~second-scale clocks with 50 ns context switches, which
float32 cannot carry.  Perf runs on CPU should additionally export
``REPRO_JAX_LEGACY_CPU=1`` before jax initializes (the benchmark entry
points do) -- XLA's legacy inline runtime executes this scan ~2-5x
faster per step than the thunk runtime; see ``_XLA_CPU_FLAGS`` below for
why it is opt-in rather than the default.
"""
from __future__ import annotations

import numbers
import os
import struct
import zlib
from dataclasses import dataclass
from functools import lru_cache, partial
from typing import Sequence

import numpy as np

# Opt-in fast path for perf runs: XLA's legacy inline CPU runtime
# executes this module's scan body ~2-5x faster per op than the thunk
# runtime that became the default in jax 0.4.32 (command-buffer dispatch
# overhead on many small fused ops).  It is NOT enabled by default --
# XLA flags are process-global, the legacy runtime flushes denormals
# (FTZ/DAZ), and this library must not change numerics for every other
# jax user in the process.  Perf entry points (benchmarks/jax_grid_bench
# and ``benchmarks.run --backend jax``) export REPRO_JAX_LEGACY_CPU=1
# before jax initializes its CPU client; the sim itself is runtime-
# agnostic (its only sub-normal-magnitude values, the EPOCH ring
# tickets, are deliberately normal floats).
_XLA_CPU_FLAGS = "--xla_cpu_use_thunk_runtime=false"
if os.environ.get("REPRO_JAX_LEGACY_CPU"):
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_cpu_use_thunk_runtime" not in _flags:
        os.environ["XLA_FLAGS"] = (_flags + " " + _XLA_CPU_FLAGS).strip()

# Host-device sharding opt-in (same contract as REPRO_JAX_LEGACY_CPU:
# process-global, so only entry points that own the process should set
# it, *before* jax initializes).  XLA presents the host as N virtual CPU
# devices; sweep_grid(host_devices=N) then shard_maps cohorts over them
# so the jax backend uses every container core the way the forked loop
# pipeline already does.
_n_host = os.environ.get("REPRO_JAX_HOST_DEVICES", "")
if _n_host.isdigit() and int(_n_host) > 1:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags +
            f" --xla_force_host_platform_device_count={int(_n_host)}"
        ).strip()

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..trace_ir import CPU, CompiledTrace
from .arrivals import HIST_BINS, LatencySummary, hist_bin_value
from .config import SimConfig, SimResult

__all__ = ["TraceArrays", "GridResult", "sweep_grid", "lower_trace"]

_STEP_BUCKET = 4096     # scan lengths round up to this (compile-cache reuse)
_PAD_SENTINEL = CPU     # padded suboperations are inert plain-CPU entries


def _bucket(n: int, bucket: int) -> int:
    return max(bucket, -(-n // bucket) * bucket)


@dataclass(frozen=True)
class TraceArrays:
    """A :class:`CompiledTrace` lowered to device arrays, shape-padded.

    ``kinds``/``durs`` are the flat suboperation columns; ``op_starts`` /
    ``op_ends`` are the per-op slice bounds (``bounds[:-1]``/``bounds[1:]``
    of the source trace).  Arrays are padded up to power-of-two-ish buckets
    so traces of similar size share one compiled sweep program; ``n_ops`` /
    ``n_subops`` are the true (pre-padding) counts, and the replay indexes
    ops modulo ``n_ops`` so padding is never executed.  ``to_trace``
    reconstructs the source trace losslessly (``tests/test_replay_jax.py``
    proves the round-trip for every registered engine).
    """

    kinds: jax.Array      # int32 (n_subops_padded,)
    durs: jax.Array       # float64 (n_subops_padded,)
    op_starts: jax.Array  # int32 (n_ops_padded,)
    op_ends: jax.Array    # int32 (n_ops_padded,)
    n_ops: int
    n_subops: int

    @classmethod
    def from_trace(cls, trace: CompiledTrace,
                   bucket: int = 1024) -> "TraceArrays":
        n_ops, n_subops = trace.n_ops, trace.n_subops
        kinds = np.full(_bucket(n_subops, bucket), _PAD_SENTINEL,
                        dtype=np.int32)
        kinds[:n_subops] = trace.kinds
        durs = np.zeros(len(kinds), dtype=np.float64)
        durs[:n_subops] = trace.durs
        n_ops_pad = _bucket(n_ops, bucket)
        starts = np.empty(n_ops_pad, dtype=np.int32)
        ends = np.empty(n_ops_pad, dtype=np.int32)
        starts[:n_ops] = trace.bounds[:-1]
        ends[:n_ops] = trace.bounds[1:]
        starts[n_ops:] = trace.bounds[-2]    # replicate the last op; the
        ends[n_ops:] = trace.bounds[-1]      # replay never reads past n_ops
        with enable_x64():
            return cls(jnp.asarray(kinds), jnp.asarray(durs),
                       jnp.asarray(starts), jnp.asarray(ends),
                       n_ops, n_subops)

    def to_trace(self) -> CompiledTrace:
        """Decode back to the exact source :class:`CompiledTrace`."""
        starts = np.asarray(self.op_starts)[: self.n_ops]
        ends = np.asarray(self.op_ends)[: self.n_ops]
        bounds = np.concatenate([starts, ends[-1:]]).astype(np.int64)
        return CompiledTrace.from_columns(
            np.asarray(self.kinds)[: self.n_subops].astype(np.int8),
            np.asarray(self.durs)[: self.n_subops],
            bounds,
        )


def lower_trace(trace: CompiledTrace, bucket: int = 1024) -> TraceArrays:
    """Functional alias for :meth:`TraceArrays.from_trace`."""
    return TraceArrays.from_trace(trace, bucket)


@dataclass(frozen=True)
class GridResult:
    """Per-cell sweep results, shaped ``(n_latencies, n_candidates)``.

    ``cell_steps_bound`` / ``cell_steps_run`` sum, over all cells, the
    scan steps their cohort *scheduled* (the per-cohort worst-case bound)
    vs. actually *executed* before the cohort's early exit fired -- the
    difference is the wasted work the early-exit scan no longer pays.
    """

    throughput: np.ndarray
    time: np.ndarray
    mem_stall_total: np.ndarray
    mem_accesses: np.ndarray
    ops: int                      # measured ops per cell (same for all)
    steps: int                    # scan length bound (max across cohorts)
    cell_steps_bound: int = 0     # sum over cells of their cohort's bound
    cell_steps_run: int = 0      # sum over cells of executed steps
    # Tail-latency planes, present only when ``collect_percentiles`` was
    # on: histogram-derived percentiles (source="hist"; each within
    # arrivals.HIST_REL_ERROR of the exact value), the exact max, the
    # recorded count, and the deadline-missed count per cell.
    p50: np.ndarray | None = None
    p90: np.ndarray | None = None
    p99: np.ndarray | None = None
    lat_max: np.ndarray | None = None
    lat_count: np.ndarray | None = None
    missed: np.ndarray | None = None
    # Raw per-cell histogram counts, shaped (n_latencies, n_candidates,
    # HIST_BINS) -- cluster sweeps sum these planes across nodes to build
    # fleet-wide percentile summaries without re-running cells.
    lat_hist: np.ndarray | None = None

    def result(self, li: int, ci: int) -> SimResult:
        """One cell as a :class:`SimResult` (no per-op latency columns --
        use the loop backends for those)."""
        summary = None
        missed = 0
        if self.p50 is not None:
            missed = int(self.missed[li, ci])
            summary = LatencySummary(
                count=int(self.lat_count[li, ci]),
                p50=float(self.p50[li, ci]),
                p90=float(self.p90[li, ci]),
                p99=float(self.p99[li, ci]),
                max=float(self.lat_max[li, ci]),
                missed=missed,
                source="hist",
            )
        return SimResult(
            ops=self.ops,
            time=float(self.time[li, ci]),
            throughput=float(self.throughput[li, ci]),
            mem_stall_total=float(self.mem_stall_total[li, ci]),
            mem_accesses=int(self.mem_accesses[li, ci]),
            missed_ops=missed,
            latency_summary=summary,
        )


def _max_window_subops(bounds: np.ndarray, n_window_ops: int) -> int:
    """Worst-case suboperation count of ``n_window_ops`` consecutive ops of
    the cyclic trace, over all start offsets (exact, via prefix sums)."""
    lens = np.diff(bounds)
    n = len(lens)
    total = int(lens.sum())
    cycles, rem = divmod(n_window_ops, n)
    worst_rem = 0
    if rem:
        cs = np.concatenate([[0], np.cumsum(np.concatenate([lens, lens]))])
        worst_rem = int((cs[rem: rem + n] - cs[:n]).max())
    return cycles * total + worst_rem


def _steps_bound(trace: CompiledTrace, n_ops: int, max_warmup: int,
                 max_threads: int) -> int:
    """Scan length guaranteeing every cell completes its measured ops.

    A cell terminates once ``warmup + n_ops - 1`` ops have completed; every
    executed suboperation belongs to an op issued from the shared cyclic
    cursor, and at most ``completions + n_threads`` ops are ever issued --
    a consecutive window whose suboperation count bounds the step count.
    """
    window = max_warmup + n_ops + max_threads
    return _bucket(_max_window_subops(trace.bounds, window), _STEP_BUCKET)


# -- the jitted grid ---------------------------------------------------------


def _make_flags(cfg: SimConfig) -> dict:
    """Static specialization flags (Python bools baked into the program)."""
    return dict(
        has_eps=cfg.eps > 0.0,
        has_rho=cfg.rho < 1.0,
        has_jitter=cfg.L_io_jitter > 0.0,
        has_rio=cfg.R_io > 0.0,
        has_bio=cfg.B_io > 0.0,
        has_bmem=cfg.B_mem > 0.0,
        has_lock=cfg.T_lock > 0.0,
        has_degrade=cfg.io_degrade != 1.0,
    )


_RNG_CHUNK = 1024   # steps per generated uniform block (memory/dispatch knob)


def _grid_body(kinds, durs, op_starts, op_ends, n_trace,
               L_mem_g, nthr_g, warm_g, n_ops, dyn, key, stream_ids, arr, *,
               T_max, P, n_ssd, steps, unroll, substeps, use_pallas,
               early_exit, n_cores,
               has_eps, has_rho, has_jitter, has_rio, has_bio, has_bmem,
               has_lock, has_arr=False, has_lat=False, has_deadline=False,
               has_degrade=False):
    """The (unjitted) grid program; ``_run_grid`` jits it, the host-device
    sharding path wraps it in ``shard_map`` over the cell axis first."""
    from repro.kernels import sched_step as sk

    has_io_clock = has_rio or has_bio
    multicore = n_cores > 1
    f = jnp.float64
    i4 = jnp.int32
    G = L_mem_g.shape[0]
    CT = n_cores * T_max    # total thread slots (core-major when C > 1)

    rho, L_dram = dyn[2], dyn[3]

    def lmem(u, L):
        """sample_lmem for scalar latencies: DRAM-tier short-circuit."""
        if has_rho:
            return jnp.where(u >= rho, L_dram, L)
        return L

    # Packed trace columns: one gather serves (kind, dur) / (start, end);
    # op bounds are carried as exact f64 integers so a thread's (i, end)
    # pair packs into a single span scalar (see sched_step.pack_span).
    kd = jnp.stack([kinds.astype(f), durs], axis=1)          # (n_subops, 2)
    se = jnp.stack([op_starts.astype(f), op_ends.astype(f)], axis=1)

    # Uniform draws actually consumed per step, in consumption order (the
    # static flags decide): eps-eviction test + its resample, IO jitter,
    # the prefetch latency sample.  Draws are generated one _RNG_CHUNK of
    # steps at a time and fed to the inner scan as xs, so the step body
    # contains no hashing.
    n_u = 2 * has_eps + has_jitter + has_rho

    # -- per-cell RNG streams ------------------------------------------------
    # Every draw derives from fold_in(key, stream_id) where the stream id
    # hashes the cell's (L_mem, n_threads) identity -- NOT its position or
    # the batch size -- so a cell's numbers are identical whether it runs
    # alone, inside the full grid, as a thread bucket of a wider sweep, or
    # as the cache-miss remainder of a partially memoized sweep (the cell
    # cache requires cell values to be a pure function of their key).
    # Per-thread init draws fold in the thread index individually for the
    # same reason: they must not depend on the batch's T_max padding.
    cell_keys = jax.vmap(jax.random.fold_in, (None, 0))(key, stream_ids)
    k_chunks = jax.vmap(lambda k: jax.random.fold_in(k, 1))(cell_keys)
    tids = jnp.arange(CT, dtype=i4)
    t_local = tids % T_max                 # slot within the owning core
    active = t_local[None, :] < nthr_g[:, None]                # (G, CT)
    u_cursor = jax.vmap(lambda k: jax.random.uniform(
        jax.random.fold_in(k, 0), (), dtype=f))(cell_keys)
    cursor0 = jnp.floor(u_cursor * n_trace).astype(i4)
    # Active threads consume consecutive cursor slots in core-major tid
    # order, like the loops' init (padding slots alias harmlessly: they
    # never execute).
    rank = (tids // T_max)[None, :] * nthr_g[:, None] + t_local[None, :]
    opidx0 = (cursor0[:, None] + rank) % n_trace
    cursor_init = (cursor0 + n_cores * nthr_g) % n_trace
    u_thread = jax.vmap(lambda k: jax.vmap(
        lambda t: jax.random.uniform(jax.random.fold_in(k, 2 + t), (2,),
                                     dtype=f))(tids))(cell_keys)  # (G, CT, 2)
    pf0 = u_thread[:, :, 0] * lmem(u_thread[:, :, 1], L_mem_g[:, None])
    if has_arr:
        # Open loop: thread ``rank`` takes arrival index ``rank`` (the
        # loops' cid-major init order); its first prefetch is anchored at
        # the arrival, and a future arrival parks the thread on the wake
        # plane -- wake keys tie-break toward the lower tid, the loops'
        # heap-push order.  Inactive padding slots read a clamped arrival
        # but never run.
        arr0 = arr[jnp.minimum(rank, arr.shape[0] - 1)]          # (G, CT)
        pf0 = pf0 + arr0
        parked0 = active & (arr0 > 0.0)
    else:
        arr0 = None
        parked0 = jnp.zeros_like(active)

    # Initial state, in the sched_step layout: active threads populate the
    # ready ring in tid order (join stamps sit an EPOCH apart just above
    # time zero -- normal floats, so FTZ cannot collapse them -- and the
    # tag bits carry the tid), parked/inactive slots hold the BIG
    # sentinel / +inf.
    span0 = sk.pack_span(op_starts[opidx0].astype(f),
                         op_ends[opidx0].astype(f))
    tids_gt = jnp.broadcast_to(tids[None, :], (G, CT))
    slots_p = jnp.arange(P, dtype=i4)[None, :]
    pf_shape = (G, n_cores, P) if multicore else (G, P)
    ci_cols = [cursor_init, jnp.zeros(G, i4), jnp.zeros(G, i4),
               jnp.zeros(G, i4), jnp.zeros(G, i4),
               (warm_g <= 0).astype(i4)]
    if has_lat:
        ci_cols.append(jnp.zeros(G, i4))           # missed-op counter
    pft_cols = [pf0, span0]
    if has_lat:
        pft_cols.append(arr0 if has_arr else jnp.zeros((G, CT), f))
    state = (
        jnp.zeros((G, 6), f).at[:, 3].set(-1.0),
        jnp.stack(ci_cols, axis=1),
        jnp.where(active & ~parked0,
                  sk.tag_encode(tids_gt.astype(f) * sk.EPOCH, tids_gt),
                  sk.BIG),
        (jnp.where(parked0, arr0, jnp.inf) if has_arr
         else jnp.full((G, CT), jnp.inf, f)),
        jnp.stack(pft_cols, axis=2),
        jnp.broadcast_to((slots_p.astype(f) * sk.EPOCH)
                         .reshape((1,) * (len(pf_shape) - 1) + (P,)),
                         pf_shape),
    )
    if multicore:
        state = state + (jnp.zeros((G, n_cores, 2), f),)
    if has_io_clock:
        state = state + (jnp.zeros((G, n_ssd), f), jnp.zeros((G, n_ssd), f))
    if has_lat:
        state = state + (jnp.zeros((G, HIST_BINS), f), jnp.zeros((G,), f))

    sub = sk.make_substep(
        n_u=n_u, n_ssd=n_ssd, has_eps=has_eps, has_rho=has_rho,
        has_jitter=has_jitter, has_rio=has_rio, has_bio=has_bio,
        has_bmem=has_bmem, has_lock=has_lock, has_arr=has_arr,
        has_lat=has_lat, has_deadline=has_deadline, has_degrade=has_degrade,
        onehot_updates=use_pallas, eager_wmin=use_pallas, n_cores=n_cores)

    if use_pallas:
        def block(s, ub):
            return sk.fused_steps(sub, s, ub, kd, se, arr, n_trace,
                                  L_mem_g, nthr_g, warm_g, n_ops,
                                  dyn), None
    else:
        def step(s, u):
            return sub(s, u, kd, se, arr, nthr_g, n_trace, L_mem_g,
                       warm_g, n_ops, dyn), None

    def chunk(s, ck):
        if n_u:
            us = jax.vmap(lambda k: jax.random.uniform(
                jax.random.fold_in(k, ck), (_RNG_CHUNK, n_u),
                dtype=f))(k_chunks)              # (G, CH, n_u), per cell
            us = jnp.moveaxis(us, 0, -1)         # (CH, n_u, G)
        else:
            us = jnp.zeros((_RNG_CHUNK, 0, G), f)
        if use_pallas:
            ub = us.reshape(_RNG_CHUNK // substeps, substeps, n_u, G)
            return jax.lax.scan(block, s, ub)
        return jax.lax.scan(step, s, us, unroll=unroll)

    n_chunks = steps // _RNG_CHUNK
    if early_exit:
        # Stop scanning once every cell in the call latched its measured
        # ops: finished cells are inert (counters and t_start/t_end are
        # latched, the state only idles on), so cutting the tail chunks
        # cannot change any result -- it only stops paying for cells that
        # are already done.  The chunk counter ck rides in the carry, so
        # the uniform feed fold_in(k_chunks, ck) is identical to the
        # monolithic scan's; XLA keeps the while carry in donated buffers.
        def w_cond(carry):
            ck, s = carry
            return (ck < n_chunks) & ~jnp.all(s[1][:, 3] >= n_ops)

        def w_body(carry):
            ck, s = carry
            s2, _ = chunk(s, ck)
            return ck + jnp.int32(1), s2

        ck_end, state = jax.lax.while_loop(
            w_cond, w_body, (jnp.int32(0), state))
    else:
        state, _ = jax.lax.scan(
            chunk, state, jnp.arange(n_chunks, dtype=i4))
        ck_end = jnp.int32(n_chunks)
    cf, ci = state[0], state[1]
    elapsed = jnp.maximum(cf[:, 4] - cf[:, 3], 1e-12)
    out = dict(
        throughput=n_ops / elapsed,
        time=elapsed,
        mem_stall_total=cf[:, 5],
        mem_accesses=ci[:, 4],
        counted=ci[:, 3],
        # Per-cell so the host-sharded path can report each shard's own
        # early-exit point (shards stop independently, no collectives).
        steps_run=jnp.broadcast_to(ck_end * _RNG_CHUNK, (G,)),
    )
    if has_lat:
        out["lat_hist"] = state[-2]
        out["lat_max"] = state[-1]
        out["missed"] = ci[:, 6]
    return out


_STATIC_GRID_ARGS = (
    "T_max", "P", "n_ssd", "steps", "unroll", "substeps", "use_pallas",
    "early_exit", "n_cores",
    "has_eps", "has_rho", "has_jitter", "has_rio", "has_bio", "has_bmem",
    "has_lock", "has_arr", "has_lat", "has_deadline", "has_degrade")

_run_grid = partial(jax.jit, static_argnames=_STATIC_GRID_ARGS)(_grid_body)


@lru_cache(maxsize=64)
def _run_grid_sharded(n_dev: int, **static):
    """Jitted ``shard_map`` wrapper of :func:`_grid_body` splitting the cell
    axis over ``n_dev`` host CPU devices (the caller pads G to a multiple).
    Each shard runs -- and early-exits -- independently: there are no
    collectives in the grid program."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    devs = jax.devices("cpu")[:n_dev]
    if hasattr(jax, "make_mesh"):
        mesh = jax.make_mesh((n_dev,), ("cells",), devices=devs)
    else:  # older jax: build the mesh directly
        from jax.sharding import Mesh
        mesh = Mesh(np.asarray(devs), ("cells",))
    cells, repl = P("cells"), P()
    fn = shard_map(
        partial(_grid_body, **static), mesh,
        in_specs=(repl, repl, repl, repl, repl,      # trace columns, n_trace
                  cells, cells, cells,               # L_mem_g, nthr_g, warm_g
                  repl, repl, repl, cells,           # n_ops, dyn, key, streams
                  repl),                             # arrival timestamps
        out_specs=cells,
        # the early-exit while_loop has no replication rule; every output
        # is cell-sharded anyway, so the rep check buys nothing here
        check_rep=False,
    )
    return jax.jit(fn)


def _thread_buckets(candidates: Sequence[int]) -> list[list[int]]:
    """Group candidate indices by the power-of-two ceiling of their thread
    count, so narrow cells never pay a wide cell's ``T_max`` padding (a
    16-thread cell in a 128-wide plane does 8x the per-step plane work it
    needs).  Cells are RNG-pure per (L_mem, n_threads), so bucketing
    cannot change any cell's result."""
    groups: dict[int, list[int]] = {}
    for j, c in enumerate(candidates):
        b = 1 if c <= 1 else 1 << (c - 1).bit_length()
        groups.setdefault(b, []).append(j)
    return [ix for _, ix in sorted(groups.items())]


def _cohorts(source: CompiledTrace, candidates: Sequence[int], n_ops: int,
             warmup_ops: int | None, n_cores: int,
             bucket_threads: bool) -> list[tuple[list[int], int, int]]:
    """Partition candidate columns into scan cohorts: ``(cols, T_max,
    steps)`` groups sharing a thread bucket *and* a step bound.

    The thread buckets are :func:`_thread_buckets`'s power-of-two ceilings;
    within a bucket, candidates whose per-cell worst-case bound lands in a
    different ``_STEP_BUCKET`` split into their own cohort, so a cohort's
    early exit is never held open by a cell with a structurally larger
    bound (uneven warmups are the common case: warmup defaults to
    ``2 * threads * cores``).  Per-cell RNG purity makes any partition
    result-invariant; ``bucket_threads=False`` collapses everything into
    the single monolithic scan (one ``T_max``, one global bound)."""
    if not bucket_threads:
        T_max = max(candidates)
        warm = (warmup_ops if warmup_ops is not None
                else 2 * T_max * n_cores)
        steps = _steps_bound(source, n_ops, warm, T_max * n_cores)
        return [(list(range(len(candidates))), T_max, steps)]
    groups: dict[tuple[int, int], list[int]] = {}
    for j, c in enumerate(candidates):
        b = 1 if c <= 1 else 1 << (c - 1).bit_length()
        warm = warmup_ops if warmup_ops is not None else 2 * c * n_cores
        steps = _steps_bound(source, n_ops, warm, c * n_cores)
        groups.setdefault((b, steps), []).append(j)
    return [(ix, max(candidates[j] for j in ix), steps)
            for (_, steps), ix in sorted(groups.items())]


def sweep_grid(
    cfg: SimConfig,
    trace: CompiledTrace | TraceArrays,
    latencies: Sequence[float],
    thread_candidates: Sequence[int],
    n_ops: int = 5000,
    warmup_ops: int | None = None,
    *,
    use_pallas: bool = False,
    unroll: int = 2,
    substeps: int = 8,
    bucket_threads: bool = True,
    early_exit: bool = True,
    host_devices: int | None = None,
    arrivals: Sequence[float] | None = None,
    collect_percentiles: bool = False,
    deadline: float = 0.0,
) -> GridResult:
    """Run the full ``latencies x thread_candidates`` grid in one compiled
    call per cohort; see the module docstring for semantics and exactness.

    ``cfg`` supplies everything except ``L_mem``/``n_threads`` (the grid
    axes); ``n_threads`` is *per core*, and ``cfg.n_cores > 1`` replays
    the multi-core scheduler (per-core rings + prefetch windows, shared
    T_lock / SSD clocks) as long as ``n_cores * T_max`` fits the tag bits.
    Scalar latencies only; ``warmup_ops`` defaults per cell to
    ``2 * n_threads * n_cores``, like the loop backends.

    ``use_pallas`` routes the scan through the fused whole-step kernel
    (``substeps`` inner steps per kernel invocation); the default jnp scan
    path uses ``unroll`` to amortize dispatch instead.
    ``bucket_threads=False`` forces the single monolithic layout (all
    candidates padded to one ``T_max``, one global step bound);
    ``early_exit=False`` additionally scans every cohort to its full
    bound -- together they reproduce the pre-cohort behavior exactly
    (per-cell RNG purity makes all four combinations bit-identical).

    ``host_devices=N > 1`` shard_maps each cohort's cell axis over N XLA
    host CPU devices (export ``REPRO_JAX_HOST_DEVICES=N`` -- or set
    ``--xla_force_host_platform_device_count`` -- *before* jax
    initializes); shards early-exit independently.  Incompatible with
    ``use_pallas`` (the interpreted kernel cannot run under shard_map).

    ``arrivals`` (a monotone timestamp sequence, seconds -- see
    :func:`repro.core.sim.arrivals.generate_arrivals`) switches every
    cell to the open-loop driver: the SAME array drives all cells (each
    consumes its own prefix), so it must cover the worst cell's demand
    ``n_cores * n_threads + warmup + n_ops``.  ``collect_percentiles``
    accumulates measured sojourns into a per-cell log-histogram (error
    bound ``arrivals.HIST_REL_ERROR`` per percentile; the max is exact)
    and fills the ``GridResult`` tail planes; ``deadline`` (seconds,
    0 = off) counts sojourns above it as missed instead of recording
    them.
    """
    if cfg.n_cores < 1:
        raise ValueError(f"n_cores must be >= 1, got {cfg.n_cores}")
    if cfg.collect_load_hist:
        raise ValueError(
            "per-load stall histograms are not available from the jax "
            "backend; use backend='loop'")
    if cfg.n_ssd < 1:
        raise ValueError(f"n_ssd must be >= 1, got {cfg.n_ssd}")
    latencies = list(latencies)
    candidates = [int(n) for n in thread_candidates]
    if not latencies or not candidates:
        raise ValueError("empty sweep grid")
    if not all(isinstance(L, numbers.Real) for L in latencies):
        raise ValueError(
            "the jax backend replays scalar latencies only; "
            "sweep_latency(backend='jax') routes mixture points through "
            "the loop backend")
    if min(candidates) < 1:
        raise ValueError(f"thread candidates must be >= 1: {candidates}")
    if substeps < 1 or _RNG_CHUNK % substeps:
        raise ValueError(
            f"substeps must divide the RNG chunk ({_RNG_CHUNK}): "
            f"{substeps}")

    from repro.kernels.sched_step import SPAN_SHIFT, TAG_BITS

    source = trace if isinstance(trace, CompiledTrace) else trace.to_trace()
    ta = trace if isinstance(trace, TraceArrays) else lower_trace(trace)
    if int(ta.op_ends[-1]) >= (1 << SPAN_SHIFT):
        raise ValueError(
            f"trace has {int(ta.op_ends[-1])} suboperations; the fused "
            f"step's span packing supports < 2**{SPAN_SHIFT}")
    n_lat, n_cand = len(latencies), len(candidates)
    if cfg.n_cores * max(candidates) > (1 << TAG_BITS):
        raise ValueError(
            f"n_cores * max threads = {cfg.n_cores * max(candidates)} "
            f"exceeds the {1 << TAG_BITS} thread slots the tag encoding "
            f"supports (TAG_BITS={TAG_BITS}); use backend='loop' for "
            "wider machines")
    n_dev = 1 if host_devices is None else int(host_devices)
    if n_dev < 1:
        raise ValueError(f"host_devices must be >= 1, got {host_devices}")
    if n_dev > 1:
        if use_pallas:
            raise ValueError(
                "host_devices > 1 cannot run the interpreted Pallas "
                "kernel under shard_map; drop use_pallas or the sharding")
        avail = len(jax.devices("cpu"))
        if n_dev > avail:
            raise ValueError(
                f"host_devices={n_dev} but jax sees {avail} host CPU "
                "device(s); export REPRO_JAX_HOST_DEVICES (or set "
                "--xla_force_host_platform_device_count) before jax "
                "initializes")

    has_arr = arrivals is not None
    has_lat = bool(collect_percentiles)
    has_deadline = has_lat and deadline > 0.0
    if deadline < 0.0:
        raise ValueError(f"deadline must be >= 0, got {deadline}")
    arr_np = np.zeros(1, dtype=np.float64)
    if has_arr:
        arr_np = np.asarray(arrivals, dtype=np.float64)
        if arr_np.ndim != 1 or arr_np.size == 0:
            raise ValueError("arrivals must be a non-empty 1-D sequence")
        need = max(
            cfg.n_cores * c
            + (warmup_ops if warmup_ops is not None else 2 * c * cfg.n_cores)
            + n_ops
            for c in candidates)
        if arr_np.size < need:
            raise ValueError(
                f"arrivals has {arr_np.size} timestamps but the widest "
                f"cell consumes up to {need} "
                "(n_cores * n_threads + warmup + n_ops)")

    dyn = (
        cfg.T_sw, cfg.eps, cfg.rho, cfg.L_dram, cfg.L_io, cfg.L_io_jitter,
        1.0 / cfg.R_io if cfg.R_io > 0.0 else 0.0,
        cfg.A_io / cfg.B_io if cfg.B_io > 0.0 else 0.0,
        cfg.L_switch,
        cfg.A_mem / cfg.B_mem if cfg.B_mem > 0.0 else 0.0,
        cfg.T_lock,
        deadline,
        cfg.T_degrade,
        cfg.io_degrade,
    )
    cohorts = _cohorts(source, candidates, n_ops, warmup_ops, cfg.n_cores,
                       bucket_threads)

    shape = (n_lat, n_cand)
    thr = np.empty(shape)
    tim = np.empty(shape)
    stall = np.empty(shape)
    macc = np.empty(shape, dtype=np.int64)
    if has_lat:
        p50 = np.empty(shape)
        p90 = np.empty(shape)
        p99 = np.empty(shape)
        lmax = np.empty(shape)
        lcount = np.empty(shape, dtype=np.int64)
        lmiss = np.empty(shape, dtype=np.int64)
        lhist = np.empty(shape + (HIST_BINS,), dtype=np.int64)
    max_steps = 0
    steps_bound_cells = 0
    steps_run_cells = 0
    with enable_x64():
        for cols, T_max, steps in cohorts:
            cand_b = [candidates[j] for j in cols]
            nc = len(cand_b)
            G = n_lat * nc
            L_mem_g = np.repeat(np.asarray(latencies, dtype=np.float64), nc)
            nthr_g = np.tile(np.asarray(cand_b, dtype=np.int32), n_lat)
            warm_g = (np.full_like(nthr_g, warmup_ops)
                      if warmup_ops is not None
                      else 2 * nthr_g * cfg.n_cores)
            max_steps = max(max_steps, steps)

            # Each cell's RNG stream is keyed by its (L_mem, n_threads)
            # VALUES, so a cell's result never depends on which other
            # cells -- or cohorts -- share the call (cache purity; see the
            # per-cell RNG comment in _grid_body).
            stream_ids = np.array(
                [zlib.crc32(struct.pack("<dq", L, n))
                 for L in np.asarray(latencies, dtype=np.float64)
                 for n in cand_b],
                dtype=np.uint32,
            )
            pad = (-G) % n_dev
            if pad:
                # Pad the cell axis to the device count by repeating the
                # last cell: same stream id -> identical results, sliced
                # off below.
                L_mem_g, nthr_g, warm_g, stream_ids = (
                    np.concatenate([a, np.repeat(a[-1:], pad, axis=0)])
                    for a in (L_mem_g, nthr_g, warm_g, stream_ids))
            static = dict(
                T_max=T_max, P=cfg.P, n_ssd=cfg.n_ssd, steps=steps,
                unroll=unroll, substeps=substeps if use_pallas else 0,
                use_pallas=use_pallas, early_exit=early_exit,
                n_cores=cfg.n_cores, has_arr=has_arr, has_lat=has_lat,
                has_deadline=has_deadline, **_make_flags(cfg),
            )
            run = (_run_grid_sharded(n_dev, **static) if n_dev > 1
                   else partial(_run_grid, **static))
            out = run(
                ta.kinds, ta.durs, ta.op_starts, ta.op_ends,
                jnp.int32(ta.n_ops),
                jnp.asarray(L_mem_g), jnp.asarray(nthr_g),
                jnp.asarray(warm_g),
                jnp.float64(n_ops),
                tuple(jnp.float64(d) for d in dyn),
                jax.random.PRNGKey(cfg.seed),
                jnp.asarray(stream_ids),
                jnp.asarray(arr_np),
            )
            out = {k: np.asarray(v)[:G] for k, v in out.items()}
            if not np.all(out["counted"] >= n_ops):
                short = int(out["counted"].min())
                raise RuntimeError(
                    f"jax replay under-ran its step bound ({steps} steps, "
                    f"worst cell counted {short}/{n_ops} ops) -- this is "
                    "a bug in _steps_bound")
            steps_bound_cells += steps * G
            steps_run_cells += int(out["steps_run"].sum())
            bshape = (n_lat, nc)
            thr[:, cols] = out["throughput"].reshape(bshape)
            tim[:, cols] = out["time"].reshape(bshape)
            stall[:, cols] = out["mem_stall_total"].reshape(bshape)
            macc[:, cols] = out["mem_accesses"].reshape(bshape)
            if has_lat:
                # Host-side percentile reduction, vectorized over cells:
                # nearest-rank on the cumulative counts, exactly
                # arrivals.summarize_hist per row.
                cum = np.cumsum(out["lat_hist"], axis=1)
                total = np.rint(cum[:, -1]).astype(np.int64)
                empty = total == 0
                for q, dest in ((0.5, p50), (0.9, p90), (0.99, p99)):
                    rank = np.ceil(q * np.maximum(total, 1))
                    b = np.minimum((cum < rank[:, None]).sum(axis=1),
                                   HIST_BINS - 1)
                    dest[:, cols] = np.where(
                        empty, np.nan, hist_bin_value(b)).reshape(bshape)
                lmax[:, cols] = np.where(
                    empty, np.nan, out["lat_max"]).reshape(bshape)
                lcount[:, cols] = total.reshape(bshape)
                lmiss[:, cols] = out["missed"].astype(
                    np.int64).reshape(bshape)
                lhist[:, cols, :] = np.rint(out["lat_hist"]).astype(
                    np.int64).reshape(bshape + (HIST_BINS,))
    return GridResult(
        throughput=thr,
        time=tim,
        mem_stall_total=stall,
        mem_accesses=macc,
        ops=n_ops,
        steps=max_steps,
        cell_steps_bound=steps_bound_cells,
        cell_steps_run=steps_run_cells,
        p50=p50 if has_lat else None,
        p90=p90 if has_lat else None,
        p99=p99 if has_lat else None,
        lat_max=lmax if has_lat else None,
        lat_count=lcount if has_lat else None,
        missed=lmiss if has_lat else None,
        lat_hist=lhist if has_lat else None,
    )
