"""Vectorized JAX replay: the whole latency x threads grid as one jitted call.

The loop backends (:mod:`.engine_loop`) re-run an interpreter per grid cell;
this module instead lowers the columnar :class:`~repro.core.trace_ir.
CompiledTrace` into device arrays **once** (:class:`TraceArrays`), expresses
one cell's scheduler recurrence as a ``jax.lax.scan`` over suboperation
executions, and batches that scan across every ``(L_mem, n_threads)`` cell
of a sweep, so an entire Fig. 9-style grid is a single compiled XLA program
(:func:`sweep_grid`).

The recurrence
--------------
One scan step executes exactly one suboperation of one thread in every grid
cell.  The step body itself lives in :mod:`repro.kernels.sched_step` (the
fused whole-step scheduler kernel; see that module for the state layout):

  * thread selection: ready threads carry a monotone FIFO *stamp* with
    their thread id packed into the low mantissa bits, so a single ``min``
    reduction pops the ring head -- no ``argmin`` anywhere in the step;
  * wake drain: every parked thread whose IO completed re-joins the back
    of the ring in wake order in one masked pass -- the *exact* drain the
    loop backends perform, not a bounded-per-step approximation -- and
    the clock idle-skips to the earliest wake-up when nothing is
    runnable;
  * MEM stalls against the thread's outstanding prefetch (or a resampled
    latency on an eps-eviction), PREIO submits to the per-device token
    clocks (round-robin striping, jitter, switch hop), op completion pays
    ``T_lock``, and the next suboperation's prefetch is issued against the
    P-deep in-flight window -- all the device arithmetic of
    :mod:`.devices`, expressed on ``(n_cells, ...)`` arrays.

Cells that complete their measured ops latch their measurement (the
counters stop; the simulation harmlessly idles on) while the scan drains
the slower cells; the scan length is a worst-case bound computed from the
trace's op-length prefix sums, so no cell can run out of steps.  Grids
whose thread candidates span a wide range are split into power-of-two
thread *buckets* so small-thread cells do not pay the widest cell's
``T_max`` padding (per-cell RNG purity makes the split invisible to
results).

Exactness
---------
Scheduling, device arithmetic, and draw *distributions* match the loop
backends; the RNG streams do not (``jax.random`` threefry vs. the stdlib
Mersenne twister), and simultaneous-ready ties can resolve in a different
order.  Per-cell throughput therefore agrees with the loop backends to
sampling noise rather than bit-identically: ~0.5% typical (tails ~1.5%)
at the default ``n_ops=5000``, shrinking as ``1/sqrt(n_ops)`` -- the 1%
per-cell bound on the paper's default grid is enforced at
``n_ops=20_000`` by ``tests/test_replay_jax.py``.  Scalar
latencies and single-core configs only; ``sweep_latency(backend="jax")``
routes mixture latencies through the loop backend per-cell.

``use_pallas=True`` runs the scan through the fused Pallas kernel
(:func:`repro.kernels.sched_step.fused_steps`): the scheduler planes stay
resident in VMEM across ``substeps`` inner steps per kernel invocation.
On TPU that is the compiled fast path; on CPU it runs in interpreter mode,
which is far too slow for real sweeps but lets CI validate the kernel
bit-for-bit against the pure-jnp scan on tiny grids.

Everything here is computed in float64 (``jax.experimental.enable_x64``):
the state mixes ~second-scale clocks with 50 ns context switches, which
float32 cannot carry.  Perf runs on CPU should additionally export
``REPRO_JAX_LEGACY_CPU=1`` before jax initializes (the benchmark entry
points do) -- XLA's legacy inline runtime executes this scan ~2-5x
faster per step than the thunk runtime; see ``_XLA_CPU_FLAGS`` below for
why it is opt-in rather than the default.
"""
from __future__ import annotations

import numbers
import os
import struct
import zlib
from dataclasses import dataclass
from functools import partial
from typing import Sequence

import numpy as np

# Opt-in fast path for perf runs: XLA's legacy inline CPU runtime
# executes this module's scan body ~2-5x faster per op than the thunk
# runtime that became the default in jax 0.4.32 (command-buffer dispatch
# overhead on many small fused ops).  It is NOT enabled by default --
# XLA flags are process-global, the legacy runtime flushes denormals
# (FTZ/DAZ), and this library must not change numerics for every other
# jax user in the process.  Perf entry points (benchmarks/jax_grid_bench
# and ``benchmarks.run --backend jax``) export REPRO_JAX_LEGACY_CPU=1
# before jax initializes its CPU client; the sim itself is runtime-
# agnostic (its only sub-normal-magnitude values, the EPOCH ring
# tickets, are deliberately normal floats).
_XLA_CPU_FLAGS = "--xla_cpu_use_thunk_runtime=false"
if os.environ.get("REPRO_JAX_LEGACY_CPU"):
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_cpu_use_thunk_runtime" not in _flags:
        os.environ["XLA_FLAGS"] = (_flags + " " + _XLA_CPU_FLAGS).strip()

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..trace_ir import CPU, CompiledTrace
from .config import SimConfig, SimResult

__all__ = ["TraceArrays", "GridResult", "sweep_grid", "lower_trace"]

_STEP_BUCKET = 4096     # scan lengths round up to this (compile-cache reuse)
_PAD_SENTINEL = CPU     # padded suboperations are inert plain-CPU entries


def _bucket(n: int, bucket: int) -> int:
    return max(bucket, -(-n // bucket) * bucket)


@dataclass(frozen=True)
class TraceArrays:
    """A :class:`CompiledTrace` lowered to device arrays, shape-padded.

    ``kinds``/``durs`` are the flat suboperation columns; ``op_starts`` /
    ``op_ends`` are the per-op slice bounds (``bounds[:-1]``/``bounds[1:]``
    of the source trace).  Arrays are padded up to power-of-two-ish buckets
    so traces of similar size share one compiled sweep program; ``n_ops`` /
    ``n_subops`` are the true (pre-padding) counts, and the replay indexes
    ops modulo ``n_ops`` so padding is never executed.  ``to_trace``
    reconstructs the source trace losslessly (``tests/test_replay_jax.py``
    proves the round-trip for every registered engine).
    """

    kinds: jax.Array      # int32 (n_subops_padded,)
    durs: jax.Array       # float64 (n_subops_padded,)
    op_starts: jax.Array  # int32 (n_ops_padded,)
    op_ends: jax.Array    # int32 (n_ops_padded,)
    n_ops: int
    n_subops: int

    @classmethod
    def from_trace(cls, trace: CompiledTrace,
                   bucket: int = 1024) -> "TraceArrays":
        n_ops, n_subops = trace.n_ops, trace.n_subops
        kinds = np.full(_bucket(n_subops, bucket), _PAD_SENTINEL,
                        dtype=np.int32)
        kinds[:n_subops] = trace.kinds
        durs = np.zeros(len(kinds), dtype=np.float64)
        durs[:n_subops] = trace.durs
        n_ops_pad = _bucket(n_ops, bucket)
        starts = np.empty(n_ops_pad, dtype=np.int32)
        ends = np.empty(n_ops_pad, dtype=np.int32)
        starts[:n_ops] = trace.bounds[:-1]
        ends[:n_ops] = trace.bounds[1:]
        starts[n_ops:] = trace.bounds[-2]    # replicate the last op; the
        ends[n_ops:] = trace.bounds[-1]      # replay never reads past n_ops
        with enable_x64():
            return cls(jnp.asarray(kinds), jnp.asarray(durs),
                       jnp.asarray(starts), jnp.asarray(ends),
                       n_ops, n_subops)

    def to_trace(self) -> CompiledTrace:
        """Decode back to the exact source :class:`CompiledTrace`."""
        starts = np.asarray(self.op_starts)[: self.n_ops]
        ends = np.asarray(self.op_ends)[: self.n_ops]
        bounds = np.concatenate([starts, ends[-1:]]).astype(np.int64)
        return CompiledTrace.from_columns(
            np.asarray(self.kinds)[: self.n_subops].astype(np.int8),
            np.asarray(self.durs)[: self.n_subops],
            bounds,
        )


def lower_trace(trace: CompiledTrace, bucket: int = 1024) -> TraceArrays:
    """Functional alias for :meth:`TraceArrays.from_trace`."""
    return TraceArrays.from_trace(trace, bucket)


@dataclass(frozen=True)
class GridResult:
    """Per-cell sweep results, shaped ``(n_latencies, n_candidates)``."""

    throughput: np.ndarray
    time: np.ndarray
    mem_stall_total: np.ndarray
    mem_accesses: np.ndarray
    ops: int                      # measured ops per cell (same for all)
    steps: int                    # scan length (max across thread buckets)

    def result(self, li: int, ci: int) -> SimResult:
        """One cell as a :class:`SimResult` (no per-op latency columns --
        use the loop backends for those)."""
        return SimResult(
            ops=self.ops,
            time=float(self.time[li, ci]),
            throughput=float(self.throughput[li, ci]),
            mem_stall_total=float(self.mem_stall_total[li, ci]),
            mem_accesses=int(self.mem_accesses[li, ci]),
        )


def _max_window_subops(bounds: np.ndarray, n_window_ops: int) -> int:
    """Worst-case suboperation count of ``n_window_ops`` consecutive ops of
    the cyclic trace, over all start offsets (exact, via prefix sums)."""
    lens = np.diff(bounds)
    n = len(lens)
    total = int(lens.sum())
    cycles, rem = divmod(n_window_ops, n)
    worst_rem = 0
    if rem:
        cs = np.concatenate([[0], np.cumsum(np.concatenate([lens, lens]))])
        worst_rem = int((cs[rem: rem + n] - cs[:n]).max())
    return cycles * total + worst_rem


def _steps_bound(trace: CompiledTrace, n_ops: int, max_warmup: int,
                 max_threads: int) -> int:
    """Scan length guaranteeing every cell completes its measured ops.

    A cell terminates once ``warmup + n_ops - 1`` ops have completed; every
    executed suboperation belongs to an op issued from the shared cyclic
    cursor, and at most ``completions + n_threads`` ops are ever issued --
    a consecutive window whose suboperation count bounds the step count.
    """
    window = max_warmup + n_ops + max_threads
    return _bucket(_max_window_subops(trace.bounds, window), _STEP_BUCKET)


# -- the jitted grid ---------------------------------------------------------


def _make_flags(cfg: SimConfig) -> dict:
    """Static specialization flags (Python bools baked into the program)."""
    return dict(
        has_eps=cfg.eps > 0.0,
        has_rho=cfg.rho < 1.0,
        has_jitter=cfg.L_io_jitter > 0.0,
        has_rio=cfg.R_io > 0.0,
        has_bio=cfg.B_io > 0.0,
        has_bmem=cfg.B_mem > 0.0,
        has_lock=cfg.T_lock > 0.0,
    )


_RNG_CHUNK = 1024   # steps per generated uniform block (memory/dispatch knob)


@partial(jax.jit, static_argnames=(
    "T_max", "P", "n_ssd", "steps", "unroll", "substeps", "use_pallas",
    "has_eps", "has_rho", "has_jitter", "has_rio", "has_bio", "has_bmem",
    "has_lock"))
def _run_grid(kinds, durs, op_starts, op_ends, n_trace,
              L_mem_g, nthr_g, warm_g, n_ops, dyn, key, stream_ids, *,
              T_max, P, n_ssd, steps, unroll, substeps, use_pallas,
              has_eps, has_rho, has_jitter, has_rio, has_bio, has_bmem,
              has_lock):
    from repro.kernels import sched_step as sk

    has_io_clock = has_rio or has_bio
    f = jnp.float64
    i4 = jnp.int32
    G = L_mem_g.shape[0]

    rho, L_dram = dyn[2], dyn[3]

    def lmem(u, L):
        """sample_lmem for scalar latencies: DRAM-tier short-circuit."""
        if has_rho:
            return jnp.where(u >= rho, L_dram, L)
        return L

    # Packed trace columns: one gather serves (kind, dur) / (start, end);
    # op bounds are carried as exact f64 integers so a thread's (i, end)
    # pair packs into a single span scalar (see sched_step.pack_span).
    kd = jnp.stack([kinds.astype(f), durs], axis=1)          # (n_subops, 2)
    se = jnp.stack([op_starts.astype(f), op_ends.astype(f)], axis=1)

    # Uniform draws actually consumed per step, in consumption order (the
    # static flags decide): eps-eviction test + its resample, IO jitter,
    # the prefetch latency sample.  Draws are generated one _RNG_CHUNK of
    # steps at a time and fed to the inner scan as xs, so the step body
    # contains no hashing.
    n_u = 2 * has_eps + has_jitter + has_rho

    # -- per-cell RNG streams ------------------------------------------------
    # Every draw derives from fold_in(key, stream_id) where the stream id
    # hashes the cell's (L_mem, n_threads) identity -- NOT its position or
    # the batch size -- so a cell's numbers are identical whether it runs
    # alone, inside the full grid, as a thread bucket of a wider sweep, or
    # as the cache-miss remainder of a partially memoized sweep (the cell
    # cache requires cell values to be a pure function of their key).
    # Per-thread init draws fold in the thread index individually for the
    # same reason: they must not depend on the batch's T_max padding.
    cell_keys = jax.vmap(jax.random.fold_in, (None, 0))(key, stream_ids)
    k_chunks = jax.vmap(lambda k: jax.random.fold_in(k, 1))(cell_keys)
    tids = jnp.arange(T_max, dtype=i4)
    active = tids[None, :] < nthr_g[:, None]                       # (G, T)
    u_cursor = jax.vmap(lambda k: jax.random.uniform(
        jax.random.fold_in(k, 0), (), dtype=f))(cell_keys)
    cursor0 = jnp.floor(u_cursor * n_trace).astype(i4)
    opidx0 = (cursor0[:, None] + tids[None, :]) % n_trace
    cursor_init = (cursor0 + nthr_g) % n_trace
    u_thread = jax.vmap(lambda k: jax.vmap(
        lambda t: jax.random.uniform(jax.random.fold_in(k, 2 + t), (2,),
                                     dtype=f))(tids))(cell_keys)  # (G, T, 2)
    pf0 = u_thread[:, :, 0] * lmem(u_thread[:, :, 1], L_mem_g[:, None])

    # Initial state, in the sched_step layout: active threads populate the
    # ready ring in tid order (join stamps sit an EPOCH apart just above
    # time zero -- normal floats, so FTZ cannot collapse them -- and the
    # tag bits carry the tid), parked/inactive slots hold the BIG
    # sentinel / +inf.
    span0 = sk.pack_span(op_starts[opidx0].astype(f),
                         op_ends[opidx0].astype(f))
    tids_gt = jnp.broadcast_to(tids[None, :], (G, T_max))
    slots_p = jnp.arange(P, dtype=i4)[None, :]
    state = (
        jnp.zeros((G, 6), f).at[:, 3].set(-1.0),
        jnp.stack(
            [cursor_init, jnp.zeros(G, i4), jnp.zeros(G, i4),
             jnp.zeros(G, i4), jnp.zeros(G, i4),
             (warm_g <= 0).astype(i4)], axis=1),
        jnp.where(active,
                  sk.tag_encode(tids_gt.astype(f) * sk.EPOCH, tids_gt),
                  sk.BIG),
        jnp.full((G, T_max), jnp.inf, f),
        jnp.stack([pf0, span0], axis=2),
        sk.tag_encode(jnp.broadcast_to(slots_p.astype(f) * sk.EPOCH, (G, P)),
                      jnp.broadcast_to(slots_p, (G, P))),
    )
    if has_io_clock:
        state = state + (jnp.zeros((G, n_ssd), f), jnp.zeros((G, n_ssd), f))

    sub = sk.make_substep(
        n_u=n_u, n_ssd=n_ssd, has_eps=has_eps, has_rho=has_rho,
        has_jitter=has_jitter, has_rio=has_rio, has_bio=has_bio,
        has_bmem=has_bmem, has_lock=has_lock,
        onehot_updates=use_pallas, eager_wmin=use_pallas)

    if use_pallas:
        def block(s, ub):
            return sk.fused_steps(sub, s, ub, kd, se, n_trace, L_mem_g,
                                  warm_g, n_ops, dyn), None
    else:
        def step(s, u):
            return sub(s, u, kd, se, n_trace, L_mem_g, warm_g, n_ops,
                       dyn), None

    def chunk(s, ck):
        if n_u:
            us = jax.vmap(lambda k: jax.random.uniform(
                jax.random.fold_in(k, ck), (_RNG_CHUNK, n_u),
                dtype=f))(k_chunks)              # (G, CH, n_u), per cell
            us = jnp.moveaxis(us, 0, -1)         # (CH, n_u, G)
        else:
            us = jnp.zeros((_RNG_CHUNK, 0, G), f)
        if use_pallas:
            ub = us.reshape(_RNG_CHUNK // substeps, substeps, n_u, G)
            return jax.lax.scan(block, s, ub)
        return jax.lax.scan(step, s, us, unroll=unroll)

    state, _ = jax.lax.scan(
        chunk, state, jnp.arange(steps // _RNG_CHUNK, dtype=i4))
    cf, ci = state[0], state[1]
    elapsed = jnp.maximum(cf[:, 4] - cf[:, 3], 1e-12)
    return dict(
        throughput=n_ops / elapsed,
        time=elapsed,
        mem_stall_total=cf[:, 5],
        mem_accesses=ci[:, 4],
        counted=ci[:, 3],
    )


def _thread_buckets(candidates: Sequence[int]) -> list[list[int]]:
    """Group candidate indices by the power-of-two ceiling of their thread
    count, so narrow cells never pay a wide cell's ``T_max`` padding (a
    16-thread cell in a 128-wide plane does 8x the per-step plane work it
    needs).  Cells are RNG-pure per (L_mem, n_threads), so bucketing
    cannot change any cell's result."""
    groups: dict[int, list[int]] = {}
    for j, c in enumerate(candidates):
        b = 1 if c <= 1 else 1 << (c - 1).bit_length()
        groups.setdefault(b, []).append(j)
    return [ix for _, ix in sorted(groups.items())]


def sweep_grid(
    cfg: SimConfig,
    trace: CompiledTrace | TraceArrays,
    latencies: Sequence[float],
    thread_candidates: Sequence[int],
    n_ops: int = 5000,
    warmup_ops: int | None = None,
    *,
    use_pallas: bool = False,
    unroll: int = 2,
    substeps: int = 8,
    bucket_threads: bool = True,
) -> GridResult:
    """Run the full ``latencies x thread_candidates`` grid in one compiled
    call per thread bucket; see the module docstring for semantics and
    exactness.

    ``cfg`` supplies everything except ``L_mem``/``n_threads`` (the grid
    axes).  Scalar latencies and single-core configs only; ``warmup_ops``
    defaults per cell to ``2 * n_threads``, like the loop backends.

    ``use_pallas`` routes the scan through the fused whole-step kernel
    (``substeps`` inner steps per kernel invocation); the default jnp scan
    path uses ``unroll`` to amortize dispatch instead.
    ``bucket_threads=False`` forces the single-call layout (all candidates
    padded to one ``T_max``).
    """
    if cfg.n_cores != 1:
        raise ValueError(
            "the jax backend replays single-core configs only; use "
            "backend='loop' for n_cores > 1")
    if cfg.collect_load_hist:
        raise ValueError(
            "per-load stall histograms are not available from the jax "
            "backend; use backend='loop'")
    if cfg.n_ssd < 1:
        raise ValueError(f"n_ssd must be >= 1, got {cfg.n_ssd}")
    latencies = list(latencies)
    candidates = [int(n) for n in thread_candidates]
    if not latencies or not candidates:
        raise ValueError("empty sweep grid")
    if not all(isinstance(L, numbers.Real) for L in latencies):
        raise ValueError(
            "the jax backend replays scalar latencies only; "
            "sweep_latency(backend='jax') routes mixture points through "
            "the loop backend")
    if min(candidates) < 1:
        raise ValueError(f"thread candidates must be >= 1: {candidates}")
    if substeps < 1 or _RNG_CHUNK % substeps:
        raise ValueError(
            f"substeps must divide the RNG chunk ({_RNG_CHUNK}): "
            f"{substeps}")

    from repro.kernels.sched_step import SPAN_SHIFT

    source = trace if isinstance(trace, CompiledTrace) else trace.to_trace()
    ta = trace if isinstance(trace, TraceArrays) else lower_trace(trace)
    if int(ta.op_ends[-1]) >= (1 << SPAN_SHIFT):
        raise ValueError(
            f"trace has {int(ta.op_ends[-1])} suboperations; the fused "
            f"step's span packing supports < 2**{SPAN_SHIFT}")
    n_lat, n_cand = len(latencies), len(candidates)

    dyn = (
        cfg.T_sw, cfg.eps, cfg.rho, cfg.L_dram, cfg.L_io, cfg.L_io_jitter,
        1.0 / cfg.R_io if cfg.R_io > 0.0 else 0.0,
        cfg.A_io / cfg.B_io if cfg.B_io > 0.0 else 0.0,
        cfg.L_switch,
        cfg.A_mem / cfg.B_mem if cfg.B_mem > 0.0 else 0.0,
        cfg.T_lock,
    )
    buckets = (_thread_buckets(candidates) if bucket_threads
               else [list(range(n_cand))])

    shape = (n_lat, n_cand)
    thr = np.empty(shape)
    tim = np.empty(shape)
    stall = np.empty(shape)
    macc = np.empty(shape, dtype=np.int64)
    max_steps = 0
    with enable_x64():
        for cols in buckets:
            cand_b = [candidates[j] for j in cols]
            T_max = max(cand_b)
            nc = len(cand_b)
            L_mem_g = np.repeat(np.asarray(latencies, dtype=np.float64), nc)
            nthr_g = np.tile(np.asarray(cand_b, dtype=np.int32), n_lat)
            warm_g = (np.full_like(nthr_g, warmup_ops)
                      if warmup_ops is not None else 2 * nthr_g)
            steps = _steps_bound(source, n_ops, int(warm_g.max()), T_max)
            max_steps = max(max_steps, steps)

            # Each cell's RNG stream is keyed by its (L_mem, n_threads)
            # VALUES, so a cell's result never depends on which other
            # cells -- or buckets -- share the call (cache purity; see the
            # per-cell RNG comment in _run_grid).
            stream_ids = np.array(
                [zlib.crc32(struct.pack("<dq", L, n))
                 for L in np.asarray(latencies, dtype=np.float64)
                 for n in cand_b],
                dtype=np.uint32,
            )
            out = _run_grid(
                ta.kinds, ta.durs, ta.op_starts, ta.op_ends,
                jnp.int32(ta.n_ops),
                jnp.asarray(L_mem_g), jnp.asarray(nthr_g),
                jnp.asarray(warm_g),
                jnp.float64(n_ops),
                tuple(jnp.float64(d) for d in dyn),
                jax.random.PRNGKey(cfg.seed),
                jnp.asarray(stream_ids),
                T_max=T_max, P=cfg.P, n_ssd=cfg.n_ssd, steps=steps,
                unroll=unroll, substeps=substeps if use_pallas else 0,
                use_pallas=use_pallas, **_make_flags(cfg),
            )
            out = {k: np.asarray(v) for k, v in out.items()}
            if not np.all(out["counted"] >= n_ops):
                short = int(out["counted"].min())
                raise RuntimeError(
                    f"jax replay under-ran its step bound ({steps} steps, "
                    f"worst cell counted {short}/{n_ops} ops) -- this is "
                    "a bug in _steps_bound")
            bshape = (n_lat, nc)
            thr[:, cols] = out["throughput"].reshape(bshape)
            tim[:, cols] = out["time"].reshape(bshape)
            stall[:, cols] = out["mem_stall_total"].reshape(bshape)
            macc[:, cols] = out["mem_accesses"].reshape(bshape)
    return GridResult(
        throughput=thr,
        time=tim,
        mem_stall_total=stall,
        mem_accesses=macc,
        ops=n_ops,
        steps=max_steps,
    )
