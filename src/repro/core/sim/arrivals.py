"""Open-loop arrival processes and the tail-latency accumulator.

The paper's Eq. 14 story is a *closed-loop* mean: every thread always has
an op in hand, so the model never sees queueing delay.  Production KV
services are judged open loop -- requests arrive on their own clock
(Poisson, bursty, diurnal), and the binding metric is P99 *sojourn* time
(arrival -> completion), not mean service time.  This module provides:

* :class:`ArrivalSpec` -- a frozen, serializable description of an arrival
  process (``poisson`` | ``bursty`` | ``diurnal`` | ``mix``), in SI units
  (``rate`` in ops/sec, ``period``/``deadline`` in seconds).
* :func:`generate_arrivals` -- a deterministic, seedable generator turning
  a spec into a monotone ``float64`` timestamp array.  Determinism is a
  contract, not a convenience: the same spec must regenerate the
  byte-identical array so (a) the sweep cell cache can key on the spec
  instead of the data and (b) all three simulation backends replay the
  *same* arrival stream (the loops and the jax grid consume one shared
  array; see ``engine_loop`` / ``replay_jax``).  The generator uses its
  own ``numpy`` RNG, disjoint from the simulator's Mersenne stream, so
  enabling open loop never perturbs closed-loop RNG draw order.
* :class:`LatencySummary` plus :func:`summarize_exact` /
  :func:`summarize_hist` -- the percentile accumulator.  The Python loops
  record exact sojourns and take nearest-rank quantiles; the jax grid
  scatters into a fixed-bin log histogram (``HIST_BINS`` bins,
  ``HIST_BINS_PER_DECADE`` per decade) whose quantile estimates carry a
  documented relative error bound of ``HIST_REL_ERROR`` (< 1.9%) for
  values inside ``[HIST_LO, HIST_LO * 10**HIST_DECADES)``.

Time-drifting Zipf skew -- the workload-side half of "arrival dynamics" --
lives in :func:`repro.core.workloads.drifting_zipf`, since key skew is a
property of the op stream, not of the arrival clock.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, fields
from typing import Sequence

import numpy as np

__all__ = [
    "ArrivalSpec",
    "generate_arrivals",
    "LatencySummary",
    "summarize_exact",
    "summarize_hist",
    "hist_bin",
    "hist_bin_value",
    "HIST_LO",
    "HIST_BINS",
    "HIST_BINS_PER_DECADE",
    "HIST_DECADES",
    "HIST_RATIO",
    "HIST_INV_LN_RATIO",
    "HIST_REL_ERROR",
]

_KINDS = ("poisson", "bursty", "diurnal", "mix")


@dataclass(frozen=True)
class ArrivalSpec:
    """Declarative arrival process (SI units: ops/sec, seconds).

    ``kind`` selects the process:

    ``poisson``
        Homogeneous Poisson at ``rate``.
    ``bursty``
        MMPP on-off: exponentially distributed ON phases (mean
        ``period * on_fraction``) alternating with OFF phases (mean
        ``period * (1 - on_fraction)``); arrivals only during ON at rate
        ``rate / on_fraction`` so the *long-run mean* rate stays ``rate``
        (duty-cycle conservation -- property-tested).
    ``diurnal``
        Non-homogeneous Poisson with sinusoidal rate
        ``rate * (1 + amplitude * sin(2*pi*t / period))`` via thinning.
    ``mix``
        Multi-tenant superposition: each entry of ``tenants`` is the
        ``to_dict()`` form of a non-mix sub-spec; the merged stream is the
        sorted union, truncated to the requested length.  Offered load is
        the sum of tenant rates.

    ``deadline`` (seconds, 0 = disabled) is the per-op SLA: measured ops
    whose sojourn exceeds it count as *missed* and are excluded from the
    percentile accumulator (they still count toward throughput).
    """

    kind: str = "poisson"
    rate: float = 100_000.0
    seed: int = 0
    on_fraction: float = 0.25     # bursty duty cycle
    period: float = 0.01          # bursty mean cycle / diurnal period (s)
    amplitude: float = 0.8        # diurnal relative swing, in [0, 1)
    deadline: float = 0.0         # SLA deadline (s); 0 disables
    tenants: tuple = ()           # mix: tuple of sub-spec dicts

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown arrival kind {self.kind!r}; valid: {_KINDS}")
        if self.kind == "mix":
            if not self.tenants:
                raise ValueError("mix arrival spec needs >= 1 tenant")
            # Normalize to a hashable tuple-of-dicts and validate eagerly.
            object.__setattr__(self, "tenants", tuple(
                dict(t) for t in self.tenants))
            for i, t in enumerate(self.tenants):
                sub = ArrivalSpec.from_dict(t)
                if sub.kind == "mix":
                    raise ValueError(f"tenant {i}: nested mix not allowed")
        elif self.rate <= 0.0:
            raise ValueError(f"rate must be > 0, got {self.rate}")
        if not 0.0 < self.on_fraction <= 1.0:
            raise ValueError(
                f"on_fraction must be in (0, 1], got {self.on_fraction}")
        if self.period <= 0.0:
            raise ValueError(f"period must be > 0, got {self.period}")
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError(
                f"amplitude must be in [0, 1), got {self.amplitude}")
        if self.deadline < 0.0:
            raise ValueError(f"deadline must be >= 0, got {self.deadline}")

    @property
    def offered_rate(self) -> float:
        """Long-run mean arrival rate in ops/sec."""
        if self.kind == "mix":
            return sum(ArrivalSpec.from_dict(t).offered_rate
                       for t in self.tenants)
        return self.rate

    def to_dict(self) -> dict:
        return {"kind": self.kind, "rate": self.rate, "seed": self.seed,
                "on_fraction": self.on_fraction, "period": self.period,
                "amplitude": self.amplitude, "deadline": self.deadline,
                "tenants": [dict(t) for t in self.tenants]}

    @classmethod
    def from_dict(cls, d: dict) -> "ArrivalSpec":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown arrival spec field(s): {sorted(unknown)}")
        d = dict(d)
        if "tenants" in d:
            d["tenants"] = tuple(dict(t) for t in d["tenants"])
        return cls(**d)

    def key(self) -> str:
        """Canonical string form, stable across processes (cache key)."""
        return json.dumps(self.to_dict(), sort_keys=True)


def _tenant_seed(spec: ArrivalSpec, idx: int, sub: dict) -> int:
    # A tenant with an explicit seed keeps it; otherwise derive one from
    # the mix seed + position, so the whole mix regenerates from one spec.
    if "seed" in sub:
        return int(sub["seed"])
    return spec.seed * 1_000_003 + idx + 1


def generate_arrivals(spec: ArrivalSpec | dict, n: int) -> np.ndarray:
    """``n`` monotone nondecreasing arrival timestamps (float64 seconds).

    Pure function of ``(spec, n)``: the same inputs regenerate the
    byte-identical array (``numpy`` PCG64 stream keyed on ``spec.seed``).
    """
    if isinstance(spec, dict):
        spec = ArrivalSpec.from_dict(spec)
    if n <= 0:
        return np.empty(0, dtype=np.float64)
    rng = np.random.default_rng(spec.seed)
    if spec.kind == "poisson":
        return np.cumsum(rng.exponential(1.0 / spec.rate, n))
    if spec.kind == "bursty":
        return _bursty(spec, n, rng)
    if spec.kind == "diurnal":
        return _diurnal(spec, n, rng)
    # mix: superpose tenant streams, keep the earliest n of the union (a
    # valid prefix: the merged n-th arrival is <= every tenant's n-th).
    streams = []
    for i, sub in enumerate(spec.tenants):
        t = ArrivalSpec.from_dict(
            dict(sub, seed=_tenant_seed(spec, i, sub)))
        streams.append(generate_arrivals(t, n))
    return np.sort(np.concatenate(streams), kind="stable")[:n]


def _bursty(spec: ArrivalSpec, n: int, rng: np.random.Generator):
    out = np.empty(n, dtype=np.float64)
    r_on = spec.rate / spec.on_fraction
    mean_on = spec.period * spec.on_fraction
    mean_off = spec.period * (1.0 - spec.on_fraction)
    t = 0.0
    i = 0
    while i < n:
        on_end = t + rng.exponential(mean_on)
        while i < n:
            g = rng.exponential(1.0 / r_on)
            if t + g >= on_end:
                break
            t += g
            out[i] = t
            i += 1
        t = on_end
        if mean_off > 0.0:
            t += rng.exponential(mean_off)
    return out


def _diurnal(spec: ArrivalSpec, n: int, rng: np.random.Generator):
    # Thinning (Lewis-Shedler): candidate stream at the peak rate, accept
    # with probability r(t)/r_max.  Strictly increasing by construction.
    out = np.empty(n, dtype=np.float64)
    r_max = spec.rate * (1.0 + spec.amplitude)
    two_pi_over_p = 2.0 * math.pi / spec.period
    t = 0.0
    i = 0
    while i < n:
        t += rng.exponential(1.0 / r_max)
        r_t = spec.rate * (1.0 + spec.amplitude
                           * math.sin(two_pi_over_p * t))
        if rng.random() * r_max < r_t:
            out[i] = t
            i += 1
    return out


# ---------------------------------------------------------------------------
# Percentile accumulator
# ---------------------------------------------------------------------------
#
# The loops keep exact per-op sojourns and take nearest-rank quantiles at
# finalize.  The jax grid cannot hold per-op lists, so it scatters counts
# into a fixed-bin log histogram: bins at ratio HIST_RATIO = 10**(1/64)
# spanning [HIST_LO, HIST_LO * 10**HIST_DECADES) = [0.1 us, 10 s).
# Reporting a bin's *geometric midpoint* bounds the relative quantile
# error by sqrt(HIST_RATIO) - 1 = HIST_REL_ERROR < 1.9% for in-range
# values (out-of-range values clamp to the edge bins).

HIST_LO = 1e-7                     # 0.1 us: well under one T_sw
HIST_BINS_PER_DECADE = 64
HIST_DECADES = 8                   # up to 10 s
HIST_BINS = HIST_BINS_PER_DECADE * HIST_DECADES
HIST_RATIO = 10.0 ** (1.0 / HIST_BINS_PER_DECADE)
HIST_INV_LN_RATIO = HIST_BINS_PER_DECADE / math.log(10.0)
HIST_REL_ERROR = math.sqrt(HIST_RATIO) - 1.0   # ~0.0182

_QS = (0.5, 0.9, 0.99)


def hist_bin(v) -> np.ndarray:
    """Log-histogram bin index for value(s) ``v`` (seconds), clamped."""
    v = np.maximum(np.asarray(v, dtype=np.float64), HIST_LO)
    b = np.floor(np.log(v / HIST_LO) * HIST_INV_LN_RATIO)
    return np.clip(b, 0, HIST_BINS - 1).astype(np.int64)


def hist_bin_value(b) -> np.ndarray:
    """Geometric midpoint of bin ``b`` (the reported quantile value)."""
    return HIST_LO * HIST_RATIO ** (np.asarray(b, dtype=np.float64) + 0.5)


@dataclass(frozen=True)
class LatencySummary:
    """Per-cell sojourn-latency tail summary (seconds).

    ``count`` ops contribute to the percentiles; ``missed`` more completed
    but blew the SLA deadline and are excluded.  ``source`` records which
    accumulator produced it: ``"exact"`` (loops, nearest-rank) or
    ``"hist"`` (jax log-histogram, error bound ``HIST_REL_ERROR``).
    An empty cell (every op missed) carries NaN percentiles.
    """

    count: int
    p50: float
    p90: float
    p99: float
    max: float
    missed: int = 0
    source: str = "exact"

    @property
    def miss_rate(self) -> float:
        total = self.count + self.missed
        return self.missed / total if total else 0.0

    def to_dict(self) -> dict:
        return {"count": self.count, "p50": self.p50, "p90": self.p90,
                "p99": self.p99, "max": self.max, "missed": self.missed,
                "source": self.source}

    @classmethod
    def from_dict(cls, d: dict) -> "LatencySummary":
        return cls(**d)


def summarize_exact(values: Sequence[float],
                    missed: int = 0) -> LatencySummary:
    """Nearest-rank quantiles over exact sojourns (the loop backends)."""
    n = len(values)
    if n == 0:
        nan = float("nan")
        return LatencySummary(0, nan, nan, nan, nan, missed, "exact")
    s = sorted(values)
    p50, p90, p99 = (s[max(math.ceil(q * n) - 1, 0)] for q in _QS)
    return LatencySummary(n, p50, p90, p99, s[-1], missed, "exact")


def summarize_hist(counts: np.ndarray, vmax: float,
                   missed: int = 0) -> LatencySummary:
    """Quantiles from a log-histogram (the jax grid backend).

    ``counts`` is the per-bin count vector (any real dtype holding exact
    integers), ``vmax`` the exactly-tracked maximum sojourn.
    """
    counts = np.asarray(counts, dtype=np.float64)
    total = int(round(float(counts.sum())))
    if total == 0:
        nan = float("nan")
        return LatencySummary(0, nan, nan, nan, nan, missed, "hist")
    cum = np.cumsum(counts)
    qs = []
    for q in _QS:
        rank = math.ceil(q * total)
        b = int(np.searchsorted(cum, rank, side="left"))
        qs.append(float(hist_bin_value(b)))
    return LatencySummary(total, qs[0], qs[1], qs[2], float(vmax),
                          missed, "hist")
