"""Key-distribution and read-write-mix workload generators (Table 5).

The paper drives Aerospike with uniform / Zipf-1.1 keys, RocksDB with
Zipf-0.99 / Zipf-0.8, and CacheLib with Gaussian and the CacheBench
"graph cache leader" key distribution; read:write mixes are 1:0, 2:1, 1:1.

Generators self-register in a registry mirroring the engine registry in
:mod:`repro.core.engines.base`: :func:`get_workload` resolves canonical
names, aliases, and CLI-style underscores, and :func:`create_workload`
instantiates by name -- which is what lets a declarative
:class:`~repro.core.experiment.Scenario` name its workload as plain data.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterator

import numpy as np

__all__ = [
    "Workload",
    "uniform",
    "zipf",
    "drifting_zipf",
    "gaussian",
    "graph_cache_leader",
    "register_workload",
    "get_workload",
    "create_workload",
    "available_workloads",
]


@dataclass(frozen=True)
class Workload:
    """A stream of (key, is_write) pairs over an integer key space."""

    name: str
    keys: np.ndarray           # int64 key ids in [0, n_keys)
    is_write: np.ndarray       # bool per op
    n_keys: int

    def __len__(self) -> int:
        return len(self.keys)

    def pairs(self) -> Iterator[tuple[int, bool]]:
        return zip(self.keys.tolist(), self.is_write.tolist())


# ---------------------------------------------------------------------------
# Registry (mirrors the engine registry in repro.core.engines.base)
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Workload]] = {}


def register_workload(name: str, *aliases: str) -> Callable[[Callable], Callable]:
    """Decorator: register a workload factory under ``name`` (+ aliases).

    The first name is canonical and is stamped on the factory as
    ``fn.workload_name`` so callers holding an alias can recover the one
    display/config name (scenario specs serialize it).  Factories take
    ``(n_keys, n_ops, **kwargs)`` and return a :class:`Workload`.
    """

    def deco(fn: Callable) -> Callable:
        for key in (name, *aliases):
            if key in _REGISTRY and _REGISTRY[key] is not fn:
                raise ValueError(f"workload name {key!r} already registered")
            _REGISTRY[key] = fn
        fn.workload_name = name
        return fn

    return deco


def get_workload(name: str) -> Callable[..., Workload]:
    """Look up a workload factory by registered name or alias.

    CLI-style underscores are accepted for any registered name
    (``graph_cache_leader`` == ``graph-cache-leader``).
    """
    fn = _REGISTRY.get(name) or _REGISTRY.get(name.replace("_", "-"))
    if fn is None:
        raise KeyError(
            f"unknown workload {name!r}; known: {sorted(_REGISTRY)}"
        )
    return fn


def create_workload(name: str, n_keys: int, n_ops: int, **kwargs) -> Workload:
    """Instantiate a registered workload by name."""
    return get_workload(name)(n_keys, n_ops, **kwargs)


def available_workloads() -> dict[str, Callable[..., Workload]]:
    """Snapshot of the registry (canonical names and aliases alike)."""
    return dict(_REGISTRY)


def _mix(n_ops: int, read_write: tuple[int, int], rng: np.random.Generator):
    r, w = read_write
    if w == 0:
        return np.zeros(n_ops, dtype=bool)
    return rng.random(n_ops) < (w / (r + w))


@register_workload("uniform")
def uniform(
    n_keys: int, n_ops: int, read_write=(1, 0), seed: int = 0
) -> Workload:
    rng = np.random.default_rng(seed)
    return Workload(
        "uniform", rng.integers(0, n_keys, n_ops), _mix(n_ops, read_write, rng), n_keys
    )


@register_workload("zipf", "zipfian")
def zipf(
    n_keys: int, n_ops: int, exponent: float = 0.99, read_write=(1, 0), seed: int = 0
) -> Workload:
    """Bounded Zipf over [0, n_keys): P(rank r) ~ 1 / r^exponent.

    Ranks are scattered over the key space with a fixed permutation hash so
    hot keys are not spatially clustered (as in real stores).
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    pmf = ranks ** (-exponent)
    cdf = np.cumsum(pmf)
    cdf /= cdf[-1]
    draws = np.searchsorted(cdf, rng.random(n_ops))
    # multiplicative-hash permutation of ranks -> key ids
    keys = (draws.astype(np.uint64) * np.uint64(2654435761)) % np.uint64(n_keys)
    return Workload(
        f"zipf{exponent}", keys.astype(np.int64), _mix(n_ops, read_write, rng), n_keys
    )


@register_workload("drifting-zipf", "zipf-drift")
def drifting_zipf(
    n_keys: int, n_ops: int, exponent0: float = 0.6, exponent1: float = 1.2,
    n_segments: int = 16, read_write=(1, 0), seed: int = 0
) -> Workload:
    """Zipf whose skew drifts linearly from ``exponent0`` to ``exponent1``
    across the op stream -- the open-loop companion to the time-varying
    arrival processes in :mod:`repro.core.sim.arrivals` (a store warming up
    or a cache whose working set concentrates over a diurnal cycle).

    The stream is cut into ``n_segments`` equal slices; slice ``i`` draws
    from a bounded Zipf at the segment-midpoint exponent, so the drift is
    piecewise-constant but deterministic in ``(n_keys, n_ops, seed)``.
    Ranks use the same permutation hash as :func:`zipf`, so the *identity*
    of the hot keys is stable while their concentration drifts.
    """
    if n_segments < 1:
        raise ValueError(f"n_segments must be >= 1, got {n_segments}")
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    u = rng.random(n_ops)
    bounds = np.linspace(0, n_ops, n_segments + 1).astype(np.int64)
    draws = np.empty(n_ops, dtype=np.int64)
    for i in range(n_segments):
        lo, hi = bounds[i], bounds[i + 1]
        if hi <= lo:
            continue
        frac = (i + 0.5) / n_segments
        e = exponent0 + (exponent1 - exponent0) * frac
        cdf = np.cumsum(ranks ** (-e))
        cdf /= cdf[-1]
        draws[lo:hi] = np.searchsorted(cdf, u[lo:hi])
    keys = (draws.astype(np.uint64) * np.uint64(2654435761)) % np.uint64(n_keys)
    return Workload(
        f"drifting_zipf{exponent0}-{exponent1}", keys.astype(np.int64),
        _mix(n_ops, read_write, rng), n_keys
    )


@register_workload("gaussian", "normal")
def gaussian(
    n_keys: int, n_ops: int, sigma_frac: float = 0.08, read_write=(2, 1), seed: int = 0
) -> Workload:
    """CacheBench-style Gaussian popularity around a moving working-set center."""
    rng = np.random.default_rng(seed)
    center = n_keys / 2.0
    keys = rng.normal(center, sigma_frac * n_keys, n_ops)
    keys = np.clip(np.round(keys), 0, n_keys - 1).astype(np.int64)
    return Workload("gaussian", keys, _mix(n_ops, read_write, rng), n_keys)


@register_workload("graph-cache-leader", "gcl")
def graph_cache_leader(
    n_keys: int, n_ops: int, read_write=(2, 1), seed: int = 0
) -> Workload:
    """Approximation of CacheBench's graph-cache-leader key distribution:
    a heavy-tailed mixture -- a small hot set (Zipf 0.9) plus a uniform
    scan component, which is what the Meta social-graph leader traces
    look like (Berg et al., OSDI'20)."""
    rng = np.random.default_rng(seed)
    hot = zipf(max(n_keys // 20, 1), n_ops, 0.9, (1, 0), seed + 1).keys
    cold = rng.integers(0, n_keys, n_ops)
    take_hot = rng.random(n_ops) < 0.8
    keys = np.where(take_hot, hot, cold).astype(np.int64)
    return Workload("graph_cache_leader", keys, _mix(n_ops, read_write, rng), n_keys)
