"""Hash-index engine: open-addressing index on slow memory, values on SSD.

The paper's hash-index store class (memcached-style flat tables, Aerospike's
earlier hash primary index): the *index* is one large array of slots on
microsecond-latency memory, the *values* live on SSD.  Open addressing makes
the probe chain prefetch-friendly -- unlike a pointer-chased tree, the slot
addresses of a linear-probe run are known up front, so one slow-memory
prefetch covers a whole cache line of slots and only line crossings pay
another hop.  That gives this engine the lowest M (memory hops per op) of
the engine matrix and, per the paper's model, the flattest latency-tolerance
curve among the index stores.
"""
from __future__ import annotations

import numpy as np

from ..trace_ir import US
from .base import EngineTimes, register_engine
from .trace import Recorder

__all__ = ["HashIndexStore"]


@register_engine("hash-index", "open-addressing")
class HashIndexStore:
    """Open-addressing (linear probing) hash index of 16-byte slots.

    get  = bucket hash (DRAM) + probe run (one slow-memory hop per touched
           cache line of ``slots_per_line`` slots) + one SSD value read.
    put  = probe run + in-place slot update (one hop) + write-buffer append;
           a large flush IO every ``flush_block // value_size`` writes.
    """

    def __init__(
        self,
        n_keys: int,
        load_factor: float = 0.7,
        slots_per_line: int = 4,       # 64-byte line / 16-byte slot
        value_size: int = 1024,
        flush_block: int = 131072,
        times: EngineTimes | None = None,
        seed: int = 0,
    ):
        if not 0.0 < load_factor < 1.0:
            raise ValueError(f"load_factor must be in (0, 1), got {load_factor}")
        self.times = times or EngineTimes()
        self.n_keys = n_keys
        self.slots_per_line = slots_per_line
        self.flush_every = max(flush_block // value_size, 1)
        cap = 1
        while cap * load_factor < n_keys:
            cap *= 2
        self.capacity = cap
        self._mask = cap - 1
        self.slots = np.full(cap, -1, dtype=np.int64)   # key id, or -1 empty
        self._probe_total = 0
        self._probe_ops = 0
        self._pending_writes = 0
        rng = np.random.default_rng(seed)
        for k in rng.permutation(n_keys).tolist():      # untraced bulk load
            self._insert(int(k))

    def _hash(self, k: int) -> int:
        return ((int(k) * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) >> 32

    def _insert(self, k: int) -> None:
        i = self._hash(k) & self._mask
        while self.slots[i] >= 0:
            i = (i + 1) & self._mask
        self.slots[i] = k

    def _probe(self, k: int, rec: Recorder) -> bool:
        """Walk the probe run, recording one MEM hop per touched cache line."""
        rec.cpu(self.times.t_probe)        # bucket hash (DRAM-side compute)
        start = self._hash(k) & self._mask
        i = start
        spl = self.slots_per_line
        line = -1
        probes = 0
        found = False
        while True:
            if i // spl != line:           # crossed into a new line of slots
                line = i // spl
                rec.mem()
            s = int(self.slots[i])
            probes += 1
            if s == k:
                found = True
                break
            if s < 0:
                break
            i = (i + 1) & self._mask
        self._probe_total += probes
        self._probe_ops += 1
        return found

    def op(self, k: int, is_write: bool, rec: Recorder) -> None:
        found = self._probe(k, rec)
        if is_write:
            rec.cpu(self.times.t_value)    # serialize into the write buffer
            rec.mem()                      # in-place slot update (new value ptr)
            self._pending_writes += 1
            if self._pending_writes >= self.flush_every:
                self._pending_writes = 0
                rec.io(pre_extra=0.5 * US)  # large-block buffered flush
        elif found:
            rec.io()                       # read the value from SSD
            rec.cpu(self.times.t_value)
        rec.end_op()

    def stats(self) -> dict:
        return {
            "load_factor": self.n_keys / self.capacity,
            "avg_probes": self._probe_total / max(self._probe_ops, 1),
        }
