"""Aerospike-like engine: in-memory tree index on slow memory, values on SSD."""
from __future__ import annotations

import numpy as np

from ..trace_ir import US
from .base import EngineTimes, register_engine
from .trace import Recorder

__all__ = ["TreeIndexStore"]


@register_engine("tree-index", "aerospike-like")
class TreeIndexStore:
    """Per-sprig unbalanced BSTs of 64-byte nodes (Aerospike primary index).

    get  = sprig hash (DRAM) + tree walk (slow-memory hops) + one SSD read.
    put  = tree walk + write-buffer append; a large flush IO every
           ``flush_block // value_size`` writes (Aerospike write blocks).
    """

    def __init__(
        self,
        n_keys: int,
        n_sprigs: int = 256,
        value_size: int = 1536,
        flush_block: int = 131072,
        times: EngineTimes | None = None,
        seed: int = 0,
    ):
        # Aerospike's storage path spends much more CPU per IO than raw
        # io_uring (network/defrag bookkeeping); the paper's Table 1
        # example quotes T_io_pre ~ 4 us, T_io_post ~ 3 us for this class.
        self.times = times or EngineTimes(t_io_pre=3.0 * US, t_io_post=2.0 * US)
        self.n_keys = n_keys
        self.n_sprigs = n_sprigs
        self.value_size = value_size
        self.flush_every = max(flush_block // value_size, 1)
        rng = np.random.default_rng(seed)
        order = rng.permutation(n_keys)
        # array-based BST per sprig: node i has key keys[i], children l/r
        self.sprig_of = (
            (np.arange(n_keys, dtype=np.uint64) * np.uint64(0x9E3779B97F4A7C15))
            % np.uint64(n_sprigs)
        ).astype(np.int64)
        self.root = [-1] * n_sprigs
        self.key = np.empty(n_keys, dtype=np.int64)
        self.left = np.full(n_keys, -1, dtype=np.int64)
        self.right = np.full(n_keys, -1, dtype=np.int64)
        self.node_of: dict[int, int] = {}
        self._n_nodes = 0
        for k in order.tolist():
            self._insert(int(k))
        self._pending_writes = 0

    def _insert(self, k: int) -> int:
        """Untraced build-time insert; returns hop count."""
        i = self._n_nodes
        self.key[i] = k
        self.node_of[k] = i
        self._n_nodes += 1
        s = int(self.sprig_of[k])
        cur = self.root[s]
        hops = 0
        if cur < 0:
            self.root[s] = i
            return 0
        while True:
            hops += 1
            if k < self.key[cur]:
                if self.left[cur] < 0:
                    self.left[cur] = i
                    return hops
                cur = self.left[cur]
            else:
                if self.right[cur] < 0:
                    self.right[cur] = i
                    return hops
                cur = self.right[cur]

    def _sprig(self, k: int) -> int:
        # python ints: intentional 64-bit multiplicative hash without
        # numpy's overflow warning
        return ((int(k) * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) % self.n_sprigs

    def _walk(self, k: int, rec: Recorder) -> bool:
        rec.cpu(self.times.t_probe)  # sprig hash + root lookup (DRAM)
        cur = self.root[self._sprig(k)]
        while cur >= 0:
            rec.mem()  # node is a 64-byte record on slow memory
            if k == self.key[cur]:
                return True
            cur = self.left[cur] if k < self.key[cur] else self.right[cur]
        return False

    def op(self, k: int, is_write: bool, rec: Recorder) -> None:
        found = self._walk(k, rec)
        if is_write:
            rec.cpu(self.times.t_value)       # serialize into write buffer
            rec.mem()                          # update index entry in place
            self._pending_writes += 1
            if self._pending_writes >= self.flush_every:
                self._pending_writes = 0
                rec.io(pre_extra=0.5 * US)     # large-block flush write
        elif found:
            rec.io()                           # read value from SSD
            rec.cpu(self.times.t_value)
        rec.end_op()

    def stats(self) -> dict:
        return {}
