"""CacheLib-like engine: two-tier cache, chained items + LRU on slow memory."""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from ..trace_ir import US
from .base import EngineTimes, register_engine
from .trace import Recorder

__all__ = ["TwoTierCacheStore"]


@register_engine("two-tier-cache", "cachelib-like")
class TwoTierCacheStore:
    """Tier-1: DRAM hash buckets -> item chains + LRU list on slow memory.
    Tier-2: SSD small-object cache. Misses fetch from the backing store
    (CPU-modelled) and admit into tier 1, evicting to tier 2.
    """

    def __init__(
        self,
        n_keys: int,
        tier1_items: int | None = None,    # None: ~8% of keys (8 GB / 100 M)
        tier2_items: int | None = None,    # None: ~32% of keys
        avg_chain: float = 1.5,
        times: EngineTimes = EngineTimes(),
        seed: int = 0,
    ):
        self.times = times
        self.n_keys = n_keys
        self.t1_cap = tier1_items if tier1_items is not None else max(n_keys // 12, 1)
        self.t2_cap = tier2_items if tier2_items is not None else max(n_keys // 3, 1)
        self.avg_chain = avg_chain
        self.t1: "OrderedDict[int, None]" = OrderedDict()
        self.t2: "OrderedDict[int, None]" = OrderedDict()
        self.rng = np.random.default_rng(seed)
        self.t1_hits = 0
        self.t2_hits = 0
        self.t2_lookups = 0
        self.gets = 0
        self._evict_buffer = 0
        self._flush_every = 16                 # buffered tier-2 region writes

    def _chain_walk(self, rec: Recorder, found: bool) -> None:
        # hash bucket is DRAM; each chained item is a slow-memory node
        rec.cpu(self.times.t_probe)
        hops = 1 + self.rng.poisson(max(self.avg_chain - 1.0, 0.0))
        if not found:
            hops = max(hops - 1, 1)
        rec.mem(int(hops))

    def _admit(self, k: int, rec: Recorder) -> None:
        self.t1[k] = None
        rec.mem(2)                             # alloc item + chain-head insert
        if len(self.t1) > self.t1_cap:
            victim, _ = self.t1.popitem(last=False)
            rec.mem(3)                         # LRU tail unlink + chain del
            self.t2[victim] = None
            self._evict_buffer += 1
            if self._evict_buffer >= self._flush_every:
                self._evict_buffer = 0
                rec.io(pre_extra=0.5 * US)     # flush a tier-2 region write
            if len(self.t2) > self.t2_cap:
                self.t2.popitem(last=False)

    def op(self, k: int, is_write: bool, rec: Recorder) -> None:
        t = self.times
        if is_write:
            if k in self.t1:
                self._chain_walk(rec, True)
                self.t1.move_to_end(k)
                rec.mem(3)                     # LRU promote
                rec.cpu(t.t_value)
            else:
                self._chain_walk(rec, False)
                rec.cpu(t.t_value)
                self._admit(k, rec)
            rec.end_op()
            return
        self.gets += 1
        if k in self.t1:
            self.t1_hits += 1
            self._chain_walk(rec, True)
            self.t1.move_to_end(k)
            rec.mem(3)                         # LRU promote
            rec.cpu(t.t_value)
            rec.end_op()
            return
        self._chain_walk(rec, False)
        self.t2_lookups += 1
        rec.io()                               # tier-2 SOC bucket read
        if k in self.t2:
            self.t2_hits += 1
            self.t2.move_to_end(k)
            rec.cpu(t.t_value)
        else:
            rec.cpu(2.0 * US)                  # backing-store fetch + build
        self._admit(k, rec)
        rec.end_op()

    @property
    def hit_stats(self) -> dict:
        t1 = self.t1_hits / max(self.gets, 1)
        t2 = self.t2_hits / max(self.t2_lookups, 1)
        return {"tier1": t1, "tier2": t2, "overall": t1 + (1 - t1) * t2}

    def stats(self) -> dict:
        return self.hit_stats
