"""SSD-based KV-store engines mirroring the paper's three modified stores.

The paper modifies Aerospike, RocksDB and CacheLib so their large in-memory
indices/caches live on microsecond-latency memory and every access to them is
a prefetch+yield.  We implement the *data-structure cores* of those three
designs (Fig. 13) as real Python/numpy structures:

  * :class:`TreeIndexStore`    (``tree-index`` / ``aerospike-like``)
  * :class:`LSMStore`          (``lsm`` / ``rocksdb-like``)
  * :class:`TwoTierCacheStore` (``two-tier-cache`` / ``cachelib-like``)

plus two more points of the paper's index/cache design space:

  * :class:`HashIndexStore`    (``hash-index`` / ``open-addressing``)
  * :class:`SlabCacheStore`    (``slab-cache`` / ``memcached-like``)

Running a workload through :func:`run_trace` produces a columnar
:class:`~repro.core.trace_ir.CompiledTrace` in which every pointer
dereference on slow memory is a MEM subop and every SSD access a
PREIO/POSTIO pair -- exactly the operation model of Sec. 3.  The trace is
executed by :mod:`repro.core.sim` to obtain throughput vs. memory latency,
and summarized into ``OpParams`` so the closed-form model of
:mod:`repro.core.latency_model` can be compared against the "measurement"
(Figs. 11(c)(d)(e)).

Only reads/updates go through the traced path; bulk loading is untraced
(the paper also measures after load + warm-up).

New engines implement the :class:`KVEngine` protocol (``op()``, ``times``,
``stats()``) and self-register via :func:`register_engine`; everything
downstream (tracing driver, sweep pipeline, benchmarks) picks them up by
name.
"""
from .base import (  # noqa: F401
    EngineTimes,
    KVEngine,
    available_engines,
    create_engine,
    get_engine,
    register_engine,
)
from .trace import Recorder, TraceResult, run_trace  # noqa: F401
from .tree_index import TreeIndexStore  # noqa: F401
from .lsm import LSMStore  # noqa: F401
from .two_tier_cache import TwoTierCacheStore  # noqa: F401
from .hash_index import HashIndexStore  # noqa: F401
from .slab_cache import SlabCacheStore  # noqa: F401

__all__ = [
    "EngineTimes",
    "KVEngine",
    "Recorder",
    "TraceResult",
    "run_trace",
    "TreeIndexStore",
    "LSMStore",
    "TwoTierCacheStore",
    "HashIndexStore",
    "SlabCacheStore",
    "register_engine",
    "get_engine",
    "create_engine",
    "available_engines",
]
