"""Memcached-like engine: slab-allocated cache, chained hash + per-class LRU.

The paper's cache class, in its memcached incarnation: items are carved out
of fixed slab classes (size classes growing geometrically), each class with
its *own* LRU list, all of it -- hash chains, items, LRU links -- on
microsecond-latency memory.  A miss fetches the value from the SSD-resident
backing store and admits it, evicting the LRU tail *of the same class* (slab
allocators never evict across classes).  Compared with the CacheLib-like
two-tier engine this store has no SSD cache tier, so its IO rate is set
purely by the miss ratio -- which makes it the engine whose latency
tolerance degrades fastest as the hit rate rises, the cache-side bookend of
the paper's qualitative claim.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from ..trace_ir import US
from .base import EngineTimes, register_engine
from .trace import Recorder

__all__ = ["SlabCacheStore"]


@register_engine("slab-cache", "memcached-like")
class SlabCacheStore:
    """Chained hash table + one LRU per slab class, items on slow memory.

    get hit  = chain walk (MEM hops) + class-LRU promote (MEM hops).
    get miss = chain walk + backing-store SSD read + admit (alloc from the
               key's slab class, evicting that class's LRU tail if full).
    set      = chain walk + item write; admits on miss like a get, and
               flushes dirty evictions to the backing store in buffered
               region writes.
    """

    #: slab classes: item sizes in bytes, geometric growth factor 2
    CLASS_SIZES = (128, 256, 512, 1024)

    def __init__(
        self,
        n_keys: int,
        cache_bytes: int | None = None,    # None: items for ~12% of keys
        avg_chain: float = 1.5,
        times: EngineTimes = EngineTimes(),
        seed: int = 0,
    ):
        self.times = times
        self.n_keys = n_keys
        self.avg_chain = avg_chain
        sizes = self.CLASS_SIZES
        if cache_bytes is None:
            mean_size = sum(sizes) / len(sizes)
            cache_bytes = int(max(n_keys // 8, 8) * mean_size)
        per_class = cache_bytes // len(sizes)
        # byte budget split evenly across classes -> small classes hold more
        # items, exactly like a memcached slab rebalancer at steady state
        self.class_cap = [max(int(per_class // s), 1) for s in sizes]
        self.lru: list[OrderedDict[int, None]] = [OrderedDict() for _ in sizes]
        self.rng = np.random.default_rng(seed)
        self.gets = [0] * len(sizes)
        self.hits = [0] * len(sizes)
        self._evict_buffer = 0
        self._flush_every = 16             # buffered backing-store writes

    def _class_of(self, k: int) -> int:
        # deterministic value-size class per key (multiplicative hash)
        return (((int(k) * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) >> 17) % len(
            self.CLASS_SIZES
        )

    def _chain_walk(self, rec: Recorder, found: bool) -> None:
        # hash bucket head is DRAM; every chained item is a slow-memory node
        rec.cpu(self.times.t_probe)
        hops = 1 + self.rng.poisson(max(self.avg_chain - 1.0, 0.0))
        if not found:
            hops = max(hops - 1, 1)
        rec.mem(int(hops))

    def _admit(self, c: int, k: int, rec: Recorder) -> None:
        self.lru[c][k] = None
        rec.mem(2)                         # slab alloc + chain-head insert
        if len(self.lru[c]) > self.class_cap[c]:
            self.lru[c].popitem(last=False)
            rec.mem(3)                     # LRU tail unlink + chain delete
            self._evict_buffer += 1
            if self._evict_buffer >= self._flush_every:
                self._evict_buffer = 0
                rec.io(pre_extra=0.5 * US)  # flush dirty evictions (region write)

    def op(self, k: int, is_write: bool, rec: Recorder) -> None:
        t = self.times
        c = self._class_of(k)
        lru = self.lru[c]
        hit = k in lru
        if not is_write:
            self.gets[c] += 1
        if hit:
            if not is_write:
                self.hits[c] += 1
            self._chain_walk(rec, True)
            lru.move_to_end(k)
            rec.mem(3)                     # class-LRU promote
            rec.cpu(t.t_value)
        else:
            self._chain_walk(rec, False)
            if not is_write:
                rec.io()                   # backing-store read from SSD
            rec.cpu(t.t_value)
            self._admit(c, k, rec)
        rec.end_op()

    def stats(self) -> dict:
        out = {}
        total_gets = sum(self.gets)
        total_hits = sum(self.hits)
        for i, size in enumerate(self.CLASS_SIZES):
            out[f"class_{size}B"] = self.hits[i] / max(self.gets[i], 1)
        out["overall"] = total_hits / max(total_gets, 1)
        return out
