"""Engine protocol, shared timing constants, and the engine registry.

An *engine* is the data-structure core of one SSD-based KV store whose
index/cache lives on microsecond-latency memory (the paper's Fig. 13
modifications).  Engines do two things: mutate their real in-memory
structures, and record every slow-memory hop / SSD access of the operation
into a :class:`~repro.core.engines.trace.Recorder`.  Everything downstream
(simulator, analytical model, benchmarks) consumes only the recorded trace,
so new engines plug in without touching the simulation layer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Callable, Protocol, runtime_checkable

from ..trace_ir import US

if TYPE_CHECKING:  # pragma: no cover
    from .trace import Recorder

__all__ = [
    "EngineTimes",
    "KVEngine",
    "register_engine",
    "get_engine",
    "create_engine",
    "available_engines",
]


@dataclass(frozen=True)
class EngineTimes:
    """CPU-time constants of one engine's suboperations (calibratable)."""

    t_mem: float = 0.10 * US      # compute attached to one slow-memory hop
    t_io_pre: float = 1.5 * US    # IO submission (io_uring sqe prep + submit)
    t_io_post: float = 0.2 * US   # completion check + copy
    t_probe: float = 0.05 * US    # a DRAM-side probe (hash, fence index)
    t_value: float = 0.3 * US     # value (de)serialization / checksum


@runtime_checkable
class KVEngine(Protocol):
    """What the tracing driver and benchmarks require of an engine."""

    times: EngineTimes

    def op(self, k: int, is_write: bool, rec: "Recorder") -> None:
        """Execute one KV operation, recording its suboperations."""
        ...

    def stats(self) -> dict:
        """Engine-specific hit/occupancy statistics (may be empty)."""
        ...


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}


def register_engine(name: str, *aliases: str) -> Callable[[type], type]:
    """Class decorator: register an engine under ``name`` (+ aliases).

    The first name is canonical and is stamped on the class as
    ``cls.engine_name`` so callers holding a class (or an alias) can recover
    the one display/config name (benchmarks key workloads by it).
    """

    def deco(cls: type) -> type:
        for key in (name, *aliases):
            if key in _REGISTRY and _REGISTRY[key] is not cls:
                raise ValueError(f"engine name {key!r} already registered")
            _REGISTRY[key] = cls
        cls.engine_name = name
        return cls

    return deco


def get_engine(name: str) -> type:
    """Look up an engine class by registered name or alias.

    CLI-style underscores are accepted for any registered name
    (``two_tier_cache`` == ``two-tier-cache``).
    """
    cls = _REGISTRY.get(name) or _REGISTRY.get(name.replace("_", "-"))
    if cls is None:
        raise KeyError(
            f"unknown engine {name!r}; known: {sorted(_REGISTRY)}"
        )
    return cls


def create_engine(name: str, *args, **kwargs):
    """Instantiate a registered engine by name."""
    return get_engine(name)(*args, **kwargs)


def available_engines() -> dict[str, type]:
    """Snapshot of the registry (canonical names and aliases alike)."""
    return dict(_REGISTRY)
