"""RocksDB-like engine: LSM data blocks on SSD, block cache on slow memory."""
from __future__ import annotations

import math
from collections import OrderedDict

from .base import EngineTimes, register_engine
from .trace import Recorder
from ..trace_ir import US

__all__ = ["LSMStore"]


@register_engine("lsm", "rocksdb-like")
class LSMStore:
    """Single sorted run partitioned into data blocks + LRU block cache.

    Fence index and memtable stay in DRAM (the paper offloads only the 32-GB
    block cache, 80% of footprint). A block-cache probe costs hash + LRU
    hops on slow memory; a hit binary-searches the block's restart points
    (slow memory); a miss reads the 4-kB block from SSD and installs it.
    """

    def __init__(
        self,
        n_keys: int,
        entries_per_block: int = 10,       # ~4 kB / 400-B values
        cache_blocks: int | None = None,   # None: sized for ~67% hit @ Zipf .99
        restart_interval: int = 16,
        memtable_ops: int = 4096,
        times: EngineTimes = EngineTimes(),
    ):
        self.times = times
        self.n_keys = n_keys
        self.epb = entries_per_block
        self.n_blocks = (n_keys + entries_per_block - 1) // entries_per_block
        if cache_blocks is None:
            cache_blocks = max(self.n_blocks // 12, 1)
        self.cache_cap = cache_blocks
        self.restart = restart_interval
        self.memtable_ops = memtable_ops
        # LRU block cache: block_id -> tick; plus an eviction clock.
        self.cache: "OrderedDict[int, None]" = OrderedDict()
        self._mem_writes = 0
        self.hits = 0
        self.lookups = 0

    def _search_block(self, rec: Recorder) -> None:
        # binary search over restart points, then linear scan inside one
        # restart interval; every probed key is a slow-memory access.
        n_restarts = max(self.epb // self.restart, 1)
        hops = max(int(math.ceil(math.log2(n_restarts + 1))), 1)
        hops += min(self.restart, self.epb) // 4  # expected linear-scan touches
        rec.mem(hops)

    def op(self, k: int, is_write: bool, rec: Recorder) -> None:
        t = self.times
        if is_write:
            rec.cpu(t.t_probe + t.t_value)     # memtable insert (DRAM skiplist)
            self._mem_writes += 1
            if self._mem_writes >= self.memtable_ops:
                self._mem_writes = 0
                # flush: one large sequential write + compaction read+write
                rec.io(pre_extra=1.0 * US)
                rec.io(pre_extra=1.0 * US)
                rec.cpu(20.0 * US)             # compaction merge CPU burst
            rec.end_op()
            return
        rec.cpu(t.t_probe)                     # memtable probe (DRAM)
        rec.cpu(t.t_probe)                     # fence-index binary search (DRAM)
        block = int(k) // self.epb
        self.lookups += 1
        rec.mem()                              # cache hash-bucket probe
        if block in self.cache:
            self.hits += 1
            self.cache.move_to_end(block)
            rec.mem(2)                         # LRU unlink/relink touches
        else:
            rec.io()                           # read 4-kB data block
            rec.cpu(t.t_value)                 # checksum + decode
            self.cache[block] = None
            rec.mem(2)                         # insert into hash + LRU head
            if len(self.cache) > self.cache_cap:
                self.cache.popitem(last=False)
                rec.mem(2)                     # evict tail: unlink + hash del
        self._search_block(rec)
        rec.cpu(t.t_value)
        rec.end_op()

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(self.lookups, 1)

    def stats(self) -> dict:
        return {"block_cache": self.hit_ratio}
