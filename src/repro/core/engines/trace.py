"""Trace recording and summarization for the KV engines.

:class:`Recorder` collects the suboperations each engine emits and builds a
columnar :class:`~repro.core.trace_ir.CompiledTrace` directly -- the hot
path never materializes per-op tuple lists.  :class:`TraceResult` bundles
the compiled trace with per-op averages and hit statistics, and summarizes
it into the paper's :class:`~repro.core.latency_model.OpParams` so the
closed-form model can be compared against the simulated "measurement"
(Figs. 11(c)(d)(e)).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..latency_model import OpParams, US
from ..trace_ir import CPU, MEM, POSTIO, PREIO, CompiledTrace, Op
from ..workloads import Workload
from .base import EngineTimes

__all__ = ["Recorder", "TraceResult", "run_trace"]


class _OpsView(list):
    """Materialized list of completed :class:`Op` with one write-through:
    ``clear()`` also clears the recorder, preserving the pre-refactor
    ``rec.ops.clear()`` idiom (used to bound warm-up memory).  Other list
    mutations affect only this snapshot."""

    def __init__(self, recorder, items):
        super().__init__(items)
        self._recorder = recorder

    def clear(self):
        super().clear()
        self._recorder.clear()


class Recorder:
    """Collects suboperations for the current KV operation, columnar-first.

    Suboperations are appended to flat ``kinds``/``durs`` columns with an
    op-boundary array; :meth:`compile` snapshots them into an immutable
    :class:`CompiledTrace`.  The legacy ``.ops`` list-of-:class:`Op` view is
    kept for backward compatibility and is materialized on demand.
    """

    def __init__(self, times: EngineTimes):
        self.t = times
        self._kinds: list[int] = []
        self._durs: list[float] = []
        self._bounds: list[int] = [0]
        self.n_mem = 0
        self.n_io = 0
        self.n_ops = 0

    def mem(self, n: int = 1) -> None:
        self._kinds.extend([MEM] * n)
        self._durs.extend([self.t.t_mem] * n)
        self.n_mem += n

    def cpu(self, t: float) -> None:
        if t > 0.0:
            self._kinds.append(CPU)
            self._durs.append(t)

    def io(self, pre_extra: float = 0.0, post_extra: float = 0.0) -> None:
        self._kinds.append(PREIO)
        self._durs.append(self.t.t_io_pre + pre_extra)
        self._kinds.append(POSTIO)
        self._durs.append(self.t.t_io_post + post_extra)
        self.n_io += 1

    def end_op(self) -> None:
        if self._bounds[-1] == len(self._kinds):  # never emit empty ops
            self._kinds.append(CPU)
            self._durs.append(self.t.t_probe)
        self._bounds.append(len(self._kinds))
        self.n_ops += 1

    def clear(self) -> None:
        """Drop all recorded ops and counters (used to bound warm-up
        memory); afterwards per-op averages reflect only what is recorded
        next."""
        self._kinds.clear()
        self._durs.clear()
        self._bounds[:] = [0]
        self.n_ops = 0
        self.n_mem = 0
        self.n_io = 0

    def compile(self) -> CompiledTrace:
        """Snapshot the recorded *completed* operations as a columnar trace
        (suboperations of an op still in flight are excluded)."""
        end = self._bounds[-1]
        return CompiledTrace(
            np.asarray(self._kinds[:end], dtype=np.int8),
            np.asarray(self._durs[:end], dtype=np.float64),
            np.asarray(self._bounds, dtype=np.int64),
        )

    @property
    def ops(self) -> list[Op]:
        """Legacy row-oriented view of the completed operations.

        Materialized fresh per access; ``.clear()`` on it clears the
        recorder (the old idiom), other mutations only touch the snapshot.
        Prefer :meth:`compile` in new code.
        """
        if self.n_ops == 0:
            return _OpsView(self, [])
        return _OpsView(self, self.compile().to_ops())


@dataclass(init=False)
class TraceResult:
    trace: CompiledTrace          # the recorded post-warm-up operations
    mem_per_op: float             # average slow-memory hops per operation
    io_per_op: float              # average SSD accesses per operation (S)
    hit_stats: dict = field(default_factory=dict)

    def __init__(self, trace=None, mem_per_op=0.0, io_per_op=0.0,
                 hit_stats=None, ops=None):
        if trace is None:
            trace = ops               # legacy keyword: TraceResult(ops=...)
        if trace is None:
            raise TypeError("TraceResult requires 'trace' (or legacy 'ops')")
        if not isinstance(trace, CompiledTrace):
            trace = CompiledTrace.from_ops(trace)  # legacy list-of-Op form
        self.trace = trace
        self.mem_per_op = mem_per_op
        self.io_per_op = io_per_op
        self.hit_stats = {} if hit_stats is None else hit_stats

    @property
    def ops(self) -> list[Op]:
        """Legacy view: the trace as a list of :class:`Op`."""
        return self.trace.to_ops()

    def op_params(self, times: EngineTimes, P: int, T_sw: float) -> OpParams:
        """Summarize the trace into the paper's model parameters.

        Calibrated the way the paper does it (Sec. 4.2.3): T_mem / T_io_pre /
        T_io_post are the mean *CPU spans between yields* measured on the
        trace -- plain CPU suboperations (hashing, serialization) do not
        yield, so their time folds into the span of the next yield point.
        M is memory accesses per *operation*; the theta functions divide
        by S internally (Sec. 3.2.3 splitting). Ops with no IO (pure
        cache hits) contribute their hops to the average.
        """
        del times  # spans are measured from the trace, not the constants
        span_sum, span_n = self.trace.yield_spans()

        def mean(kind: int, default: float) -> float:
            return span_sum[kind] / span_n[kind] if span_n[kind] else default

        S = max(self.io_per_op, 1e-9)
        return OpParams(
            M=self.mem_per_op,
            T_mem=mean(MEM, 0.1 * US),
            T_io_pre=mean(PREIO, 1.5 * US),
            T_io_post=mean(POSTIO, 0.2 * US),
            T_sw=T_sw,
            P=P,
            S=S,
        )


def run_trace(store, wl: Workload, warmup_frac: float = 0.3) -> TraceResult:
    """Run a workload through an engine, recording only the post-warm-up ops."""
    n_warm = int(len(wl) * warmup_frac)
    warm_rec = Recorder(store.times)
    rec = Recorder(store.times)
    for i, (k, w) in enumerate(wl.pairs()):
        store.op(int(k), bool(w), warm_rec if i < n_warm else rec)
        if i < n_warm:
            warm_rec.clear()  # discard warm-up subops to bound memory
    hit_stats = store.stats() if hasattr(store, "stats") else {}
    return TraceResult(
        trace=rec.compile(),
        mem_per_op=rec.n_mem / max(rec.n_ops, 1),
        io_per_op=rec.n_io / max(rec.n_ops, 1),
        hit_stats=hit_stats,
    )
