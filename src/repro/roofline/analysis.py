"""Roofline terms from a compiled dry-run artifact.

Three terms per (arch x shape x mesh) cell, in seconds:

  compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory     = HLO_bytes / (chips * HBM_BW)
  collective = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. Collective bytes
are NOT in cost_analysis: we parse the compiled HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute. Sizes are whole-array (global) bytes, so
they are divided by the participating chip count; a ring all-reduce moves
2(n-1)/n of the shard per link, which we fold in as the standard factor.

Hardware constants (TPU v5e-class target): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, 50 GB/s per ICI link.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'bf16[128,4096]'-style shape string."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUP_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2  # permutes etc. -- pairwise


def _link_factor(kind: str, n: int) -> float:
    """Per-chip ICI link bytes as a multiple of the op's *output* bytes
    (post-SPMD shapes are per-device), assuming ring algorithms:
      all-gather       out * (n-1)/n      (receives every other shard)
      reduce-scatter   out * (n-1)        (input is n x output)
      all-reduce       2 * out * (n-1)/n  (RS + AG on same-size buffer)
      all-to-all       out * (n-1)/n
      collective-permute  out             (point to point)
    """
    if n <= 1:
        return 0.0
    if kind == "all-gather":
        return (n - 1) / n
    if kind == "reduce-scatter":
        return float(n - 1)
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind == "all-to-all":
        return (n - 1) / n
    return 1.0  # collective-permute


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-chip ICI link bytes by collective kind, parsed from compiled
    (SPMD-partitioned, per-device) HLO text.

    Lines look like ``%name = bf16[8,128]{1,0} all-reduce(...),
    replica_groups=[16,16]<=[256]...`` (possibly tuple-shaped). The
    output-shape bytes are scaled by the ring-traffic factor for the
    parsed replica-group size.
    """
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in _COLLECTIVES:
            # match "= <shape> kind(" (also -start variants; skip -done)
            if f" {kind}(" not in s and f" {kind}-start(" not in s:
                continue
            eq = s.find("= ")
            if eq < 0:
                continue
            shape_part = s[eq + 2:]
            total = 0
            if shape_part.startswith("("):
                inner = shape_part[1 : shape_part.find(")")]
                for comp in inner.split("),"):
                    total += _shape_bytes(comp.split("{")[0])
            else:
                total = _shape_bytes(shape_part.split("{")[0].split(" ")[0])
            out[kind] += total * _link_factor(kind, _group_size(s))
            counts[kind] += 1
            break
    out["_counts"] = counts  # type: ignore[assignment]
    return out


_DEF_RE = re.compile(r"%([\w\.\-]+) = (\w+\[[\d,]*\])")
_DOT_OPERANDS_RE = re.compile(r" dot\(([^)]*)\)")


def dot_bytes(hlo_text: str) -> float:
    """Fusion-adjusted HBM-traffic estimate: operand + output bytes of every
    dot (matmul) in the per-device HLO.

    Rationale: on the TPU target, elementwise/norm ops fuse into the matmuls
    that produce/consume them, so HBM traffic is dominated by matmul operand
    streams; the CPU backend's ``bytes accessed`` counts every unfused
    intermediate and overstates TPU traffic by an order of magnitude. This
    estimate errs slightly high where the CPU inserts f32 converts around
    bf16 dots, and slightly low by ignoring pure-elementwise traffic; it is
    the number the memory roofline term uses, with the raw ``bytes
    accessed`` kept alongside as an upper bound.
    """
    shapes: dict[str, str] = {}
    for m in _DEF_RE.finditer(hlo_text):
        shapes[m.group(1)] = m.group(2)
    total = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        if " dot(" not in s:
            continue
        m = _DEF_RE.search(s)
        if m:
            total += _shape_bytes(m.group(2))
        ops = _DOT_OPERANDS_RE.search(s)
        if ops:
            for ref in ops.group(1).split(","):
                name = ref.strip().lstrip("%")
                if name in shapes:
                    total += _shape_bytes(shapes[name])
    return total


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                     # global (sum over chips)
    hlo_bytes: float                     # global, raw 'bytes accessed' (upper bound)
    coll_bytes_link: float = 0.0         # per-chip ICI link bytes (ring-adjusted)
    hbm_bytes_est: float = 0.0           # global, fusion-adjusted (dot streams)
    coll_by_kind: dict = field(default_factory=dict)
    model_flops: float = 0.0
    per_device_memory: float = 0.0       # bytes (args+temps, memory_analysis)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        b = self.hbm_bytes_est or self.hlo_bytes
        return b / (self.chips * HBM_BW)

    @property
    def t_memory_upper(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_link / ICI_BW

    @property
    def bottleneck(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute term / max term: 1.0 == perfectly compute-bound."""
        m = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / m if m else 0.0

    def row(self) -> dict:
        return {
            **asdict(self),
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_memory_upper": self.t_memory_upper,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape, n_params: int, n_active: int) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for prefill, 2*N*B for decode
    (N = active params for MoE)."""
    D = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * D
    if shape.kind == "prefill":
        return 2.0 * n_active * D
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def count_params(specs) -> int:
    import jax
    import numpy as np

    leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes")
    )
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def active_params(cfg, specs) -> int:
    """Active-per-token parameter count (MoE: top_k + shared experts only)."""
    import numpy as np

    total = count_params(specs)
    if cfg.family != "moe":
        return total
    # subtract the inactive routed experts
    per_expert = 3 * cfg.d_model * cfg.d_expert * cfg.n_layers
    inactive = (cfg.n_experts - cfg.top_k) * per_expert
    return int(total - inactive)
