"""whisper-small [audio] -- enc-dec, conv frontend stubbed.
[arXiv:2212.04356; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,                # 12 encoder + 12 decoder blocks
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    norm_kind="ln",
    mlp_kind="gelu",
    use_rope=False,
    tie_embeddings=True,
    max_positions=32770,        # decoder positions extended for decode_32k
    n_frames=1500,
    citation="arXiv:2212.04356",
).resolve()
