"""Model configuration dataclass + the four assigned input shapes."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # attention / block options
    qkv_bias: bool = False
    norm_kind: str = "rms"         # rms | ln
    mlp_kind: str = "swiglu"       # swiglu | gelu
    tie_embeddings: bool = False
    use_rope: bool = True
    rope_theta: float = 1e4
    sliding_window: int | None = None
    causal: bool = True
    attn_block_kv: int = 1024
    # MoE
    n_experts: int = 0
    n_shared: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 32           # dispatch groups (align with data shards)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    attn_every: int = 0            # hybrid: shared attn at every k-th layer
    # RWKV
    rwkv_head_dim: int = 64
    # enc-dec
    max_positions: int = 0         # decoder learned-position table (0 = unused)
    n_frames: int = 1500           # stub audio frontend output length
    # VLM
    vision_dim: int = 1152
    n_patches: int = 0             # stub patch-embedding prefix length
    # lowering/analysis
    unroll_inner: int = 0        # unroll cap for attention/SSM chunk loops (metric lowering)
    unroll_layers: bool = False  # unroll layer/microbatch scans (metric lowering)
    remat_groups: int = 0        # 2-level (sqrt) activation remat: outer scan groups
    # training numerics
    moment_dtype: str = "float32"  # bf16 for the >=100B configs (memory)
    citation: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def resolve(self) -> "ModelConfig":
        return self.replace(head_dim=self.head_dim_)


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 7),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256,
        vocab=512,
        head_dim=32,
        attn_block_kv=64,
        ssm_chunk=16,
        max_positions=512 if cfg.max_positions else 0,
        n_frames=24 if cfg.family == "encdec" else cfg.n_frames,
        sliding_window=64 if cfg.sliding_window else None,
        vision_dim=48 if cfg.family == "vlm" else cfg.vision_dim,
        n_patches=8 if cfg.family == "vlm" else 0,
    )
    if cfg.family == "moe":
        kw.update(n_experts=8, n_shared=min(cfg.n_shared, 2), top_k=2, d_expert=64)
    if cfg.family in ("hybrid", "ssm"):
        kw.update(ssm_state=16, ssm_head_dim=32, rwkv_head_dim=32)
    if cfg.family == "hybrid":
        kw.update(attn_every=3)
    return cfg.replace(**kw).resolve()
