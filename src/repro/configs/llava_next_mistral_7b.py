"""llava-next-mistral-7b [vlm] -- anyres tiling, Mistral-7B backbone.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    rope_theta=1e6,
    sliding_window=4096,        # Mistral-v0.1 SWA; enables long_500k ring cache
    vision_dim=1152,
    n_patches=2880,             # anyres: 576 base + 4 x 576 tiles (stub)
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
).resolve()
