"""rwkv6-3b "Finch" [ssm] -- attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                 # bookkeeping only; attn-free
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    use_rope=False,
    rwkv_head_dim=64,
    ssm_chunk=32,
    citation="arXiv:2404.05892",
).resolve()
