"""starcoder2-3b [dense] -- GQA, RoPE, LN + GELU FFN. [arXiv:2402.19173; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    qkv_bias=True,
    norm_kind="ln",
    mlp_kind="gelu",
    rope_theta=1e5,
    tie_embeddings=True,
    sliding_window=4096,
    citation="arXiv:2402.19173",
).resolve()
