"""qwen1.5-110b [dense] -- QKV bias, GQA kv=8. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    moment_dtype="bfloat16",
    remat_groups=10,    # ZeRO-sharded moments in bf16 at >=100B
    citation="hf:Qwen/Qwen1.5-0.5B",
).resolve()
