"""zamba2-7b [hybrid] -- Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,               # 13 shared-attn sites over 81 layers
    citation="arXiv:2411.15242",
).resolve()
