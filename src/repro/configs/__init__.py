"""Architecture registry: the ten assigned configs, selectable by ``--arch``."""
from __future__ import annotations

from .base import SHAPES, ModelConfig, Shape, smoke_config  # noqa: F401

from . import (  # noqa: E402
    deepseek_moe_16b,
    llama3_405b,
    llava_next_mistral_7b,
    qwen1_5_110b,
    qwen2_5_3b,
    qwen2_moe_a2_7b,
    rwkv6_3b,
    starcoder2_3b,
    whisper_small,
    zamba2_7b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        llava_next_mistral_7b,
        qwen2_5_3b,
        starcoder2_3b,
        qwen1_5_110b,
        llama3_405b,
        deepseek_moe_16b,
        qwen2_moe_a2_7b,
        zamba2_7b,
        rwkv6_3b,
        whisper_small,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return list(ARCHS)


def shape_applicable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs, and why not if it doesn't.

    long_500k needs sub-quadratic attention: it runs for SSM/hybrid archs and
    for sliding-window transformers (O(window) ring cache); it is skipped for
    pure full-attention archs. Enc-dec has no 500k decode either.
    """
    if shape.name == "long_500k":
        if cfg.family == "encdec":
            return False, "enc-dec: 500k autoregressive decode not architecturally meaningful"
        if cfg.family in ("ssm", "hybrid"):
            return True, ""
        if cfg.sliding_window is not None:
            return True, ""
        return False, "pure full attention: O(seq) KV at 500k is not sub-quadratic"
    return True, ""


def shape_config(cfg: ModelConfig, shape: Shape) -> ModelConfig:
    """Per-shape config adjustments (documented in DESIGN.md SS5)."""
    if shape.name == "long_500k" and cfg.family == "hybrid":
        # zamba2's shared attention runs sliding-window at 500k context
        return cfg.replace(sliding_window=4096)
    if shape.kind == "prefill" and shape.seq_len > 8192:
        # larger flash blocks for long prefill
        return cfg.replace(attn_block_kv=2048)
    return cfg
