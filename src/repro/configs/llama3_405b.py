"""llama3-405b [dense] -- GQA kv=8, 128k vocab. [arXiv:2407.21783; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    rope_theta=5e5,
    moment_dtype="bfloat16",
    remat_groups=14,
    citation="arXiv:2407.21783",
).resolve()
