"""Uniform API over the model zoo: one dispatch point per architecture.

Every family exposes the same four entry points through :func:`get_api`:

  * ``param_specs(cfg)``                   -> ParamSpec tree
  * ``logits(params, batch, cfg)``         -> (logits, aux_loss)
  * ``init_cache(cfg, batch, max_len)``    -> decode cache/state pytree
  * ``decode(params, cache, tokens, cfg)`` -> (logits, new cache)

``batch`` is a dict with 'tokens' (B, S_text) plus optional 'patches'
(VLM stub) / 'frames' (audio stub); 'targets' and 'loss_mask' are consumed
by the train step, not the model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax.numpy as jnp

from .models import hybrid, moe, rwkv6, transformer, vlm, whisper

__all__ = ["ArchAPI", "get_api"]


@dataclass(frozen=True)
class ArchAPI:
    param_specs: Callable[[Any], dict]
    logits: Callable[..., tuple]
    init_cache: Callable[..., Any]
    decode: Callable[..., tuple]


def _zero_aux(logits):
    return logits, jnp.zeros((), jnp.float32)


def _dense_api() -> ArchAPI:
    return ArchAPI(
        param_specs=transformer.param_specs,
        logits=lambda p, b, cfg, remat=True: _zero_aux(
            transformer.forward(p, b["tokens"], cfg, remat=remat)
        ),
        init_cache=transformer.init_cache,
        decode=transformer.decode_step,
    )


def _moe_api() -> ArchAPI:
    return ArchAPI(
        param_specs=moe.param_specs,
        logits=lambda p, b, cfg, remat=True: moe.forward(
            p, b["tokens"], cfg, remat=remat
        ),
        init_cache=moe.init_cache,
        decode=moe.decode_step,
    )


def _vlm_api() -> ArchAPI:
    return ArchAPI(
        param_specs=vlm.param_specs,
        logits=lambda p, b, cfg, remat=True: _zero_aux(
            vlm.forward(p, b["tokens"], cfg, patches=b.get("patches"), remat=remat)
        ),
        init_cache=vlm.init_cache,
        decode=vlm.decode_step,
    )


def _hybrid_api() -> ArchAPI:
    return ArchAPI(
        param_specs=hybrid.param_specs,
        logits=lambda p, b, cfg, remat=True: _zero_aux(
            hybrid.forward(p, b["tokens"], cfg, remat=remat)
        ),
        init_cache=hybrid.init_cache,
        decode=hybrid.decode_step,
    )


def _ssm_api() -> ArchAPI:
    return ArchAPI(
        param_specs=rwkv6.param_specs,
        logits=lambda p, b, cfg, remat=True: _zero_aux(
            rwkv6.forward(p, b["tokens"], cfg, remat=remat)
        ),
        init_cache=lambda cfg, batch, max_len: rwkv6.init_state(cfg, batch),
        decode=rwkv6.decode_step,
    )


def _encdec_api() -> ArchAPI:
    return ArchAPI(
        param_specs=whisper.param_specs,
        logits=lambda p, b, cfg, remat=True: _zero_aux(
            whisper.forward(p, b["tokens"], cfg, frames=b["frames"], remat=remat)
        ),
        init_cache=lambda cfg, batch, max_len: whisper.init_cache(
            cfg, batch, max_len, cfg.n_frames
        ),
        decode=whisper.decode_step,
    )


_FAMILIES = {
    "dense": _dense_api,
    "moe": _moe_api,
    "vlm": _vlm_api,
    "hybrid": _hybrid_api,
    "ssm": _ssm_api,
    "encdec": _encdec_api,
}


def get_api(cfg) -> ArchAPI:
    return _FAMILIES[cfg.family]()
