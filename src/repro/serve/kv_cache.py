"""Tiered paged KV cache: the paper's offloaded-index/cache design for LLM
serving.

Pages live in a *slow tier* page store (host DRAM / CXL-class memory on a
real deployment; a dedicated buffer here) and are accessed ONLY through the
prefetch pipeline (``repro.kernels.paged_decode_attention``). A per-sequence
block table plays the role of the KV store's index; free pages are managed
by a free list. The prefetch depth is sized by the paper's model via
``repro.core.planner.plan_pipeline_depth``: T_mem = per-page attention
compute, E = the rest of the decode step (MLP/collectives), L_mem = the
slow-tier fetch latency -- the same Theta_prob law that governs the KV
stores governs this pipeline.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.latency_model import OpParams
from ..core.planner import plan_pipeline_depth
from ..core.tiering import MemoryTier, TPU_HOST

__all__ = ["PagedKVCache", "PageStoreConfig"]


@dataclass(frozen=True)
class PageStoreConfig:
    n_pages: int
    page_size: int = 64
    n_kv_heads: int = 8
    head_dim: int = 128
    n_layers: int = 4
    dtype: object = jnp.bfloat16
    tier: MemoryTier = TPU_HOST


class PagedKVCache:
    """Block-table paged KV store for one model's decode path.

    Host-side bookkeeping (free list, per-sequence tables) is numpy; the
    page payloads are jax arrays shaped (L, n_pages, page, Hkv, D).
    """

    def __init__(self, cfg: PageStoreConfig):
        self.cfg = cfg
        shape = (cfg.n_layers, cfg.n_pages, cfg.page_size, cfg.n_kv_heads,
                 cfg.head_dim)
        self.k_pages = jnp.zeros(shape, cfg.dtype)
        self.v_pages = jnp.zeros(shape, cfg.dtype)
        self.free: list[int] = list(range(cfg.n_pages))[::-1]
        self.tables: dict[int, list[int]] = {}
        self.lengths: dict[int, int] = {}

    # -- index management (the "in-memory index" of the paper) -------------
    def admit(self, seq_id: int, prompt_len: int) -> bool:
        need = -(-max(prompt_len, 1) // self.cfg.page_size)
        if len(self.free) < need:
            return False
        self.tables[seq_id] = [self.free.pop() for _ in range(need)]
        self.lengths[seq_id] = prompt_len
        return True

    def extend(self, seq_id: int, n_tokens: int = 1) -> bool:
        new_len = self.lengths[seq_id] + n_tokens
        need = -(-new_len // self.cfg.page_size) - len(self.tables[seq_id])
        if need > len(self.free):
            return False
        for _ in range(need):
            self.tables[seq_id].append(self.free.pop())
        self.lengths[seq_id] = new_len
        return True

    def release(self, seq_id: int) -> None:
        self.free.extend(self.tables.pop(seq_id, []))
        self.lengths.pop(seq_id, None)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.cfg.n_pages

    # -- page IO ------------------------------------------------------------
    def write_prompt(self, seq_id: int, k: jnp.ndarray, v: jnp.ndarray) -> None:
        """k, v: (L, S, Hkv, D) from prefill; scattered into this sequence's
        pages (page-aligned writes into the slow tier)."""
        L, S, Hkv, D = k.shape
        page = self.cfg.page_size
        table = self.tables[seq_id]
        pad = len(table) * page - S
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = k.reshape(L, len(table), page, Hkv, D)
        vp = v.reshape(L, len(table), page, Hkv, D)
        idx = jnp.asarray(table, jnp.int32)
        self.k_pages = self.k_pages.at[:, idx].set(kp)
        self.v_pages = self.v_pages.at[:, idx].set(vp)

    def append_token(self, seq_id: int, k_t: jnp.ndarray, v_t: jnp.ndarray) -> None:
        """k_t, v_t: (L, Hkv, D) for the newly decoded position."""
        pos = self.lengths[seq_id] - 1
        page_idx = self.tables[seq_id][pos // self.cfg.page_size]
        slot = pos % self.cfg.page_size
        self.k_pages = self.k_pages.at[:, page_idx, slot].set(k_t)
        self.v_pages = self.v_pages.at[:, page_idx, slot].set(v_t)

    def batch_views(self, seq_ids: list[int], ppseq: int | None = None):
        """(block_tables (B, ppseq), lengths (B,)) padded for the kernel."""
        if ppseq is None:
            ppseq = max((len(self.tables[s]) for s in seq_ids), default=1)
        bt = np.zeros((len(seq_ids), ppseq), np.int32)
        ln = np.zeros((len(seq_ids),), np.int32)
        for i, s in enumerate(seq_ids):
            t = self.tables[s]
            bt[i, : len(t)] = t
            ln[i] = self.lengths[s]
        return jnp.asarray(bt), jnp.asarray(ln)

    # -- model-driven pipeline sizing ----------------------------------------
    def plan_prefetch_depth(
        self,
        t_page_compute: float,
        t_step_other: float,
        max_depth: int = 16,
    ) -> int:
        """Size the DMA staging-buffer count from the paper's Theta model:
        one 'operation' = one decode step of one sequence = (pages) memory
        suboperations + the rest of the step as the 'IO'."""
        avg_pages = max(
            int(np.mean([len(t) for t in self.tables.values()])) if self.tables else 1,
            1,
        )
        p = OpParams(
            M=float(avg_pages),
            T_mem=t_page_compute,
            T_io_pre=t_step_other / 2,
            T_io_post=t_step_other / 2,
            T_sw=0.0,
            P=2,
            S=1.0,
        )
        plan = plan_pipeline_depth(p, self.cfg.tier.latency, p_max=max_depth)
        return plan.prefetch_depth
