"""Continuous-batching serving engine over the tiered paged KV cache.

Requests are admitted into decode slots as pages allow; each engine step
decodes one token for every active sequence with the paged-attention
prefetch pipeline; finished sequences release their pages. The scheduler
overlaps, in the paper's terms, the "memory suboperations" (page fetches
of step t+1's attention) with the "IO" (the dense compute of step t) --
Observation O2 is why a deep slow tier does not stall decode.

This engine runs end-to-end on CPU for the smoke models (examples/ and
tests/); the dry-run lowers its step for the production mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tf
from ..models.layers import DTYPE, init_params
from ..kernels.ops import paged_decode_attention
from .kv_cache import PagedKVCache, PageStoreConfig

__all__ = ["Request", "ServeEngine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Minimal but real: prefill -> paged decode -> sample -> continue."""

    def __init__(self, cfg, params=None, *, n_pages: int = 256,
                 page_size: int = 16, max_slots: int = 8, seed: int = 0,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params if params is not None else init_params(
            tf.param_specs(cfg), jax.random.PRNGKey(seed)
        )
        self.cache = PagedKVCache(PageStoreConfig(
            n_pages=n_pages, page_size=page_size, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, n_layers=cfg.n_layers,
        ))
        self.max_slots = max_slots
        self.greedy = greedy
        self.active: dict[int, Request] = {}
        self.waiting: list[Request] = []
        self.rng = jax.random.PRNGKey(seed + 1)
        self._jit_prefill = jax.jit(lambda p, t: tf.prefill(p, t, cfg))
        self.steps = 0

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def run(self, max_steps: int = 1000) -> list[Request]:
        finished = []
        while (self.waiting or self.active) and self.steps < max_steps:
            finished.extend(self.step())
        return finished

    # ----------------------------------------------------------------- core
    def _admit(self) -> None:
        while self.waiting and len(self.active) < self.max_slots:
            req = self.waiting[0]
            if not self.cache.admit(req.rid, len(req.prompt)):
                break
            self.waiting.pop(0)
            logits, cache = self._jit_prefill(
                self.params, jnp.asarray(req.prompt)[None]
            )
            # cache["k"]: (L, 1, W, Hkv, D) -> per-layer (L, S, Hkv, D)
            S = len(req.prompt)
            k = cache["k"][:, 0, :S]
            v = cache["v"][:, 0, :S]
            self.cache.write_prompt(req.rid, k, v)
            tok = self._sample(logits[:, -1])[0]
            req.out_tokens.append(int(tok))
            self.active[req.rid] = req

    def _sample(self, logits: jnp.ndarray) -> np.ndarray:
        if self.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1)).reshape(-1)
        self.rng, sub = jax.random.split(self.rng)
        return np.asarray(jax.random.categorical(sub, logits)).reshape(-1)

    def _decode_active(self) -> jnp.ndarray:
        """One token for every active sequence via the paged kernel."""
        cfg = self.cfg
        seq_ids = sorted(self.active)
        tokens = jnp.asarray(
            [[self.active[s].out_tokens[-1]] for s in seq_ids], jnp.int32
        )
        for s in seq_ids:
            self.cache.extend(s, 1)
        bt, lengths = self.cache.batch_views(seq_ids)
        B = len(seq_ids)
        x = self.params["embed"].astype(DTYPE)[tokens]          # (B,1,d)
        pos = lengths - 1                                        # new slot index
        positions = pos[:, None]
        new_k, new_v = [], []
        for li in range(cfg.n_layers):
            lw = jax.tree.map(lambda a: a[li], self.params["layers"])
            h = tf._norm(x, None, cfg, "attn_norm", "attn_norm_b", lw)
            q, k, v = tf._qkv(h, lw, cfg, positions)
            # write the new token's KV into its page slot, then attend over
            # the page store through the DMA-prefetch kernel.
            new_k.append(k[:, 0])
            new_v.append(v[:, 0])
            self._write_token_layer(li, seq_ids, k[:, 0], v[:, 0], pos)
            o = paged_decode_attention(
                q[:, 0], self.cache.k_pages[li], self.cache.v_pages[li],
                bt, lengths,
            )
            o = jnp.einsum("be,ed->bd", o.reshape(B, -1), lw["wo"])[:, None]
            x = x + o
            h = tf._norm(x, None, cfg, "mlp_norm", "mlp_norm_b", lw)
            x = x + tf.mlp(h, lw["mlp"], cfg.mlp_kind)
        x = tf._norm(x, self.params, cfg, "final_norm", "final_norm_b")
        head = (self.params["embed"].T if cfg.tie_embeddings
                else self.params["lm_head"])
        return jnp.einsum("bsd,dv->bsv", x, head)[:, 0]

    def _write_token_layer(self, li, seq_ids, k_t, v_t, pos) -> None:
        page = self.cache.cfg.page_size
        for i, s in enumerate(seq_ids):
            p = int(pos[i])
            page_idx = self.cache.tables[s][p // page]
            slot = p % page
            self.cache.k_pages = self.cache.k_pages.at[li, page_idx, slot].set(k_t[i])
            self.cache.v_pages = self.cache.v_pages.at[li, page_idx, slot].set(v_t[i])

    def step(self) -> list[Request]:
        self._admit()
        finished: list[Request] = []
        if self.active:
            logits = self._decode_active()
            toks = self._sample(logits)
            for tok, s in zip(toks, sorted(self.active)):
                req = self.active[s]
                req.out_tokens.append(int(tok))
                if len(req.out_tokens) >= req.max_new_tokens:
                    req.done = True
                    finished.append(req)
                    self.cache.release(s)
                    del self.active[s]
        self.steps += 1
        return finished
