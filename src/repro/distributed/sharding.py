"""Logical-axis -> mesh-axis rules: FSDP x TP x EP (x SP) in one table.

Two rule tables, because the same logical name means different things on a
weight and on an activation:

  * **param rules** -- weights are 2-D sharded: the 'embed' (row) dimension
    is FSDP-sharded over the data axis (and the pod axis on multi-pod
    meshes), while 'mlp' / 'heads_flat' / 'vocab' / 'expert' columns are
    tensor/expert-parallel over the model axis. XLA GSPMD inserts the
    per-layer all-gathers (FSDP) and the row-parallel reduce-scatters (TP)
    automatically from these specs.
  * **activation rules** -- 'batch' is data(+pod)-parallel, the hidden
    'mlp'/'heads' dimensions are model-parallel, 'embed' is replicated.
    'seq' is optionally sequence-parallel (set ``seq_shard=True`` for the
    long-context shapes).

Keeping both in one module means a new architecture only has to name its
axes; no per-tensor hand sharding anywhere in the model zoo.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.layers import logical_to_pspec, param_shardings

__all__ = [
    "param_rules",
    "act_rules",
    "state_shardings",
    "batch_shardings",
    "batch_axes",
]


def _batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def batch_axes(mesh: Mesh):
    return _batch_axes(mesh)


def param_rules(mesh: Mesh, fsdp: bool = True, policy: str = "baseline") -> dict[str, Any]:
    """Weight sharding: FSDP on 'embed' rows x TP/EP on the model axis.

    Policies (the hillclimb levers, EXPERIMENTS.md SSPerf):
      baseline -- FSDP(data) x TP(model)
      dp2d     -- no tensor parallelism: weights fully sharded over BOTH
                  axes (FSDP over data x model); right for small models
                  where TP all-reduces dominate
      sp       -- baseline weights + sequence-parallel activations
      serve    -- TP-resident weights, NO FSDP: there is no optimizer state
                  at inference, so weights live sharded over the model axis
                  and are never all-gathered (kills the decode cells'
                  dominant collective)
    """
    if policy == "dp2d":
        ba = _batch_axes(mesh)
        both = (ba, "model") if isinstance(ba, str) else (*ba, "model")
        return {
            "embed": both, "embed2": None, "mlp": None, "heads_flat": None,
            "heads": None, "vocab": None, "expert": None, "expert_mlp": None,
            "expert_group": both, "layers": None, "seq": None, "batch": None,
        }
    return {
        "embed": None if (policy == "serve" or not fsdp) else _batch_axes(mesh),
        "embed2": None,
        "mlp": "model",
        "heads_flat": "model",
        "heads": "model",
        "vocab": "model",
        "expert": "model",
        "expert_mlp": None,
        "expert_group": _batch_axes(mesh),
        "layers": None,
        "seq": None,
        "batch": None,
    }


def act_rules(mesh: Mesh, seq_shard: bool = False, policy: str = "baseline") -> dict[str, Any]:
    """Activation sharding: DP batch, TP hidden, optional SP sequence."""
    ba = _batch_axes(mesh)
    if policy == "dp2d":
        both = (ba, "model") if isinstance(ba, str) else (*ba, "model")
        return {
            "batch": both, "seq": None, "embed": None, "embed2": None,
            "mlp": None, "heads_flat": None, "heads": None, "vocab": None,
            "expert": None, "expert_mlp": None, "expert_group": both,
            "seq_res": None, "layers": None,
        }
    return {
        "batch": ba,
        "seq": None,  # never 'model': q/k/v constraints carry head sharding
        "seq_res": "model" if (seq_shard or policy == "sp") else None,
        "embed": None,
        "embed2": None,
        "mlp": "model",
        "heads_flat": "model",
        "heads": "model",
        "vocab": "model",
        "expert": "model",
        "expert_mlp": None,
        "expert_group": ba,
        "layers": None,
    }


def state_shardings(specs, mesh: Mesh, fsdp: bool = True, policy: str = "baseline"):
    """NamedSharding tree for a ParamSpec tree (weights + optimizer moments,
    which inherit their parameter's sharding)."""
    return param_shardings(specs, mesh, param_rules(mesh, fsdp, policy))


def _dp_size(mesh: Mesh, policy: str = "baseline") -> int:
    size = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        size *= mesh.shape["pod"]
    if policy == "dp2d":
        size *= mesh.shape["model"]
    return size


def batch_shardings(batch_specs: dict, mesh: Mesh, policy: str = "baseline") -> dict:
    """Input batches are sharded on their leading (batch) dimension only;
    a batch smaller than the data-parallel extent stays replicated (the
    long_500k single-sequence shapes are model-parallel-only work)."""
    ba = _batch_axes(mesh)
    if policy == "dp2d":
        ba = (ba, "model") if isinstance(ba, str) else (*ba, "model")
    dp = _dp_size(mesh, policy)

    def one(s):
        lead = ba if s.shape and s.shape[0] % dp == 0 else None
        spec = P(lead, *([None] * (len(s.shape) - 1)))
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, batch_specs)


def cache_shardings(cache_specs, mesh: Mesh, batch_dim: int = 1):
    """Decode caches: shard the batch dimension (layer-stacked pytrees have
    batch at dim 1) and -- for the big (layers, B, S, H, D) KV stacks --
    the sequence dimension over the model axis, so a 32k x 128-seq cache
    spreads over the whole mesh instead of one data row. Attention over a
    sequence-sharded cache lowers to a partial-softmax + all-reduce, which
    the dry-run validates. Divisibility guards keep batch-1 shapes valid."""
    ba = _batch_axes(mesh)
    dp = _dp_size(mesh)
    mp = mesh.shape["model"]

    def one(s):
        spec: list = [None] * len(s.shape)
        bd = batch_dim if len(s.shape) > batch_dim else 0
        if s.shape and s.shape[bd] % dp == 0:
            spec[bd] = ba
        if len(s.shape) >= 5 and s.shape[2] % mp == 0 and s.shape[2] >= mp * 128:
            spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_specs)
