"""Fused whole-step scheduler kernel (batched over grid cells).

:mod:`repro.core.sim.replay_jax` replays one scheduler *step* -- wake the
completed parked threads, pop the FIFO ready ring, execute one suboperation
(MEM stall / PREIO submit / op completion), issue the next prefetch -- for
every cell of a latency x threads grid at once.  This module is that step,
factored so the exact same arithmetic runs two ways:

  * :func:`make_substep` builds the pure-jnp step body; the jax backend's
    ``lax.scan`` path calls it directly (one call per step, ``unroll``
    amortizing dispatch);
  * :func:`fused_steps` wraps the same body in a single
    ``pl.pallas_call`` that keeps all scheduler planes resident in
    VMEM/registers while an inner ``fori_loop`` executes a batch of K
    substeps per kernel invocation (the ``substeps`` knob), so the planes
    do not round-trip through HBM between steps.

The TPU is the compile target; on CPU the kernel runs in ``interpret=True``
mode (the :mod:`repro.kernels.compat` convention), which is how CI validates
it bit-for-bit against the jnp path on tiny grids
(``tests/test_replay_jax.py``).  Bit-identity holds by construction: both
paths execute ``make_substep``'s ops in the same order; the kernel variant
only switches the per-row gather/scatter *implementation* to one-hot
select/merge forms (``onehot_updates``), which produce bit-identical values
(a one-term masked sum is exact) while staying on the VPU-friendly subset
of ops.

Tag-encoded minima
------------------
``argmin`` is several times the cost of ``min`` on every backend we care
about (and the old step needed four of them).  Instead, every plane that is
reduced to "earliest entry + which thread" stores its key with the entry
*index* packed into the low :data:`TAG_BITS` bits of the float64 mantissa
(:func:`tag_encode`): a single ``min`` reduction then returns the winning
key and its index together (:func:`tag_tid`).  Keys are non-negative
simulated-time stamps whose meaningful differences (>= nanoseconds on a
seconds-scale clock) dwarf the ``2**TAG_BITS``-ulp tag perturbation, so
the encoding never reorders distinct keys; exact ties break toward the
lower index, matching ``argmin`` -- and matching the scalar loop's
lowest-tid-first drain of simultaneous IO completions.

State layout (the kernel ref contract)
--------------------------------------
``G`` cells, ``T`` thread slots, ``P`` prefetch slots, ``S`` SSDs:

  ============  ============  =================================================
  plane         shape/dtype   contents
  ============  ============  =================================================
  ``cf``        (G, 6) f64    0 now, 1 prefetch-bw clock, 2 lock clock,
                              3 t_start, 4 t_end, 5 measured stall seconds
  ``ci``        (G, 6) i32    0 trace cursor, 1 IO round-robin, 2 completed
                              ops, 3 measured ops, 4 measured MEM accesses,
                              5 measuring flag
  ``stamp``     (G, T) f64    ready threads' ring ticket: the *pop time*
                              at which the thread last started a
                              suboperation (tag-encoded with the tid);
                              ``BIG`` when parked or inactive
  ``wake``      (G, T) f64    parked threads' IO completion time, stored
                              *exact* (the idle-skip reads it back as a
                              time; ``ring_keys`` tags it on the fly);
                              ``+inf`` when ready or inactive.  Threads
                              whose IO completed are derived into the
                              ring at pop time, never written back
  ``pft``       (G, T, 2) f64 0 outstanding prefetch completion time,
                              1 trace span ``end * 2**SPAN_SHIFT + i``
                              (both integers < 2**SPAN_SHIFT: exact)
  ``pf_slots``  (G, P) f64    P-deep in-flight prefetch window completion
                              times, stored exact (the all-busy delay
                              reads the minimum back as a time; the slot
                              pick tags on the fly)
  ``io_tok``    (G, S) f64    per-device IOPS token clocks (clock configs)
  ``io_bw``     (G, S) f64    per-device bandwidth token clocks
  ============  ============  =================================================

With ``n_cores = C > 1`` (see :func:`make_substep`) the thread planes hold
``T = C * T_per_core`` core-major slots tagged by *global* tid,
``pf_slots`` becomes ``(G, C, P)``, and one extra plane ``cores``
``(G, C, 2)`` (0 local clock, 1 prefetch-bw clock) sits between
``pf_slots`` and the IO clocks; ``cf[:, 0]``/``cf[:, 1]`` then carry the
global drain horizon (running max of pop times, mirroring the loop's
shared parked heap -- see the in-step comment) / nothing.

The K-substep batching contract: one :func:`fused_steps` invocation consumes
a ``(K, n_u, G)`` block of pre-drawn uniforms and advances the state by
exactly K substeps -- state crosses the kernel boundary only once per K
steps, and the uniform feed is the only per-step input, so a scan over
blocks of K is step-for-step identical to a scan over single steps.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.sim.arrivals import HIST_BINS, HIST_INV_LN_RATIO, HIST_LO
from ..core.trace_ir import MEM, PREIO

__all__ = [
    "TAG_BITS", "SPAN_SHIFT", "BIG", "EPOCH", "tag_encode", "tag_tid",
    "tag_value",
    "pack_span", "unpack_span", "make_substep", "fused_steps",
]

TAG_BITS = 8                       # index bits packed into the mantissa
_TAG_MASK = np.uint64((1 << TAG_BITS) - 1)
_KEY_MASK = np.uint64(~_TAG_MASK & 0xFFFFFFFFFFFFFFFF)

# Sentinel for "no entry" (parked/inactive threads in the stamp plane).
# Finite -- not inf -- so it tag-decodes to thread 0 instead of garbage;
# real stamps stay far below it.
BIG = float(
    (np.float64(1e30).view(np.uint64) & _KEY_MASK).view(np.float64))

SPAN_SHIFT = 26                    # pft span packing: end*2**26 + i, exact
_SPAN = float(1 << SPAN_SHIFT)     # in f64 while both stay below 2**26
_INV_SPAN = 1.0 / _SPAN

# Spacing for "time zero, position k" init keys.  The CPU runtimes run
# with FTZ/DAZ, so a denormal key (e.g. raw-bits ``k``) silently compares
# equal to 0.0 and the tagged min collapses every initial ring slot onto
# index 0.  Spacing by the smallest *normal* f64 keeps the init keys
# ordered, distinct, flush-proof, and far below any real simulated time.
EPOCH = float(np.finfo(np.float64).tiny)


def tag_encode(key, idx):
    """Pack ``idx`` into the low :data:`TAG_BITS` mantissa bits of ``key``.

    ``key`` must be non-negative and distinct keys must differ by more than
    ``2**TAG_BITS`` ulps for the order to survive (see module docstring).
    """
    bits = jax.lax.bitcast_convert_type(key, jnp.uint64)
    tag = idx.astype(jnp.uint64) & _TAG_MASK
    return jax.lax.bitcast_convert_type((bits & _KEY_MASK) | tag,
                                        jnp.float64)


def tag_tid(enc):
    """The index packed by :func:`tag_encode` (int32)."""
    bits = jax.lax.bitcast_convert_type(enc, jnp.uint64)
    return (bits & _TAG_MASK).astype(jnp.int32)


def tag_value(enc):
    """The key with its tag bits cleared (a 256-ulp floor of the original)."""
    bits = jax.lax.bitcast_convert_type(enc, jnp.uint64)
    return jax.lax.bitcast_convert_type(bits & _KEY_MASK, jnp.float64)


def pack_span(start, end):
    """``end * 2**SPAN_SHIFT + start`` as exact f64 (both < 2**SPAN_SHIFT)."""
    return end * _SPAN + start


def unpack_span(span):
    """Inverse of :func:`pack_span` -> ``(i, end)`` as f64 integers."""
    end = jnp.floor(span * _INV_SPAN)
    return span - end * _SPAN, end


def make_substep(*, n_u, n_ssd, has_eps, has_rho, has_jitter, has_rio,
                 has_bio, has_bmem, has_lock, has_arr=False, has_lat=False,
                 has_deadline=False, has_degrade=False, onehot_updates=False,
                 eager_wmin=False, n_cores=1):
    """Build the scheduler substep body, specialized on the static config.

    The returned ``substep(state, u, kd, se, arr, nthr_g, n_trace,
    L_mem_g, warm_g, n_ops, dyn) -> state`` advances every cell by one
    suboperation execution.  ``state`` is the tuple documented in the
    module docstring (``io_tok``/``io_bw`` present only when an IO clock
    is configured); ``u`` is the ``(n_u, G)`` uniform block for this step;
    ``kd``/``se`` are the packed trace columns; ``arr`` the shared
    open-loop arrival timestamp vector (a 1-wide dummy when ``has_arr``
    is off); ``nthr_g`` the per-cell thread counts (int32, read only when
    ``has_arr``); ``dyn`` the tuple of dynamic scalars (the degrade pair
    last).

    ``has_arr`` replays the loops' open-loop driver: op completions fetch
    the next arrival at the shared index ``n_cores * nthr_g + done``
    (clamped to the last entry), stamp it as the new op's start, gate the
    next prefetch issue at ``max(now, arrival)``, and park the thread on
    the wake plane until the arrival clock when it is still in the
    future.  ``has_lat`` widens ``pft`` with an op-start slot, widens
    ``ci`` with a missed-op counter, and appends two state planes --
    ``hist`` (G, HIST_BINS) f64 sojourn log-histogram counts and
    ``latmax`` (G,) f64 exact max sojourn (see
    :mod:`repro.core.sim.arrivals` for the binning and its error bound).
    ``has_deadline`` additionally classifies measured sojourns above
    ``dyn``'s deadline as missed (counted, excluded from the histogram).
    ``has_degrade`` multiplies ``L_io`` by ``dyn``'s ``io_degrade`` for
    IOs submitted at ``now >= T_degrade`` (mid-run device slowdown; same
    submission-time rule as the loops' ``SSDClocks.submit``).

    ``onehot_updates`` switches the per-row thread-plane gathers/scatters
    to bit-identical one-hot select/merge forms (the Pallas kernel's
    VPU-friendly subset); ``eager_wmin`` always runs the starved-cell
    idle-skip re-derivation instead of branching on whether any cell is
    starved (kernels prefer straight-line code; the resulting values are
    identical either way).

    ``n_cores > 1`` adds a core axis: thread planes become ``(G, C*T)``
    core-major with *global* tids in the tag bits (so ``C*T`` must stay
    <= 2**TAG_BITS), the prefetch window and its bandwidth clock become
    per-core (``pf_slots`` is ``(G, C, P)``, and a new ``cores`` plane
    ``(G, C, 2)`` carries each core's local clock and prefetch-bw clock),
    while the trace cursor, op counters, T_lock clock, and SSD token
    clocks stay shared -- exactly the generic loop's sharing.  Each step
    first picks the core with the earliest next-event time (its local
    clock if it has a runnable thread, else its earliest parked wake --
    the loop's core heap + idle-skip collapsed into one tagged min; ties
    break to the lower core id like ``heapq``) and then runs the
    single-core step body on that core's thread segment.  The
    ``n_cores == 1`` path is byte-for-byte the pre-existing substep.
    """
    has_io_clock = has_rio or has_bio
    multicore = n_cores > 1
    f = jnp.float64
    i4 = jnp.int32

    def sel_thread(plane, tid):
        """``plane[g, tid[g]]`` -- gather, or a one-term masked sum."""
        if onehot_updates:
            T = plane.shape[1]
            hot = jax.lax.broadcasted_iota(i4, (plane.shape[0], T), 1) \
                == tid[:, None]
            if plane.ndim == 3:
                return jnp.sum(jnp.where(hot[:, :, None], plane, 0.0), 1)
            return jnp.sum(jnp.where(hot, plane, 0.0), 1)
        if plane.ndim == 3:
            return jnp.take_along_axis(plane, tid[:, None, None], 1)[:, 0]
        return jnp.take_along_axis(plane, tid[:, None], 1)[:, 0]

    def upd_thread(plane, tid, val):
        """``plane.at[g, tid[g]].set(val[g])`` -- scatter or one-hot merge."""
        if onehot_updates:
            T = plane.shape[1]
            hot = jax.lax.broadcasted_iota(i4, (plane.shape[0], T), 1) \
                == tid[:, None]
            if plane.ndim == 3:
                return jnp.where(hot[:, :, None], val[:, None, :], plane)
            return jnp.where(hot, val[:, None], plane)
        rows = jnp.arange(plane.shape[0], dtype=i4)
        return plane.at[rows, tid].set(val)

    def substep(s, u, kd, se, arr, nthr_g, n_trace, L_mem_g, warm_g,
                n_ops, dyn):
        (T_sw, eps, rho, L_dram, L_io, jitter, inv_R, cost_bw_io, L_switch,
         cost_bmem, T_lock, deadline, T_degrade, io_degrade) = dyn
        cf, ci, stamp, wake, pft, pf_slots = s[:6]
        si = 6
        if multicore:
            cores = s[si]
            si += 1
        if has_io_clock:
            io_tok, io_bw = s[si], s[si + 1]
            si += 2
        if has_lat:
            lat_hist, latmax = s[si], s[si + 1]
            si += 2
        G, T = stamp.shape
        un = iter(range(n_u))

        def lmem(uu, L):
            """sample_lmem for scalar latencies: DRAM-tier short-circuit."""
            if has_rho:
                return jnp.where(uu >= rho, L_dram, L)
            return L

        counted0 = ci[:, 3]
        reached = counted0 >= n_ops    # cell already took its last op

        if multicore:
            # -- core selection: the loop's core heap as one tagged min -----
            # Heap entries are the cores' clocks at their last *yield*, NOT
            # their next-event times: the loop pops the core whose last run
            # ended earliest, and a core popped with an empty ring jumps
            # straight to its earliest parked wake and executes there -- it
            # never re-enters the heap re-keyed.  So selection compares the
            # yield clocks, and the idle-skip applies only to the *selected*
            # core (the single-core path per core segment).  The scan is an
            # exact unrolled min (C is small and static): cores running the
            # same ops sit within a few ulps of each other, well inside the
            # 2**TAG_BITS quantum, so a tag-encoded min would collapse
            # distinct clocks into ties and pick the wrong core.  Strict
            # ``<`` breaks ties to the lower cid, exactly ``heapq``'s
            # (t, cid) entries.
            C, Tpc = n_cores, T // n_cores
            core_now = cores[:, :, 0]                        # (G, C)
            wake3 = wake.reshape(G, C, Tpc)
            stamp3 = stamp.reshape(G, C, Tpc)
            cstar = jnp.zeros((G,), i4)
            now = core_now[:, 0]
            for c in range(1, C):
                cand = core_now[:, c]
                earlier = cand < now
                cstar = jnp.where(earlier, c, cstar)
                now = jnp.where(earlier, cand, now)
            # The selected core's ring head / idle-skip, exactly the
            # single-core derivation over its thread segment; tags are
            # global tids, so ``tid`` indexes the flat planes directly.
            wake_c = sel_thread(wake3, cstar)                # (G, Tpc)
            stamp_c = sel_thread(stamp3, cstar)
            gtid_c = (cstar[:, None] * Tpc
                      + jax.lax.broadcasted_iota(i4, (G, Tpc), 1))

            def ring_keys_mc(now_v):
                wkey = tag_encode(
                    jnp.maximum(jnp.minimum(wake_c, BIG), T * EPOCH), gtid_c)
                return jnp.where(wake_c <= now_v[:, None], wkey, stamp_c)

            head = jnp.min(ring_keys_mc(now), axis=1)
            starved = head >= BIG

            def skip_mc(now_v):
                w_min = jnp.min(wake_c, axis=1)
                now2 = jnp.where(starved, jnp.maximum(now_v, w_min), now_v)
                return now2, jnp.min(ring_keys_mc(now2), axis=1)

            if eager_wmin:
                now, head = skip_mc(now)
            else:
                now, head = jax.lax.cond(
                    jnp.any(starved), lambda: skip_mc(now),
                    lambda: (now, head))
            pop_now = now
        else:
            now = cf[:, 0]

            # -- pop the ring head: one tag-encoded min replaces argmin -----
            # Ring stamps are *entry tickets*: a thread re-enters the ring
            # keyed by its pop time, and a parked thread whose IO completed
            # joins at its wake time -- so the FIFO order is just time
            # order, and parked-but-complete threads can be *derived* into
            # the ring at pop time instead of being written back.  The key
            # plane below stays a temporary the backend fuses into the min
            # reduction; the materialized wake drain it replaces (two
            # carried full-plane writes per step) was the single largest
            # cost of the old step.
            tids_row = jax.lax.broadcasted_iota(i4, (G, T), 1)

            def ring_keys(now_v):
                wkey = tag_encode(
                    jnp.maximum(jnp.minimum(wake, BIG), T * EPOCH), tids_row)
                return jnp.where(wake <= now_v[:, None], wkey, stamp)

            head = jnp.min(ring_keys(now), axis=1)

            # -- idle-skip: nothing ready, nothing eligible -> jump to the --
            # earliest wake-up and re-derive the keys.  Starvation is rare
            # for healthy thread counts, so the jnp path branches around the
            # second pass at run time; the kernel path runs it
            # straight-line.  The values agree either way: a cell that did
            # not starve re-derives identical keys from an unchanged
            # ``now``.
            starved = head >= BIG

            def skip(now_v):
                w_min = jnp.min(wake, axis=1)
                now2 = jnp.where(starved, jnp.maximum(now_v, w_min), now_v)
                return now2, jnp.min(ring_keys(now2), axis=1)

            if eager_wmin:
                now, head = skip(now)
            else:
                now, head = jax.lax.cond(
                    jnp.any(starved), lambda: skip(now), lambda: (now, head))
        tid = tag_tid(head)
        # The popped thread's next ring ticket.  The scalar loop drains
        # wake-ups only at iteration start, *after* the previous runner
        # re-joined the deque -- so a thread woken during the runner's
        # execution window queues behind it.  Keying the re-entrant
        # runner by its pop time (not its yield time) reproduces that
        # order exactly: wakes <= pop time drained at or before this
        # iteration and sort ahead; later wakes sort behind.  The key is
        # clamped to T*EPOCH -- above every init stamp, still a normal
        # float -- because a pop at time zero (every core's first pop)
        # would otherwise store a denormal ticket that FTZ/DAZ runtimes
        # read back as 0.0 with tag 0, re-running the popped thread ahead
        # of the untouched ring instead of appending it at the tail.
        ticket = tag_encode(jnp.maximum(now, T * EPOCH), tid)

        pft_r = sel_thread(pft, tid)                 # (G, 2) or (G, 3)
        pf_tid0 = pft_r[:, 0]
        op_start_r = pft_r[:, 2] if has_lat else None
        i_f, end_f = unpack_span(pft_r[:, 1])
        kd_i = kd[i_f.astype(i4)]                    # (G, 2)
        kind = kd_i[:, 0]
        dur = kd_i[:, 1]

        # -- MEM: stall on the outstanding prefetch (or an eps re-fetch) ----
        is_mem = kind == MEM
        ready_at = pf_tid0
        if has_eps:
            u_eps = u[next(un)]
            u_evict = u[next(un)]
            ready_at = jnp.where(u_eps < eps,
                                 now + lmem(u_evict, L_mem_g), ready_at)
        stall = ready_at - now
        stalled = is_mem & (stall > 0.0)
        live = (ci[:, 5] > 0) & ~reached
        mem_stall = cf[:, 5] + jnp.where(stalled & live, stall, 0.0)
        mem_acc = ci[:, 4] + (is_mem & live)
        now = jnp.where(stalled, ready_at, now) + dur

        # -- op completion: counters, measurement window, next op, T_lock ---
        i2 = i_f + 1.0
        eoo = i2 >= end_f
        done = ci[:, 2] + eoo
        meas_evt = eoo & (done >= warm_g) & ~reached
        measuring = jnp.maximum(ci[:, 5], meas_evt)
        counted = counted0 + meas_evt
        t_start = jnp.where(meas_evt & (cf[:, 3] < 0.0), now, cf[:, 3])
        if has_arr:
            # The next op's arrival: the loops consume one shared index
            # per issue -- n_cores * n_threads at init, then one per
            # completion -- so completion k (pre-increment ``done``) reads
            # index total_threads + ci[:, 2].  Clamped to the last entry,
            # matching the loops' guard (only reachable after the cell
            # latched, where nothing observable depends on it).
            arr_next = arr[jnp.minimum(
                n_cores * nthr_g + ci[:, 2], arr.shape[0] - 1)]
        if has_lat:
            # Sojourn at the pre-T_lock completion instant, mirroring the
            # loops (collection happens before the lock charge there).
            sojourn = now - op_start_r
            if has_deadline:
                is_miss = sojourn > deadline
            else:
                is_miss = jnp.zeros_like(eoo)
            rec = meas_evt & ~is_miss
            missed = ci[:, 6] + (meas_evt & is_miss)
            b = jnp.clip(
                jnp.floor(jnp.log(jnp.maximum(sojourn, HIST_LO) / HIST_LO)
                          * HIST_INV_LN_RATIO),
                0, HIST_BINS - 1).astype(i4)
            inc = jnp.where(rec, 1.0, 0.0)
            if onehot_updates:
                hot = jax.lax.broadcasted_iota(
                    i4, lat_hist.shape, 1) == b[:, None]
                lat_hist = lat_hist + jnp.where(hot, inc[:, None], 0.0)
            else:
                rows = jnp.arange(G, dtype=i4)
                lat_hist = lat_hist.at[rows, b].add(inc)
            latmax = jnp.where(rec, jnp.maximum(latmax, sojourn), latmax)
            op_start_new = jnp.where(
                eoo, arr_next if has_arr else now, op_start_r)
        se_c = se[ci[:, 0]]                          # (G, 2)
        span_next = jnp.where(eoo, pack_span(se_c[:, 0], se_c[:, 1]),
                              pft_r[:, 1] + 1.0)
        ni = jnp.where(eoo, se_c[:, 0], i2)
        cursor = jnp.where(eoo, (ci[:, 0] + 1) % n_trace, ci[:, 0])
        lock_next = cf[:, 2]
        if has_lock:
            lock_end = jnp.maximum(now, lock_next) + T_lock
            now = jnp.where(eoo, lock_end, now)
            lock_next = jnp.where(eoo, lock_end, lock_next)

        # -- PREIO: submit against the striped per-device token clocks ------
        park = (kind == PREIO) & ~eoo
        io_rr = ci[:, 1]
        if not has_io_clock:
            svc = now
            io_out = ()
        elif n_ssd == 1:
            # Inlined single-device clocks (the common matrix config);
            # clocks only advance for cells actually submitting an IO.
            tok1, bw1 = io_tok[:, 0], io_bw[:, 0]
            svc = now
            if has_rio:
                svc = jnp.maximum(svc, tok1)
                tok1 = jnp.where(park, svc + inv_R, tok1)
            if has_bio:
                svc = jnp.maximum(svc, bw1)
                bw1 = jnp.where(park, svc + cost_bw_io, bw1)
            io_out = (tok1[:, None], bw1[:, None])
        else:
            from .token_clock import _update
            devmask = (jax.lax.broadcasted_iota(i4, (G, n_ssd), 1)
                       == (io_rr % n_ssd)[:, None]) & park[:, None]
            svc, tok2d, bw2d = _update(
                now[:, None], devmask, io_tok, io_bw, inv_R, cost_bw_io)
            svc = svc[:, 0]
            io_out = (tok2d, bw2d)
            io_rr = io_rr + park
        lat_io = L_io
        if has_degrade:
            # Same submission-time rule as the loops: the row's current
            # time decides whether this IO pays the degraded latency.
            lat_io = jnp.where(now >= T_degrade, L_io * io_degrade, L_io)
        if has_jitter:
            lat_io = lat_io * (1.0 + jitter * (2.0 * u[next(un)] - 1.0))
        park_until = svc + lat_io + L_switch

        # -- issue the next suboperation's prefetch (P-deep window) ---------
        issue = kd[ni.astype(i4)][:, 0] == MEM
        # All P slots in flight <=> the window minimum is still in the
        # future, so the all-busy delay is just max(now, min slot); the
        # minimum slot is also the replacement target either way.
        if multicore:
            # The selected core's private window + bandwidth clock.
            slots_row = sel_thread(pf_slots, cstar)          # (G, P)
            pf_bw = sel_thread(cores, cstar)[:, 1]
        else:
            slots_row = pf_slots
            pf_bw = cf[:, 1]
        # Slots store *exact* completion times; the tagged key exists only
        # inside the min reduction, so the all-busy delay below is computed
        # from the true float (tag-flooring it drifts ~256 ulps per issue,
        # which compounds over long runs).  The EPOCH clamp keeps the
        # time-zero init keys normal under FTZ/DAZ.
        slot_iota = jax.lax.broadcasted_iota(i4, slots_row.shape, 1)
        slot = tag_tid(jnp.min(
            tag_encode(jnp.maximum(slots_row, EPOCH), slot_iota), axis=1))
        slot_min = sel_thread(slots_row, slot)
        if has_arr:
            # Open loop: a not-yet-arrived op issues at its arrival clock
            # (post-T_lock now, exactly the loops' max(now, arrival)).
            t_iss = jnp.where(eoo, jnp.maximum(now, arr_next), now)
        else:
            t_iss = now
        pstart = jnp.maximum(t_iss, slot_min)
        if has_bmem:
            pstart = jnp.maximum(pstart, pf_bw)
            pf_bw = jnp.where(issue, pstart + cost_bmem, pf_bw)
        u_pf = u[next(un)] if has_rho else None
        comp = pstart + lmem(u_pf, L_mem_g)
        slots_row = upd_thread(
            slots_row, slot,
            jnp.where(issue, comp, slot_min))
        pf_slots = (upd_thread(pf_slots, cstar, slots_row) if multicore
                    else slots_row)
        pf_tid = jnp.where(issue, comp, pf_tid0)

        # -- yield: context switch, park or re-enter the ready ring ---------
        now = now + T_sw
        if has_arr:
            # Open loop: the freshly fetched op has not arrived yet --
            # park until the arrival clock.  Mutually exclusive with the
            # IO park (that one requires ~eoo).
            park_arr = eoo & (arr_next > now)
            parked_any = park | park_arr
            wake_val = jnp.where(park_arr, arr_next,
                                 jnp.where(park,
                                           jnp.maximum(park_until, now),
                                           jnp.inf))
        else:
            parked_any = park
            wake_val = jnp.where(park, jnp.maximum(park_until, now),
                                 jnp.inf)
        stamp = upd_thread(stamp, tid, jnp.where(parked_any, BIG, ticket))
        # Wake times are stored exact (no tag): the starved idle-skip and
        # the eligibility compare read them back as *times*, and a tagged
        # store would perturb those reads by up to 2**TAG_BITS ulps per
        # park.  ``ring_keys`` re-tags on the fly for the pop ordering.
        wake = upd_thread(wake, tid, wake_val)
        pft_cols = [pf_tid, span_next]
        if has_lat:
            pft_cols.append(op_start_new)
        pft = upd_thread(pft, tid, jnp.stack(pft_cols, axis=1))

        crossed = (counted >= n_ops) & ~reached
        if multicore:
            cores = upd_thread(cores, cstar,
                               jnp.stack([now, pf_bw], axis=1))
            # -- global drain horizon: the loop's cross-core wake-ups -------
            # The scalar loop drains the *shared* parked heap against the
            # global pop horizon, so when one core's clock jumps ahead
            # (e.g. a starved idle-skip), parked threads of *lagging* cores
            # enter their rings early -- and run below their own core's
            # clock, before their IO completion time.  ``cf[:, 0]`` carries
            # that horizon H (the running max of pop times); threads whose
            # wake fell at or below H while still above their core's clock
            # are materialized into the stamp plane here, ticketed at their
            # core's current clock (the ring-tail position the loop's
            # append gives them).  Threads whose wake is at or below their
            # own clock stay derived (key = wake) as in the single-core
            # path.
            H = jnp.maximum(cf[:, 0], pop_now)
            clock_t = jnp.broadcast_to(
                cores[:, :, 0][:, :, None], (G, C, Tpc)).reshape(G, T)
            tids_all = jax.lax.broadcasted_iota(i4, (G, T), 1)
            early = (wake <= H[:, None]) & (wake > clock_t)
            # Ticket one tag-grid step *below* the core clock: the loop
            # appends the drained thread before the core's next pop, whose
            # runner re-enters ticketed at that same clock value -- the
            # bias keeps the drained thread strictly ahead of it.  Real
            # pops sit >= T_sw apart, far more than one grid step, so the
            # bias cannot cross an earlier ticket.
            cbits = jax.lax.bitcast_convert_type(
                jnp.maximum(clock_t, 2.0 * T * EPOCH), jnp.uint64)
            tail_key = jax.lax.bitcast_convert_type(
                cbits - jnp.uint64(1 << TAG_BITS), jnp.float64)
            stamp = jnp.where(early, tag_encode(tail_key, tids_all), stamp)
            wake = jnp.where(early, jnp.inf, wake)
            # The loop reports elapsed time against the *latest* core clock
            # at exit (``max(c.now for c in cores)``).
            t_end = jnp.where(crossed, jnp.max(cores[:, :, 0], axis=1),
                              cf[:, 4])
            pf_bw = cf[:, 1]   # cf slot 1 is unused with a core axis
            now = H            # cf slot 0 carries the drain horizon
        else:
            t_end = jnp.where(crossed, now, cf[:, 4])
        cf = jnp.stack([now, pf_bw, lock_next, t_start, t_end, mem_stall],
                       axis=1)
        ci_cols = [cursor, io_rr, done, counted, mem_acc, measuring]
        if has_lat:
            ci_cols.append(missed)
        ci = jnp.stack(ci_cols, axis=1)
        out = (cf, ci, stamp, wake, pft, pf_slots)
        if multicore:
            out = out + (cores,)
        out = out + io_out
        if has_lat:
            out = out + (lat_hist, latmax)
        return out

    return substep


def fused_steps(substep, state, u_block, kd, se, arr, n_trace, L_mem_g,
                nthr_g, warm_g, n_ops, dyn, *, interpret: bool | None = None):
    """Advance ``state`` by K substeps in one ``pallas_call`` invocation.

    ``substep`` must come from :func:`make_substep` (built with
    ``onehot_updates=True, eager_wmin=True`` for the kernel-friendly op
    subset); ``u_block`` is the ``(K, n_u, G)`` uniform feed; ``arr`` the
    shared arrival vector (a 1-wide dummy closed loop) and ``nthr_g`` the
    per-cell thread counts.  All planes are kernel refs: they are read
    once, carried through an in-kernel ``fori_loop`` over the K substeps,
    and written back once, so on a compiled backend the scheduler state
    never leaves VMEM between substeps.  ``interpret=None`` auto-selects
    interpreter mode off-TPU (CPU CI validates bit-identity against the
    jnp scan path this way).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    K = u_block.shape[0]
    if u_block.shape[1] == 0:
        # Draw-free configs (no eps/rho/jitter) consume no uniforms; a
        # zero-size ref breaks pallas_call, so feed a 1-wide dummy block
        # the substep never reads.
        u_block = jnp.zeros((K, 1) + u_block.shape[2:], u_block.dtype)
    n_state = len(state)
    dyn_arr = jnp.stack([jnp.asarray(d, jnp.float64) for d in dyn])

    def kernel(*refs):
        ins = refs[:n_state + 9]
        outs = refs[n_state + 9:]
        s0 = tuple(r[:] for r in ins[:n_state])
        (u_ref, kd_ref, se_ref, arr_ref, ntr_ref, lmem_ref, nthr_ref,
         warm_ref, nops_ref) = ins[n_state:n_state + 9]
        kd_v, se_v = kd_ref[:], se_ref[:]
        arr_v = arr_ref[:]
        n_trace = ntr_ref[0]
        L_mem_g, warm_g = lmem_ref[:], warm_ref[:]
        nthr_v = nthr_ref[:]
        n_ops = nops_ref[0]
        dyn_v = tuple(nops_ref[1 + j] for j in range(dyn_arr.shape[0]))

        def body(k, s):
            return substep(s, u_ref[k], kd_v, se_v, arr_v, nthr_v,
                           n_trace, L_mem_g, warm_g, n_ops, dyn_v)

        final = jax.lax.fori_loop(0, K, body, s0)
        for ref, val in zip(outs, final):
            ref[:] = val

    # n_ops and the dynamic scalars travel in one small f64 vector; the
    # trace length is a (1,) i32 ref.
    scal = jnp.concatenate([jnp.asarray([n_ops], jnp.float64), dyn_arr])
    out = pl.pallas_call(
        kernel,
        out_shape=tuple(
            jax.ShapeDtypeStruct(s.shape, s.dtype) for s in state),
        interpret=interpret,
    )(*state, u_block, kd, se, arr,
      jnp.asarray(n_trace, jnp.int32).reshape(1),
      L_mem_g, nthr_g, warm_g, scal)
    return out
