"""RWKV-6 WKV recurrence as a Pallas TPU kernel.

Sequential over time (the recurrence is inherently serial), parallel over
(batch x head). Time is split into chunks that stream through VMEM via the
automatic pipeline (the 'arbitrary' innermost grid dimension); the (D x D)
matrix state persists in VMEM scratch across chunk iterations. Inside a
chunk the per-step update is VPU work: an outer product k v^T, a diagonal
decay scale, and a vector-matrix read-out r.S.

This is the TPU adaptation of the fla/RWKV CUDA kernels: where the GPU
version assigns a thread per channel and loops t in registers, the TPU
version assigns a grid cell per (b, h) and keeps the whole state tile
resident in VMEM -- same dataflow, memory-hierarchy-appropriate tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

__all__ = ["wkv6"]


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref, *,
            chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u_row = u_ref[0].astype(jnp.float32)             # (D,)

    def step(j, state):
        rt = r_ref[0, j, 0].astype(jnp.float32)      # (D,)
        kt = k_ref[0, j, 0].astype(jnp.float32)
        vt = v_ref[0, j, 0].astype(jnp.float32)
        wt = jnp.exp(lw_ref[0, j, 0].astype(jnp.float32))
        kv = kt[:, None] * vt[None, :]               # (D, D) outer product
        out = jnp.einsum(
            "d,de->e", rt, state + u_row[:, None] * kv,
            preferred_element_type=jnp.float32,
        )
        o_ref[0, j, 0] = out.astype(o_ref.dtype)
        return state * wt[:, None] + kv

    state = jax.lax.fori_loop(0, chunk, step, state_ref[...])
    state_ref[...] = state


def wkv6(
    r: jnp.ndarray,              # (B, S, H, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_w: jnp.ndarray,          # (B, S, H, D) <= 0
    u: jnp.ndarray,              # (H, D)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, H, D = r.shape
    chunk = min(chunk, S)
    nc = pl.cdiv(S, chunk)
    assert S % chunk == 0, "pad sequence to a chunk multiple"

    kernel = functools.partial(_kernel, chunk=chunk)
    spec = lambda b, h, c: (b, c, h, 0)
    blk = (1, chunk, 1, D)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec(blk, spec),
            pl.BlockSpec(blk, spec),
            pl.BlockSpec(blk, spec),
            pl.BlockSpec(blk, spec),
            pl.BlockSpec((1, D), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec(blk, spec),
        out_shape=jax.ShapeDtypeStruct((B, S, H, D), r.dtype),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, log_w, u)
