"""Jit'd public wrappers for the Pallas kernels.

The TPU is the *target*; on CPU (this container) every kernel runs in
``interpret=True`` mode, which executes the kernel body in Python for
correctness validation against :mod:`repro.kernels.ref`. ``use_pallas``
lets callers fall back to the pure-jnp paths in :mod:`repro.models.layers`.
"""
from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention as _flash
from .paged_kv_gather import paged_decode_attention as _paged
from .wkv6 import wkv6 as _wkv6

__all__ = ["flash_attention", "paged_decode_attention", "wkv6", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "sliding_window",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, sliding_window=None,
                    block_q: int = 256, block_k: int = 256):
    """(B, Hq, S, D) head-major flash attention."""
    return _flash(q, k, v, causal=causal, sliding_window=sliding_window,
                  block_q=block_q, block_k=block_k, interpret=not on_tpu())


@functools.partial(jax.jit, static_argnames=("n_buffers",))
def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths,
                           n_buffers: int = 2):
    """Decode attention over a slow-tier page store with DMA prefetch."""
    return _paged(q, k_pages, v_pages, block_tables, lengths,
                  n_buffers=n_buffers, interpret=not on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, log_w, u, chunk: int = 64):
    """RWKV-6 linear recurrence, chunk-streamed."""
    return _wkv6(r, k, v, log_w, u, chunk=chunk, interpret=not on_tpu())
