"""Paged decode attention with an explicit double-buffered DMA prefetch
pipeline -- the paper's technique, TPU-native.

The KV page store lives in a *slow tier* (HBM here; host memory on a real
deployment) and is never blocked by the automatic Pallas pipeline: pages
are pulled on demand through ``pltpu.make_async_copy`` using the per-
sequence block table, exactly the pointer-chase -> prefetch -> yield ->
use discipline of the paper:

  * issue the DMA for page i+1  (== ``__builtin_prefetch``),
  * compute attention on page i (== the thread the core switches to),
  * wait on the DMA only when page i+1's compute needs it
    (== the load that hits cache because the prefetch landed).

``n_buffers`` is the prefetch queue depth P of the paper's model (Eq. 3):
the planner (repro.core.planner) sizes it from the measured page-fetch
latency and per-page compute time via the same Theta equations, because
the law max{T_compute, L_fetch/P} is hardware-independent.

Block tables arrive via scalar prefetch (PrefetchScalarGridSpec) so the
page indices are known to the DMA engine ahead of the compute -- the
TPU equivalent of computing the next pointer before yielding.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

__all__ = ["paged_decode_attention"]

NEG_INF = -1e30


def _kernel(
    # scalar-prefetch operands
    block_tables_ref,            # (B, ppseq) int32, SMEM
    lengths_ref,                 # (B,) int32, SMEM
    # array operands
    q_ref,                       # (1, rep, D) VMEM block
    k_pages_ref,                 # (P, page, Hkv, D) ANY (slow tier)
    v_pages_ref,
    # outputs
    o_ref,                       # (1, rep, D)
    # scratch
    k_buf, v_buf,                # (n_buf, page, D) VMEM staging
    sem,                         # DMA semaphores (n_buf, 2)
    *,
    page: int,
    n_buf: int,
    scale: float,
):
    b = pl.program_id(0)
    h = pl.program_id(1)
    length = lengths_ref[b]
    n_pages = jax.lax.div(length + page - 1, page)

    def start_fetch(p_idx, slot):
        page_id = block_tables_ref[b, p_idx]
        pltpu.make_async_copy(
            k_pages_ref.at[page_id, :, h], k_buf.at[slot], sem.at[slot, 0]
        ).start()
        pltpu.make_async_copy(
            v_pages_ref.at[page_id, :, h], v_buf.at[slot], sem.at[slot, 1]
        ).start()

    def wait_fetch(p_idx, slot):
        page_id = block_tables_ref[b, p_idx]
        pltpu.make_async_copy(
            k_pages_ref.at[page_id, :, h], k_buf.at[slot], sem.at[slot, 0]
        ).wait()
        pltpu.make_async_copy(
            v_pages_ref.at[page_id, :, h], v_buf.at[slot], sem.at[slot, 1]
        ).wait()

    # warm the pipeline: issue the first min(n_buf, n_pages) prefetches
    for slot in range(n_buf):
        @pl.when(slot < n_pages)
        def _prime(slot=slot):
            start_fetch(slot, slot)

    q = q_ref[0, 0].astype(jnp.float32) * scale     # (rep, D)

    def body(p_idx, carry):
        acc, m, l = carry
        slot = jax.lax.rem(p_idx, n_buf)
        # wait for this page's DMA, load it out of the staging buffer, and
        # only then re-issue the slot for page p_idx + n_buf (the paper's
        # yield: pages p+1 .. p+n_buf-1 are already in flight, so the MXU
        # works while the DMA engine fills the queue back to depth P).
        wait_fetch(p_idx, slot)
        k = k_buf[slot].astype(jnp.float32)          # (page, D)
        v = v_buf[slot].astype(jnp.float32)

        @pl.when(p_idx + n_buf < n_pages)
        def _next():
            start_fetch(p_idx + n_buf, slot)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (rep, page)
        pos = p_idx * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(pos < length, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_new = acc * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ()))
        )
        return acc_new, m_new, l_new

    rep, D = q.shape
    acc0 = jnp.zeros((rep, D), jnp.float32)
    m0 = jnp.full((rep, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((rep, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_pages, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-37)).astype(o_ref.dtype)


def paged_decode_attention(
    q: jnp.ndarray,              # (B, Hq, D) one new token per sequence
    k_pages: jnp.ndarray,        # (P, page, Hkv, D) slow-tier page store
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,   # (B, ppseq) int32
    lengths: jnp.ndarray,        # (B,) int32
    *,
    n_buffers: int = 2,          # prefetch depth "P" of the paper's model
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    n_pages_store, page, Hkv, _ = k_pages.shape
    rep = Hq // Hkv
    ppseq = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)
    n_buf = max(2, min(n_buffers, ppseq))

    qg = q.reshape(B, Hkv, rep, D)

    kernel = functools.partial(
        _kernel, page=page, n_buf=n_buf, scale=scale
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, rep, D), lambda b, h, *_: (b, h, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, D), lambda b, h, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_buf, page, D), k_pages.dtype),
            pltpu.VMEM((n_buf, page, D), v_pages.dtype),
            pltpu.SemaphoreType.DMA((n_buf, 2)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables, lengths, qg.reshape(B, Hkv, rep, D), k_pages, v_pages)
    return out.reshape(B, Hq, D)