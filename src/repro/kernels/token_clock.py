"""Per-op token-clock update as a Pallas kernel (batched over grid cells).

The hot scalar update of the simulator's IO path (see
``repro.core.sim.devices``) is the token-clock grant: admit a request at
``submit`` by taking ``svc = max(submit, clock)`` and advancing the clock by
the per-request spacing.  The jax sweep backend
(:mod:`repro.core.sim.replay_jax`) performs this update once per scheduler
step for *every* cell of the latency x threads grid at once, so the batched
form is pure VPU work over ``(n_cells, n_ssd)`` clock arrays:

  * ``devmask`` one-hot selects each cell's round-robin device (all-zero
    rows for cells whose current suboperation is not an IO submission);
  * the IOPS clock is granted first, then the bandwidth clock, matching the
    scalar loops' ``svc = max(svc, tok); tok = svc + 1/R_io`` order exactly;
  * clocks of unselected devices pass through unchanged.

The TPU is the target; on CPU the kernel runs in ``interpret=True`` mode
(the :mod:`repro.kernels.compat` convention), which is how CI validates it
against :func:`token_clock_update_ref` -- the pure-jnp twin used by the jax
backend's default (non-Pallas) path.  Both paths are bit-identical: same
ops, same order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["token_clock_update", "token_clock_update_ref"]


def _grant(submit, clocks, devmask, spacing):
    """One token-clock grant: ``svc = max(submit, clocks[dev])``, and the
    selected device's clock advances to ``svc + spacing``.

    ``submit`` is ``(G, 1)``, ``clocks``/``devmask`` are ``(G, n_ssd)``,
    ``spacing`` broadcasts.  A spacing of 0 disables the clock (grant
    passes through); a fully-masked row (no IO this step) is not gated:
    its ``at_dev`` reads 0.0, and simulated time is non-negative, so
    ``max(submit, 0) == submit``.
    """
    enabled = spacing > 0.0
    at_dev = jnp.sum(jnp.where(devmask, clocks, 0.0), axis=-1, keepdims=True)
    svc = jnp.where(enabled, jnp.maximum(submit, at_dev), submit)
    new_clocks = jnp.where(enabled & devmask, svc + spacing, clocks)
    return svc, new_clocks


def _update(submit, devmask, tok, bw, inv_r, cost_bw):
    """IOPS clock first, then bandwidth -- the bandwidth grant sees the
    IOPS-delayed service time, like ``SSDClocks.submit`` / the compiled
    loop.  All shapes as in :func:`_grant`."""
    svc, tok = _grant(submit, tok, devmask, inv_r)
    svc, bw = _grant(svc, bw, devmask, cost_bw)
    return svc, tok, bw


def _kernel(submit_ref, devmask_ref, tok_ref, bw_ref, inv_r_ref, cost_ref,
            svc_ref, tok_out_ref, bw_out_ref):
    svc, tok, bw = _update(
        submit_ref[:], devmask_ref[:] != 0, tok_ref[:], bw_ref[:],
        inv_r_ref[0, 0], cost_ref[0, 0],
    )
    svc_ref[:] = svc
    tok_out_ref[:] = tok
    bw_out_ref[:] = bw


def token_clock_update(submit, devmask, tok_next, bw_next, inv_r, cost_bw,
                       *, interpret: bool | None = None):
    """Pallas form of :func:`token_clock_update_ref` (same contract).

    ``interpret=None`` auto-selects interpreter mode off-TPU so the kernel
    runs (slowly, but correctly) on CPU CI; pass ``False`` to force
    compilation on a TPU backend.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    G, S = tok_next.shape
    dt = tok_next.dtype
    svc, tok, bw = pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((G, 1), dt),
            jax.ShapeDtypeStruct((G, S), dt),
            jax.ShapeDtypeStruct((G, S), dt),
        ),
        interpret=interpret,
    )(
        submit.reshape(G, 1).astype(dt),
        devmask.astype(jnp.int32),
        tok_next,
        bw_next,
        jnp.asarray(inv_r, dt).reshape(1, 1),
        jnp.asarray(cost_bw, dt).reshape(1, 1),
    )
    return svc[:, 0], tok, bw


def token_clock_update_ref(submit, devmask, tok_next, bw_next, inv_r,
                           cost_bw):
    """Pure-jnp reference: grant ``submit`` (``(G,)``) against the per-cell
    per-device clocks (``(G, n_ssd)``), device selected by the boolean
    one-hot ``devmask``.  ``inv_r``/``cost_bw`` are the clock spacings
    (``1/R_io`` and ``A_io/B_io``); a spacing of 0 disables that clock.
    Returns ``(svc, tok_next', bw_next')``.
    """
    svc, tok, bw = _update(
        submit[:, None], devmask, tok_next, bw_next,
        jnp.asarray(inv_r, tok_next.dtype),
        jnp.asarray(cost_bw, bw_next.dtype),
    )
    return svc[:, 0], tok, bw
