"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``*_ref`` is a direct, unoptimized statement of the math; kernel tests
sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "paged_decode_attention_ref", "wkv6_ref"]


def flash_attention_ref(
    q: jnp.ndarray,              # (B, S, Hq, D)
    k: jnp.ndarray,              # (B, S, Hkv, D)
    v: jnp.ndarray,
    causal: bool = True,
    sliding_window: int | None = None,
) -> jnp.ndarray:
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) / math.sqrt(D)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if sliding_window is not None:
        mask &= pos[:, None] - pos[None, :] < sliding_window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_attention_ref(
    q: jnp.ndarray,              # (B, Hq, D) -- one new token per sequence
    k_pages: jnp.ndarray,        # (P, page, Hkv, D) page store ("slow tier")
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,   # (B, pages_per_seq) int32
    lengths: jnp.ndarray,        # (B,) valid tokens per sequence
) -> jnp.ndarray:
    B, Hq, D = q.shape
    page = k_pages.shape[1]
    Hkv = k_pages.shape[2]
    rep = Hq // Hkv
    ppseq = block_tables.shape[1]
    # gather each sequence's pages into a contiguous (B, ppseq*page, Hkv, D)
    k_seq = k_pages[block_tables].reshape(B, ppseq * page, Hkv, D)
    v_seq = v_pages[block_tables].reshape(B, ppseq * page, Hkv, D)
    kk = jnp.repeat(k_seq, rep, axis=2)
    vv = jnp.repeat(v_seq, rep, axis=2)
    s = jnp.einsum(
        "bhd,bkhd->bhk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) / math.sqrt(D)
    valid = jnp.arange(ppseq * page)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhk,bkhd->bhd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def wkv6_ref(
    r: jnp.ndarray,              # (B, S, H, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_w: jnp.ndarray,          # (B, S, H, D), <= 0
    u: jnp.ndarray,              # (H, D)
) -> jnp.ndarray:
    """Sequential WKV recurrence (fp32):
    out_t = r_t . (S_{t-1} + (u*k_t) v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    B, S, H, D = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    wf = jnp.exp(log_w.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        out = jnp.einsum("bhd,bhde->bhe", rt, state + uf[None, :, :, None] * kv)
        new = state * wt[..., None] + kv
        return new, out

    s0 = jnp.zeros((B, H, D, D), jnp.float32)
    xs = tuple(x.transpose(1, 0, 2, 3) for x in (rf, kf, vf, wf))
    _, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype)
