"""Blocked flash attention as a Pallas TPU kernel.

TPU-native tiling: q is blocked (BQ x D) in VMEM, the kv loop is the
innermost ('arbitrary') grid dimension so K/V blocks stream HBM -> VMEM
through the automatic Pallas pipeline -- the hardware analogue of the
paper's prefetch-and-yield: block i+1 is being DMA'd while block i is on
the MXU. Online softmax state (m, l, acc) lives in VMEM scratch across kv
iterations. Causal/sliding-window blocks that are fully masked are skipped
via the grid index map (work elision, not masking).

Layout notes (MXU/VPU alignment): BQ and BK are multiples of 128 when the
sequence allows; D (head_dim) 64/128/256 are all lane-aligned. Grouped
query heads are folded into the q-block rows so GQA does not replicate KV.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, block_q: int, block_k: int, scale: float,
            seq_len: int, sliding_window: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale     # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)             # (BK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_len
    if causal:
        mask &= q_pos >= k_pos
    if sliding_window:
        mask &= q_pos - k_pos < sliding_window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ()))
    )

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,              # (B, Hq, S, D)  -- head-major layout
    k: jnp.ndarray,              # (B, Hkv, S, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    sliding_window: int | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (B, Hq, S, D). Grouped heads: Hq % Hkv == 0."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = pl.cdiv(S, block_q)
    nk = pl.cdiv(S, block_k)
    scale = 1.0 / math.sqrt(D)

    grid = (B, Hq, nq, nk)

    kernel = functools.partial(
        _kernel, causal=causal, block_q=block_q, block_k=block_k,
        scale=scale, seq_len=S, sliding_window=sliding_window or 0,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),      # m
            pltpu.VMEM((block_q, 1), jnp.float32),      # l
            pltpu.VMEM((block_q, D), jnp.float32),      # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
