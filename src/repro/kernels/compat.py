"""Version compatibility for jax.experimental.pallas.tpu.

``CompilerParams`` was called ``TPUCompilerParams`` before jax 0.6; the
kernels target the new name and fall back here so they run on both.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
