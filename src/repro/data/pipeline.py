"""Tokenized LM data pipeline: deterministic, shardable, resumable.

Sources: synthetic (seeded Markov-ish token streams -- no external data in
this container) or a binary token file. The pipeline is keyed by
(step, host_id): any host can reconstruct its shard of any step, which is
what makes restart-and-replay and elastic re-sharding trivial (the
fault-tolerance loop calls ``iterator(start_step)``).

Host->device prefetch: a depth-k queue of device_put futures -- the same
latency-hiding law as everything else in this repo (the step compute is
the "IO" that hides the host-copy "memory access").
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Iterator

import jax
import numpy as np

__all__ = ["DataConfig", "synthetic_batches", "file_batches", "prefetch"]


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1


def _host_slice(cfg: DataConfig) -> tuple[int, int]:
    per = cfg.global_batch // cfg.n_hosts
    return cfg.host_id * per, per


def synthetic_batches(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    """Deterministic synthetic LM batches: order-1 Markov streams whose
    transition structure gives a learnable (non-uniform) distribution."""
    start, per = _host_slice(cfg)
    step = start_step
    while True:
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id])
        )
        # next-token = affine hash of current + noise: learnable structure
        cur = rng.integers(0, cfg.vocab, (per, 1))
        toks = [cur]
        for _ in range(cfg.seq_len):
            nxt = (toks[-1] * 31 + 17) % cfg.vocab
            noise = rng.integers(0, cfg.vocab, (per, 1))
            take = rng.random((per, 1)) < 0.25
            toks.append(np.where(take, noise, nxt))
        seq = np.concatenate(toks, axis=1).astype(np.int32)
        yield {
            "tokens": seq[:, :-1],
            "targets": seq[:, 1:],
            "loss_mask": np.ones((per, cfg.seq_len), np.float32),
        }
        step += 1


def file_batches(path: str, cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    """Binary int32 token file, strided deterministically by (step, host)."""
    data = np.memmap(path, dtype=np.int32, mode="r")
    n_tokens = len(data)
    start, per = _host_slice(cfg)
    span = cfg.seq_len + 1
    n_seqs = n_tokens // span
    step = start_step
    while True:
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
        idx = rng.choice(n_seqs, cfg.global_batch, replace=False)[start : start + per]
        seq = np.stack([data[i * span : (i + 1) * span] for i in idx])
        yield {
            "tokens": seq[:, :-1].astype(np.int32),
            "targets": seq[:, 1:].astype(np.int32),
            "loss_mask": np.ones((per, cfg.seq_len), np.float32),
        }
        step += 1


def prefetch(it: Iterator[dict], depth: int = 2, sharding=None) -> Iterator[dict]:
    """Host->device prefetch queue (depth = the paper's P, once again)."""
    q: deque = deque()
    lock = threading.Lock()

    def put_one():
        try:
            batch = next(it)
        except StopIteration:
            return False
        dev = jax.tree.map(
            lambda x: jax.device_put(x, sharding) if sharding is not None
            else jax.device_put(x),
            batch,
        )
        with lock:
            q.append(dev)
        return True

    alive = True
    for _ in range(depth):
        alive = put_one() and alive
    while q:
        out = q.popleft()
        if alive:
            alive = put_one()
        yield out
