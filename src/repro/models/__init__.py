"""Pure-JAX model zoo: dense GQA transformer, MoE, Mamba-2, RWKV-6,
Zamba2-style hybrid, Whisper-style enc-dec, LLaVA-style VLM."""
from . import hybrid, layers, mamba2, moe, rwkv6, transformer, vlm, whisper  # noqa: F401
