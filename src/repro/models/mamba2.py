"""Mamba-2 (SSD) block, pure JAX, chunk-parallel.

Implements the state-space-duality formulation: within a chunk the output is
a masked (decay-weighted) attention-like matmul; across chunks a low-rank
state (H, Dh, N) is carried by a scan. All decay exponents are differences
of a monotone cumulative log-decay, hence <= 0 -- numerically stable in
fp32 without clamping. Decode is the O(1) recurrent update with a rolling
depthwise-conv cache.

Used standalone is not a full model; :mod:`repro.models.hybrid` (zamba2)
composes these blocks with a shared attention block, and a pure-Mamba model
could be built the same way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import DTYPE, ParamSpec, rms_norm, shard

__all__ = [
    "block_specs",
    "block_forward",
    "block_decode",
    "init_state",
    "dims",
]

CONV_K = 4


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    G = cfg.ssm_groups
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * G * N
    in_dim = 2 * d_inner + 2 * G * N + H
    return d_inner, H, G, N, conv_dim, in_dim


def block_specs(cfg, n_layers: int) -> dict:
    d = cfg.d_model
    d_inner, H, G, N, conv_dim, in_dim = dims(cfg)
    L = n_layers
    return {
        "norm": ParamSpec((L, d), ("layers", "embed"), init="ones"),
        "in_proj": ParamSpec((L, d, in_dim), ("layers", "embed", "mlp")),
        "conv_w": ParamSpec((L, CONV_K, conv_dim), ("layers", None, "mlp")),
        "conv_b": ParamSpec((L, conv_dim), ("layers", "mlp"), init="zeros"),
        "A_log": ParamSpec((L, H), ("layers", None), dtype=jnp.float32, init="zeros"),
        "D": ParamSpec((L, H), ("layers", None), dtype=jnp.float32, init="ones"),
        "dt_bias": ParamSpec((L, H), ("layers", None), dtype=jnp.float32, init="zeros"),
        "out_norm": ParamSpec((L, d_inner), ("layers", "mlp"), init="ones"),
        "out_proj": ParamSpec((L, d_inner, d), ("layers", "mlp", "embed")),
    }


def _split_proj(x, lw, cfg):
    d_inner, H, G, N, conv_dim, _ = dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, lw["in_proj"])
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim :]
    return z, xBC, dt


def _conv(xBC, lw, cache=None):
    """Causal depthwise conv, kernel CONV_K. cache: (B, K-1, conv_dim)."""
    if cache is None:
        pad = jnp.zeros((xBC.shape[0], CONV_K - 1, xBC.shape[-1]), xBC.dtype)
    else:
        pad = cache.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    S = xBC.shape[1]
    y = sum(
        xp[:, j : j + S] * lw["conv_w"][j][None, None] for j in range(CONV_K)
    ) + lw["conv_b"][None, None]
    new_cache = xp[:, -(CONV_K - 1) :]
    return jax.nn.silu(y.astype(jnp.float32)).astype(xBC.dtype), new_cache


def _ssm_inputs(xBC, dt, lw, cfg):
    d_inner, H, G, N, _, _ = dims(cfg)
    B_, S = xBC.shape[0], xBC.shape[1]
    xs = xBC[..., :d_inner].reshape(B_, S, H, cfg.ssm_head_dim)
    Bm = xBC[..., d_inner : d_inner + G * N].reshape(B_, S, G, N)
    Cm = xBC[..., d_inner + G * N :].reshape(B_, S, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lw["dt_bias"])          # (B,S,H)
    la = -jnp.exp(lw["A_log"])[None, None] * dt                           # log a_t <= 0
    xbar = xs.astype(jnp.float32) * dt[..., None]                         # dt-scaled input
    return xs, xbar, Bh.astype(jnp.float32), Ch.astype(jnp.float32), la


def ssd_chunked(xbar, Bh, Ch, la, chunk: int, state0=None, unroll: int = 0):
    """Chunk-parallel SSD. All args fp32.

    xbar: (B,S,H,Dh); Bh/Ch: (B,S,H,N); la: (B,S,H) log-decay.
    Returns (y (B,S,H,Dh), final_state (B,H,Dh,N)).
    """
    B_, S, H, Dh = xbar.shape
    N = Bh.shape[-1]
    Q = min(chunk, S)
    nc = (S + Q - 1) // Q
    if nc * Q != S:  # pad with identity steps (la=0, xbar=0)
        pad = nc * Q - S
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))

    def csplit(t):
        return t.reshape(B_, nc, Q, *t.shape[2:]).transpose(1, 0, *range(2, t.ndim + 1))

    xc, Bc, Cc, lac = csplit(xbar), csplit(Bh), csplit(Ch), csplit(la)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    if state0 is None:
        state0 = jnp.zeros((B_, H, Dh, N), jnp.float32)

    def step(S_prev, inp):
        xc_, Bc_, Cc_, lac_ = inp                                # (B,Q,...)
        cum = jnp.cumsum(lac_, axis=1)                           # (B,Q,H)
        # intra-chunk masked decay attention: D_ij = exp(cum_i - cum_j), i>=j
        dmat = cum[:, :, None, :] - cum[:, None, :, :]           # (B,i,j,H)
        dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
        scores = jnp.einsum("bihn,bjhn->bijh", Cc_, Bc_) * jnp.exp(dmat)
        y = jnp.einsum("bijh,bjhd->bihd", scores, xc_)
        # contribution of the carried state + state update
        y = y + jnp.einsum("bihn,bhdn->bihd", Cc_ * jnp.exp(cum)[..., None], S_prev)
        decay_end = jnp.exp(cum[:, -1:, :] - cum)                # (B,Q,H)
        S_new = S_prev * jnp.exp(cum[:, -1])[..., None, None] + jnp.einsum(
            "bjhn,bjhd->bhdn", Bc_ * decay_end[..., None], xc_
        )
        return S_new, y

    final, ys = jax.lax.scan(step, state0, (xc, Bc, Cc, lac),
                             unroll=min(nc, int(unroll)) if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, nc * Q, H, Dh)
    return y[:, :S], final


def block_forward(x, lw, cfg, state0=None, conv_cache=None):
    """Full Mamba-2 block: norm -> proj -> conv -> SSD -> gate -> out.

    x: (B,S,d). Returns (out (B,S,d), (final_state, conv_cache)).
    """
    d_inner, H, G, N, _, _ = dims(cfg)
    h = rms_norm(x, lw["norm"])
    z, xBC, dt = _split_proj(h, lw, cfg)
    xBC, new_conv = _conv(xBC, lw, conv_cache)
    xs, xbar, Bh, Ch, la = _ssm_inputs(xBC, dt, lw, cfg)
    y, final = ssd_chunked(xbar, Bh, Ch, la, cfg.ssm_chunk, state0,
                           unroll=cfg.unroll_inner)
    y = y + lw["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], d_inner)
    y = rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(DTYPE), lw["out_norm"]
    )
    out = jnp.einsum("bse,ed->bsd", y, lw["out_proj"])
    return x + shard(out, "batch", "seq_res", "embed"), (final, new_conv)


def init_state(cfg, batch: int, n_layers: int):
    d_inner, H, G, N, conv_dim, _ = dims(cfg)
    return {
        "ssm": jnp.zeros((n_layers, batch, H, cfg.ssm_head_dim, N), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, CONV_K - 1, conv_dim), DTYPE),
    }


def block_decode(x, lw, cfg, state, conv_cache):
    """One-token recurrent update. x: (B,1,d)."""
    d_inner, H, G, N, _, _ = dims(cfg)
    h = rms_norm(x, lw["norm"])
    z, xBC, dt = _split_proj(h, lw, cfg)
    xBC, new_conv = _conv(xBC, lw, conv_cache)
    xs, xbar, Bh, Ch, la = _ssm_inputs(xBC, dt, lw, cfg)
    a = jnp.exp(la[:, 0])                                   # (B,H)
    new_state = state * a[..., None, None] + jnp.einsum(
        "bhn,bhd->bhdn", Bh[:, 0], xbar[:, 0]
    )
    y = jnp.einsum("bhn,bhdn->bhd", Ch[:, 0], new_state)
    y = y + lw["D"][None, :, None] * xs[:, 0].astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, d_inner)
    y = rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(DTYPE), lw["out_norm"]
    )
    out = jnp.einsum("bse,ed->bsd", y, lw["out_proj"])
    return x + out, (new_state, new_conv)
