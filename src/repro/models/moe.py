"""Mixture-of-Experts transformer (DeepSeekMoE / Qwen2-MoE style).

Shared experts (always-on SwiGLU) + fine-grained routed experts with top-k
softmax gating and capacity-based token dropping (GShard discipline). The
dispatch is sort-based -- expert id / rank-within-expert computed with a
stable argsort, tokens scattered into an (E, C, d) buffer -- so it lowers
to gather/scatter HLO that shards cleanly with experts on the 'model'
(expert-parallel) mesh axis; the O(T*E*C) one-hot einsum of the original
GShard formulation is never materialized.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import DTYPE, ParamSpec, mlp, rms_norm, shard
from . import transformer as tf

__all__ = ["param_specs", "forward", "decode_step", "init_cache", "moe_mlp"]


def padded_experts(cfg) -> int:
    """Routed experts padded to a multiple of 16 so the expert dimension
    shards over the 16-wide model axis (qwen2-moe's 60 -> 64; the 4 dead
    experts are masked out of routing and receive no tokens)."""
    return -(-cfg.n_experts // 16) * 16


def _moe_layer_specs(cfg) -> dict:
    sp = tf._layer_specs(cfg)
    L, d, fe = cfg.n_layers, cfg.d_model, cfg.d_expert
    E = padded_experts(cfg)
    fs = cfg.n_shared * cfg.d_expert
    sp["router"] = ParamSpec((L, d, E), ("layers", "embed", None), dtype=jnp.float32)
    sp["experts"] = {
        # the hidden dim of an expert is NOT tensor-parallel -- experts are
        # already sharded over the model axis (EP); 'expert_mlp' maps to None
        "wi_gate": ParamSpec((L, E, d, fe), ("layers", "expert", "embed", "expert_mlp")),
        "wi_up": ParamSpec((L, E, d, fe), ("layers", "expert", "embed", "expert_mlp")),
        "wo": ParamSpec((L, E, fe, d), ("layers", "expert", "expert_mlp", "embed")),
    }
    if cfg.n_shared:
        sp["shared"] = {
            "wi_gate": ParamSpec((L, d, fs), ("layers", "embed", "mlp")),
            "wi_up": ParamSpec((L, d, fs), ("layers", "embed", "mlp")),
            "wo": ParamSpec((L, fs, d), ("layers", "mlp", "embed")),
        }
    del sp["mlp"]
    return sp


def param_specs(cfg) -> dict:
    sp = tf.param_specs(cfg)
    sp["layers"] = _moe_layer_specs(cfg)
    return sp


def capacity(n_tokens: int, cfg) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(min(n_tokens, max(c, 8)), 1)


def n_groups(T: int, cfg) -> int:
    """Largest group count <= moe_groups dividing T. Groups align with the
    data-parallel shards so the rank-within-expert sort never crosses a
    device boundary (a global argsort over 10^6 tokens is a partitioning
    disaster at 256+ chips -- a 40-minute XLA compile in practice)."""
    g = min(cfg.moe_groups, T)
    while T % g:
        g -= 1
    return max(g, 1)


def moe_mlp(x: jnp.ndarray, lw: dict, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Routed + shared expert FFN. x: (B, S, d) -> (out, aux_loss).

    GShard-style grouped dispatch: tokens are split into G groups (aligned
    with the data-parallel shards); each group computes its own top-k,
    rank-within-expert (shard-local stable sort) and capacity; the
    (G, E, C, d) dispatch buffer then crosses from group-major to
    expert-major layout in one SPMD all-to-all.
    """
    B, S, d = x.shape
    T = B * S
    E, k = padded_experts(cfg), cfg.top_k
    G = n_groups(T, cfg)
    Tg = T // G
    C = capacity(Tg, cfg)
    xt = shard(x.reshape(G, Tg, d), "expert_group", None, "embed")

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), lw["router"])
    if E != cfg.n_experts:  # mask padded (dead) experts out of routing
        dead = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(dead[None, None], -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)                    # (G, Tg, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # (G, Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style load-balance auxiliary loss (global average).
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    ) / k
    aux = E * jnp.sum(me * ce)

    # Per-group sort-based rank-within-expert (shard-local).
    flat_e = expert_idx.reshape(G, Tg * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    first = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)
    rank_sorted = jnp.arange(Tg * k)[None] - jnp.take_along_axis(
        first, sorted_e, axis=-1
    )
    rank = jnp.zeros((G, Tg * k), jnp.int32).at[
        jnp.arange(G)[:, None], order
    ].set(rank_sorted.astype(jnp.int32))
    keep = rank < C
    dest = flat_e * C + jnp.minimum(rank, C - 1)               # (G, Tg*k)
    tok = jnp.arange(Tg * k) // k

    vals = jnp.where(keep[..., None], xt[:, tok], 0).astype(DTYPE)
    buf = jnp.zeros((G, E * C, d), DTYPE).at[
        jnp.arange(G)[:, None], dest
    ].add(vals)
    # group-major -> expert-major: the EP all-to-all happens here
    buf = shard(buf.reshape(G, E, C, d), "expert_group", "expert", None, "embed")

    gt = jnp.einsum("gecd,edf->gecf", buf, lw["experts"]["wi_gate"])
    up = jnp.einsum("gecd,edf->gecf", buf, lw["experts"]["wi_up"])
    h = jax.nn.silu(gt.astype(jnp.float32)).astype(DTYPE) * up
    h = shard(h, "expert_group", "expert", None, "expert_mlp")
    y = jnp.einsum("gecf,efd->gecd", h, lw["experts"]["wo"])
    y = y.reshape(G, E * C, d)

    out_tok = y[jnp.arange(G)[:, None], dest] * (
        keep[..., None] * gate_vals.reshape(G, Tg * k, 1)
    ).astype(DTYPE)
    routed = out_tok.reshape(G, Tg, k, d).sum(axis=2).reshape(T, d)

    out = routed
    if cfg.n_shared:
        out = out + mlp(x.reshape(1, T, d), lw["shared"], "swiglu")[0]
    return out.reshape(B, S, d).astype(x.dtype), aux


def _layer_body(x, lw, cfg, positions):
    h = tf._norm(x, None, cfg, "attn_norm", "attn_norm_b", lw)
    q, kk, v = tf._qkv(h, lw, cfg, positions)
    o = tf.attention(
        q, kk, v, causal=cfg.causal, sliding_window=cfg.sliding_window,
        block_kv=cfg.attn_block_kv, unroll=cfg.unroll_inner,
    )
    o = jnp.einsum("bse,ed->bsd", o.reshape(*o.shape[:2], -1), lw["wo"])
    x = x + o
    h = tf._norm(x, None, cfg, "mlp_norm", "mlp_norm_b", lw)
    y, aux = moe_mlp(h, lw, cfg)
    return shard(x + y, "batch", "seq_res", "embed"), aux


def forward(params, tokens, cfg, prefix_embeds=None, remat: bool = True,
            last_only: bool = False):
    """Returns (logits, aux_loss_mean)."""
    x = params["embed"].astype(DTYPE)[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(DTYPE), x], axis=1)
    B, S, _ = x.shape
    x = shard(x, "batch", "seq_res", "embed")
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, lw):
        return _layer_body(x, lw, cfg, positions)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, aux = jax.lax.scan(body, x, params["layers"],
                          unroll=cfg.n_layers if cfg.unroll_layers else 1)
    if last_only:
        x = x[:, -1:]
    x = tf._norm(x, params, cfg, "final_norm", "final_norm_b")
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return shard(logits, "batch", "seq", "vocab"), jnp.mean(aux)


init_cache = tf.init_cache


def decode_step(params, cache, tokens, cfg):
    x = params["embed"].astype(DTYPE)[tokens]
    x = shard(x, "batch", "seq_res", "embed")
    pos = cache["pos"]
    B = x.shape[0]

    def body(x, xs):
        lw, kc, vc = xs
        h = tf._norm(x, None, cfg, "attn_norm", "attn_norm_b", lw)
        positions = jnp.broadcast_to(pos[:, None], (B, 1))
        q, kk, v = tf._qkv(h, lw, cfg, positions)
        W = kc.shape[1]
        slot = (pos[0] % W).astype(jnp.int32)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, kk, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        cache_len = jnp.minimum(pos[0] + 1, W)
        o = tf.decode_attention(q, kc, vc, cache_len)
        o = jnp.einsum("bse,ed->bsd", o.reshape(B, 1, -1), lw["wo"])
        x = x + o
        h = tf._norm(x, None, cfg, "mlp_norm", "mlp_norm_b", lw)
        y, _ = moe_mlp(h, lw, cfg)
        return x + y, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]),
        unroll=cfg.n_layers if cfg.unroll_layers else 1,
    )
    x = tf._norm(x, params, cfg, "final_norm", "final_norm_b")
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return shard(logits, "batch", "seq", "vocab"), {"k": k_new, "v": v_new, "pos": pos + 1}
