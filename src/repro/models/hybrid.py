"""Zamba2-style hybrid: Mamba-2 backbone + a *shared* attention block.

The layer pattern is ``attn_every``-periodic: every 6th layer position runs
the single shared attention+MLP block (one parameter set reused at every
site, as in Zamba/Zamba2); all other positions are Mamba-2 blocks. With
n_layers=81 and attn_every=6 that is 13 shared-attention applications and
68 Mamba layers.

Long-context (500k) decode works because the SSM state is O(1) and the
shared attention block switches to a sliding-window ring-buffer KV cache
when ``cfg.sliding_window`` is set (the long_500k serving config sets 4096;
see DESIGN.md SS5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import mamba2
from . import transformer as tf
from .layers import DTYPE, ParamSpec, shard

__all__ = ["param_specs", "forward", "decode_step", "init_cache", "plan_layers"]


def plan_layers(cfg) -> tuple[int, int, list[str]]:
    """Returns (n_mamba, n_attn, pattern list of 'm'/'a')."""
    pattern = []
    for i in range(cfg.n_layers):
        pattern.append("a" if (i + 1) % cfg.attn_every == 0 else "m")
    return pattern.count("m"), pattern.count("a"), pattern


def _shared_attn_specs(cfg) -> dict:
    """One dense transformer layer's worth of params (unstacked: L dim = 1
    folded away) -- shared across all attention sites."""
    import dataclasses

    one = dataclasses.replace(cfg, n_layers=1)
    sp = tf._layer_specs(one)

    def unstack(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape[1:], s.axes[1:], s.dtype, s.init)

    return jax.tree.map(unstack, sp, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_specs(cfg) -> dict:
    n_mamba, n_attn, _ = plan_layers(cfg)
    d = cfg.d_model
    return {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), init="embed"),
        "mamba": mamba2.block_specs(cfg, n_mamba),
        "shared_attn": _shared_attn_specs(cfg),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "lm_head": ParamSpec((d, cfg.vocab), ("embed", "vocab")),
    }


def _segments(cfg) -> list[tuple[int, int, bool]]:
    """[(mamba_start, mamba_end, attn_after)] covering the layer pattern."""
    _, _, pattern = plan_layers(cfg)
    segs = []
    m = 0
    run = 0
    for p in pattern:
        if p == "m":
            run += 1
        else:
            segs.append((m, m + run, True))
            m += run
            run = 0
    if run:
        segs.append((m, m + run, False))
    return segs


def forward(params, tokens, cfg, prefix_embeds=None, remat: bool = True,
            last_only: bool = False):
    x = params["embed"].astype(DTYPE)[tokens]
    B, S = x.shape[0], x.shape[1]
    x = shard(x, "batch", "seq_res", "embed")
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def mamba_body(x, lw):
        y, _ = mamba2.block_forward(x, lw, cfg)
        return y, None

    attn_body = lambda x: tf._layer_body(x, params["shared_attn"], cfg, positions)
    if remat:
        mamba_body = jax.checkpoint(mamba_body, prevent_cse=False)
        attn_body = jax.checkpoint(attn_body, prevent_cse=False)

    for start, end, attn_after in _segments(cfg):
        seg = jax.tree.map(lambda a: a[start:end], params["mamba"])
        x, _ = jax.lax.scan(mamba_body, x, seg,
                            unroll=(end - start) if cfg.unroll_layers else 1)
        if attn_after:
            x = attn_body(x)

    if last_only:
        x = x[:, -1:]
    x = tf.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return shard(logits, "batch", "seq", "vocab")


def init_cache(cfg, batch: int, max_len: int) -> dict:
    n_mamba, n_attn, _ = plan_layers(cfg)
    W = tf.cache_window(cfg, max_len)
    return {
        "ssm": mamba2.init_state(cfg, batch, n_mamba),
        "k": jnp.zeros((n_attn, batch, W, cfg.n_kv_heads, cfg.head_dim), DTYPE),
        "v": jnp.zeros((n_attn, batch, W, cfg.n_kv_heads, cfg.head_dim), DTYPE),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, cache, tokens, cfg):
    x = params["embed"].astype(DTYPE)[tokens]
    pos = cache["pos"]
    ssm, conv = cache["ssm"]["ssm"], cache["ssm"]["conv"]
    new_ssm, new_conv = [], []
    k_caches, v_caches = [], []
    ai = 0
    for start, end, attn_after in _segments(cfg):
        for li in range(start, end):
            lw = jax.tree.map(lambda a: a[li], params["mamba"])
            x, (s_new, c_new) = mamba2.block_decode(x, lw, cfg, ssm[li], conv[li])
            new_ssm.append(s_new)
            new_conv.append(c_new)
        if attn_after:
            x, kc, vc = tf._decode_layer(
                x, params["shared_attn"], cache["k"][ai], cache["v"][ai], pos, cfg
            )
            k_caches.append(kc)
            v_caches.append(vc)
            ai += 1
    x = tf.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    new_cache = {
        "ssm": {"ssm": jnp.stack(new_ssm), "conv": jnp.stack(new_conv)},
        "k": jnp.stack(k_caches),
        "v": jnp.stack(v_caches),
        "pos": pos + 1,
    }
    return shard(logits, "batch", "seq", "vocab"), new_cache
