"""Dense decoder-only transformer (GQA + RoPE), pure JAX.

Covers qwen2.5-3b, starcoder2-3b, qwen1.5-110b, llama3-405b and the Mistral
backbone of llava-next. One stacked-parameter layer block is scanned with
``jax.lax.scan`` (+ remat for training) so the HLO stays compact at 126
layers. Supports: RMSNorm/LayerNorm, SwiGLU/GELU FFN, QKV bias, sliding-
window attention, tied embeddings, full-cache decode and ring-buffer
(sliding-window) decode.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import (
    DTYPE,
    ParamSpec,
    attention,
    decode_attention,
    layer_norm,
    mlp,
    rms_norm,
    rope,
    shard,
)

__all__ = [
    "param_specs",
    "forward",
    "prefill",
    "decode_step",
    "init_cache",
]


def _layer_specs(cfg) -> dict:
    d, hq, hkv, dh, ff = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    L = cfg.n_layers
    sp: dict[str, Any] = {
        "attn_norm": ParamSpec((L, d), ("layers", "embed"), init="ones"),
        "mlp_norm": ParamSpec((L, d), ("layers", "embed"), init="ones"),
        "wq": ParamSpec((L, d, hq * dh), ("layers", "embed", "heads_flat")),
        "wk": ParamSpec((L, d, hkv * dh), ("layers", "embed", None)),
        "wv": ParamSpec((L, d, hkv * dh), ("layers", "embed", None)),
        "wo": ParamSpec((L, hq * dh, d), ("layers", "heads_flat", "embed")),
    }
    if cfg.qkv_bias:
        sp["bq"] = ParamSpec((L, hq * dh), ("layers", "heads_flat"), init="zeros")
        sp["bk"] = ParamSpec((L, hkv * dh), ("layers", None), init="zeros")
        sp["bv"] = ParamSpec((L, hkv * dh), ("layers", None), init="zeros")
    if cfg.mlp_kind == "swiglu":
        sp["mlp"] = {
            "wi_gate": ParamSpec((L, d, ff), ("layers", "embed", "mlp")),
            "wi_up": ParamSpec((L, d, ff), ("layers", "embed", "mlp")),
            "wo": ParamSpec((L, ff, d), ("layers", "mlp", "embed")),
        }
    else:
        sp["mlp"] = {
            "wi": ParamSpec((L, d, ff), ("layers", "embed", "mlp")),
            "wo": ParamSpec((L, ff, d), ("layers", "mlp", "embed")),
        }
    if cfg.norm_kind == "ln":
        sp["attn_norm_b"] = ParamSpec((L, d), ("layers", "embed"), init="zeros")
        sp["mlp_norm_b"] = ParamSpec((L, d), ("layers", "embed"), init="zeros")
    return sp


def param_specs(cfg) -> dict:
    d = cfg.d_model
    sp = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), init="embed"),
        "layers": _layer_specs(cfg),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
    }
    if cfg.norm_kind == "ln":
        sp["final_norm_b"] = ParamSpec((d,), ("embed",), init="zeros")
    if not cfg.tie_embeddings:
        sp["lm_head"] = ParamSpec((d, cfg.vocab), ("embed", "vocab"))
    return sp


def _norm(x, w, cfg, gamma_key, beta_key, lw=None):
    src = lw if lw is not None else w
    if cfg.norm_kind == "ln":
        return layer_norm(x, src[gamma_key], src[beta_key])
    return rms_norm(x, src[gamma_key])


def _qkv(x, lw, cfg, positions):
    B, S, d = x.shape
    dh = cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, lw["wq"])
    k = jnp.einsum("bsd,de->bse", x, lw["wk"])
    v = jnp.einsum("bsd,de->bse", x, lw["wv"])
    if cfg.qkv_bias:
        q, k, v = q + lw["bq"], k + lw["bk"], v + lw["bv"]
    q = q.reshape(B, S, cfg.n_heads, dh)
    k = k.reshape(B, S, cfg.n_kv_heads, dh)
    v = v.reshape(B, S, cfg.n_kv_heads, dh)
    q = shard(q, "batch", "seq", "heads", None)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _layer_body(x, lw, cfg, positions):
    h = _norm(x, None, cfg, "attn_norm", "attn_norm_b", lw)
    q, k, v = _qkv(h, lw, cfg, positions)
    o = attention(
        q, k, v, causal=cfg.causal, sliding_window=cfg.sliding_window,
        block_kv=cfg.attn_block_kv, unroll=cfg.unroll_inner,
    )
    o = jnp.einsum("bse,ed->bsd", o.reshape(*o.shape[:2], -1), lw["wo"])
    x = x + o
    h = _norm(x, None, cfg, "mlp_norm", "mlp_norm_b", lw)
    x = x + mlp(h, lw["mlp"], cfg.mlp_kind)
    return shard(x, "batch", "seq_res", "embed")


def forward(
    params: dict,
    tokens: jnp.ndarray,                 # (B, S) int32
    cfg,
    prefix_embeds: jnp.ndarray | None = None,   # (B, S_pre, d) VLM patches
    remat: bool = True,
    last_only: bool = False,             # head on the final position only
) -> jnp.ndarray:
    """Training/prefill forward pass -> logits (B, S[, vocab-sharded])."""
    x = params["embed"].astype(DTYPE)[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(DTYPE), x], axis=1)
    B, S, _ = x.shape
    x = shard(x, "batch", "seq_res", "embed")
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    body = lambda x, lw: (_layer_body(x, lw, cfg, positions), None)
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    G = cfg.remat_groups
    if G > 1 and cfg.n_layers % G == 0 and not cfg.unroll_layers:
        # 2-level ("sqrt") remat: only G group-boundary activations are
        # saved; each group's layers are recomputed during its backward.
        per = cfg.n_layers // G
        grouped = jax.tree.map(
            lambda a: a.reshape(G, per, *a.shape[1:]), params["layers"]
        )

        def group_body(x, glw):
            y, _ = jax.lax.scan(body, x, glw)
            return y, None

        x, _ = jax.lax.scan(
            jax.checkpoint(group_body, prevent_cse=False), x, grouped
        )
    else:
        x, _ = jax.lax.scan(body, x, params["layers"],
                            unroll=cfg.n_layers if cfg.unroll_layers else 1)

    if last_only:
        x = x[:, -1:]
    x = _norm(x, params, cfg, "final_norm", "final_norm_b")
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return shard(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Decode path (full cache or sliding-window ring buffer)
# ---------------------------------------------------------------------------

def cache_window(cfg, max_len: int) -> int:
    """Physical cache length: the sliding window if one exists (ring), else
    the full context."""
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_cache(cfg, batch: int, max_len: int) -> dict:
    W = cache_window(cfg, max_len)
    kv_shape = (cfg.n_layers, batch, W, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(kv_shape, DTYPE),
        "v": jnp.zeros(kv_shape, DTYPE),
        "pos": jnp.zeros((batch,), jnp.int32),   # absolute next position
    }


def _decode_layer(x, lw, k_cache, v_cache, pos, cfg):
    """One decode layer; returns (x, new_k_slot, new_v_slot)."""
    B = x.shape[0]
    h = _norm(x, None, cfg, "attn_norm", "attn_norm_b", lw)
    positions = jnp.broadcast_to(pos[:, None], (B, 1))
    q, k, v = _qkv(h, lw, cfg, positions)
    W = k_cache.shape[1]
    slot = (pos[0] % W).astype(jnp.int32)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    cache_len = jnp.minimum(pos[0] + 1, W)
    o = decode_attention(q, k_cache, v_cache, cache_len)
    o = jnp.einsum("bse,ed->bsd", o.reshape(B, 1, -1), lw["wo"])
    x = x + o
    h = _norm(x, None, cfg, "mlp_norm", "mlp_norm_b", lw)
    x = x + mlp(h, lw["mlp"], cfg.mlp_kind)
    return x, k_cache, v_cache


def decode_step(params: dict, cache: dict, tokens: jnp.ndarray, cfg):
    """One autoregressive step. tokens: (B, 1) -> (logits (B,1,V), cache)."""
    x = params["embed"].astype(DTYPE)[tokens]
    x = shard(x, "batch", "seq_res", "embed")
    pos = cache["pos"]

    def body(x, xs):
        lw, kc, vc = xs
        x, kc, vc = _decode_layer(x, lw, kc, vc, pos, cfg)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]),
        unroll=cfg.n_layers if cfg.unroll_layers else 1,
    )
    x = _norm(x, params, cfg, "final_norm", "final_norm_b")
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    new_cache = {"k": k_new, "v": v_new, "pos": pos + 1}
    return shard(logits, "batch", "seq", "vocab"), new_cache


def prefill(params: dict, tokens: jnp.ndarray, cfg, max_len: int | None = None):
    """Run the prompt through the model, building the KV cache.

    Returns (last-token logits, cache). Implemented as a full forward that
    also captures per-layer K/V (the serving engine's paged path replaces
    this with the Pallas kernel pipeline).
    """
    B, S = tokens.shape
    max_len = max_len or S
    W = cache_window(cfg, max_len)
    x = params["embed"].astype(DTYPE)[tokens]
    x = shard(x, "batch", "seq_res", "embed")
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, lw):
        h = _norm(x, None, cfg, "attn_norm", "attn_norm_b", lw)
        q, k, v = _qkv(h, lw, cfg, positions)
        o = attention(
            q, k, v, causal=cfg.causal, sliding_window=cfg.sliding_window,
            block_kv=cfg.attn_block_kv, unroll=cfg.unroll_inner,
        )
        o = jnp.einsum("bse,ed->bsd", o.reshape(*o.shape[:2], -1), lw["wo"])
        x = x + o
        h = _norm(x, None, cfg, "mlp_norm", "mlp_norm_b", lw)
        x = x + mlp(h, lw["mlp"], cfg.mlp_kind)
        x = shard(x, "batch", "seq_res", "embed")
        # keep the last W positions in the (ring) cache, slot = pos % W
        k_keep = k[:, -W:]
        v_keep = v[:, -W:]
        if S >= W:
            # slot s must hold absolute position p with p % W == s; the last
            # W positions are [S-W, S), so index j -> slot (j + S) % W.
            k_slot = jnp.roll(k_keep, S % W, axis=1)
            v_slot = jnp.roll(v_keep, S % W, axis=1)
        else:
            pad = W - S
            k_slot = jnp.pad(k_keep, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_slot = jnp.pad(v_keep, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, (k_slot, v_slot)

    x, (k_cache, v_cache) = jax.lax.scan(
        body, x, params["layers"],
        unroll=cfg.n_layers if cfg.unroll_layers else 1,
    )
    x = _norm(x, params, cfg, "final_norm", "final_norm_b")
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], head)
    cache = {
        "k": k_cache,
        "v": v_cache,
        "pos": jnp.full((B,), S, jnp.int32),
    }
    return logits, cache
