"""LLaVA-NeXT-style VLM: Mistral-7B backbone + projected patch embeddings.

Per the assignment the vision tower is a stub: ``input_specs()`` supplies
precomputed anyres patch embeddings (B, n_patches, vision_dim); this module
owns only the multimodal projector (vision_dim -> d_model MLP) and defers
everything else to the dense transformer backbone. Sequence layout is
[patches | text]; the training loss is masked to text positions by the
train step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer as tf
from .layers import DTYPE, ParamSpec

__all__ = ["param_specs", "forward", "decode_step", "init_cache", "project_patches"]


def param_specs(cfg) -> dict:
    sp = tf.param_specs(cfg)
    sp["projector"] = {
        "w1": ParamSpec((cfg.vision_dim, cfg.d_model), (None, "embed")),
        "b1": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "w2": ParamSpec((cfg.d_model, cfg.d_model), ("embed", "embed2")),
        "b2": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
    }
    return sp


def project_patches(params, patches: jnp.ndarray) -> jnp.ndarray:
    """(B, n_patches, vision_dim) -> (B, n_patches, d_model), 2-layer GELU MLP."""
    p = params["projector"]
    h = jnp.einsum("bpv,vd->bpd", patches.astype(DTYPE), p["w1"]) + p["b1"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(DTYPE)
    return jnp.einsum("bpd,de->bpe", h, p["w2"]) + p["b2"]


def forward(params, tokens, cfg, patches=None, remat: bool = True,
            last_only: bool = False):
    prefix = project_patches(params, patches) if patches is not None else None
    return tf.forward(params, tokens, cfg, prefix_embeds=prefix, remat=remat,
                      last_only=last_only)


init_cache = tf.init_cache
decode_step = tf.decode_step
