"""RWKV-6 "Finch" (attention-free, data-dependent per-channel decay).

Time-mix: WKV linear recurrence with matrix state S (head: key-dim x
value-dim) and data-dependent diagonal decay w_t produced by a token-shift
LoRA; bonus term u for the current token. Channel-mix: token-shifted
squared-ReLU FFN with sigmoid receptance.

The training path is chunk-parallel: within a chunk of Q tokens the pairwise
decay tensor exp(ce_i - c_j) (i > j) is formed explicitly per (Q, Q, Dh) --
every exponent is a difference of a monotone cumulative log-decay, hence
<= 0, so the computation is exactly the recurrence, fp32-stable, with no
clamping. Decode is the O(1) per-token state update. There is no KV cache
anywhere -- this is the arch for which the paper's paged-KV technique is
inapplicable (see DESIGN.md SS5); state offload reuses the same prefetch
pipeline instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import DTYPE, ParamSpec, layer_norm, shard

__all__ = ["param_specs", "forward", "decode_step", "init_state"]

TM_LORA = 32     # token-shift mixing LoRA rank
TD_LORA = 64     # decay LoRA rank


def _layer_specs(cfg) -> dict:
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    H, Dh = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    return {
        "ln1": ParamSpec((L, d), ("layers", "embed"), init="ones"),
        "ln1_b": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
        "ln2": ParamSpec((L, d), ("layers", "embed"), init="ones"),
        "ln2_b": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
        # time-mix
        "maa_x": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
        "maa_wkvrg": ParamSpec((L, 5, d), ("layers", None, "embed"), init="zeros"),
        "maa_w1": ParamSpec((L, d, 5 * TM_LORA), ("layers", "embed", None)),
        "maa_w2": ParamSpec((L, 5, TM_LORA, d), ("layers", None, None, "embed")),
        "decay_base": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
        "decay_w1": ParamSpec((L, d, TD_LORA), ("layers", "embed", None)),
        "decay_w2": ParamSpec((L, TD_LORA, d), ("layers", None, "embed")),
        "bonus_u": ParamSpec((L, H, Dh), ("layers", None, None), init="zeros"),  # H=40 not 16-divisible: replicate
        "wr": ParamSpec((L, d, d), ("layers", "embed", "heads_flat")),
        "wk": ParamSpec((L, d, d), ("layers", "embed", "heads_flat")),
        "wv": ParamSpec((L, d, d), ("layers", "embed", "heads_flat")),
        "wg": ParamSpec((L, d, d), ("layers", "embed", "heads_flat")),
        "wo": ParamSpec((L, d, d), ("layers", "heads_flat", "embed")),
        "gn": ParamSpec((L, d), ("layers", "embed"), init="ones"),
        "gn_b": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
        # channel-mix
        "cm_maa_k": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
        "cm_maa_r": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
        "cm_wk": ParamSpec((L, d, ff), ("layers", "embed", "mlp")),
        "cm_wv": ParamSpec((L, ff, d), ("layers", "mlp", "embed")),
        "cm_wr": ParamSpec((L, d, d), ("layers", "embed", "embed2")),
    }


def param_specs(cfg) -> dict:
    d = cfg.d_model
    return {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), init="embed"),
        "ln_in": ParamSpec((d,), ("embed",), init="ones"),
        "ln_in_b": ParamSpec((d,), ("embed",), init="zeros"),
        "layers": _layer_specs(cfg),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "final_norm_b": ParamSpec((d,), ("embed",), init="zeros"),
        "lm_head": ParamSpec((d, cfg.vocab), ("embed", "vocab")),
    }


def _time_mix_inputs(x, x_prev, lw):
    """Token-shift DDLerp -> (xw, xk, xv, xr, xg) and decay w (log-space)."""
    sx = x_prev - x
    xxx = x + sx * lw["maa_x"]
    B, S, d = x.shape
    lo = jnp.tanh(jnp.einsum("bsd,dr->bsr", xxx, lw["maa_w1"]))
    lo = lo.reshape(B, S, 5, TM_LORA)
    mix = jnp.einsum("bsfr,frd->bsfd", lo, lw["maa_w2"]) + lw["maa_wkvrg"][None, None]
    xs = x[:, :, None] + sx[:, :, None] * mix              # (B,S,5,d)
    xw, xk, xv, xr, xg = [xs[:, :, i] for i in range(5)]
    dec = lw["decay_base"] + jnp.einsum(
        "bsr,rd->bsd", jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, lw["decay_w1"])),
        lw["decay_w2"],
    )
    log_w = -jnp.exp(dec.astype(jnp.float32))              # log w_t <= 0
    return xk, xv, xr, xg, log_w


def wkv_chunked(r, k, v, log_w, u, chunk: int, state0=None, unroll: int = 0):
    """Chunk-parallel WKV. r,k,v: (B,S,H,Dh); log_w: (B,S,H,Dh) <= 0.

    out_t = r_t . (S_{t-1} + (u * k_t) v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (out (B,S,H,Dh_v), final_state (B,H,Dh,Dh)).
    """
    B, S, H, Dh = r.shape
    Q = min(chunk, S)
    nc = (S + Q - 1) // Q
    if nc * Q != S:
        pad = nc * Q - S
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def csplit(t):
        return t.reshape(B, nc, Q, H, Dh).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, lwc = csplit(r), csplit(k), csplit(v), csplit(log_w)
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    if state0 is None:
        state0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)

    def step(S_prev, inp):
        rc_, kc_, vc_, lwc_ = inp                          # (B,Q,H,Dh)
        c = jnp.cumsum(lwc_, axis=1)                       # inclusive
        ce = c - lwc_                                      # exclusive
        # intra-chunk: s_ij = sum_d r_i k_j exp(ce_i - c_j), strictly i > j;
        # ce_i - c_j <= 0 for i > j, so every exponent is stable.
        dmat = ce[:, :, None] - c[:, None, :]              # (B,i,j,H,Dh)
        dexp = jnp.where(mask[None, :, :, None, None], jnp.exp(dmat), 0.0)
        s = jnp.einsum("bihd,bjhd,bijhd->bijh", rc_, kc_, dexp)
        y = jnp.einsum("bijh,bjhe->bihe", s, vc_)
        diag = jnp.einsum("bihd,bihd->bih", rc_, kc_ * u[None, None])
        y = y + diag[..., None] * vc_
        # inter-chunk contribution + state carry
        y = y + jnp.einsum("bihd,bhde->bihe", rc_ * jnp.exp(ce), S_prev)
        total = jnp.exp(c[:, -1])                          # (B,H,Dh)
        kdec = kc_ * jnp.exp(c[:, -1:] - c)
        S_new = S_prev * total[..., None] + jnp.einsum("bjhd,bjhe->bhde", kdec, vc_)
        return S_new, y

    final, ys = jax.lax.scan(step, state0, (rc, kc, vc, lwc),
                             unroll=min(nc, int(unroll)) if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nc * Q, H, Dh)
    return y[:, :S], final


def _heads(t, H, Dh):
    return t.reshape(*t.shape[:2], H, Dh).astype(jnp.float32)


def _time_mix(x, x_prev, lw, cfg, state0=None, decode=False):
    H, Dh = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    xk, xv, xr, xg, log_w = _time_mix_inputs(x, x_prev, lw)
    r = _heads(jnp.einsum("bsd,de->bse", xr, lw["wr"]), H, Dh)
    k = _heads(jnp.einsum("bsd,de->bse", xk, lw["wk"]), H, Dh)
    v = _heads(jnp.einsum("bsd,de->bse", xv, lw["wv"]), H, Dh)
    g = jnp.einsum("bsd,de->bse", xg, lw["wg"])
    lwh = log_w.reshape(*log_w.shape[:2], H, Dh)
    u = lw["bonus_u"].astype(jnp.float32)
    if decode:
        state = state0
        out_t = jnp.einsum(
            "bhd,bhde->bhe", r[:, 0], state + jnp.einsum(
                "bhd,bhe->bhde", u[None] * k[:, 0], v[:, 0])
        )
        new_state = state * jnp.exp(lwh[:, 0])[..., None] + jnp.einsum(
            "bhd,bhe->bhde", k[:, 0], v[:, 0]
        )
        y, final = out_t[:, None], new_state
    else:
        y, final = wkv_chunked(r, k, v, lwh, u, cfg.ssm_chunk, state0,
                               unroll=cfg.unroll_inner)
    B, S = x.shape[:2]
    y = y.reshape(B, S, H * Dh).astype(DTYPE)
    # per-head group norm == LayerNorm over each head's channels
    yh = y.reshape(B, S, H, Dh).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, S, H * Dh).astype(DTYPE) * lw["gn"] + lw["gn_b"]
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(DTYPE)
    return jnp.einsum("bse,ed->bsd", y, lw["wo"]), final


def _channel_mix(x, x_prev, lw):
    sx = x_prev - x
    xk = x + sx * lw["cm_maa_k"]
    xr = x + sx * lw["cm_maa_r"]
    kk = jnp.einsum("bsd,df->bsf", xk, lw["cm_wk"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(DTYPE)
    kk = shard(kk, "batch", "seq", "mlp")
    vv = jnp.einsum("bsf,fd->bsd", kk, lw["cm_wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, lw["cm_wr"]).astype(jnp.float32))
    return rr.astype(DTYPE) * vv


def _shift(x, last=None):
    """x_prev: previous token's activations (zero or carried for decode)."""
    if last is None:
        return jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1) if x.shape[1] > 1 else last[:, None]


def _layer(x, lw, cfg, st=None, decode=False):
    """st: None (train) or dict(tm_state, tm_last, cm_last)."""
    h = layer_norm(x, lw["ln1"], lw["ln1_b"])
    h_prev = _shift(h, st["tm_last"] if decode else None)
    tm, new_state = _time_mix(
        h, h_prev, lw, cfg, st["tm_state"] if decode else None, decode
    )
    x = x + tm
    h2 = layer_norm(x, lw["ln2"], lw["ln2_b"])
    h2_prev = _shift(h2, st["cm_last"] if decode else None)
    x = x + _channel_mix(h2, h2_prev, lw)
    new_st = {
        "tm_state": new_state,
        "tm_last": h[:, -1],
        "cm_last": h2[:, -1],
    }
    return shard(x, "batch", "seq_res", "embed"), new_st


def forward(params, tokens, cfg, prefix_embeds=None, remat: bool = True,
            last_only: bool = False):
    x = params["embed"].astype(DTYPE)[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(DTYPE), x], axis=1)
    x = layer_norm(x, params["ln_in"], params["ln_in_b"])
    x = shard(x, "batch", "seq_res", "embed")

    def body(x, lw):
        y, _ = _layer(x, lw, cfg)
        return y, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"],
                        unroll=cfg.n_layers if cfg.unroll_layers else 1)
    if last_only:
        x = x[:, -1:]
    x = layer_norm(x, params["final_norm"], params["final_norm_b"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return shard(logits, "batch", "seq", "vocab")


def init_state(cfg, batch: int) -> dict:
    H, Dh, d, L = cfg.n_rwkv_heads, cfg.rwkv_head_dim, cfg.d_model, cfg.n_layers
    return {
        "tm_state": jnp.zeros((L, batch, H, Dh, Dh), jnp.float32),
        "tm_last": jnp.zeros((L, batch, d), DTYPE),
        "cm_last": jnp.zeros((L, batch, d), DTYPE),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, state, tokens, cfg):
    x = params["embed"].astype(DTYPE)[tokens]
    x = layer_norm(x, params["ln_in"], params["ln_in_b"])

    def body(x, xs):
        lw, tm_s, tm_l, cm_l = xs
        y, st = _layer(x, lw, cfg, {"tm_state": tm_s, "tm_last": tm_l, "cm_last": cm_l},
                       decode=True)
        return y, (st["tm_state"], st["tm_last"], st["cm_last"])

    x, (tm_s, tm_l, cm_l) = jax.lax.scan(
        body, x, (params["layers"], state["tm_state"], state["tm_last"], state["cm_last"]),
        unroll=cfg.n_layers if cfg.unroll_layers else 1,
    )
    x = layer_norm(x, params["final_norm"], params["final_norm_b"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    new_state = {"tm_state": tm_s, "tm_last": tm_l, "cm_last": cm_l,
                 "pos": state["pos"] + 1}
    return shard(logits, "batch", "seq", "vocab"), new_state
