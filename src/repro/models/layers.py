"""Shared pure-JAX building blocks for the model zoo.

Conventions
-----------
* Parameters are nested dicts of ``jnp.ndarray``. Each model exposes
  ``param_specs(cfg)`` returning an identically-nested dict of
  :class:`ParamSpec`; ``init_params`` / ``abstract_params`` materialize it.
* Every tensor dimension carries a *logical axis name*; the distributed
  layer (``repro.distributed.sharding``) maps logical names to mesh axes.
  ``shard(x, *names)`` inserts a ``with_sharding_constraint`` when a mesh
  context is active and is the identity otherwise, so the same model code
  runs on one CPU device and on a 512-chip mesh.
* Layer stacks are scanned (``jax.lax.scan``) over a leading 'layers' axis
  to keep HLO compact at 100+ layers, with ``jax.checkpoint`` (remat)
  around the per-layer body for training.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "ParamSpec",
    "init_params",
    "abstract_params",
    "param_shardings",
    "mesh_context",
    "shard",
    "rms_norm",
    "layer_norm",
    "rope",
    "attention",
    "decode_attention",
    "mlp",
    "DTYPE",
]

DTYPE = jnp.bfloat16


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names per dim
    dtype: Any = DTYPE
    init: str = "fan_in"                  # fan_in | zeros | ones | embed

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_one(spec: ParamSpec, key) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        # 1/sqrt(d) keeps tied-head logits O(1) at init (CE ~ ln V)
        scale = 1.0 / math.sqrt(max(spec.shape[-1], 1))
    else:  # fan_in: scale by the penultimate (input) dimension
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def init_params(specs, key) -> dict:
    """Materialize a ParamSpec tree into real arrays."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs) -> dict:
    """ShapeDtypeStruct tree for lowering without allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Mesh context: logical-axis -> PartitionSpec resolution
# ---------------------------------------------------------------------------

_MESH_CTX: list[tuple[Any, dict[str, Any]]] = []


@contextmanager
def mesh_context(mesh, rules: dict[str, Any]):
    """Activate logical->mesh axis rules for ``shard`` / ``param_shardings``.

    ``rules`` maps a logical axis name to a mesh axis name, a tuple of mesh
    axis names, or None (replicated).
    """
    _MESH_CTX.append((mesh, rules))
    try:
        yield
    finally:
        _MESH_CTX.pop()


def logical_to_pspec(axes: tuple[str | None, ...], rules: dict[str, Any]) -> P:
    return P(*(rules.get(a) if a is not None else None for a in axes))


def shard(x: jnp.ndarray, *axes: str | None) -> jnp.ndarray:
    """Constrain ``x``'s sharding by logical axis names (no-op w/o context)."""
    if not _MESH_CTX:
        return x
    mesh, rules = _MESH_CTX[-1]
    spec = logical_to_pspec(axes, rules)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec)
    )


def param_shardings(specs, mesh, rules: dict[str, Any]):
    """NamedSharding tree for a ParamSpec tree under the given rules."""
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, logical_to_pspec(s.axes, rules)),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def layer_norm(
    x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma + beta


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4) -> jnp.ndarray:
    """Apply RoPE. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32) / (head_dim // 2))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    angles = angles[..., :, None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (training/prefill: blockwise-causal; decode: cached)
# ---------------------------------------------------------------------------

def attention(
    q: jnp.ndarray,              # (B, S, Hq, D)
    k: jnp.ndarray,              # (B, S, Hkv, D)
    v: jnp.ndarray,              # (B, S, Hkv, D)
    *,
    causal: bool = True,
    sliding_window: int | None = None,
    block_kv: int = 1024,
    unroll: bool = False,
) -> jnp.ndarray:
    """Blockwise (flash-style) grouped-query attention with a custom VJP.

    KV is processed in chunks of ``block_kv`` with an online softmax so the
    S x S score matrix is never materialized, and the backward pass is the
    FlashAttention recompute-per-block algorithm (hand-written VJP): only
    (out, lse) are saved, so differentiating through the block loop does
    NOT store per-block carries -- this is what keeps the train cells in
    HBM. Query heads stay grouped (B, S, Hkv, rep, D) so repeated KV is
    never formed. This is the pure-JAX twin of
    ``repro.kernels.flash_attention``; ``unroll`` unrolls the block loops
    (used by the dry-run's metric lowering so cost_analysis sees every
    block).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    if S <= block_kv:  # small enough: single dense block
        scale = 1.0 / math.sqrt(D)
        qg = q.reshape(B, S, Hkv, rep, D).astype(jnp.float32) * scale
        return _attn_dense(qg, k, v, causal, sliding_window).astype(q.dtype)
    win = 0 if sliding_window is None else int(sliding_window)
    out = _flash(q, k, v, bool(causal), win, int(block_kv), int(unroll))
    return out


def _flash_mask(q_pos, kv_pos, causal: bool, win: int, S: int):
    mask = (kv_pos < S)[None, :]
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if win:
        mask &= q_pos[:, None] - kv_pos[None, :] < win
    return mask


def _flash_fwd_impl(q, k, v, causal, win, block_kv, unroll):
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, S, Hkv, rep, D).astype(jnp.float32) * scale
    nb = (S + block_kv - 1) // block_kv
    pad = nb * block_kv - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(S)

    def step(carry, inp):
        acc, m, l = carry
        blk_idx, kb_i, vb_i = inp
        kv_pos = blk_idx * block_kv + jnp.arange(block_kv)
        s_ij = jnp.einsum("bqhrd,bkhd->bhrqk", qg, kb_i.astype(jnp.float32))
        mask = _flash_mask(q_pos, kv_pos, causal, win, S)
        s_ij = jnp.where(mask[None, None, None], s_ij, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s_ij, axis=-1))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.where(
            jnp.isinf(s_ij), 0.0, jnp.exp(s_ij - m_safe[..., None])
        )
        corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhrqk,bkhd->bhrqd", p, vb_i.astype(jnp.float32)
        )
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Hkv, rep, S, D), jnp.float32)
    m0 = jnp.full((B, Hkv, rep, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, S), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (jnp.arange(nb), kb, vb),
        unroll=min(nb, int(unroll)) if unroll else 1,
    )
    l_safe = jnp.maximum(l, 1e-37)
    out = acc / l_safe[..., None]                      # (B,Hkv,rep,S,D)
    lse = m + jnp.log(l_safe)                          # (B,Hkv,rep,S)
    out_std = out.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D).astype(q.dtype)
    return out_std, (out, lse)


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, win, block_kv, unroll):
    out, _ = _flash_fwd_impl(q, k, v, causal, win, block_kv, unroll)
    return out


def _flash_fwd(q, k, v, causal, win, block_kv, unroll):
    out_std, (_, lse) = _flash_fwd_impl(q, k, v, causal, win, block_kv, unroll)
    # residuals are bf16 out + fp32 lse only (FlashAttention-2 discipline)
    return out_std, (q, k, v, out_std, lse)


def _flash_bwd(causal, win, block_kv, unroll, res, g):
    q, k, v, out_std, lse = res                # out_std: (B,S,Hq,D) bf16
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, S, Hkv, rep, D).astype(jnp.float32)
    do = g.reshape(B, S, Hkv, rep, D).astype(jnp.float32).transpose(0, 2, 3, 1, 4)
    out = out_std.reshape(B, S, Hkv, rep, D).astype(jnp.float32).transpose(
        0, 2, 3, 1, 4
    )
    delta = jnp.sum(do * out, axis=-1)         # (B,Hkv,rep,S)
    nb = (S + block_kv - 1) // block_kv
    pad = nb * block_kv - S
    kp, vp = k, v
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(S)

    def step(dq_acc, inp):
        blk_idx, kb_i, vb_i = inp
        kv_pos = blk_idx * block_kv + jnp.arange(block_kv)
        kf = kb_i.astype(jnp.float32)
        vf = vb_i.astype(jnp.float32)
        s_ij = jnp.einsum("bqhrd,bkhd->bhrqk", qg * scale, kf)
        mask = _flash_mask(q_pos, kv_pos, causal, win, S)
        p = jnp.where(
            mask[None, None, None], jnp.exp(s_ij - lse[..., None]), 0.0
        )                                       # (B,Hkv,rep,S,K)
        dv_i = jnp.einsum("bhrqk,bhrqd->bkhd", p, do)
        dp = jnp.einsum("bhrqd,bkhd->bhrqk", do, vf)
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bhrqk,bkhd->bqhrd", ds, kf)
        dk_i = jnp.einsum("bhrqk,bqhrd->bkhd", ds, qg)
        return dq_acc, (dk_i, dv_i)

    dq0 = jnp.zeros((B, S, Hkv, rep, D), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(
        step, dq0, (jnp.arange(nb), kb, vb),
        unroll=min(nb, int(unroll)) if unroll else 1,
    )
    dq = dq.reshape(B, S, Hq, D).astype(q.dtype)
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(B, nb * block_kv, Hkv, D)[:, :S]
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(B, nb * block_kv, Hkv, D)[:, :S]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def _attn_dense(qg, k, v, causal, sliding_window):
    """qg: (B,S,Hkv,rep,D) fp32 pre-scaled; k, v: (B,S,Hkv,D)."""
    B, S, Hkv, rep, D = qg.shape
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k.astype(jnp.float32))
    q_pos = jnp.arange(S)
    mask = jnp.ones((S, S), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= q_pos[None, :]
    if sliding_window is not None:
        mask &= q_pos[:, None] - q_pos[None, :] < sliding_window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", p, v.astype(jnp.float32))
    return out.reshape(B, S, Hkv * rep, D)


def decode_attention(
    q: jnp.ndarray,              # (B, 1, Hq, D)
    k_cache: jnp.ndarray,        # (B, S_max, Hkv, D)
    v_cache: jnp.ndarray,
    cache_len,                   # scalar or (B,) valid lengths
) -> jnp.ndarray:
    """Single-token attention against a KV cache.

    Works unchanged for sliding-window ring buffers: keys are stored
    post-RoPE with absolute positions, so scores depend only on relative
    position and the physical slot order inside the ring is irrelevant;
    the window is enforced by the ring size and ``cache_len`` counts
    valid (written) slots clamped to the ring capacity.
    """
    B, S_max, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    n_rep = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32) * scale          # (B, 1, Hq, D)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    if n_rep > 1:
        qf = qf.reshape(B, 1, Hkv, n_rep, D)
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, kf)   # (B,Hkv,rep,1,S)
    else:
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)[:, :, None]
    idx = jnp.arange(S_max)
    valid = idx[None, :] < jnp.reshape(cache_len, (-1, 1))
    s = jnp.where(valid[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)         # fully-masked rows
    out = jnp.einsum("bhrqk,bkhd->bqhrd", p, vf)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp(x: jnp.ndarray, w: dict, kind: str = "swiglu") -> jnp.ndarray:
    """SwiGLU (w: wi_gate, wi_up, wo) or GELU (w: wi, wo) feed-forward."""
    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, w["wi_gate"])
        u = jnp.einsum("bsd,df->bsf", x, w["wi_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, w["wi"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, w["wo"])
