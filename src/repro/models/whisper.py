"""Whisper-style encoder-decoder (audio backbone; conv frontend stubbed).

Per the assignment, the modality frontend is a stub: ``input_specs()``
supplies precomputed mel-frame embeddings (B, T_frames, d_model) in place
of the two strided conv1d layers. Encoder: bidirectional transformer with
sinusoidal positions. Decoder: causal self-attention (learned positions)
+ cross-attention to the encoder output + GELU FFN, all pre-LN.

The 32k decode/prefill shapes are applied mechanically to the decoder
self-attention context (position table extended); see DESIGN.md SS5.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import (
    DTYPE,
    ParamSpec,
    attention,
    decode_attention,
    layer_norm,
    shard,
)

__all__ = ["param_specs", "forward", "encode", "decode_step", "init_cache"]


def _mha_specs(L, d, prefix=""):
    return {
        prefix + "wq": ParamSpec((L, d, d), ("layers", "embed", "heads_flat")),
        prefix + "wk": ParamSpec((L, d, d), ("layers", "embed", "heads_flat")),
        prefix + "wv": ParamSpec((L, d, d), ("layers", "embed", "heads_flat")),
        prefix + "wo": ParamSpec((L, d, d), ("layers", "heads_flat", "embed")),
        prefix + "bq": ParamSpec((L, d), ("layers", "heads_flat"), init="zeros"),
        prefix + "bv": ParamSpec((L, d), ("layers", "heads_flat"), init="zeros"),
        prefix + "bo": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
    }


def _block_specs(L, d, ff, cross: bool):
    sp = {
        "ln1": ParamSpec((L, d), ("layers", "embed"), init="ones"),
        "ln1_b": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
        "ln2": ParamSpec((L, d), ("layers", "embed"), init="ones"),
        "ln2_b": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
        "mlp": {
            "wi": ParamSpec((L, d, ff), ("layers", "embed", "mlp")),
            "bi": ParamSpec((L, ff), ("layers", "mlp"), init="zeros"),
            "wo": ParamSpec((L, ff, d), ("layers", "mlp", "embed")),
            "bo": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
        },
        **_mha_specs(L, d),
    }
    if cross:
        sp.update(_mha_specs(L, d, "x_"))
        sp["lnx"] = ParamSpec((L, d), ("layers", "embed"), init="ones")
        sp["lnx_b"] = ParamSpec((L, d), ("layers", "embed"), init="zeros")
    return sp


def padded_vocab(cfg) -> int:
    """51865 is not 16-divisible; pad the (tied) embedding so the vocab
    dimension shards over the model axis. Dead ids never appear as targets
    and contribute O(100/52k) softmax mass -- documented, negligible."""
    return -(-cfg.vocab // 16) * 16


def param_specs(cfg) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    L = cfg.n_layers               # encoder layers == decoder layers
    return {
        "embed": ParamSpec((padded_vocab(cfg), d), ("vocab", "embed"), init="embed"),
        "pos_dec": ParamSpec((cfg.max_positions, d), (None, "embed"), init="embed"),
        "enc": _block_specs(L, d, ff, cross=False),
        "dec": _block_specs(L, d, ff, cross=True),
        "ln_enc": ParamSpec((d,), ("embed",), init="ones"),
        "ln_enc_b": ParamSpec((d,), ("embed",), init="zeros"),
        "ln_dec": ParamSpec((d,), ("embed",), init="ones"),
        "ln_dec_b": ParamSpec((d,), ("embed",), init="zeros"),
    }


def _sinusoid(T: int, d: int) -> jnp.ndarray:
    inv = jnp.exp(-math.log(10000.0) * jnp.arange(d // 2) / (d // 2 - 1))
    ang = jnp.arange(T)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1).astype(DTYPE)


def _mha(x, kv, lw, cfg, prefix="", causal=False):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    q = jnp.einsum("bsd,de->bse", x, lw[prefix + "wq"]) + lw[prefix + "bq"]
    k = jnp.einsum("bsd,de->bse", kv, lw[prefix + "wk"])
    v = jnp.einsum("bsd,de->bse", kv, lw[prefix + "wv"]) + lw[prefix + "bv"]
    Skv = kv.shape[1]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, Skv, H, dh)
    v = v.reshape(B, Skv, H, dh)
    if S == Skv:
        o = attention(q, k, v, causal=causal, block_kv=cfg.attn_block_kv,
                      unroll=cfg.unroll_inner)
    else:  # cross-attention, never causal
        o = _cross_attn(q, k, v)
    o = o.reshape(B, S, d)
    return jnp.einsum("bse,ed->bsd", o, lw[prefix + "wo"]) + lw[prefix + "bo"]


def _cross_attn(q, k, v):
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32))
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def _ffn(x, lw):
    h = jnp.einsum("bsd,df->bsf", x, lw["mlp"]["wi"]) + lw["mlp"]["bi"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, lw["mlp"]["wo"]) + lw["mlp"]["bo"]


def encode(params, frames, cfg, remat: bool = True):
    """frames: (B, T, d_model) precomputed frame embeddings (conv stub)."""
    x = frames.astype(DTYPE) + _sinusoid(frames.shape[1], cfg.d_model)[None]
    x = shard(x, "batch", "seq_res", "embed")

    def body(x, lw):
        h = layer_norm(x, lw["ln1"], lw["ln1_b"])
        x = x + _mha(h, h, lw, cfg, causal=False)
        h = layer_norm(x, lw["ln2"], lw["ln2_b"])
        x = x + _ffn(h, lw)
        return shard(x, "batch", "seq_res", "embed"), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc"],
                        unroll=cfg.n_layers if cfg.unroll_layers else 1)
    return layer_norm(x, params["ln_enc"], params["ln_enc_b"])


def forward(params, tokens, cfg, frames=None, remat: bool = True,
            last_only: bool = False):
    """Teacher-forced decoder logits. frames: (B, T, d) stub embeddings."""
    enc_out = encode(params, frames, cfg, remat)
    B, S = tokens.shape
    x = params["embed"].astype(DTYPE)[tokens] + params["pos_dec"][:S][None]
    x = shard(x, "batch", "seq_res", "embed")

    def body(x, lw):
        h = layer_norm(x, lw["ln1"], lw["ln1_b"])
        x = x + _mha(h, h, lw, cfg, causal=True)
        h = layer_norm(x, lw["lnx"], lw["lnx_b"])
        x = x + _mha(h, enc_out, lw, cfg, prefix="x_")
        h = layer_norm(x, lw["ln2"], lw["ln2_b"])
        x = x + _ffn(h, lw)
        return shard(x, "batch", "seq_res", "embed"), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec"],
                        unroll=cfg.n_layers if cfg.unroll_layers else 1)
    if last_only:
        x = x[:, -1:]
    x = layer_norm(x, params["ln_dec"], params["ln_dec_b"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T)
    return shard(logits, "batch", "seq", "vocab")


def init_cache(cfg, batch: int, max_len: int, n_frames: int) -> dict:
    d, L, H = cfg.d_model, cfg.n_layers, cfg.n_heads
    dh = d // H
    return {
        "k": jnp.zeros((L, batch, max_len, H, dh), DTYPE),
        "v": jnp.zeros((L, batch, max_len, H, dh), DTYPE),
        "xk": jnp.zeros((L, batch, n_frames, H, dh), DTYPE),
        "xv": jnp.zeros((L, batch, n_frames, H, dh), DTYPE),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def precompute_cross_kv(params, enc_out, cfg):
    """Cross-attention K/V per decoder layer from the encoder output."""
    B, T, d = enc_out.shape
    H = cfg.n_heads
    dh = d // H

    def body(_, lw):
        k = jnp.einsum("btd,de->bte", enc_out, lw["x_wk"]).reshape(B, T, H, dh)
        v = (jnp.einsum("btd,de->bte", enc_out, lw["x_wv"]) + lw["x_bv"]).reshape(
            B, T, H, dh
        )
        return None, (k, v)

    _, (xk, xv) = jax.lax.scan(body, None, params["dec"])
    return xk, xv


def decode_step(params, cache, tokens, cfg):
    B = tokens.shape[0]
    pos = cache["pos"]
    x = params["embed"].astype(DTYPE)[tokens] + params["pos_dec"][pos[0]][None, None]
    H = cfg.n_heads
    dh = cfg.d_model // H

    def body(x, xs):
        lw, kc, vc, xk, xv = xs
        h = layer_norm(x, lw["ln1"], lw["ln1_b"])
        q = (jnp.einsum("bsd,de->bse", h, lw["wq"]) + lw["bq"]).reshape(B, 1, H, dh)
        k = jnp.einsum("bsd,de->bse", h, lw["wk"]).reshape(B, 1, H, dh)
        v = (jnp.einsum("bsd,de->bse", h, lw["wv"]) + lw["bv"]).reshape(B, 1, H, dh)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos[0], axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos[0], axis=1)
        o = decode_attention(q, kc, vc, pos[0] + 1)
        x = x + jnp.einsum("bse,ed->bsd", o.reshape(B, 1, -1), lw["wo"]) + lw["bo"]
        h = layer_norm(x, lw["lnx"], lw["lnx_b"])
        q = (jnp.einsum("bsd,de->bse", h, lw["x_wq"]) + lw["x_bq"]).reshape(B, 1, H, dh)
        o = decode_attention(q, xk, xv, xk.shape[1])
        x = x + jnp.einsum("bse,ed->bsd", o.reshape(B, 1, -1), lw["x_wo"]) + lw["x_bo"]
        h = layer_norm(x, lw["ln2"], lw["ln2_b"])
        x = x + _ffn(h, lw)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=cfg.n_layers if cfg.unroll_layers else 1,
    )
    x = layer_norm(x, params["ln_dec"], params["ln_dec_b"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T)
    new_cache = dict(cache, k=k_new, v=v_new, pos=pos + 1)
    return shard(logits, "batch", "seq", "vocab"), new_cache
