"""Mesh-agnostic checkpointing with async save and atomic publish.

Format: one ``.npz`` chunk per top-level state key plus a JSON manifest
(step, flat key list, config fingerprint). Saves write to a temp directory
and atomically rename -- a preempted save can never corrupt the latest
checkpoint, and restart always finds a complete one (the checkpoint/restart
half of fault tolerance; see repro.train.ft for the failure handling).

Restore is *elastic*: arrays are loaded host-side and ``device_put`` with
shardings derived from the current mesh, so a checkpoint written on a
(16, 16) mesh restores onto (2, 16, 16) or onto 4 CPU devices unchanged
(named-axis PartitionSpecs are mesh-shape-agnostic).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_SEP = "//"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0][0:] or []:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _to_savable(a: np.ndarray) -> tuple[np.ndarray, str]:
    """npz can't hold ml_dtypes (bfloat16 etc.); store a same-width uint view
    plus the dtype name for exact restoration."""
    if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
        width = a.dtype.itemsize
        return a.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[width]), a.dtype.name
    return a, ""


def save(ckpt_dir: str, step: int, state, blocking: bool = True,
         extra: dict | None = None) -> threading.Thread | None:
    """Write state to ``<ckpt_dir>/step_<step>`` (tmp + atomic rename)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)
    host = {}
    viewed: dict[str, str] = {}
    for k, v in flat.items():                 # device -> host now
        arr, dtname = _to_savable(np.asarray(v))
        host[k] = arr
        if dtname:
            viewed[k] = dtname

    def _write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}_{os.getpid()}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "state.npz"), **host)
        manifest = {
            "step": step,
            "keys": sorted(host),
            "viewed_dtypes": viewed,
            "time": time.time(),
            **(extra or {}),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, name, "manifest.json")
        ):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Load ``like``-structured state; place with ``shardings`` if given."""
    import json as _json

    import ml_dtypes

    base = os.path.join(ckpt_dir, f"step_{step}")
    data = np.load(os.path.join(base, "state.npz"))
    with open(os.path.join(base, "manifest.json")) as f:
        viewed = _json.load(f).get("viewed_dtypes", {})
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for (path_elems, leaf) in paths:
        key = _SEP.join(_path_str(p) for p in path_elems)
        arr = data[key]
        if key in viewed:
            arr = arr.view(np.dtype(getattr(ml_dtypes, viewed[key])))
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    else:
        tree = jax.tree.map(jax.device_put, tree)
    return tree


class CheckpointManager:
    """Keep-last-k manager with async saves for the training loop."""

    def __init__(self, ckpt_dir: str, keep: int = 3, every: int = 100):
        self.dir = ckpt_dir
        self.keep = keep
        self.every = every
        self._pending: threading.Thread | None = None

    def maybe_save(self, step: int, state, force: bool = False) -> bool:
        if not force and (self.every <= 0 or step % self.every):
            return False
        if self._pending is not None:
            self._pending.join()
        self._pending = save(self.dir, step, state, blocking=False)
        self._gc(step)
        return True

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self, now_step: int) -> None:
        if not os.path.isdir(self.dir):
            return
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.startswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)
