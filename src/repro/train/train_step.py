"""Train step: loss, grad (with optional microbatch accumulation), AdamW.

The step is a pure function of (state, batch) suitable for ``jax.jit`` with
donated state. Gradient accumulation is a ``lax.scan`` over microbatches --
XLA schedules each microbatch's reduce-scatter against the next microbatch's
forward, which is the standard compute/comm overlap at scale. Optional
cross-pod gradient compression (int8 + error feedback) plugs in between
grad and update (see repro.optim.grad_compress).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from ..optim.adamw import AdamWConfig, adamw_update, init_opt_state
from ..optim.schedule import warmup_cosine

__all__ = ["TrainHParams", "make_loss_fn", "make_train_step", "init_train_state"]


@dataclass(frozen=True)
class TrainHParams:
    peak_lr: float = 3e-4
    warmup: int = 200
    total_steps: int = 10000
    microbatches: int = 1
    aux_weight: float = 0.01       # MoE load-balance loss weight
    remat: bool = True             # activation checkpointing per layer
    adamw: AdamWConfig = field(default_factory=AdamWConfig)


def make_loss_fn(api, cfg, hp: TrainHParams):
    def loss_fn(params, batch):
        logits, aux = api.logits(params, batch, cfg, remat=hp.remat)
        tgt = batch["targets"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(tgt.shape, jnp.float32)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, tgt[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(nll) / denom
        total = loss + hp.aux_weight * aux
        return total, {"loss": loss, "aux": aux, "tokens": denom}

    return loss_fn


def init_train_state(params, hp: TrainHParams, moment_dtype=jnp.float32):
    ocfg = AdamWConfig(moment_dtype=moment_dtype, **{
        k: getattr(hp.adamw, k) for k in ("b1", "b2", "eps", "weight_decay", "grad_clip")
    })
    return {"params": params, "opt": init_opt_state(params, ocfg)}


def _split_micro(batch: dict, k: int) -> dict:
    from ..models.layers import shard

    def split(x):
        y = x.reshape(k, x.shape[0] // k, *x.shape[1:])
        return shard(y, None, "batch", *([None] * (y.ndim - 2)))

    return jax.tree.map(split, batch)


def make_train_step(api, cfg, hp: TrainHParams, moment_dtype=jnp.float32,
                    grad_transform=None, accum_dtype=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_transform(grads) -> grads`` is the hook where cross-pod gradient
    compression is inserted (identity by default).
    """
    loss_fn = make_loss_fn(api, cfg, hp)
    ocfg = AdamWConfig(moment_dtype=moment_dtype, **{
        k: getattr(hp.adamw, k) for k in ("b1", "b2", "eps", "weight_decay", "grad_clip")
    })

    acc_dt = accum_dtype or moment_dtype

    def compute_grads(params, batch):
        if hp.microbatches <= 1:
            return jax.grad(loss_fn, has_aux=True)(params, batch)
        micro = _split_micro(batch, hp.microbatches)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)

        def body(carry, mb):
            acc, _ = carry
            g, aux = jax.grad(loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, b: a + b.astype(acc_dt), acc, g)
            return (acc, aux), None

        (gsum, aux), _ = jax.lax.scan(body, (g0, {
            "loss": jnp.zeros((), jnp.float32),
            "aux": jnp.zeros((), jnp.float32),
            "tokens": jnp.zeros((), jnp.float32),
        }), micro, unroll=hp.microbatches if cfg.unroll_layers else 1)
        inv = 1.0 / hp.microbatches
        return jax.tree.map(lambda g: g * inv, gsum), aux

    def train_step(state, batch):
        grads, aux = compute_grads(state["params"], batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        step = state["opt"]["count"]
        lr = warmup_cosine(step, hp.peak_lr, hp.warmup, hp.total_steps)
        new_params, new_opt, om = adamw_update(
            state["params"], grads, state["opt"], lr, ocfg
        )
        metrics = {**aux, **om, "step": step + 1}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
