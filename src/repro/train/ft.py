"""Fault tolerance: restartable training, straggler detection, elastic remesh.

At thousand-node scale the assumptions are: (1) some host *will* fail
mid-run -- recovery is restore-latest + replay, (2) some host will run slow
before it fails -- detect via step-time outliers and flag for eviction,
(3) the replacement pool may change the world size -- checkpoints are
mesh-agnostic (named-axis shardings), so the same state restores onto a
resized mesh and the data pipeline re-shards by host id.

In this single-process container the multi-host signals are simulated
(tests inject failures/delays); the logic is the deployable part.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

__all__ = ["StragglerDetector", "RestartableLoop", "elastic_restore"]


@dataclass
class StragglerDetector:
    """Per-host step-time ring; flags hosts slower than k x median.

    On a real deployment each host contributes its step wall-time through a
    tiny all-gather (or the coordinator service); here ``observe`` takes the
    vector directly.
    """

    n_hosts: int
    window: int = 16
    threshold: float = 2.0
    grace_steps: int = 3
    _times: list[deque] = field(default_factory=list)
    _strikes: np.ndarray | None = None

    def __post_init__(self):
        self._times = [deque(maxlen=self.window) for _ in range(self.n_hosts)]
        self._strikes = np.zeros(self.n_hosts, np.int32)

    def observe(self, step_times: np.ndarray) -> list[int]:
        """Returns host ids that have been slow for ``grace_steps`` steps."""
        for h, t in enumerate(step_times):
            self._times[h].append(float(t))
        med = np.median([np.median(q) for q in self._times if q])
        slow = np.array(
            [bool(q) and np.median(q) > self.threshold * med for q in self._times]
        )
        self._strikes = np.where(slow, self._strikes + 1, 0)
        return [int(h) for h in np.nonzero(self._strikes >= self.grace_steps)[0]]


class RestartableLoop:
    """Run a step function with checkpoint/restart semantics.

    ``step_fn(state, batch) -> (state, metrics)`` may raise (preemption,
    device loss, injected test failures); the loop restores the latest
    checkpoint and replays from there, up to ``max_restarts``.
    """

    def __init__(self, step_fn, manager, data_iter_fn, *, max_restarts: int = 3):
        self.step_fn = step_fn
        self.manager = manager
        self.data_iter_fn = data_iter_fn   # (start_step) -> iterator of batches
        self.max_restarts = max_restarts
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def run(self, state, n_steps: int, start_step: int = 0,
            restore_fn=None):
        step = start_step
        data = self.data_iter_fn(step)
        while step < n_steps:
            try:
                batch = next(data)
                t0 = time.time()
                state, metrics = self.step_fn(state, batch)
                metrics = dict(metrics) if isinstance(metrics, dict) else {}
                metrics["wall"] = time.time() - t0
                metrics["restarts"] = self.restarts
                self.metrics_log.append(metrics)
                step += 1
                self.manager.maybe_save(step, state)
            except Exception:  # noqa: BLE001 -- any failure triggers recovery
                self.restarts += 1
                if self.restarts > self.max_restarts or restore_fn is None:
                    raise
                from .checkpoint import latest_step

                last = latest_step(self.manager.dir)
                if last is None:
                    raise
                state = restore_fn(last)
                step = last
                data = self.data_iter_fn(step)   # deterministic replay point
        self.manager.wait()
        return state, step


def elastic_restore(ckpt_dir: str, step: int, like, new_mesh, specs,
                    fsdp: bool = True):
    """Restore a checkpoint onto a *different* mesh (elastic scaling).

    ``specs`` is the ParamSpec tree; shardings are re-derived from the new
    mesh's named axes, so nothing about the checkpoint depends on the world
    size it was written at.
    """
    from ..distributed.sharding import state_shardings
    from .checkpoint import restore

    shard = state_shardings(specs, new_mesh, fsdp)
    return restore(ckpt_dir, step, like["params"] if "params" in like else like,
                   shardings=shard)
