"""End-to-end training driver: data -> jit train_step -> checkpoint/FT."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..data.pipeline import DataConfig, prefetch, synthetic_batches
from ..distributed.sharding import act_rules, state_shardings
from ..models.layers import init_params, mesh_context
from ..zoo import get_api
from .checkpoint import CheckpointManager, latest_step, restore
from .ft import RestartableLoop
from .train_step import TrainHParams, init_train_state, make_train_step

__all__ = ["Trainer"]


@dataclass
class Trainer:
    cfg: object                     # ModelConfig
    hp: TrainHParams
    mesh: object | None = None
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    seed: int = 0

    def __post_init__(self):
        self.api = get_api(self.cfg)
        self.specs = self.api.param_specs(self.cfg)
        mdtype = (jnp.bfloat16 if self.cfg.moment_dtype == "bfloat16"
                  else jnp.float32)
        self._mdtype = mdtype
        step = make_train_step(self.api, self.cfg, self.hp, moment_dtype=mdtype)
        if self.mesh is not None:
            rules = act_rules(self.mesh)
            mesh = self.mesh

            def wrapped(state, batch):
                with mesh_context(mesh, rules):
                    return step(state, batch)

            p_shard = state_shardings(self.specs, mesh)
            from jax.sharding import NamedSharding, PartitionSpec as P

            state_shard = {
                "params": p_shard,
                "opt": {"m": p_shard, "v": p_shard,
                        "count": NamedSharding(mesh, P())},
            }
            self.train_step = jax.jit(
                wrapped, in_shardings=(state_shard, None),
                out_shardings=(state_shard, None), donate_argnums=0,
            )
        else:
            self.train_step = jax.jit(step, donate_argnums=0)
        self.manager = CheckpointManager(self.ckpt_dir, every=self.ckpt_every)

    def init_state(self):
        params = init_params(self.specs, jax.random.PRNGKey(self.seed))
        return init_train_state(params, self.hp, self._mdtype)

    def data_iter(self, start_step: int = 0, batch_override: int | None = None):
        dcfg = DataConfig(
            global_batch=batch_override or self.hp_global_batch,
            seq_len=self.hp_seq_len,
            vocab=self.cfg.vocab,
            seed=self.seed,
        )
        return prefetch(synthetic_batches(dcfg, start_step))

    hp_global_batch: int = 8
    hp_seq_len: int = 128

    def fit(self, n_steps: int, resume: bool = True):
        state = self.init_state()
        start = 0
        if resume:
            last = latest_step(self.ckpt_dir)
            if last is not None:
                state = restore(self.ckpt_dir, last, jax.eval_shape(lambda: state))
                start = last

        def restore_fn(step):
            return restore(self.ckpt_dir, step, jax.eval_shape(self.init_state))

        loop = RestartableLoop(
            self.train_step, self.manager,
            lambda s: self.data_iter(s), max_restarts=3,
        )
        state, end = loop.run(state, n_steps, start_step=start,
                              restore_fn=restore_fn)
        self.manager.maybe_save(end, state, force=True)
        self.manager.wait()
        return state, loop.metrics_log
