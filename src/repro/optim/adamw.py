"""AdamW with ZeRO-style sharded moments (pure JAX, no optax).

Moments inherit their parameter's (FSDP x TP) sharding, so optimizer state
is fully sharded across the mesh -- this is what makes the 100B+ configs
fit. ``moment_dtype`` can be bf16 for the largest models (the config
decides); the update math is always fp32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update"]


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32


def init_opt_state(params, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jnp.ndarray:
    sq = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def adamw_update(params, grads, opt_state, lr, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / b1c
        vhat = v32 / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"m": new_m, "v": new_v, "count": count}, metrics
