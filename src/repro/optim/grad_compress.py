"""Cross-pod gradient compression: int8 quantization with error feedback.

The multi-pod mesh reduces gradients over the DCN-crossing 'pod' axis;
at 2+ pods that link is ~10x slower than ICI, so the pod-axis reduction is
the term worth compressing. Each pod quantizes (grad - error_feedback) to
int8 with a per-tensor scale, psums the int8 payload (as int32 to avoid
overflow across pods), dequantizes, and keeps the quantization residual in
an error-feedback buffer (Seide et al. 1-bit SGD discipline; convergence
relies on the residual being re-injected next step).

``compressed_psum`` is written with jax.shard_map over the 'pod' axis only
(model/data stay auto-sharded); ``quantize``/``dequantize`` are also used
standalone in tests and in the checkpoint codec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["quantize", "dequantize", "ef_compress_grads", "make_crosspod_psum"]


def quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads, err):
    """Quantize each gradient leaf with error feedback.

    Returns (q_tree, scale_tree, new_err_tree). The caller reduces q over
    the pod axis and dequantizes; new_err holds what quantization dropped.
    """
    def one(g, e):
        y = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, s = quantize(y)
        back = dequantize(q, s)
        return q, s, (y - back).astype(e.dtype)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    qs, ss, es = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    unf = lambda xs: jax.tree.unflatten(tdef, list(xs))
    return unf(qs), unf(ss), unf(es)


def make_crosspod_psum(mesh):
    """Returns psum_int8(q_tree, scale_tree) -> mean-gradient tree, reducing
    over the 'pod' mesh axis inside shard_map (other axes stay auto)."""
    if "pod" not in mesh.axis_names:
        return None
    n_pods = mesh.shape["pod"]

    def psum_one(q, s):
        # scales differ per pod: agree on the max scale, requantize the
        # local payload to it, then integer-psum. (jax's psum carries int32;
        # a production deployment would run an int8 ring reduce-scatter and
        # widen only at the accumulate -- the wire format is the int8 q.)
        s_shared = jax.lax.pmax(s, "pod")
        qr = jnp.round(q.astype(jnp.float32) * (s / s_shared)).astype(jnp.int32)
        total = jax.lax.psum(qr, "pod")
        return total.astype(jnp.float32) * (s_shared / n_pods)

    def crosspod(q_tree, s_tree):
        return jax.tree.map(psum_one, q_tree, s_tree)

    return crosspod
