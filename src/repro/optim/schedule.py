"""Learning-rate schedules (warmup + cosine / constant / rsqrt)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "warmup_rsqrt", "constant"]


def warmup_cosine(step, peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def warmup_rsqrt(step, peak_lr: float, warmup: int):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    return jnp.where(step < warmup, warm, peak_lr * jnp.sqrt(warmup / jnp.maximum(step, 1)))


def constant(step, peak_lr: float, warmup: int = 0):
    step = jnp.asarray(step, jnp.float32)
    if warmup:
        return jnp.minimum(peak_lr, peak_lr * step / warmup)
    return jnp.full_like(step, peak_lr)
