"""repro: microsecond-latency-memory KV-store paper as a JAX/TPU framework."""
__version__ = "0.1.0"
