#!/usr/bin/env python
"""Validate checked-in benchmark measurements (schema + floors).

One table-driven validator handles four measurement schemas, dispatched
on the file's ``schema`` field (see :data:`SCHEMAS` -- each schema
declares its entry fields, per-entry invariants, summary fields, and its
baseline/fresh check functions):

``repro.jax_grid_bench/v1`` (``BENCH_jax_grid.json``)
    Perf measurements.  Baseline mode enforces the repo's acceptance
    floors on whatever suites it contains: warm jax >= 1x the loop
    pipeline on the paper default grid, >= 5x on a >= 2000-cell mega
    grid, and cohort early-exit >= 1.5x the monolithic single-scan
    layout on the heterogeneous (het) grid.

``repro.tail_latency_bench/v1`` (``BENCH_tail_latency.json``)
    Open-loop tail-latency measurements (see
    ``benchmarks/tail_latency_bench.py``).  Invariants instead of perf
    floors: achieved load <= offered load (an open-loop run cannot
    complete faster than ops arrive, beyond a small ramp tolerance),
    P99 >= P90 >= P50 > 0 per entry, miss_rate in [0, 1], and >= 2
    distinct offered loads so the load axis of the figure exists.

``repro.cluster_bench/v1`` (``BENCH_cluster.json``)
    Sharded-fleet measurements (see ``benchmarks/cluster_bench.py``).
    Machine-independent invariants: ordered fleet percentiles, fleet
    achieved load <= offered, per-node achieved <= offered on entries
    without a migration event (a handover time-concentrates a node's
    arrivals into a sub-window, so its windowed rate may legitimately
    exceed its stream-averaged offered rate), per-entry node shares
    summing to 1, count + missed == n_ops at both levels, and the
    degraded-node scenario actually containing a degraded node.

``repro.scenario_suite/v1`` (``BENCH_scenarios.json``)
    Scenario-suite sweeps (``benchmarks.run --suite``).  Invariants:
    the shared index and the per-scenario artifacts cover the same
    scenario names with matching row counts, and every row carries a
    positive throughput at >= 1 thread.  Row-level regression against a
    baseline is ``tools/artifact_diff.py``'s job (the rows are
    machine-independent on the loop backend), so fresh mode only
    re-validates the fresh file's invariants.

Two modes::

    python tools/check_bench.py BENCH_jax_grid.json
        Schema-validate the checked-in baseline and enforce its
        schema's floors/invariants.

    python tools/check_bench.py --fresh smoke.json \
        --baseline BENCH_jax_grid.json [--max-regress 3.0]
        CI perf-smoke: schema-validate a freshly measured file too.
        For the jax-grid schema, additionally fail if the warm
        jax/loop ratio regressed by more than ``--max-regress`` x vs
        the same-named suite in the baseline (deliberately generous --
        CI machines differ from the baseline machine; the job catches
        order-of-magnitude regressions, not 20% noise).  For the other
        schemas the fresh file's invariants are enforced directly --
        they are machine-independent -- and no ratio is compared.

Exit status 0 on success; 1 with a message on any failure (2 for CLI
usage errors, from argparse).
"""
from __future__ import annotations

import argparse
import json
import sys

SCHEMA = "repro.jax_grid_bench/v1"
TAIL_SCHEMA = "repro.tail_latency_bench/v1"
CLUSTER_SCHEMA = "repro.cluster_bench/v1"
SUITE_SCHEMA = "repro.scenario_suite/v1"

# Open-loop invariants: achieved may exceed offered only by the ramp
# tolerance.  The first total_threads arrivals are backlogged at t=0
# and burn down faster than the offered rate, so a measurement window
# of n_ops ops overshoots by O(threads / n_ops): ~1.7% at the full
# suite's 4000 ops, ~4% at the smoke suite's 800.
TAIL_RAMP_TOL = 1.05
TAIL_MIN_LOADS = 2

_TAIL_ENTRY_FIELDS = {
    "name": str, "engine": str, "L_us": (int, float), "n_threads": int,
    "n_ops": int, "offered_frac": (int, float),
    "offered_load": (int, float), "achieved_load": (int, float),
    "p50_us": (int, float), "p90_us": (int, float),
    "p99_us": (int, float), "max_us": (int, float), "count": int,
    "missed": int, "miss_rate": (int, float), "source": str,
}

# Cluster invariants: a node's sub-stream need not be time-homogeneous
# (startup ramp plus skew drift concentrate its arrivals), so the
# per-node bound is generous -- it catches frame/unit errors, not 20%
# windowing.  Entries with migrate=true skip the per-node bound entirely.
CLUSTER_RAMP_TOL = 1.25
CLUSTER_SHARE_TOL = 1e-3

_CLUSTER_ENTRY_FIELDS = {
    "name": str, "engine": str, "backend": str, "n_nodes": int,
    "L_us": (int, float), "n_threads": int, "n_ops": int,
    "migrate": bool, "offered_frac": (int, float),
    "offered_load": (int, float), "achieved_load": (int, float),
    "p50_us": (int, float), "p90_us": (int, float),
    "p99_us": (int, float), "max_us": (int, float), "count": int,
    "missed": int, "miss_rate": (int, float), "source": str,
    "nodes": list,
}

_CLUSTER_NODE_FIELDS = {
    "node": int, "share": (int, float), "degraded": bool, "n_ops": int,
    "offered_load": (int, float), "achieved_load": (int, float),
    "count": int, "missed": int,
}

_ENTRY_FIELDS = {
    "name": str, "engine": str, "n_ssd": int, "n_latencies": int,
    "n_threads": int, "cells": int, "n_ops": int, "loop_s": (int, float),
    "loop_mode": str, "jax_cold_s": (int, float),
    "jax_warm_s": (int, float), "warm_speedup": (int, float),
}

# het-suite entries additionally carry the cohort-vs-monolithic
# measurement and the early-exit wasted-step counters.
_HET_FIELDS = {
    "jax_cohort_warm_s": (int, float), "jax_mono_warm_s": (int, float),
    "mono_speedup": (int, float), "cell_steps_bound": int,
    "cell_steps_run": int, "steps_saved_frac": (int, float),
}

_SUITE_INDEX_FIELDS = {
    "scenario": str, "file": str, "engine": str, "workload": str,
    "n_rows": int, "arrival": str, "cluster_nodes": int,
    "wall_s": (int, float),
}

_SUITE_ROW_FIELDS = {
    "n_threads": int, "throughput": (int, float),
    "model_throughput": (int, float),
}

# Acceptance floors enforced on the checked-in baseline.
DEFAULT_MIN_SPEEDUP = 1.0
MEGA_MIN_SPEEDUP = 5.0
MEGA_MIN_CELLS = 2000
HET_MIN_MONO_SPEEDUP = 1.5


def fail(msg: str) -> None:
    sys.exit(f"check_bench: FAIL: {msg}")


def _check_fields(obj: dict, fields: dict, where: str, path: str) -> None:
    """Presence + type check (bool only passes where bool is declared)."""
    for field, typ in fields.items():
        if field not in obj:
            fail(f"{path}: {where} missing {field!r}")
        v = obj[field]
        if typ is bool:
            if not isinstance(v, bool):
                fail(f"{path}: {where} field {field!r} has type "
                     f"{type(v).__name__}, wanted bool")
        elif not isinstance(v, typ) or isinstance(v, bool):
            fail(f"{path}: {where} field {field!r} has type "
                 f"{type(v).__name__}")


# -- per-schema entry / doc validation hooks ---------------------------------

def _grid_entry_extra(e: dict, tag: str, path: str) -> None:
    if e["cells"] != e["n_latencies"] * e["n_threads"]:
        fail(f"{path}: {tag}: cells != lats * threads")
    for field in ("loop_s", "jax_cold_s", "jax_warm_s", "warm_speedup"):
        if e[field] <= 0:
            fail(f"{path}: {tag}: {field} must be > 0")
    if e["name"].startswith("het"):
        _check_fields(e, _HET_FIELDS, tag, path)
        if e["cell_steps_run"] > e["cell_steps_bound"]:
            fail(f"{path}: {tag}: cell_steps_run exceeds cell_steps_bound")


def _cluster_entry_extra(e: dict, tag: str, path: str) -> None:
    if len(e["nodes"]) != e["n_nodes"]:
        fail(f"{path}: {tag}: {len(e['nodes'])} node records for "
             f"n_nodes={e['n_nodes']}")
    for n in e["nodes"]:
        if not isinstance(n, dict):
            fail(f"{path}: {tag}: node record is not an object: {n!r}")
        _check_fields(n, _CLUSTER_NODE_FIELDS,
                      f"{tag} node {n.get('node', '?')}", path)


def _suite_doc_extra(doc: dict, path: str) -> None:
    index = doc.get("index")
    if not isinstance(index, list) or not index:
        fail(f"{path}: index must be a non-empty list")
    arts = doc.get("artifacts")
    if not isinstance(arts, dict) or not arts:
        fail(f"{path}: artifacts must be a non-empty object")
    for e in index:
        if not isinstance(e, dict):
            fail(f"{path}: index entry is not an object: {e!r}")
        tag = f"index entry {e.get('scenario', '?')!r}"
        _check_fields(e, _SUITE_INDEX_FIELDS, tag, path)
    names = [e["scenario"] for e in index]
    if sorted(names) != sorted(arts):
        fail(f"{path}: index scenarios {sorted(names)} do not match "
             f"artifacts {sorted(arts)}")
    for name, art in arts.items():
        rows = art.get("rows") if isinstance(art, dict) else None
        if not isinstance(rows, list) or not rows:
            fail(f"{path}: artifact {name!r} has missing/empty rows")
        for i, row in enumerate(rows):
            if not isinstance(row, dict):
                fail(f"{path}: artifact {name!r} row {i} is not an "
                     "object")
            _check_fields(row, _SUITE_ROW_FIELDS,
                          f"artifact {name!r} row {i}", path)


# -- per-schema baseline checks (floors / invariants) ------------------------

def check_floors(doc: dict, path: str) -> list[str]:
    msgs = []
    summary = doc["summary"]
    if "default" in summary:
        s = summary["default"]["warm_speedup"]
        if s < DEFAULT_MIN_SPEEDUP:
            fail(f"{path}: default-grid warm speedup {s}x is below the "
                 f"{DEFAULT_MIN_SPEEDUP}x floor")
        msgs.append(f"default grid: {s}x (floor {DEFAULT_MIN_SPEEDUP}x)")
    if "mega" in summary:
        s, cells = (summary["mega"]["warm_speedup"],
                    summary["mega"]["cells"])
        if cells < MEGA_MIN_CELLS:
            fail(f"{path}: mega suite has {cells} cells "
                 f"(< {MEGA_MIN_CELLS})")
        if s < MEGA_MIN_SPEEDUP:
            fail(f"{path}: mega-grid warm speedup {s}x is below the "
                 f"{MEGA_MIN_SPEEDUP}x floor")
        msgs.append(f"mega grid: {s}x over {cells} cells "
                    f"(floor {MEGA_MIN_SPEEDUP}x)")
    if "het" in summary:
        agg = summary["het"]
        if "mono_speedup" not in agg:
            fail(f"{path}: het summary missing 'mono_speedup'")
        s = agg["mono_speedup"]
        if s < HET_MIN_MONO_SPEEDUP:
            fail(f"{path}: het-grid cohort-vs-monolithic speedup {s}x is "
                 f"below the {HET_MIN_MONO_SPEEDUP}x floor")
        msgs.append(
            f"het grid: cohorts {s}x over monolithic "
            f"(floor {HET_MIN_MONO_SPEEDUP}x; early exit saved "
            f"{agg.get('steps_saved_frac', 0):.1%} of bounded steps)")
    return msgs


def check_tail_invariants(doc: dict, path: str) -> list[str]:
    """The machine-independent open-loop invariants (see module doc)."""
    entries = doc["entries"]
    loads = set()
    for e in entries:
        tag = f"{e['name']} L={e['L_us']}us @{e['offered_frac']}"
        loads.add(e["offered_load"])
        if e["offered_load"] <= 0:
            fail(f"{path}: {tag}: offered_load must be > 0")
        if e["achieved_load"] > e["offered_load"] * TAIL_RAMP_TOL:
            fail(f"{path}: {tag}: achieved load {e['achieved_load']} "
                 f"exceeds offered {e['offered_load']} x {TAIL_RAMP_TOL} "
                 "-- an open-loop run cannot outrun its arrivals")
        if not 0 < e["p50_us"] <= e["p90_us"] <= e["p99_us"] \
                <= e["max_us"]:
            fail(f"{path}: {tag}: percentiles not ordered "
                 f"(p50={e['p50_us']} p90={e['p90_us']} "
                 f"p99={e['p99_us']} max={e['max_us']})")
        if not 0 <= e["miss_rate"] <= 1:
            fail(f"{path}: {tag}: miss_rate {e['miss_rate']} not in "
                 "[0, 1]")
        if e["count"] + e["missed"] != e["n_ops"]:
            fail(f"{path}: {tag}: count + missed != n_ops")
    if len(loads) < TAIL_MIN_LOADS:
        fail(f"{path}: needs >= {TAIL_MIN_LOADS} distinct offered loads, "
             f"got {sorted(loads)}")
    worst = max(e["p99_us"] / e["p50_us"] for e in entries)
    return [f"{path}: open-loop invariants ok ({len(entries)} points, "
            f"{len(loads)} offered loads, worst P99/P50 {worst:.2f}x)"]


def check_cluster_invariants(doc: dict, path: str) -> list[str]:
    """The machine-independent fleet invariants (see module doc)."""
    entries = doc["entries"]
    for e in entries:
        tag = f"{e['name']} L={e['L_us']}us"
        if e["offered_load"] <= 0:
            fail(f"{path}: {tag}: offered_load must be > 0")
        if e["achieved_load"] > e["offered_load"] * CLUSTER_RAMP_TOL:
            fail(f"{path}: {tag}: fleet achieved {e['achieved_load']} "
                 f"exceeds offered {e['offered_load']} x "
                 f"{CLUSTER_RAMP_TOL} -- an open-loop fleet cannot outrun "
                 "its arrivals")
        if not 0 < e["p50_us"] <= e["p90_us"] <= e["p99_us"] \
                <= e["max_us"]:
            fail(f"{path}: {tag}: fleet percentiles not ordered "
                 f"(p50={e['p50_us']} p90={e['p90_us']} "
                 f"p99={e['p99_us']} max={e['max_us']})")
        if not 0 <= e["miss_rate"] <= 1:
            fail(f"{path}: {tag}: miss_rate {e['miss_rate']} not in [0, 1]")
        if e["count"] + e["missed"] != e["n_ops"]:
            fail(f"{path}: {tag}: fleet count + missed != n_ops")
        share_sum = sum(n["share"] for n in e["nodes"])
        if abs(share_sum - 1.0) > CLUSTER_SHARE_TOL:
            fail(f"{path}: {tag}: node shares sum to {share_sum}, not 1")
        for n in e["nodes"]:
            ntag = f"{tag} node {n['node']}"
            if n["n_ops"] == 0:
                continue
            if n["count"] + n["missed"] != n["n_ops"]:
                fail(f"{path}: {ntag}: count + missed != n_ops")
            if not e["migrate"] and n["achieved_load"] > \
                    n["offered_load"] * CLUSTER_RAMP_TOL:
                fail(f"{path}: {ntag}: achieved {n['achieved_load']} "
                     f"exceeds offered {n['offered_load']} x "
                     f"{CLUSTER_RAMP_TOL}")
    degraded = [e for e in entries
                if any(n["degraded"] and n["n_ops"] > 0
                       for n in e["nodes"])]
    declared = {name for name, agg in doc["summary"].items()
                if agg["degraded_nodes"]}
    if declared and not degraded:
        fail(f"{path}: summary declares degraded nodes in "
             f"{sorted(declared)} but no entry carries a degraded node "
             "serving ops")
    if not declared:
        fail(f"{path}: no scenario declares a degraded node -- the "
             "degraded-node scenario is part of the suite")
    scenarios = sorted({e["name"] for e in entries})
    return [f"{path}: fleet invariants ok ({len(entries)} points, "
            f"{len(scenarios)} scenarios {scenarios}, "
            f"{len(degraded)} degraded-node points)"]


def check_suite_invariants(doc: dict, path: str) -> list[str]:
    """Scenario-suite invariants: the index and the row tables agree and
    every row is a plausible operating point."""
    index = {e["scenario"]: e for e in doc["index"]}
    n_cluster = 0
    for name, art in doc["artifacts"].items():
        rows = art["rows"]
        if index[name]["n_rows"] != len(rows):
            fail(f"{path}: artifact {name!r} has {len(rows)} rows but "
                 f"the index declares {index[name]['n_rows']}")
        for i, row in enumerate(rows):
            tag = f"artifact {name!r} row {i}"
            if row["throughput"] <= 0 or row["model_throughput"] <= 0:
                fail(f"{path}: {tag}: throughput must be > 0")
            if row["n_threads"] < 1:
                fail(f"{path}: {tag}: n_threads must be >= 1")
            nodes = row.get("nodes")
            if nodes:
                n_cluster += 1
                if len(nodes) != index[name]["cluster_nodes"]:
                    fail(f"{path}: {tag}: {len(nodes)} node records but "
                         f"the index declares "
                         f"{index[name]['cluster_nodes']} nodes")
                share_sum = sum(n["share"] for n in nodes)
                if abs(share_sum - 1.0) > CLUSTER_SHARE_TOL:
                    fail(f"{path}: {tag}: node shares sum to "
                         f"{share_sum}, not 1")
    total = sum(len(a["rows"]) for a in doc["artifacts"].values())
    return [f"{path}: suite invariants ok ({len(index)} scenarios, "
            f"{total} rows, {n_cluster} cluster rows)"]


# -- per-schema fresh-vs-baseline checks -------------------------------------

def check_regression(fresh: dict, base: dict, max_regress: float) -> list:
    msgs = []
    base_sum = base["summary"]
    compared = 0
    for name, agg in fresh["summary"].items():
        if name not in base_sum:
            continue
        compared += 1
        got, ref = agg["warm_speedup"], base_sum[name]["warm_speedup"]
        if got * max_regress < ref:
            fail(f"suite {name!r}: warm speedup {got}x vs baseline "
                 f"{ref}x -- regressed more than {max_regress}x")
        msgs.append(f"{name}: {got}x vs baseline {ref}x "
                    f"(allowed >= {ref / max_regress:.2f}x)")
    if not compared:
        fail("fresh file shares no suite with the baseline "
             f"(fresh: {sorted(fresh['summary'])}, "
             f"baseline: {sorted(base_sum)})")
    return msgs


def _fresh_invariants(check):
    """Fresh-mode hook for schemas whose invariants are
    machine-independent: enforce them on the fresh file directly, no
    baseline ratio."""
    def hook(fresh, base, fresh_path, max_regress):
        return check(fresh, fresh_path)
    return hook


def _fresh_grid(fresh, base, fresh_path, max_regress):
    return check_regression(fresh, base, max_regress)


def _fresh_suite(fresh, base, fresh_path, max_regress):
    # Row-level drift vs the baseline is artifact_diff's job; here only
    # the fresh file's own invariants are enforceable.
    return (check_suite_invariants(fresh, fresh_path)
            + ["suite rows: compare vs the baseline with "
               "tools/artifact_diff.py"])


# -- the schema table --------------------------------------------------------

class SchemaSpec:
    """Everything schema-specific, as one table row: entry shape,
    per-entry and per-document validation hooks, summary fields, and the
    baseline/fresh check functions."""

    def __init__(self, name, summary_fields, baseline_check, fresh_check,
                 entry_fields=None, entry_tag=None, entry_extra=None,
                 doc_extra=None, size=None, flat_summary=False):
        self.name = name
        self.entry_fields = entry_fields
        self.entry_tag = entry_tag or (
            lambda e: f"entry {e.get('name', '?')!r}")
        self.entry_extra = entry_extra
        self.summary_fields = summary_fields
        # flat_summary: summary is one object of aggregate fields (the
        # suite schema) rather than a per-suite mapping of objects.
        self.flat_summary = flat_summary
        self.doc_extra = doc_extra
        self.baseline_check = baseline_check
        self.fresh_check = fresh_check
        self.size = size or (lambda d: f"{len(d['entries'])} entries")

    def validate(self, doc: dict, path: str) -> None:
        host = doc.get("host")
        if not isinstance(host, dict) or "cpu_count" not in host:
            fail(f"{path}: missing/invalid host block")
        if self.entry_fields is not None:
            entries = doc.get("entries")
            if not isinstance(entries, list) or not entries:
                fail(f"{path}: entries must be a non-empty list")
            for e in entries:
                if not isinstance(e, dict):
                    fail(f"{path}: entry is not an object: {e!r}")
                tag = self.entry_tag(e)
                _check_fields(e, self.entry_fields, tag, path)
                if self.entry_extra is not None:
                    self.entry_extra(e, tag, path)
        summary = doc.get("summary")
        if not isinstance(summary, dict) or not summary:
            fail(f"{path}: summary must be a non-empty object")
        if self.flat_summary:
            for field in self.summary_fields:
                if field not in summary:
                    fail(f"{path}: summary missing {field!r}")
        else:
            for name, agg in summary.items():
                if not isinstance(agg, dict):
                    fail(f"{path}: summary {name!r} is not an object")
                for field in self.summary_fields:
                    if field not in agg:
                        fail(f"{path}: summary {name!r} missing {field!r}")
        if self.doc_extra is not None:
            self.doc_extra(doc, path)


def _tag_with_lat(kind):
    return lambda e: (f"{kind} entry {e.get('name', '?')!r} "
                      f"(L={e.get('L_us', '?')}us)")


SCHEMAS: dict[str, SchemaSpec] = {
    SCHEMA: SchemaSpec(
        SCHEMA,
        entry_fields=_ENTRY_FIELDS,
        entry_extra=_grid_entry_extra,
        summary_fields=("cells", "loop_s", "jax_warm_s", "warm_speedup"),
        baseline_check=check_floors,
        fresh_check=_fresh_grid,
    ),
    TAIL_SCHEMA: SchemaSpec(
        TAIL_SCHEMA,
        entry_fields=_TAIL_ENTRY_FIELDS,
        entry_tag=_tag_with_lat("tail"),
        summary_fields=("capacity", "offered_fracs", "n_points"),
        baseline_check=check_tail_invariants,
        fresh_check=_fresh_invariants(check_tail_invariants),
    ),
    CLUSTER_SCHEMA: SchemaSpec(
        CLUSTER_SCHEMA,
        entry_fields=_CLUSTER_ENTRY_FIELDS,
        entry_tag=_tag_with_lat("cluster"),
        entry_extra=_cluster_entry_extra,
        summary_fields=("capacity", "offered_frac", "n_points", "n_nodes",
                        "hottest_share", "degraded_nodes", "migrate"),
        baseline_check=check_cluster_invariants,
        fresh_check=_fresh_invariants(check_cluster_invariants),
    ),
    SUITE_SCHEMA: SchemaSpec(
        SUITE_SCHEMA,
        summary_fields=("n_scenarios", "total_rows", "total_wall_s"),
        flat_summary=True,
        doc_extra=_suite_doc_extra,
        baseline_check=check_suite_invariants,
        fresh_check=_fresh_suite,
        size=lambda d: (f"{len(d['artifacts'])} scenarios, "
                        f"{sum(len(a['rows']) for a in d['artifacts'].values())} rows"),
    ),
}


def load(path: str) -> tuple[dict, SchemaSpec]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{path}: unreadable or not JSON ({e})")
    got = doc.get("schema") if isinstance(doc, dict) else doc
    spec = SCHEMAS.get(got) if isinstance(got, str) else None
    if spec is None:
        fail(f"{path}: schema must be one of {sorted(SCHEMAS)}, "
             f"got {got!r}")
    spec.validate(doc, path)
    return doc, spec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline_pos", nargs="?", default=None,
                    metavar="BENCH.json",
                    help="baseline to schema-validate and floor-check")
    ap.add_argument("--fresh", default=None, metavar="NEW.json",
                    help="freshly measured file to compare vs --baseline")
    ap.add_argument("--baseline", default=None, metavar="BENCH.json")
    ap.add_argument("--max-regress", type=float, default=3.0,
                    help="max allowed warm-speedup regression factor "
                         "(default 3.0)")
    args = ap.parse_args()

    baseline_path = args.baseline or args.baseline_pos
    if baseline_path is None:
        ap.error("need a baseline file (positional or --baseline)")
    base, spec = load(baseline_path)
    msgs = [f"{baseline_path}: schema ok ({spec.size(base)})"]
    msgs += spec.baseline_check(base, baseline_path)

    if args.fresh:
        fresh, fresh_spec = load(args.fresh)
        msgs.append(f"{args.fresh}: schema ok")
        if fresh_spec.name != spec.name:
            fail(f"{args.fresh}: schema {fresh_spec.name!r} does not "
                 f"match baseline {spec.name!r}")
        msgs += spec.fresh_check(fresh, base, args.fresh,
                                 args.max_regress)

    for m in msgs:
        print(f"check_bench: {m}")
    print("check_bench: OK")


if __name__ == "__main__":
    main()
