#!/usr/bin/env python
"""Validate checked-in benchmark measurements (schema + floors).

Handles three measurement schemas, dispatched on the file's ``schema``
field:

``repro.jax_grid_bench/v1`` (``BENCH_jax_grid.json``)
    Perf measurements.  Baseline mode enforces the repo's acceptance
    floors on whatever suites it contains: warm jax >= 1x the loop
    pipeline on the paper default grid, >= 5x on a >= 2000-cell mega
    grid, and cohort early-exit >= 1.5x the monolithic single-scan
    layout on the heterogeneous (het) grid.

``repro.tail_latency_bench/v1`` (``BENCH_tail_latency.json``)
    Open-loop tail-latency measurements (see
    ``benchmarks/tail_latency_bench.py``).  Invariants instead of perf
    floors: achieved load <= offered load (an open-loop run cannot
    complete faster than ops arrive, beyond a small ramp tolerance),
    P99 >= P90 >= P50 > 0 per entry, miss_rate in [0, 1], and >= 2
    distinct offered loads so the load axis of the figure exists.

``repro.cluster_bench/v1`` (``BENCH_cluster.json``)
    Sharded-fleet measurements (see ``benchmarks/cluster_bench.py``).
    Machine-independent invariants: ordered fleet percentiles, fleet
    achieved load <= offered, per-node achieved <= offered on entries
    without a migration event (a handover time-concentrates a node's
    arrivals into a sub-window, so its windowed rate may legitimately
    exceed its stream-averaged offered rate), per-entry node shares
    summing to 1, count + missed == n_ops at both levels, and the
    degraded-node scenario actually containing a degraded node.

Two modes::

    python tools/check_bench.py BENCH_jax_grid.json
        Schema-validate the checked-in baseline and enforce its
        schema's floors/invariants.

    python tools/check_bench.py --fresh smoke.json \
        --baseline BENCH_jax_grid.json [--max-regress 3.0]
        CI perf-smoke: schema-validate a freshly measured file too.
        For the jax-grid schema, additionally fail if the warm
        jax/loop ratio regressed by more than ``--max-regress`` x vs
        the same-named suite in the baseline (deliberately generous --
        CI machines differ from the baseline machine; the job catches
        order-of-magnitude regressions, not 20% noise).  For the
        tail-latency schema the fresh file's invariants are enforced
        directly -- they are machine-independent -- and no ratio is
        compared.

Exit status 0 on success; 1 with a message on any failure.
"""
from __future__ import annotations

import argparse
import json
import sys

SCHEMA = "repro.jax_grid_bench/v1"
TAIL_SCHEMA = "repro.tail_latency_bench/v1"
CLUSTER_SCHEMA = "repro.cluster_bench/v1"

# Open-loop invariants: achieved may exceed offered only by the ramp
# tolerance.  The first total_threads arrivals are backlogged at t=0
# and burn down faster than the offered rate, so a measurement window
# of n_ops ops overshoots by O(threads / n_ops): ~1.7% at the full
# suite's 4000 ops, ~4% at the smoke suite's 800.
TAIL_RAMP_TOL = 1.05
TAIL_MIN_LOADS = 2

_TAIL_ENTRY_FIELDS = {
    "name": str, "engine": str, "L_us": (int, float), "n_threads": int,
    "n_ops": int, "offered_frac": (int, float),
    "offered_load": (int, float), "achieved_load": (int, float),
    "p50_us": (int, float), "p90_us": (int, float),
    "p99_us": (int, float), "max_us": (int, float), "count": int,
    "missed": int, "miss_rate": (int, float), "source": str,
}

# Cluster invariants: a node's sub-stream need not be time-homogeneous
# (startup ramp plus skew drift concentrate its arrivals), so the
# per-node bound is generous -- it catches frame/unit errors, not 20%
# windowing.  Entries with migrate=true skip the per-node bound entirely.
CLUSTER_RAMP_TOL = 1.25
CLUSTER_SHARE_TOL = 1e-3

_CLUSTER_ENTRY_FIELDS = {
    "name": str, "engine": str, "backend": str, "n_nodes": int,
    "L_us": (int, float), "n_threads": int, "n_ops": int,
    "migrate": bool, "offered_frac": (int, float),
    "offered_load": (int, float), "achieved_load": (int, float),
    "p50_us": (int, float), "p90_us": (int, float),
    "p99_us": (int, float), "max_us": (int, float), "count": int,
    "missed": int, "miss_rate": (int, float), "source": str,
    "nodes": list,
}

_CLUSTER_NODE_FIELDS = {
    "node": int, "share": (int, float), "degraded": bool, "n_ops": int,
    "offered_load": (int, float), "achieved_load": (int, float),
    "count": int, "missed": int,
}

_ENTRY_FIELDS = {
    "name": str, "engine": str, "n_ssd": int, "n_latencies": int,
    "n_threads": int, "cells": int, "n_ops": int, "loop_s": (int, float),
    "loop_mode": str, "jax_cold_s": (int, float),
    "jax_warm_s": (int, float), "warm_speedup": (int, float),
}

# het-suite entries additionally carry the cohort-vs-monolithic
# measurement and the early-exit wasted-step counters.
_HET_FIELDS = {
    "jax_cohort_warm_s": (int, float), "jax_mono_warm_s": (int, float),
    "mono_speedup": (int, float), "cell_steps_bound": int,
    "cell_steps_run": int, "steps_saved_frac": (int, float),
}

# Acceptance floors enforced on the checked-in baseline.
DEFAULT_MIN_SPEEDUP = 1.0
MEGA_MIN_SPEEDUP = 5.0
MEGA_MIN_CELLS = 2000
HET_MIN_MONO_SPEEDUP = 1.5


def fail(msg: str) -> None:
    sys.exit(f"check_bench: FAIL: {msg}")


def load(path: str) -> dict:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{path}: unreadable or not JSON ({e})")
    if isinstance(doc, dict) and doc.get("schema") == TAIL_SCHEMA:
        validate_tail_schema(doc, path)
    elif isinstance(doc, dict) and doc.get("schema") == CLUSTER_SCHEMA:
        validate_cluster_schema(doc, path)
    else:
        validate_schema(doc, path)
    return doc


def _check_fields(obj: dict, fields: dict, where: str, path: str) -> None:
    """Presence + type check (bool only passes where bool is declared)."""
    for field, typ in fields.items():
        if field not in obj:
            fail(f"{path}: {where} missing {field!r}")
        v = obj[field]
        if typ is bool:
            if not isinstance(v, bool):
                fail(f"{path}: {where} field {field!r} has type "
                     f"{type(v).__name__}, wanted bool")
        elif not isinstance(v, typ) or isinstance(v, bool):
            fail(f"{path}: {where} field {field!r} has type "
                 f"{type(v).__name__}")


def validate_cluster_schema(doc: dict, path: str) -> None:
    host = doc.get("host")
    if not isinstance(host, dict) or "cpu_count" not in host:
        fail(f"{path}: missing/invalid host block")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        fail(f"{path}: entries must be a non-empty list")
    for e in entries:
        if not isinstance(e, dict):
            fail(f"{path}: entry is not an object: {e!r}")
        tag = f"cluster entry {e.get('name', '?')!r} (L={e.get('L_us', '?')}us)"
        _check_fields(e, _CLUSTER_ENTRY_FIELDS, tag, path)
        if len(e["nodes"]) != e["n_nodes"]:
            fail(f"{path}: {tag}: {len(e['nodes'])} node records for "
                 f"n_nodes={e['n_nodes']}")
        for n in e["nodes"]:
            if not isinstance(n, dict):
                fail(f"{path}: {tag}: node record is not an object: {n!r}")
            _check_fields(n, _CLUSTER_NODE_FIELDS,
                          f"{tag} node {n.get('node', '?')}", path)
    summary = doc.get("summary")
    if not isinstance(summary, dict) or not summary:
        fail(f"{path}: summary must be a non-empty object")
    for name, agg in summary.items():
        for field in ("capacity", "offered_frac", "n_points", "n_nodes",
                      "hottest_share", "degraded_nodes", "migrate"):
            if field not in agg:
                fail(f"{path}: summary {name!r} missing {field!r}")


def check_cluster_invariants(doc: dict, path: str) -> list[str]:
    """The machine-independent fleet invariants (see module doc)."""
    entries = doc["entries"]
    for e in entries:
        tag = f"{e['name']} L={e['L_us']}us"
        if e["offered_load"] <= 0:
            fail(f"{path}: {tag}: offered_load must be > 0")
        if e["achieved_load"] > e["offered_load"] * CLUSTER_RAMP_TOL:
            fail(f"{path}: {tag}: fleet achieved {e['achieved_load']} "
                 f"exceeds offered {e['offered_load']} x "
                 f"{CLUSTER_RAMP_TOL} -- an open-loop fleet cannot outrun "
                 "its arrivals")
        if not 0 < e["p50_us"] <= e["p90_us"] <= e["p99_us"] \
                <= e["max_us"]:
            fail(f"{path}: {tag}: fleet percentiles not ordered "
                 f"(p50={e['p50_us']} p90={e['p90_us']} "
                 f"p99={e['p99_us']} max={e['max_us']})")
        if not 0 <= e["miss_rate"] <= 1:
            fail(f"{path}: {tag}: miss_rate {e['miss_rate']} not in [0, 1]")
        if e["count"] + e["missed"] != e["n_ops"]:
            fail(f"{path}: {tag}: fleet count + missed != n_ops")
        share_sum = sum(n["share"] for n in e["nodes"])
        if abs(share_sum - 1.0) > CLUSTER_SHARE_TOL:
            fail(f"{path}: {tag}: node shares sum to {share_sum}, not 1")
        for n in e["nodes"]:
            ntag = f"{tag} node {n['node']}"
            if n["n_ops"] == 0:
                continue
            if n["count"] + n["missed"] != n["n_ops"]:
                fail(f"{path}: {ntag}: count + missed != n_ops")
            if not e["migrate"] and n["achieved_load"] > \
                    n["offered_load"] * CLUSTER_RAMP_TOL:
                fail(f"{path}: {ntag}: achieved {n['achieved_load']} "
                     f"exceeds offered {n['offered_load']} x "
                     f"{CLUSTER_RAMP_TOL}")
    degraded = [e for e in entries
                if any(n["degraded"] and n["n_ops"] > 0
                       for n in e["nodes"])]
    declared = {name for name, agg in doc["summary"].items()
                if agg["degraded_nodes"]}
    if declared and not degraded:
        fail(f"{path}: summary declares degraded nodes in "
             f"{sorted(declared)} but no entry carries a degraded node "
             "serving ops")
    if not declared:
        fail(f"{path}: no scenario declares a degraded node -- the "
             "degraded-node scenario is part of the suite")
    scenarios = sorted({e["name"] for e in entries})
    return [f"{path}: fleet invariants ok ({len(entries)} points, "
            f"{len(scenarios)} scenarios {scenarios}, "
            f"{len(degraded)} degraded-node points)"]


def validate_tail_schema(doc: dict, path: str) -> None:
    host = doc.get("host")
    if not isinstance(host, dict) or "cpu_count" not in host:
        fail(f"{path}: missing/invalid host block")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        fail(f"{path}: entries must be a non-empty list")
    for e in entries:
        if not isinstance(e, dict):
            fail(f"{path}: entry is not an object: {e!r}")
        for field, typ in _TAIL_ENTRY_FIELDS.items():
            if field not in e:
                fail(f"{path}: tail entry {e.get('name', '?')!r} "
                     f"(L={e.get('L_us', '?')}us) missing {field!r}")
            if not isinstance(e[field], typ) or isinstance(e[field], bool):
                fail(f"{path}: tail entry {e['name']!r} field {field!r} "
                     f"has type {type(e[field]).__name__}")
    summary = doc.get("summary")
    if not isinstance(summary, dict) or not summary:
        fail(f"{path}: summary must be a non-empty object")
    for name, agg in summary.items():
        for field in ("capacity", "offered_fracs", "n_points"):
            if field not in agg:
                fail(f"{path}: summary {name!r} missing {field!r}")


def check_tail_invariants(doc: dict, path: str) -> list[str]:
    """The machine-independent open-loop invariants (see module doc)."""
    entries = doc["entries"]
    loads = set()
    for e in entries:
        tag = f"{e['name']} L={e['L_us']}us @{e['offered_frac']}"
        loads.add(e["offered_load"])
        if e["offered_load"] <= 0:
            fail(f"{path}: {tag}: offered_load must be > 0")
        if e["achieved_load"] > e["offered_load"] * TAIL_RAMP_TOL:
            fail(f"{path}: {tag}: achieved load {e['achieved_load']} "
                 f"exceeds offered {e['offered_load']} x {TAIL_RAMP_TOL} "
                 "-- an open-loop run cannot outrun its arrivals")
        if not 0 < e["p50_us"] <= e["p90_us"] <= e["p99_us"] \
                <= e["max_us"]:
            fail(f"{path}: {tag}: percentiles not ordered "
                 f"(p50={e['p50_us']} p90={e['p90_us']} "
                 f"p99={e['p99_us']} max={e['max_us']})")
        if not 0 <= e["miss_rate"] <= 1:
            fail(f"{path}: {tag}: miss_rate {e['miss_rate']} not in "
                 "[0, 1]")
        if e["count"] + e["missed"] != e["n_ops"]:
            fail(f"{path}: {tag}: count + missed != n_ops")
    if len(loads) < TAIL_MIN_LOADS:
        fail(f"{path}: needs >= {TAIL_MIN_LOADS} distinct offered loads, "
             f"got {sorted(loads)}")
    worst = max(e["p99_us"] / e["p50_us"] for e in entries)
    return [f"{path}: open-loop invariants ok ({len(entries)} points, "
            f"{len(loads)} offered loads, worst P99/P50 {worst:.2f}x)"]


def validate_schema(doc: dict, path: str) -> None:
    if not isinstance(doc, dict) or doc.get("schema") != SCHEMA:
        fail(f"{path}: schema must be {SCHEMA!r}, {TAIL_SCHEMA!r} or "
             f"{CLUSTER_SCHEMA!r}, "
             f"got {doc.get('schema') if isinstance(doc, dict) else doc!r}")
    host = doc.get("host")
    if not isinstance(host, dict) or "cpu_count" not in host:
        fail(f"{path}: missing/invalid host block")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        fail(f"{path}: entries must be a non-empty list")
    for e in entries:
        if not isinstance(e, dict):
            fail(f"{path}: entry is not an object: {e!r}")
        for field, typ in _ENTRY_FIELDS.items():
            if field not in e:
                fail(f"{path}: entry {e.get('name', '?')!r} missing "
                     f"{field!r}")
            if not isinstance(e[field], typ) or isinstance(e[field], bool):
                fail(f"{path}: entry {e['name']!r} field {field!r} has "
                     f"type {type(e[field]).__name__}")
        if e["cells"] != e["n_latencies"] * e["n_threads"]:
            fail(f"{path}: entry {e['name']!r}: cells != lats * threads")
        for field in ("loop_s", "jax_cold_s", "jax_warm_s",
                      "warm_speedup"):
            if e[field] <= 0:
                fail(f"{path}: entry {e['name']!r}: {field} must be > 0")
        if e["name"].startswith("het"):
            for field, typ in _HET_FIELDS.items():
                if field not in e:
                    fail(f"{path}: het entry {e['name']!r} missing "
                         f"{field!r}")
                if (not isinstance(e[field], typ)
                        or isinstance(e[field], bool)):
                    fail(f"{path}: entry {e['name']!r} field {field!r} "
                         f"has type {type(e[field]).__name__}")
            if e["cell_steps_run"] > e["cell_steps_bound"]:
                fail(f"{path}: entry {e['name']!r}: cell_steps_run "
                     "exceeds cell_steps_bound")
    summary = doc.get("summary")
    if not isinstance(summary, dict) or not summary:
        fail(f"{path}: summary must be a non-empty object")
    for name, agg in summary.items():
        for field in ("cells", "loop_s", "jax_warm_s", "warm_speedup"):
            if field not in agg:
                fail(f"{path}: summary {name!r} missing {field!r}")


def check_floors(doc: dict, path: str) -> list[str]:
    msgs = []
    summary = doc["summary"]
    if "default" in summary:
        s = summary["default"]["warm_speedup"]
        if s < DEFAULT_MIN_SPEEDUP:
            fail(f"{path}: default-grid warm speedup {s}x is below the "
                 f"{DEFAULT_MIN_SPEEDUP}x floor")
        msgs.append(f"default grid: {s}x (floor {DEFAULT_MIN_SPEEDUP}x)")
    if "mega" in summary:
        s, cells = (summary["mega"]["warm_speedup"],
                    summary["mega"]["cells"])
        if cells < MEGA_MIN_CELLS:
            fail(f"{path}: mega suite has {cells} cells "
                 f"(< {MEGA_MIN_CELLS})")
        if s < MEGA_MIN_SPEEDUP:
            fail(f"{path}: mega-grid warm speedup {s}x is below the "
                 f"{MEGA_MIN_SPEEDUP}x floor")
        msgs.append(f"mega grid: {s}x over {cells} cells "
                    f"(floor {MEGA_MIN_SPEEDUP}x)")
    if "het" in summary:
        agg = summary["het"]
        if "mono_speedup" not in agg:
            fail(f"{path}: het summary missing 'mono_speedup'")
        s = agg["mono_speedup"]
        if s < HET_MIN_MONO_SPEEDUP:
            fail(f"{path}: het-grid cohort-vs-monolithic speedup {s}x is "
                 f"below the {HET_MIN_MONO_SPEEDUP}x floor")
        msgs.append(
            f"het grid: cohorts {s}x over monolithic "
            f"(floor {HET_MIN_MONO_SPEEDUP}x; early exit saved "
            f"{agg.get('steps_saved_frac', 0):.1%} of bounded steps)")
    return msgs


def check_regression(fresh: dict, base: dict, max_regress: float) -> list:
    msgs = []
    base_sum = base["summary"]
    compared = 0
    for name, agg in fresh["summary"].items():
        if name not in base_sum:
            continue
        compared += 1
        got, ref = agg["warm_speedup"], base_sum[name]["warm_speedup"]
        if got * max_regress < ref:
            fail(f"suite {name!r}: warm speedup {got}x vs baseline "
                 f"{ref}x -- regressed more than {max_regress}x")
        msgs.append(f"{name}: {got}x vs baseline {ref}x "
                    f"(allowed >= {ref / max_regress:.2f}x)")
    if not compared:
        fail("fresh file shares no suite with the baseline "
             f"(fresh: {sorted(fresh['summary'])}, "
             f"baseline: {sorted(base_sum)})")
    return msgs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline_pos", nargs="?", default=None,
                    metavar="BENCH.json",
                    help="baseline to schema-validate and floor-check")
    ap.add_argument("--fresh", default=None, metavar="NEW.json",
                    help="freshly measured file to compare vs --baseline")
    ap.add_argument("--baseline", default=None, metavar="BENCH.json")
    ap.add_argument("--max-regress", type=float, default=3.0,
                    help="max allowed warm-speedup regression factor "
                         "(default 3.0)")
    args = ap.parse_args()

    baseline_path = args.baseline or args.baseline_pos
    if baseline_path is None:
        ap.error("need a baseline file (positional or --baseline)")
    base = load(baseline_path)
    msgs = [f"{baseline_path}: schema ok "
            f"({len(base['entries'])} entries)"]
    if base["schema"] == TAIL_SCHEMA:
        msgs += check_tail_invariants(base, baseline_path)
    elif base["schema"] == CLUSTER_SCHEMA:
        msgs += check_cluster_invariants(base, baseline_path)
    else:
        msgs += check_floors(base, baseline_path)

    if args.fresh:
        fresh = load(args.fresh)
        msgs.append(f"{args.fresh}: schema ok")
        if fresh["schema"] != base["schema"]:
            fail(f"{args.fresh}: schema {fresh['schema']!r} does not "
                 f"match baseline {base['schema']!r}")
        if base["schema"] == TAIL_SCHEMA:
            # tail/cluster invariants are machine-independent: enforce
            # them on the fresh measurement directly, no baseline ratio
            msgs += check_tail_invariants(fresh, args.fresh)
        elif base["schema"] == CLUSTER_SCHEMA:
            msgs += check_cluster_invariants(fresh, args.fresh)
        else:
            msgs += check_regression(fresh, base, args.max_regress)

    for m in msgs:
        print(f"check_bench: {m}")
    print("check_bench: OK")


if __name__ == "__main__":
    main()
