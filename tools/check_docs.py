#!/usr/bin/env python
"""Docs smoke-checker: run the code in the docs, resolve the links.

Checks two things over ``README.md`` + ``docs/*.md``:

1. **Code blocks run.**  Every fenced ```` ```python ```` block is executed
   (doctest-style smoke): blocks within one file share a namespace, in
   order, so a guide can build on earlier snippets.  A block whose fence
   info string contains ``no-run`` (```` ```python no-run ````) is parsed
   for syntax only.  Shell blocks are never executed.
2. **Internal links resolve.**  Every relative markdown link target
   (``[text](../src/...)``, anchors stripped) must exist on disk; http(s)/
   mailto links are ignored.

Exit code 0 iff everything passes; findings are printed one per line as
``file:line: message``.  Run from the repo root with ``PYTHONPATH=src``:

    PYTHONPATH=src python tools/check_docs.py                      # all docs
    PYTHONPATH=src python tools/check_docs.py docs/SIMULATION.md   # a subset
    PYTHONPATH=src python tools/check_docs.py --exclude docs/SIMULATION.md

The CI ``docs`` job splits along that line: the generic pass excludes
``docs/SIMULATION.md`` and a dedicated step runs just that chapter (it
drives jax and is by far the slowest doc, so a failure should name it and
nothing should execute it twice); ``tests/test_docs.py`` runs the same
checks in-process so the tier-1 suite catches doc rot too.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

# ```python [info...]\n ... \n``` (tolerates indented closing fence)
_FENCE = re.compile(
    r"^```(?P<info>[^\n`]*)\n(?P<body>.*?)^[ \t]*```[ \t]*$",
    re.S | re.M,
)
# [text](target) -- skips images' leading ! by matching the bracket pair only
_LINK = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")

_EXTERNAL = ("http://", "https://", "mailto:")


def _rel(path: Path) -> str:
    """Repo-relative display path (absolute for files outside the repo)."""
    try:
        return str(path.relative_to(REPO_ROOT))
    except ValueError:
        return str(path)


def doc_files(root: Path = REPO_ROOT) -> list[Path]:
    files = []
    readme = root / "README.md"
    if readme.exists():
        files.append(readme)
    files.extend(sorted((root / "docs").glob("*.md")))
    return files


def iter_python_blocks(text: str):
    """Yield ``(line_number, info_string, source)`` per fenced python block."""
    for m in _FENCE.finditer(text):
        info = m.group("info").strip().lower()
        if not info.startswith("python"):
            continue
        line = text.count("\n", 0, m.start()) + 1
        yield line, info, m.group("body")


def check_code_blocks(path: Path) -> list[str]:
    """Execute every python block of one file in a shared namespace."""
    problems = []
    ns: dict = {"__name__": f"docs_check_{path.stem}"}
    for line, info, src in iter_python_blocks(path.read_text()):
        where = f"{_rel(path)}:{line}"
        try:
            code = compile(src, f"{where} (code block)", "exec")
        except SyntaxError as e:
            problems.append(f"{where}: syntax error in code block: {e}")
            continue
        if "no-run" in info:
            continue
        try:
            exec(code, ns)  # noqa: S102 - the whole point of the checker
        except Exception as e:  # noqa: BLE001
            problems.append(
                f"{where}: code block raised {type(e).__name__}: {e}"
            )
    return problems


def check_links(path: Path) -> list[str]:
    problems = []
    text = path.read_text()
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            line = text.count("\n", 0, m.start()) + 1
            problems.append(
                f"{_rel(path)}:{line}: broken link -> {target}"
            )
    return problems


def run(root: Path = REPO_ROOT,
        files: list[Path] | None = None) -> list[str]:
    problems = []
    for path in files if files is not None else doc_files(root):
        problems.extend(check_links(path))
        problems.extend(check_code_blocks(path))
    return problems


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    names: list[str] = []
    excludes: list[str] = []
    it = iter(argv)
    for a in it:
        if a == "--exclude":
            nxt = next(it, None)
            if nxt is None:
                print("check_docs: --exclude needs a path")
                return 1
            excludes.append(nxt)
        else:
            names.append(a)
    if names:
        files = [Path(a).resolve() for a in names]
        missing = [str(p) for p in files if not p.is_file()]
        if missing:
            print(f"check_docs: no such doc file(s): {', '.join(missing)}")
            return 1
    else:
        files = doc_files()
    skip = {Path(e).resolve() for e in excludes}
    files = [f for f in files if f.resolve() not in skip]
    problems = run(files=files)
    for p in problems:
        print(p)
    print(
        f"check_docs: {len(files)} files, "
        f"{sum(len(list(iter_python_blocks(f.read_text()))) for f in files)} "
        f"python blocks, {len(problems)} problem(s)"
    )
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
