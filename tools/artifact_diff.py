#!/usr/bin/env python
"""Compare two RunArtifact JSONs row by row, with a threshold exit code.

A :class:`~repro.core.experiment.RunArtifact` is the serialized result of
one scenario run (sweep table + model predictions + tail summaries).  Two
artifacts of the *same scenario* should agree: across backends within the
documented tolerance, across machines exactly (the simulator is
deterministic in virtual time), across code changes within whatever bound
the change claims.  This tool makes that check scriptable::

    python tools/artifact_diff.py A.json B.json
        Report per-row relative differences (throughput, model error,
        tail percentiles); exit 0.

    python tools/artifact_diff.py A.json B.json --max-rel 0.01
        Additionally exit 1 if any compared quantity differs by more
        than 1% relative (--max-rel-tail overrides the bound for tail
        percentiles, which carry binning error on the jax backend).

    python tools/artifact_diff.py A.json B.json --exact
        Exit 1 unless every compared row quantity is bit-identical
        (loop-backend determinism checks).

Rows are aligned by their latency label; artifacts whose latency axes or
winning thread counts disagree exit 2 (structural mismatch -- thread
counts are part of the operating point, not a tolerance question).
Cluster artifacts additionally compare per-node throughput and tails.
Stdlib-only, like the other ``tools/`` checkers.
"""
from __future__ import annotations

import argparse
import json
import sys

TAIL_FIELDS = ("p50_us", "p90_us", "p99_us")


def load_rows(path: str) -> list[dict]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"artifact_diff: FAIL: {path}: unreadable or not JSON "
                 f"({e})")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        sys.exit(f"artifact_diff: FAIL: {path}: not a RunArtifact "
                 "(missing/empty rows)")
    return rows


def label(row: dict) -> str:
    l_us = row["L_us"]
    if isinstance(l_us, list):
        return "Lmix" + "|".join(f"{lat:g}@{p:g}" for lat, p in l_us) + "us"
    return f"L{l_us:g}us"


def rel(a: float, b: float) -> float:
    ref = max(abs(a), abs(b))
    return abs(a - b) / ref if ref else 0.0


class Diff:
    """Accumulates compared quantities and the worst relative error."""

    def __init__(self) -> None:
        self.worst = 0.0
        self.worst_what = "nothing compared"
        self.n = 0

    def add(self, what: str, a: float, b: float) -> float:
        r = rel(a, b)
        self.n += 1
        if self.n == 1 or r > self.worst:
            self.worst, self.worst_what = r, what
        return r


def diff_tails(what: str, ta: dict | None, tb: dict | None,
               d: Diff, out: list[str]) -> None:
    if ta is None or tb is None:
        if (ta is None) != (tb is None):
            out.append(f"  {what}: tail only in one artifact (skipped)")
        return
    parts = []
    for f in TAIL_FIELDS:
        va, vb = ta.get(f), tb.get(f)
        if va is None or vb is None:
            continue
        r = d.add(f"{what} {f}", va, vb)
        parts.append(f"{f} {va:g}/{vb:g} ({r:+.2%})")
    if parts:
        out.append(f"  {what}: " + "  ".join(parts))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("a", metavar="A.json")
    ap.add_argument("b", metavar="B.json")
    ap.add_argument("--max-rel", type=float, default=None, metavar="FRAC",
                    help="exit 1 if any compared quantity differs by more "
                         "than FRAC relative (default: report only)")
    ap.add_argument("--max-rel-tail", type=float, default=None,
                    metavar="FRAC",
                    help="separate bound for tail percentiles (default: "
                         "--max-rel; jax-backend tails carry ~2% binning "
                         "error)")
    ap.add_argument("--exact", action="store_true",
                    help="require bit-identical compared quantities "
                         "(equivalent to --max-rel 0)")
    args = ap.parse_args()
    if args.exact:
        args.max_rel = 0.0
        args.max_rel_tail = 0.0
    if args.max_rel_tail is None:
        args.max_rel_tail = args.max_rel

    rows_a, rows_b = load_rows(args.a), load_rows(args.b)
    by_label = {label(r): r for r in rows_b}
    if [label(r) for r in rows_a] != list(by_label):
        sys.exit(f"artifact_diff: FAIL: latency axes differ: "
                 f"{[label(r) for r in rows_a]} vs {list(by_label)}")

    d, d_tail = Diff(), Diff()
    out: list[str] = []
    for ra in rows_a:
        rb = by_label[label(ra)]
        if ra["n_threads"] != rb["n_threads"]:
            print(f"artifact_diff: FAIL: {label(ra)}: winning thread "
                  f"counts differ ({ra['n_threads']} vs "
                  f"{rb['n_threads']})", file=sys.stderr)
            sys.exit(2)
        r_thr = d.add(f"{label(ra)} throughput",
                      ra["throughput"], rb["throughput"])
        err_a = rel(ra["throughput"], ra["model_throughput"])
        err_b = rel(rb["throughput"], rb["model_throughput"])
        out.append(
            f"{label(ra)}: threads {ra['n_threads']}  "
            f"throughput {ra['throughput']:.1f}/{rb['throughput']:.1f} "
            f"({r_thr:+.2%})  model-err {err_a:.2%}/{err_b:.2%} "
            f"({d.add(f'{label(ra)} model error', err_a, err_b):+.2%})")
        diff_tails(f"{label(ra)} fleet tail", ra.get("tail"),
                   rb.get("tail"), d_tail, out)
        na, nb = ra.get("nodes") or [], rb.get("nodes") or []
        if len(na) != len(nb):
            sys.exit(f"artifact_diff: FAIL: {label(ra)}: node counts "
                     f"differ ({len(na)} vs {len(nb)})")
        for xa, xb in zip(na, nb):
            w = f"{label(ra)} node {xa['node']}"
            r_n = d.add(f"{w} throughput",
                        xa["throughput"], xb["throughput"])
            out.append(f"  {w}: throughput {xa['throughput']:.1f}/"
                       f"{xb['throughput']:.1f} ({r_n:+.2%})")
            diff_tails(f"{w} tail", xa.get("tail"), xb.get("tail"),
                       d_tail, out)

    for line in out:
        print(f"artifact_diff: {line}")
    print(f"artifact_diff: worst: {d.worst:.4%} ({d.worst_what}) over "
          f"{d.n} quantities; worst tail: {d_tail.worst:.4%} "
          f"({d_tail.worst_what}) over {d_tail.n}")
    failed = []
    if args.max_rel is not None and d.worst > args.max_rel:
        failed.append(f"{d.worst_what}: {d.worst:.4%} > "
                      f"{args.max_rel:.4%}")
    if args.max_rel_tail is not None and d_tail.worst > args.max_rel_tail:
        failed.append(f"{d_tail.worst_what}: {d_tail.worst:.4%} > "
                      f"{args.max_rel_tail:.4%}")
    if failed:
        sys.exit("artifact_diff: FAIL: " + "; ".join(failed))
    print("artifact_diff: OK")


if __name__ == "__main__":
    main()
