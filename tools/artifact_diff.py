#!/usr/bin/env python
"""Compare two RunArtifact JSONs row by row, with a threshold exit code.

A :class:`~repro.core.experiment.RunArtifact` is the serialized result of
one scenario run (sweep table + model predictions + tail summaries).  Two
artifacts of the *same scenario* should agree: across backends within the
documented tolerance, across machines exactly (the simulator is
deterministic in virtual time), across code changes within whatever bound
the change claims.  This tool makes that check scriptable::

    python tools/artifact_diff.py A.json B.json
        Report per-row relative differences (throughput, model error,
        tail percentiles); exit 0.

    python tools/artifact_diff.py A.json B.json --max-rel 0.01
        Additionally exit 1 if any compared quantity differs by more
        than 1% relative (--max-rel-tail overrides the bound for tail
        percentiles, which carry binning error on the jax backend).

    python tools/artifact_diff.py A.json B.json --exact
        Exit 1 unless every compared row quantity is bit-identical
        (loop-backend determinism checks).

Rows are aligned by their latency label; artifacts whose latency axes or
winning thread counts disagree exit 2 (structural mismatch -- thread
counts are part of the operating point, not a tolerance question).
Cluster artifacts additionally compare per-node throughput and tails.

Suite documents (``benchmarks.run --suite``, schema
``repro.scenario_suite/v1``) are compared suite-wise: both files must
cover the same scenario names, and each scenario's rows are diffed
against its namesake with the same thresholds -- the one worst-relative
verdict spans the whole suite.  Stdlib-only, like the other ``tools/``
checkers.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

TAIL_FIELDS = ("p50_us", "p90_us", "p99_us")
SUITE_SCHEMA = "repro.scenario_suite/v1"


def load_doc(path: str) -> dict:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"artifact_diff: FAIL: {path}: unreadable or not JSON "
                 f"({e})")
    if not isinstance(doc, dict):
        sys.exit(f"artifact_diff: FAIL: {path}: not a JSON object")
    return doc


def is_suite(doc: dict) -> bool:
    return doc.get("schema") == SUITE_SCHEMA or "artifacts" in doc


def rows_of(doc: dict, path: str) -> list[dict]:
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        sys.exit(f"artifact_diff: FAIL: {path}: not a RunArtifact "
                 "(missing/empty rows)")
    return rows


def load_rows(path: str) -> list[dict]:
    return rows_of(load_doc(path), path)


def label(row: dict) -> str:
    l_us = row["L_us"]
    if isinstance(l_us, list):
        return "Lmix" + "|".join(f"{lat:g}@{p:g}" for lat, p in l_us) + "us"
    return f"L{l_us:g}us"


def rel(a: float, b: float) -> float:
    # A non-finite quantity is an infinite difference unless both sides
    # carry the identical value -- NaN must never satisfy a threshold by
    # making every comparison false.
    if not (math.isfinite(a) and math.isfinite(b)):
        return 0.0 if a == b else math.inf
    ref = max(abs(a), abs(b))
    return abs(a - b) / ref if ref else 0.0


class Diff:
    """Accumulates compared quantities and the worst relative error."""

    def __init__(self) -> None:
        self.worst = 0.0
        self.worst_what = "nothing compared"
        self.n = 0

    def add(self, what: str, a: float, b: float) -> float:
        r = rel(a, b)
        self.n += 1
        if self.n == 1 or r > self.worst:
            self.worst, self.worst_what = r, what
        return r


def diff_tails(what: str, ta: dict | None, tb: dict | None,
               d: Diff, out: list[str]) -> None:
    if ta is None or tb is None:
        if (ta is None) != (tb is None):
            out.append(f"  {what}: tail only in one artifact (skipped)")
        return
    parts = []
    for f in TAIL_FIELDS:
        va, vb = ta.get(f), tb.get(f)
        if va is None or vb is None:
            continue
        r = d.add(f"{what} {f}", va, vb)
        parts.append(f"{f} {va:g}/{vb:g} ({r:+.2%})")
    if parts:
        out.append(f"  {what}: " + "  ".join(parts))


def diff_rows(rows_a: list[dict], rows_b: list[dict], d: Diff,
              d_tail: Diff, out: list[str], where: str = "") -> None:
    """Diff one aligned pair of row tables into the shared accumulators.

    ``where`` prefixes every message (the scenario name in suite mode).
    Structural mismatches exit immediately: diverging latency axes and
    node counts exit 1, diverging winning thread counts exit 2.
    """
    by_label = {label(r): r for r in rows_b}
    if [label(r) for r in rows_a] != list(by_label):
        sys.exit(f"artifact_diff: FAIL: {where}latency axes differ: "
                 f"{[label(r) for r in rows_a]} vs {list(by_label)}")

    for ra in rows_a:
        rb = by_label[label(ra)]
        if ra["n_threads"] != rb["n_threads"]:
            print(f"artifact_diff: FAIL: {where}{label(ra)}: winning "
                  f"thread counts differ ({ra['n_threads']} vs "
                  f"{rb['n_threads']})", file=sys.stderr)
            sys.exit(2)
        r_thr = d.add(f"{where}{label(ra)} throughput",
                      ra["throughput"], rb["throughput"])
        err_a = rel(ra["throughput"], ra["model_throughput"])
        err_b = rel(rb["throughput"], rb["model_throughput"])
        out.append(
            f"{where}{label(ra)}: threads {ra['n_threads']}  "
            f"throughput {ra['throughput']:.1f}/{rb['throughput']:.1f} "
            f"({r_thr:+.2%})  model-err {err_a:.2%}/{err_b:.2%} "
            f"({d.add(f'{where}{label(ra)} model error', err_a, err_b):+.2%})")
        diff_tails(f"{where}{label(ra)} fleet tail", ra.get("tail"),
                   rb.get("tail"), d_tail, out)
        na, nb = ra.get("nodes") or [], rb.get("nodes") or []
        if len(na) != len(nb):
            sys.exit(f"artifact_diff: FAIL: {where}{label(ra)}: node "
                     f"counts differ ({len(na)} vs {len(nb)})")
        for xa, xb in zip(na, nb):
            w = f"{where}{label(ra)} node {xa['node']}"
            r_n = d.add(f"{w} throughput",
                        xa["throughput"], xb["throughput"])
            out.append(f"  {w}: throughput {xa['throughput']:.1f}/"
                       f"{xb['throughput']:.1f} ({r_n:+.2%})")
            diff_tails(f"{w} tail", xa.get("tail"), xb.get("tail"),
                       d_tail, out)


def suite_row_tables(doc_a: dict, doc_b: dict, path_a: str,
                     path_b: str) -> list[tuple[str, list, list]]:
    """Align two suite documents scenario-by-scenario."""
    arts_a, arts_b = doc_a.get("artifacts"), doc_b.get("artifacts")
    for path, arts in ((path_a, arts_a), (path_b, arts_b)):
        if not isinstance(arts, dict) or not arts:
            sys.exit(f"artifact_diff: FAIL: {path}: not a scenario suite "
                     "(missing/empty artifacts)")
    if sorted(arts_a) != sorted(arts_b):
        only_a = sorted(set(arts_a) - set(arts_b))
        only_b = sorted(set(arts_b) - set(arts_a))
        sys.exit(f"artifact_diff: FAIL: suite scenario sets differ "
                 f"(only in {path_a}: {only_a}; only in {path_b}: "
                 f"{only_b})")
    return [
        (name,
         rows_of(arts_a[name], f"{path_a}[{name}]"),
         rows_of(arts_b[name], f"{path_b}[{name}]"))
        for name in sorted(arts_a)
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("a", metavar="A.json")
    ap.add_argument("b", metavar="B.json")
    ap.add_argument("--max-rel", type=float, default=None, metavar="FRAC",
                    help="exit 1 if any compared quantity differs by more "
                         "than FRAC relative (default: report only)")
    ap.add_argument("--max-rel-tail", type=float, default=None,
                    metavar="FRAC",
                    help="separate bound for tail percentiles (default: "
                         "--max-rel; jax-backend tails carry ~2% binning "
                         "error)")
    ap.add_argument("--exact", action="store_true",
                    help="require bit-identical compared quantities "
                         "(equivalent to --max-rel 0)")
    args = ap.parse_args()
    if args.exact:
        args.max_rel = 0.0
        args.max_rel_tail = 0.0
    if args.max_rel_tail is None:
        args.max_rel_tail = args.max_rel

    doc_a, doc_b = load_doc(args.a), load_doc(args.b)
    if is_suite(doc_a) != is_suite(doc_b):
        kind = lambda d: "suite" if is_suite(d) else "artifact"  # noqa: E731
        sys.exit(f"artifact_diff: FAIL: cannot compare a {kind(doc_a)} "
                 f"against a {kind(doc_b)}")

    d, d_tail = Diff(), Diff()
    out: list[str] = []
    if is_suite(doc_a):
        for name, rows_a, rows_b in suite_row_tables(
                doc_a, doc_b, args.a, args.b):
            diff_rows(rows_a, rows_b, d, d_tail, out, where=f"{name} ")
    else:
        diff_rows(rows_of(doc_a, args.a), rows_of(doc_b, args.b),
                  d, d_tail, out)

    for line in out:
        print(f"artifact_diff: {line}")
    print(f"artifact_diff: worst: {d.worst:.4%} ({d.worst_what}) over "
          f"{d.n} quantities; worst tail: {d_tail.worst:.4%} "
          f"({d_tail.worst_what}) over {d_tail.n}")
    failed = []
    if args.max_rel is not None and d.worst > args.max_rel:
        failed.append(f"{d.worst_what}: {d.worst:.4%} > "
                      f"{args.max_rel:.4%}")
    if args.max_rel_tail is not None and d_tail.worst > args.max_rel_tail:
        failed.append(f"{d_tail.worst_what}: {d_tail.worst:.4%} > "
                      f"{args.max_rel_tail:.4%}")
    if failed:
        sys.exit("artifact_diff: FAIL: " + "; ".join(failed))
    print("artifact_diff: OK")


if __name__ == "__main__":
    main()
