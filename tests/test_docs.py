"""Docs stay runnable: the same checks the CI ``docs`` job runs via
``tools/check_docs.py`` -- every python code block in README.md and
docs/*.md executes, and every internal link resolves."""
import importlib.util
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

spec = importlib.util.spec_from_file_location(
    "check_docs", ROOT / "tools" / "check_docs.py")
check_docs = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_docs)

DOCS = check_docs.doc_files(ROOT)


def test_docs_exist():
    names = {p.name for p in DOCS}
    assert "README.md" in names
    assert "ENGINES.md" in names
    assert "ARCHITECTURE.md" in names


@pytest.mark.parametrize("path", DOCS, ids=[p.name for p in DOCS])
def test_links_resolve(path):
    assert check_docs.check_links(path) == []


@pytest.mark.slow
@pytest.mark.parametrize("path", DOCS, ids=[p.name for p in DOCS])
def test_code_blocks_run(path):
    assert check_docs.check_code_blocks(path) == []


def test_checker_catches_breakage(tmp_path):
    """The checker itself works: broken links and raising blocks are found."""
    docs = tmp_path / "docs"
    docs.mkdir()
    bad = docs / "BAD.md"
    bad.write_text(
        "see [missing](nope.md)\n\n```python\nraise RuntimeError('boom')\n```\n"
        "\n```python no-run\nraise RuntimeError('never runs')\n```\n"
    )
    assert len(check_docs.check_links(bad)) == 1
    problems = check_docs.check_code_blocks(bad)
    assert len(problems) == 1 and "boom" in problems[0]
