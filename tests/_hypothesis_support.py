"""Optional-hypothesis shim for the property-based tests.

The test suite uses ``hypothesis`` for a handful of property tests, but the
package is optional (see the ``test`` extra in ``pyproject.toml``).  Test
modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly:

  * hypothesis installed -- re-exports the real thing, tests run as usual;
  * hypothesis absent    -- ``@given(...)`` turns the test into a clean
    ``pytest.skip`` and ``st``/``settings`` degrade to inert placeholders,
    so the module still imports and every non-property test keeps running
    (a plain module-level ``pytest.importorskip`` would skip those too).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _InertStrategy:
        """Stand-in for ``hypothesis.strategies``: any attribute access or
        call yields another inert placeholder, so strategy expressions in
        decorators evaluate without the real package."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _InertStrategy()

    def settings(*args, **kwargs):  # noqa: D401 - decorator factory
        return lambda fn: fn

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed (pip install '.[test]')"
        )(fn)
