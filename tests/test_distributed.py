"""Distribution layer: sharding rules + real multi-device execution.

Multi-device tests run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps its single CPU device (the dry-run is the only place that
sets 512).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, smoke_config
from repro.distributed.sharding import act_rules, param_rules
from repro.models.layers import logical_to_pspec
from repro.zoo import get_api

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


class TestShardingRules:
    def test_param_specs_resolve(self):
        """Every arch's full-size ParamSpec tree resolves to valid specs."""
        class FakeMesh:
            axis_names = ("data", "model")

        rules = param_rules(FakeMesh())
        for arch, cfg in ARCHS.items():
            specs = get_api(cfg)
            tree = specs.param_specs(cfg)
            leaves = jax.tree.leaves(
                tree, is_leaf=lambda x: hasattr(x, "axes"))
            for s in leaves:
                spec = logical_to_pspec(s.axes, rules)
                assert isinstance(spec, P)
                assert len(spec) == len(s.shape)

    def test_fsdp_shards_weights_over_data(self):
        class FakeMesh:
            axis_names = ("pod", "data", "model")

        r = param_rules(FakeMesh())
        assert r["embed"] == ("pod", "data")
        assert r["mlp"] == "model"

    def test_act_rules_batch(self):
        class M1:
            axis_names = ("data", "model")

        class M2:
            axis_names = ("pod", "data", "model")

        assert act_rules(M1())["batch"] == "data"
        assert act_rules(M2())["batch"] == ("pod", "data")


@pytest.mark.slow
def test_train_step_executes_on_8_devices():
    """Actually run (not just lower) a sharded train step on a 4x2 mesh."""
    res = _run_subprocess("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS, smoke_config
        from repro.distributed.sharding import act_rules, state_shardings
        from repro.models.layers import init_params, mesh_context
        from repro.train.train_step import TrainHParams, init_train_state, make_train_step
        from repro.zoo import get_api
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = smoke_config(ARCHS["qwen2.5-3b"])
        api = get_api(cfg)
        hp = TrainHParams(total_steps=4, warmup=1, microbatches=2)
        step = make_train_step(api, cfg, hp)
        rules = act_rules(mesh)

        def fn(state, batch):
            with mesh_context(mesh, rules):
                return step(state, batch)

        specs = api.param_specs(cfg)
        p_shard = state_shardings(specs, mesh)
        state_shard = {"params": p_shard, "opt": {"m": p_shard, "v": p_shard,
                       "count": NamedSharding(mesh, P())}}
        params = init_params(specs, jax.random.PRNGKey(0))
        state = init_train_state(params, hp)
        state = jax.device_put(state, state_shard)
        t = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0, cfg.vocab)
        batch = {"tokens": t[:, :-1], "targets": t[:, 1:],
                 "loss_mask": jnp.ones((8, 32), jnp.float32)}
        bshard = jax.tree.map(
            lambda x: NamedSharding(mesh, P("data", *([None]*(x.ndim-1)))), batch)
        batch = jax.device_put(batch, bshard)
        jitted = jax.jit(fn, in_shardings=(state_shard, bshard),
                         out_shardings=(state_shard, None), donate_argnums=0)
        state, metrics = jitted(state, batch)
        state, metrics = jitted(state, batch)
        print(json.dumps({"loss": float(metrics["loss"]),
                          "devices": len(jax.devices())}))
    """)
    assert res["devices"] == 8
    assert res["loss"] == res["loss"]  # finite


@pytest.mark.slow
def test_elastic_checkpoint_across_mesh_sizes(tmp_path):
    """Save params on a (4,2) mesh, restore onto (2,2): elastic scaling."""
    res = _run_subprocess(f"""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, smoke_config
        from repro.distributed.sharding import state_shardings
        from repro.models.layers import init_params
        from repro.train.checkpoint import restore, save
        from repro.zoo import get_api

        cfg = smoke_config(ARCHS["starcoder2-3b"])
        api = get_api(cfg)
        specs = api.param_specs(cfg)
        big = jax.make_mesh((4, 2), ("data", "model"))
        params = jax.device_put(
            init_params(specs, jax.random.PRNGKey(0)),
            state_shardings(specs, big))
        save({str(tmp_path)!r}, 1, params)

        from jax.sharding import Mesh
        small = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                     ("data", "model"))
        like = jax.eval_shape(lambda: params)
        back = restore({str(tmp_path)!r}, 1, like,
                       shardings=state_shardings(specs, small))
        ok = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)))
        print(json.dumps({{"ok": ok}}))
    """)
    assert res["ok"]


@pytest.mark.slow
def test_crosspod_compressed_psum():
    """shard_map int8 psum over a 'pod' axis reproduces the mean gradient."""
    res = _run_subprocess("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.optim.grad_compress import ef_compress_grads, make_crosspod_psum

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        crosspod = make_crosspod_psum(mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P

        g_global = jnp.asarray(
            np.random.default_rng(0).normal(0, 1, (2, 64)), jnp.float32)

        def per_pod(g):
            q, s, e = ef_compress_grads({"g": g[0]}, {"g": jnp.zeros_like(g[0])})
            out = crosspod(q, s)
            return out["g"][None]

        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:  # pre-0.6 jax keeps it in experimental
            from jax.experimental.shard_map import shard_map
        f = shard_map(per_pod, mesh=mesh,
                      in_specs=P("pod", None), out_specs=P("pod", None))
        got = f(g_global)
        want = jnp.mean(g_global, axis=0)
        err = float(jnp.max(jnp.abs(got[0] - want)))
        scale = float(jnp.max(jnp.abs(want)))
        print(json.dumps({"rel": err / scale}))
    """)
    assert res["rel"] < 0.02
