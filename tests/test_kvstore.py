"""KV-store engines: semantics, traces, and model agreement (O4)."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st  # optional-hypothesis shim

from repro.core import workloads
from repro.core.kvstore import (
    EngineTimes,
    LSMStore,
    Recorder,
    TreeIndexStore,
    TwoTierCacheStore,
    run_trace,
)
from repro.core.latency_model import US, theta_mask_inv, theta_prob_inv
from repro.core.simulator import MEM, PREIO, SimConfig, simulate, trace_source

NK = 50_000
NOPS = 20_000


@pytest.fixture(scope="module")
def tree_trace():
    store = TreeIndexStore(NK, seed=1)
    wl = workloads.uniform(NK, NOPS, (1, 0), seed=2)
    return store, run_trace(store, wl)


class TestTreeIndexStore:
    def test_all_keys_found(self):
        store = TreeIndexStore(1000, seed=0)
        rec = Recorder(store.times)
        for k in range(0, 1000, 37):
            assert store._walk(k, rec)

    def test_absent_keys_not_found(self):
        store = TreeIndexStore(1000, seed=0)
        rec = Recorder(store.times)
        for k in range(1000, 1100):
            assert not store._walk(k, rec)

    def test_depth_is_logarithmic(self, tree_trace):
        _, tr = tree_trace
        # random BST expected depth ~1.39 log2(n/sprigs); n/sprig ~ 195
        expect = 1.39 * np.log2(NK / 256)
        assert 0.5 * expect < tr.mem_per_op - 1 < 1.8 * expect

    def test_one_io_per_read(self, tree_trace):
        _, tr = tree_trace
        assert tr.io_per_op == pytest.approx(1.0, abs=0.01)


class TestLSMStore:
    def test_zipf_hit_ratio(self):
        store = LSMStore(NK)
        wl = workloads.zipf(NK, NOPS, 0.99, seed=3)
        tr = run_trace(store, wl)
        assert 0.3 < tr.hit_stats["block_cache"] < 0.9
        # io per op == miss ratio (reads only)
        assert tr.io_per_op == pytest.approx(
            1 - store.hit_ratio, abs=0.1
        )

    def test_less_skew_more_io(self):
        t_hi = run_trace(LSMStore(NK), workloads.zipf(NK, NOPS, 0.99, seed=3))
        t_lo = run_trace(LSMStore(NK), workloads.zipf(NK, NOPS, 0.5, seed=3))
        assert t_lo.io_per_op > t_hi.io_per_op


class TestTwoTierCacheStore:
    def test_hit_stats(self):
        store = TwoTierCacheStore(NK, seed=4)
        wl = workloads.gaussian(NK, NOPS, 0.08, (2, 1), seed=5)
        tr = run_trace(store, wl)
        hs = tr.hit_stats
        assert 0.05 < hs["tier1"] < 0.95
        assert 0 <= hs["tier2"] <= 1
        assert tr.io_per_op > 0  # misses + eviction flushes reach the SSD

    def test_capacity_conservation(self):
        store = TwoTierCacheStore(2000, tier1_items=100, tier2_items=300, seed=0)
        wl = workloads.uniform(2000, 5000, (2, 1), seed=1)
        run_trace(store, wl, warmup_frac=0.0)
        assert len(store.t1) <= 100
        assert len(store.t2) <= 300


class TestModelAgreement:
    """O4: the Theta_prob model explains the engines' simulated throughput
    far better than masking-only, across the latency sweep."""

    @pytest.mark.parametrize("which", ["tree", "lsm", "cache"])
    def test_prob_closer_than_mask(self, which):
        if which == "tree":
            store = TreeIndexStore(NK, seed=1)
            wl = workloads.uniform(NK, NOPS, (1, 0), seed=2)
        elif which == "lsm":
            store = LSMStore(NK)
            wl = workloads.zipf(NK, NOPS, 0.99, seed=3)
        else:
            store = TwoTierCacheStore(NK, seed=4)
            wl = workloads.gaussian(NK, NOPS, 0.08, (2, 1), seed=5)
        tr = run_trace(store, wl)
        p = tr.op_params(store.times, P=12, T_sw=0.05 * US)
        src = trace_source(tr.ops)
        for l_us in (5.0, 8.0):
            best = max(
                simulate(SimConfig(L_mem=l_us * US, P=12, n_threads=n, seed=7),
                         src, 6000).throughput
                for n in (24, 40, 56)
            )
            L = np.array([l_us * US])
            prob = 1 / theta_prob_inv(L, p)[0]
            mask = 1 / theta_mask_inv(L, p)[0]
            err_prob = abs(best - prob) / prob
            err_mask = abs(best - mask) / mask
            assert err_prob < 0.25
            assert err_prob <= err_mask + 0.02


class TestRecorder:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 8), st.booleans()), min_size=1,
                    max_size=40))
    def test_counts_match_subops(self, plan):
        rec = Recorder(EngineTimes())
        for n_mem, io in plan:
            rec.mem(n_mem) if n_mem else rec.cpu(1e-7)
            if io:
                rec.io()
            rec.end_op()
        assert rec.n_ops == len(plan)
        n_mem = sum(1 for op in rec.ops for k, _ in op.subops if k == MEM)
        n_pre = sum(1 for op in rec.ops for k, _ in op.subops if k == PREIO)
        assert n_mem == rec.n_mem
        assert n_pre == rec.n_io
