"""Multi-SSD device models: per-device token-clock conservation, striping,
switch fan-out, and the no-op guarantee of the single-device defaults."""
import random

import pytest

from repro.core.sim import SimConfig, SSDClocks, microbenchmark_source, simulate

US = 1e-6


def _drain(cfg, n):
    """Submit n IOs at t=0 and return their completion times."""
    ssd = SSDClocks(cfg)
    rng = random.Random(0)
    return [ssd.submit(0.0, rng) for _ in range(n)]


class TestTokenClockConservation:
    def test_per_device_iops_spacing(self):
        """Each device's token clock enforces exactly 1/R_io spacing; with
        jitter off, completion times expose the service times directly."""
        cfg = SimConfig(R_io=100e3, L_io_jitter=0.0, n_ssd=2)
        comps = _drain(cfg, 20)
        for dev in (0, 1):
            svc = [c - cfg.L_io for c in comps[dev::2]]   # round-robin stripe
            for i, s in enumerate(svc):
                assert s == pytest.approx(i / 100e3)

    def test_aggregate_rate_scales_with_devices(self):
        """N devices admit exactly N IOs per token period: conservation --
        no tokens created or destroyed by the striping."""
        R = 100e3
        horizon = 1e-3                      # 1 ms => R*horizon tokens/device
        for n_ssd in (1, 2, 4):
            cfg = SimConfig(R_io=R, L_io_jitter=0.0, L_io=0.0, n_ssd=n_ssd)
            comps = _drain(cfg, 2000)
            admitted = sum(1 for c in comps if c <= horizon)
            # n_ssd * (R * horizon) tokens exist in [0, horizon]; the +n_ssd
            # allows the burst-of-one each fresh clock grants at t=0
            assert admitted == pytest.approx(n_ssd * R * horizon, abs=n_ssd)

    def test_bandwidth_clock_is_per_device(self):
        cfg = SimConfig(B_io=1e9, A_io=1e6, R_io=0.0, L_io_jitter=0.0,
                        L_io=0.0, n_ssd=2)
        comps = _drain(cfg, 8)
        for dev in (0, 1):
            svc = comps[dev::2]
            for i, s in enumerate(svc):
                assert s == pytest.approx(i * 1e6 / 1e9)

    def test_switch_hop_added_once_per_io(self):
        base = _drain(SimConfig(R_io=50e3, L_io_jitter=0.0, n_ssd=2), 10)
        hop = _drain(SimConfig(R_io=50e3, L_io_jitter=0.0, n_ssd=2,
                               L_switch=0.5 * US), 10)
        for b, h in zip(base, hop):
            assert h - b == pytest.approx(0.5 * US)

    def test_invalid_device_count_rejected(self):
        with pytest.raises(ValueError, match="n_ssd"):
            SSDClocks(SimConfig(n_ssd=0))


class TestEndToEnd:
    def test_single_device_default_is_noop(self):
        """n_ssd=1, L_switch=0 must reproduce the pre-matrix arithmetic;
        this seeded result is a regression anchor for the refactor."""
        src = microbenchmark_source(10, 0.1 * US, 1.5 * US, 0.2 * US)
        a = simulate(SimConfig(L_mem=2 * US, n_threads=32, R_io=75e3, seed=3),
                     src, 2000)
        b = simulate(SimConfig(L_mem=2 * US, n_threads=32, R_io=75e3, seed=3,
                               n_ssd=1, L_switch=0.0), src, 2000)
        assert a.throughput == b.throughput

    def test_iops_bound_throughput_scales(self):
        """An IOPS-bound workload doubles with the device count (until some
        other limit binds), the paper's multi-SSD scaling argument."""
        src = microbenchmark_source(10, 0.1 * US, 1.5 * US, 0.2 * US)
        thr = {}
        for n_ssd in (1, 2):
            r = simulate(SimConfig(L_mem=1 * US, n_threads=64, R_io=40e3,
                                   n_ssd=n_ssd, seed=3), src, 3000)
            thr[n_ssd] = r.throughput
            assert r.throughput <= 40e3 * n_ssd * 1.001   # never beats the cap
        assert thr[2] / thr[1] == pytest.approx(2.0, rel=0.02)

    def test_switch_hop_costs_little_with_io_masking(self):
        """The fan-out hop lands on parked (IO-waiting) threads, so a 0.5 us
        switch costs well under its face value in throughput."""
        src = microbenchmark_source(10, 0.1 * US, 1.5 * US, 0.2 * US)
        base = simulate(SimConfig(L_mem=1 * US, n_threads=48, n_ssd=2,
                                  seed=3), src, 3000)
        hop = simulate(SimConfig(L_mem=1 * US, n_threads=48, n_ssd=2,
                                 L_switch=0.5 * US, seed=3), src, 3000)
        assert hop.throughput > 0.97 * base.throughput
