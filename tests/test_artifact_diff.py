"""``tools/artifact_diff.py`` -- two RunArtifact JSONs, a threshold, an
exit code.

Contract: identical artifacts pass ``--exact`` (exit 0); a relative
difference above ``--max-rel`` (or ``--max-rel-tail`` for percentiles)
exits 1; structural mismatches -- diverging latency axes, node counts, or
winning thread counts -- are never a tolerance question (thread counts
exit 2, the rest exit 1 with a FAIL message).  The tool is stdlib-only,
so the test drives its real ``main()`` through ``sys.argv``.
"""
import copy
import importlib.util
import json
import math
from pathlib import Path

import pytest

from _hypothesis_support import given, settings, st  # optional shim

ROOT = Path(__file__).resolve().parent.parent

spec = importlib.util.spec_from_file_location(
    "artifact_diff", ROOT / "tools" / "artifact_diff.py")
artifact_diff = importlib.util.module_from_spec(spec)
spec.loader.exec_module(artifact_diff)


def _row(L=2.0, thr=100_000.0, model=108_000.0, n_threads=8,
         tail=None, nodes=None):
    r = {"L_us": L, "throughput": thr, "model_throughput": model,
         "n_threads": n_threads}
    if tail is not None:
        r["tail"] = tail
    if nodes is not None:
        r["nodes"] = nodes
    return r


def _cluster_rows():
    tail = {"p50_us": 40.0, "p90_us": 90.0, "p99_us": 220.0}
    nodes = [
        {"node": 0, "throughput": 60_000.0,
         "tail": {"p50_us": 35.0, "p90_us": 80.0, "p99_us": 200.0}},
        {"node": 1, "throughput": 40_000.0,
         "tail": {"p50_us": 50.0, "p90_us": 110.0, "p99_us": 260.0}},
    ]
    return [_row(L=2.0, tail=tail, nodes=nodes),
            _row(L=5.0, thr=80_000.0, model=85_000.0, tail=tail,
                 nodes=nodes)]


@pytest.fixture
def write_pair(tmp_path):
    def _write(rows_a, rows_b):
        a, b = tmp_path / "a.json", tmp_path / "b.json"
        a.write_text(json.dumps({"rows": rows_a}))
        b.write_text(json.dumps({"rows": rows_b}))
        return str(a), str(b)
    return _write


def _run(monkeypatch, argv):
    monkeypatch.setattr("sys.argv", ["artifact_diff.py", *argv])
    try:
        artifact_diff.main()
    except SystemExit as e:
        if e.code in (None, 0):
            return 0
        return e.code if isinstance(e.code, int) else 1
    return 0


class TestExitCodes:
    def test_identical_artifacts_pass_exact(self, write_pair, monkeypatch):
        rows = _cluster_rows()
        a, b = write_pair(rows, copy.deepcopy(rows))
        assert _run(monkeypatch, [a, b, "--exact"]) == 0

    def test_report_only_never_fails_on_drift(self, write_pair,
                                              monkeypatch):
        rows_b = _cluster_rows()
        rows_b[0]["throughput"] *= 1.5
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b]) == 0            # no threshold

    def test_throughput_drift_breaches_max_rel(self, write_pair,
                                               monkeypatch):
        rows_b = _cluster_rows()
        # scale model with throughput so only the throughput axis drifts
        # (model *error* is itself a compared quantity)
        rows_b[0]["throughput"] *= 1.02
        rows_b[0]["model_throughput"] *= 1.02
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b, "--max-rel", "0.05"]) == 0
        assert _run(monkeypatch, [a, b, "--max-rel", "0.01"]) == 1

    def test_tail_bound_is_separate(self, write_pair, monkeypatch):
        rows_b = _cluster_rows()
        rows_b[0]["tail"] = dict(rows_b[0]["tail"], p99_us=240.0)  # ~9%
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b, "--max-rel", "0.01",
                                  "--max-rel-tail", "0.2"]) == 0
        assert _run(monkeypatch, [a, b, "--max-rel", "0.01"]) == 1

    def test_per_node_drift_is_compared(self, write_pair, monkeypatch):
        rows_b = _cluster_rows()
        rows_b[1]["nodes"][1]["throughput"] *= 1.1    # fleet fields equal
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b, "--max-rel", "0.05"]) == 1

    def test_thread_count_mismatch_exits_2(self, write_pair, monkeypatch):
        rows_b = _cluster_rows()
        rows_b[0]["n_threads"] = 16
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b]) == 2

    def test_latency_axis_mismatch_fails(self, write_pair, monkeypatch):
        rows_b = _cluster_rows()
        rows_b[1]["L_us"] = 8.0
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b]) == 1

    def test_node_count_mismatch_fails(self, write_pair, monkeypatch):
        rows_b = _cluster_rows()
        del rows_b[0]["nodes"][1]
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b]) == 1

    def test_unreadable_or_rowless_artifact_fails(self, tmp_path,
                                                  monkeypatch):
        good = tmp_path / "good.json"
        good.write_text(json.dumps({"rows": _cluster_rows()}))
        empty = tmp_path / "empty.json"
        empty.write_text(json.dumps({"rows": []}))
        assert _run(monkeypatch, [str(good), str(tmp_path / "nope")]) == 1
        assert _run(monkeypatch, [str(good), str(empty)]) == 1

    def test_nan_quantity_fails_exact(self, write_pair, monkeypatch):
        rows_b = _cluster_rows()
        rows_b[0]["tail"]["p99_us"] = math.nan
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b, "--exact"]) == 1
        assert _run(monkeypatch, [a, b]) == 0        # report-only

    def test_mixture_labels_align(self, write_pair, monkeypatch):
        row = _row(L=[[1.0, 0.7], [10.0, 0.3]])
        a, b = write_pair([row], [copy.deepcopy(row)])
        assert _run(monkeypatch, [a, b, "--exact"]) == 0


def _suite(rows_by_name, **extra):
    doc = {"schema": artifact_diff.SUITE_SCHEMA, "suite": "scenarios",
           "artifacts": {name: {"rows": rows}
                         for name, rows in rows_by_name.items()}}
    doc.update(extra)
    return doc


class TestSuiteMode:
    """Suite documents are compared scenario-by-scenario; one verdict."""

    def _write(self, tmp_path, doc_a, doc_b):
        a, b = tmp_path / "a.json", tmp_path / "b.json"
        a.write_text(json.dumps(doc_a))
        b.write_text(json.dumps(doc_b))
        return str(a), str(b)

    def test_identical_suites_pass_exact(self, tmp_path, monkeypatch):
        doc = _suite({"one": [_row()], "two": _cluster_rows()})
        a, b = self._write(tmp_path, doc, copy.deepcopy(doc))
        assert _run(monkeypatch, [a, b, "--exact"]) == 0

    def test_scenario_set_mismatch_fails(self, tmp_path, monkeypatch):
        doc_a = _suite({"one": [_row()], "two": [_row()]})
        doc_b = _suite({"one": [_row()], "three": [_row()]})
        a, b = self._write(tmp_path, doc_a, doc_b)
        assert _run(monkeypatch, [a, b]) == 1

    def test_drift_in_any_scenario_breaches_threshold(self, tmp_path,
                                                      monkeypatch):
        doc_a = _suite({"one": [_row()], "two": [_row()]})
        doc_b = copy.deepcopy(doc_a)
        doc_b["artifacts"]["two"]["rows"][0]["throughput"] *= 1.05
        doc_b["artifacts"]["two"]["rows"][0]["model_throughput"] *= 1.05
        a, b = self._write(tmp_path, doc_a, doc_b)
        assert _run(monkeypatch, [a, b, "--max-rel", "0.1"]) == 0
        assert _run(monkeypatch, [a, b, "--max-rel", "0.01"]) == 1

    def test_suite_vs_plain_artifact_fails(self, tmp_path, monkeypatch):
        doc_a = _suite({"one": [_row()]})
        a, b = self._write(tmp_path, doc_a, {"rows": [_row()]})
        assert _run(monkeypatch, [a, b]) == 1

    def test_thread_mismatch_inside_suite_exits_2(self, tmp_path,
                                                  monkeypatch):
        doc_a = _suite({"one": [_row()]})
        doc_b = copy.deepcopy(doc_a)
        doc_b["artifacts"]["one"]["rows"][0]["n_threads"] = 16
        a, b = self._write(tmp_path, doc_a, doc_b)
        assert _run(monkeypatch, [a, b]) == 2


# -- property-based fuzz: generated row tables -------------------------------
#
# @given forbids function-scoped fixtures (monkeypatch, tmp_path), so
# these tests manage sys.argv themselves and draw from the session-scoped
# tmp_path_factory.


def _run_argv(argv):
    import sys as _sys
    old = _sys.argv
    _sys.argv = ["artifact_diff.py", *argv]
    try:
        try:
            artifact_diff.main()
        except SystemExit as e:
            if e.code in (None, 0):
                return 0
            return e.code if isinstance(e.code, int) else 1
        return 0
    finally:
        _sys.argv = old


@st.composite
def _tables(draw):
    """A syntactically valid row table: unique latency axis, positive
    throughputs, ordered tails, optional per-node breakdown."""
    lats = draw(st.lists(
        st.sampled_from([0.5, 1.0, 2.0, 5.0, 8.0, 12.0]),
        unique=True, min_size=1, max_size=4))
    with_nodes = draw(st.booleans())
    rows = []
    for L in lats:
        thr = draw(st.floats(min_value=1e3, max_value=1e6,
                             allow_nan=False, allow_infinity=False))
        p50 = draw(st.floats(min_value=1.0, max_value=400.0,
                             allow_nan=False, allow_infinity=False))
        tail = {"p50_us": p50, "p90_us": p50 * 2.0, "p99_us": p50 * 5.0}
        row = _row(L=L, thr=thr, model=thr * 1.04, tail=tail)
        if with_nodes:
            row["nodes"] = [
                {"node": i, "throughput": thr / 2.0, "tail": dict(tail)}
                for i in range(2)]
        rows.append(row)
    return rows


class TestFuzzedTables:
    """Properties the differ must hold for on any well-formed table."""

    @given(rows=_tables())
    @settings(max_examples=25, deadline=None)
    def test_table_is_exact_equal_to_its_copy(self, rows,
                                              tmp_path_factory):
        tmp = tmp_path_factory.mktemp("fz")
        a, b = tmp / "a.json", tmp / "b.json"
        a.write_text(json.dumps({"rows": rows}))
        b.write_text(json.dumps({"rows": copy.deepcopy(rows)}))
        assert _run_argv([str(a), str(b), "--exact"]) == 0

    @given(rows=_tables(), pick=st.integers(min_value=0, max_value=99))
    @settings(max_examples=25, deadline=None)
    def test_row_misalignment_never_passes(self, rows, pick,
                                           tmp_path_factory):
        # Dropping or relabeling any row must be structural (exit 1),
        # never a silent pass -- rows are aligned by latency label.
        rows_b = copy.deepcopy(rows)
        idx = pick % len(rows_b)
        if pick % 2 == 0 and len(rows_b) > 1:
            del rows_b[idx]
        else:
            rows_b[idx]["L_us"] = 99.0
        tmp = tmp_path_factory.mktemp("fz")
        a, b = tmp / "a.json", tmp / "b.json"
        a.write_text(json.dumps({"rows": rows}))
        b.write_text(json.dumps({"rows": rows_b}))
        assert _run_argv([str(a), str(b)]) == 1

    @given(rows=_tables(), pick=st.integers(min_value=0, max_value=99))
    @settings(max_examples=25, deadline=None)
    def test_nan_tail_never_satisfies_exact(self, rows, pick,
                                            tmp_path_factory):
        # NaN makes every comparison false; rel() must map it to an
        # infinite difference, not let it slide under the threshold.
        rows_b = copy.deepcopy(rows)
        row = rows_b[pick % len(rows_b)]
        field = ("p50_us", "p90_us", "p99_us")[pick % 3]
        row["tail"][field] = math.nan
        tmp = tmp_path_factory.mktemp("fz")
        a, b = tmp / "a.json", tmp / "b.json"
        a.write_text(json.dumps({"rows": rows}))
        b.write_text(json.dumps({"rows": rows_b}))
        assert _run_argv([str(a), str(b), "--exact"]) == 1
        # ...but report-only mode still completes (exit 0, worst=inf).
        assert _run_argv([str(a), str(b)]) == 0

    @given(rows=_tables(), pick=st.integers(min_value=0, max_value=99))
    @settings(max_examples=25, deadline=None)
    def test_per_node_asymmetry_is_structural(self, rows, pick,
                                              tmp_path_factory):
        # A node present on one side only must exit 1 regardless of
        # thresholds -- node counts are part of the artifact's shape.
        rows_b = copy.deepcopy(rows)
        row = rows_b[pick % len(rows_b)]
        if "nodes" not in row:
            row["nodes"] = [{"node": 0, "throughput": 1.0}]
        else:
            del row["nodes"][0]
        tmp = tmp_path_factory.mktemp("fz")
        a, b = tmp / "a.json", tmp / "b.json"
        a.write_text(json.dumps({"rows": rows}))
        b.write_text(json.dumps({"rows": rows_b}))
        assert _run_argv([str(a), str(b)]) == 1
