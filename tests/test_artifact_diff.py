"""``tools/artifact_diff.py`` -- two RunArtifact JSONs, a threshold, an
exit code.

Contract: identical artifacts pass ``--exact`` (exit 0); a relative
difference above ``--max-rel`` (or ``--max-rel-tail`` for percentiles)
exits 1; structural mismatches -- diverging latency axes, node counts, or
winning thread counts -- are never a tolerance question (thread counts
exit 2, the rest exit 1 with a FAIL message).  The tool is stdlib-only,
so the test drives its real ``main()`` through ``sys.argv``.
"""
import copy
import importlib.util
import json
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

spec = importlib.util.spec_from_file_location(
    "artifact_diff", ROOT / "tools" / "artifact_diff.py")
artifact_diff = importlib.util.module_from_spec(spec)
spec.loader.exec_module(artifact_diff)


def _row(L=2.0, thr=100_000.0, model=108_000.0, n_threads=8,
         tail=None, nodes=None):
    r = {"L_us": L, "throughput": thr, "model_throughput": model,
         "n_threads": n_threads}
    if tail is not None:
        r["tail"] = tail
    if nodes is not None:
        r["nodes"] = nodes
    return r


def _cluster_rows():
    tail = {"p50_us": 40.0, "p90_us": 90.0, "p99_us": 220.0}
    nodes = [
        {"node": 0, "throughput": 60_000.0,
         "tail": {"p50_us": 35.0, "p90_us": 80.0, "p99_us": 200.0}},
        {"node": 1, "throughput": 40_000.0,
         "tail": {"p50_us": 50.0, "p90_us": 110.0, "p99_us": 260.0}},
    ]
    return [_row(L=2.0, tail=tail, nodes=nodes),
            _row(L=5.0, thr=80_000.0, model=85_000.0, tail=tail,
                 nodes=nodes)]


@pytest.fixture
def write_pair(tmp_path):
    def _write(rows_a, rows_b):
        a, b = tmp_path / "a.json", tmp_path / "b.json"
        a.write_text(json.dumps({"rows": rows_a}))
        b.write_text(json.dumps({"rows": rows_b}))
        return str(a), str(b)
    return _write


def _run(monkeypatch, argv):
    monkeypatch.setattr("sys.argv", ["artifact_diff.py", *argv])
    try:
        artifact_diff.main()
    except SystemExit as e:
        if e.code in (None, 0):
            return 0
        return e.code if isinstance(e.code, int) else 1
    return 0


class TestExitCodes:
    def test_identical_artifacts_pass_exact(self, write_pair, monkeypatch):
        rows = _cluster_rows()
        a, b = write_pair(rows, copy.deepcopy(rows))
        assert _run(monkeypatch, [a, b, "--exact"]) == 0

    def test_report_only_never_fails_on_drift(self, write_pair,
                                              monkeypatch):
        rows_b = _cluster_rows()
        rows_b[0]["throughput"] *= 1.5
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b]) == 0            # no threshold

    def test_throughput_drift_breaches_max_rel(self, write_pair,
                                               monkeypatch):
        rows_b = _cluster_rows()
        # scale model with throughput so only the throughput axis drifts
        # (model *error* is itself a compared quantity)
        rows_b[0]["throughput"] *= 1.02
        rows_b[0]["model_throughput"] *= 1.02
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b, "--max-rel", "0.05"]) == 0
        assert _run(monkeypatch, [a, b, "--max-rel", "0.01"]) == 1

    def test_tail_bound_is_separate(self, write_pair, monkeypatch):
        rows_b = _cluster_rows()
        rows_b[0]["tail"] = dict(rows_b[0]["tail"], p99_us=240.0)  # ~9%
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b, "--max-rel", "0.01",
                                  "--max-rel-tail", "0.2"]) == 0
        assert _run(monkeypatch, [a, b, "--max-rel", "0.01"]) == 1

    def test_per_node_drift_is_compared(self, write_pair, monkeypatch):
        rows_b = _cluster_rows()
        rows_b[1]["nodes"][1]["throughput"] *= 1.1    # fleet fields equal
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b, "--max-rel", "0.05"]) == 1

    def test_thread_count_mismatch_exits_2(self, write_pair, monkeypatch):
        rows_b = _cluster_rows()
        rows_b[0]["n_threads"] = 16
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b]) == 2

    def test_latency_axis_mismatch_fails(self, write_pair, monkeypatch):
        rows_b = _cluster_rows()
        rows_b[1]["L_us"] = 8.0
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b]) == 1

    def test_node_count_mismatch_fails(self, write_pair, monkeypatch):
        rows_b = _cluster_rows()
        del rows_b[0]["nodes"][1]
        a, b = write_pair(_cluster_rows(), rows_b)
        assert _run(monkeypatch, [a, b]) == 1

    def test_unreadable_or_rowless_artifact_fails(self, tmp_path,
                                                  monkeypatch):
        good = tmp_path / "good.json"
        good.write_text(json.dumps({"rows": _cluster_rows()}))
        empty = tmp_path / "empty.json"
        empty.write_text(json.dumps({"rows": []}))
        assert _run(monkeypatch, [str(good), str(tmp_path / "nope")]) == 1
        assert _run(monkeypatch, [str(good), str(empty)]) == 1

    def test_mixture_labels_align(self, write_pair, monkeypatch):
        row = _row(L=[[1.0, 0.7], [10.0, 0.3]])
        a, b = write_pair([row], [copy.deepcopy(row)])
        assert _run(monkeypatch, [a, b, "--exact"]) == 0
