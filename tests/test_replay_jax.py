"""The vectorized jax sweep backend (``repro.core.sim.replay_jax``).

Guarantees, strongest first:

  1. Trace lowering is *lossless*: ``CompiledTrace`` -> device arrays ->
     decoded trace round-trips exactly, for every registered engine's
     default-pairing trace and for arbitrary (hypothesis-generated) op
     lists.
  2. The Pallas token-clock kernel (interpreter mode on CPU) is
     *bit-identical* to the pure-jnp path inside the grid.
  3. Per-cell throughput is *tolerance-equivalent* to the loop backends:
     the jax grid reproduces the loops' scheduling and device arithmetic
     but draws from a different RNG stream (threefry vs. Mersenne), so
     cells agree to sampling noise -- within 1% on the paper's default
     grid once cells are long enough to average the noise out
     (``n_ops=20_000``; at the default 5000 expect up to ~1.5%).  See
     docs/SIMULATION.md "When is each backend exact?".
"""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import workloads
from repro.core.conformance import P50_TOL, P99_TOL, jax_grid_tol
from repro.core.engines import LSMStore, available_engines, run_trace
from repro.core.experiment import (
    RunOptions,
    build_engine,
    default_scenario,
    run_scenario,
)
from repro.core.sim import SimConfig, simulate_compiled, sweep_latency
from repro.core.sim import replay_jax, sweep as sweep_mod
from repro.core.sim.replay_jax import TraceArrays, lower_trace, sweep_grid
from repro.core.trace_ir import CPU, MEM, POSTIO, PREIO, CompiledTrace, Op

from _hypothesis_support import given, settings, st  # optional-hypothesis shim

US = 1e-6

ENGINES = sorted({cls.engine_name for cls in available_engines().values()})


@pytest.fixture(scope="module")
def lsm_small():
    store = LSMStore(30_000)
    wl = workloads.zipf(30_000, 10_000, 0.99, (1, 0), seed=3)
    return run_trace(store, wl)


def _grid_vs_loop(cfg, trace, lats, cands, n_ops):
    """Max per-cell |rel. diff| of the jax grid vs. the compiled loop
    (bit-identical to the generic loop, per tests/test_sweep.py)."""
    grid = sweep_grid(cfg, trace, lats, cands, n_ops=n_ops)
    worst = 0.0
    for li, L in enumerate(lats):
        for ci, n in enumerate(cands):
            ref = simulate_compiled(
                dataclasses.replace(cfg, L_mem=L, n_threads=n), trace, n_ops)
            worst = max(worst, abs(grid.throughput[li, ci] - ref.throughput)
                        / ref.throughput)
    return worst, grid


# -- 1. lossless trace lowering ----------------------------------------------


class TestLowering:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_default_pairing_trace_round_trips(self, engine):
        store, wl = build_engine(engine, 20_000, 6_000)
        trace = run_trace(store, wl).trace
        back = lower_trace(trace).to_trace()
        assert np.array_equal(back.kinds, trace.kinds)
        assert np.array_equal(back.durs, trace.durs)       # float64, exact
        assert np.array_equal(back.bounds, trace.bounds)

    def test_padding_is_invisible(self, lsm_small):
        ta = lower_trace(lsm_small.trace, bucket=4096)
        assert len(ta.kinds) % 4096 == 0
        assert ta.n_subops == lsm_small.trace.n_subops
        assert ta.to_trace().counts() == lsm_small.trace.counts()

    def test_sweep_grid_accepts_prelowered_arrays(self, lsm_small):
        cfg = SimConfig(P=12, seed=7)
        ta = lower_trace(lsm_small.trace)
        a = sweep_grid(cfg, ta, [5 * US], [24], n_ops=1000)
        b = sweep_grid(cfg, lsm_small.trace, [5 * US], [24], n_ops=1000)
        assert a.throughput[0, 0] == b.throughput[0, 0]

    @settings(max_examples=25, deadline=None)
    @given(ops=st.lists(
        st.lists(
            st.tuples(st.sampled_from([MEM, PREIO, POSTIO, CPU]),
                      st.floats(0.0, 1e-5, allow_nan=False)),
            min_size=1, max_size=7),
        min_size=1, max_size=40))
    def test_round_trip_property(self, ops):
        trace = CompiledTrace.from_ops([Op(tuple(sub)) for sub in ops])
        back = TraceArrays.from_trace(trace, bucket=64).to_trace()
        assert np.array_equal(back.kinds, trace.kinds)
        assert np.array_equal(back.durs, trace.durs)
        assert np.array_equal(back.bounds, trace.bounds)


# -- 2. the Pallas kernel ----------------------------------------------------


class TestPallasTokenClock:
    def test_interpreter_kernel_matches_jnp_path_exactly(self, lsm_small):
        # Same draws, same arithmetic -> the whole grid result must be
        # numerically identical, not just close.  Tiny cell: interpreter
        # mode runs the kernel body per scheduler step.
        cfg = SimConfig(P=12, seed=7, n_ssd=2, R_io=250e3,
                        L_switch=0.3 * US)
        ref = sweep_grid(cfg, lsm_small.trace, [5 * US], [8], n_ops=150)
        pal = sweep_grid(cfg, lsm_small.trace, [5 * US], [8], n_ops=150,
                         use_pallas=True)
        assert np.array_equal(ref.throughput, pal.throughput)
        assert np.array_equal(ref.mem_stall_total, pal.mem_stall_total)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_fused_step_bit_identical_per_engine(self, engine):
        # Every registered engine produces a different suboperation mix
        # (MEM chains, PREIO bursts, lock sections); the fused whole-step
        # kernel must replay each of them bit-for-bit like the jnp scan,
        # not just within tolerance.
        sc = default_scenario(engine, n_keys=2_000, n_wl_ops=600)
        store = available_engines()[engine](sc.n_keys, **sc.engine_kwargs)
        wname, wkw = sc.resolved_workload()
        wl = workloads.create_workload(wname, sc.n_keys, sc.n_wl_ops, **wkw)
        trace = run_trace(store, wl).trace
        cfg = sc.sim_config()
        ref = sweep_grid(cfg, trace, [1 * US, 5 * US], [4, 8], n_ops=120)
        pal = sweep_grid(cfg, trace, [1 * US, 5 * US], [4, 8], n_ops=120,
                         use_pallas=True, substeps=4)
        for fld in ("throughput", "time", "mem_stall_total",
                    "mem_accesses"):
            assert np.array_equal(getattr(ref, fld), getattr(pal, fld)), fld

    def test_no_jitter_deterministic_exact_match(self, lsm_small):
        # Every stochastic device feature off -> zero uniforms consumed
        # per step (the n_u=0 edge of the kernel's uniform-feed contract);
        # the replay is then a deterministic function of the trace, and
        # both paths must agree exactly with themselves across calls and
        # with each other.
        cfg = SimConfig(P=12, seed=7, L_io_jitter=0.0)
        assert cfg.eps == 0.0 and cfg.rho == 1.0
        ref = sweep_grid(cfg, lsm_small.trace, [1 * US, 8 * US], [8, 16],
                         n_ops=200)
        again = sweep_grid(cfg, lsm_small.trace, [1 * US, 8 * US], [8, 16],
                           n_ops=200)
        pal = sweep_grid(cfg, lsm_small.trace, [1 * US, 8 * US], [8, 16],
                         n_ops=200, use_pallas=True)
        assert np.array_equal(ref.throughput, again.throughput)
        assert np.array_equal(ref.throughput, pal.throughput)
        assert np.array_equal(ref.time, pal.time)
        assert np.array_equal(ref.mem_stall_total, pal.mem_stall_total)
        assert np.array_equal(ref.mem_accesses, pal.mem_accesses)

    def test_kernel_unit_grant_semantics(self):
        from repro.kernels.token_clock import (
            token_clock_update,
            token_clock_update_ref,
        )

        submit = np.array([10.0, 20.0, 30.0])
        devmask = np.array([[True, False], [False, True], [False, False]])
        tok = np.array([[12.0, 0.0], [0.0, 19.0], [99.0, 99.0]])
        bw = np.zeros((3, 2))
        for fn in (token_clock_update_ref, token_clock_update):
            svc, tok2, bw2 = fn(jax.numpy.asarray(submit),
                                jax.numpy.asarray(devmask),
                                jax.numpy.asarray(tok),
                                jax.numpy.asarray(bw), 0.5, 0.0)
            svc, tok2, bw2 = map(np.asarray, (svc, tok2, bw2))
            assert svc[0] == 12.0 and tok2[0, 0] == 12.5   # gated by clock
            assert svc[1] == 20.0 and tok2[1, 1] == 20.5   # clock behind
            assert svc[2] == 30.0                          # masked row:
            assert np.all(tok2[2] == 99.0)                 # clocks untouched
            assert np.all(bw2 == 0.0)                      # disabled limit


# -- 3. tolerance equivalence against the loop backends ----------------------


class TestGridEquivalence:
    def test_small_grid_close_to_loop(self, lsm_small):
        cfg = SimConfig(P=12, seed=7)
        worst, _ = _grid_vs_loop(cfg, lsm_small.trace,
                                 [1 * US, 5 * US], [24, 48], n_ops=5000)
        assert worst < jax_grid_tol(5000), f"{worst:.2%}"

    FEATURES = [
        dict(eps=0.05),
        dict(rho=0.9),
        dict(T_lock=0.1 * US),
        dict(A_mem=64, B_mem=64 / (0.5 * US)),
        dict(R_io=250e3),
        dict(n_ssd=2, R_io=250e3, B_io=400e6, L_switch=0.3 * US),
    ]

    @pytest.mark.parametrize("kw", FEATURES,
                             ids=[",".join(k) for k in FEATURES])
    def test_device_features_close_to_loop(self, lsm_small, kw):
        cfg = SimConfig(P=12, seed=7, **kw)
        # non-default device features add small systematic offsets on top
        # of the contract's sampling-noise scaling, hence the 1.25x slack
        worst, _ = _grid_vs_loop(cfg, lsm_small.trace,
                                 [1 * US, 5 * US], [24, 48], n_ops=5000)
        assert worst < jax_grid_tol(5000, slack=1.25), f"{kw}: {worst:.2%}"

    @pytest.mark.slow
    @pytest.mark.parametrize("engine", ENGINES)
    def test_paper_default_grid_within_1pct_per_engine(self, engine):
        """The acceptance criterion: every cell of the paper's default
        latency x threads grid within 1% of the loop backend, for every
        registered engine, with the default matrix device config.  Cells
        run n_ops=20_000 so RNG-stream sampling noise (~0.5% at the
        default 5000) averages below the bound; the grid axes are the
        scenario defaults."""
        sc = default_scenario(engine, n_keys=30_000, n_wl_ops=9_000)
        store = available_engines()[engine](sc.n_keys, **sc.engine_kwargs)
        wname, wkw = sc.resolved_workload()
        wl = workloads.create_workload(wname, sc.n_keys, sc.n_wl_ops, **wkw)
        trace = run_trace(store, wl).trace
        cfg = sc.sim_config()
        worst, _ = _grid_vs_loop(
            cfg, trace, [l * US for l in sc.latencies_us],
            list(sc.thread_candidates), n_ops=20_000)
        assert worst < jax_grid_tol(20_000), \
            f"{engine}: worst cell {worst:.2%}"

    def test_cell_results_independent_of_grid_composition(self, lsm_small):
        """Cache purity: a cell's numbers are a function of its own
        identity (config, latency, thread count, trace, n_ops) -- never of
        which other cells happen to share the batched call.  This is what
        lets the cell cache serve jax cells across differently-shaped
        sweeps (a partially-cached sweep re-runs only the missing cells in
        a smaller grid)."""
        cfg = SimConfig(P=12, seed=7)
        alone = sweep_grid(cfg, lsm_small.trace, [5 * US], [8], n_ops=400)
        batched = sweep_grid(cfg, lsm_small.trace, [0.1 * US, 5 * US],
                             [8, 16], n_ops=400)
        assert alone.throughput[0, 0] == batched.throughput[1, 0]
        assert alone.mem_stall_total[0, 0] == batched.mem_stall_total[1, 0]

    def test_partially_cached_sweep_matches_cold_sweep(self, lsm_small,
                                                       tmp_path):
        cfg = SimConfig(P=12, seed=7)
        lats = [1 * US, 5 * US]
        cold = sweep_latency(cfg, lsm_small, lats, (8, 16), n_ops=400,
                             backend="jax")
        # warm the cache with only the first latency, then sweep both:
        # the second latency's cells run in a smaller grid than cold's
        sweep_latency(cfg, lsm_small, lats[:1], (8, 16), n_ops=400,
                      backend="jax", cache_dir=tmp_path)
        mixed = sweep_latency(cfg, lsm_small, lats, (8, 16), n_ops=400,
                              backend="jax", cache_dir=tmp_path)
        for a, b in zip(cold, mixed):
            assert a.result.throughput == b.result.throughput

    def test_mem_counters_track_loop(self, lsm_small):
        cfg = SimConfig(P=12, seed=7)
        grid = sweep_grid(cfg, lsm_small.trace, [5 * US], [24], n_ops=5000)
        ref = simulate_compiled(
            dataclasses.replace(cfg, L_mem=5 * US, n_threads=24),
            lsm_small.trace, 5000)
        assert grid.ops == ref.ops == 5000
        assert abs(grid.mem_accesses[0, 0] - ref.mem_accesses) \
            / ref.mem_accesses < 0.01
        assert abs(grid.mem_stall_total[0, 0] - ref.mem_stall_total) \
            / ref.mem_stall_total < 0.05

    MULTICORE = [
        dict(n_cores=2),
        dict(n_cores=4),
        dict(n_cores=2, T_lock=0.1 * US),
        dict(n_cores=4, T_lock=0.05 * US),
    ]

    @pytest.mark.parametrize(
        "kw", MULTICORE,
        ids=[f"c{d['n_cores']}" + ("+lock" if "T_lock" in d else "")
             for d in MULTICORE])
    def test_multicore_grid_close_to_loop(self, lsm_small, kw):
        """n_cores > 1 runs natively in the grid (no loop fallback): the
        per-core run queues, the shared parked heap's global drain
        horizon, and the lock serialization point all tolerance-track the
        compiled loop (which is bit-identical to the generic loop)."""
        cfg = SimConfig(P=12, seed=7, **kw)
        worst, _ = _grid_vs_loop(cfg, lsm_small.trace,
                                 [1 * US, 5 * US], [8, 16], n_ops=6000)
        assert worst < jax_grid_tol(6000, slack=1.1), f"{kw}: {worst:.2%}"

    def test_multicore_matches_pallas_path(self, lsm_small):
        cfg = SimConfig(P=12, seed=7, n_cores=2)
        ref = sweep_grid(cfg, lsm_small.trace, [1 * US, 5 * US], [4, 8],
                         n_ops=300)
        pal = sweep_grid(cfg, lsm_small.trace, [1 * US, 5 * US], [4, 8],
                         n_ops=300, use_pallas=True, substeps=4)
        for fld in ("throughput", "time", "mem_stall_total",
                    "mem_accesses"):
            assert np.array_equal(getattr(ref, fld), getattr(pal, fld)), fld


# -- 3b. cohorts, early exit, host sharding ----------------------------------


def _het_grids(trace, cfg, n_ops=300, **kw):
    """The same heterogeneous cells through the cohort early-exit layout
    and the monolithic single-scan layout (PR 6's shape: every cell padded
    to T_max, scanned to the one global bound)."""
    lats = [0.5 * US, 5 * US]
    cands = [4, 8, 24]            # three pow2 buckets, uneven warmups
    coh = sweep_grid(cfg, trace, lats, cands, n_ops=n_ops, **kw)
    mono = sweep_grid(cfg, trace, lats, cands, n_ops=n_ops,
                      bucket_threads=False, early_exit=False, **kw)
    return coh, mono


class TestCohortEarlyExit:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_cohorts_bit_identical_to_monolithic_per_engine(self, engine):
        """Cell purity is the whole contract: regrouping cells into
        cohorts and cutting the scan short at the all-done point may not
        change a single bit of any cell, for any engine's suboperation
        mix."""
        sc = default_scenario(engine, n_keys=2_000, n_wl_ops=600)
        store = available_engines()[engine](sc.n_keys, **sc.engine_kwargs)
        wname, wkw = sc.resolved_workload()
        wl = workloads.create_workload(wname, sc.n_keys, sc.n_wl_ops, **wkw)
        trace = run_trace(store, wl).trace
        coh, mono = _het_grids(trace, sc.sim_config())
        for fld in ("throughput", "time", "mem_stall_total",
                    "mem_accesses"):
            assert np.array_equal(getattr(coh, fld),
                                  getattr(mono, fld)), (engine, fld)

    def test_cohorts_bit_identical_under_pallas(self, lsm_small):
        coh, mono = _het_grids(lsm_small.trace, SimConfig(P=12, seed=7),
                               use_pallas=True, substeps=4)
        assert np.array_equal(coh.throughput, mono.throughput)

    def test_cohorts_bit_identical_with_devices_and_cores(self, lsm_small):
        # skew from the device axis too: multi-SSD token clocks and a
        # multi-core thread split exercise the widest per-cell state
        cfg = SimConfig(P=12, seed=7, n_ssd=2, R_io=250e3,
                        L_switch=0.3 * US, n_cores=2)
        coh, mono = _het_grids(lsm_small.trace, cfg)
        assert np.array_equal(coh.throughput, mono.throughput)

    def test_early_exit_skips_steps_on_uneven_grids(self, lsm_small):
        """The perf claim in counter form: on a heterogeneous grid the
        executed steps stay strictly below the scheduled worst-case
        bound, and the monolithic layout schedules at least as much."""
        cfg = SimConfig(P=12, seed=7)
        coh, mono = _het_grids(lsm_small.trace, cfg, n_ops=500)
        assert 0 < coh.cell_steps_run < coh.cell_steps_bound
        assert mono.cell_steps_bound >= coh.cell_steps_bound
        # early_exit=False runs every scheduled step
        assert mono.cell_steps_run == mono.cell_steps_bound

    def test_host_devices_validation(self, lsm_small):
        with pytest.raises(ValueError, match="host_devices"):
            sweep_grid(SimConfig(), lsm_small.trace, [1 * US], [8],
                       host_devices=0)
        with pytest.raises(ValueError, match="Pallas"):
            sweep_grid(SimConfig(), lsm_small.trace, [1 * US], [8],
                       host_devices=2, use_pallas=True)
        import jax as _jax
        avail = len(_jax.devices("cpu"))
        with pytest.raises(ValueError, match="host CPU"):
            sweep_grid(SimConfig(), lsm_small.trace, [1 * US], [8],
                       host_devices=avail + 1)

    @pytest.mark.slow
    def test_sharded_grid_bit_identical_in_subprocess(self, lsm_small):
        """host_devices=N shard_maps the cell axis over N XLA host CPU
        devices; per-cell purity makes the sharded grid bit-identical to
        the unsharded one.  The device count is fixed at jax init, so the
        comparison runs in a subprocess with XLA_FLAGS forcing 2 host
        devices."""
        import os
        import subprocess
        import sys
        import textwrap

        prog = textwrap.dedent("""
            import numpy as np
            from repro.core import workloads
            from repro.core.engines import LSMStore, run_trace
            from repro.core.sim import SimConfig
            from repro.core.sim.replay_jax import sweep_grid
            US = 1e-6
            tr = run_trace(LSMStore(4_000),
                           workloads.zipf(4_000, 1_500, 0.99, (1, 0),
                                          seed=3)).trace
            cfg = SimConfig(P=12, seed=7)
            lats, cands = [1 * US, 5 * US], [8, 16, 24]
            one = sweep_grid(cfg, tr, lats, cands, n_ops=300)
            two = sweep_grid(cfg, tr, lats, cands, n_ops=300,
                             host_devices=2)
            for fld in ("throughput", "time", "mem_stall_total",
                        "mem_accesses"):
                assert np.array_equal(getattr(one, fld),
                                      getattr(two, fld)), fld
            print("SHARDED_OK")
        """)
        env = dict(os.environ,
                   XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                              + " --xla_force_host_platform_device_count=2"
                              ).strip(),
                   PYTHONPATH=os.pathsep.join(
                       filter(None, [os.path.join(os.path.dirname(__file__),
                                                  os.pardir, "src"),
                              os.environ.get("PYTHONPATH", "")])))
        out = subprocess.run([sys.executable, "-c", prog], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "SHARDED_OK" in out.stdout


# -- 4. validation and API contracts -----------------------------------------


class TestValidation:
    def test_rejects_multicore_mixtures_and_empty(self, lsm_small):
        # Multi-core fits as long as n_cores * T_max fits the tag bits.
        with pytest.raises(ValueError, match="tag"):
            sweep_grid(SimConfig(n_cores=4), lsm_small.trace, [1 * US],
                       [128])
        with pytest.raises(ValueError, match="n_cores"):
            sweep_grid(SimConfig(n_cores=0), lsm_small.trace, [1 * US], [8])
        with pytest.raises(ValueError, match="scalar latencies"):
            sweep_grid(SimConfig(), lsm_small.trace,
                       [[(5 * US, 1.0)]], [8])
        with pytest.raises(ValueError, match="histograms"):
            sweep_grid(SimConfig(collect_load_hist=True),
                       lsm_small.trace, [1 * US], [8])
        with pytest.raises(ValueError, match="empty"):
            sweep_grid(SimConfig(), lsm_small.trace, [], [8])

    def test_sweep_latency_backend_validation(self, lsm_small):
        cfg = SimConfig(P=12, seed=7)
        with pytest.raises(ValueError, match="backend must be one of"):
            sweep_latency(cfg, lsm_small, [1 * US], (8,), backend="numpy")
        with pytest.raises(ValueError, match="adaptive"):
            sweep_latency(cfg, lsm_small, [1 * US], (8,), backend="jax",
                          adaptive=True)
        with pytest.raises(ValueError, match="collection"):
            sweep_latency(cfg, lsm_small, [1 * US], (8,), backend="jax",
                          collect_latency=True)
        with pytest.raises(ValueError, match="callable"):
            sweep_latency(cfg, lambda rng: None, [1 * US], (8,),
                          backend="jax")


# -- 5. sweep_latency / experiment integration -------------------------------


class TestSweepIntegration:
    def test_jax_backend_returns_equivalent_points(self, lsm_small):
        cfg = SimConfig(P=12, seed=7)
        lats = [1 * US, 5 * US]
        loop = sweep_latency(cfg, lsm_small, lats, (24, 48), n_ops=5000,
                             processes=1)
        jaxp = sweep_latency(cfg, lsm_small, lats, (24, 48), n_ops=5000,
                             backend="jax")
        for a, b in zip(loop, jaxp):
            for n, thr in a.per_thread.items():
                assert abs(b.per_thread[n] - thr) / thr < 0.02
            assert b.result.ops == a.result.ops

    def test_mixture_points_fall_back_to_loop_bit_identically(
            self, lsm_small):
        cfg = SimConfig(P=12, seed=7)
        mix = [(5 * US, 0.9), (14 * US, 0.1)]
        (la, lb) = sweep_latency(cfg, lsm_small, [mix, 1 * US], (24,),
                                 n_ops=5000, processes=1)
        (ja, jb) = sweep_latency(cfg, lsm_small, [mix, 1 * US], (24,),
                                 n_ops=5000, backend="jax")
        assert ja.result.throughput == la.result.throughput   # loop-run cell
        assert jb.result.throughput != lb.result.throughput   # jax-run cell
        assert abs(jb.result.throughput - lb.result.throughput) \
            / lb.result.throughput < 0.02

    def test_experiment_runs_with_jax_backend(self):
        sc = default_scenario("hash-index", n_keys=8_000, n_wl_ops=3_000,
                              latencies_us=(0.1, 5), n_ops=1500,
                              thread_candidates=(16, 24))
        art_loop = run_scenario(sc)
        art_jax = run_scenario(sc, RunOptions(backend="jax"))
        assert art_jax.scenario == art_loop.scenario     # spec unchanged
        assert art_jax.S == art_loop.S                   # same trace
        for rl, rj in zip(art_loop.rows, art_jax.rows):
            assert abs(rj.throughput - rl.throughput) / rl.throughput < 0.03


# -- 6. the salted, backend-keyed cell cache ---------------------------------


class TestSweepCellCache:
    def test_backends_never_share_cells(self, lsm_small, tmp_path):
        cfg = SimConfig(P=12, seed=7)
        sweep_latency(cfg, lsm_small, [1 * US], (24,), n_ops=1000,
                      processes=1, cache_dir=tmp_path)
        n_loop = len(list(tmp_path.glob("*.json")))
        jax1 = sweep_latency(cfg, lsm_small, [1 * US], (24,), n_ops=1000,
                             cache_dir=tmp_path, backend="jax")
        assert len(list(tmp_path.glob("*.json"))) == 2 * n_loop
        # and a second jax sweep is served from its own cells
        jax2 = sweep_latency(cfg, lsm_small, [1 * US], (24,), n_ops=1000,
                             cache_dir=tmp_path, backend="jax")
        assert jax2[0].result.throughput == jax1[0].result.throughput
        assert len(list(tmp_path.glob("*.json"))) == 2 * n_loop

    def test_code_salt_invalidates_cells(self, lsm_small, tmp_path,
                                         monkeypatch):
        """The ROADMAP regression: cells cached by an older revision of the
        simulator must not be served after the code changes."""
        cfg = SimConfig(P=12, seed=7)
        sweep_latency(cfg, lsm_small, [1 * US], (24,), n_ops=1000,
                      processes=1, cache_dir=tmp_path)
        before = len(list(tmp_path.glob("*.json")))
        monkeypatch.setattr(sweep_mod, "_CODE_SALT", "pretend-new-code")
        sweep_latency(cfg, lsm_small, [1 * US], (24,), n_ops=1000,
                      processes=1, cache_dir=tmp_path)
        after = len(list(tmp_path.glob("*.json")))
        assert after == 2 * before, "stale cells were served across code versions"

    def test_salt_is_derived_from_sources(self):
        salt = sweep_mod._code_salt()
        assert isinstance(salt, str) and len(salt) == 16
        assert salt == sweep_mod._code_salt()   # stable within a process

    def test_clear_sweep_cache(self, lsm_small, tmp_path):
        from repro.core.sim import clear_sweep_cache

        cfg = SimConfig(P=12, seed=7)
        sweep_latency(cfg, lsm_small, [1 * US, 5 * US], (24,), n_ops=800,
                      processes=1, cache_dir=tmp_path)
        n = len(list(tmp_path.glob("*.json")))
        assert n == 2
        # non-cell files sharing the directory are not ours to delete
        spec = tmp_path / "spec.json"
        spec.write_text("{}")
        art = tmp_path / "deadbeef.json"   # json, but not a sha1 cell name
        art.write_text("{}")
        assert clear_sweep_cache(tmp_path) == n
        assert spec.exists() and art.exists()
        assert sorted(p.name for p in tmp_path.glob("*.json")) == [
            "deadbeef.json", "spec.json"]
        assert clear_sweep_cache(tmp_path) == 0
        assert clear_sweep_cache(tmp_path / "nonexistent") == 0

    def test_cli_sweep_cache_clear(self, tmp_path, capsys, monkeypatch):
        import benchmarks.run as run_mod

        stale = tmp_path / ("ab" * 20 + ".json")   # a cell-shaped name
        stale.write_text("{}")
        keep = tmp_path / "spec.json"
        keep.write_text("{}")
        monkeypatch.setattr("sys.argv", [
            "benchmarks.run", "--only", "no_such_bench",
            "--sweep-cache", str(tmp_path), "--sweep-cache-clear"])
        run_mod.main()
        assert not stale.exists()
        assert keep.exists()
        assert "cleared 1 cell(s)" in capsys.readouterr().err

    def test_cli_clear_without_cache_dir_exits(self, capsys, monkeypatch):
        import benchmarks.run as run_mod

        monkeypatch.setattr("sys.argv",
                            ["benchmarks.run", "--sweep-cache-clear"])
        with pytest.raises(SystemExit, match="requires --sweep-cache"):
            run_mod.main()


class TestSweepCachePrune:
    """LRU-by-mtime eviction (``prune_sweep_cache``): cache hits refresh a
    cell's mtime, pruning removes the least-recently-used cells first."""

    @staticmethod
    def _cell(tmp_path, tag: str, size: int, age_s: float):
        """A cell-shaped file of ``size`` bytes last used ``age_s`` ago."""
        import hashlib
        import os
        import time

        name = hashlib.sha1(tag.encode()).hexdigest() + ".json"
        p = tmp_path / name
        p.write_text("x" * size)
        t = time.time() - age_s
        os.utime(p, (t, t))
        return p

    def test_prune_by_size_evicts_oldest_first(self, tmp_path):
        from repro.core.sim import prune_sweep_cache

        old = self._cell(tmp_path, "old", 100, age_s=300)
        mid = self._cell(tmp_path, "mid", 100, age_s=200)
        new = self._cell(tmp_path, "new", 100, age_s=100)
        assert prune_sweep_cache(tmp_path, max_bytes=150) == 2
        assert not old.exists() and not mid.exists()
        assert new.exists()

    def test_prune_by_age(self, tmp_path):
        from repro.core.sim import prune_sweep_cache

        stale = self._cell(tmp_path, "stale", 10, age_s=10 * 86400)
        fresh = self._cell(tmp_path, "fresh", 10, age_s=1 * 86400)
        assert prune_sweep_cache(tmp_path, max_age_days=5) == 1
        assert not stale.exists() and fresh.exists()

    def test_prune_leaves_fitting_caches_alone(self, tmp_path):
        from repro.core.sim import prune_sweep_cache

        kept = self._cell(tmp_path, "kept", 50, age_s=500)
        foreign = tmp_path / "spec.json"    # not a cell: never touched
        foreign.write_text("x" * 10_000)
        assert prune_sweep_cache(tmp_path, max_bytes=100,
                                 max_age_days=30) == 0
        assert kept.exists() and foreign.exists()
        assert prune_sweep_cache(tmp_path / "nonexistent",
                                 max_bytes=0) == 0

    def test_prune_validates_args(self, tmp_path):
        from repro.core.sim import prune_sweep_cache

        with pytest.raises(ValueError, match="max_bytes"):
            prune_sweep_cache(tmp_path, max_bytes=-1)
        with pytest.raises(ValueError, match="max_age_days"):
            prune_sweep_cache(tmp_path, max_age_days=-0.5)

    def test_cache_hit_refreshes_mtime(self, lsm_small, tmp_path):
        """A served cell is recently-used: ``_cache_load`` bumps its mtime
        so a later prune evicts cold cells before hot ones."""
        import os
        import time

        cfg = SimConfig(P=12, seed=7)
        sweep_latency(cfg, lsm_small, [1 * US], (24,), n_ops=600,
                      processes=1, cache_dir=tmp_path)
        (cell,) = tmp_path.glob("*.json")
        past = time.time() - 9 * 86400
        os.utime(cell, (past, past))
        sweep_latency(cfg, lsm_small, [1 * US], (24,), n_ops=600,
                      processes=1, cache_dir=tmp_path)   # pure cache hit
        assert os.path.getmtime(cell) > past + 86400

    def test_cli_sweep_cache_prune(self, tmp_path, capsys, monkeypatch):
        import benchmarks.run as run_mod

        self._cell(tmp_path, "a", 100, age_s=300)
        survivor = self._cell(tmp_path, "b", 100, age_s=100)
        monkeypatch.setattr("sys.argv", [
            "benchmarks.run", "--only", "no_such_bench",
            "--sweep-cache", str(tmp_path),
            "--sweep-cache-prune", "0.0001"])    # 100-byte budget
        run_mod.main()
        assert "pruned 1 cell(s)" in capsys.readouterr().err
        assert list(tmp_path.glob("*.json")) == [survivor]

    def test_cli_prune_without_cache_dir_exits(self, monkeypatch):
        import benchmarks.run as run_mod

        monkeypatch.setattr("sys.argv", [
            "benchmarks.run", "--sweep-cache-prune-days", "7"])
        with pytest.raises(SystemExit, match="requires --sweep-cache"):
            run_mod.main()


# -- 8. open-loop arrivals + tail percentiles --------------------------------
#
# The other half of the cross-backend tail matrix (the generic-vs-compiled
# bit-identity half lives in tests/test_arrivals.py).  The jax grid shares
# the loops' arrival array but draws service latencies from a different
# RNG stream and reports quantiles as log-histogram bin midpoints, so the
# contract is tolerance equivalence: HIST_REL_ERROR (< 1.9%) of binning
# error plus cross-stream sampling noise.  Measured worst cases at
# n_ops=400 on these configs: P50 within 3.4%, P99 within 6.2%; the
# asserted bounds (P50_TOL/P99_TOL, 8% / 12%, imported from the contract
# table in repro.core.conformance) carry margin over that.

from repro.core.sim import (  # noqa: E402
    HIST_REL_ERROR,
    ArrivalSpec,
    generate_arrivals,
)

ARR_SPECS = {
    "poisson": ArrivalSpec(kind="poisson", rate=150e3, seed=5),
    "bursty": ArrivalSpec(kind="bursty", rate=150e3, seed=5,
                          on_fraction=0.3, period=0.002),
}


def _arrival_array(spec, cfg, cands, n_ops):
    need = max(3 * cfg.n_cores * c for c in cands) + n_ops + 1
    return generate_arrivals(spec, need)


@pytest.fixture(scope="module")
def hash_small():
    store = available_engines()["hash-index"](4_000)
    wl = workloads.zipf(4_000, 1_500, 0.99, (1, 0), seed=3)
    return run_trace(store, wl)


class TestOpenLoopGrid:
    LATS = [1 * US, 5 * US]
    CANDS = [8, 16]
    N_OPS = 400

    def _grid(self, cfg, trace, arr, **kw):
        return sweep_grid(cfg, trace, self.LATS, self.CANDS,
                          n_ops=self.N_OPS, arrivals=arr,
                          collect_percentiles=True, **kw)

    @pytest.mark.parametrize("mode", sorted(ARR_SPECS))
    def test_grid_tail_close_to_compiled_loop(self, hash_small, mode):
        cfg = SimConfig(P=12, seed=7)
        arr = _arrival_array(ARR_SPECS[mode], cfg, self.CANDS, self.N_OPS)
        grid = self._grid(cfg, hash_small.trace, arr)
        for li, L in enumerate(self.LATS):
            for ci, n in enumerate(self.CANDS):
                ref = simulate_compiled(
                    dataclasses.replace(cfg, L_mem=L, n_threads=n),
                    hash_small.trace, self.N_OPS, arrivals=arr,
                    collect_percentiles=True)
                g = grid.result(li, ci)
                assert g.throughput == pytest.approx(
                    ref.throughput, rel=0.02)
                gs, rs = g.latency_summary, ref.latency_summary
                assert gs.source == "hist" and rs.source == "exact"
                assert gs.count == rs.count == self.N_OPS
                assert gs.p50 == pytest.approx(rs.p50, rel=P50_TOL)
                assert gs.p99 == pytest.approx(rs.p99, rel=P99_TOL)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_per_engine_poisson_tail(self, engine):
        # Load-normalized (60% of the engine's own capacity) so every
        # engine sits at the same utilization regardless of service time.
        store, wl = build_engine(engine, 4_000, 1_200)
        tr = run_trace(store, wl)
        cfg = SimConfig(P=12, seed=7)
        cell = dataclasses.replace(cfg, L_mem=3 * US, n_threads=16)
        cap = simulate_compiled(cell, tr.trace, self.N_OPS).throughput
        spec = ArrivalSpec(rate=0.6 * cap, seed=5)
        arr = _arrival_array(spec, cfg, [16], self.N_OPS)
        grid = sweep_grid(cfg, tr.trace, [3 * US], [16], n_ops=self.N_OPS,
                          arrivals=arr, collect_percentiles=True)
        ref = simulate_compiled(cell, tr.trace, self.N_OPS, arrivals=arr,
                                collect_percentiles=True)
        gs, rs = grid.result(0, 0).latency_summary, ref.latency_summary
        assert gs.p90 == pytest.approx(rs.p90, rel=P99_TOL)
        assert gs.p99 == pytest.approx(rs.p99, rel=P99_TOL)
        # Nearest-rank P50 is only comparable when the median is not on
        # a distributional cliff: two-tier-cache splits sojourns into a
        # DRAM-hit mode and a miss mode with ~half the mass each, so the
        # two backends' medians can legally land on opposite sides of
        # the gap (P90/P99 agree to ~3% there).  Gate on the spread.
        if rs.p90 < 1.5 * rs.p50:
            assert gs.p50 == pytest.approx(rs.p50, rel=P50_TOL)

    def test_pallas_open_loop_bit_identical(self, hash_small):
        cfg = SimConfig(P=12, seed=7)
        spec = dataclasses.replace(ARR_SPECS["bursty"], deadline=300e-6)
        arr = _arrival_array(spec, cfg, self.CANDS, self.N_OPS)
        ref = self._grid(cfg, hash_small.trace, arr,
                         deadline=spec.deadline)
        pal = self._grid(cfg, hash_small.trace, arr,
                         deadline=spec.deadline, use_pallas=True)
        for f in ("throughput", "p50", "p90", "p99", "lat_max",
                  "lat_count", "missed"):
            assert np.array_equal(getattr(ref, f), getattr(pal, f),
                                  equal_nan=True), f

    def test_closed_loop_percentiles_leave_throughput_identical(
            self, hash_small):
        cfg = SimConfig(P=12, seed=7)
        plain = sweep_grid(cfg, hash_small.trace, self.LATS, self.CANDS,
                           n_ops=self.N_OPS)
        with_p = sweep_grid(cfg, hash_small.trace, self.LATS, self.CANDS,
                            n_ops=self.N_OPS, collect_percentiles=True)
        assert np.array_equal(plain.throughput, with_p.throughput)
        s = with_p.result(0, 0).latency_summary
        assert s is not None and s.count == self.N_OPS
        assert plain.result(0, 0).latency_summary is None

    def test_deadline_misses_on_grid(self, hash_small):
        cfg = SimConfig(P=12, seed=7)
        spec = ArrivalSpec(kind="poisson", rate=400e3, seed=5,
                           deadline=150e-6)
        arr = _arrival_array(spec, cfg, [16], self.N_OPS)
        grid = sweep_grid(cfg, hash_small.trace, [5 * US], [16],
                          n_ops=self.N_OPS, arrivals=arr,
                          collect_percentiles=True, deadline=spec.deadline)
        r = grid.result(0, 0)
        s = r.latency_summary
        assert r.missed_ops == s.missed > 0
        assert s.count + s.missed == self.N_OPS
        if s.count:
            # reported quantiles are bin midpoints: a survivor's bin can
            # straddle the deadline, so allow one half-bin of overshoot
            assert s.p99 <= spec.deadline * (1 + 2 * HIST_REL_ERROR)

    def test_sweep_latency_jax_arrival_matches_loop(self, hash_small,
                                                    tmp_path):
        cfg = SimConfig(P=12, seed=7)
        spec = ARR_SPECS["poisson"]
        kw = dict(n_ops=self.N_OPS, arrival=spec,
                  collect_percentiles=True)
        loop = sweep_latency(cfg, hash_small, self.LATS, self.CANDS,
                             processes=1, **kw)
        jaxp = sweep_latency(cfg, hash_small, self.LATS, self.CANDS,
                             backend="jax", **kw)
        for a, b in zip(loop, jaxp):
            sa, sb = a.result.latency_summary, b.result.latency_summary
            assert sa.source == "exact" and sb.source == "hist"
            assert sb.p50 == pytest.approx(sa.p50, rel=P50_TOL)
            assert sb.p99 == pytest.approx(sa.p99, rel=P99_TOL)
        # and the jax cells cache + round-trip their summaries
        cached = sweep_latency(cfg, hash_small, self.LATS, self.CANDS,
                               backend="jax", cache_dir=str(tmp_path),
                               **kw)
        warm = sweep_latency(cfg, hash_small, self.LATS, self.CANDS,
                             backend="jax", cache_dir=str(tmp_path), **kw)
        for a, b in zip(cached, warm):
            assert a.throughput == b.throughput
            assert (a.result.latency_summary.to_dict()
                    == b.result.latency_summary.to_dict())

    def test_grid_arrival_validation(self, hash_small):
        cfg = SimConfig(P=12, seed=7)
        with pytest.raises(ValueError, match="arrivals"):
            sweep_grid(cfg, hash_small.trace, [1 * US], [8], n_ops=400,
                       arrivals=np.zeros(3))
        with pytest.raises(ValueError, match="deadline"):
            sweep_grid(cfg, hash_small.trace, [1 * US], [8], n_ops=400,
                       deadline=-1.0)
