"""``repro.core.conformance`` -- the differential fuzzer and its
contract table.

Three layers, cheapest first:

1. The contract table and tolerance scaling laws are pure data/math --
   checked exhaustively (the table is what ``tests/test_replay_jax.py``
   and ``tests/test_cluster.py`` import their bounds from, so its
   internal consistency is itself a contract).
2. Scenario sampling and shrinking are deterministic plumbing -- checked
   with a stubbed ``check_scenario`` so no simulation runs.
3. The checked-in corpus under ``examples/conformance/`` must parse,
   stay within the sampler's size budget, and (slow) replay green
   through the real differential checks -- the same gate CI's nightly
   fuzz job enforces.
"""
import json
import random
from pathlib import Path

import pytest

from repro.core import conformance
from repro.core.conformance import (
    CHECK_NAMES,
    CONTRACTS,
    ConformanceFailure,
    jax_grid_tol,
    sample_scenario,
    scenario_for_seed,
    shrink_scenario,
    tail_tol,
    write_repro,
    replay_corpus,
)
from repro.core.experiment import Scenario

ROOT = Path(__file__).resolve().parent.parent
CORPUS = ROOT / "examples" / "conformance"


# -- 1. contract table + tolerance scaling -----------------------------------


class TestContractTable:
    def test_keys_match_contract_names(self):
        for key, c in CONTRACTS.items():
            assert key == c.name

    def test_bit_identical_contracts_carry_no_tolerances(self):
        for c in CONTRACTS.values():
            if c.bit_identical:
                assert c.throughput_tol is None
                assert c.p50_tol is None and c.p99_tol is None

    def test_tolerance_contracts_fully_specified(self):
        for c in CONTRACTS.values():
            if not c.bit_identical:
                assert c.throughput_tol and c.ref_ops
                assert c.p50_tol and c.p99_tol and c.tail_ref_ops
                assert c.p50_tol <= c.p99_tol   # medians are tighter

    def test_every_contract_documents_why(self):
        assert all(c.why for c in CONTRACTS.values())

    def test_all_backend_pairs_covered(self):
        # every distinct execution path pairs off against a reference
        flat = " ".join(part for c in CONTRACTS.values() for part in c.pair)
        for backend in ("simulate", "simulate_compiled", "sweep_grid",
                        "use_pallas", "sweep_cluster"):
            assert backend in flat

    def test_jax_grid_tol_is_base_at_and_above_ref(self):
        c = CONTRACTS["jax-vs-loop"]
        assert jax_grid_tol(c.ref_ops) == pytest.approx(c.throughput_tol)
        assert jax_grid_tol(10 * c.ref_ops) == pytest.approx(
            c.throughput_tol)

    def test_tolerance_scales_as_inverse_sqrt_below_ref(self):
        # quartering the sample doubles the allowed noise
        assert jax_grid_tol(5_000) == pytest.approx(2 * jax_grid_tol(20_000))
        assert tail_tol(100, base=0.12) == pytest.approx(
            2 * tail_tol(400, base=0.12))

    def test_slack_is_multiplicative(self):
        assert jax_grid_tol(5_000, slack=1.25) == pytest.approx(
            1.25 * jax_grid_tol(5_000))

    def test_existing_test_literals_map_onto_the_law(self):
        # the historical per-test bounds are points on one curve
        assert jax_grid_tol(5_000) == pytest.approx(0.02)
        assert jax_grid_tol(20_000) == pytest.approx(0.01)
        assert jax_grid_tol(5_000, slack=1.25) == pytest.approx(0.025)


# -- 2. sampling + shrinking (no simulation) ---------------------------------


class TestSampling:
    def test_seed_determinism(self):
        for seed in (0, 7, 41):
            assert scenario_for_seed(seed) == scenario_for_seed(seed)

    def test_seeds_explore_the_space(self):
        scs = [scenario_for_seed(s) for s in range(30)]
        assert len({sc.to_json() for sc in scs}) == 30
        assert len({sc.engine for sc in scs}) >= 5
        assert any(sc.cluster for sc in scs)
        assert any(sc.arrival for sc in scs)
        assert any(not sc.arrival and not sc.cluster for sc in scs)

    def test_samples_stay_within_the_size_budget(self):
        # the documented budget that keeps a differential pass cheap
        for seed in range(30):
            sc = scenario_for_seed(seed)
            assert sc.n_keys <= 3_000 and sc.n_wl_ops <= 1_000
            assert sc.n_ops <= 600
            assert len(sc.latencies_us) * len(sc.thread_candidates) <= 4
            if sc.cluster:
                assert sc.cluster["n_nodes"] <= 4

    def test_samples_round_trip_through_json(self):
        for seed in range(10):
            sc = scenario_for_seed(seed)
            assert Scenario.from_json(sc.to_json()) == sc

    def test_sample_scenario_consumes_rng(self):
        rng = random.Random(1)
        a = sample_scenario(rng, 0)
        b = sample_scenario(rng, 0)
        assert a != b                       # stream advances


class TestShrinker:
    @staticmethod
    def _fails_if(pred):
        def stub(sc, checks=CHECK_NAMES):
            if pred(sc):
                return [ConformanceFailure("jax", "stub", "fail", sc)]
            return []
        return stub

    def test_shrinks_to_minimal_failing_spec(self, monkeypatch):
        # failure depends only on n_ops >= 300: the shrinker must keep
        # halving while the failure persists and stop at the boundary
        monkeypatch.setattr(conformance, "check_scenario",
                            self._fails_if(lambda sc: sc.n_ops >= 300))
        sc = scenario_for_seed(2)
        assert sc.n_ops >= 300
        small, evals = shrink_scenario(sc)
        assert small.n_ops == 300
        assert 0 < evals <= 40
        assert small.name.endswith("-shrunk")
        # everything irrelevant to the failure was stripped
        assert not small.cluster and not small.arrival
        assert len(small.latencies_us) == 1
        assert len(small.thread_candidates) == 1

    def test_budget_bounds_evaluations(self, monkeypatch):
        monkeypatch.setattr(conformance, "check_scenario",
                            self._fails_if(lambda sc: True))
        _, evals = shrink_scenario(scenario_for_seed(2), budget=5)
        assert evals <= 5

    def test_unshrinkable_failure_keeps_the_spec(self, monkeypatch):
        # a failure that vanishes under ANY reduction cannot be shrunk
        full = scenario_for_seed(2)
        monkeypatch.setattr(conformance, "check_scenario",
                            self._fails_if(lambda sc: sc == full))
        small, _ = shrink_scenario(full)
        assert small == full


class TestReproEmission:
    def test_write_repro_round_trips(self, tmp_path):
        sc = scenario_for_seed(3)
        path = write_repro(sc, "jax", tmp_path)
        assert path.name == f"repro_jax_{sc.name}.json"
        assert Scenario.from_json(path.read_text()) == sc

    def test_replay_corpus_empty_dir_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            replay_corpus(tmp_path)

    def test_replay_corpus_runs_every_file(self, tmp_path, monkeypatch):
        seen = []
        monkeypatch.setattr(
            conformance, "check_scenario",
            lambda sc, checks=CHECK_NAMES: seen.append(sc.name) or [])
        for seed in (1, 2):
            write_repro(scenario_for_seed(seed), "jax", tmp_path)
        assert replay_corpus(tmp_path) == []
        assert len(seen) == 2


# -- 3. the checked-in corpus ------------------------------------------------


def _corpus_paths():
    return sorted(CORPUS.glob("*.json"))


class TestCorpus:
    def test_corpus_is_nonempty_and_parses(self):
        paths = _corpus_paths()
        assert len(paths) >= 8
        names = set()
        for path in paths:
            sc = Scenario.from_json(path.read_text())
            names.add(sc.name)
            # corpus specs obey the sampler's size budget: replay stays
            # cheap enough to run on every CI push
            assert sc.n_ops <= 600 and sc.n_keys <= 3_000
        assert len(names) == len(paths)

    def test_corpus_covers_the_fuzz_axes(self):
        scs = [Scenario.from_json(p.read_text()) for p in _corpus_paths()]
        kinds = {dict(sc.arrival).get("kind", "closed") if sc.arrival
                 else "closed" for sc in scs}
        assert {"closed", "poisson", "bursty", "diurnal"} <= kinds
        assert sum(1 for sc in scs if sc.cluster) >= 3
        assert len({sc.engine for sc in scs}) >= 6

    def test_cheapest_corpus_entry_replays_green(self):
        # tier-1 smoke: the smallest single-host spec through the full
        # differential pass (compiled + jax + pallas)
        scs = [(p, Scenario.from_json(p.read_text()))
               for p in _corpus_paths()]
        path, sc = min(
            ((p, s) for p, s in scs if not s.cluster),
            key=lambda ps: ps[1].n_ops * ps[1].n_wl_ops)
        fails = conformance.check_scenario(sc)
        assert not fails, f"{path.name}: {[str(f) for f in fails]}"

    @pytest.mark.slow
    def test_full_corpus_replays_green(self):
        fails = replay_corpus(CORPUS)
        assert not fails, [str(f) for f in fails]
