"""Open-loop arrival processes + the tail-latency accumulator.

Contract, strongest first:

  1. :func:`generate_arrivals` is a *pure function* of ``(spec, n)``:
     byte-identical regeneration, and prefix stability
     (``generate(spec, n)[:m] == generate(spec, m)``) -- the property
     that lets the sweep cell cache key on the spec instead of the data.
  2. The processes have their advertised statistics (property-tested):
     Poisson interarrival mean within CI bounds, bursty duty-cycle
     conservation (long-run mean rate == ``rate`` while the in-burst
     rate is ``rate / on_fraction``), diurnal strictly monotone.
  3. The generic and compiled loops produce *bit-identical* tail
     summaries under open-loop arrivals, for every registered engine x
     {closed, poisson, bursty} (the jax grid's tolerance half of the
     matrix lives in tests/test_replay_jax.py).
  4. Accumulator edge cases: empty cells (all missed), single-op cells,
     identical latencies, f32-vs-f64 histogram binning, and artifact
     JSON round-trips (old artifacts without ``tail`` still load).
  5. The sweep cell cache stores percentile summaries: a
     ``collect_percentiles`` sweep hits cells cached by a previous
     percentile sweep, upgrades-in-place cells cached without one, and
     never serves closed-loop cells to open-loop requests.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.core import workloads
from repro.core.engines import available_engines, run_trace
from repro.core.experiment import (
    RunArtifact,
    RunOptions,
    default_scenario,
    run_scenario,
)
from repro.core.sim import (
    HIST_REL_ERROR,
    ArrivalSpec,
    LatencySummary,
    SimConfig,
    generate_arrivals,
    simulate,
    simulate_compiled,
    summarize_exact,
    summarize_hist,
    sweep_latency,
    trace_source,
)
from repro.core.sim.arrivals import (
    HIST_BINS,
    HIST_LO,
    HIST_RATIO,
    hist_bin,
    hist_bin_value,
)
from repro.core.sim import sweep as sweep_mod

from _hypothesis_support import given, settings, st  # optional shim

US = 1e-6

ENGINES = sorted({cls.engine_name for cls in available_engines().values()})

SPECS = {
    "poisson": ArrivalSpec(kind="poisson", rate=150e3, seed=5),
    "bursty": ArrivalSpec(kind="bursty", rate=150e3, seed=5,
                          on_fraction=0.3, period=0.002),
    "diurnal": ArrivalSpec(kind="diurnal", rate=150e3, seed=5,
                           period=0.005, amplitude=0.7),
    "mix": ArrivalSpec(kind="mix", seed=5, tenants=(
        {"kind": "poisson", "rate": 90e3},
        {"kind": "bursty", "rate": 60e3, "on_fraction": 0.5,
         "period": 0.004},
    )),
}


@pytest.fixture(scope="module")
def small_trace():
    store = available_engines()["hash-index"](4_000)
    wl = workloads.zipf(4_000, 1_500, 0.99, (1, 0), seed=3)
    return run_trace(store, wl)


# -- 1. determinism ----------------------------------------------------------


class TestDeterminism:
    @pytest.mark.parametrize("kind", sorted(SPECS))
    def test_byte_identical_regeneration(self, kind):
        spec = SPECS[kind]
        a = generate_arrivals(spec, 3000)
        b = generate_arrivals(ArrivalSpec.from_dict(spec.to_dict()), 3000)
        assert a.tobytes() == b.tobytes()
        assert a.dtype == np.float64

    @pytest.mark.parametrize("kind", sorted(SPECS))
    def test_prefix_stability(self, kind):
        # Cells consume prefixes of one stream; a cell's result must not
        # depend on how long an array the sweep happened to generate.
        spec = SPECS[kind]
        long = generate_arrivals(spec, 4000)
        for m in (1, 7, 100, 3999):
            assert long[:m].tobytes() == generate_arrivals(spec, m).tobytes()

    @pytest.mark.parametrize("kind", sorted(SPECS))
    def test_monotone_nonnegative(self, kind):
        t = generate_arrivals(SPECS[kind], 3000)
        assert np.all(np.diff(t) >= 0.0) and t[0] >= 0.0

    def test_seed_changes_stream(self):
        a = generate_arrivals(ArrivalSpec(rate=1e5, seed=0), 500)
        b = generate_arrivals(ArrivalSpec(rate=1e5, seed=1), 500)
        assert not np.array_equal(a, b)

    def test_accepts_dict_and_rejects_unknown_fields(self):
        d = {"kind": "poisson", "rate": 1e5, "seed": 2}
        assert np.array_equal(generate_arrivals(d, 64),
                              generate_arrivals(ArrivalSpec.from_dict(d), 64))
        with pytest.raises(ValueError, match="unknown arrival spec"):
            ArrivalSpec.from_dict({"kind": "poisson", "rats": 1e5})

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="kind"):
            ArrivalSpec(kind="lumpy")
        with pytest.raises(ValueError, match="rate"):
            ArrivalSpec(rate=0.0)
        with pytest.raises(ValueError, match="on_fraction"):
            ArrivalSpec(kind="bursty", on_fraction=0.0)
        with pytest.raises(ValueError, match="deadline"):
            ArrivalSpec(deadline=-1e-3)
        with pytest.raises(ValueError, match="tenant"):
            ArrivalSpec(kind="mix")
        with pytest.raises(ValueError, match="nested mix"):
            ArrivalSpec(kind="mix", tenants=(
                {"kind": "mix", "tenants": ({"kind": "poisson"},)},))

    def test_mix_offered_rate_sums_tenants(self):
        assert SPECS["mix"].offered_rate == pytest.approx(150e3)

    def test_key_is_stable_json(self):
        spec = SPECS["bursty"]
        assert json.loads(spec.key()) == spec.to_dict()
        assert spec.key() == ArrivalSpec.from_dict(spec.to_dict()).key()


# -- 2. process statistics (property-tested) ---------------------------------


class TestProcessStatistics:
    @given(st.integers(0, 2**31 - 1),
           st.floats(1e3, 1e6, allow_nan=False))
    @settings(max_examples=25, deadline=None)
    def test_poisson_interarrival_mean_in_ci(self, seed, rate):
        n = 4000
        t = generate_arrivals(ArrivalSpec(rate=rate, seed=seed), n)
        gaps = np.diff(np.concatenate(([0.0], t)))
        # exponential(1/rate): sample mean has sd (1/rate)/sqrt(n);
        # 5 sigma keeps the property test deterministic-in-practice
        assert abs(gaps.mean() - 1.0 / rate) < 5.0 / (rate * math.sqrt(n))

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_bursty_duty_cycle_conservation(self, seed):
        # ~40 ON/OFF cycles: the long-run mean rate must come out at
        # ``rate`` even though in-burst arrivals run at rate/on_fraction.
        rate, frac, period, n = 200e3, 0.25, 0.001, 80_000
        spec = ArrivalSpec(kind="bursty", rate=rate, seed=seed,
                           on_fraction=frac, period=period)
        t = generate_arrivals(spec, n)
        achieved = n / t[-1]
        assert achieved == pytest.approx(rate, rel=0.25)
        # in-burst gaps concentrate near 1/(rate/frac) << the OFF gaps:
        # the median gap reflects the ON rate, not the mean rate
        med_gap = float(np.median(np.diff(t)))
        assert med_gap < 1.5 / (rate / frac)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_diurnal_monotone_and_rate_conserving(self, seed):
        spec = ArrivalSpec(kind="diurnal", rate=100e3, seed=seed,
                           period=0.01, amplitude=0.8)
        t = generate_arrivals(spec, 20_000)
        assert np.all(np.diff(t) > 0.0)        # thinning: strictly increasing
        # 20 full periods: the sinusoid integrates out
        assert 20_000 / t[-1] == pytest.approx(100e3, rel=0.15)

    def test_diurnal_rate_actually_swings(self):
        # Arrivals per half-period alternate high/low with the sinusoid.
        spec = ArrivalSpec(kind="diurnal", rate=100e3, seed=9,
                           period=0.01, amplitude=0.8)
        t = generate_arrivals(spec, 20_000)
        half = 0.005
        counts = np.bincount((t // half).astype(int))
        highs, lows = counts[0:-1:2], counts[1:-1:2]
        assert highs.mean() > 2.0 * lows.mean()


# -- 3. generic vs compiled loop: bit-identical summaries --------------------


def _arrival_array(spec, cfg, n_ops):
    total = cfg.n_cores * cfg.n_threads
    return generate_arrivals(spec, total + 2 * total + n_ops + 1)


def _summaries_identical(a, b):
    assert (a is None) == (b is None)
    if a is None:
        return
    da, db = a.to_dict(), b.to_dict()
    assert da.keys() == db.keys()
    for k in da:
        va, vb = da[k], db[k]
        if isinstance(va, float) and math.isnan(va):
            assert math.isnan(vb), k
        else:
            assert va == vb, k


MODES = [None, "poisson", "bursty"]


class TestLoopBitIdentity:
    @pytest.mark.parametrize("engine", ENGINES)
    @pytest.mark.parametrize("mode", MODES, ids=["closed", "poisson",
                                                 "bursty"])
    def test_generic_vs_compiled_summary(self, engine, mode):
        store = available_engines()[engine](4_000)
        wl = workloads.zipf(4_000, 1_200, 0.99, (1, 0), seed=3)
        tr = run_trace(store, wl)
        cfg = SimConfig(seed=7, n_threads=16, L_mem=3 * US)
        n_ops = 300
        kw = dict(collect_percentiles=True)
        if mode is not None:
            kw["arrivals"] = _arrival_array(SPECS[mode], cfg, n_ops)
        g = simulate(cfg, trace_source(tr.ops), n_ops, **kw)
        c = simulate_compiled(cfg, tr.trace, n_ops, **kw)
        assert g.throughput == c.throughput
        assert g.time == c.time
        assert g.missed_ops == c.missed_ops
        _summaries_identical(g.latency_summary, c.latency_summary)

    @pytest.mark.parametrize("mode", ["poisson", "bursty"])
    def test_multicore_fast_path_summary(self, small_trace, mode):
        cfg = SimConfig(seed=7, n_cores=2, n_threads=8, L_mem=2 * US)
        arr = _arrival_array(SPECS[mode], cfg, 400)
        g = simulate(cfg, trace_source(small_trace.ops), 400,
                     arrivals=arr, collect_percentiles=True)
        c = simulate_compiled(cfg, small_trace.trace, 400,
                              arrivals=arr, collect_percentiles=True)
        assert g.throughput == c.throughput
        _summaries_identical(g.latency_summary, c.latency_summary)

    def test_deadline_marks_misses(self, small_trace):
        cfg = SimConfig(seed=7, n_threads=16, L_mem=5 * US)
        spec = SPECS["poisson"]
        arr = _arrival_array(spec, cfg, 400)
        # deadline at the no-deadline run's P90: the same deterministic
        # replay must then miss ~10% of ops -- a guaranteed nontrivial
        # split between counted and missed
        probe = simulate_compiled(cfg, small_trace.trace, 400,
                                  arrivals=arr, collect_percentiles=True)
        deadline = probe.latency_summary.p90
        g = simulate(cfg, trace_source(small_trace.ops), 400, arrivals=arr,
                     collect_percentiles=True, deadline=deadline)
        c = simulate_compiled(cfg, small_trace.trace, 400, arrivals=arr,
                              collect_percentiles=True, deadline=deadline)
        assert g.missed_ops == c.missed_ops > 0
        s = g.latency_summary
        assert s.count + s.missed == 400
        assert s.count > 0 and 0.0 < s.miss_rate < 1.0
        # misses are excluded from the accumulator: whatever remains met
        # the SLA, so every reported percentile is under the deadline
        assert s.p99 <= deadline
        _summaries_identical(s, c.latency_summary)

    def test_open_loop_underload_matches_offered_rate(self, small_trace):
        # At half capacity the loop must *pace* (park on the arrival
        # clock), not free-run: achieved ~~ offered, well under capacity.
        cfg = SimConfig(seed=7, n_threads=16, L_mem=1 * US)
        closed = simulate_compiled(cfg, small_trace.trace, 800)
        spec = ArrivalSpec(rate=0.5 * closed.throughput, seed=1)
        r = simulate_compiled(cfg, small_trace.trace, 800,
                              arrivals=_arrival_array(spec, cfg, 800),
                              collect_percentiles=True)
        assert r.throughput <= spec.rate * 1.05
        assert r.throughput >= spec.rate * 0.8


# -- 4. accumulator edge cases -----------------------------------------------


class TestAccumulator:
    def test_empty_cell_all_missed(self):
        for s in (summarize_exact([], missed=7),
                  summarize_hist(np.zeros(HIST_BINS), 0.0, missed=7)):
            assert s.count == 0 and s.missed == 7
            assert s.miss_rate == 1.0
            assert all(math.isnan(v)
                       for v in (s.p50, s.p90, s.p99, s.max))

    def test_no_ops_at_all(self):
        s = summarize_exact([])
        assert s.miss_rate == 0.0 and s.count == 0

    def test_single_op_cell(self):
        s = summarize_exact([42e-6])
        assert (s.p50, s.p90, s.p99, s.max) == (42e-6,) * 4
        h = summarize_hist(
            np.bincount(hist_bin([42e-6]), minlength=HIST_BINS), 42e-6)
        assert h.count == 1 and h.max == 42e-6
        assert h.p50 == h.p99 == pytest.approx(42e-6, rel=HIST_REL_ERROR)

    def test_identical_latencies(self):
        vals = [3.7e-5] * 1000
        s = summarize_exact(vals)
        assert s.p50 == s.p99 == s.max == 3.7e-5
        h = summarize_hist(
            np.bincount(hist_bin(vals), minlength=HIST_BINS), 3.7e-5)
        assert h.p50 == h.p99 == pytest.approx(3.7e-5, rel=HIST_REL_ERROR)

    def test_nearest_rank_small_n(self):
        s = summarize_exact([1.0, 2.0, 3.0, 4.0])
        assert (s.p50, s.p90, s.p99, s.max) == (2.0, 4.0, 4.0, 4.0)

    def test_hist_bound_on_random_samples(self):
        rng = np.random.default_rng(0)
        vals = rng.lognormal(math.log(1e-4), 1.0, 5000)
        exact = summarize_exact(vals.tolist())
        h = summarize_hist(
            np.bincount(hist_bin(vals), minlength=HIST_BINS), vals.max())
        for q in ("p50", "p90", "p99"):
            assert getattr(h, q) == pytest.approx(
                getattr(exact, q), rel=HIST_REL_ERROR)
        assert h.max == exact.max   # max is tracked exactly

    def test_hist_clamps_out_of_range(self):
        b = hist_bin([0.0, HIST_LO / 10, 1e9])
        assert b[0] == b[1] == 0 and b[2] == HIST_BINS - 1

    def test_bin_midpoint_inverts_bin(self):
        bins = np.arange(HIST_BINS)
        assert np.array_equal(hist_bin(hist_bin_value(bins)), bins)

    def test_f32_vs_f64_binning(self):
        # The jax grid may run in f32 (enable_x64 off): binning a value
        # stored as f32 must land in the same bin as the f64 path for
        # values away from bin edges (geometric midpoints are the
        # farthest-from-edge representatives; f32 rounding is ~1e-7
        # relative, the bin is ~3.7e-2 wide in relative terms).
        mids = hist_bin_value(np.arange(HIST_BINS))
        assert np.array_equal(hist_bin(mids.astype(np.float32)),
                              hist_bin(mids))
        # and values *on* edges may legally differ by at most one bin
        edges = HIST_LO * HIST_RATIO ** np.arange(1, HIST_BINS)
        d = np.abs(hist_bin(edges.astype(np.float32)) - hist_bin(edges))
        assert d.max() <= 1

    def test_summary_json_round_trip(self):
        s = LatencySummary(10, 1e-5, 2e-5, 3e-5, 4e-5, missed=2,
                           source="hist")
        assert LatencySummary.from_dict(
            json.loads(json.dumps(s.to_dict()))) == s
        nan_s = summarize_exact([], missed=1)
        back = LatencySummary.from_dict(
            json.loads(json.dumps(nan_s.to_dict())))
        _summaries_identical(nan_s, back)


# -- artifact round-trips ----------------------------------------------------


class TestArtifactRoundTrip:
    @pytest.fixture(scope="class")
    def art(self):
        s = default_scenario("hash-index", n_keys=2_000, n_wl_ops=800,
                             n_ops=300, latencies_us=(0.5, 5.0),
                             thread_candidates=(8,),
                             arrival={"kind": "poisson", "rate": 2e5,
                                      "seed": 3})
        return run_scenario(s, RunOptions(collect_percentiles=True,
                                          cache_dir=None))

    def test_tail_fields_round_trip(self, art):
        assert all(r.tail is not None for r in art.rows)
        t = art.rows[0].tail
        assert t["offered_load"] == pytest.approx(2e5)
        assert t["source"] == "exact"
        assert t["p99_us"] >= t["p50_us"] > 0
        assert RunArtifact.from_json(art.to_json()) == art

    def test_old_artifacts_without_tail_still_load(self, art):
        doc = json.loads(art.to_json())
        for r in doc["rows"]:
            r.pop("tail", None)
        old = RunArtifact.from_json(json.dumps(doc))
        assert all(r.tail is None for r in old.rows)
        assert len(old.rows) == len(art.rows)

    def test_scenario_arrival_validated_eagerly(self):
        with pytest.raises(ValueError, match="rate"):
            default_scenario("hash-index",
                             arrival={"kind": "poisson", "rate": -1.0})

    def test_closed_loop_without_percentiles_has_no_tail(self):
        s = default_scenario("hash-index", n_keys=2_000, n_wl_ops=800,
                             n_ops=200, latencies_us=(2.0,),
                             thread_candidates=(8,))
        art = run_scenario(s, RunOptions(cache_dir=None))
        assert art.rows[0].tail is None


# -- 5. sweep cache: percentile summaries are cached -------------------------


class TestSweepCachePercentiles:
    LATS = (1 * US, 5 * US)
    CANDS = (8, 16)

    def _sweep(self, tr, tmp_path, **kw):
        cfg = SimConfig(P=12, seed=7)
        return sweep_latency(cfg, tr.trace, list(self.LATS), self.CANDS,
                             n_ops=300, processes=1,
                             cache_dir=str(tmp_path), **kw)

    def _count_runs(self, monkeypatch):
        calls = {"n": 0}
        real = sweep_mod._run_cell

        def counting(*a, **kw):
            calls["n"] += 1
            return real(*a, **kw)

        monkeypatch.setattr(sweep_mod, "_run_cell", counting)
        return calls

    def test_percentile_sweep_hits_its_own_cache(self, small_trace,
                                                 tmp_path, monkeypatch):
        spec = SPECS["poisson"]
        cold = self._sweep(small_trace, tmp_path, arrival=spec,
                           collect_percentiles=True)
        calls = self._count_runs(monkeypatch)
        warm = self._sweep(small_trace, tmp_path, arrival=spec,
                           collect_percentiles=True)
        assert calls["n"] == 0, "warm percentile sweep recomputed cells"
        for a, b in zip(cold, warm):
            assert a.throughput == b.throughput
            _summaries_identical(a.result.latency_summary,
                                 b.result.latency_summary)
            assert b.result.latency_summary.source == "exact"

    def test_summaryless_cells_upgrade_in_place(self, small_trace,
                                                tmp_path, monkeypatch):
        # Cells cached by a plain sweep lack the summary: a percentile
        # sweep must treat them as misses (recompute), after which the
        # upgraded cells satisfy both kinds of request.
        spec = SPECS["poisson"]
        self._sweep(small_trace, tmp_path, arrival=spec)
        calls = self._count_runs(monkeypatch)
        self._sweep(small_trace, tmp_path, arrival=spec,
                    collect_percentiles=True)
        n_cells = len(self.LATS) * len(self.CANDS)
        assert calls["n"] == n_cells, "summaryless cells must be misses"
        calls["n"] = 0
        self._sweep(small_trace, tmp_path, arrival=spec)   # plain request
        self._sweep(small_trace, tmp_path, arrival=spec,
                    collect_percentiles=True)
        assert calls["n"] == 0, "upgraded cells must serve both requests"

    def test_closed_and_open_cells_never_shared(self, small_trace,
                                                tmp_path, monkeypatch):
        self._sweep(small_trace, tmp_path, collect_percentiles=True)
        calls = self._count_runs(monkeypatch)
        open_pts = self._sweep(small_trace, tmp_path,
                               arrival=SPECS["poisson"],
                               collect_percentiles=True)
        assert calls["n"] == len(self.LATS) * len(self.CANDS)
        # and different arrival specs get different cells too
        calls["n"] = 0
        other = dataclasses.replace(SPECS["poisson"], seed=99)
        self._sweep(small_trace, tmp_path, arrival=other,
                    collect_percentiles=True)
        assert calls["n"] == len(self.LATS) * len(self.CANDS)
        assert all(p.result.latency_summary is not None for p in open_pts)

    def test_arrival_dict_and_spec_key_identically(self, small_trace,
                                                   tmp_path, monkeypatch):
        spec = SPECS["bursty"]
        self._sweep(small_trace, tmp_path, arrival=spec,
                    collect_percentiles=True)
        calls = self._count_runs(monkeypatch)
        self._sweep(small_trace, tmp_path, arrival=spec.to_dict(),
                    collect_percentiles=True)
        assert calls["n"] == 0

    def test_missed_ops_round_trip_through_cache(self, small_trace,
                                                 tmp_path, monkeypatch):
        spec = dataclasses.replace(SPECS["poisson"], rate=400e3,
                                   deadline=120e-6)
        cold = self._sweep(small_trace, tmp_path, arrival=spec,
                           collect_percentiles=True)
        assert any(p.result.missed_ops > 0 for p in cold)
        calls = self._count_runs(monkeypatch)
        warm = self._sweep(small_trace, tmp_path, arrival=spec,
                           collect_percentiles=True)
        assert calls["n"] == 0
        for a, b in zip(cold, warm):
            assert a.result.missed_ops == b.result.missed_ops
