"""Back-compat shims (`repro.core.kvstore` / `repro.core.simulator`) and the
engine registry introduced by the layering refactor."""
import importlib
import warnings

import pytest

import repro.core.kvstore as kvstore_shim
import repro.core.simulator as simulator_shim
from repro.core.engines import (
    KVEngine,
    LSMStore,
    TreeIndexStore,
    TwoTierCacheStore,
    available_engines,
    create_engine,
    get_engine,
)
from repro.core.trace_ir import CompiledTrace, Op

US = 1e-6


class TestShims:
    KV_NAMES = ["EngineTimes", "Recorder", "TraceResult", "TreeIndexStore",
                "LSMStore", "TwoTierCacheStore", "run_trace"]
    SIM_NAMES = ["SimConfig", "SimResult", "Op", "simulate",
                 "microbenchmark_source", "trace_source", "best_over_threads",
                 "MEM", "PREIO", "POSTIO", "CPU", "US"]

    @pytest.mark.parametrize("name", KV_NAMES)
    def test_kvstore_exports(self, name):
        assert hasattr(kvstore_shim, name)

    @pytest.mark.parametrize("name", SIM_NAMES)
    def test_simulator_exports(self, name):
        assert hasattr(simulator_shim, name)

    @pytest.mark.parametrize("shim", [kvstore_shim, simulator_shim])
    def test_shims_warn_on_import(self, shim):
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            importlib.reload(shim)
        assert any(issubclass(w.category, DeprecationWarning) for w in caught)

    def test_attribute_style_access_on_repro_core(self):
        # `import repro.core` then attribute access worked pre-refactor
        # (the old __init__ imported both submodules); PEP 562 keeps it.
        import repro.core
        assert repro.core.kvstore is kvstore_shim
        assert repro.core.simulator is simulator_shim
        with pytest.raises(AttributeError):
            repro.core.no_such_module

    def test_shim_classes_are_the_canonical_ones(self):
        from repro.core.engines import lsm
        from repro.core.sim import config
        assert kvstore_shim.LSMStore is lsm.LSMStore
        assert simulator_shim.SimConfig is config.SimConfig

    def test_legacy_op_replay_path_still_works(self):
        op = Op(((simulator_shim.MEM, 0.1 * US),
                 (simulator_shim.PREIO, 1.5 * US),
                 (simulator_shim.POSTIO, 0.2 * US)))
        src = simulator_shim.trace_source([op])
        cfg = simulator_shim.SimConfig(L_mem=1 * US, n_threads=8, seed=1)
        r = simulator_shim.simulate(cfg, src, 200)
        assert r.ops == 200 and r.throughput > 0

    def test_traceresult_accepts_legacy_op_list(self):
        ops = [Op(((0, 0.1 * US),))] * 3
        tr = kvstore_shim.TraceResult(trace=ops, mem_per_op=1.0, io_per_op=0.0)
        assert isinstance(tr.trace, CompiledTrace)
        assert tr.ops == ops
        # ... including under the old dataclass's field name
        legacy = kvstore_shim.TraceResult(ops=ops, mem_per_op=1.0,
                                          io_per_op=0.0)
        assert legacy.ops == ops
        with pytest.raises(TypeError):
            kvstore_shim.TraceResult(mem_per_op=1.0, io_per_op=0.0)

    def test_kvstore_shim_keeps_transitive_names(self):
        # the old module exposed these via its own imports; legacy code
        # imported them from repro.core.kvstore directly
        for name in ("Op", "MEM", "PREIO", "POSTIO", "CPU", "US",
                     "OpParams", "Workload"):
            assert hasattr(kvstore_shim, name), name

    def test_recorder_ops_clear_writes_through(self):
        # the pre-refactor run_trace bounded warm-up memory via
        # `warm_rec.ops.clear()`; that idiom must keep clearing the recorder
        rec = kvstore_shim.Recorder(kvstore_shim.EngineTimes())
        rec.mem(2)
        rec.end_op()
        rec.ops.clear()
        assert rec.n_ops == 0
        assert rec.n_mem == 0 and rec.n_io == 0  # averages stay consistent
        assert rec.ops == []
        rec.mem(1)
        rec.end_op()
        assert len(rec.ops) == 1

    def test_recorder_ops_view_mid_operation(self):
        rec = kvstore_shim.Recorder(kvstore_shim.EngineTimes())
        rec.mem(1)
        rec.end_op()
        rec.mem(2)                      # op still in flight
        assert len(rec.ops) == 1        # only completed ops appear
        rec.end_op()
        assert len(rec.ops) == 2


class TestRegistry:
    def test_canonical_names_and_aliases(self):
        eng = available_engines()
        assert eng["tree-index"] is TreeIndexStore
        assert eng["aerospike-like"] is TreeIndexStore
        assert eng["lsm"] is LSMStore
        assert eng["rocksdb-like"] is LSMStore
        assert eng["two-tier-cache"] is TwoTierCacheStore
        assert eng["cachelib-like"] is TwoTierCacheStore

    def test_get_and_create(self):
        assert get_engine("lsm") is LSMStore
        store = create_engine("lsm", 1000, cache_blocks=10)
        assert isinstance(store, LSMStore) and store.cache_cap == 10
        with pytest.raises(KeyError, match="unknown engine"):
            get_engine("nope")

    def test_engines_satisfy_protocol(self):
        for cls, kwargs in ((TreeIndexStore, {}), (LSMStore, {}),
                            (TwoTierCacheStore, {})):
            store = cls(500, **kwargs)
            assert isinstance(store, KVEngine)
            assert isinstance(store.stats(), dict)
