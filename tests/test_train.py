"""Training substrate: trainer, checkpoint/restart, stragglers, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st  # optional-hypothesis shim

from repro.configs import ARCHS, smoke_config
from repro.models.layers import init_params
from repro.optim.grad_compress import dequantize, ef_compress_grads, quantize
from repro.optim.schedule import constant, warmup_cosine, warmup_rsqrt
from repro.train.checkpoint import CheckpointManager, latest_step, restore, save
from repro.train.ft import RestartableLoop, StragglerDetector
from repro.train.train_step import TrainHParams, init_train_state, make_train_step
from repro.train.trainer import Trainer
from repro.zoo import get_api


def test_trainer_learns(tmp_path):
    cfg = smoke_config(ARCHS["qwen2.5-3b"])
    hp = TrainHParams(peak_lr=1e-3, warmup=10, total_steps=120)
    tr = Trainer(cfg, hp, ckpt_dir=str(tmp_path), ckpt_every=0)
    tr.hp_global_batch, tr.hp_seq_len = 16, 48
    _, log = tr.fit(120, resume=False)
    first = np.mean([m["loss"] for m in log[:10]])
    last = np.mean([m["loss"] for m in log[-10:]])
    assert last < first - 1.0  # clearly learning the synthetic structure


def test_checkpoint_roundtrip(tmp_path):
    cfg = smoke_config(ARCHS["starcoder2-3b"])
    api = get_api(cfg)
    hp = TrainHParams(total_steps=10, warmup=1)
    params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
    state = init_train_state(params, hp)
    save(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    like = jax.eval_shape(lambda: state)
    back = restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_determinism(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    cfg = smoke_config(ARCHS["qwen2.5-3b"])
    api = get_api(cfg)
    hp = TrainHParams(peak_lr=1e-3, warmup=1, total_steps=6)
    step = jax.jit(make_train_step(api, cfg, hp))

    def batch(i):
        rng = jax.random.PRNGKey(100 + i)
        t = jax.random.randint(rng, (4, 17), 0, cfg.vocab)
        return {"tokens": t[:, :-1], "targets": t[:, 1:],
                "loss_mask": jnp.ones((4, 16), jnp.float32)}

    params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
    s_a = init_train_state(params, hp)
    for i in range(6):
        s_a, _ = step(s_a, batch(i))

    s_b = init_train_state(params, hp)
    for i in range(3):
        s_b, _ = step(s_b, batch(i))
    save(str(tmp_path), 3, s_b)
    s_b = restore(str(tmp_path), 3, jax.eval_shape(lambda: s_b))
    for i in range(3, 6):
        s_b, _ = step(s_b, batch(i))

    for a, b in zip(jax.tree.leaves(s_a["params"]), jax.tree.leaves(s_b["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restartable_loop_recovers(tmp_path):
    """Inject a failure mid-run; the loop restores and finishes."""
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 5:
            raise RuntimeError("injected preemption")
        return state + 1, {"loss": float(state)}

    mgr = CheckpointManager(str(tmp_path), every=2)
    saved = {}

    def save_and_track(step, state, force=False):
        saved[step] = int(state)
        return CheckpointManager.maybe_save(mgr, step, jnp.asarray(state), force)

    mgr.maybe_save = save_and_track  # type: ignore[method-assign]

    def data_iter(start):
        def gen():
            i = start
            while True:
                yield i
                i += 1
        return gen()

    def restore_fn(step):
        return jnp.asarray(saved[step])

    loop = RestartableLoop(step_fn, mgr, data_iter, max_restarts=2)
    state, end = loop.run(jnp.asarray(0), 8, restore_fn=restore_fn)
    assert end == 8
    assert int(state) == 8
    assert loop.restarts == 1


def test_straggler_detector():
    det = StragglerDetector(n_hosts=8, window=8, threshold=2.0, grace_steps=3)
    rng = np.random.default_rng(0)
    flagged: list[int] = []
    for step in range(12):
        times = rng.normal(1.0, 0.05, 8)
        times[3] = 3.5  # host 3 is consistently 3.5x slower
        flagged = det.observe(times)
    assert flagged == [3]

    det2 = StragglerDetector(n_hosts=8, grace_steps=3)
    for step in range(12):
        times = rng.normal(1.0, 0.05, 8)
        if step == 4:
            times[5] = 5.0  # one transient blip: must NOT flag
        assert 5 not in det2.observe(times)


class TestGradCompression:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2,
                    max_size=64))
    def test_quantize_bound(self, xs):
        x = jnp.asarray(xs, jnp.float32)
        q, s = quantize(x)
        err = jnp.abs(dequantize(q, s) - x)
        assert float(jnp.max(err)) <= float(s) / 2 + 1e-6

    def test_error_feedback_preserves_sum(self):
        """EF invariant: dequantized + residual == grad + old residual."""
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (32, 8)),
                              jnp.float32)}
        e = {"w": jnp.zeros((32, 8), jnp.float32)}
        q, s, e2 = ef_compress_grads(g, e)
        back = dequantize(q["w"], s["w"])
        np.testing.assert_allclose(back + e2["w"], g["w"], atol=1e-5)

    def test_ef_converges_on_repeat(self):
        """Repeatedly compressing the same gradient transmits it in full."""
        g = jnp.asarray(np.random.default_rng(1).normal(0, 1, (64,)), jnp.float32)
        e = jnp.zeros_like(g)
        sent = jnp.zeros_like(g)
        for _ in range(8):
            q, s, e_new = ef_compress_grads({"g": g}, {"g": e})
            sent = sent + dequantize(q["g"], s["g"])
            e = e_new["g"]
        np.testing.assert_allclose(sent / 8, g, atol=0.02)


def test_schedules():
    assert float(warmup_cosine(0, 1e-3, 10, 100)) == 0.0
    assert float(warmup_cosine(10, 1e-3, 10, 100)) == pytest.approx(1e-3)
    assert float(warmup_cosine(100, 1e-3, 10, 100)) == pytest.approx(1e-4)
    assert float(warmup_rsqrt(40, 1e-3, 10)) == pytest.approx(5e-4)
    assert float(constant(5, 1e-3)) == pytest.approx(1e-3)


def test_microbatch_equivalence():
    """mb=1 and mb=4 give (nearly) the same gradients -> same first step."""
    cfg = smoke_config(ARCHS["qwen2.5-3b"])
    api = get_api(cfg)
    params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    t = jax.random.randint(rng, (8, 17), 0, cfg.vocab)
    batch = {"tokens": t[:, :-1], "targets": t[:, 1:],
             "loss_mask": jnp.ones((8, 16), jnp.float32)}
    outs = []
    for mb in (1, 4):
        hp = TrainHParams(peak_lr=1e-2, warmup=0, total_steps=10,
                          microbatches=mb)
        step = jax.jit(make_train_step(api, cfg, hp,
                                       accum_dtype=jnp.float32))
        state = init_train_state(init_params(api.param_specs(cfg),
                                             jax.random.PRNGKey(0)), hp)
        state, m = step(state, batch)
        outs.append(state["params"]["embed"])
    np.testing.assert_allclose(
        outs[0].astype(jnp.float32), outs[1].astype(jnp.float32), atol=2e-2
    )
