"""Serving engine: paged tiered KV cache + continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st  # optional-hypothesis shim

from repro.configs import ARCHS, smoke_config
from repro.core.tiering import CXL_MICROSECOND, DRAM
from repro.models import transformer as tf
from repro.models.layers import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.kv_cache import PagedKVCache, PageStoreConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config(ARCHS["qwen2.5-3b"]).replace(sliding_window=None)
    params = init_params(tf.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


class TestPagedKVCache:
    def _cache(self, n_pages=32, page=8):
        return PagedKVCache(PageStoreConfig(
            n_pages=n_pages, page_size=page, n_kv_heads=2, head_dim=16,
            n_layers=2))

    def test_admit_extend_release(self):
        c = self._cache()
        assert c.admit(1, 20)       # 3 pages
        assert len(c.tables[1]) == 3
        assert c.extend(1, 5)       # 25 tokens -> 4 pages
        assert len(c.tables[1]) == 4
        c.release(1)
        assert len(c.free) == 32

    def test_admission_control(self):
        c = self._cache(n_pages=4)
        assert c.admit(1, 30)       # 4 pages: all of them
        assert not c.admit(2, 1)    # no pages left
        c.release(1)
        assert c.admit(2, 1)

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 40), st.integers(0, 30)),
                    min_size=1, max_size=12))
    def test_free_list_conservation(self, ops):
        c = self._cache(n_pages=64)
        live = {}
        for i, (plen, ext) in enumerate(ops):
            if c.admit(i, plen):
                live[i] = True
                c.extend(i, ext)
        used = sum(len(t) for t in c.tables.values())
        assert used + len(c.free) == 64
        for s in list(live):
            c.release(s)
        assert len(c.free) == 64

    def test_plan_prefetch_depth_scales_with_latency(self):
        c = self._cache()
        c.admit(0, 60)
        fast = c.plan_prefetch_depth(2e-6, 20e-6)
        c.cfg = PageStoreConfig(
            n_pages=32, page_size=8, n_kv_heads=2, head_dim=16, n_layers=2,
            tier=CXL_MICROSECOND)
        slow_depth = c.plan_prefetch_depth(2e-6, 20e-6)
        assert slow_depth >= fast >= 1


class TestEngineCorrectness:
    def test_paged_equals_dense_decode(self, small_model):
        """The engine's paged decode path must produce the same tokens as
        the plain full-cache decode path (greedy)."""
        cfg, params = small_model
        prompt = np.arange(1, 9, dtype=np.int32)
        n_new = 6

        # reference: prefill (cache sized for prompt + new tokens) + dense decode
        logits, cache = jax.jit(
            lambda p, t: tf.prefill(p, t, cfg, max_len=len(prompt) + n_new + 1)
        )(params, jnp.asarray(prompt)[None])
        ref_tokens = [int(jnp.argmax(logits[0, -1]))]
        dec = jax.jit(lambda p, c, t: tf.decode_step(p, c, t, cfg))
        for _ in range(n_new - 1):
            lg, cache = dec(params, cache,
                            jnp.asarray([[ref_tokens[-1]]], jnp.int32))
            ref_tokens.append(int(jnp.argmax(lg[0, -1])))

        eng = ServeEngine(cfg, params, n_pages=64, page_size=8, max_slots=2)
        req = Request(rid=0, prompt=prompt, max_new_tokens=n_new)
        eng.submit(req)
        done = eng.run(max_steps=50)
        assert done and done[0].out_tokens == ref_tokens

    def test_continuous_batching(self, small_model):
        cfg, params = small_model
        eng = ServeEngine(cfg, params, n_pages=64, page_size=8, max_slots=2)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab, 6).astype(np.int32),
                        max_new_tokens=4) for i in range(5)]
        for r in reqs:
            eng.submit(r)
        done = eng.run(max_steps=200)
        assert len(done) == 5
        assert all(len(r.out_tokens) == 4 for r in done)
        assert len(eng.cache.free) == eng.cache.cfg.n_pages  # all released

    def test_page_utilization_reporting(self, small_model):
        cfg, params = small_model
        eng = ServeEngine(cfg, params, n_pages=16, page_size=8, max_slots=4)
        eng.submit(Request(rid=0, prompt=np.arange(1, 17, dtype=np.int32),
                           max_new_tokens=8))
        eng.step()  # request still active -> pages held
        assert 0 < eng.cache.utilization <= 1
        eng.run(max_steps=50)
        assert eng.cache.utilization == 0.0
