"""Simulator vs. closed-form model: the O3 validation, in test form."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_support import given, settings, st  # optional-hypothesis shim

from repro.core.latency_model import (
    OpParams,
    US,
    theta_mask_inv,
    theta_mem_inv,
    theta_prob_inv,
)
from repro.core.simulator import (
    SimConfig,
    best_over_threads,
    microbenchmark_source,
    simulate,
)

P_EX = OpParams()  # Table 1 example values


def _mem_only_cfg(L, n=64):
    return SimConfig(L_mem=L, P=10, n_threads=n, T_sw=P_EX.T_sw, seed=3)


class TestMemoryOnly:
    @pytest.mark.parametrize("l_us", [0.1, 1.0, 3.0, 10.0])
    def test_matches_eq3(self, l_us):
        """Memory-only throughput == Eq. 3 (both regimes) within 1%."""
        src = microbenchmark_source(10, P_EX.T_mem, 0, 0, n_io=0)
        r = simulate(_mem_only_cfg(l_us * US), src, 6000)
        pred = 1 / theta_mem_inv(np.array([l_us * US]), P_EX)[0] / 10
        assert r.throughput == pytest.approx(pred, rel=0.01)


class TestMemoryAndIO:
    @pytest.mark.parametrize("l_us", [0.1, 3.0, 5.0, 8.0])
    def test_tracks_prob_model(self, l_us):
        """With the paper's protocol (best thread count per point), the
        simulated throughput is within [-8%, +15%] of Theta_prob and always
        at least as high as the masking-only prediction (O2/O3)."""
        src = microbenchmark_source(10, P_EX.T_mem, P_EX.T_io_pre, P_EX.T_io_post)
        cfg = SimConfig(L_mem=l_us * US, P=10, T_sw=P_EX.T_sw, seed=5)
        best, _ = best_over_threads(cfg, src, 5000, candidates=(24, 32, 48, 64))
        L = np.array([l_us * US])
        prob = 1 / theta_prob_inv(L, P_EX)[0]
        mask = 1 / theta_mask_inv(L, P_EX)[0]
        assert best.throughput >= mask * 0.97
        assert 0.92 * prob <= best.throughput <= 1.20 * prob

    def test_io_increases_latency_tolerance(self):
        """O2 in sim form: normalized throughput at 5us is much higher with
        IO than without."""
        src_io = microbenchmark_source(10, P_EX.T_mem, P_EX.T_io_pre, P_EX.T_io_post)
        src_no = microbenchmark_source(10, P_EX.T_mem, 0, 0, n_io=0)

        def norm(src):
            out = []
            for l_us in (0.1, 5.0):
                cfg = SimConfig(L_mem=l_us * US, P=10, T_sw=P_EX.T_sw, seed=7)
                r, _ = best_over_threads(cfg, src, 4000, candidates=(24, 32, 48))
                out.append(r.throughput)
            return out[1] / out[0]

        assert norm(src_io) > norm(src_no) + 0.2


class TestExtendedScenarios:
    def test_ssd_iops_cap(self):
        src = microbenchmark_source(10, P_EX.T_mem, P_EX.T_io_pre, P_EX.T_io_post)
        cfg = SimConfig(L_mem=0.1 * US, P=10, n_threads=64, R_io=30e3, seed=1)
        r = simulate(cfg, src, 4000)
        assert r.throughput <= 30e3 * 1.02

    def test_memory_bandwidth_throttle(self):
        src = microbenchmark_source(10, P_EX.T_mem, 0, 0, n_io=0)
        cfg = SimConfig(L_mem=0.1 * US, P=10, n_threads=64,
                        A_mem=64, B_mem=64 / (1.0 * US), seed=1)  # 1 line/us
        r = simulate(cfg, src, 4000)
        assert r.throughput <= 1e5 * 1.02  # 10 accesses/op at 1/us

    def test_eviction_slows(self):
        src = microbenchmark_source(10, P_EX.T_mem, P_EX.T_io_pre, P_EX.T_io_post)
        base = simulate(SimConfig(L_mem=5 * US, n_threads=48, seed=2), src, 4000)
        ev = simulate(SimConfig(L_mem=5 * US, n_threads=48, eps=0.2, seed=2),
                      src, 4000)
        assert ev.throughput < base.throughput

    def test_tiering_helps(self):
        src = microbenchmark_source(10, P_EX.T_mem, P_EX.T_io_pre, P_EX.T_io_post)
        full = simulate(SimConfig(L_mem=8 * US, n_threads=48, rho=1.0, seed=2),
                        src, 4000)
        half = simulate(SimConfig(L_mem=8 * US, n_threads=48, rho=0.5, seed=2),
                        src, 4000)
        assert half.throughput >= full.throughput * 0.98

    def test_tail_latency_mixture(self):
        src = microbenchmark_source(10, P_EX.T_mem, P_EX.T_io_pre, P_EX.T_io_post)
        mix = [(5 * US, 0.90), (14 * US, 0.099), (48 * US, 0.001)]  # Sec. 5.1
        r = simulate(SimConfig(L_mem=mix, n_threads=64, seed=2), src, 4000)
        flat = simulate(SimConfig(L_mem=5 * US, n_threads=64, seed=2), src, 4000)
        assert 0.5 * flat.throughput < r.throughput <= flat.throughput * 1.02

    def test_multicore_scales(self):
        src = microbenchmark_source(10, P_EX.T_mem, P_EX.T_io_pre, P_EX.T_io_post)
        one = simulate(SimConfig(L_mem=5 * US, n_threads=32, n_cores=1, seed=2),
                       src, 3000)
        four = simulate(SimConfig(L_mem=5 * US, n_threads=32, n_cores=4, seed=2),
                        src, 12000)
        assert four.throughput > 3.0 * one.throughput

    def test_lock_contention_sublinear(self):
        src = microbenchmark_source(10, P_EX.T_mem, P_EX.T_io_pre, P_EX.T_io_post)
        four = simulate(SimConfig(L_mem=5 * US, n_threads=32, n_cores=4,
                                  T_lock=2.0 * US, seed=2), src, 8000)
        assert four.throughput <= 1 / (2.0 * US) * 1.05  # lock serializes


class TestConservation:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 15), st.floats(0.1, 8.0), st.integers(4, 64))
    def test_all_ops_complete(self, M, l_us, n_threads):
        src = microbenchmark_source(M, 0.1 * US, 1.5 * US, 0.2 * US)
        cfg = SimConfig(L_mem=l_us * US, n_threads=n_threads, seed=11)
        r = simulate(cfg, src, 500)
        assert r.ops == 500
        assert r.throughput > 0
        assert r.mem_stall_total >= 0

    def test_load_latency_histogram(self):
        """Fig. 10: most loads hit cache (zero stall) at moderate latency."""
        src = microbenchmark_source(10, P_EX.T_mem, P_EX.T_io_pre, P_EX.T_io_post)
        cfg = SimConfig(L_mem=2 * US, n_threads=32, seed=4,
                        collect_load_hist=True)
        r = simulate(cfg, src, 3000)
        stalls = np.array(r.load_stalls)
        assert (stalls == 0).mean() > 0.8
