"""Data pipeline determinism/resume + model-driven planner."""
import numpy as np
import pytest

from repro.core.latency_model import OpParams, US
from repro.core.planner import plan_concurrency, plan_pipeline_depth
from repro.core.tiering import tail_mixture
from repro.data.pipeline import DataConfig, prefetch, synthetic_batches


class TestPipeline:
    def test_deterministic_per_step(self):
        cfg = DataConfig(global_batch=8, seq_len=32, vocab=128, seed=3)
        a = next(synthetic_batches(cfg, start_step=5))
        b = next(synthetic_batches(cfg, start_step=5))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_resume_replays_identically(self):
        """The fault-tolerance contract: iterating from step k reproduces
        exactly the batches a continuous run would have seen."""
        cfg = DataConfig(global_batch=4, seq_len=16, vocab=64, seed=1)
        straight = synthetic_batches(cfg, 0)
        seen = [next(straight) for _ in range(6)]
        resumed = synthetic_batches(cfg, 3)
        for i in range(3):
            np.testing.assert_array_equal(
                next(resumed)["tokens"], seen[3 + i]["tokens"])

    def test_host_sharding_partitions_batch(self):
        full = DataConfig(global_batch=8, seq_len=16, vocab=64, seed=2)
        h0 = DataConfig(global_batch=8, seq_len=16, vocab=64, seed=2,
                        host_id=0, n_hosts=2)
        h1 = DataConfig(global_batch=8, seq_len=16, vocab=64, seed=2,
                        host_id=1, n_hosts=2)
        b0 = next(synthetic_batches(h0, 0))
        b1 = next(synthetic_batches(h1, 0))
        assert b0["tokens"].shape[0] == 4 and b1["tokens"].shape[0] == 4
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_prefetch_preserves_order_and_count(self):
        cfg = DataConfig(global_batch=2, seq_len=8, vocab=32, seed=4)

        def take(it, n):
            return [next(it) for _ in range(n)]

        plain = take(synthetic_batches(cfg, 0), 5)
        pre = prefetch(synthetic_batches(cfg, 0), depth=3)
        fetched = take(pre, 5)
        for a, b in zip(plain, fetched):
            np.testing.assert_array_equal(a["tokens"], np.asarray(b["tokens"]))

    def test_targets_are_shifted_tokens(self):
        cfg = DataConfig(global_batch=2, seq_len=8, vocab=32, seed=5)
        b = next(synthetic_batches(cfg, 0))
        # pipeline yields (tokens, targets) from one contiguous stream
        assert b["tokens"].shape == b["targets"].shape


class TestPlanner:
    def test_concurrency_grows_with_latency(self):
        p = OpParams()
        n_fast = plan_concurrency(p, 0.1 * US)
        n_slow = plan_concurrency(p, 10 * US)
        assert n_slow > n_fast >= 1

    def test_pipeline_depth_knee(self):
        """Eq. 8: with large E (lots of masking work) a shallow pipeline
        suffices; with no IO, depth must cover L/(T_mem+T_sw)."""
        heavy_io = OpParams(M=4, T_io_pre=20 * US, T_io_post=20 * US)
        no_io = OpParams(M=4, T_io_pre=0.1 * US, T_io_post=0.1 * US)
        d_heavy = plan_pipeline_depth(heavy_io, 5 * US).prefetch_depth
        d_light = plan_pipeline_depth(no_io, 5 * US).prefetch_depth
        assert d_heavy <= d_light

    def test_efficiency_target_met(self):
        p = OpParams()
        plan = plan_pipeline_depth(p, 3 * US, target=0.95)
        assert plan.efficiency >= 0.95

    def test_tail_mixture_mean(self):
        mix = tail_mixture(5 * US, 48 * US, 0.001)
        mean = sum(l * pr for l, pr in mix)
        assert mean == pytest.approx(5 * US, rel=1e-9)
