"""The compiled fast loop and the batched sweep pipeline.

Equivalence guarantees, strongest first:

  1. ``simulate_compiled`` is *bit-identical* to the generic event loop
     replaying the same trace (same RNG draw order by construction).
  2. ``sweep_latency`` agrees with the legacy protocol -- a Python loop
     calling ``best_over_threads`` per latency point over a persistent
     tuple-trace source -- within 2% per point on the Fig. 11
     configurations, while being several times faster (the acceptance
     criterion of the layering refactor).
"""
import dataclasses
import time

import pytest

from repro.core import workloads
from repro.core.engines import (
    LSMStore,
    TreeIndexStore,
    TwoTierCacheStore,
    run_trace,
)
from repro.core.sim import (
    SimConfig,
    best_over_threads,
    simulate,
    simulate_compiled,
    sweep_latency,
    trace_source,
)

US = 1e-6


@pytest.fixture(scope="module")
def lsm_small():
    store = LSMStore(30_000)
    wl = workloads.zipf(30_000, 10_000, 0.99, (1, 0), seed=3)
    return run_trace(store, wl)


def _assert_identical(a, b):
    assert a.throughput == b.throughput
    assert a.ops == b.ops
    assert a.time == b.time
    assert a.mem_stall_total == b.mem_stall_total
    assert a.mem_accesses == b.mem_accesses


class TestCompiledLoop:
    CONFIGS = [
        dict(L_mem=5 * US, n_threads=40),
        dict(L_mem=0.1 * US, n_threads=24),
        dict(L_mem=8 * US, n_threads=56, eps=0.05),
        dict(L_mem=3 * US, n_threads=32, R_io=50e3, T_lock=0.1 * US),
        dict(L_mem=2 * US, n_threads=32, A_mem=64, B_mem=64 / (0.5 * US)),
        dict(L_mem=[(5 * US, 0.9), (14 * US, 0.099), (48 * US, 0.001)],
             n_threads=48, rho=0.9),
    ]

    @pytest.mark.parametrize("kw", CONFIGS,
                             ids=[f"cfg{i}" for i in range(len(CONFIGS))])
    def test_bit_identical_to_generic(self, lsm_small, kw):
        cfg = SimConfig(seed=7, **kw)
        generic = simulate(cfg, trace_source(lsm_small.ops), 3000)
        compiled = simulate_compiled(cfg, lsm_small.trace, 3000)
        _assert_identical(generic, compiled)

    MULTICORE = [
        dict(n_cores=2, n_threads=16),
        dict(n_cores=4, n_threads=8),
        dict(n_cores=2, n_threads=16, T_lock=0.1 * US),
        dict(n_cores=3, n_threads=12, L_io_jitter=0.0),
    ]

    @pytest.mark.parametrize("kw", MULTICORE,
                             ids=[f"mc{i}" for i in range(len(MULTICORE))])
    def test_multicore_fast_path_bit_identical(self, lsm_small, kw):
        """n_cores > 1 no longer falls back: the dedicated multicore fast
        loop replays the generic loop's per-core run queues, shared parked
        heap, and RNG draw order bit-for-bit."""
        cfg = SimConfig(L_mem=5 * US, seed=7, **kw)
        generic = simulate(cfg, trace_source(lsm_small.ops), 3000)
        compiled = simulate_compiled(cfg, lsm_small.trace, 3000)
        _assert_identical(generic, compiled)

    def test_multicore_latency_collection_identical(self, lsm_small):
        cfg = SimConfig(L_mem=2 * US, n_threads=12, n_cores=2, seed=5)
        generic = simulate(cfg, trace_source(lsm_small.ops), 2000,
                           collect_latency=True)
        compiled = simulate_compiled(cfg, lsm_small.trace, 2000,
                                     collect_latency=True)
        assert compiled.op_latencies == generic.op_latencies

    def test_latency_and_hist_collection(self, lsm_small):
        cfg = SimConfig(L_mem=2 * US, n_threads=24, seed=5,
                        collect_load_hist=True)
        generic = simulate(cfg, trace_source(lsm_small.ops), 2000,
                           collect_latency=True)
        compiled = simulate_compiled(cfg, lsm_small.trace, 2000,
                                     collect_latency=True)
        assert compiled.op_latencies == generic.op_latencies
        assert compiled.load_stalls == generic.load_stalls


class TestSweepPipeline:
    def test_parallel_equals_serial(self, lsm_small):
        cfg = SimConfig(P=12, seed=7)
        lats = [0.1 * US, 5 * US]
        serial = sweep_latency(cfg, lsm_small, lats, (24, 40), n_ops=2000,
                               processes=1)
        parallel = sweep_latency(cfg, lsm_small, lats, (24, 40), n_ops=2000,
                                 processes=2)
        for a, b in zip(serial, parallel):
            assert a.n_threads == b.n_threads
            _assert_identical(a.result, b.result)

    def test_accepts_many_source_kinds(self, lsm_small):
        cfg = SimConfig(P=12, seed=7)
        lats = [5 * US]
        from_trace = sweep_latency(cfg, lsm_small.trace, lats, (32,),
                                   n_ops=1500)
        from_result = sweep_latency(cfg, lsm_small, lats, (32,), n_ops=1500)
        from_ops = sweep_latency(cfg, lsm_small.ops, lats, (32,), n_ops=1500)
        _assert_identical(from_trace[0].result, from_result[0].result)
        _assert_identical(from_trace[0].result, from_ops[0].result)
        with pytest.raises(TypeError):
            sweep_latency(cfg, 12345, lats)

    def test_cell_seeding_matches_legacy_protocol(self, lsm_small):
        """Each grid cell is seeded like the legacy replace(cfg, ...) call,
        so a fresh-source legacy simulation is bit-identical to the cell."""
        cfg = SimConfig(P=12, seed=7)
        (pt,) = sweep_latency(cfg, lsm_small, [5 * US], (24, 40, 56),
                              n_ops=2500)
        legacy_cell = simulate(
            dataclasses.replace(cfg, L_mem=5 * US, n_threads=pt.n_threads),
            trace_source(lsm_small.ops), 2500)
        _assert_identical(pt.result, legacy_cell)
        assert set(pt.per_thread) == {24, 40, 56}

    def test_stateful_callable_parallel_is_deterministic(self, lsm_small):
        # trace_source closures carry state; parallel runs must still be
        # repeatable (every cell gets a pristine fork of the call state)
        cfg = SimConfig(P=12, seed=7)
        lats = [0.1 * US, 5 * US]
        runs = [
            sweep_latency(cfg, trace_source(lsm_small.ops), lats, (24, 40),
                          n_ops=1500, processes=2)
            for _ in range(2)
        ]
        for a, b in zip(*runs):
            assert a.n_threads == b.n_threads
            _assert_identical(a.result, b.result)

    def test_disk_cache_roundtrip(self, lsm_small, tmp_path):
        cfg = SimConfig(P=12, seed=7)
        lats = [1 * US, 5 * US]
        first = sweep_latency(cfg, lsm_small, lats, (24, 40), n_ops=1500,
                              processes=1, cache_dir=tmp_path)
        assert len(list(tmp_path.glob("*.json"))) == 4
        second = sweep_latency(cfg, lsm_small, lats, (24, 40), n_ops=1500,
                               processes=1, cache_dir=tmp_path)
        for a, b in zip(first, second):
            assert a.n_threads == b.n_threads
            assert a.result.throughput == b.result.throughput

    def test_corrupt_cache_cells_are_recomputed(self, lsm_small, tmp_path):
        cfg = SimConfig(P=12, seed=7)
        first = sweep_latency(cfg, lsm_small, [5 * US], (24, 40), n_ops=1500,
                              processes=1, cache_dir=tmp_path)
        files = sorted(tmp_path.glob("*.json"))
        files[0].write_text("{garbage")   # not JSON
        files[1].write_text("[]")         # JSON, wrong top-level type
        second = sweep_latency(cfg, lsm_small, [5 * US], (24, 40), n_ops=1500,
                               processes=1, cache_dir=tmp_path)
        assert second[0].result.throughput == first[0].result.throughput


class TestLatencyCollection:
    """Regression for the sweep-cell cache silently degrading results: the
    cached cells drop ``op_latencies``, so any call that needs latencies
    must bypass the cache (loads and stores) instead of returning
    ``mean_op_latency == 0`` on a hit."""

    def test_collect_latency_bypasses_cache(self, lsm_small, tmp_path):
        cfg = SimConfig(P=12, seed=7)
        kw = dict(n_ops=1500, processes=1, cache_dir=tmp_path)
        # warm the cache with a non-collecting sweep ...
        warm = sweep_latency(cfg, lsm_small, [5 * US], (24, 40), **kw)
        assert warm[0].result.op_latencies == []
        assert len(list(tmp_path.glob("*.json"))) == 2
        # ... then a collecting sweep over the same cells must NOT hit it
        hot = sweep_latency(cfg, lsm_small, [5 * US], (24, 40),
                            collect_latency=True, **kw)
        assert len(hot[0].result.op_latencies) > 0
        assert hot[0].result.mean_op_latency > 0
        # and it must not have poisoned the cache for later cached sweeps
        assert len(list(tmp_path.glob("*.json"))) == 2
        again = sweep_latency(cfg, lsm_small, [5 * US], (24, 40), **kw)
        assert again[0].result.throughput == warm[0].result.throughput

    def test_collected_latencies_match_direct_simulation(self, lsm_small):
        cfg = SimConfig(P=12, seed=7)
        (pt,) = sweep_latency(cfg, lsm_small, [5 * US], (24,), n_ops=1500,
                              processes=1, collect_latency=True)
        direct = simulate_compiled(
            dataclasses.replace(cfg, L_mem=5 * US, n_threads=24),
            lsm_small.trace, 1500, collect_latency=True)
        assert pt.result.op_latencies == direct.op_latencies

    def test_parallel_cells_return_latencies(self, lsm_small):
        cfg = SimConfig(P=12, seed=7)
        pts = sweep_latency(cfg, lsm_small, [0.1 * US, 5 * US], (24, 40),
                            n_ops=1500, processes=2, collect_latency=True)
        for pt in pts:
            assert len(pt.result.op_latencies) > 0


class TestAdaptiveSweep:
    """The warm-started thread search must agree with the full grid on the
    paper sweep while evaluating fewer cells."""

    LATS_US = (0.1, 0.3, 0.5, 1, 2, 3, 5, 8, 10)   # the Fig. 9-11 axis
    CANDIDATES = (16, 24, 32, 48, 64)

    def test_same_winner_as_full_grid_on_paper_sweep(self, lsm_small):
        cfg = SimConfig(P=12, seed=7)
        lats = [l * US for l in self.LATS_US]
        full = sweep_latency(cfg, lsm_small, lats, self.CANDIDATES,
                             n_ops=2000)
        adapt = sweep_latency(cfg, lsm_small, lats, self.CANDIDATES,
                              n_ops=2000, adaptive=True)
        for f, a in zip(full, adapt):
            assert a.n_threads == f.n_threads
            _assert_identical(a.result, f.result)
        cells_full = sum(len(p.per_thread) for p in full)
        cells_adapt = sum(len(p.per_thread) for p in adapt)
        assert cells_adapt < cells_full
        # evaluated cells agree with the corresponding full-grid cells
        for f, a in zip(full, adapt):
            for n, thr in a.per_thread.items():
                assert thr == f.per_thread[n]

    def test_adaptive_uses_cell_cache(self, lsm_small, tmp_path):
        cfg = SimConfig(P=12, seed=7)
        lats = [0.1 * US, 5 * US]
        first = sweep_latency(cfg, lsm_small, lats, (24, 40), n_ops=1500,
                              adaptive=True, cache_dir=tmp_path)
        cached = len(list(tmp_path.glob("*.json")))
        assert cached == sum(len(p.per_thread) for p in first)
        second = sweep_latency(cfg, lsm_small, lats, (24, 40), n_ops=1500,
                               adaptive=True, cache_dir=tmp_path)
        assert len(list(tmp_path.glob("*.json"))) == cached
        for a, b in zip(first, second):
            assert a.n_threads == b.n_threads
            assert a.result.throughput == b.result.throughput

    def test_adaptive_shares_cache_with_full_grid(self, lsm_small, tmp_path):
        # adaptive cells are keyed exactly like grid cells, so the two
        # modes memoize into (and reuse) the same cache
        cfg = SimConfig(P=12, seed=7)
        sweep_latency(cfg, lsm_small, [5 * US], (24, 40), n_ops=1500,
                      processes=1, cache_dir=tmp_path)
        n_before = len(list(tmp_path.glob("*.json")))
        sweep_latency(cfg, lsm_small, [5 * US], (24, 40), n_ops=1500,
                      adaptive=True, cache_dir=tmp_path)
        assert len(list(tmp_path.glob("*.json"))) == n_before


@pytest.mark.slow
class TestAcceptance:
    """The refactor's acceptance criterion, verbatim: an 8-point latency
    sweep on the LSM engine trace through ``sweep_latency`` is >= 3x faster
    than calling ``best_over_threads`` per point over tuple traces (the
    assertion uses a conservative 2x floor so a loaded CI box cannot flake
    the suite; a quiet 2-core box measures ~3.5x), and per-point throughput
    agrees within 2% on the Fig. 11 configurations.

    The legacy baseline builds a fresh tuple-trace source per point -- each
    point is then an independent legacy measurement.  (The old sweep helper
    instead threaded one stateful source through all points, making every
    number depend on the whole call history; that path drifts up to ~3%
    from *itself* depending on where the cyclic replay happens to start,
    which is replay-window noise, not a loop difference -- with identical
    source state the two loops are bit-identical, see TestCompiledLoop.)

    All sims are seeded, so the agreement numbers here are deterministic.
    """

    LATS_US = (0.1, 0.5, 1, 2, 3, 5, 8, 10)
    CANDIDATES = (16, 24, 32, 48, 64)
    N_OPS = 5000

    def _legacy(self, ops, cfg, lats_us, candidates):
        out = {}
        t0 = time.perf_counter()
        for l_us in lats_us:
            r, n = best_over_threads(
                dataclasses.replace(cfg, L_mem=l_us * US), trace_source(ops),
                self.N_OPS, candidates=candidates)
            out[l_us] = r.throughput
        return out, time.perf_counter() - t0

    def test_lsm_fig11_speed_and_agreement(self):
        store = LSMStore(100_000)
        wl = workloads.zipf(100_000, 30_000, 0.99, (1, 0), seed=3)
        tr = run_trace(store, wl)
        cfg = SimConfig(P=12, seed=7)
        ops = tr.ops   # materialize the tuple trace outside the timed region

        legacy, t_legacy = self._legacy(ops, cfg, self.LATS_US,
                                        self.CANDIDATES)

        t0 = time.perf_counter()
        pts = sweep_latency(cfg, tr.trace, [l * US for l in self.LATS_US],
                            self.CANDIDATES, n_ops=self.N_OPS)
        t_sweep = time.perf_counter() - t0

        for l_us, pt in zip(self.LATS_US, pts):
            rel = abs(pt.throughput - legacy[l_us]) / legacy[l_us]
            assert rel < 0.02, f"L={l_us}us: {rel:.2%} off legacy"
        speedup = t_legacy / t_sweep
        print(f"\nsweep speedup: {speedup:.2f}x "
              f"(legacy {t_legacy:.2f}s, sweep {t_sweep:.2f}s)")
        assert speedup >= 2.0

    @pytest.mark.parametrize("which", ["tree", "cache"])
    def test_other_fig11_engines_agree(self, which):
        if which == "tree":
            store = TreeIndexStore(100_000, seed=1)
            wl = workloads.uniform(100_000, 30_000, (1, 0), seed=2)
        else:
            store = TwoTierCacheStore(100_000, seed=4)
            wl = workloads.gaussian(100_000, 30_000, 0.08, (2, 1), seed=5)
        tr = run_trace(store, wl)
        cfg = SimConfig(P=12, seed=7)
        ops = tr.ops
        lats_us = (0.1, 5, 8)
        legacy, _ = self._legacy(ops, cfg, lats_us, self.CANDIDATES)
        pts = sweep_latency(cfg, tr.trace, [l * US for l in lats_us],
                            self.CANDIDATES, n_ops=self.N_OPS)
        for l_us, pt in zip(lats_us, pts):
            rel = abs(pt.throughput - legacy[l_us]) / legacy[l_us]
            assert rel < 0.02, f"L={l_us}us: {rel:.2%} off legacy"
