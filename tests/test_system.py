"""End-to-end behaviour of the paper's system (the headline observations),
plus dry-run machinery checks that don't need the 512-device environment."""
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, list_archs, smoke_config
from repro.core import workloads
from repro.core.kvstore import TreeIndexStore, run_trace
from repro.core.latency_model import US, theta_mask_inv, theta_prob_inv
from repro.core.simulator import SimConfig, best_over_threads, trace_source
from repro.launch.specs import batch_specs, default_microbatches
from repro.roofline.analysis import (
    RooflineTerms,
    collective_bytes,
    dot_bytes,
    model_flops,
)


def test_paper_headline_near_dram_at_5us():
    """The paper's thesis, end to end on the tree-index store: with
    prefetch+yield threads and async IO, throughput at L_mem = 5 us stays
    within ~20% of DRAM throughput (the paper reports 2-19% degradation
    across stores/settings; our tree engine with its measured parameters
    sits in that band)."""
    store = TreeIndexStore(50_000, seed=1)
    wl = workloads.uniform(50_000, 20_000, (1, 0), seed=2)
    tr = run_trace(store, wl)
    src = trace_source(tr.ops)
    thr = {}
    for l_us in (0.1, 5.0):
        cfg = SimConfig(L_mem=l_us * US, P=12, seed=7)
        r, _ = best_over_threads(cfg, src, 6000, candidates=(24, 40, 56))
        thr[l_us] = r.throughput
    degradation = 1 - thr[5.0] / thr[0.1]
    assert degradation < 0.20


def test_masking_only_underestimates():
    """O3's second half: the masking-only model underestimates measured
    throughput at long latency (the paper: by up to 32.7%)."""
    store = TreeIndexStore(50_000, seed=1)
    wl = workloads.uniform(50_000, 20_000, (1, 0), seed=2)
    tr = run_trace(store, wl)
    p = tr.op_params(store.times, P=12, T_sw=0.05 * US)
    src = trace_source(tr.ops)
    cfg = SimConfig(L_mem=8 * US, P=12, seed=7)
    r, _ = best_over_threads(cfg, src, 6000, candidates=(24, 40, 56))
    mask = 1 / theta_mask_inv(np.array([8 * US]), p)[0]
    prob = 1 / theta_prob_inv(np.array([8 * US]), p)[0]
    assert r.throughput > mask * 1.05
    assert abs(r.throughput - prob) < abs(r.throughput - mask)


class TestDryRunMachinery:
    def test_all_cells_enumerable(self):
        """40 (arch x shape) cells exist; skips are exactly the documented
        long_500k inapplicables."""
        from repro.configs import shape_applicable

        cells = [(a, s) for a in list_archs() for s in SHAPES]
        assert len(cells) == 40
        skips = [c for c in cells
                 if not shape_applicable(get_config(c[0]), SHAPES[c[1]])[0]]
        assert len(skips) == 6
        assert all(s == "long_500k" for _, s in skips)

    def test_batch_specs_cover_inputs(self):
        for arch in list_archs():
            cfg = get_config(arch)
            for sname, shape in SHAPES.items():
                specs = batch_specs(cfg, shape)
                assert "tokens" in specs
                if shape.kind == "train":
                    assert specs["targets"].shape == (
                        shape.global_batch, shape.seq_len)
                if cfg.family == "vlm" and shape.kind != "decode":
                    assert "patches" in specs

    def test_default_microbatches_divide(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        for arch in list_archs():
            cfg = get_config(arch)
            mb = default_microbatches(cfg, SHAPES["train_4k"], FakeMesh())
            assert SHAPES["train_4k"].global_batch % mb == 0

    def test_collective_bytes_parser(self):
        hlo = """
        %all-reduce.1 = f32[128,256]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[4,2]<=[8]
        %ag = bf16[64,64]{1,0} all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={0}
        %rs = bf16[8,64]{1,0} reduce-scatter(%q), replica_groups=[2,8]<=[16]
        """
        out = collective_bytes(hlo)
        counts = out.pop("_counts")
        assert counts["all-reduce"] == 1 and counts["all-gather"] == 1
        # all-reduce over groups of 2: 128*256*4 * 2*(2-1)/2
        assert out["all-reduce"] == pytest.approx(128 * 256 * 4 * 1.0)
        # all-gather over groups of 4: 64*64*2 * (4-1)/4
        assert out["all-gather"] == pytest.approx(64 * 64 * 2 * 0.75)
        # reduce-scatter over groups of 8: 8*64*2 * (8-1)
        assert out["reduce-scatter"] == pytest.approx(8 * 64 * 2 * 7)

    def test_dot_bytes_parser(self):
        hlo = """
        %p0 = bf16[128,64]{1,0} parameter(0)
        %p1 = bf16[64,32]{1,0} parameter(1)
        %dot = f32[128,32]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        """
        got = dot_bytes(hlo)
        assert got == pytest.approx(128 * 64 * 2 + 64 * 32 * 2 + 128 * 32 * 4)

    def test_roofline_terms(self):
        t = RooflineTerms(
            arch="x", shape="train_4k", mesh="single", chips=256,
            hlo_flops=1e18, hlo_bytes=1e15, coll_bytes_link=5e10,
            hbm_bytes_est=5e14, model_flops=6e17,
        )
        assert t.t_compute == pytest.approx(19.83, rel=1e-3)
        assert t.t_memory == pytest.approx(5e14 / (256 * 819e9))
        assert t.t_collective == pytest.approx(1.0)
        assert t.bottleneck == "compute"
        assert 0 < t.useful_ratio < 1
        assert t.roofline_fraction == pytest.approx(1.0)  # compute-bound
        coll = RooflineTerms(
            arch="x", shape="s", mesh="m", chips=256,
            hlo_flops=1e16, hlo_bytes=1e15, coll_bytes_link=5e11,
            hbm_bytes_est=5e14, model_flops=6e15,
        )
        assert coll.bottleneck == "collective"
        assert coll.roofline_fraction < 0.1

    def test_model_flops_kinds(self):
        cfg = get_config("qwen2.5-3b")
        n = 3e9
        tr = model_flops(cfg, SHAPES["train_4k"], n, n)
        pf = model_flops(cfg, SHAPES["prefill_32k"], n, n)
        dc = model_flops(cfg, SHAPES["decode_32k"], n, n)
        assert tr == pytest.approx(6 * n * 256 * 4096)
        assert pf == pytest.approx(2 * n * 32 * 32768)
        assert dc == pytest.approx(2 * n * 128)


def test_smoke_configs_are_small():
    for arch, cfg in ARCHS.items():
        sc = smoke_config(cfg)
        assert sc.d_model <= 256 and sc.vocab <= 1024
        assert sc.family == cfg.family
