"""Sharded-cluster layer: spec, partitioners, degeneracy, fleets.

Contract, strongest first:

  1. The partitioners are *pure numpy functions* of (keys, spec), shared
     by every backend (property-tested): hash partitioning balanced over
     the key space, range partitioning monotone with near-equal widths,
     replica sets distinct ring prefixes, op assignment deterministic
     with writes pinned to the primary.
  2. Degeneracy: a trivial ``ClusterSpec`` (one node, replication 1, no
     route hop, no overrides) is *byte-identical* to the plain
     single-host path for every registered engine on both loop backends
     -- same throughput, same winner, same tails -- and bit-identical on
     the jax grid (the cluster layer rides on the single-host
     equivalence proofs).
  3. Mid-run degrade semantics: ``io_degrade=g`` with ``T_degrade=0`` is
     bitwise the same run as ``L_io * g``, and an onset beyond the run
     horizon is bitwise the same as no degrade at all, on both loops.
  4. A real fleet agrees across backends: 4-node hot-shard sweep, jax
     fleet throughput within 1% of the loop and fleet tails within the
     histogram binning bound; op-stream shares identical (pure numpy).
  5. Spec validation rejects malformed fleets eagerly; specs and cluster
     artifacts JSON-round-trip.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import workloads
from repro.core.conformance import CONTRACTS
from repro.core.cluster import (
    ClusterSpec,
    assign_ops,
    replica_set,
    shard_of,
    sweep_cluster,
)
from repro.core.engines import available_engines, get_engine, run_trace
from repro.core.experiment import (
    Experiment,
    RunArtifact,
    RunOptions,
    Scenario,
)
from repro.core.sim import SimConfig, US, simulate, simulate_compiled

from _hypothesis_support import given, settings, st  # optional shim

ENGINES = sorted({cls.engine_name for cls in available_engines().values()})


# -- 1. partitioners ---------------------------------------------------------


class TestPartitioners:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 12), st.integers(1, 8))
    def test_hash_balanced_over_key_space(self, n_nodes, scale):
        # Uniform coverage of the key space must land near-uniformly on
        # the shards -- the property that makes "hash" the scattered
        # partition (skew then comes only from the workload's key
        # popularity, never from the partitioner itself).
        n_keys = 512 * n_nodes * scale
        spec = ClusterSpec(n_nodes=n_nodes, partition="hash")
        shard = shard_of(np.arange(n_keys), spec, n_keys)
        counts = np.bincount(shard, minlength=n_nodes)
        assert counts.sum() == n_keys
        assert counts.min() > 0
        assert counts.max() <= 2 * n_keys / n_nodes

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 12), st.integers(1, 300))
    def test_range_monotone_near_equal_widths(self, n_nodes, extra):
        n_keys = n_nodes + extra
        spec = ClusterSpec(n_nodes=n_nodes, partition="range")
        shard = shard_of(np.arange(n_keys), spec, n_keys)
        assert shard[0] == 0 and shard[-1] == n_nodes - 1
        assert np.all(np.diff(shard) >= 0)          # contiguous ranges
        counts = np.bincount(shard, minlength=n_nodes)
        assert counts.min() >= n_keys // n_nodes
        assert counts.max() <= -(-n_keys // n_nodes)  # ceil

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 12), st.integers(0, 11))
    def test_replica_set_is_distinct_ring_prefix(self, n_nodes, shard):
        shard %= n_nodes
        for repl in range(1, n_nodes + 1):
            spec = ClusterSpec(n_nodes=n_nodes, replication=repl)
            rs = replica_set(shard, spec)
            assert len(rs) == repl == len(set(rs))
            assert rs[0] == shard
            assert all(0 <= n < n_nodes for n in rs)
            assert rs == tuple((shard + j) % n_nodes for j in range(repl))

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_assignment_deterministic_writes_at_primary(self, seed):
        rng = np.random.default_rng(seed)
        n_keys, n_ops = 400, 300
        keys = rng.integers(0, n_keys, n_ops)
        is_write = rng.random(n_ops) < 0.3
        spec = ClusterSpec(n_nodes=5, replication=3,
                           replica_policy="spread")
        node = assign_ops(keys, is_write, spec, n_keys)
        # deterministic: a second call is byte-identical
        assert node.dtype == np.int64
        assert np.array_equal(node,
                              assign_ops(keys, is_write, spec, n_keys))
        assert np.all((0 <= node) & (node < spec.n_nodes))
        shard = shard_of(keys, spec, n_keys)
        # writes never leave the primary; spread reads stay on a replica
        assert np.array_equal(node[is_write], shard[is_write])
        for i in np.flatnonzero(~is_write):
            assert node[i] in replica_set(int(shard[i]), spec)
        # replication=1 spread degenerates to the primary assignment
        one = dataclasses.replace(spec, replication=1)
        assert np.array_equal(assign_ops(keys, is_write, one, n_keys),
                              shard)

    def test_migrate_reassigns_only_tail_of_stream(self):
        n_keys, n_ops = 200, 400
        keys = np.arange(n_ops) % n_keys
        is_write = np.zeros(n_ops, dtype=bool)
        spec = ClusterSpec(n_nodes=4, migrate={"shard": 0, "to": 2,
                                               "at_frac": 0.5})
        shard = shard_of(keys, spec, n_keys)
        node = assign_ops(keys, is_write, spec, n_keys)
        cut = n_ops // 2
        assert np.array_equal(node[:cut], shard[:cut])
        moved = shard[cut:] == 0
        assert moved.any()
        assert np.all(node[cut:][moved] == 2)
        assert np.array_equal(node[cut:][~moved], shard[cut:][~moved])


# -- 2. spec validation + round-trip -----------------------------------------


class TestClusterSpec:
    def test_round_trip_and_key(self):
        spec = ClusterSpec(
            n_nodes=4, partition="range", replication=2,
            replica_policy="spread", L_route_us=5.0,
            node_overrides={"1": {"io_degrade": 4.0,
                                  "T_degrade_us": 2000.0}},
            migrate={"shard": 0, "to": 2, "at_frac": 0.5})
        assert ClusterSpec.from_dict(spec.to_dict()) == spec
        assert spec.key() == ClusterSpec.from_dict(spec.to_dict()).key()
        assert not spec.is_trivial
        assert ClusterSpec().is_trivial
        assert not ClusterSpec(L_route_us=1.0).is_trivial

    @pytest.mark.parametrize("kw", [
        {"n_nodes": 0},
        {"partition": "modulo"},
        {"n_nodes": 2, "replication": 3},
        {"replica_policy": "nearest"},
        {"L_route_us": -1.0},
        {"n_nodes": 2, "node_overrides": {"9": {"R_io": 1e5}}},
        {"node_overrides": {"x": {"R_io": 1e5}}},
        {"node_overrides": {"0": {"bogus": 1.0}}},
        {"node_overrides": {"0": {"R_io": "fast"}}},
        {"n_nodes": 2, "migrate": {"shard": 0}},
        {"n_nodes": 2, "migrate": {"shard": 0, "to": 0, "at_frac": 0.5}},
        {"n_nodes": 2, "migrate": {"shard": 0, "to": 1, "at_frac": 2.0}},
        {"n_nodes": 2, "migrate": {"shard": 5, "to": 1, "at_frac": 0.5}},
    ])
    def test_validation_rejects(self, kw):
        with pytest.raises(ValueError):
            ClusterSpec(**kw)

    def test_from_dict_rejects_unknown_fields(self):
        with pytest.raises(ValueError, match="unknown ClusterSpec field"):
            ClusterSpec.from_dict({"n_nodes": 2, "quorum": 1})

    def test_node_config_overrides_and_seed(self):
        cfg = SimConfig(P=8, seed=7)
        spec = ClusterSpec(
            n_nodes=3,
            node_overrides={"1": {"L_io_us": 100.0, "io_degrade": 2.0,
                                  "T_degrade_us": 500.0, "n_ssd": 2}})
        assert spec.node_config(cfg, 0) is cfg       # identity on node 0
        c1 = spec.node_config(cfg, 1)
        assert c1.L_io == pytest.approx(100.0 * US)
        assert c1.io_degrade == 2.0
        assert c1.T_degrade == pytest.approx(500.0 * US)
        assert c1.n_ssd == 2 and c1.seed == 8
        c2 = spec.node_config(cfg, 2)
        assert c2.seed == 9 and c2.L_io == cfg.L_io


# -- 3. degrade semantics ----------------------------------------------------


def _hash_trace(n_keys=1_500, n_wl_ops=700, seed=3):
    store = get_engine("hash-index")(n_keys, seed=6)
    wl = workloads.create_workload("uniform", n_keys, n_wl_ops,
                                   read_write=(1, 0), seed=seed)
    return run_trace(store, wl)


class TestDegradeSemantics:
    def test_degrade_from_t0_is_lio_scaling_bitwise(self):
        tr = _hash_trace()
        base = dict(P=10, seed=7, n_threads=12, n_ssd=2, R_io=250e3)
        deg = SimConfig(**base, io_degrade=4.0, T_degrade=0.0)
        sc4 = SimConfig(**base, L_io=4 * SimConfig().L_io)
        # a fresh source per run: as_source() carries replay-cursor state
        for run in (lambda c: simulate_compiled(c, tr.trace, 400, None,
                                                False),
                    lambda c: simulate(c, tr.trace.as_source(), 400, None,
                                       False)):
            a, b = run(deg), run(sc4)
            assert a.throughput == b.throughput      # bitwise, incl. jitter
            assert a.time == b.time

    def test_degrade_beyond_horizon_is_inert_bitwise(self):
        tr = _hash_trace()
        base = dict(P=10, seed=7, n_threads=12, n_ssd=2, R_io=250e3)
        late = SimConfig(**base, io_degrade=4.0, T_degrade=10.0)
        plain = SimConfig(**base)
        for run in (lambda c: simulate_compiled(c, tr.trace, 400, None,
                                                False),
                    lambda c: simulate(c, tr.trace.as_source(), 400, None,
                                       False)):
            a, b = run(late), run(plain)
            assert a.throughput == b.throughput
            assert a.time == b.time


# -- 4. degeneracy: trivial spec == single-host path -------------------------


def _tiny_scenario(engine, **kw):
    base = dict(engine=engine, workload="zipf",
                workload_kwargs={"exponent": 0.9, "read_write": (1, 0),
                                 "seed": 3},
                n_keys=1_500, n_wl_ops=800, n_ops=250,
                latencies_us=(2.0,), thread_candidates=(8,))
    base.update(kw)
    return Scenario(**base)


class TestDegeneracy:
    @pytest.mark.parametrize("engine", ENGINES)
    @pytest.mark.parametrize("backend", ["loop", "generic"])
    def test_trivial_spec_byte_identical_loops(self, engine, backend):
        # The plain path has no "generic" backend; the generic and
        # compiled loops are bit-identical by contract, so the trivial
        # cluster on either loop must match the plain compiled loop.
        scenario = _tiny_scenario(
            engine, arrival={"kind": "poisson", "rate": 120e3, "seed": 5})
        plain = Experiment(scenario, RunOptions(
            backend="loop", collect_percentiles=True)).run()
        triv = Experiment(
            dataclasses.replace(scenario, cluster={"n_nodes": 1}),
            RunOptions(backend=backend, collect_percentiles=True)).run()
        assert len(plain.rows) == len(triv.rows)
        for ra, rb in zip(plain.rows, triv.rows):
            assert ra.throughput == rb.throughput    # byte-for-byte
            assert ra.n_threads == rb.n_threads
            assert ra.per_thread == rb.per_thread
            assert ra.tail == rb.tail
            assert rb.nodes is not None and len(rb.nodes) == 1

    @pytest.mark.parametrize("engine", ENGINES)
    def test_trivial_spec_bit_identical_jax(self, engine):
        scenario = _tiny_scenario(engine, n_ops=150)
        opts = RunOptions(backend="jax")
        plain = Experiment(scenario, opts).run()
        triv = Experiment(
            dataclasses.replace(scenario, cluster={"n_nodes": 1}),
            opts).run()
        for ra, rb in zip(plain.rows, triv.rows):
            assert ra.throughput == rb.throughput
            assert ra.n_threads == rb.n_threads


# -- 5. fleets: cross-backend agreement + artifact shape ---------------------


FLEET = {"n_nodes": 4, "partition": "hash", "L_route_us": 5.0,
         "replication": 2, "replica_policy": "spread"}


class TestFleet:
    def test_sweep_cluster_fleet_is_sum_of_nodes(self):
        tr = _hash_trace(n_wl_ops=900)
        wl = workloads.create_workload("uniform", 1_500, 900,
                                       read_write=(1, 0), seed=3)
        # the trace drops warmup ops; align the key stream with it
        keys = wl.keys[-tr.trace.n_ops:]
        is_write = wl.is_write[-tr.trace.n_ops:]
        cfg = SimConfig(P=10, seed=7, n_ssd=2, R_io=250e3)
        spec = ClusterSpec(**{k: v for k, v in FLEET.items()})
        pts = sweep_cluster(cfg, tr.trace, keys, is_write, spec,
                            [2.0 * US], (8,), n_ops=300)
        (pt,) = pts
        assert len(pt.nodes) == spec.n_nodes
        active = [nc for nc in pt.nodes if nc.n_ops]
        assert sum(nc.share for nc in active) == pytest.approx(1.0)
        assert pt.result.throughput == pytest.approx(
            sum(nc.throughput for nc in active))
        assert sum(nc.n_ops for nc in pt.nodes) == 300

    def test_fleet_loop_vs_jax_within_bounds(self):
        scenario = _tiny_scenario(
            "hash-index", n_wl_ops=1_600, n_ops=800,
            workload_kwargs={"exponent": 1.2, "read_write": (1, 0),
                             "seed": 3},
            cluster=dict(FLEET),
            arrival={"kind": "poisson", "rate": 300e3, "seed": 11})
        loop = Experiment(scenario, RunOptions(
            backend="loop", collect_percentiles=True)).run()
        grid = Experiment(scenario, RunOptions(
            backend="jax", collect_percentiles=True)).run()
        # the documented fleet contract: n_ops=800 is the contract's
        # reference size, so the tolerances apply unscaled
        contract = CONTRACTS["cluster-jax-vs-loop"]
        for ra, rb in zip(loop.rows, grid.rows):
            assert ra.n_threads == rb.n_threads
            rel = abs(ra.throughput - rb.throughput) / ra.throughput
            assert rel <= contract.throughput_tol
            # shares are pure numpy -- identical, not just close
            assert [n["share"] for n in ra.nodes] == \
                   [n["share"] for n in rb.nodes]
            for f, tol in (("p50_us", contract.p50_tol),
                           ("p99_us", contract.p99_tol)):
                rel_t = (abs(ra.tail[f] - rb.tail[f])
                         / max(ra.tail[f], rb.tail[f]))
                assert rel_t <= tol, (f, ra.tail, rb.tail)
        # cluster artifacts (fleet tail + per-node dicts) round-trip
        assert RunArtifact.from_json(loop.to_json()) == loop
