"""Per-architecture smoke tests (reduced configs) + decode parity."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable, smoke_config
from repro.models.layers import init_params
from repro.train.train_step import TrainHParams, init_train_state, make_train_step
from repro.zoo import get_api

B, S = 2, 32


def _batch(cfg, rng, seq=S):
    batch = {"tokens": jax.random.randint(rng, (B, seq), 0, cfg.vocab)}
    s_total = seq
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.n_patches, cfg.vision_dim)) * 0.02
        s_total = seq + cfg.n_patches
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, cfg.n_frames, cfg.d_model)) * 0.02
    batch["targets"] = jax.random.randint(rng, (B, s_total), 0, cfg.vocab)
    batch["loss_mask"] = jnp.ones((B, s_total), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
class TestSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = smoke_config(ARCHS[arch])
        api = get_api(cfg)
        params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1))
        logits, aux = jax.jit(lambda p, b: api.logits(p, b, cfg))(params, batch)
        s_total = batch["targets"].shape[1]
        assert logits.shape == (B, s_total, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        assert bool(jnp.isfinite(aux))

    def test_train_step_no_nan(self, arch):
        cfg = smoke_config(ARCHS[arch])
        api = get_api(cfg)
        hp = TrainHParams(total_steps=10, warmup=1)
        step = jax.jit(make_train_step(api, cfg, hp), donate_argnums=0)
        params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
        state = init_train_state(params, hp)
        state, metrics = step(state, _batch(cfg, jax.random.PRNGKey(1)))
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        assert float(metrics["loss"]) < 2.0 * jnp.log(cfg.vocab)

    def test_decode_step_runs(self, arch):
        cfg = smoke_config(ARCHS[arch])
        api = get_api(cfg)
        params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
        cache = api.init_cache(cfg, B, 64)
        if cfg.family == "encdec":
            from repro.models import whisper
            frames = jax.random.normal(
                jax.random.PRNGKey(2), (B, cfg.n_frames, cfg.d_model)) * 0.02
            enc = whisper.encode(params, frames, cfg)
            cache["xk"], cache["xv"] = whisper.precompute_cross_kv(params, enc, cfg)
        lg, cache2 = jax.jit(lambda p, c, t: api.decode(p, c, t, cfg))(
            params, cache, jnp.ones((B, 1), jnp.int32))
        assert lg.shape == (B, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
        assert int(cache2["pos"][0]) == 1


# forward contracts (S,S) at once, decode contracts (1,S_max) with masking:
# different accumulation shapes differ by a few bf16 ulps, so tolerances are
# relative-1e-2 for bf16 paths (MoE capacity & mamba chunk paths are looser).
_PARITY_TOL = {
    "qwen2.5-3b": 1e-2, "starcoder2-3b": 1e-2, "qwen1.5-110b": 1e-2,
    "llama3-405b": 1e-2, "rwkv6-3b": 1e-2,
    "deepseek-moe-16b": 2e-2, "qwen2-moe-a2.7b": 2e-2,
    "zamba2-7b": 5e-2, "whisper-small": 2e-2, "llava-next-mistral-7b": 1e-2,
}


@pytest.mark.parametrize(
    "arch", ["qwen2.5-3b", "starcoder2-3b", "deepseek-moe-16b", "zamba2-7b",
             "rwkv6-3b", "whisper-small"]
)
def test_decode_matches_forward(arch):
    """Step-by-step decode reproduces the teacher-forced forward pass."""
    cfg = smoke_config(ARCHS[arch])
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=64.0)  # no capacity drops in parity
    api = get_api(cfg)
    params = init_params(api.param_specs(cfg), jax.random.PRNGKey(1))
    Sp = 10
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, Sp), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frames, cfg.d_model)) * 0.02
    full, _ = api.logits(params, batch, cfg, remat=False)
    cache = api.init_cache(cfg, B, Sp + 2)
    if cfg.family == "encdec":
        from repro.models import whisper
        enc = whisper.encode(params, batch["frames"], cfg)
        cache["xk"], cache["xv"] = whisper.precompute_cross_kv(params, enc, cfg)
    dec = jax.jit(lambda p, c, t: api.decode(p, c, t, cfg))
    outs = []
    for i in range(Sp):
        lg, cache = dec(params, cache, toks[:, i : i + 1])
        outs.append(lg[:, 0])
    step = jnp.stack(outs, axis=1).astype(jnp.float32)
    fullf = full.astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(step - fullf))) / (
        float(jnp.max(jnp.abs(fullf))) + 1e-9)
    assert rel < _PARITY_TOL[arch], rel


def test_sliding_window_ring_parity():
    """Ring-buffer decode == full forward with the same sliding window."""
    cfg = smoke_config(ARCHS["starcoder2-3b"]).replace(sliding_window=8)
    api = get_api(cfg)
    params = init_params(api.param_specs(cfg), jax.random.PRNGKey(1))
    Sp = 20  # > 2x window: the ring wraps
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, Sp), 0, cfg.vocab)
    full, _ = api.logits(params, {"tokens": toks}, cfg, remat=False)
    cache = api.init_cache(cfg, B, Sp)
    assert cache["k"].shape[2] == 8  # ring is window-sized
    dec = jax.jit(lambda p, c, t: api.decode(p, c, t, cfg))
    outs = []
    for i in range(Sp):
        lg, cache = dec(params, cache, toks[:, i : i + 1])
        outs.append(lg[:, 0])
    step = jnp.stack(outs, axis=1).astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(step - full.astype(jnp.float32)))) / (
        float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 1e-2, rel


def test_long_500k_applicability_table():
    """The DESIGN.md SS5 skip table is enforced in code."""
    runs = {a: shape_applicable(get_config(a), SHAPES["long_500k"])[0]
            for a in ARCHS}
    assert runs["rwkv6-3b"] and runs["zamba2-7b"]
    assert runs["llava-next-mistral-7b"] and runs["starcoder2-3b"]  # SWA
    for a in ("qwen2.5-3b", "qwen1.5-110b", "llama3-405b",
              "deepseek-moe-16b", "qwen2-moe-a2.7b", "whisper-small"):
        assert not runs[a], a
