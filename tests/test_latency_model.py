"""Unit + property tests for the paper's closed-form throughput models."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st  # optional-hypothesis shim

from repro.core.latency_model import (
    OpParams,
    PAPER_EXAMPLE,
    SystemParams,
    US,
    cost_performance_ratio,
    fit_p_tsw_from_memory_only,
    lstar_best,
    lstar_mem,
    normalized_throughput,
    theta_best_inv,
    theta_extended_inv,
    theta_mask_inv,
    theta_mem_inv,
    theta_multi_inv,
    theta_prob_inv,
    theta_single_inv,
)

L_GRID = np.array([0.1, 0.3, 0.5, 1, 2, 3, 5, 8, 10]) * US


class TestPaperValues:
    """The worked example of Table 1 / Sec. 3 quotes concrete numbers."""

    def test_E(self):
        assert PAPER_EXAMPLE.E == pytest.approx(7.1 * US)

    def test_lstar_memory_only(self):
        # Eq. 4: 10 * (0.1 + 0.05) = 1.5 us
        assert lstar_mem(PAPER_EXAMPLE) == pytest.approx(1.5 * US)

    def test_lstar_with_io(self):
        # Eq. 8: 1.5 + 10*7.1/10 = 8.6 us
        assert lstar_best(PAPER_EXAMPLE) == pytest.approx(8.6 * US)

    def test_masking_degradation_at_5us(self):
        # Sec. 3.2.1: "the masking-only model predicts 29% throughput
        # degradation at a memory latency of 5 usec"
        norm = normalized_throughput(theta_mask_inv, np.array([5 * US]))
        assert 1 - norm[0] == pytest.approx(0.29, abs=0.01)

    def test_prob_degradation_at_5us(self):
        # Sec. 3.2.2: "The degradation is much smaller, 7%"
        norm = normalized_throughput(theta_prob_inv, np.array([5 * US]))
        assert 1 - norm[0] == pytest.approx(0.07, abs=0.015)

    def test_cpr_table6_ranges(self):
        # Table 6, c = 0.4: flash 1.19-1.50, compressed DRAM 1.23-1.36
        lo = cost_performance_ratio(0.4, 0.2, 0.19)
        hi = cost_performance_ratio(0.4, 0.15, 0.02)
        assert 1.15 < lo < 1.25 and 1.4 < hi < 1.55
        lo = cost_performance_ratio(0.4, 0.5, 0.02)
        hi = cost_performance_ratio(0.4, 1 / 3, 0.0)
        assert 1.2 < lo < 1.3 and 1.3 < hi < 1.4


class TestModelOrdering:
    def test_mask_le_prob_le_best(self):
        """Throughputs: masking-only <= probabilistic <= best-case (Fig. 3)."""
        mask = 1 / theta_mask_inv(L_GRID)
        prob = 1 / theta_prob_inv(L_GRID)
        best = 1 / theta_best_inv(L_GRID)
        assert np.all(mask <= prob * 1.0001)
        assert np.all(prob <= best * 1.0001)

    def test_monotone_in_latency(self):
        for fn in (theta_single_inv, theta_mem_inv, theta_mask_inv,
                   theta_prob_inv, theta_best_inv):
            inv = fn(L_GRID)
            assert np.all(np.diff(inv) >= -1e-12), fn.__name__

    def test_mem_only_flat_then_linear(self):
        p = PAPER_EXAMPLE
        inv = theta_mem_inv(L_GRID, p)
        flat = 1 / (p.T_mem + p.T_sw)
        assert 1 / inv[0] == pytest.approx(flat)
        # beyond the knee: slope L/P
        assert inv[-1] == pytest.approx(L_GRID[-1] / p.P, rel=1e-6)


@st.composite
def op_params(draw):
    return OpParams(
        M=draw(st.integers(1, 20)),
        T_mem=draw(st.floats(0.05, 0.3)) * US,
        T_io_pre=draw(st.floats(0.5, 6.0)) * US,
        T_io_post=draw(st.floats(0.1, 4.0)) * US,
        T_sw=draw(st.floats(0.01, 0.2)) * US,
        P=draw(st.integers(2, 16)),
        S=draw(st.sampled_from([0.25, 0.5, 1.0, 2.0])),
    )


class TestProperties:
    @settings(max_examples=30, deadline=None)
    @given(op_params(), st.floats(0.1, 10.0), st.integers(6, 16))
    def test_prob_between_mask_and_best(self, p, l_us, P):
        # the Fig. 3 ordering; at P<=4 corners the additive-wait form of
        # Theta_prob can exceed Theta_mask's max-form by ~1%, so the
        # property is asserted in the paper regime P>=6.
        p = OpParams(**{**p.__dict__, "P": P})
        L = np.array([l_us * US])
        mask = theta_mask_inv(L, p)[0]
        prob = theta_prob_inv(L, p)[0]
        best = theta_best_inv(L, p)[0]
        assert best <= prob * 1.001
        assert prob <= mask * 1.001

    @settings(max_examples=30, deadline=None)
    @given(op_params())
    def test_dram_plateau(self, p):
        """At DRAM latency every model reaches the latency-free plateau."""
        L = np.array([0.05 * US])
        plateau = p.S * ((p.M / p.S) * (p.T_mem + p.T_sw) + p.E)
        assert theta_prob_inv(L, p)[0] <= plateau * 1.05

    @settings(max_examples=20, deadline=None)
    @given(op_params(), st.floats(0.2, 0.99))
    def test_tiering_improves(self, p, rho):
        """Eq. 15: offloading less (smaller rho) never hurts throughput."""
        L = np.array([6 * US])
        full = theta_prob_inv(L, p, sysp=SystemParams(rho=1.0))[0]
        part = theta_prob_inv(L, p, sysp=SystemParams(rho=rho))[0]
        assert part <= full * 1.001

    @settings(max_examples=20, deadline=None)
    @given(op_params(), st.floats(0.01, 0.2), st.integers(6, 16))
    def test_eviction_hurts(self, p, eps, P):
        # Model artifact (documented): post-eviction stalls drain the
        # prefetch queue like post-IO subops (Sec. 3.2.3), so at P<=4 or
        # with the S-split amplifying per-IO M, the predicted net effect
        # can be slightly positive (up to ~4% at P=2). In the paper's base
        # regime (S=1, P>=6) eviction never helps; assert the property there.
        p = OpParams(**{**p.__dict__, "P": P, "S": 1.0})
        L = np.array([5 * US])
        clean = theta_prob_inv(L, p, sysp=SystemParams(eps=0.0))[0]
        evict = theta_prob_inv(L, p, sysp=SystemParams(eps=eps))[0]
        assert evict >= clean * 0.999

    def test_extended_io_caps(self):
        """Eq. 14: the SSD bandwidth/IOPS terms cap the throughput."""
        p = PAPER_EXAMPLE
        slow_ssd = SystemParams(R_io=50e3)
        inv = theta_extended_inv(np.array([0.1 * US]), p, slow_ssd)
        assert 1 / inv[0] <= 50e3 * 1.001


def test_fit_p_tsw_roundtrip():
    p = OpParams(P=12, T_sw=0.05 * US)
    th = 1 / theta_mem_inv(L_GRID, p)
    P_est, tsw_est = fit_p_tsw_from_memory_only(L_GRID, th, p.T_mem)
    assert P_est == 12
    assert tsw_est == pytest.approx(p.T_sw, rel=0.05)
