"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_kv_gather import paged_decode_attention
from repro.kernels.ref import (
    flash_attention_ref,
    paged_decode_attention_ref,
    wkv6_ref,
)
from repro.kernels.wkv6 import wkv6


def _tol(dt):
    return 3e-2 if dt == jnp.bfloat16 else 5e-5


FLASH_CASES = [
    # (B, S, Hq, Hkv, D, causal, window, dtype, bq, bk)
    (2, 256, 4, 2, 64, True, None, jnp.float32, 128, 128),
    (1, 512, 2, 2, 128, True, None, jnp.bfloat16, 128, 256),
    (2, 384, 4, 1, 64, False, None, jnp.float32, 128, 128),
    (1, 512, 4, 2, 64, True, 128, jnp.float32, 128, 128),
    (1, 256, 8, 8, 128, True, None, jnp.bfloat16, 64, 64),
    (3, 128, 2, 1, 32, True, None, jnp.float32, 64, 64),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    B, S, Hq, Hkv, D, causal, win, dt, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dt)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dt)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dt)
    out = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, sliding_window=win, block_q=bq, block_k=bk,
        interpret=True,
    ).transpose(0, 2, 1, 3)
    ref = flash_attention_ref(q, k, v, causal, win)
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), atol=_tol(dt), rtol=0
    )


PAGED_CASES = [
    # (B, Hq, Hkv, D, page, ppseq, n_buf, dtype)
    (2, 4, 2, 64, 16, 8, 2, jnp.float32),
    (3, 8, 2, 128, 32, 4, 3, jnp.bfloat16),
    (1, 2, 1, 64, 8, 16, 4, jnp.float32),
    (4, 8, 8, 64, 16, 6, 2, jnp.bfloat16),
    (2, 16, 2, 128, 64, 3, 2, jnp.float32),
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_decode_matches_ref(case):
    B, Hq, Hkv, D, page, ppseq, n_buf, dt = case
    P = 2 * B * ppseq
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dt)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D), dt)
    vp = jax.random.normal(ks[2], (P, page, Hkv, D), dt)
    bt = jax.random.permutation(jax.random.PRNGKey(3), P)[: B * ppseq]
    bt = bt.reshape(B, ppseq).astype(jnp.int32)
    lengths = jnp.array([(i * 53 + 17) % (page * ppseq) + 1 for i in range(B)],
                        jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lengths, n_buffers=n_buf,
                                 interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), atol=_tol(dt), rtol=0
    )


def test_paged_decode_empty_and_full_sequences():
    """Edge cases: a length-1 sequence and an exactly-full page table."""
    B, Hq, Hkv, D, page, ppseq = 2, 4, 2, 64, 8, 4
    P = 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, Hkv, D), jnp.float32)
    bt = jnp.arange(B * ppseq, dtype=jnp.int32).reshape(B, ppseq)
    lengths = jnp.array([1, page * ppseq], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=0)


WKV_CASES = [
    (2, 64, 2, 32, 16, jnp.float32),
    (1, 128, 4, 64, 64, jnp.float32),
    (2, 64, 2, 64, 32, jnp.bfloat16),
    (1, 96, 1, 32, 32, jnp.float32),
]


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv6_matches_ref(case):
    B, S, H, D, chunk, dt = case
    ks = [jax.random.normal(jax.random.PRNGKey(i), (B, S, H, D)) * 0.5
          for i in range(3)]
    r, k, v = (x.astype(dt) for x in ks)
    lw = -jnp.exp(jax.random.normal(jax.random.PRNGKey(7), (B, S, H, D)) * 0.5)
    u = jax.random.normal(jax.random.PRNGKey(9), (H, D)) * 0.3
    out = wkv6(r, k, v, lw, u, chunk=chunk, interpret=True)
    ref = wkv6_ref(r, k, v, lw, u)
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
    np.testing.assert_allclose(
        out.astype(jnp.float32) / scale, ref.astype(jnp.float32) / scale,
        atol=_tol(dt), rtol=0,
    )


def test_flash_attention_jnp_twin_agrees():
    """The model-zoo pure-jnp flash (custom VJP) and the Pallas kernel are
    the same algorithm -- cross-check them against each other."""
    from repro.models.layers import attention

    B, S, Hq, Hkv, D = 2, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    a = attention(q, k, v, causal=True, block_kv=128)
    b = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, block_q=128, block_k=128, interpret=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(a, b, atol=5e-5, rtol=0)
